#!/usr/bin/env python3
"""Compare a freshly-measured BENCH_*.json against the committed baseline.

Usage: check_bench_regression.py BASELINE.json FRESH.json [--tolerance 0.20]

Every BENCH file is a flat ``{"bench": ..., "unit": ..., "results": {key: value}}``
object (see rust/benches/perf.rs).  Keys are compared only when present in
both files; higher is better for throughput-style keys, lower is better for
``*_walltime_s`` and ``*_peak_rss_mib`` keys.  A relative regression beyond the tolerance on any
shared key fails the check (exit 1).  A missing or unreadable baseline is a
warn-pass (exit 0): the first run on a new machine commits the baseline
instead of failing.

CI-bench caveat: shared runners are noisy, so the gate only re-runs the
cheap sections (GOLF_BENCH_SECTIONS) and uses a generous tolerance.
"""

import json
import sys


def load(path):
    with open(path) as f:
        doc = json.load(f)
    results = doc.get("results", {})
    if not isinstance(results, dict):
        raise ValueError(f"{path}: 'results' is not an object")
    return {k: float(v) for k, v in results.items()}


def main(argv):
    args = [a for a in argv[1:] if not a.startswith("--")]
    tol = 0.20
    for a in argv[1:]:
        if a.startswith("--tolerance"):
            tol = float(a.split("=", 1)[1] if "=" in a else argv[argv.index(a) + 1])
    if len(args) < 2:
        print(__doc__)
        return 2
    baseline_path, fresh_path = args[0], args[1]

    try:
        base = load(baseline_path)
    except (OSError, ValueError, json.JSONDecodeError) as e:
        print(f"WARN: no usable baseline at {baseline_path} ({e}); passing")
        return 0
    try:
        fresh = load(fresh_path)
    except (OSError, ValueError, json.JSONDecodeError) as e:
        print(f"ERROR: fresh bench output unreadable at {fresh_path} ({e})")
        return 1

    shared = sorted(set(base) & set(fresh))
    if not shared:
        print("WARN: baseline and fresh results share no keys; passing")
        return 0

    failed = []
    for k in shared:
        b, f = base[k], fresh[k]
        if b <= 0:
            continue
        lower_is_better = k.endswith("_walltime_s") or k.endswith("_peak_rss_mib")
        # regression = fresh worse than baseline by more than tol
        ratio = (f / b) if lower_is_better else (b / f if f > 0 else float("inf"))
        worse = ratio - 1.0
        status = "FAIL" if worse > tol else "ok"
        print(f"{status:>4}  {k}: baseline {b:.1f} fresh {f:.1f} ({worse:+.1%})")
        if worse > tol:
            failed.append(k)

    if failed:
        print(f"\n{len(failed)} key(s) regressed beyond {tol:.0%}: {', '.join(failed)}")
        return 1
    print(f"\nall {len(shared)} shared keys within {tol:.0%}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
