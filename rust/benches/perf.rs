//! Performance microbenches for the whole stack (EXPERIMENTS.md §Perf):
//!
//! * L3 event-driven simulator: delivered messages/s end to end.
//! * Native backend: batched update + eval throughput at paper shapes.
//! * PJRT backend: the same ops through the AOT artifacts, including the
//!   per-call overhead / batch-size break-even.
//!
//!     cargo bench --bench perf


#![allow(deprecated)] // this suite pins the legacy shims (run/run_batched/run_deployment) bit-for-bit
use golf::data::synthetic::{reuters_like, spambase_like, urls_like, Scale};
use golf::engine::native::NativeBackend;
use golf::engine::pjrt::PjrtBackend;
use golf::engine::{Backend, LearnerKind, StepBatch, StepOp};
use golf::gossip::create_model::Variant;
use golf::gossip::protocol::{run, ExecMode, ProtocolConfig};
use golf::learning::MergeMode;
use golf::util::benchkit::bench;
use golf::util::rng::Rng;
use std::io::Write;

fn batch(rng: &mut Rng, b: usize, d: usize) -> StepBatch {
    let mut sb = StepBatch::default();
    sb.resize(b, d);
    for v in sb.w1.iter_mut().chain(&mut sb.w2).chain(&mut sb.x) {
        *v = rng.normal() as f32;
    }
    for i in 0..b {
        sb.y[i] = rng.sign();
        sb.t1[i] = 1.0 + rng.below(100) as f32;
        sb.t2[i] = 1.0 + rng.below(100) as f32;
    }
    sb
}

/// Resolve where a `BENCH_<name>.json` file lands.  `GOLF_BENCH_OUT` is
/// respected both ways: a value ending in `.json` names the protocol file
/// directly (the pre-kernels behavior; siblings land next to it), anything
/// else is treated as an output directory.
fn bench_out_path(name: &str) -> std::path::PathBuf {
    match std::env::var("GOLF_BENCH_OUT") {
        Err(_) => name.into(),
        Ok(v) if v.ends_with(".json") => {
            let p = std::path::PathBuf::from(v);
            if name == "BENCH_protocol.json" {
                p
            } else {
                p.parent()
                    .unwrap_or_else(|| std::path::Path::new("."))
                    .join(name)
            }
        }
        Ok(v) => {
            std::fs::create_dir_all(&v).ok();
            std::path::Path::new(&v).join(name)
        }
    }
}

/// Write one bench family's results as a flat JSON object so the perf
/// trajectory is tracked from PR to PR.
fn write_bench_json(bench: &str, unit: &str, results: &[(String, f64)]) {
    let path = bench_out_path(&format!("BENCH_{bench}.json"));
    let mut body =
        format!("{{\n  \"bench\": \"{bench}\",\n  \"unit\": \"{unit}\",\n  \"results\": {{\n");
    for (i, (k, v)) in results.iter().enumerate() {
        let comma = if i + 1 < results.len() { "," } else { "" };
        body.push_str(&format!("    \"{k}\": {v:.1}{comma}\n"));
    }
    body.push_str("  }\n}\n");
    match std::fs::File::create(&path).and_then(|mut f| f.write_all(body.as_bytes())) {
        Ok(()) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\ncould not write {}: {e}", path.display()),
    }
}

/// Section filter: `GOLF_BENCH_SECTIONS=protocol,sharded` runs only the
/// named sections (comma-separated); unset runs everything.  The CI
/// regression gate uses this to re-run the cheap sections only.
fn section_enabled(name: &str) -> bool {
    match std::env::var("GOLF_BENCH_SECTIONS") {
        Err(_) => true,
        Ok(v) => v.split(',').any(|s| s.trim() == name),
    }
}

/// Peak resident set size of this process in MiB (`VmHWM` from
/// /proc/self/status), or 0.0 where procfs is unavailable.
fn peak_rss_mib() -> f64 {
    let Ok(s) = std::fs::read_to_string("/proc/self/status") else { return 0.0 };
    for line in s.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: f64 = rest
                .trim()
                .trim_end_matches("kB")
                .trim()
                .parse()
                .unwrap_or(0.0);
            return kb / 1024.0;
        }
    }
    0.0
}

/// A dense synthetic teacher problem sized for node-count scaling runs:
/// `n` training rows (one gossip node each), a deliberately small test set,
/// and d=10 features so walltime measures the event engine, not the kernels.
fn scaling_dataset(seed: u64, n: usize) -> golf::data::Dataset {
    use golf::data::{Dataset, Examples, Matrix};
    let d = 10usize;
    let mut rng = Rng::new(seed ^ 0x5ca1e);
    let teacher: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
    let mut gen = |rows: usize| {
        let mut x = Vec::with_capacity(rows * d);
        let mut y = Vec::with_capacity(rows);
        for _ in 0..rows {
            let row: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            let dot: f32 = row.iter().zip(&teacher).map(|(a, b)| a * b).sum();
            let label = if dot >= 0.0 { 1.0 } else { -1.0 };
            y.push(if rng.chance(0.05) { -label } else { label });
            x.extend(row);
        }
        (Examples::Dense(Matrix::from_vec(rows, d, x)), y)
    };
    let (train, train_y) = gen(n);
    let (test, test_y) = gen(1000);
    Dataset { name: format!("teacher{n}"), train, train_y, test, test_y }
}

fn main() {
    let mut rng = Rng::new(1);
    let mut json: Vec<(String, f64)> = Vec::new();

    if section_enabled("protocol") {
        println!("--- L3 event-driven simulator throughput");
        for (name, ds, cycles) in [
            ("urls 1000 nodes d=10", urls_like(1, Scale(0.1)), 50u64),
            ("spambase 4140 nodes d=57", spambase_like(1, Scale::FULL), 20),
            ("reuters 500 nodes d=9947", reuters_like(1, Scale(0.25)), 10),
        ] {
            let mut msgs = 0u64;
            let r = bench(&format!("event sim: {name}"), 0, 3, || {
                let mut cfg = ProtocolConfig::paper_default(cycles);
                cfg.eval.n_peers = 0; // isolate protocol cost from eval cost
                cfg.eval.at_cycles = vec![cycles];
                let res = run(cfg, &ds);
                msgs = res.stats.messages_sent;
            });
            println!(
                "    -> {:.2} M delivered messages/s",
                r.throughput(msgs as f64) / 1e6
            );
        }

        println!("\n--- event-driven stepping: scalar vs micro-batched (same semantics)");
        for (key, name, ds, cycles) in [
            ("urls", "urls 1000 nodes d=10", urls_like(1, Scale(0.1)), 40u64),
            ("spambase", "spambase 1035 nodes d=57", spambase_like(1, Scale(0.25)), 25),
            ("reuters", "reuters 500 nodes d=9947", reuters_like(1, Scale(0.25)), 8),
        ] {
            let delta = ProtocolConfig::paper_default(1).delta;
            for (mode_key, mode_name, exec) in [
                ("scalar", "scalar        ", ExecMode::Scalar),
                ("microbatch", "microbatch w=0", ExecMode::MicroBatch { coalesce: 0 }),
                (
                    "microbatch_w4",
                    "microbatch w=Δ/4",
                    ExecMode::MicroBatch { coalesce: delta / 4 },
                ),
            ] {
                let mut msgs = 0u64;
                let mut calls = 0u64;
                let r = bench(&format!("event {mode_name}: {name}"), 0, 3, || {
                    let mut cfg = ProtocolConfig::paper_default(cycles);
                    cfg.eval.n_peers = 0;
                    cfg.eval.at_cycles = vec![cycles];
                    cfg.exec = exec;
                    let res = run(cfg, &ds);
                    msgs = res.stats.updates_applied;
                    calls = res.stats.engine_calls;
                });
                let per_s = r.throughput(msgs as f64);
                println!(
                    "    -> {:.2} M delivered messages/s  ({:.1} rows/engine-call)",
                    per_s / 1e6,
                    msgs as f64 / calls.max(1) as f64
                );
                json.push((format!("event_{mode_key}_{key}"), per_s));
            }
        }
    }

    // `kernels` and `pairwise` share one BENCH_kernels.json file, written
    // once after both sections have had their chance to run
    let mut kjson: Vec<(String, f64)> = Vec::new();
    let mut kjson_touched = false;

    if section_enabled("kernels") {
        // ---- dense vs sparse kernels (O(d) vs O(nnz); DESIGN.md §7) -----------
        println!("\n--- kernels: dense vs O(nnz) sparse execution path");
        kjson_touched = true;
        {
            let mut native = NativeBackend::new();
            // (shape key, d, nnz, batch rows): spambase-like, reuters-like, and a
            // URL-collection-like raw feature space
            for (key, d, nnz, b) in [
                ("d60", 60usize, 57usize, 256usize),
                ("d10k", 10_000, 60, 64),
                ("d1m", 1_000_000, 130, 4),
            ] {
                // one set of rows, staged both ways
                let mut idxs: Vec<Vec<u32>> = Vec::with_capacity(b);
                let mut vals: Vec<Vec<f32>> = Vec::with_capacity(b);
                for _ in 0..b {
                    let mut seen = std::collections::HashSet::new();
                    let mut idx: Vec<u32> = Vec::with_capacity(nnz);
                    while idx.len() < nnz {
                        let j = rng.below(d as u64) as u32;
                        if seen.insert(j) {
                            idx.push(j);
                        }
                    }
                    idx.sort_unstable();
                    vals.push(idx.iter().map(|_| rng.normal() as f32).collect());
                    idxs.push(idx);
                }
                let mut dense_sb = StepBatch::default();
                dense_sb.resize(b, d);
                for v in dense_sb.w1.iter_mut().chain(&mut dense_sb.w2) {
                    *v = rng.normal() as f32;
                }
                for i in 0..b {
                    dense_sb.y[i] = rng.sign();
                    dense_sb.t1[i] = 1.0 + rng.below(100) as f32;
                    dense_sb.t2[i] = 1.0 + rng.below(100) as f32;
                    for (&j, &v) in idxs[i].iter().zip(&vals[i]) {
                        dense_sb.x[i * d + j as usize] = v;
                    }
                }
                let mut sparse_sb = dense_sb.clone();
                sparse_sb.resize_for(b, d, true);
                for i in 0..b {
                    sparse_sb.push_sparse_x_row(&idxs[i], &vals[i]);
                }
                let iters = if d >= 1_000_000 { 10 } else { 200 };
                for (vkey, variant) in [("rw", Variant::Rw), ("mu", Variant::Mu)] {
                    let op = StepOp {
                        learner: LearnerKind::Pegasos,
                        variant,
                        hp: 0.01,
                        merge: MergeMode::Average,
                    };
                    let rd = bench(&format!("dense  pegasos {vkey} {key} b={b}"), 2, iters, || {
                        native.step(&op, &mut dense_sb).unwrap();
                    });
                    let rs = bench(&format!("sparse pegasos {vkey} {key} b={b}"), 2, iters, || {
                        native.step(&op, &mut sparse_sb).unwrap();
                    });
                    let speedup = rd.mean_ns / rs.mean_ns;
                    println!(
                        "    -> dense {:.0} ns/update, sparse {:.0} ns/update: speedup x{:.1}",
                        rd.mean_ns / b as f64,
                        rs.mean_ns / b as f64,
                        speedup
                    );
                    kjson.push((format!("dense_{vkey}_{key}"), rd.throughput(b as f64)));
                    kjson.push((format!("sparse_{vkey}_{key}"), rs.throughput(b as f64)));
                    kjson.push((format!("speedup_{vkey}_{key}"), speedup));
                }
            }

            // end-to-end event-driven gossip on reuters: forced dense vs sparse
            println!("\n--- kernels: end-to-end event-driven run, --exec dense vs sparse");
            {
                use golf::gossip::protocol::ExecPath;
                let ds = reuters_like(2, Scale(0.25));
                let mut per_s = [0.0f64; 2];
                for (slot, (pkey, path)) in
                    [("dense", ExecPath::Dense), ("sparse", ExecPath::Sparse)].iter().enumerate()
                {
                    let mut msgs = 0u64;
                    let r = bench(&format!("event sim reuters --exec {pkey}"), 0, 2, || {
                        let mut cfg = ProtocolConfig::paper_default(6);
                        cfg.eval.n_peers = 0;
                        cfg.eval.at_cycles = vec![6];
                        cfg.path = *path;
                        let res = run(cfg, &ds);
                        msgs = res.stats.updates_applied;
                    });
                    per_s[slot] = r.throughput(msgs as f64);
                    kjson.push((format!("protocol_{pkey}_reuters"), per_s[slot]));
                }
                println!("    -> end-to-end speedup x{:.1}", per_s[1] / per_s[0]);
                kjson.push(("speedup_protocol_reuters".into(), per_s[1] / per_s[0]));
            }

            // batched evaluation on a reuters-like sparse test set, vs the same
            // rows densified (the pre-sparse-path evaluator's layout)
            println!("\n--- kernels: batched evaluation, sparse vs densified test set");
            {
                use golf::data::dataset::Examples;
                use golf::data::matrix::Matrix;
                let ds = reuters_like(3, Scale(0.25));
                let d = ds.d();
                let n = ds.n_test();
                let m = 100usize;
                let w: Vec<f32> = (0..m * d).map(|_| rng.normal() as f32).collect();
                let mut dense = vec![0.0f32; n * d];
                for i in 0..n {
                    ds.test.row(i).write_dense(&mut dense[i * d..(i + 1) * d]);
                }
                let dense_ex = Examples::Dense(Matrix::from_vec(n, d, dense));
                let rd = bench(&format!("eval dense  n={n} d={d} m={m}"), 1, 5, || {
                    std::hint::black_box(
                        native
                            .error_counts_examples(&dense_ex, &ds.test_y, &w, m)
                            .unwrap(),
                    );
                });
                let rs = bench(&format!("eval sparse n={n} d={d} m={m}"), 1, 5, || {
                    std::hint::black_box(
                        native
                            .error_counts_examples(&ds.test, &ds.test_y, &w, m)
                            .unwrap(),
                    );
                });
                let speedup = rd.mean_ns / rs.mean_ns;
                println!("    -> eval speedup x{speedup:.1}");
                kjson.push(("eval_dense_reuters".into(), rd.throughput((n * m) as f64)));
                kjson.push(("eval_sparse_reuters".into(), rs.throughput((n * m) as f64)));
                kjson.push(("speedup_eval_reuters".into(), speedup));
            }
        }
    }

    if section_enabled("pairwise") {
        // ---- pairwise AUC objective (DESIGN.md §17): reservoir-pair step vs
        // the pointwise step it replaces, dense and O(nnz) sparse, across
        // reservoir capacities ----------------------------------------------
        use golf::data::dataset::{Examples, Row};
        use golf::data::matrix::Matrix;
        use golf::data::Csr;
        use golf::gossip::create_model::{create_model_pairwise_step, create_model_step};
        use golf::learning::pairwise::{self, PairScratch, PairwiseAuc};
        use golf::learning::{Learner, LinearModel};
        println!("\n--- pairwise: pointwise vs reservoir-pair CREATEMODEL step");
        kjson_touched = true;
        let pool = 256usize;
        for (key, d, nnz) in [("d57_dense", 57usize, 0usize), ("d10k_sparse", 10_000, 60)] {
            let sparse = nnz > 0;
            let mut xrow_idx: Vec<u32> = Vec::new();
            let mut xrow_val: Vec<f32> = Vec::new();
            let mut xrow_dense: Vec<f32> = Vec::new();
            let train = if sparse {
                let mut m = Csr::new(d);
                let mut mk_row = |rng: &mut Rng| {
                    let mut seen = std::collections::HashSet::new();
                    let mut idx: Vec<u32> = Vec::with_capacity(nnz);
                    while idx.len() < nnz {
                        let j = rng.below(d as u64) as u32;
                        if seen.insert(j) {
                            idx.push(j);
                        }
                    }
                    idx.sort_unstable();
                    let val: Vec<f32> = idx.iter().map(|_| rng.normal() as f32).collect();
                    (idx, val)
                };
                for _ in 0..pool {
                    let (idx, val) = mk_row(&mut rng);
                    let entries: Vec<(u32, f32)> =
                        idx.into_iter().zip(val).collect();
                    m.push_row(&entries);
                }
                (xrow_idx, xrow_val) = mk_row(&mut rng);
                Examples::Sparse(m)
            } else {
                let data: Vec<f32> = (0..pool * d).map(|_| rng.normal() as f32).collect();
                xrow_dense = (0..d).map(|_| rng.normal() as f32).collect();
                Examples::Dense(Matrix::from_vec(pool, d, data))
            };
            let x = if sparse {
                Row::Sparse(&xrow_idx, &xrow_val)
            } else {
                Row::Dense(&xrow_dense)
            };
            let w1: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            let w2: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            let learner = Learner::pegasos(0.01);
            let mut last = LinearModel::from_weights(w2.clone(), 12);
            let rp = bench(&format!("pointwise pegasos mu {key}"), 50, 1000, || {
                let m1 = LinearModel::from_weights(w1.clone(), 10);
                std::hint::black_box(create_model_step(
                    Variant::Mu,
                    MergeMode::Average,
                    &learner,
                    m1,
                    &mut last,
                    &x,
                    1.0,
                ));
            });
            kjson.push((format!("pairwise_pointwise_{key}"), rp.throughput(1.0)));
            let auc = PairwiseAuc::new(0.01);
            for k in [4usize, 16, 64] {
                // alternate reservoir labels so half the entries form
                // opposite-class pairs against the local y = +1 example
                let mut res = pairwise::reservoir_new(k);
                for i in 0..k {
                    let yj = if i % 2 == 0 { -1.0 } else { 1.0 };
                    pairwise::offer(&mut res, (i % pool) as u32, yj, i as u64);
                }
                let mut scratch = PairScratch::default();
                let mut last = LinearModel::from_weights(w2.clone(), 12);
                let r = bench(&format!("pairwise  auc     mu {key} K={k}"), 50, 1000, || {
                    let m1 = LinearModel::from_weights(w1.clone(), 10);
                    std::hint::black_box(create_model_pairwise_step(
                        Variant::Mu,
                        MergeMode::Average,
                        &auc,
                        m1,
                        &mut last,
                        &x,
                        1.0,
                        &res,
                        &train,
                        &mut scratch,
                    ));
                });
                println!("    -> x{:.1} the pointwise step", r.mean_ns / rp.mean_ns);
                kjson.push((format!("pairwise_k{k}_{key}"), r.throughput(1.0)));
            }
        }

        // end-to-end event-driven gossip: pointwise pegasos vs pairwise AUC
        println!("\n--- pairwise: end-to-end event-driven run, pegasos vs pairwise-auc");
        {
            let ds = urls_like(6, Scale(0.05));
            let mut per_s = [0.0f64; 2];
            for (slot, pkey) in ["pegasos", "pairwise"].iter().enumerate() {
                let mut updates = 0u64;
                let r = bench(&format!("event sim urls learner={pkey}"), 0, 2, || {
                    let mut cfg = ProtocolConfig::paper_default(20);
                    cfg.eval.n_peers = 0;
                    cfg.eval.at_cycles = vec![20];
                    cfg.seed = 6;
                    if *pkey == "pairwise" {
                        cfg.learner = Learner::pairwise_auc(0.01);
                        cfg.reservoir = 16;
                    }
                    let res = run(cfg, &ds);
                    updates = res.stats.updates_applied;
                });
                per_s[slot] = r.throughput(updates as f64);
                kjson.push((format!("pairwise_protocol_{pkey}_urls"), per_s[slot]));
            }
            println!(
                "    -> pairwise protocol costs x{:.2} the pointwise one",
                per_s[0] / per_s[1].max(1e-12)
            );
        }
    }

    if kjson_touched {
        write_bench_json(
            "kernels",
            "row_updates_per_s (speedup_* keys: dense_ns / sparse_ns; pairwise_*: steps_per_s)",
            &kjson,
        );
    }

    // `scenarios` and `node_scaling` share one BENCH_scenarios.json file,
    // written once after both sections have had their chance to run
    let mut sjson: Vec<(String, f64)> = Vec::new();
    let mut sjson_touched = false;

    if section_enabled("scenarios") {
        // ---- scenario library sweep (DESIGN.md §11): event-driven runs of
        // every built-in timeline on one urls-like network, tracking how much
        // protocol throughput each failure script costs ---------------------
        println!("\n--- scenario library: event-driven run of every built-in");
        sjson_touched = true;
        {
            let ds = urls_like(4, Scale(0.02)); // 200 nodes, >= trace coverage
            for &name in golf::scenario::builtin_names() {
                let scn = golf::scenario::builtin(name).expect("built-in");
                let cycles = scn.cycles_hint.unwrap_or(200);
                scn.validate(ds.n_train(), cycles).expect("built-in fits its hint");
                let mut updates = 0u64;
                let mut blocked = 0u64;
                let r = bench(&format!("scenario {name}: urls 200 nodes"), 0, 2, || {
                    let mut cfg = ProtocolConfig::paper_default(cycles);
                    cfg.eval.n_peers = 0;
                    cfg.eval.at_cycles = vec![cycles];
                    cfg.seed = 4;
                    cfg.scenario = Some(scn.clone());
                    let res = run(cfg, &ds);
                    updates = res.stats.updates_applied;
                    blocked = res.stats.messages_blocked;
                });
                let per_s = r.throughput(updates as f64);
                println!(
                    "    -> {:.2} M applied updates/s ({} partition-blocked)",
                    per_s / 1e6,
                    blocked
                );
                sjson.push((name.replace('-', "_"), per_s));
            }
        }
    }

    // ---- topology subsystem (DESIGN.md §16): generator cost at scale and
    // the protocol-throughput price of graph-constrained peer sampling ----
    if section_enabled("topology") {
        use golf::p2p::{Topology, TopologySpec};
        println!("\n--- topology: generator build cost at 100k nodes");
        sjson_touched = true;
        for name in ["ring:2", "grid", "kreg:4", "ba:3"] {
            let spec = TopologySpec::parse(name).expect("spec").expect("non-complete");
            let n = 100_000usize;
            let mut edges = 0usize;
            let r = bench(&format!("topo build {name} n=100k"), 0, 3, || {
                let t = Topology::build(&spec, n, 9).expect("build");
                edges = t.metrics().edges;
            });
            println!(
                "    -> {:.2} M edges/s ({} edges)",
                r.throughput(edges as f64) / 1e6,
                edges
            );
            sjson.push((
                format!("topo_build_{}_eps", name.replace(':', "")),
                r.throughput(edges as f64),
            ));
        }

        println!("\n--- topology: graph-constrained event-driven runs, urls 1000 nodes");
        {
            let ds = urls_like(4, Scale(0.1)); // 1000 nodes
            let cycles = 30u64;
            let mut base_s = 0.0f64;
            for name in ["complete", "ring:2", "kreg:4", "ba:3"] {
                let mut msgs = 0u64;
                let r = bench(&format!("event sim urls 1000 --topology {name}"), 0, 2, || {
                    let mut cfg = ProtocolConfig::paper_default(cycles);
                    cfg.eval.n_peers = 0;
                    cfg.eval.at_cycles = vec![cycles];
                    cfg.seed = 9;
                    cfg.topology = TopologySpec::parse(name).expect("spec");
                    let res = run(cfg, &ds);
                    msgs = res.stats.messages_sent;
                });
                let per_s = r.throughput(msgs as f64);
                if name == "complete" {
                    base_s = per_s;
                }
                println!(
                    "    -> {:.2} M delivered messages/s (x{:.2} vs complete)",
                    per_s / 1e6,
                    per_s / base_s.max(1e-12)
                );
                sjson.push((
                    format!("topo_urls1k_{}", name.replace(':', "")),
                    per_s,
                ));
            }
        }
    }

    // ---- node-group deployment scaling (DESIGN.md §15): real socket runs
    // at node counts the thread-per-node runtime could not host, tracking
    // walltime, decoded frames/s, and peak RSS, plus the group-runtime
    // scheduling metrics (frames/wake, worst timer lag) ------------------
    if section_enabled("node_scaling") {
        use golf::coordinator::run_deployment;
        use golf::net::deploy::DeployConfig;
        println!("\n--- node-group deployment: 256 / 1k / 4k / 10k real nodes");
        sjson_touched = true;
        for n in [256usize, 1000, 4000, 10_000] {
            let ds = scaling_dataset(8, n);
            let cfg = DeployConfig {
                n_nodes: n,
                node_groups: 0, // auto: thread-ledger budget, floored per group cap
                delta: std::time::Duration::from_millis(100),
                cycles: 3,
                eval_peers: 8,
                eval_at_cycles: vec![3],
                seed: 8,
                ..Default::default()
            };
            let t0 = std::time::Instant::now();
            let report = run_deployment(&cfg, &ds).expect("deployment");
            let wall = t0.elapsed().as_secs_f64();
            let s = &report.stats;
            let frames_s = s.messages_received as f64 / wall.max(1e-12);
            println!(
                "    -> {n} nodes / {} group(s): {:.1}s wall, {} frames ({:.0}/s), \
                 {:.2} frames/wake, reused {}, timer lag max {:.2} ms, peak RSS {:.0} MiB",
                s.node_groups,
                wall,
                s.messages_received,
                frames_s,
                s.frames_per_wake,
                s.conns_reused,
                s.timer_lag_ms_max,
                peak_rss_mib()
            );
            sjson.push((format!("nodes{n}_walltime_s"), wall));
            sjson.push((format!("nodes{n}_frames_per_s"), frames_s));
            sjson.push((format!("nodes{n}_peak_rss_mib"), peak_rss_mib()));
        }
    }

    if sjson_touched {
        write_bench_json(
            "scenarios",
            "applied_updates_per_s (nodes*_ keys: deployment node scaling)",
            &sjson,
        );
    }

    if section_enabled("backend") {
        println!("\n--- native backend: batched MU step");
        let op = StepOp {
            learner: LearnerKind::Pegasos,
            variant: Variant::Mu,
            hp: 0.01,
            merge: MergeMode::Average,
        };
        let mut native = NativeBackend::new();
        for (b, d) in [(128, 10), (1024, 10), (128, 57), (1024, 57), (128, 1024), (128, 10240)] {
            let mut sb = batch(&mut rng, b, d);
            let r = bench(&format!("native mu step b={b} d={d}"), 2, 10, || {
                native.step(&op, &mut sb).unwrap();
            });
            println!("    -> {:.2} M row-updates/s", r.throughput(b as f64) / 1e6);
        }

        println!("\n--- native backend: eval error_counts");
        for (n, d, m) in [(1024, 10, 100), (1024, 57, 100), (600, 9947, 100)] {
            let x: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
            let y: Vec<f32> = (0..n).map(|_| rng.sign()).collect();
            let w: Vec<f32> = (0..m * d).map(|_| rng.normal() as f32).collect();
            let r = bench(&format!("native eval n={n} d={d} m={m}"), 1, 5, || {
                std::hint::black_box(native.error_counts(&x, &y, n, d, &w, m).unwrap());
            });
            println!(
                "    -> {:.2} G dot-products/s",
                r.throughput((n * m) as f64) / 1e9
            );
        }

        let dir = PjrtBackend::default_dir();
        if dir.join("manifest.tsv").exists() {
            println!("\n--- PJRT backend: batched MU step (AOT artifacts, CPU client)");
            let mut pjrt = PjrtBackend::new(&dir).expect("pjrt backend");
            for (b, d) in [(1, 10), (16, 10), (128, 10), (1024, 10), (128, 57), (1024, 57), (128, 1024)] {
                let mut sb = batch(&mut rng, b, d);
                let r = bench(&format!("pjrt mu step b={b} d={d}"), 2, 10, || {
                    pjrt.step(&op, &mut sb).unwrap();
                });
                println!(
                    "    -> {:.3} M row-updates/s (per-call overhead amortized over {b} rows)",
                    r.throughput(b as f64) / 1e6
                );
            }
            println!("\n--- PJRT backend: eval error_counts");
            for (n, d, m) in [(1024, 10, 100), (1024, 57, 100)] {
                let x: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
                let y: Vec<f32> = (0..n).map(|_| rng.sign()).collect();
                let w: Vec<f32> = (0..m * d).map(|_| rng.normal() as f32).collect();
                let r = bench(&format!("pjrt eval n={n} d={d} m={m}"), 1, 5, || {
                    std::hint::black_box(pjrt.error_counts(&x, &y, n, d, &w, m).unwrap());
                });
                println!(
                    "    -> {:.2} G dot-products/s",
                    r.throughput((n * m) as f64) / 1e9
                );
            }
        } else {
            println!("\n(pjrt benches skipped: no artifacts — run `make artifacts`)");
        }

        println!("\n--- L3 hot-path optimization: CREATEMODEL before/after (perf §L3)");
        {
            use golf::data::dataset::Row;
            use golf::gossip::create_model::{create_model, create_model_step};
            use golf::learning::{Learner, LinearModel};
            for d in [57usize, 9947] {
                let learner = Learner::pegasos(0.01);
                let w1: Vec<f32> = (0..d).map(|i| (i % 7) as f32).collect();
                let w2: Vec<f32> = (0..d).map(|i| (i % 5) as f32).collect();
                let x: Vec<f32> = (0..d).map(|i| (i % 3) as f32 * 0.1).collect();
                // BEFORE: reference path — clone incoming + allocating merge
                let before = bench(&format!("createModel MU reference d={d}"), 100, 2000, || {
                    let m1 = LinearModel::from_weights(w1.clone(), 10);
                    let m2 = LinearModel::from_weights(w2.clone(), 12);
                    std::hint::black_box(create_model(
                        Variant::Mu,
                        MergeMode::Average,
                        &learner,
                        m1.clone(), // simulator used to clone for lastModel
                        &m2,
                        &Row::Dense(&x),
                        1.0,
                    ));
                });
                // AFTER: in-place step used by the simulator
                let mut last = LinearModel::from_weights(w2.clone(), 12);
                let after = bench(&format!("createModel MU step      d={d}"), 100, 2000, || {
                    let m1 = LinearModel::from_weights(w1.clone(), 10);
                    std::hint::black_box(create_model_step(
                        Variant::Mu,
                        MergeMode::Average,
                        &learner,
                        m1,
                        &mut last,
                        &Row::Dense(&x),
                        1.0,
                    ));
                });
                println!(
                    "    -> speedup x{:.2} (both include the unavoidable one message-buffer alloc)",
                    before.mean_ns / after.mean_ns
                );
            }
        }

        println!("\n--- merge / model algebra");
        {
            use golf::learning::LinearModel;
            let d = 9947;
            let a = LinearModel::from_weights((0..d).map(|i| i as f32).collect(), 1);
            let b = LinearModel::from_weights((0..d).map(|i| (d - i) as f32).collect(), 2);
            let r = bench("merge d=9947", 10, 100, || {
                std::hint::black_box(LinearModel::merge(&a, &b));
            });
            println!("    -> {:.2} GB/s effective", r.throughput((d * 4 * 3) as f64) / 1e9);
        }
    }

    // ---- sharded executor scaling (DESIGN.md §13): same run, 1/2/4/8
    // shards — results are bit-for-bit identical, so this measures pure
    // execution strategy ------------------------------------------------
    if section_enabled("sharded") {
        println!("\n--- sharded executor: shards=1/2/4/8 on urls 10k nodes");
        let ds = urls_like(5, Scale(1.0));
        let cycles = 10u64;
        let mut base_s = 0.0f64;
        for shards in [1usize, 2, 4, 8] {
            let mut msgs = 0u64;
            let r = bench(&format!("event sim urls 10k --shards {shards}"), 0, 2, || {
                let mut cfg = ProtocolConfig::paper_default(cycles);
                cfg.eval.n_peers = 0;
                cfg.eval.at_cycles = vec![cycles];
                cfg.seed = 5;
                cfg.shards = shards;
                let res = run(cfg, &ds);
                msgs = res.stats.messages_sent;
            });
            let per_s = r.throughput(msgs as f64);
            if shards == 1 {
                base_s = per_s;
            }
            println!(
                "    -> {:.2} M messages/s (x{:.2} vs shards=1)",
                per_s / 1e6,
                per_s / base_s.max(1e-12)
            );
            json.push((format!("sharded_urls10k_s{shards}"), per_s));
        }
    }

    // ---- node-count scaling toward 1M nodes: one >= 100k-node run with
    // walltime + peak RSS, shards=1 vs the machine's parallelism --------
    if section_enabled("scale") {
        println!("\n--- node-count scaling: 100k-node event-driven run");
        let ds = scaling_dataset(6, 100_000);
        let shards_hi = golf::util::threads::budget().clamp(2, 8);
        for (key, shards) in [("s1", 1usize), ("sN", shards_hi)] {
            let t0 = std::time::Instant::now();
            let mut cfg = ProtocolConfig::paper_default(5);
            cfg.eval.n_peers = 0;
            cfg.eval.at_cycles = vec![5];
            cfg.seed = 6;
            cfg.shards = shards;
            let res = run(cfg, &ds);
            let wall = t0.elapsed().as_secs_f64();
            println!(
                "    -> shards={shards}: {:.1}s wall, {} messages sent, peak RSS {:.0} MiB",
                wall,
                res.stats.messages_sent,
                peak_rss_mib()
            );
            json.push((format!("scale100k_{key}_walltime_s"), wall));
            json.push((
                format!("scale100k_{key}_msgs_per_s"),
                res.stats.messages_sent as f64 / wall.max(1e-12),
            ));
        }
        json.push(("scale100k_shards_hi".into(), shards_hi as f64));
        json.push(("scale100k_peak_rss_mib".into(), peak_rss_mib()));
    }

    // ---- the 1M-node target (DESIGN.md §14): one full-universe run over
    // the memory hot path — pooled message buffers, packed liveness bitsets,
    // chunked micro-batch kernels.  Peak RSS is the headline number: the
    // run must fit workstation RAM, not just finish.  Named its own section
    // so CI's cheap re-runs can exclude it ------------------------------
    if section_enabled("scale1m") {
        println!("\n--- node-count scaling: 1M-node event-driven run");
        let ds = scaling_dataset(7, 1_000_000);
        let shards = golf::util::threads::budget().clamp(2, 8);
        let t0 = std::time::Instant::now();
        let mut cfg = ProtocolConfig::paper_default(3);
        cfg.eval.n_peers = 0;
        cfg.eval.at_cycles = vec![3];
        cfg.seed = 7;
        cfg.shards = shards;
        let res = run(cfg, &ds);
        let wall = t0.elapsed().as_secs_f64();
        let requested = res.stats.pool_hits + res.stats.pool_misses;
        let hit_rate = res.stats.pool_hits as f64 / (requested.max(1)) as f64;
        // NB: VmHWM is a process-wide high-water mark; when earlier sections
        // ran in the same invocation it includes their footprint too.  Run
        // GOLF_BENCH_SECTIONS=scale1m for a clean measurement.
        println!(
            "    -> shards={shards}: {:.1}s wall, {} messages sent, \
             pool hit rate {:.3}, peak RSS {:.0} MiB",
            wall,
            res.stats.messages_sent,
            hit_rate,
            peak_rss_mib()
        );
        json.push(("scale1m_walltime_s".into(), wall));
        json.push((
            "scale1m_msgs_per_s".into(),
            res.stats.messages_sent as f64 / wall.max(1e-12),
        ));
        // percent: write_bench_json keeps one decimal, so a 0..1 ratio
        // would quantize to nothing useful
        json.push(("scale1m_pool_hit_rate_pct".into(), hit_rate * 100.0));
        json.push(("scale1m_peak_rss_mib".into(), peak_rss_mib()));
    }

    write_bench_json("protocol", "delivered_messages_per_s", &json);
}
