//! Bench target regenerating Figure 2: P2PegasosMU vs P2PegasosUM vs
//! PERFECT MATCHING — prediction error and mean pairwise cosine model
//! similarity.  CSVs land in results/.
//!
//!     cargo bench --bench fig2
//!     GOLF_SCALE=0.1 GOLF_CYCLES=100 cargo bench --bench fig2   (quick)

use golf::experiments::{self, common, fig2};
use std::time::Instant;

fn main() {
    let scale = common::env_scale();
    let cycles = std::env::var("GOLF_CYCLES").ok().and_then(|s| s.parse().ok());
    let seed = 42;
    println!("=== Figure 2 (scale {scale}, cycles {cycles:?}) ===\n");
    let sets = experiments::datasets(seed, scale);

    let t0 = Instant::now();
    let panels = fig2::run_figure(&sets, cycles, seed);
    let dt = t0.elapsed();
    let dir = common::results_dir();
    fig2::to_csv(&panels, &dir).expect("writing CSVs");

    for p in &panels {
        println!("--- {}", p.dataset);
        for c in &p.curves {
            let last = c.points.last().unwrap();
            let mid = &c.points[c.points.len() / 2];
            println!(
                "  {:<24} err mid/final {:.3}/{:.3}   similarity mid/final {:.3}/{:.3}",
                c.label,
                mid.err_mean,
                last.err_mean,
                mid.similarity.unwrap_or(f64::NAN),
                last.similarity.unwrap_or(f64::NAN),
            );
        }
        println!();
    }
    println!(
        "wrote {} CSV panels to {} in {:.1}s",
        panels.len(),
        dir.display(),
        dt.as_secs_f64()
    );
    println!("\nexpected shape (paper): mu converges faster than um; um shows lower model");
    println!("similarity; perfect matching does not clearly beat random sampling for Pegasos.");
}
