//! Bench target regenerating Table I: dataset statistics + sequential
//! Pegasos error after 20,000 iterations, with run-time measurement.
//!
//!     cargo bench --bench table1
//!     GOLF_SCALE=0.1 cargo bench --bench table1     (quick)

use golf::experiments::{self, common, table1};
use golf::util::benchkit::bench;

fn main() {
    let scale = common::env_scale();
    let seed = 42;
    println!("=== Table I (scale {scale}) ===\n");
    let sets = experiments::datasets(seed, scale);

    let rows = table1::run(&sets, seed);
    table1::print(&rows);

    println!("\ntiming the 20k-iteration baseline per dataset:");
    for e in &sets {
        bench(&format!("pegasos-20k {}", e.ds.name), 0, 1, || {
            std::hint::black_box(golf::baselines::sequential::pegasos_20k_error(
                &e.ds, e.lambda, seed,
            ));
        });
    }

    println!("\npaper-vs-measured (shape check): errors within 0.05 of Table I");
    for r in &rows {
        let ok = (r.pegasos_20k - r.paper_pegasos_20k).abs() < 0.05;
        println!(
            "  {}: ours {:.3} vs paper {:.3}  [{}]",
            r.name,
            r.pegasos_20k,
            r.paper_pegasos_20k,
            if ok { "ok" } else { "DIVERGES" }
        );
    }
}
