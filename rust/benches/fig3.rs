//! Bench target regenerating Figure 3: local voting (Algorithm 4, cache 10)
//! vs freshest-model prediction for RW and MU, with and without failures.
//! Includes the beyond-paper cache-size ablation (DESIGN.md §8).
//!
//!     cargo bench --bench fig3
//!     GOLF_SCALE=0.1 GOLF_CYCLES=100 cargo bench --bench fig3   (quick)

use golf::experiments::{self, common, fig3};
use std::time::Instant;

fn main() {
    let scale = common::env_scale();
    let cycles = std::env::var("GOLF_CYCLES").ok().and_then(|s| s.parse().ok());
    let seed = 42;
    println!("=== Figure 3 (scale {scale}, cycles {cycles:?}) ===\n");
    let sets = experiments::datasets(seed, scale);

    let t0 = Instant::now();
    let panels = fig3::run_figure(&sets, cycles, seed);
    let dt = t0.elapsed();
    let dir = common::results_dir();
    fig3::to_csv(&panels, &dir).expect("writing CSVs");

    for p in &panels {
        println!(
            "--- {} ({})",
            p.dataset,
            if p.failures { "all failures" } else { "no failures" }
        );
        for c in &p.curves {
            let last = c.points.last().unwrap();
            println!(
                "  {:<16} freshest {:.3} -> voted {:.3}  (gain {:+.3})",
                c.label,
                last.err_mean,
                last.err_vote.unwrap_or(f64::NAN),
                last.err_mean - last.err_vote.unwrap_or(f64::NAN),
            );
        }
    }

    // ablation: cache size sweep on the urls dataset (MU)
    println!("\n--- cache-size ablation (urls, MU, beyond paper)");
    let e = &sets[2];
    let sweep_cycles = cycles.unwrap_or(200).min(200);
    for (size, curve) in fig3::cache_sweep(e, sweep_cycles, &[1, 2, 5, 10, 20], seed) {
        let last = curve.points.last().unwrap();
        println!(
            "  cache {size:>2}: freshest {:.3}  voted {:.3}",
            last.err_mean,
            last.err_vote.unwrap_or(f64::NAN)
        );
    }
    println!(
        "\nwrote {} CSV panels to {} in {:.1}s",
        panels.len(),
        dir.display(),
        dt.as_secs_f64()
    );
    println!("\nexpected shape (paper): voting helps rw a lot, mu a little; early cycles can");
    println!("degrade slightly (cached models are staler than the freshest).");
}
