//! Bench target regenerating Figure 1: error-vs-cycle curves for sequential
//! Pegasos, P2PegasosRW, P2PegasosMU, WB1, WB2 — without failures and under
//! the extreme failure scenario — on all three datasets.  CSVs land in
//! results/ for plotting.
//!
//!     cargo bench --bench fig1
//!     GOLF_SCALE=0.1 GOLF_CYCLES=100 cargo bench --bench fig1   (quick)

use golf::experiments::{self, common, fig1};
use std::time::Instant;

fn main() {
    let scale = common::env_scale();
    let cycles = std::env::var("GOLF_CYCLES").ok().and_then(|s| s.parse().ok());
    let seed = 42;
    println!("=== Figure 1 (scale {scale}, cycles {cycles:?}) ===\n");
    let sets = experiments::datasets(seed, scale);

    let t0 = Instant::now();
    let panels = fig1::run_figure(&sets, cycles, seed);
    let dt = t0.elapsed();

    let dir = common::results_dir();
    fig1::to_csv(&panels, &dir).expect("writing CSVs");

    for p in &panels {
        println!(
            "--- {} ({}) — cycles to reach 2x final-error of the best curve:",
            p.dataset,
            if p.failures { "all failures" } else { "no failures" }
        );
        let best_final = p
            .curves
            .iter()
            .map(|c| c.final_error())
            .fold(f64::INFINITY, f64::min);
        let thr = (2.0 * best_final).max(0.05);
        for (label, cyc) in fig1::cycles_to_threshold(p, thr) {
            println!(
                "  {label:<22} -> {}",
                cyc.map_or("not reached".into(), |c| format!("cycle {c}"))
            );
        }
        println!();
    }
    println!(
        "wrote {} CSV panels to {} in {:.1}s",
        panels.len(),
        dir.display(),
        dt.as_secs_f64()
    );
    println!("\nexpected shape (paper): wb1 <= wb2 <= p2pegasos-mu << p2pegasos-rw ~= pegasos;");
    println!("failure rows shifted right ~10x but converging to the same error.");
}
