//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment has no network access and no crates.io registry, so
//! the real `anyhow` cannot be fetched.  This shim implements exactly the
//! surface the `golf` crate uses:
//!
//! * [`Error`] — a string-backed error (context chain flattened into the
//!   message, so `{}` and `{:#}` both print the full chain),
//! * [`Result`] with the defaulted error parameter,
//! * [`Context`] — `.context(...)` / `.with_context(...)` on `Result` (for
//!   both `std::error::Error` sources and `anyhow::Error` itself) and on
//!   `Option`,
//! * the [`anyhow!`], [`bail!`] and [`ensure!`] macros,
//! * `?`-conversion from any `std::error::Error`.
//!
//! Dropping in the real crate is a one-line change in rust/Cargo.toml; no
//! source edits are needed.

use std::fmt;

/// String-backed error value.  Like `anyhow::Error` it deliberately does NOT
/// implement `std::error::Error` — that is what makes the blanket `From`
/// impl below coherent.
pub struct Error {
    msg: String,
}

impl Error {
    /// Create an error from any displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error { msg: m.to_string() }
    }

    fn wrap<C: fmt::Display>(self, c: C) -> Self {
        Error { msg: format!("{c}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{}` and the real anyhow's `{:#}` both render the full chain here
        // (contexts are flattened eagerly).
        write!(f, "{}", self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(&format!(": {s}"));
            src = s.source();
        }
        Error { msg }
    }
}

/// `Result` with the defaulted error type, matching `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

mod private {
    /// Sealed conversion trait so `Context` covers both `E: std::error::Error`
    /// and `anyhow::Error` without overlapping impls (`Error` itself does not
    /// implement `std::error::Error`).
    pub trait IntoError {
        fn into_error(self) -> crate::Error;
    }

    impl<E: std::error::Error> IntoError for E {
        fn into_error(self) -> crate::Error {
            crate::Error::from(self)
        }
    }

    impl IntoError for crate::Error {
        fn into_error(self) -> crate::Error {
            self
        }
    }
}

/// Context attachment, mirroring `anyhow::Context`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: private::IntoError> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into_error().wrap(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into_error().wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an error.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Early-return with an error when the condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::{Context, Error, Result};

    fn io_fail() -> Result<()> {
        let e = std::io::Error::new(std::io::ErrorKind::Other, "boom");
        Err(e).context("reading file")
    }

    #[test]
    fn context_chain_flattens() {
        let e = io_fail().unwrap_err();
        assert_eq!(e.to_string(), "reading file: boom");
        assert_eq!(format!("{e:#}"), "reading file: boom");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(e.to_string(), "missing 7");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<u32> {
            let n: u32 = "x".parse()?;
            Ok(n)
        }
        assert!(f().is_err());
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: u32) -> Result<u32> {
            crate::ensure!(x < 10, "too big: {x}");
            if x == 3 {
                crate::bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(f(12).unwrap_err().to_string(), "too big: 12");
        assert_eq!(f(3).unwrap_err().to_string(), "three is right out");
        let e = crate::anyhow!("plain {}", 1);
        assert_eq!(e.to_string(), "plain 1");
        let _: Error = crate::anyhow!("literal only");
    }
}
