//! PJRT runtime: loads the AOT HLO-text artifacts and executes them on the
//! XLA CPU client.  This is the only place Python output crosses into the
//! Rust request path — as compiled executables, never as an interpreter.
//!
//! Pattern follows /opt/xla-example/src/bin/load_hlo.rs: HLO *text* →
//! `HloModuleProto::from_text_file` → `XlaComputation` → `client.compile`.

use crate::runtime::artifacts::Manifest;
// Offline build: the `xla` name resolves to the in-repo stub.  Swap this
// line for the real xla-rs crate to enable the PJRT path (see xla_stub.rs).
use crate::runtime::xla_stub as xla;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::Path;

pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    execs: HashMap<String, xla::PjRtLoadedExecutable>,
    /// executions per artifact (perf accounting)
    pub calls: HashMap<String, u64>,
}

impl Runtime {
    /// Load the manifest from `artifacts/` and create the CPU PJRT client.
    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, manifest, execs: HashMap::new(), calls: HashMap::new() })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Resolve an op + shape needs to a concrete artifact name (smallest
    /// fitting bucket).
    pub fn resolve(&self, op: &str, needs: &[(&str, usize)]) -> Result<(String, HashMap<String, usize>)> {
        let e = self.manifest.select(op, needs)?;
        Ok((e.name.clone(), e.params.clone()))
    }

    fn ensure_compiled(&mut self, name: &str) -> Result<()> {
        if self.execs.contains_key(name) {
            return Ok(());
        }
        let entry = self
            .manifest
            .entries
            .iter()
            .find(|e| e.name == name)
            .with_context(|| format!("unknown artifact {name}"))?;
        let proto = xla::HloModuleProto::from_text_file(&entry.file)
            .with_context(|| format!("parsing HLO text {:?}", entry.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        self.execs.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute artifact `name`; returns the flattened output tuple.
    /// (aot.py lowers with return_tuple=True, so the root is always a tuple.)
    pub fn execute(&mut self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        self.ensure_compiled(name)?;
        *self.calls.entry(name.to_string()).or_insert(0) += 1;
        let exe = &self.execs[name];
        let result = exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {name}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {name}"))?;
        Ok(lit.to_tuple()?)
    }

    /// Number of distinct compiled executables (perf accounting).
    pub fn compiled_count(&self) -> usize {
        self.execs.len()
    }
}

/// Build a [rows, cols] f32 literal from a slice.
pub fn literal_matrix(data: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
    debug_assert_eq!(data.len(), rows * cols);
    Ok(xla::Literal::vec1(data).reshape(&[rows as i64, cols as i64])?)
}

/// Build a [len] f32 literal.
pub fn literal_vec(data: &[f32]) -> xla::Literal {
    xla::Literal::vec1(data)
}

/// Read a f32 literal back into a Vec.
pub fn literal_to_vec(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}
