//! Artifact manifest: maps (op, shape requirements) to the AOT-compiled HLO
//! text files emitted by `python/compile/aot.py` (see `make artifacts`).
//!
//! Shapes are bucketed (aot.py `FULL`/`QUICK` tables); the runtime picks the
//! smallest bucket that fits a request and mask-pads the batch.

use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub name: String,
    pub op: String,
    /// shape bucket parameters, e.g. {"b": 128, "d": 64}
    pub params: HashMap<String, usize>,
    pub file: PathBuf,
}

#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub entries: Vec<ArtifactEntry>,
    pub dir: PathBuf,
}

impl Manifest {
    /// Parse `<dir>/manifest.tsv` (columns: name, op, k=v params, file).
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Self> {
        let mut entries = Vec::new();
        for (i, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let cols: Vec<&str> = line.split('\t').collect();
            if cols.len() != 4 {
                return Err(anyhow!("manifest line {}: expected 4 columns", i + 1));
            }
            let mut params = HashMap::new();
            for kv in cols[2].split(',') {
                if kv.is_empty() {
                    continue;
                }
                let (k, v) = kv
                    .split_once('=')
                    .ok_or_else(|| anyhow!("manifest line {}: bad param {kv:?}", i + 1))?;
                params.insert(
                    k.to_string(),
                    v.parse::<usize>()
                        .with_context(|| format!("manifest line {}: param {kv:?}", i + 1))?,
                );
            }
            entries.push(ArtifactEntry {
                name: cols[0].to_string(),
                op: cols[1].to_string(),
                params,
                file: dir.join(cols[3]),
            });
        }
        Ok(Manifest { entries, dir: dir.to_path_buf() })
    }

    /// Smallest bucket of `op` satisfying every `(key, >= need)` constraint.
    /// "Smallest" = lexicographic on the constrained params (padding cost).
    pub fn select(&self, op: &str, needs: &[(&str, usize)]) -> Result<&ArtifactEntry> {
        let mut best: Option<&ArtifactEntry> = None;
        'entry: for e in self.entries.iter().filter(|e| e.op == op) {
            for &(k, need) in needs {
                match e.params.get(k) {
                    Some(&have) if have >= need => {}
                    _ => continue 'entry,
                }
            }
            let cost = |e: &ArtifactEntry| -> usize {
                needs.iter().map(|&(k, _)| e.params[k]).product()
            };
            if best.map_or(true, |b| cost(e) < cost(b)) {
                best = Some(e);
            }
        }
        best.ok_or_else(|| {
            anyhow!(
                "no artifact for op {op} with {needs:?} (have: {:?})",
                self.entries
                    .iter()
                    .filter(|e| e.op == op)
                    .map(|e| &e.params)
                    .collect::<Vec<_>>()
            )
        })
    }

    pub fn ops(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.entries.iter().map(|e| e.op.as_str()).collect();
        v.sort();
        v.dedup();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# name\top\tparams\tfile
pegasos_rw_b128_d16\tpegasos_rw\tb=128,d=16\tpegasos_rw_b128_d16.hlo.txt
pegasos_rw_b128_d64\tpegasos_rw\tb=128,d=64\tpegasos_rw_b128_d64.hlo.txt
pegasos_rw_b1024_d16\tpegasos_rw\tb=1024,d=16\tpegasos_rw_b1024_d16.hlo.txt
merge_b128_d16\tmerge\tb=128,d=16\tmerge_b128_d16.hlo.txt
";

    #[test]
    fn parse_and_select_smallest_fit() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.entries.len(), 4);
        let e = m.select("pegasos_rw", &[("b", 100), ("d", 10)]).unwrap();
        assert_eq!(e.name, "pegasos_rw_b128_d16");
        let e = m.select("pegasos_rw", &[("b", 200), ("d", 16)]).unwrap();
        assert_eq!(e.name, "pegasos_rw_b1024_d16");
        let e = m.select("pegasos_rw", &[("b", 10), ("d", 17)]).unwrap();
        assert_eq!(e.name, "pegasos_rw_b128_d64");
    }

    #[test]
    fn select_fails_when_too_big() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert!(m.select("pegasos_rw", &[("b", 5000), ("d", 16)]).is_err());
        assert!(m.select("nonexistent", &[]).is_err());
    }

    #[test]
    fn parse_rejects_malformed() {
        assert!(Manifest::parse("a\tb\tc", Path::new("/")).is_err());
        assert!(Manifest::parse("a\tb\tbadparam\tf", Path::new("/")).is_err());
        assert!(Manifest::parse("a\tb\tk=x\tf", Path::new("/")).is_err());
    }

    #[test]
    fn ops_deduped() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.ops(), vec!["merge", "pegasos_rw"]);
    }
}
