//! Offline stub of the `xla` (xla-rs) API surface used by [`super::client`].
//!
//! The container building this repo has no XLA/PJRT shared libraries and no
//! crates.io access, so the real `xla` crate cannot be compiled.  This stub
//! keeps the whole runtime layer type-checking: every constructor returns a
//! descriptive error, so any code path that would actually need XLA fails
//! gracefully at run time (and all PJRT tests/benches already skip when no
//! `artifacts/manifest.tsv` is present).
//!
//! To link the real binding: add `xla` to rust/Cargo.toml and replace the
//! `use crate::runtime::xla_stub as xla;` line in runtime/client.rs with the
//! external crate.  No other source changes are required.

use std::fmt;
use std::path::Path;

/// Error type mirroring `xla::Error` closely enough for `?` conversion into
/// `anyhow::Error`.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable<T>(what: &str) -> Result<T, Error> {
    Err(Error(format!(
        "{what}: XLA/PJRT runtime not linked in this offline build \
         (see rust/src/runtime/xla_stub.rs for how to enable it)"
    )))
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self, Error> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unavailable("PjRtClient::compile")
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<Self, Error> {
        unavailable("HloModuleProto::from_text_file")
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// Host-side literal; holds data so `vec1`/`reshape` (which run before any
/// device interaction) behave, while device round-trips error out.
pub struct Literal {
    data: Vec<f32>,
}

impl Literal {
    pub fn vec1(data: &[f32]) -> Literal {
        Literal { data: data.to_vec() }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Ok(Literal { data: self.data.clone() })
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        unavailable("Literal::to_vec")
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        unavailable("Literal::to_tuple")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_report_offline_build() {
        let err = PjRtClient::cpu().err().expect("stub must not construct");
        assert!(err.to_string().contains("not linked"));
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }

    #[test]
    fn host_side_literals_work() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[2, 2]).is_ok());
        assert!(l.to_vec::<f32>().is_err());
    }
}
