//! XLA/PJRT runtime layer: artifact manifest + lazy-compiled executables.
//! (PjRtClient::cpu() -> HloModuleProto::from_text_file -> compile ->
//! execute; adapted from /opt/xla-example load_hlo.)
pub mod artifacts;
pub mod client;
pub mod xla_stub;

pub use artifacts::{ArtifactEntry, Manifest};
pub use client::{literal_matrix, literal_to_vec, literal_vec, Runtime};
