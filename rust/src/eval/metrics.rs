//! Prediction-error metrics (Section VI-A(h)): the 0-1 error of a model (or
//! of a cache-backed voting predictor) over a held-out test set.

use crate::data::dataset::Examples;
use crate::gossip::cache::ModelCache;
use crate::gossip::predict::Predictor;
use crate::learning::linear::LinearModel;

/// Sign-flipped copy of a label vector: the evaluation target after a
/// scenario concept drift inverts the concept (DESIGN.md §11).  One shared
/// definition so the simulators and the deployment coordinator cannot
/// drift apart in how they re-label.
pub fn flipped_labels(y: &[f32]) -> Vec<f32> {
    y.iter().map(|&v| -v).collect()
}

/// 0-1 error of a single model. The zero model (margin 0) counts every
/// positive example as a miss — sign(0) is treated as -1 throughout.
pub fn zero_one_error(m: &LinearModel, test: &Examples, y: &[f32]) -> f64 {
    debug_assert_eq!(test.n(), y.len());
    let mut wrong = 0usize;
    for i in 0..test.n() {
        if m.predict(&test.row(i)) != y[i] {
            wrong += 1;
        }
    }
    wrong as f64 / test.n().max(1) as f64
}

/// 0-1 error of a cache-backed predictor (Algorithm 4).
pub fn cache_error(
    cache: &ModelCache,
    predictor: Predictor,
    test: &Examples,
    y: &[f32],
) -> f64 {
    let mut wrong = 0usize;
    for i in 0..test.n() {
        if predictor.predict(cache, &test.row(i)) != y[i] {
            wrong += 1;
        }
    }
    wrong as f64 / test.n().max(1) as f64
}

/// Error of the margin-weighted full-population vote (Eq. 18/19, the WB1/WB2
/// baselines): sign(sum_j <w_j, x>).
pub fn weighted_vote_error(models: &[&LinearModel], test: &Examples, y: &[f32]) -> f64 {
    let mut wrong = 0usize;
    for i in 0..test.n() {
        let x = test.row(i);
        let s: f32 = models.iter().map(|m| m.raw_margin(&x)).sum();
        let pred = if s > 0.0 { 1.0 } else { -1.0 };
        if pred != y[i] {
            wrong += 1;
        }
    }
    wrong as f64 / test.n().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::matrix::Matrix;

    fn test_set() -> (Examples, Vec<f32>) {
        // y = sign(x0)
        let m = Matrix::from_vec(4, 2, vec![1., 0., -1., 0., 2., 1., -2., 1.]);
        (Examples::Dense(m), vec![1.0, -1.0, 1.0, -1.0])
    }

    #[test]
    fn perfect_model_zero_error() {
        let (x, y) = test_set();
        let m = LinearModel::from_weights(vec![1.0, 0.0], 0);
        assert_eq!(zero_one_error(&m, &x, &y), 0.0);
    }

    #[test]
    fn inverted_model_full_error() {
        let (x, y) = test_set();
        let m = LinearModel::from_weights(vec![-1.0, 0.0], 0);
        assert_eq!(zero_one_error(&m, &x, &y), 1.0);
    }

    #[test]
    fn zero_model_errs_on_positives() {
        let (x, y) = test_set();
        let m = LinearModel::zeros(2);
        assert_eq!(zero_one_error(&m, &x, &y), 0.5);
    }

    #[test]
    fn vote_error_beats_bad_member() {
        let (x, y) = test_set();
        let good = LinearModel::from_weights(vec![1.0, 0.0], 0);
        let bad = LinearModel::from_weights(vec![-0.1, 0.0], 0);
        let e = weighted_vote_error(&[&good, &bad, &good], &x, &y);
        assert_eq!(e, 0.0);
    }

    #[test]
    fn cache_error_matches_single_for_freshest() {
        let (x, y) = test_set();
        let mut c = ModelCache::new(3);
        let m = LinearModel::from_weights(vec![1.0, 0.0], 0);
        c.add(LinearModel::from_weights(vec![-1.0, 0.0], 0));
        c.add(m.clone());
        assert_eq!(
            cache_error(&c, Predictor::Freshest, &x, &y),
            zero_one_error(&m, &x, &y)
        );
    }
}
