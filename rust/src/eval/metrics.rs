//! Prediction-error metrics (Section VI-A(h)): the 0-1 error of a model (or
//! of a cache-backed voting predictor) over a held-out test set.

use crate::data::dataset::Examples;
use crate::gossip::cache::ModelCache;
use crate::gossip::predict::Predictor;
use crate::learning::linear::LinearModel;

/// Sign-flipped copy of a label vector: the evaluation target after a
/// scenario concept drift inverts the concept (DESIGN.md §11).  One shared
/// definition so the simulators and the deployment coordinator cannot
/// drift apart in how they re-label.
pub fn flipped_labels(y: &[f32]) -> Vec<f32> {
    y.iter().map(|&v| -v).collect()
}

/// 0-1 error of a single model. The zero model (margin 0) counts every
/// positive example as a miss — sign(0) is treated as -1 throughout.
pub fn zero_one_error(m: &LinearModel, test: &Examples, y: &[f32]) -> f64 {
    debug_assert_eq!(test.n(), y.len());
    let mut wrong = 0usize;
    for i in 0..test.n() {
        if m.predict(&test.row(i)) != y[i] {
            wrong += 1;
        }
    }
    wrong as f64 / test.n().max(1) as f64
}

/// 0-1 error of a cache-backed predictor (Algorithm 4).
pub fn cache_error(
    cache: &ModelCache,
    predictor: Predictor,
    test: &Examples,
    y: &[f32],
) -> f64 {
    let mut wrong = 0usize;
    for i in 0..test.n() {
        if predictor.predict(cache, &test.row(i)) != y[i] {
            wrong += 1;
        }
    }
    wrong as f64 / test.n().max(1) as f64
}

/// Area under the ROC curve of a score vector against ±1 labels, via the
/// Mann-Whitney rank-sum with average ranks for ties: the probability that a
/// uniformly drawn positive example outranks a uniformly drawn negative one
/// (ties count half).  Degenerate single-class test sets score 0.5 — the
/// chance level, so an uninformative metric never masquerades as a good or
/// bad one.  This is the target the pairwise hinge objective optimizes
/// (DESIGN.md §17).
pub fn auc(scores: &[f32], y: &[f32]) -> f64 {
    debug_assert_eq!(scores.len(), y.len());
    let n_pos = y.iter().filter(|&&v| v > 0.0).count();
    let n_neg = y.len() - n_pos;
    if n_pos == 0 || n_neg == 0 {
        return 0.5;
    }
    let mut order: Vec<usize> = (0..scores.len()).collect();
    order.sort_by(|&a, &b| scores[a].partial_cmp(&scores[b]).unwrap_or(std::cmp::Ordering::Equal));
    // average ranks over tied score groups, 1-based
    let mut rank_sum_pos = 0.0f64;
    let mut i = 0;
    while i < order.len() {
        let mut j = i;
        while j < order.len() && scores[order[j]] == scores[order[i]] {
            j += 1;
        }
        let avg_rank = ((i + 1) + j) as f64 / 2.0; // mean of ranks i+1..=j
        for &k in &order[i..j] {
            if y[k] > 0.0 {
                rank_sum_pos += avg_rank;
            }
        }
        i = j;
    }
    let u = rank_sum_pos - (n_pos * (n_pos + 1)) as f64 / 2.0;
    u / (n_pos as f64 * n_neg as f64)
}

/// Error of the margin-weighted full-population vote (Eq. 18/19, the WB1/WB2
/// baselines): sign(sum_j <w_j, x>).
pub fn weighted_vote_error(models: &[&LinearModel], test: &Examples, y: &[f32]) -> f64 {
    let mut wrong = 0usize;
    for i in 0..test.n() {
        let x = test.row(i);
        let s: f32 = models.iter().map(|m| m.raw_margin(&x)).sum();
        let pred = if s > 0.0 { 1.0 } else { -1.0 };
        if pred != y[i] {
            wrong += 1;
        }
    }
    wrong as f64 / test.n().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::matrix::Matrix;

    fn test_set() -> (Examples, Vec<f32>) {
        // y = sign(x0)
        let m = Matrix::from_vec(4, 2, vec![1., 0., -1., 0., 2., 1., -2., 1.]);
        (Examples::Dense(m), vec![1.0, -1.0, 1.0, -1.0])
    }

    #[test]
    fn perfect_model_zero_error() {
        let (x, y) = test_set();
        let m = LinearModel::from_weights(vec![1.0, 0.0], 0);
        assert_eq!(zero_one_error(&m, &x, &y), 0.0);
    }

    #[test]
    fn inverted_model_full_error() {
        let (x, y) = test_set();
        let m = LinearModel::from_weights(vec![-1.0, 0.0], 0);
        assert_eq!(zero_one_error(&m, &x, &y), 1.0);
    }

    #[test]
    fn zero_model_errs_on_positives() {
        let (x, y) = test_set();
        let m = LinearModel::zeros(2);
        assert_eq!(zero_one_error(&m, &x, &y), 0.5);
    }

    #[test]
    fn vote_error_beats_bad_member() {
        let (x, y) = test_set();
        let good = LinearModel::from_weights(vec![1.0, 0.0], 0);
        let bad = LinearModel::from_weights(vec![-0.1, 0.0], 0);
        let e = weighted_vote_error(&[&good, &bad, &good], &x, &y);
        assert_eq!(e, 0.0);
    }

    #[test]
    fn auc_perfect_inverted_and_random() {
        let y = [1.0, 1.0, -1.0, -1.0];
        assert_eq!(auc(&[0.9, 0.8, 0.2, 0.1], &y), 1.0);
        assert_eq!(auc(&[0.1, 0.2, 0.8, 0.9], &y), 0.0);
        // all tied scores: every pair counts half
        assert_eq!(auc(&[0.5, 0.5, 0.5, 0.5], &y), 0.5);
    }

    #[test]
    fn auc_handles_partial_ties_with_average_ranks() {
        // scores: pos {0.8, 0.5}, neg {0.5, 0.1} — pairs: (0.8>0.5)=1,
        // (0.8>0.1)=1, (0.5=0.5)=0.5, (0.5>0.1)=1 → 3.5/4
        let got = auc(&[0.8, 0.5, 0.5, 0.1], &[1.0, 1.0, -1.0, -1.0]);
        assert!((got - 0.875).abs() < 1e-12, "{got}");
    }

    #[test]
    fn auc_single_class_is_chance() {
        assert_eq!(auc(&[0.1, 0.9], &[1.0, 1.0]), 0.5);
        assert_eq!(auc(&[0.1, 0.9], &[-1.0, -1.0]), 0.5);
    }

    #[test]
    fn auc_agrees_with_brute_force_pair_count() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(77);
        for _ in 0..20 {
            let n = 3 + rng.below_usize(30);
            // coarse scores force plenty of ties
            let scores: Vec<f32> = (0..n).map(|_| (rng.below_usize(5) as f32) / 4.0).collect();
            let y: Vec<f32> = (0..n).map(|_| rng.sign()).collect();
            let (mut wins, mut pairs) = (0.0f64, 0.0f64);
            for i in 0..n {
                for j in 0..n {
                    if y[i] > 0.0 && y[j] < 0.0 {
                        pairs += 1.0;
                        if scores[i] > scores[j] {
                            wins += 1.0;
                        } else if scores[i] == scores[j] {
                            wins += 0.5;
                        }
                    }
                }
            }
            let expect = if pairs == 0.0 { 0.5 } else { wins / pairs };
            let got = auc(&scores, &y);
            assert!((got - expect).abs() < 1e-9, "{got} vs {expect}");
        }
    }

    #[test]
    fn cache_error_matches_single_for_freshest() {
        let (x, y) = test_set();
        let mut c = ModelCache::new(3);
        let m = LinearModel::from_weights(vec![1.0, 0.0], 0);
        c.add(LinearModel::from_weights(vec![-1.0, 0.0], 0));
        c.add(m.clone());
        assert_eq!(
            cache_error(&c, Predictor::Freshest, &x, &y),
            zero_one_error(&m, &x, &y)
        );
    }
}
