//! CSV output for convergence curves — the bench targets write these files
//! so the paper's figures can be re-plotted from the repo.

use crate::eval::tracker::Curve;
use std::io::Write;
use std::path::Path;

/// Write a set of curves in long format:
/// `label,cycle,err_mean,err_std,err_vote,similarity,auc,messages_sent`.
pub fn write_curves(path: &Path, curves: &[Curve]) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "label,cycle,err_mean,err_std,err_vote,similarity,auc,messages_sent")?;
    for c in curves {
        for p in &c.points {
            writeln!(
                f,
                "{},{},{:.6},{:.6},{},{},{},{}",
                c.label,
                p.cycle,
                p.err_mean,
                p.err_std,
                p.err_vote.map_or(String::new(), |v| format!("{v:.6}")),
                p.similarity.map_or(String::new(), |v| format!("{v:.6}")),
                p.auc.map_or(String::new(), |v| format!("{v:.6}")),
                p.messages_sent
            )?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::tracker::point_from_errors;

    #[test]
    fn writes_long_format() {
        let mut c = Curve::new("p2pegasos-mu");
        c.push(point_from_errors(1, &[0.4], None, Some(0.5), None, 10));
        c.push(point_from_errors(2, &[0.3], Some(&[0.25]), None, Some(&[0.75]), 20));
        let dir = std::env::temp_dir().join("golf_csv_test");
        let path = dir.join("curves.csv");
        write_curves(&path, &[c]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.trim().lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].starts_with("label,cycle"));
        assert!(lines[0].contains(",auc,"));
        assert!(lines[1].starts_with("p2pegasos-mu,1,0.4"));
        assert!(lines[2].contains(",0.250000,"));
        assert!(lines[2].contains(",0.750000,"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
