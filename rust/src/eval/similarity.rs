//! Model-similarity metric (Section VI-A(h)): mean pairwise cosine
//! similarity of the models circulating in the network — used in Fig. 2 to
//! relate convergence speed to model diversity.

use crate::learning::linear::LinearModel;

/// Mean cosine similarity over all unordered pairs.
pub fn mean_pairwise_cosine(models: &[&LinearModel]) -> f64 {
    let k = models.len();
    if k < 2 {
        return 1.0;
    }
    let mut sum = 0.0f64;
    let mut pairs = 0u64;
    for i in 0..k {
        for j in (i + 1)..k {
            sum += LinearModel::cosine(models[i], models[j]) as f64;
            pairs += 1;
        }
    }
    sum / pairs as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_models_similarity_one() {
        let a = LinearModel::from_weights(vec![1.0, 2.0], 0);
        let b = a.clone();
        let c = a.clone();
        assert!((mean_pairwise_cosine(&[&a, &b, &c]) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn orthogonal_models_similarity_zero() {
        let a = LinearModel::from_weights(vec![1.0, 0.0], 0);
        let b = LinearModel::from_weights(vec![0.0, 1.0], 0);
        assert!(mean_pairwise_cosine(&[&a, &b]).abs() < 1e-6);
    }

    #[test]
    fn single_model_defined_as_one() {
        let a = LinearModel::from_weights(vec![1.0], 0);
        assert_eq!(mean_pairwise_cosine(&[&a]), 1.0);
    }

    #[test]
    fn mixed_pairs_average() {
        let a = LinearModel::from_weights(vec![1.0, 0.0], 0);
        let b = LinearModel::from_weights(vec![-1.0, 0.0], 0);
        let c = LinearModel::from_weights(vec![0.0, 1.0], 0);
        // pairs: (a,b)=-1, (a,c)=0, (b,c)=0
        assert!((mean_pairwise_cosine(&[&a, &b, &c]) + 1.0 / 3.0).abs() < 1e-6);
    }
}
