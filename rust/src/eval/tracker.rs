//! Convergence-curve tracking: log-spaced measurement cycles (the paper's
//! figures use a logarithmic x axis) and the per-point statistics collected
//! over the sampled evaluation peers.

use crate::util::stats;

/// Log-spaced cycle grid: 1..10 by 1, 10..100 by 10, 100..1000 by 100, ...
/// always including `max_cycle`.  A zero-cycle run measures nothing — the
/// grid is empty (cycle 0 is the un-run initial state, not a measurement
/// point).
pub fn log_spaced_cycles(max_cycle: u64) -> Vec<u64> {
    if max_cycle == 0 {
        return Vec::new();
    }
    let mut pts = Vec::new();
    let mut step = 1u64;
    let mut c = 1u64;
    while c <= max_cycle {
        pts.push(c);
        if c >= step * 10 {
            step *= 10;
        }
        c += step;
    }
    if pts.last() != Some(&max_cycle) {
        pts.push(max_cycle);
    }
    pts
}

/// One measured point of a convergence curve.
#[derive(Clone, Debug)]
pub struct EvalPoint {
    pub cycle: u64,
    /// mean 0-1 error over sampled peers, freshest-model prediction
    pub err_mean: f64,
    pub err_std: f64,
    /// mean 0-1 error with cache voting (Algorithm 4), when enabled
    pub err_vote: Option<f64>,
    /// mean pairwise cosine similarity of sampled models, when enabled
    pub similarity: Option<f64>,
    /// mean test-set AUC (Mann-Whitney) over sampled peers, when enabled —
    /// the ranking metric of the pairwise objective (DESIGN.md §17)
    pub auc: Option<f64>,
    /// messages sent network-wide up to this point
    pub messages_sent: u64,
}

/// A full convergence curve plus run metadata.
#[derive(Clone, Debug, Default)]
pub struct Curve {
    pub label: String,
    pub points: Vec<EvalPoint>,
}

impl Curve {
    pub fn new(label: impl Into<String>) -> Self {
        Curve { label: label.into(), points: Vec::new() }
    }

    pub fn push(&mut self, p: EvalPoint) {
        self.points.push(p);
    }

    pub fn final_error(&self) -> f64 {
        self.points.last().map(|p| p.err_mean).unwrap_or(1.0)
    }

    /// First cycle at which the mean error drops below `threshold`
    /// (convergence-speed comparison across algorithms).
    pub fn cycles_to_reach(&self, threshold: f64) -> Option<u64> {
        self.points
            .iter()
            .find(|p| p.err_mean <= threshold)
            .map(|p| p.cycle)
    }
}

/// Aggregate per-peer errors into an EvalPoint.
pub fn point_from_errors(
    cycle: u64,
    errs: &[f64],
    vote_errs: Option<&[f64]>,
    similarity: Option<f64>,
    aucs: Option<&[f64]>,
    messages_sent: u64,
) -> EvalPoint {
    EvalPoint {
        cycle,
        err_mean: stats::mean(errs),
        err_std: stats::std_dev(errs),
        err_vote: vote_errs.map(stats::mean),
        similarity,
        auc: aucs.map(stats::mean),
        messages_sent,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_grid_shape() {
        let g = log_spaced_cycles(1000);
        assert_eq!(g[0], 1);
        assert!(g.contains(&10));
        assert!(g.contains(&100));
        assert!(g.contains(&1000));
        assert_eq!(*g.last().unwrap(), 1000);
        // strictly increasing
        for w in g.windows(2) {
            assert!(w[0] < w[1]);
        }
        // dense early, sparse late
        assert!(g.iter().filter(|&&c| c <= 10).count() >= 10);
        assert!(g.iter().filter(|&&c| c > 100).count() <= 10);
    }

    #[test]
    fn log_grid_includes_max_when_off_grid() {
        let g = log_spaced_cycles(137);
        assert_eq!(*g.last().unwrap(), 137);
    }

    #[test]
    fn log_grid_zero_cycles_is_empty() {
        // regression: this used to emit a bogus cycle-0 measurement point
        assert!(log_spaced_cycles(0).is_empty());
        assert_eq!(log_spaced_cycles(1), vec![1]);
    }

    #[test]
    fn curve_threshold_search() {
        let mut c = Curve::new("x");
        for (cy, e) in [(1, 0.5), (10, 0.2), (100, 0.05)] {
            c.push(point_from_errors(cy, &[e], None, None, None, 0));
        }
        assert_eq!(c.cycles_to_reach(0.2), Some(10));
        assert_eq!(c.cycles_to_reach(0.01), None);
        assert_eq!(c.final_error(), 0.05);
    }

    #[test]
    fn point_aggregation() {
        let p =
            point_from_errors(5, &[0.1, 0.3], Some(&[0.0, 0.2]), Some(0.8), Some(&[0.7, 0.9]), 42);
        assert!((p.err_mean - 0.2).abs() < 1e-12);
        assert_eq!(p.err_vote, Some(0.1));
        assert_eq!(p.similarity, Some(0.8));
        assert!((p.auc.unwrap() - 0.8).abs() < 1e-12);
        assert_eq!(p.messages_sent, 42);
    }
}
