//! Evaluation: 0-1 error metrics, model-similarity, log-spaced convergence
//! tracking, and CSV export for figure regeneration.
pub mod csv;
pub mod metrics;
pub mod similarity;
pub mod tracker;

pub use metrics::{auc, cache_error, flipped_labels, weighted_vote_error, zero_one_error};
pub use similarity::mean_pairwise_cosine;
pub use tracker::{log_spaced_cycles, Curve, EvalPoint};
