//! Message-level failure model (paper Section VI-A(i)):
//!
//! * message drop with a fixed probability (0.5 in the "extreme failure"
//!   scenario),
//! * message delay — either a small fixed transmission delay (no-failure
//!   runs) or uniform in [Δ, 10Δ] (failure runs),
//! * delivery to an offline node silently loses the message (churn).

use crate::sim::event::Ticks;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DelayModel {
    /// Constant transmission delay in ticks.
    Fixed(Ticks),
    /// Uniform in [lo, hi) ticks (paper failure scenario: [Δ, 10Δ]).
    Uniform { lo: Ticks, hi: Ticks },
}

impl DelayModel {
    pub fn sample(&self, rng: &mut Rng) -> Ticks {
        match *self {
            DelayModel::Fixed(d) => d,
            DelayModel::Uniform { lo, hi } => rng.range_u64(lo, hi),
        }
    }

    pub fn mean(&self) -> f64 {
        match *self {
            DelayModel::Fixed(d) => d as f64,
            DelayModel::Uniform { lo, hi } => (lo + hi) as f64 / 2.0,
        }
    }

    /// Smallest delay this model can ever sample.  The sharded simulator's
    /// conservative lookahead (DESIGN.md §13) windows event processing by
    /// the minimum over all delay models a run can install, so a message
    /// sent inside a window always arrives at or after the window's end.
    pub fn min_delay(&self) -> Ticks {
        match *self {
            DelayModel::Fixed(d) => d,
            DelayModel::Uniform { lo, .. } => lo,
        }
    }
}

#[derive(Clone, Copy, Debug)]
pub struct NetworkConfig {
    pub delay: DelayModel,
    pub drop_prob: f64,
}

impl NetworkConfig {
    /// Reliable network with a small fixed delay (default: 10 ticks = Δ/100).
    pub fn reliable() -> Self {
        NetworkConfig { delay: DelayModel::Fixed(10), drop_prob: 0.0 }
    }

    /// The paper's extreme failure scenario for gossip period `delta`:
    /// 50% drop + uniform [Δ, 10Δ] delay.
    pub fn extreme(delta: Ticks) -> Self {
        NetworkConfig {
            delay: DelayModel::Uniform { lo: delta, hi: 10 * delta },
            drop_prob: 0.5,
        }
    }
}

/// What the network decided for one transmitted message.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fate {
    /// delivered after this many ticks
    Deliver(Ticks),
    /// lost to the random drop model
    Dropped,
    /// blocked by an active partition (src and dst in different components)
    /// or a failed topology edge
    Blocked,
}

/// Network instance: decides per-message fate and counts outcomes.
///
/// Partitions (scenario engine, DESIGN.md §11) are a per-node component-id
/// map installed via [`Network::set_partition`]: a send whose endpoints sit
/// in different components is blocked **at send time**, before the drop
/// roll, consuming no RNG draws — so a partition-free run is bit-for-bit
/// identical to one where partitions were never configured.  Messages
/// already in flight when a partition starts (or heals) keep their decided
/// fate: fate is sealed at send, which keeps the
/// `sent = dropped + blocked + lost_offline + delivered + in_flight`
/// accounting exact across partition/heal transitions.
///
/// Topology edge failures (`edge_fail` / `edge_restore` / `bridge_cut`
/// mutations, DESIGN.md §16) follow the exact same contract: a failed edge
/// is a canonical `(min, max)` pair in [`Network::edge_block`], checked in
/// the same pre-RNG block as partitions, so unaffected sends keep their
/// bit-identical fate stream.
#[derive(Debug)]
pub struct Network {
    pub cfg: NetworkConfig,
    /// active partition: component id per node (None = fully connected)
    partition: Option<Vec<u32>>,
    /// failed topology edges as canonical (min, max) pairs
    edge_block: std::collections::HashSet<(u32, u32)>,
    pub sent: u64,
    pub dropped: u64,
    /// sends blocked by an active partition or a failed edge
    pub blocked: u64,
    pub lost_offline: u64,
    delivered: u64,
}

impl Network {
    pub fn new(cfg: NetworkConfig) -> Self {
        Network {
            cfg,
            partition: None,
            edge_block: std::collections::HashSet::new(),
            sent: 0,
            dropped: 0,
            blocked: 0,
            lost_offline: 0,
            delivered: 0,
        }
    }

    /// Install (or heal, with `None`) a partition: component id per node.
    pub fn set_partition(&mut self, components: Option<Vec<u32>>) {
        self.partition = components;
    }

    pub fn partitioned(&self) -> bool {
        self.partition.is_some()
    }

    /// Mark topology edges as failed: sends across them block at send time
    /// (canonical `(min, max)` pairs; direction-agnostic).
    pub fn fail_edges(&mut self, edges: &[(u32, u32)]) {
        for &(a, b) in edges {
            self.edge_block.insert((a.min(b), a.max(b)));
        }
    }

    /// Restore failed edges: the listed pairs, or — with `None` — all of
    /// them (link-level heal).
    pub fn restore_edges(&mut self, edges: Option<&[(u32, u32)]>) {
        match edges {
            Some(list) => {
                for &(a, b) in list {
                    self.edge_block.remove(&(a.min(b), a.max(b)));
                }
            }
            None => self.edge_block.clear(),
        }
    }

    /// Number of currently failed edges.
    pub fn failed_edges(&self) -> usize {
        self.edge_block.len()
    }

    /// Decide the fate of a message from `src` to `dst`.  Partition and
    /// failed-edge checks precede (and draw nothing from) the RNG-based
    /// drop/delay models.
    pub fn transmit_between(&mut self, src: usize, dst: usize, rng: &mut Rng) -> Fate {
        self.sent += 1;
        if let Some(p) = &self.partition {
            // nodes beyond the compiled universe default to component 0
            let cs = p.get(src).copied().unwrap_or(0);
            let cd = p.get(dst).copied().unwrap_or(0);
            if cs != cd {
                self.blocked += 1;
                return Fate::Blocked;
            }
        }
        if !self.edge_block.is_empty() {
            let key = ((src.min(dst)) as u32, (src.max(dst)) as u32);
            if self.edge_block.contains(&key) {
                self.blocked += 1;
                return Fate::Blocked;
            }
        }
        if self.cfg.drop_prob > 0.0 && rng.chance(self.cfg.drop_prob) {
            self.dropped += 1;
            Fate::Dropped
        } else {
            Fate::Deliver(self.cfg.delay.sample(rng))
        }
    }

    /// Returns `Some(delivery_delay)` or `None` if the message is dropped
    /// (partition-unaware legacy surface; see [`Network::transmit_between`]).
    pub fn transmit(&mut self, rng: &mut Rng) -> Option<Ticks> {
        match self.transmit_between(0, 0, rng) {
            Fate::Deliver(d) => Some(d),
            _ => None,
        }
    }

    pub fn note_lost_offline(&mut self) {
        self.lost_offline += 1;
    }

    /// Record an actual delivery (the receiver applied the message).
    pub fn note_delivered(&mut self) {
        self.delivered += 1;
    }

    /// Messages actually handed to a receiver.  Tracked explicitly:
    /// the old derivation `sent - dropped - lost_offline` silently counted
    /// messages still in flight at the horizon as delivered.
    pub fn delivered(&self) -> u64 {
        self.delivered
    }

    /// Messages sent but neither dropped, blocked, lost to churn, nor
    /// delivered yet.
    pub fn in_flight(&self) -> u64 {
        self.sent - self.dropped - self.blocked - self.lost_offline - self.delivered
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reliable_never_drops() {
        let mut net = Network::new(NetworkConfig::reliable());
        let mut rng = Rng::new(1);
        for _ in 0..1000 {
            assert_eq!(net.transmit(&mut rng), Some(10));
            net.note_delivered();
        }
        assert_eq!(net.dropped, 0);
        assert_eq!(net.delivered(), 1000);
        assert_eq!(net.in_flight(), 0);
    }

    #[test]
    fn extreme_drops_about_half() {
        let mut net = Network::new(NetworkConfig::extreme(1000));
        let mut rng = Rng::new(2);
        let n = 20_000;
        for _ in 0..n {
            net.transmit(&mut rng);
        }
        let rate = net.dropped as f64 / n as f64;
        assert!((rate - 0.5).abs() < 0.02, "drop rate {rate}");
    }

    #[test]
    fn extreme_delay_in_range() {
        let cfg = NetworkConfig::extreme(1000);
        let mut rng = Rng::new(3);
        let mut sum = 0.0;
        let n = 10_000;
        for _ in 0..n {
            let d = cfg.delay.sample(&mut rng);
            assert!((1000..10_000).contains(&d));
            sum += d as f64;
        }
        let mean = sum / n as f64;
        assert!((mean - cfg.delay.mean()).abs() < 100.0, "mean {mean}");
    }

    #[test]
    fn min_delay_bounds_samples() {
        assert_eq!(DelayModel::Fixed(10).min_delay(), 10);
        let u = DelayModel::Uniform { lo: 1000, hi: 10_000 };
        assert_eq!(u.min_delay(), 1000);
        let mut rng = Rng::new(6);
        for _ in 0..1000 {
            assert!(u.sample(&mut rng) >= u.min_delay());
        }
    }

    #[test]
    fn offline_loss_accounting() {
        let mut net = Network::new(NetworkConfig::reliable());
        let mut rng = Rng::new(4);
        net.transmit(&mut rng);
        net.note_lost_offline();
        assert_eq!(net.delivered(), 0);
        assert_eq!(net.in_flight(), 0);
    }

    /// Partitions block cross-component sends without consuming RNG draws,
    /// so the surviving stream is bit-for-bit what an unpartitioned network
    /// would have produced for the same-component sends.
    #[test]
    fn partition_blocks_cross_component_without_rng_draws() {
        let mut a = Network::new(NetworkConfig::extreme(1000));
        let mut b = Network::new(NetworkConfig::extreme(1000));
        b.set_partition(Some(vec![0, 0, 1, 1]));
        assert!(b.partitioned());
        let mut ra = Rng::new(8);
        let mut rb = Rng::new(8);
        let pairs = [(0, 1), (0, 2), (2, 3), (3, 1), (1, 0)];
        for &(s, d) in &pairs {
            let fb = b.transmit_between(s, d, &mut rb);
            if s / 2 != d / 2 {
                assert_eq!(fb, Fate::Blocked);
            } else {
                // same-component fate matches the unpartitioned network's
                // stream exactly: blocks drew nothing
                assert_eq!(fb, a.transmit_between(s, d, &mut ra));
            }
        }
        assert_eq!(b.blocked, 2);
        assert_eq!(b.sent, pairs.len() as u64);
        // heal: everything flows again
        b.set_partition(None);
        assert!(!b.partitioned());
        assert_ne!(b.transmit_between(0, 3, &mut rb), Fate::Blocked);
        // ids beyond the component map default to component 0
        b.set_partition(Some(vec![1]));
        assert_eq!(b.transmit_between(0, 7, &mut rb), Fate::Blocked);
        assert_ne!(b.transmit_between(7, 9, &mut rb), Fate::Blocked);
    }

    /// Failed edges block both directions without consuming RNG draws —
    /// the same no-perturbation contract partitions honor — and restore
    /// (selective or full) re-opens them.
    #[test]
    fn edge_failures_block_without_rng_draws() {
        let mut a = Network::new(NetworkConfig::extreme(1000));
        let mut b = Network::new(NetworkConfig::extreme(1000));
        b.fail_edges(&[(0, 1), (2, 3)]);
        assert_eq!(b.failed_edges(), 2);
        let mut ra = Rng::new(8);
        let mut rb = Rng::new(8);
        let pairs = [(0usize, 1usize), (1, 0), (0, 2), (3, 2), (1, 3)];
        for &(s, d) in &pairs {
            let fb = b.transmit_between(s, d, &mut rb);
            let canon = (s.min(d) as u32, s.max(d) as u32);
            if canon == (0, 1) || canon == (2, 3) {
                assert_eq!(fb, Fate::Blocked, "{s}->{d}");
            } else {
                // unaffected sends match the healthy network's fate stream
                assert_eq!(fb, a.transmit_between(s, d, &mut ra), "{s}->{d}");
            }
        }
        assert_eq!(b.blocked, 3);
        // selective restore re-opens one link, full restore the rest
        b.restore_edges(Some(&[(1, 0)]));
        assert_eq!(b.failed_edges(), 1);
        assert_ne!(b.transmit_between(0, 1, &mut rb), Fate::Blocked);
        assert_eq!(b.transmit_between(3, 2, &mut rb), Fate::Blocked);
        b.restore_edges(None);
        assert_eq!(b.failed_edges(), 0);
        assert_ne!(b.transmit_between(2, 3, &mut rb), Fate::Blocked);
    }

    /// Accounting stays exact when partitions block sends.
    #[test]
    fn partition_blocked_accounting() {
        let mut net = Network::new(NetworkConfig::reliable());
        net.set_partition(Some(vec![0, 1]));
        let mut rng = Rng::new(10);
        assert_eq!(net.transmit_between(0, 1, &mut rng), Fate::Blocked);
        assert_eq!(net.transmit_between(0, 0, &mut rng), Fate::Deliver(10));
        net.note_delivered();
        assert_eq!(net.blocked, 1);
        assert_eq!(net.delivered(), 1);
        assert_eq!(net.in_flight(), 0);
    }

    /// Regression: messages still in flight at the horizon were counted as
    /// delivered by the old `sent - dropped - lost_offline` derivation.
    #[test]
    fn in_flight_messages_are_not_counted_delivered() {
        let mut net = Network::new(NetworkConfig::reliable());
        let mut rng = Rng::new(5);
        for _ in 0..3 {
            net.transmit(&mut rng); // scheduled but never applied
        }
        assert_eq!(net.delivered(), 0, "in-flight must not count as delivered");
        assert_eq!(net.in_flight(), 3);
        net.note_delivered();
        assert_eq!(net.delivered(), 1);
        assert_eq!(net.in_flight(), 2);
    }
}
