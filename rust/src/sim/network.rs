//! Message-level failure model (paper Section VI-A(i)):
//!
//! * message drop with a fixed probability (0.5 in the "extreme failure"
//!   scenario),
//! * message delay — either a small fixed transmission delay (no-failure
//!   runs) or uniform in [Δ, 10Δ] (failure runs),
//! * delivery to an offline node silently loses the message (churn).

use crate::sim::event::Ticks;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DelayModel {
    /// Constant transmission delay in ticks.
    Fixed(Ticks),
    /// Uniform in [lo, hi) ticks (paper failure scenario: [Δ, 10Δ]).
    Uniform { lo: Ticks, hi: Ticks },
}

impl DelayModel {
    pub fn sample(&self, rng: &mut Rng) -> Ticks {
        match *self {
            DelayModel::Fixed(d) => d,
            DelayModel::Uniform { lo, hi } => rng.range_u64(lo, hi),
        }
    }

    pub fn mean(&self) -> f64 {
        match *self {
            DelayModel::Fixed(d) => d as f64,
            DelayModel::Uniform { lo, hi } => (lo + hi) as f64 / 2.0,
        }
    }
}

#[derive(Clone, Copy, Debug)]
pub struct NetworkConfig {
    pub delay: DelayModel,
    pub drop_prob: f64,
}

impl NetworkConfig {
    /// Reliable network with a small fixed delay (default: 10 ticks = Δ/100).
    pub fn reliable() -> Self {
        NetworkConfig { delay: DelayModel::Fixed(10), drop_prob: 0.0 }
    }

    /// The paper's extreme failure scenario for gossip period `delta`:
    /// 50% drop + uniform [Δ, 10Δ] delay.
    pub fn extreme(delta: Ticks) -> Self {
        NetworkConfig {
            delay: DelayModel::Uniform { lo: delta, hi: 10 * delta },
            drop_prob: 0.5,
        }
    }
}

/// Network instance: decides per-message fate and counts outcomes.
#[derive(Debug)]
pub struct Network {
    pub cfg: NetworkConfig,
    pub sent: u64,
    pub dropped: u64,
    pub lost_offline: u64,
    delivered: u64,
}

impl Network {
    pub fn new(cfg: NetworkConfig) -> Self {
        Network { cfg, sent: 0, dropped: 0, lost_offline: 0, delivered: 0 }
    }

    /// Returns `Some(delivery_delay)` or `None` if the message is dropped.
    pub fn transmit(&mut self, rng: &mut Rng) -> Option<Ticks> {
        self.sent += 1;
        if self.cfg.drop_prob > 0.0 && rng.chance(self.cfg.drop_prob) {
            self.dropped += 1;
            None
        } else {
            Some(self.cfg.delay.sample(rng))
        }
    }

    pub fn note_lost_offline(&mut self) {
        self.lost_offline += 1;
    }

    /// Record an actual delivery (the receiver applied the message).
    pub fn note_delivered(&mut self) {
        self.delivered += 1;
    }

    /// Messages actually handed to a receiver.  Tracked explicitly:
    /// the old derivation `sent - dropped - lost_offline` silently counted
    /// messages still in flight at the horizon as delivered.
    pub fn delivered(&self) -> u64 {
        self.delivered
    }

    /// Messages sent but neither dropped, lost to churn, nor delivered yet.
    pub fn in_flight(&self) -> u64 {
        self.sent - self.dropped - self.lost_offline - self.delivered
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reliable_never_drops() {
        let mut net = Network::new(NetworkConfig::reliable());
        let mut rng = Rng::new(1);
        for _ in 0..1000 {
            assert_eq!(net.transmit(&mut rng), Some(10));
            net.note_delivered();
        }
        assert_eq!(net.dropped, 0);
        assert_eq!(net.delivered(), 1000);
        assert_eq!(net.in_flight(), 0);
    }

    #[test]
    fn extreme_drops_about_half() {
        let mut net = Network::new(NetworkConfig::extreme(1000));
        let mut rng = Rng::new(2);
        let n = 20_000;
        for _ in 0..n {
            net.transmit(&mut rng);
        }
        let rate = net.dropped as f64 / n as f64;
        assert!((rate - 0.5).abs() < 0.02, "drop rate {rate}");
    }

    #[test]
    fn extreme_delay_in_range() {
        let cfg = NetworkConfig::extreme(1000);
        let mut rng = Rng::new(3);
        let mut sum = 0.0;
        let n = 10_000;
        for _ in 0..n {
            let d = cfg.delay.sample(&mut rng);
            assert!((1000..10_000).contains(&d));
            sum += d as f64;
        }
        let mean = sum / n as f64;
        assert!((mean - cfg.delay.mean()).abs() < 100.0, "mean {mean}");
    }

    #[test]
    fn offline_loss_accounting() {
        let mut net = Network::new(NetworkConfig::reliable());
        let mut rng = Rng::new(4);
        net.transmit(&mut rng);
        net.note_lost_offline();
        assert_eq!(net.delivered(), 0);
        assert_eq!(net.in_flight(), 0);
    }

    /// Regression: messages still in flight at the horizon were counted as
    /// delivered by the old `sent - dropped - lost_offline` derivation.
    #[test]
    fn in_flight_messages_are_not_counted_delivered() {
        let mut net = Network::new(NetworkConfig::reliable());
        let mut rng = Rng::new(5);
        for _ in 0..3 {
            net.transmit(&mut rng); // scheduled but never applied
        }
        assert_eq!(net.delivered(), 0, "in-flight must not count as delivered");
        assert_eq!(net.in_flight(), 3);
        net.note_delivered();
        assert_eq!(net.delivered(), 1);
        assert_eq!(net.in_flight(), 2);
    }
}
