//! Event types and the deterministic priority queue of the discrete-event
//! engine (our in-repo PeerSim replacement; DESIGN.md §4).
//!
//! Time is measured in integer *ticks*; the gossip period Δ defaults to
//! 1000 ticks (sim/engine.rs), so one tick ≈ 10 ms at the paper's Δ = 10 s.

use crate::gossip::message::ModelMsg;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

pub type NodeId = usize;
pub type Ticks = u64;

#[derive(Clone, Debug)]
pub enum Event {
    /// Active-loop firing of Algorithm 1 at `node` (wait(Δ) elapsed).
    GossipTick { node: NodeId },
    /// Message delivery: `msg` arrives at `dst`.
    Deliver { dst: NodeId, msg: ModelMsg },
    /// Churn: node comes online.
    Join { node: NodeId },
    /// Churn: node goes offline.
    Leave { node: NodeId },
    /// Measurement probe (error curve sample point).
    Eval,
}

/// A scheduled event. Ordering: time, then insertion sequence — ties resolve
/// in schedule order, which keeps runs deterministic for a given seed.
#[derive(Debug)]
struct Scheduled {
    time: Ticks,
    seq: u64,
    event: Event,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for Scheduled {}
impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Scheduled {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.time, self.seq).cmp(&(other.time, other.seq))
    }
}

/// Min-heap event queue with deterministic FIFO tie-breaking.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Reverse<Scheduled>>,
    next_seq: u64,
}

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, time: Ticks, event: Event) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Reverse(Scheduled { time, seq, event }));
    }

    pub fn pop(&mut self) -> Option<(Ticks, Event)> {
        self.heap.pop().map(|Reverse(s)| (s.time, s.event))
    }

    pub fn peek_time(&self) -> Option<Ticks> {
        self.heap.peek().map(|Reverse(s)| s.time)
    }

    /// Time and event of the next pop, without removing it (the micro-batch
    /// coalescing loop uses this to decide when to flush).
    pub fn peek(&self) -> Option<(Ticks, &Event)> {
        self.heap.peek().map(|Reverse(s)| (s.time, &s.event))
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(30, Event::Eval);
        q.push(10, Event::Join { node: 1 });
        q.push(20, Event::Leave { node: 2 });
        let times: Vec<Ticks> = std::iter::from_fn(|| q.pop().map(|(t, _)| t)).collect();
        assert_eq!(times, vec![10, 20, 30]);
    }

    #[test]
    fn fifo_on_ties() {
        let mut q = EventQueue::new();
        for node in 0..10 {
            q.push(5, Event::Join { node });
        }
        let mut order = Vec::new();
        while let Some((_, Event::Join { node })) = q.pop() {
            order.push(node);
        }
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn peek_matches_pop() {
        let mut q = EventQueue::new();
        q.push(42, Event::Eval);
        assert_eq!(q.peek_time(), Some(42));
        assert!(matches!(q.peek(), Some((42, Event::Eval))));
        assert_eq!(q.len(), 1);
        q.pop();
        assert!(q.is_empty());
        assert!(q.peek().is_none());
    }
}
