//! Event types and the deterministic priority queue of the discrete-event
//! engine (our in-repo PeerSim replacement; DESIGN.md §4).
//!
//! Time is measured in integer *ticks*; the gossip period Δ defaults to
//! 1000 ticks (sim/engine.rs), so one tick ≈ 10 ms at the paper's Δ = 10 s.

use crate::gossip::message::ModelMsg;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

pub type NodeId = usize;
pub type Ticks = u64;

#[derive(Clone, Debug)]
pub enum Event {
    /// Active-loop firing of Algorithm 1 at `node` (wait(Δ) elapsed).
    GossipTick { node: NodeId },
    /// Message delivery: `msg` arrives at `dst`.
    Deliver { dst: NodeId, msg: ModelMsg },
    /// Churn: node comes online.
    Join { node: NodeId },
    /// Churn: node goes offline.
    Leave { node: NodeId },
    /// Measurement probe (error curve sample point).
    Eval,
}

/// A scheduled event. Ordering: time, then insertion sequence — ties resolve
/// in schedule order, which keeps runs deterministic for a given seed.
#[derive(Debug)]
struct Scheduled {
    time: Ticks,
    seq: u64,
    event: Event,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for Scheduled {}
impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Scheduled {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.time, self.seq).cmp(&(other.time, other.seq))
    }
}

/// Min-heap event queue with deterministic FIFO tie-breaking.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Reverse<Scheduled>>,
    next_seq: u64,
}

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, time: Ticks, event: Event) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Reverse(Scheduled { time, seq, event }));
    }

    pub fn pop(&mut self) -> Option<(Ticks, Event)> {
        self.heap.pop().map(|Reverse(s)| (s.time, s.event))
    }

    pub fn peek_time(&self) -> Option<Ticks> {
        self.heap.peek().map(|Reverse(s)| s.time)
    }

    /// Time and event of the next pop, without removing it (the micro-batch
    /// coalescing loop uses this to decide when to flush).
    pub fn peek(&self) -> Option<(Ticks, &Event)> {
        self.heap.peek().map(|Reverse(s)| (s.time, &s.event))
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

/// Total-order key for the sharded simulator's per-shard queues
/// (DESIGN.md §13).  Unlike [`EventQueue`]'s `(time, insertion-seq)`
/// ordering, a `KeyedQueue`'s order is a *pure function of event content*:
/// `(time, class, a, b)` where the class ranks event kinds at equal time
/// (churn < delivery < tick) and `(a, b)` uniquely identify the event
/// within its class — `(src, per-source send counter)` for deliveries,
/// `(node, 0)` for gossip ticks.  Because every key is unique by
/// construction, the pop order is independent of insertion order, which is
/// exactly what makes cross-shard envelope arrival order irrelevant.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct EventKey {
    pub time: Ticks,
    pub class: u8,
    pub a: u64,
    pub b: u64,
}

impl EventKey {
    /// Delivery of the `seq`-th send from `src` (class 2).
    pub fn deliver(time: Ticks, src: NodeId, seq: u64) -> Self {
        Self { time, class: 2, a: src as u64, b: seq }
    }

    /// Gossip tick at `node` (class 3; at most one pending per node).
    pub fn tick(time: Ticks, node: NodeId) -> Self {
        Self { time, class: 3, a: node as u64, b: 0 }
    }
}

#[derive(Debug)]
struct Keyed<E> {
    key: EventKey,
    event: E,
}

impl<E> PartialEq for Keyed<E> {
    fn eq(&self, other: &Self) -> bool {
        self.key == other.key
    }
}
impl<E> Eq for Keyed<E> {}
impl<E> PartialOrd for Keyed<E> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Keyed<E> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.key.cmp(&other.key)
    }
}

/// Min-heap ordered solely by [`EventKey`] — insertion order never matters.
#[derive(Debug)]
pub struct KeyedQueue<E> {
    heap: BinaryHeap<Reverse<Keyed<E>>>,
}

impl<E> Default for KeyedQueue<E> {
    fn default() -> Self {
        Self { heap: BinaryHeap::new() }
    }
}

impl<E> KeyedQueue<E> {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, key: EventKey, event: E) {
        self.heap.push(Reverse(Keyed { key, event }));
    }

    pub fn pop(&mut self) -> Option<(EventKey, E)> {
        self.heap.pop().map(|Reverse(k)| (k.key, k.event))
    }

    pub fn peek_key(&self) -> Option<EventKey> {
        self.heap.peek().map(|Reverse(k)| k.key)
    }

    pub fn peek(&self) -> Option<(EventKey, &E)> {
        self.heap.peek().map(|Reverse(k)| (k.key, &k.event))
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(30, Event::Eval);
        q.push(10, Event::Join { node: 1 });
        q.push(20, Event::Leave { node: 2 });
        let times: Vec<Ticks> = std::iter::from_fn(|| q.pop().map(|(t, _)| t)).collect();
        assert_eq!(times, vec![10, 20, 30]);
    }

    #[test]
    fn fifo_on_ties() {
        let mut q = EventQueue::new();
        for node in 0..10 {
            q.push(5, Event::Join { node });
        }
        let mut order = Vec::new();
        while let Some((_, Event::Join { node })) = q.pop() {
            order.push(node);
        }
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn keyed_queue_order_is_insertion_independent() {
        let keys = vec![
            EventKey::tick(5, 3),
            EventKey::deliver(5, 1, 0),
            EventKey::deliver(5, 0, 2),
            EventKey::deliver(5, 0, 1),
            EventKey::tick(4, 9),
            EventKey::deliver(7, 2, 0),
        ];
        // forward insertion vs reversed insertion: identical pop order
        let mut fwd = KeyedQueue::new();
        for &k in &keys {
            fwd.push(k, ());
        }
        let mut rev = KeyedQueue::new();
        for &k in keys.iter().rev() {
            rev.push(k, ());
        }
        let a: Vec<EventKey> = std::iter::from_fn(|| fwd.pop().map(|(k, _)| k)).collect();
        let b: Vec<EventKey> = std::iter::from_fn(|| rev.pop().map(|(k, _)| k)).collect();
        assert_eq!(a, b);
        // and the order itself: time, then class (deliver < tick), then src, then seq
        assert_eq!(
            a,
            vec![
                EventKey::tick(4, 9),
                EventKey::deliver(5, 0, 1),
                EventKey::deliver(5, 0, 2),
                EventKey::deliver(5, 1, 0),
                EventKey::tick(5, 3),
                EventKey::deliver(7, 2, 0),
            ]
        );
    }

    #[test]
    fn keyed_queue_peek_matches_pop() {
        let mut q = KeyedQueue::new();
        q.push(EventKey::tick(10, 0), "tick");
        q.push(EventKey::deliver(10, 4, 7), "msg");
        assert_eq!(q.peek_key(), Some(EventKey::deliver(10, 4, 7)));
        assert!(matches!(q.peek(), Some((_, &"msg"))));
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop().map(|(_, e)| e), Some("msg"));
        assert_eq!(q.pop().map(|(_, e)| e), Some("tick"));
        assert!(q.is_empty());
    }

    #[test]
    fn peek_matches_pop() {
        let mut q = EventQueue::new();
        q.push(42, Event::Eval);
        assert_eq!(q.peek_time(), Some(42));
        assert!(matches!(q.peek(), Some((42, Event::Eval))));
        assert_eq!(q.len(), 1);
        q.pop();
        assert!(q.is_empty());
        assert!(q.peek().is_none());
    }
}
