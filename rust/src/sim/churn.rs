//! Churn model (paper Section VI-A(i)): online-session lengths drawn from a
//! lognormal distribution (Stutzbach & Rejaie's model, which the paper fits
//! to a FileList.org BitTorrent-community trace we cannot access — DESIGN.md
//! §4), offline gaps scaled so ~90% of peers are online at any moment, and
//! state retained across sessions.
//!
//! Parameters are expressed in ticks.  With the paper's Δ = 10 s and our
//! Δ = 1000 ticks, the default median session of 100Δ corresponds to ~17 min,
//! in the range reported for BitTorrent communities.

use crate::sim::event::{NodeId, Ticks};
use crate::util::bitset::Bitset;
use crate::util::rng::Rng;

/// Upper bound on a single drawn online/offline interval, in ticks
/// (2^40 ticks ≈ 349 years at Δ = 1000 ticks = 10 s).  Lognormal draws have
/// unbounded support: an extreme sigma produces values that overflow `f64 →
/// u64` casts (`exp(…) = inf` saturates to `u64::MAX`) and then overflow
/// the `t + len` interval arithmetic in [`ChurnSchedule::generate`].  Every
/// draw is clamped here instead — far beyond any plausible horizon, so the
/// clamp never distorts a realistic schedule.
pub const MAX_SESSION_TICKS: Ticks = 1 << 40;

/// Clamp a lognormal draw into `[1, MAX_SESSION_TICKS]` ticks, mapping
/// non-finite values (overflowed `exp`) to the cap.
fn clamp_session(x: f64) -> Ticks {
    if x.is_finite() {
        x.clamp(1.0, MAX_SESSION_TICKS as f64) as Ticks
    } else {
        MAX_SESSION_TICKS
    }
}

#[derive(Clone, Copy, Debug)]
pub struct ChurnConfig {
    /// lognormal mu of the online-session length (ln ticks)
    pub mu: f64,
    /// lognormal sigma
    pub sigma: f64,
    /// target steady-state online fraction (paper: 0.9)
    pub online_fraction: f64,
}

impl ChurnConfig {
    /// Defaults matched to the paper's setup for gossip period `delta`.
    pub fn paper_default(delta: Ticks) -> Self {
        ChurnConfig {
            mu: ((100 * delta) as f64).ln(), // median session = 100 cycles
            sigma: 1.39,                     // Stutzbach-Rejaie-style spread
            online_fraction: 0.9,
        }
    }

    fn draw_online(&self, rng: &mut Rng) -> Ticks {
        clamp_session(rng.lognormal(self.mu, self.sigma))
    }

    fn draw_offline(&self, rng: &mut Rng) -> Ticks {
        // E[offline] = E[online] * (1-f)/f gives the target online fraction.
        let scale = (1.0 - self.online_fraction) / self.online_fraction;
        clamp_session(rng.lognormal(self.mu, self.sigma) * scale)
    }
}

/// Precomputed alternating online/offline timeline for every node.
#[derive(Debug)]
pub struct ChurnSchedule {
    /// per node: sorted list of (go_online_at, go_offline_at)
    pub intervals: Vec<Vec<(Ticks, Ticks)>>,
    pub horizon: Ticks,
}

impl ChurnSchedule {
    /// Build a schedule for `n` nodes over `[0, horizon)`.
    pub fn generate(cfg: &ChurnConfig, n: usize, horizon: Ticks, rng: &mut Rng) -> Self {
        let mut intervals = vec![Vec::new(); n];
        for node_iv in intervals.iter_mut() {
            // stationary start: begin mid-session with prob = online fraction
            let mut t: Ticks;
            let mut online = rng.chance(cfg.online_fraction);
            if online {
                // already partway through an online session
                let len = cfg.draw_online(rng);
                let into = rng.below(len.max(1));
                let end = len - into;
                node_iv.push((0, end.min(horizon)));
                t = end;
                online = false;
            } else {
                // already partway through an offline gap: the residual of one
                // gap remains, and the *next* phase is the first online
                // session.  (Leaving `online = false` here made the loop draw
                // a second full offline gap on top of the residual, so ~10%
                // of nodes joined far later than the stationary model
                // predicts — see the stationary-start regression tests.)
                let len = cfg.draw_offline(rng);
                t = len - rng.below(len.max(1));
                online = true;
            }
            while t < horizon {
                if online {
                    let len = cfg.draw_online(rng);
                    node_iv.push((t, t.saturating_add(len).min(horizon)));
                    t = t.saturating_add(len);
                } else {
                    t = t.saturating_add(cfg.draw_offline(rng));
                }
                online = !online;
            }
        }
        ChurnSchedule { intervals, horizon }
    }

    /// Build a schedule from explicit per-node online intervals (scenario
    /// trace replay; `crate::scenario::driver::trace_schedule`).  Intervals
    /// must be sorted and pairwise disjoint per node — the scenario layer
    /// validates this before compiling.
    pub fn from_intervals(intervals: Vec<Vec<(Ticks, Ticks)>>, horizon: Ticks) -> Self {
        debug_assert!(intervals.iter().all(|iv| {
            iv.windows(2).all(|w| w[0].1 <= w[1].0) && iv.iter().all(|&(s, e)| s < e)
        }));
        ChurnSchedule { intervals, horizon }
    }

    /// Is `node` online at `time`?
    pub fn is_online(&self, node: NodeId, time: Ticks) -> bool {
        let iv = &self.intervals[node];
        match iv.binary_search_by(|&(s, _)| s.cmp(&time)) {
            Ok(_) => true, // session starts exactly at `time`
            Err(0) => false,
            Err(i) => time < iv[i - 1].1,
        }
    }

    /// The node's next pause/resume boundary strictly after `time`, as
    /// `(tick, goes_online)`; `None` once the node's liveness no longer
    /// changes before the horizon.  This is what lets the node-group
    /// deployment runtime (DESIGN.md §15) schedule churn as timer-wheel
    /// events instead of re-querying `is_online` on every wake.
    pub fn next_transition(&self, node: NodeId, time: Ticks) -> Option<(Ticks, bool)> {
        let iv = &self.intervals[node];
        // first interval whose start is strictly after `time`
        let i = iv.partition_point(|&(s, _)| s <= time);
        if i > 0 && iv[i - 1].1 > time && iv[i - 1].1 < self.horizon {
            // inside (or before the end of) the previous session: the next
            // boundary is that session's end, unless it outlives the run
            return Some((iv[i - 1].1, false));
        }
        iv.get(i).map(|&(s, _)| (s, true))
    }

    /// Materialize the liveness snapshot at `time` over every scheduled
    /// node as a packed [`Bitset`] — the replica form the simulators carry
    /// (DESIGN.md §14: 1 bit/node instead of `Vec<bool>`'s byte).
    pub fn online_at(&self, time: Ticks) -> Bitset {
        Bitset::from_fn(self.intervals.len(), |i| self.is_online(i, time))
    }

    /// All join/leave transitions as (time, node, goes_online).
    pub fn events(&self) -> Vec<(Ticks, NodeId, bool)> {
        let mut ev = Vec::new();
        for (node, iv) in self.intervals.iter().enumerate() {
            for &(s, e) in iv {
                if s > 0 {
                    ev.push((s, node, true));
                }
                if e < self.horizon {
                    ev.push((e, node, false));
                }
            }
        }
        ev.sort_by_key(|&(t, n, _)| (t, n));
        ev
    }

    /// Fraction of node-time online (sanity metric).
    pub fn measured_online_fraction(&self) -> f64 {
        let total: u128 = self
            .intervals
            .iter()
            .flat_map(|iv| iv.iter().map(|&(s, e)| (e - s) as u128))
            .sum();
        total as f64 / (self.horizon as f64 * self.intervals.len() as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_fraction_near_target() {
        let cfg = ChurnConfig::paper_default(1000);
        let mut rng = Rng::new(42);
        let sched = ChurnSchedule::generate(&cfg, 500, 1_000_000, &mut rng);
        let f = sched.measured_online_fraction();
        assert!((f - 0.9).abs() < 0.05, "online fraction {f}");
    }

    #[test]
    fn next_transition_walks_the_event_sequence() {
        let cfg = ChurnConfig::paper_default(1000);
        let mut rng = Rng::new(11);
        let sched = ChurnSchedule::generate(&cfg, 40, 200_000, &mut rng);
        for node in 0..40 {
            // walking next_transition from 0 must visit exactly this node's
            // entries in the global event sequence, in order
            let expect: Vec<(Ticks, bool)> = sched
                .events()
                .into_iter()
                .filter(|&(t, n, _)| n == node && t > 0)
                .map(|(t, _, on)| (t, on))
                .collect();
            let mut walked = Vec::new();
            let mut t = 0;
            while let Some((next, on)) = sched.next_transition(node, t) {
                assert!(next > t, "transitions advance strictly");
                assert_eq!(sched.is_online(node, next), on, "node {node} t {next}");
                walked.push((next, on));
                t = next;
                assert!(walked.len() <= expect.len(), "non-terminating walk");
            }
            assert_eq!(walked, expect, "node {node}");
        }
    }

    #[test]
    fn is_online_consistent_with_intervals() {
        let cfg = ChurnConfig::paper_default(1000);
        let mut rng = Rng::new(7);
        let sched = ChurnSchedule::generate(&cfg, 50, 100_000, &mut rng);
        for node in 0..50 {
            for &(s, e) in &sched.intervals[node] {
                assert!(sched.is_online(node, s));
                if e > s + 1 {
                    assert!(sched.is_online(node, (s + e) / 2));
                }
                if e < sched.horizon {
                    assert!(!sched.is_online(node, e));
                }
            }
        }
    }

    #[test]
    fn events_alternate_per_node() {
        let cfg = ChurnConfig::paper_default(100);
        let mut rng = Rng::new(3);
        let sched = ChurnSchedule::generate(&cfg, 20, 50_000, &mut rng);
        let ev = sched.events();
        // events sorted by time
        for w in ev.windows(2) {
            assert!(w[0].0 <= w[1].0);
        }
        // per node, transitions alternate join/leave
        for node in 0..20 {
            let seq: Vec<bool> = ev
                .iter()
                .filter(|&&(_, n, _)| n == node)
                .map(|&(_, _, up)| up)
                .collect();
            for w in seq.windows(2) {
                assert_ne!(w[0], w[1], "node {node} transitions must alternate");
            }
        }
    }

    /// Regression (stationary start): a node that begins offline must join
    /// after the *residual* of a single offline gap (mean ≈ E[gap]/2), not
    /// after residual + another full gap as the old code did.
    #[test]
    fn offline_starters_join_within_one_residual_gap() {
        let cfg = ChurnConfig::paper_default(1000);
        // E[offline gap] = E[lognormal(mu, sigma)] * (1-f)/f
        let scale = (1.0 - cfg.online_fraction) / cfg.online_fraction;
        let e_gap = (cfg.mu + cfg.sigma * cfg.sigma / 2.0).exp() * scale;
        let mut rng = Rng::new(11);
        let sched = ChurnSchedule::generate(&cfg, 3000, 2_000_000, &mut rng);
        let first_joins: Vec<f64> = sched
            .intervals
            .iter()
            .filter_map(|iv| iv.first().map(|&(s, _)| s))
            .filter(|&s| s > 0) // offline starters only
            .map(|s| s as f64)
            .collect();
        assert!(first_joins.len() > 150, "expected ~10% offline starters");
        let mean = first_joins.iter().sum::<f64>() / first_joins.len() as f64;
        // with the fix the mean residual is ~E[gap]/2; the old double-gap
        // draw put it near 1.5 * E[gap]
        assert!(
            mean < e_gap,
            "mean first join {mean:.0} vs single-gap mean {e_gap:.0}"
        );
    }

    /// Regression (stationary start): the online fraction must hold the
    /// ~90% target from the very beginning of the run, not dip while the
    /// offline starters sit out a spurious extra gap.
    #[test]
    fn early_window_online_fraction_stays_at_target() {
        let cfg = ChurnConfig::paper_default(1000);
        let mut rng = Rng::new(12);
        let n = 3000;
        let sched = ChurnSchedule::generate(&cfg, n, 2_000_000, &mut rng);
        let window = 20_000; // 20 cycles at delta = 1000 ticks
        let online_time: u64 = sched
            .intervals
            .iter()
            .flat_map(|iv| iv.iter().map(|&(s, e)| e.min(window).saturating_sub(s)))
            .sum();
        let f = online_time as f64 / (window as f64 * n as f64);
        assert!(f > 0.86, "early-window online fraction {f}");
        assert!(f < 0.95, "early-window online fraction {f}");
    }

    /// Regression: a pathological sigma used to saturate the `f64 → u64`
    /// cast (`exp(…) = inf` → `u64::MAX`) and then overflow the interval
    /// arithmetic.  Draws now clamp to `MAX_SESSION_TICKS` and the schedule
    /// stays well-formed.
    #[test]
    fn pathological_sigma_clamps_instead_of_overflowing() {
        let cfg = ChurnConfig { mu: 10.0, sigma: 500.0, online_fraction: 0.9 };
        let mut rng = Rng::new(21);
        let horizon = 1_000_000;
        let sched = ChurnSchedule::generate(&cfg, 200, horizon, &mut rng);
        for iv in &sched.intervals {
            for &(s, e) in iv {
                assert!(s < e, "empty/inverted interval ({s}, {e})");
                assert!(e <= horizon);
            }
            for w in iv.windows(2) {
                assert!(w[0].1 <= w[1].0, "overlap {w:?}");
            }
        }
        // the clamp itself: direct draws never exceed the cap
        for _ in 0..1000 {
            assert!(cfg.draw_online(&mut rng) <= MAX_SESSION_TICKS);
            assert!(cfg.draw_offline(&mut rng) >= 1);
        }
    }

    #[test]
    fn from_intervals_replays_exactly() {
        let sched = ChurnSchedule::from_intervals(
            vec![vec![(0, 10), (20, 30)], vec![(5, 40)]],
            40,
        );
        assert!(sched.is_online(0, 0));
        assert!(!sched.is_online(0, 15));
        assert!(sched.is_online(0, 25));
        assert!(!sched.is_online(1, 0));
        assert!(sched.is_online(1, 39));
        let ev = sched.events();
        // node 0: leave@10, join@20, leave@30; node 1: join@5 (end at
        // horizon emits no event)
        assert_eq!(ev.len(), 4);
    }

    #[test]
    fn online_at_matches_per_node_queries() {
        let cfg = ChurnConfig::paper_default(1000);
        let mut rng = Rng::new(17);
        let n = 80;
        let sched = ChurnSchedule::generate(&cfg, n, 100_000, &mut rng);
        for t in [0, 1, 999, 50_000, 99_999] {
            let bs = sched.online_at(t);
            assert_eq!(bs.len(), n);
            for node in 0..n {
                assert_eq!(bs.test(node), sched.is_online(node, t), "node {node} @ {t}");
            }
        }
    }

    #[test]
    fn intervals_are_disjoint_and_sorted() {
        let cfg = ChurnConfig::paper_default(1000);
        let mut rng = Rng::new(9);
        let sched = ChurnSchedule::generate(&cfg, 100, 200_000, &mut rng);
        for iv in &sched.intervals {
            for w in iv.windows(2) {
                assert!(w[0].1 <= w[1].0, "overlapping sessions {w:?}");
            }
        }
    }
}
