//! Discrete-event P2P simulation substrate (in-repo PeerSim replacement):
//! deterministic event queue, message failure models, and lognormal churn.
pub mod churn;
pub mod event;
pub mod network;

pub use churn::{ChurnConfig, ChurnSchedule};
pub use event::{Event, EventQueue, NodeId, Ticks};
pub use network::{DelayModel, Fate, Network, NetworkConfig};
