//! PERFECT MATCHING baseline (Section VI-A(e)): the gossip protocol with the
//! peer-sampling service replaced by a fresh random perfect matching every
//! cycle, so each peer receives exactly one message per cycle.  Maximizes
//! mixing efficiency; not practical (needs global coordination), used in
//! Fig. 2 to probe the model-diversity hypothesis.

use crate::data::dataset::Dataset;
use crate::gossip::protocol::{GossipSim, ProtocolConfig, RunResult};
use crate::p2p::overlay::SamplerConfig;

/// Run the given configuration with the matching sampler swapped in.
pub fn run_perfect_matching(mut cfg: ProtocolConfig, data: &Dataset) -> RunResult {
    cfg.sampler = SamplerConfig::Matching;
    GossipSim::new(cfg, data).run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{urls_like, Scale};

    #[test]
    fn matching_converges_and_each_node_gets_one_msg() {
        let ds = urls_like(1, Scale(0.02));
        let mut cfg = ProtocolConfig::paper_default(30);
        cfg.eval.n_peers = 15;
        let n = ds.n_train() as f64;
        let res = run_perfect_matching(cfg, &ds);
        // every node sends exactly one message per cycle (even count of
        // online nodes -> full matching; our scale gives even n)
        let per_cycle = res.stats.messages_sent as f64 / (n * 30.0);
        assert!(per_cycle > 0.9, "messages per node-cycle {per_cycle}");
        let first = res.curve.points.first().unwrap().err_mean;
        assert!(res.curve.final_error() < first);
    }
}
