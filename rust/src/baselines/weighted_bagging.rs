//! Weighted bagging baselines WB1 / WB2 (Section VI-A(e), Eq. 18-19):
//! N models trained on independent uniform sample streams — the *ideal*
//! utilization of the N parallel updates the network performs per cycle.
//!
//! Key implementation shortcut, straight from the paper's own Eq. (6)/(7):
//! margin-weighted voting over a set of linear models equals prediction by
//! their average, so the vote over N (or min(2^t, N)) models reduces to one
//! summed model — evaluation is O(d) per test row instead of O(N d).

use crate::data::dataset::Dataset;
use crate::eval::tracker::{point_from_errors, Curve};
use crate::eval::{self, zero_one_error};
use crate::learning::{Learner, LinearModel};
use crate::util::rng::Rng;

pub enum Bagging {
    /// Eq. (18): vote over all N models.
    Wb1,
    /// Eq. (19): vote over min(2^t, N) models — the number of models a
    /// gossip node has (transitively) heard from by cycle t.
    Wb2,
}

/// Error curves for the chosen variant.  Models are updated once per cycle
/// each, on independent uniform streams.
pub fn curve(data: &Dataset, learner: &Learner, variant: Bagging, cycles: u64, seed: u64) -> Curve {
    let n = data.n_train();
    let d = data.d();
    let mut rng = Rng::new(seed);
    let mut models: Vec<LinearModel> = (0..n).map(|_| LinearModel::zeros(d)).collect();

    let label = match variant {
        Bagging::Wb1 => "wb1",
        Bagging::Wb2 => "wb2",
    };
    let mut curve = Curve::new(label);
    let grid = eval::log_spaced_cycles(cycles);
    let mut done = 0u64;

    for &target in &grid {
        while done < target {
            for m in models.iter_mut() {
                let i = rng.below_usize(n);
                learner.update(m, &data.train.row(i), data.train_y[i]);
            }
            done += 1;
        }
        // voting == averaged model (Eq. 6/7); subset for WB2
        let k = match variant {
            Bagging::Wb1 => n,
            Bagging::Wb2 => {
                if target >= 63 {
                    n
                } else {
                    ((1u64 << target.min(62)) as usize).min(n)
                }
            }
        };
        let mut sum = vec![0.0f32; d];
        // a fresh random subset per measurement, as a node's 2^t influence
        // set is random
        let idx = rng.sample_indices(n, k);
        let mut buf = vec![0.0f32; d];
        for &i in &idx {
            models[i].write_weights(&mut buf);
            for (s, &v) in sum.iter_mut().zip(&buf) {
                *s += v;
            }
        }
        let avg = LinearModel::from_weights(sum, target);
        let e = zero_one_error(&avg, &data.test, &data.test_y);
        curve.push(point_from_errors(target, &[e], None, None, None, 0));
    }
    curve
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{urls_like, Scale};

    #[test]
    fn wb1_converges_fast() {
        let ds = urls_like(1, Scale(0.02));
        let c = curve(&ds, &Learner::pegasos(0.01), Bagging::Wb1, 30, 5);
        assert!(c.final_error() < 0.2, "final {}", c.final_error());
        // bagging should already be decent after very few cycles
        let early = c.points.iter().find(|p| p.cycle == 5).unwrap().err_mean;
        assert!(early < 0.35, "early {early}");
    }

    #[test]
    fn wb2_approaches_wb1() {
        let ds = urls_like(2, Scale(0.02));
        let w1 = curve(&ds, &Learner::pegasos(0.01), Bagging::Wb1, 40, 5);
        let w2 = curve(&ds, &Learner::pegasos(0.01), Bagging::Wb2, 40, 5);
        // by cycle 40, 2^t >> N so the two coincide in distribution
        assert!((w1.final_error() - w2.final_error()).abs() < 0.05);
        // at cycle 1, WB2 votes over 2 models and should generally be worse
        // than WB1's full vote (allow equality on easy seeds)
        let e1 = w1.points.iter().find(|p| p.cycle == 1).unwrap().err_mean;
        let e2 = w2.points.iter().find(|p| p.cycle == 1).unwrap().err_mean;
        assert!(e2 >= e1 - 0.05, "wb2 {e2} vs wb1 {e1}");
    }
}
