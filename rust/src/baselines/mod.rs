//! Baseline algorithms from Section VI-A: sequential Pegasos, the two
//! weighted-bagging idealizations, and the perfect-matching sampler variant.
pub mod perfect_matching;
pub mod sequential;
pub mod weighted_bagging;

pub use perfect_matching::run_perfect_matching;
pub use sequential::pegasos_20k_error;
pub use weighted_bagging::Bagging;
