//! Sequential Pegasos baseline (Table I last row, Fig. 1 "Pegasos"): a
//! single model trained on uniformly drawn examples.  In cycle t this is
//! exactly what P2PegasosRW produces at every node in the failure-free case,
//! so it doubles as the no-merge convergence reference.

use crate::data::dataset::Dataset;
use crate::eval::tracker::{point_from_errors, Curve};
use crate::eval::{self, zero_one_error};
use crate::learning::{Learner, LinearModel};
use crate::util::rng::Rng;

/// Train for `iters` uniform random samples; return the final model.
pub fn train(data: &Dataset, learner: &Learner, iters: u64, seed: u64) -> LinearModel {
    let mut rng = Rng::new(seed);
    let n = data.n_train();
    let mut m = LinearModel::zeros(data.d());
    for _ in 0..iters {
        let i = rng.below_usize(n);
        learner.update(&mut m, &data.train.row(i), data.train_y[i]);
    }
    m
}

/// Table I bottom row: 0-1 test error after 20,000 Pegasos iterations.
pub fn pegasos_20k_error(data: &Dataset, lambda: f32, seed: u64) -> f64 {
    let m = train(data, &Learner::pegasos(lambda), 20_000, seed);
    zero_one_error(&m, &data.test, &data.test_y)
}

/// Error curve on the log-spaced cycle grid: the model at point t has seen
/// exactly t samples (one gossip cycle = one update in the paper's
/// accounting).
pub fn curve(data: &Dataset, learner: &Learner, cycles: u64, seed: u64) -> Curve {
    let mut rng = Rng::new(seed);
    let n = data.n_train();
    let mut m = LinearModel::zeros(data.d());
    let mut c = Curve::new(format!("{}-sequential", learner.name()));
    let grid = eval::log_spaced_cycles(cycles);
    let mut done = 0u64;
    for &target in &grid {
        while done < target {
            let i = rng.below_usize(n);
            learner.update(&mut m, &data.train.row(i), data.train_y[i]);
            done += 1;
        }
        let e = zero_one_error(&m, &data.test, &data.test_y);
        c.push(point_from_errors(target, &[e], None, None, None, 0));
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{urls_like, Scale};

    #[test]
    fn curve_error_falls() {
        let ds = urls_like(1, Scale(0.02));
        let c = curve(&ds, &Learner::pegasos(0.01), 2000, 7);
        let first = c.points.first().unwrap().err_mean;
        let last = c.final_error();
        assert!(last < first, "{first} -> {last}");
        assert!(last < 0.2);
    }

    #[test]
    fn train_is_deterministic() {
        let ds = urls_like(2, Scale(0.01));
        let a = train(&ds, &Learner::pegasos(0.01), 500, 3);
        let b = train(&ds, &Learner::pegasos(0.01), 500, 3);
        assert_eq!(a.weights(), b.weights());
        assert_eq!(a.t, 500);
    }
}
