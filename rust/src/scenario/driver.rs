//! Scenario compilation and driving (DESIGN.md §11).
//!
//! A validated [`Scenario`] compiles into a [`CompiledScenario`]: a sorted
//! list of `(tick, Mutation)` pairs plus the resolved churn directive and
//! initial membership.  Compilation is **seed-deterministic and
//! order-independent**: the node subsets behind mass-leave waves are drawn
//! from per-event derived RNG streams (`derive_seed` over
//! `scenario/<name>/<event>@<cycle>`), never from a shared sequential
//! stream, so two compilations of the same scenario — in the simulator, the
//! batched engine, a deployment coordinator, or all 512 nodes of a
//! deployment — agree mutation for mutation.
//!
//! Execution paths hold a [`ScenarioDriver`] cursor and call
//! [`ScenarioDriver::pop_due`] at tick boundaries, applying each
//! [`Mutation`] to their own state (the event-driven simulator flushes any
//! pending micro-batch first, so scalar and micro-batched execution see
//! mutations at identical points — pinned bit-for-bit in
//! tests/engine_parity.rs).  Phase ends revert to the scenario baseline
//! (drop/delay), heal partitions, and restore forced leavers; point events
//! are one-way.

use crate::p2p::Topology;
use crate::scenario::{
    ChurnSpec, EdgeSet, Membership, PointAction, Scenario, ScenarioError, TraceEntry,
};
use crate::sim::churn::{ChurnConfig, ChurnSchedule};
use crate::sim::event::Ticks;
use crate::sim::network::{DelayModel, NetworkConfig};
use crate::util::rng::{derive_seed, Rng};

/// One tick-indexed state change.
#[derive(Clone, Debug, PartialEq)]
pub enum Mutation {
    /// set the message-drop probability
    SetDrop(f64),
    /// set the message-delay model (already resolved to ticks)
    SetDelay(DelayModel),
    /// install a partition: per-node component ids over the full universe;
    /// cross-component sends are blocked until [`Mutation::Heal`]
    SetPartition(Vec<u32>),
    /// heal partitions *and* restore every failed topology edge
    Heal,
    /// fail the listed topology edges (canonical `(min, max)` pairs):
    /// sends across them block in both directions, consuming no RNG
    /// draws, until restored
    EdgeFail(Vec<(u32, u32)>),
    /// restore the listed failed edges, or every failed edge (`None`)
    EdgeRestore(Option<Vec<(u32, u32)>>),
    /// toggle the concept: training and test labels flip sign
    Drift,
    /// flash crowd: `k` new nodes join (ids continue from the current
    /// membership; the model store grows by `k` SoA rows)
    Grow(usize),
    /// force the listed nodes offline (scenario overlay on top of churn)
    ForceOffline(Vec<usize>),
    /// lift the forced-offline overlay for the listed nodes
    Restore(Vec<usize>),
}

impl Mutation {
    /// Human-readable one-liner for progress streaming
    /// ([`crate::api::RunEvent::Scenario`]).
    pub fn describe(&self) -> String {
        match self {
            Mutation::SetDrop(p) => format!("drop probability -> {p}"),
            Mutation::SetDelay(m) => format!("delay model -> {m:?}"),
            Mutation::SetPartition(components) => {
                let k = components.iter().copied().max().map_or(1, |m| m + 1);
                format!("partition into {k} components")
            }
            Mutation::Heal => "partition healed, failed links restored".to_string(),
            Mutation::EdgeFail(edges) => format!("{} topology links fail", edges.len()),
            Mutation::EdgeRestore(None) => "all failed links restored".to_string(),
            Mutation::EdgeRestore(Some(edges)) => {
                format!("{} topology links restored", edges.len())
            }
            Mutation::Drift => "concept drift: labels invert".to_string(),
            Mutation::Grow(k) => format!("{k} nodes join"),
            Mutation::ForceOffline(ids) => format!("{} nodes forced offline", ids.len()),
            Mutation::Restore(ids) => format!("{} nodes restored", ids.len()),
        }
    }
}

/// The resolved churn directive of a compiled scenario.
#[derive(Clone, Debug, PartialEq)]
pub enum CompiledChurn {
    /// keep whatever the base configuration says
    Inherit,
    Off,
    /// the paper's lognormal model at the run's Δ
    Paper,
    /// replayed availability intervals (still in cycles; resolved against
    /// Δ and the horizon by [`resolve_churn_schedule`])
    Trace(Vec<TraceEntry>),
}

/// A [`Scenario`] resolved against a concrete run: `n` nodes, gossip period
/// `delta` ticks, `cycles` horizon, base network config and seed.
#[derive(Clone, Debug)]
pub struct CompiledScenario {
    pub name: String,
    /// tick-sorted mutations (stable order on ties: baseline, then phases
    /// by start cycle, then events)
    pub muts: Vec<(Ticks, Mutation)>,
    /// membership at tick 0 (grows via [`Mutation::Grow`])
    pub initial: usize,
    pub churn: CompiledChurn,
    pub delta: Ticks,
}

impl CompiledScenario {
    /// Compile a **validated** scenario (callers run
    /// [`Scenario::validate`] first; compilation re-validates and surfaces
    /// the same typed errors).  `topo` is the run's resolved graph
    /// topology, if any — required when the timeline mutates edges
    /// (`edge_fail`/`edge_restore`/`bridge_cut`), ignored otherwise.
    pub fn compile(
        s: &Scenario,
        n: usize,
        delta: Ticks,
        cycles: u64,
        seed: u64,
        base_net: NetworkConfig,
        topo: Option<&Topology>,
    ) -> Result<CompiledScenario, ScenarioError> {
        s.validate(n, cycles)?;
        s.validate_topology(topo)?;
        let n0 = s.initial_nodes(n);
        let tick = |c: u64| c * delta;
        // the baseline the phase ends revert to: the run's network config
        // with the scenario-level overrides folded in
        let mut base = base_net;
        let mut muts: Vec<(Ticks, Mutation)> = Vec::new();
        if let Some(p) = s.drop {
            base.drop_prob = p;
            muts.push((0, Mutation::SetDrop(p)));
        }
        if let Some(d) = s.delay {
            base.delay = d.to_model(delta);
            muts.push((0, Mutation::SetDelay(base.delay)));
        }

        // per-wave leave selections come from derived streams keyed by the
        // wave's identity, so compilation order can never change them
        let leave_ids = |what: &str, at: u64, membership: usize, frac: f64| -> Vec<usize> {
            let k = ((membership as f64 * frac).round() as usize).clamp(1, membership);
            let mut rng =
                Rng::new(derive_seed(seed, &format!("scenario/{}/{what}@{at}", s.name)));
            let mut ids = rng.sample_indices(membership, k);
            ids.sort_unstable();
            ids
        };

        // membership as of a phase start: the initial population plus every
        // join event strictly before it (same-tick Grow mutations apply
        // *after* phase-start mutations, so `<` matches the runtime order) —
        // a leave wave after a flash crowd must sample the grown network,
        // not the founding nodes only
        let membership_at = |cycle: u64| -> usize {
            let mut m = n0;
            for e in &s.events {
                if e.at < cycle {
                    if let PointAction::Join(j) = &e.action {
                        m += resolve_join(*j, n0);
                    }
                }
            }
            m
        };

        for p in &s.phases {
            if let Some(d) = p.drop {
                muts.push((tick(p.from), Mutation::SetDrop(d)));
                muts.push((tick(p.to), Mutation::SetDrop(base.drop_prob)));
            }
            if let Some(d) = p.delay {
                muts.push((tick(p.from), Mutation::SetDelay(d.to_model(delta))));
                muts.push((tick(p.to), Mutation::SetDelay(base.delay)));
            }
            if let Some(spec) = &p.partition {
                muts.push((tick(p.from), Mutation::SetPartition(spec.components(n))));
                muts.push((tick(p.to), Mutation::Heal));
            }
            if let Some(f) = p.leave {
                let ids = leave_ids(&p.name, p.from, membership_at(p.from), f);
                muts.push((tick(p.from), Mutation::ForceOffline(ids.clone())));
                muts.push((tick(p.to), Mutation::Restore(ids)));
            }
        }
        // events run in sorted (at, name) order, which is exactly the order
        // their mutations apply at runtime, so a running membership counter
        // is correct here
        let mut membership = n0;
        for e in &s.events {
            let m = match &e.action {
                PointAction::Drift => Mutation::Drift,
                PointAction::Heal => Mutation::Heal,
                PointAction::Drop(p) => Mutation::SetDrop(*p),
                PointAction::Delay(d) => Mutation::SetDelay(d.to_model(delta)),
                PointAction::Partition(spec) => Mutation::SetPartition(spec.components(n)),
                PointAction::Leave(f) => {
                    Mutation::ForceOffline(leave_ids(&e.name, e.at, membership, *f))
                }
                PointAction::Join(m) => {
                    let k = resolve_join(*m, n0);
                    membership += k;
                    Mutation::Grow(k)
                }
                // edge subsets mirror the leave-wave idiom: a per-event
                // derived stream samples *edge indices* of the canonical
                // edge list, so compilation order and shard count can
                // never change which links fail
                PointAction::EdgeFail(EdgeSet::Fraction(f)) => {
                    let all = topo.map_or(&[][..], |t| t.edges());
                    let edges = if all.is_empty() {
                        Vec::new() // unreachable after validate_topology
                    } else {
                        let k = ((all.len() as f64 * f).round() as usize).clamp(1, all.len());
                        let mut rng = Rng::new(derive_seed(
                            seed,
                            &format!("scenario/{}/{}@{}", s.name, e.name, e.at),
                        ));
                        let mut idx = rng.sample_indices(all.len(), k);
                        idx.sort_unstable();
                        idx.into_iter().map(|i| all[i]).collect()
                    };
                    Mutation::EdgeFail(edges)
                }
                PointAction::EdgeFail(EdgeSet::List(edges)) => {
                    Mutation::EdgeFail(edges.clone())
                }
                PointAction::EdgeRestore(edges) => Mutation::EdgeRestore(edges.clone()),
                // a bridge cut resolves to the concrete cut-set: exactly
                // the topology edges crossing between the partition's
                // components (deterministic, no sampling)
                PointAction::BridgeCut(spec) => {
                    let edges =
                        topo.map_or_else(Vec::new, |t| t.crossing_edges(&spec.components(n)));
                    Mutation::EdgeFail(edges)
                }
            };
            muts.push((tick(e.at), m));
        }
        // stable by tick: baseline first, then phases (already sorted by
        // start), then events (already sorted by at)
        muts.sort_by_key(|&(t, _)| t);
        let churn = match &s.churn {
            None => CompiledChurn::Inherit,
            Some(ChurnSpec::Off) => CompiledChurn::Off,
            Some(ChurnSpec::Paper) => CompiledChurn::Paper,
            Some(ChurnSpec::Trace(e)) => CompiledChurn::Trace(e.clone()),
        };
        Ok(CompiledScenario { name: s.name.clone(), muts, initial: n0, churn, delta })
    }

    /// The tick at which `node` becomes a member: 0 for the initial
    /// population, the matching [`Mutation::Grow`] tick for flash-crowd
    /// joiners, `Ticks::MAX` for ids never reached.
    pub fn join_tick(&self, node: usize) -> Ticks {
        if node < self.initial {
            return 0;
        }
        let mut next = self.initial;
        for (t, m) in &self.muts {
            if let Mutation::Grow(k) = m {
                next += k;
                if node < next {
                    return *t;
                }
            }
        }
        Ticks::MAX
    }

    /// Total membership after every join wave.
    pub fn final_membership(&self) -> usize {
        self.initial
            + self
                .muts
                .iter()
                .map(|(_, m)| if let Mutation::Grow(k) = m { *k } else { 0 })
                .sum::<usize>()
    }
}

/// Join waves: fractions are relative to the *initial* membership
/// ("join:3.0" quadruples a flash-crowd run), counts are absolute.
fn resolve_join(m: Membership, initial: usize) -> usize {
    m.resolve(initial).max(1)
}

/// Cursor over a compiled timeline.  Each execution context owns one (the
/// two simulators, every shard runner, the deployment coordinator, and
/// every deployment node thread) and applies mutations to its own state as
/// ticks pass.  The compiled timeline itself is immutable and shared via
/// `Arc` — at 1M nodes a `ForceOffline` wave can carry tens of thousands of
/// node ids, and a per-shard deep clone of that (DESIGN.md §14) is exactly
/// the replicated-state cost this type exists to avoid.
#[derive(Clone, Debug)]
pub struct ScenarioDriver {
    compiled: std::sync::Arc<CompiledScenario>,
    cursor: usize,
}

impl ScenarioDriver {
    pub fn new(compiled: std::sync::Arc<CompiledScenario>) -> Self {
        ScenarioDriver { compiled, cursor: 0 }
    }

    pub fn compiled(&self) -> &CompiledScenario {
        &self.compiled
    }

    /// The tick at which this cursor's next mutation becomes due, if any
    /// remain.  The node-group deployment runtime (DESIGN.md §15) arms a
    /// timer at this tick's wall-clock image instead of polling `pop_due`
    /// every wake.
    pub fn next_due_tick(&self) -> Option<Ticks> {
        self.compiled.muts.get(self.cursor).map(|&(t, _)| t)
    }

    /// Is a mutation due at or before `now`?
    pub fn has_due(&self, now: Ticks) -> bool {
        self.compiled
            .muts
            .get(self.cursor)
            .map_or(false, |&(t, _)| t <= now)
    }

    /// Pop the next mutation due at or before `now`, if any.
    pub fn pop_due(&mut self, now: Ticks) -> Option<Mutation> {
        if self.has_due(now) {
            let m = self.compiled.muts[self.cursor].1.clone();
            self.cursor += 1;
            Some(m)
        } else {
            None
        }
    }
}

/// Resolve the churn schedule for a run, honoring a scenario's churn
/// directive while reproducing `GossipSim`'s historical RNG fork order:
/// exactly one fork is consumed when (and only when) a lognormal schedule
/// is generated, so matched simulator/deployment runs draw identical
/// schedules and scenario-free runs are bit-for-bit unchanged.  Trace
/// replay is deterministic and consumes no fork.
pub fn resolve_churn_schedule(
    base: Option<&ChurnConfig>,
    scn: Option<&CompiledScenario>,
    n: usize,
    delta: Ticks,
    horizon: Ticks,
    rng: &mut Rng,
) -> Option<ChurnSchedule> {
    match scn.map(|c| &c.churn) {
        Some(CompiledChurn::Off) => None,
        Some(CompiledChurn::Paper) => {
            let cfg = ChurnConfig::paper_default(delta);
            let mut crng = rng.fork();
            Some(ChurnSchedule::generate(&cfg, n, horizon, &mut crng))
        }
        Some(CompiledChurn::Trace(entries)) => {
            Some(trace_schedule(entries, n, delta, horizon))
        }
        Some(CompiledChurn::Inherit) | None => base.map(|c| {
            let mut crng = rng.fork();
            ChurnSchedule::generate(c, n, horizon, &mut crng)
        }),
    }
}

/// Build a [`ChurnSchedule`] from replayed availability intervals: trace
/// entries are per-node `[from, to)` *cycles*, mapped to ticks and clamped
/// to the horizon; nodes without entries stay online for the whole run.
pub fn trace_schedule(
    entries: &[TraceEntry],
    n: usize,
    delta: Ticks,
    horizon: Ticks,
) -> ChurnSchedule {
    let mut intervals: Vec<Vec<(Ticks, Ticks)>> = vec![Vec::new(); n];
    let mut mentioned = vec![false; n];
    for e in entries {
        if e.node >= n {
            continue; // validated earlier; never panic on a stale trace
        }
        mentioned[e.node] = true;
        let s = (e.from * delta).min(horizon);
        let t = (e.to * delta).min(horizon);
        if s < t {
            intervals[e.node].push((s, t));
        }
    }
    for (node, iv) in intervals.iter_mut().enumerate() {
        if !mentioned[node] {
            iv.push((0, horizon));
        } else {
            iv.sort_unstable();
        }
    }
    ChurnSchedule::from_intervals(intervals, horizon)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::{builtin, DelaySpec, PartitionSpec, Phase, PointEvent};

    fn net() -> NetworkConfig {
        NetworkConfig::reliable()
    }

    #[test]
    fn compile_orders_and_reverts_phases() {
        let mut s = Scenario::empty("t");
        s.drop = Some(0.1);
        s.phases.push(Phase {
            name: "storm".into(),
            from: 10,
            to: 20,
            drop: Some(0.9),
            delay: Some(DelaySpec::Uniform(1.0, 2.0)),
            partition: Some(PartitionSpec::Halves),
            leave: Some(0.5),
        });
        let c = CompiledScenario::compile(&s, 10, 1000, 50, 7, net(), None).unwrap();
        assert_eq!(c.initial, 10);
        let ticks: Vec<Ticks> = c.muts.iter().map(|&(t, _)| t).collect();
        assert!(ticks.windows(2).all(|w| w[0] <= w[1]), "{ticks:?}");
        // baseline drop at 0; phase start at 10_000; revert at 20_000
        assert_eq!(c.muts[0], (0, Mutation::SetDrop(0.1)));
        assert!(c
            .muts
            .iter()
            .any(|m| *m == (10_000, Mutation::SetDrop(0.9))));
        assert!(c.muts.iter().any(|m| *m == (20_000, Mutation::SetDrop(0.1))));
        assert!(c.muts.iter().any(|m| matches!(m, (20_000, Mutation::Heal))));
        // revert of the delay goes back to the reliable baseline
        assert!(c
            .muts
            .iter()
            .any(|m| *m == (20_000, Mutation::SetDelay(DelayModel::Fixed(10)))));
        // forced leavers are restored with the same ids
        let off: Vec<_> = c
            .muts
            .iter()
            .filter_map(|(t, m)| match m {
                Mutation::ForceOffline(ids) => Some((*t, ids.clone())),
                _ => None,
            })
            .collect();
        let on: Vec<_> = c
            .muts
            .iter()
            .filter_map(|(t, m)| match m {
                Mutation::Restore(ids) => Some((*t, ids.clone())),
                _ => None,
            })
            .collect();
        assert_eq!(off.len(), 1);
        assert_eq!(off[0].1.len(), 5);
        assert_eq!(on[0].1, off[0].1);
        assert_eq!((off[0].0, on[0].0), (10_000, 20_000));
    }

    #[test]
    fn compile_is_seed_deterministic() {
        let mut s = Scenario::empty("det");
        s.phases.push(Phase {
            name: "out".into(),
            from: 5,
            to: 9,
            drop: None,
            delay: None,
            partition: None,
            leave: Some(0.3),
        });
        let a = CompiledScenario::compile(&s, 40, 1000, 20, 42, net(), None).unwrap();
        let b = CompiledScenario::compile(&s, 40, 1000, 20, 42, net(), None).unwrap();
        assert_eq!(a.muts, b.muts);
        let c = CompiledScenario::compile(&s, 40, 1000, 20, 43, net(), None).unwrap();
        assert_ne!(a.muts, c.muts, "leave subsets must depend on the seed");
    }

    /// Regression: a leave wave scheduled after a flash-crowd join must
    /// sample the *grown* membership, not the founding nodes only.
    #[test]
    fn phase_leave_after_join_samples_grown_membership() {
        let mut s = Scenario::empty("late-outage");
        s.initial = Some(crate::scenario::Membership::Fraction(0.25));
        s.events.push(PointEvent {
            name: "crowd".into(),
            at: 10,
            action: PointAction::Join(crate::scenario::Membership::Fraction(3.0)),
        });
        s.phases.push(Phase {
            name: "out".into(),
            from: 20,
            to: 30,
            drop: None,
            delay: None,
            partition: None,
            leave: Some(0.5),
        });
        let c = CompiledScenario::compile(&s, 100, 1000, 40, 3, net(), None).unwrap();
        let off: Vec<&Vec<usize>> = c
            .muts
            .iter()
            .filter_map(|(_, m)| match m {
                Mutation::ForceOffline(ids) => Some(ids),
                _ => None,
            })
            .collect();
        assert_eq!(off.len(), 1);
        // half of the post-join membership (100), not half of the 25 founders
        assert_eq!(off[0].len(), 50);
        assert!(
            off[0].iter().any(|&i| i >= 25),
            "the wave must be able to hit flash-crowd joiners"
        );
    }

    #[test]
    fn join_tick_and_membership_accounting() {
        let s = builtin("flash-crowd").unwrap();
        let c = CompiledScenario::compile(&s, 100, 1000, 300, 1, net(), None).unwrap();
        assert_eq!(c.initial, 25);
        assert_eq!(c.final_membership(), 100);
        assert_eq!(c.join_tick(0), 0);
        assert_eq!(c.join_tick(24), 0);
        assert_eq!(c.join_tick(25), 100 * 1000);
        assert_eq!(c.join_tick(99), 100 * 1000);
        assert_eq!(c.join_tick(100), Ticks::MAX);
    }

    #[test]
    fn driver_pops_in_order_once() {
        let mut s = Scenario::empty("d");
        s.drop = Some(0.2);
        s.events.push(PointEvent {
            name: "x".into(),
            at: 3,
            action: PointAction::Drift,
        });
        let c = CompiledScenario::compile(&s, 10, 100, 10, 1, net(), None).unwrap();
        let mut d = ScenarioDriver::new(std::sync::Arc::new(c));
        assert!(d.has_due(0));
        assert_eq!(d.pop_due(0), Some(Mutation::SetDrop(0.2)));
        assert_eq!(d.pop_due(0), None, "drift at tick 300 is not due yet");
        assert!(!d.has_due(299));
        assert_eq!(d.pop_due(300), Some(Mutation::Drift));
        assert_eq!(d.pop_due(10_000), None, "timeline exhausted");
    }

    #[test]
    fn trace_schedule_replays_intervals() {
        let entries = vec![
            TraceEntry { node: 0, from: 0, to: 5 },
            TraceEntry { node: 0, from: 8, to: 12 },
            TraceEntry { node: 2, from: 3, to: 4 },
        ];
        let sched = trace_schedule(&entries, 4, 100, 1000);
        assert!(sched.is_online(0, 0));
        assert!(sched.is_online(0, 499));
        assert!(!sched.is_online(0, 600));
        assert!(sched.is_online(0, 900));
        assert!(!sched.is_online(2, 0));
        assert!(sched.is_online(2, 350));
        // untouched nodes stay online the whole run
        assert!(sched.is_online(1, 0) && sched.is_online(1, 999));
        assert!(sched.is_online(3, 500));
        // clamped to the horizon
        let far = vec![TraceEntry { node: 0, from: 5, to: 99 }];
        let sched = trace_schedule(&far, 2, 100, 1000);
        assert!(sched.is_online(0, 999));
        assert!(!sched.is_online(0, 400));
    }

    #[test]
    fn resolve_churn_preserves_fork_order() {
        // no scenario + base config == Paper override at the same seed
        let base = ChurnConfig::paper_default(1000);
        let mut rng1 = Rng::new(9);
        let a = resolve_churn_schedule(Some(&base), None, 20, 1000, 50_000, &mut rng1).unwrap();
        let s = builtin("paper-fig3").unwrap();
        let c = CompiledScenario::compile(&s, 20, 1000, 40, 9, net(), None).unwrap();
        let mut rng2 = Rng::new(9);
        let b =
            resolve_churn_schedule(None, Some(&c), 20, 1000, 50_000, &mut rng2).unwrap();
        assert_eq!(a.intervals, b.intervals);
        // both consumed exactly one fork: the parent streams stay in step
        assert_eq!(rng1.next_u64(), rng2.next_u64());
        // Off yields no schedule and consumes nothing
        let mut s_off = Scenario::empty("off");
        s_off.churn = Some(ChurnSpec::Off);
        let c = CompiledScenario::compile(&s_off, 20, 1000, 40, 9, net(), None).unwrap();
        let mut rng3 = Rng::new(9);
        assert!(resolve_churn_schedule(Some(&base), Some(&c), 20, 1000, 50_000, &mut rng3)
            .is_none());
    }

    #[test]
    fn edge_events_compile_against_the_topology() {
        use crate::p2p::{Topology, TopologySpec};
        use crate::scenario::EdgeSet;
        let spec = TopologySpec::parse("ring:1").unwrap().unwrap();
        let topo = Topology::build(&spec, 20, 7).unwrap(); // 20 edges
        let mut s = Scenario::empty("links");
        s.events.push(PointEvent {
            name: "storm".into(),
            at: 5,
            action: PointAction::EdgeFail(EdgeSet::Fraction(0.3)),
        });
        s.events.push(PointEvent {
            name: "cut".into(),
            at: 10,
            action: PointAction::BridgeCut(PartitionSpec::Halves),
        });
        s.events.push(PointEvent {
            name: "zfix".into(),
            at: 15,
            action: PointAction::EdgeRestore(None),
        });
        let c = CompiledScenario::compile(&s, 20, 1000, 20, 42, net(), Some(&topo)).unwrap();
        let failed: Vec<_> = c
            .muts
            .iter()
            .filter_map(|(t, m)| match m {
                Mutation::EdgeFail(e) => Some((*t, e.clone())),
                _ => None,
            })
            .collect();
        assert_eq!(failed.len(), 2);
        // 30% of 20 edges = 6, all real topology edges, sorted canonical
        assert_eq!(failed[0].0, 5000);
        assert_eq!(failed[0].1.len(), 6);
        for &(a, b) in &failed[0].1 {
            assert!(topo.has_edge(a as usize, b as usize), "{a}-{b}");
        }
        assert!(failed[0].1.windows(2).all(|w| w[0] < w[1]));
        // the halves bridge cut on a ring is exactly the two crossing links
        assert_eq!(failed[1].0, 10_000);
        assert_eq!(failed[1].1, topo.crossing_edges(&PartitionSpec::Halves.components(20)));
        assert_eq!(failed[1].1.len(), 2);
        assert!(c.muts.contains(&(15_000, Mutation::EdgeRestore(None))));
        // seed-deterministic subset, sensitive to the seed
        let c2 = CompiledScenario::compile(&s, 20, 1000, 20, 42, net(), Some(&topo)).unwrap();
        assert_eq!(c.muts, c2.muts);
        let c3 = CompiledScenario::compile(&s, 20, 1000, 20, 43, net(), Some(&topo)).unwrap();
        assert_ne!(
            c.muts, c3.muts,
            "sampled edge subsets must depend on the seed"
        );
        // compiling an edge scenario without a graph is a typed error
        assert!(matches!(
            CompiledScenario::compile(&s, 20, 1000, 20, 42, net(), None),
            Err(ScenarioError::NeedsTopology { .. })
        ));
        // ...as is naming an edge the graph does not have
        let mut bad = Scenario::empty("bad");
        bad.events.push(PointEvent {
            name: "x".into(),
            at: 1,
            action: PointAction::EdgeFail(EdgeSet::List(vec![(2, 5)])),
        });
        assert!(matches!(
            CompiledScenario::compile(&bad, 20, 1000, 20, 42, net(), Some(&topo)),
            Err(ScenarioError::UnknownEdge { a: 2, b: 5, .. })
        ));
    }

    #[test]
    fn paper_fig3_compiles_to_the_extreme_constants() {
        let s = builtin("paper-fig3").unwrap();
        let c = CompiledScenario::compile(&s, 50, 1000, 100, 42, net(), None).unwrap();
        assert_eq!(c.churn, CompiledChurn::Paper);
        assert_eq!(c.muts.len(), 2);
        assert_eq!(c.muts[0], (0, Mutation::SetDrop(0.5)));
        assert_eq!(
            c.muts[1],
            (0, Mutation::SetDelay(DelayModel::Uniform { lo: 1000, hi: 10_000 }))
        );
    }
}
