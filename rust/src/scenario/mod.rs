//! Scenario engine (DESIGN.md §11): declarative, phased failure/workload
//! timelines driven uniformly through the event-driven simulator, the
//! cycle-synchronous batched engine, and the socket deployment runtime.
//!
//! The paper's robustness claims rest on a *single* extreme-failure setup
//! (fixed churn model, constant 50% drop, uniform delay).  A [`Scenario`]
//! generalizes that to a timeline: ordered [`Phase`]s (interval conditions
//! that revert to the baseline when the phase ends) plus point
//! [`PointEvent`]s (one-way mutations), over the failure axes
//!
//! * message **drop** probability and **delay** model (expressed in gossip
//!   cycles, so one scenario file works at any tick scale),
//! * **partitions** over node predicates (halves, modulo classes, a leading
//!   fraction, or an explicit id list) with later healing,
//! * **edge failures** on a graph topology (DESIGN.md §16): a
//!   seed-deterministic fraction or explicit list of topology edges fails
//!   (`edge_fail`), cut-sets between partition components fail
//!   (`bridge_cut`), and failed links come back (`edge_restore`, or any
//!   `heal`),
//! * **mass leave / flash-crowd join** membership waves (joins grow the
//!   model store; leaves reuse the churn pause machinery),
//! * **concept drift** (label re-labeling: the synthetic concept inverts,
//!   models must re-converge),
//! * **churn source**: the paper's lognormal model, none, or a replayed
//!   availability **trace** (per-node up/down intervals).
//!
//! Scenarios parse from the `[scenario]` / `[phase.*]` / `[event.*]`
//! sections of the INI format (`config/ini.rs`), either embedded in an
//! experiment config or as a standalone `.scn` file, and validate at parse
//! time with typed [`ScenarioError`]s (overlapping phases, events past the
//! horizon, partitions of unknown node ids, infeasible joins).  A validated
//! scenario compiles ([`driver::CompiledScenario`]) into a seed-deterministic
//! list of tick-indexed [`driver::Mutation`]s that every execution path
//! applies at tick boundaries.  A library of named built-ins
//! ([`builtin`], [`builtin_names`]) backs `golf scenario` and the sweep
//! grid's scenario axis.

use crate::config::ini::{self, Document, Section};
use std::fmt;

pub mod driver;

pub use driver::{
    resolve_churn_schedule, CompiledChurn, CompiledScenario, Mutation, ScenarioDriver,
};

/// Typed scenario parse/validation error: every rejection names what was
/// wrong instead of silently misbehaving at run time.
#[derive(Clone, Debug, PartialEq)]
pub enum ScenarioError {
    /// the underlying INI text failed to parse
    Ini(String),
    UnknownKey { section: String, key: String },
    BadValue { section: String, key: String, value: String },
    MissingKey { section: String, key: String },
    /// a phase with `from >= to`
    EmptyPhase { phase: String },
    /// two phases share cycles — reverting to the baseline would be
    /// ambiguous, so overlap is rejected outright
    OverlappingPhases { a: String, b: String },
    /// a phase end or event lies beyond the run horizon
    PastHorizon { what: String, at: u64, cycles: u64 },
    /// a partition or trace names a node id the run does not have
    UnknownNode { what: String, node: usize, n: usize },
    /// initial membership or a join/leave wave is infeasible
    BadMembership { what: String, detail: String },
    /// two phases (or two events) share a name — their INI sections would
    /// collide, so serialization could not round-trip
    DuplicateName { what: String, name: String },
    /// a churn trace entry is malformed (order, overlap)
    BadTrace { detail: String },
    /// an edge action (`edge_fail`/`edge_restore`/`bridge_cut`) appears in
    /// a run with the implicit complete topology — there is no edge set to
    /// mutate without a `topology =` graph
    NeedsTopology { what: String },
    /// an edge action names an edge the configured graph does not have
    /// (including self-loops, which no topology ever has)
    UnknownEdge { what: String, a: u32, b: u32 },
    UnknownBuiltin { name: String },
    Io { path: String, detail: String },
}

impl fmt::Display for ScenarioError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ScenarioError::Ini(e) => write!(f, "scenario ini: {e}"),
            ScenarioError::UnknownKey { section, key } => {
                write!(f, "[{section}]: unknown key {key:?}")
            }
            ScenarioError::BadValue { section, key, value } => {
                write!(f, "[{section}]: bad value for {key}: {value:?}")
            }
            ScenarioError::MissingKey { section, key } => {
                write!(f, "[{section}]: missing required key {key:?}")
            }
            ScenarioError::EmptyPhase { phase } => {
                write!(f, "phase {phase:?}: `from` must be strictly before `to`")
            }
            ScenarioError::OverlappingPhases { a, b } => {
                write!(f, "phases {a:?} and {b:?} overlap")
            }
            ScenarioError::PastHorizon { what, at, cycles } => {
                write!(f, "{what} at cycle {at} lies past the {cycles}-cycle horizon")
            }
            ScenarioError::UnknownNode { what, node, n } => {
                write!(f, "{what} names node {node}, but the run has only {n} nodes")
            }
            ScenarioError::BadMembership { what, detail } => {
                write!(f, "{what}: {detail}")
            }
            ScenarioError::DuplicateName { what, name } => {
                write!(f, "two {what} sections share the name {name:?}")
            }
            ScenarioError::BadTrace { detail } => write!(f, "churn trace: {detail}"),
            ScenarioError::NeedsTopology { what } => {
                write!(
                    f,
                    "{what} mutates topology edges, but the run uses the implicit \
                     complete graph (set `topology =` to a non-complete graph)"
                )
            }
            ScenarioError::UnknownEdge { what, a, b } => {
                write!(f, "{what} names edge {a}-{b}, which the topology does not have")
            }
            ScenarioError::UnknownBuiltin { name } => {
                write!(
                    f,
                    "unknown built-in scenario {name:?} (try `golf scenario --list`)"
                )
            }
            ScenarioError::Io { path, detail } => write!(f, "{path}: {detail}"),
        }
    }
}

impl std::error::Error for ScenarioError {}

/// Where a run's churn comes from when a scenario overrides it.
#[derive(Clone, Debug, PartialEq)]
pub enum ChurnSpec {
    /// disable churn regardless of the base configuration
    Off,
    /// the paper's lognormal model at the run's Δ
    Paper,
    /// replay per-node (up_cycle, down_cycle) availability intervals; nodes
    /// without entries stay online for the whole run
    Trace(Vec<TraceEntry>),
}

/// One availability interval of a churn trace: `node` is online during
/// `[from, to)` gossip cycles.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceEntry {
    pub node: usize,
    pub from: u64,
    pub to: u64,
}

/// Message delay expressed in fractional gossip cycles, so scenario files
/// are independent of the tick scale (1 cycle = Δ ticks).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DelaySpec {
    Fixed(f64),
    Uniform(f64, f64),
}

impl DelaySpec {
    /// Resolve to the simulator's tick-based delay model.
    pub fn to_model(self, delta: crate::sim::event::Ticks) -> crate::sim::network::DelayModel {
        use crate::sim::network::DelayModel;
        let t = |c: f64| (c * delta as f64).round().max(0.0) as crate::sim::event::Ticks;
        match self {
            DelaySpec::Fixed(c) => DelayModel::Fixed(t(c)),
            DelaySpec::Uniform(lo, hi) => DelayModel::Uniform { lo: t(lo), hi: t(hi).max(t(lo) + 1) },
        }
    }
}

/// A node count expressed either as an absolute count or relative fraction.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Membership {
    /// fraction of a reference population (initial membership for joins,
    /// the full universe for `initial_nodes`)
    Fraction(f64),
    Count(usize),
}

impl Membership {
    pub fn resolve(self, reference: usize) -> usize {
        match self {
            Membership::Fraction(f) => (reference as f64 * f).round() as usize,
            Membership::Count(k) => k,
        }
    }
}

/// How a partition assigns nodes to components (cross-component messages
/// are blocked until healed).
#[derive(Clone, Debug, PartialEq)]
pub enum PartitionSpec {
    /// first half vs second half
    Halves,
    /// component = node id mod k
    Mod(u32),
    /// the leading `f` fraction of ids vs the rest
    First(f64),
    /// the listed nodes vs everyone else
    Nodes(Vec<usize>),
}

impl PartitionSpec {
    /// Per-node component ids over an `n`-node universe.
    pub fn components(&self, n: usize) -> Vec<u32> {
        match self {
            PartitionSpec::Halves => (0..n).map(|i| u32::from(i >= n / 2)).collect(),
            PartitionSpec::Mod(k) => (0..n).map(|i| (i as u32) % k.max(1)).collect(),
            PartitionSpec::First(f) => {
                let cut = (n as f64 * f).round() as usize;
                (0..n).map(|i| u32::from(i >= cut)).collect()
            }
            PartitionSpec::Nodes(ids) => {
                let mut c = vec![0u32; n];
                for &i in ids {
                    if i < n {
                        c[i] = 1;
                    }
                }
                c
            }
        }
    }

    fn validate(&self, what: &str, n: usize) -> Result<(), ScenarioError> {
        if let PartitionSpec::Nodes(ids) = self {
            for &i in ids {
                if i >= n {
                    return Err(ScenarioError::UnknownNode {
                        what: what.to_string(),
                        node: i,
                        n,
                    });
                }
            }
        }
        Ok(())
    }
}

/// Which topology edges an `edge_fail` event hits: a seed-deterministic
/// fraction of the graph's edge set (sampled at compile time from a
/// per-event derived stream) or an explicit list.  Lists are canonical:
/// `(min, max)` endpoint order, sorted, deduplicated — matching
/// [`crate::p2p::Topology::edges`].
#[derive(Clone, Debug, PartialEq)]
pub enum EdgeSet {
    Fraction(f64),
    List(Vec<(u32, u32)>),
}

/// An interval condition over `[from, to)` cycles.  Conditions set at
/// `from` revert to the scenario baseline at `to` (partitions heal, forced
/// leavers rejoin).
#[derive(Clone, Debug, PartialEq)]
pub struct Phase {
    pub name: String,
    pub from: u64,
    pub to: u64,
    pub drop: Option<f64>,
    pub delay: Option<DelaySpec>,
    pub partition: Option<PartitionSpec>,
    /// fraction of the current membership forced offline for the phase
    pub leave: Option<f64>,
}

/// A one-way mutation at a single cycle boundary.
#[derive(Clone, Debug, PartialEq)]
pub struct PointEvent {
    pub name: String,
    pub at: u64,
    pub action: PointAction,
}

#[derive(Clone, Debug, PartialEq)]
pub enum PointAction {
    /// invert the concept: training and test labels flip sign (a second
    /// drift flips back)
    Drift,
    /// flash crowd: grow membership by `Membership` (fractions are relative
    /// to the *initial* membership)
    Join(Membership),
    /// force a fraction of the current membership offline permanently
    /// (use a phase `leave` for a bounded outage)
    Leave(f64),
    Drop(f64),
    Delay(DelaySpec),
    Partition(PartitionSpec),
    /// restores partitions *and* failed topology edges
    Heal,
    /// fail a subset of the graph topology's edges: sends across them
    /// block (both directions, no RNG perturbation) until restored
    EdgeFail(EdgeSet),
    /// restore failed edges: an explicit list, or every failed edge
    /// (`None`, the bare `edge_restore` form)
    EdgeRestore(Option<Vec<(u32, u32)>>),
    /// fail every edge crossing between the components of a
    /// [`PartitionSpec`] — a partition expressed through the graph, so
    /// only real topology links are cut
    BridgeCut(PartitionSpec),
}

/// A declarative failure/workload timeline.  See the module docs for the
/// INI surface and [`driver::CompiledScenario`] for execution.
#[derive(Clone, Debug, PartialEq)]
pub struct Scenario {
    pub name: String,
    /// one-line description shown by `golf scenario --list`
    pub summary: String,
    /// suggested run length; `golf scenario` uses it when `--cycles` is
    /// not given (phases/events must fit the actual horizon)
    pub cycles_hint: Option<u64>,
    /// churn override; `None` inherits the base configuration's churn
    pub churn: Option<ChurnSpec>,
    /// baseline drop probability applied from cycle 0 (None = inherit)
    pub drop: Option<f64>,
    /// baseline delay model applied from cycle 0 (None = inherit)
    pub delay: Option<DelaySpec>,
    /// initial membership; `None` = every node from the start
    pub initial: Option<Membership>,
    pub phases: Vec<Phase>,
    pub events: Vec<PointEvent>,
}

impl Scenario {
    /// A do-nothing timeline (baseline run under a scenario harness).
    pub fn empty(name: &str) -> Self {
        Scenario {
            name: name.to_string(),
            summary: String::new(),
            cycles_hint: None,
            churn: None,
            drop: None,
            delay: None,
            initial: None,
            phases: Vec::new(),
            events: Vec::new(),
        }
    }

    /// Parse the `[scenario]` / `[phase.*]` / `[event.*]` sections of an
    /// INI document (other sections, e.g. `[experiment]`, are ignored —
    /// scenarios embed in experiment configs).
    pub fn from_ini_doc(doc: &Document) -> Result<Self, ScenarioError> {
        let empty = Section::new();
        let head = doc.get("scenario").unwrap_or(&empty);
        let mut s = Scenario::empty("unnamed");
        for (k, v) in head {
            let bad = || ScenarioError::BadValue {
                section: "scenario".into(),
                key: k.clone(),
                value: v.clone(),
            };
            match k.as_str() {
                "name" => s.name = v.clone(),
                "summary" => s.summary = v.clone(),
                "cycles_hint" => s.cycles_hint = Some(v.parse().map_err(|_| bad())?),
                "churn" => s.churn = Some(parse_churn(v, k)?),
                "drop" => s.drop = Some(parse_prob(v).ok_or_else(bad)?),
                "delay" => s.delay = Some(parse_delay(v).ok_or_else(bad)?),
                "initial_nodes" => s.initial = Some(parse_membership(v).ok_or_else(bad)?),
                _ => {
                    return Err(ScenarioError::UnknownKey {
                        section: "scenario".into(),
                        key: k.clone(),
                    })
                }
            }
        }
        // HashMap iteration order is arbitrary: collect prefixed sections
        // and sort by name so parsing (and therefore compilation, including
        // its derived-seed draws) is deterministic.
        let mut phase_names: Vec<&String> = doc
            .keys()
            .filter(|k| k.strip_prefix("phase.").is_some())
            .collect();
        phase_names.sort();
        for full in phase_names {
            let name = full.strip_prefix("phase.").unwrap().to_string();
            s.phases.push(parse_phase(&name, full, &doc[full])?);
        }
        let mut event_names: Vec<&String> = doc
            .keys()
            .filter(|k| k.strip_prefix("event.").is_some())
            .collect();
        event_names.sort();
        for full in event_names {
            let name = full.strip_prefix("event.").unwrap().to_string();
            s.events.push(parse_event(&name, full, &doc[full])?);
        }
        // deterministic timeline order: phases by (from, name), events by
        // (at, name) — file order never matters
        s.phases.sort_by(|a, b| (a.from, &a.name).cmp(&(b.from, &b.name)));
        s.events.sort_by(|a, b| (a.at, &a.name).cmp(&(b.at, &b.name)));
        Ok(s)
    }

    /// Parse a scenario from raw INI text (the standalone `.scn` format).
    pub fn from_ini(text: &str) -> Result<Self, ScenarioError> {
        let doc = ini::parse(text).map_err(|e| ScenarioError::Ini(e.to_string()))?;
        Self::from_ini_doc(&doc)
    }

    /// Serialize back to the `[scenario]` / `[phase.*]` / `[event.*]` INI
    /// sections — the inverse of [`Scenario::from_ini_doc`].
    /// `from_ini(to_ini_sections())` reconstructs an equal scenario, up to
    /// the parser's canonical ordering (phases by `(from, name)`, events by
    /// `(at, name)`) and comment-character sanitization of the summary.
    pub fn to_ini_sections(&self) -> String {
        let mut out = String::from("[scenario]\n");
        out.push_str(&format!("name = {}\n", sanitize(&self.name)));
        if !self.summary.is_empty() {
            out.push_str(&format!("summary = {}\n", sanitize(&self.summary)));
        }
        if let Some(c) = self.cycles_hint {
            out.push_str(&format!("cycles_hint = {c}\n"));
        }
        if let Some(ch) = &self.churn {
            out.push_str(&format!("churn = {}\n", fmt_churn(ch)));
        }
        if let Some(p) = self.drop {
            out.push_str(&format!("drop = {p}\n"));
        }
        if let Some(d) = &self.delay {
            out.push_str(&format!("delay = {}\n", fmt_delay(d)));
        }
        if let Some(m) = &self.initial {
            out.push_str(&format!("initial_nodes = {}\n", fmt_membership(m)));
        }
        for p in &self.phases {
            out.push_str(&format!(
                "\n[phase.{}]\nfrom = {}\nto = {}\n",
                sanitize(&p.name),
                p.from,
                p.to
            ));
            if let Some(d) = p.drop {
                out.push_str(&format!("drop = {d}\n"));
            }
            if let Some(d) = &p.delay {
                out.push_str(&format!("delay = {}\n", fmt_delay(d)));
            }
            if let Some(spec) = &p.partition {
                out.push_str(&format!("partition = {}\n", fmt_partition(spec)));
            }
            if let Some(f) = p.leave {
                out.push_str(&format!("leave = {f}\n"));
            }
        }
        for e in &self.events {
            out.push_str(&format!(
                "\n[event.{}]\nat = {}\naction = {}\n",
                sanitize(&e.name),
                e.at,
                fmt_action(&e.action)
            ));
        }
        out
    }

    /// Read and parse a `.scn` file (resolving any `churn = trace:FILE`
    /// reference relative to the current directory).
    pub fn from_file(path: &str) -> Result<Self, ScenarioError> {
        let text = std::fs::read_to_string(path).map_err(|e| ScenarioError::Io {
            path: path.to_string(),
            detail: e.to_string(),
        })?;
        Self::from_ini(&text)
    }

    /// Resolve the initial membership against an `n`-node universe.
    pub fn initial_nodes(&self, n: usize) -> usize {
        self.initial.map_or(n, |m| m.resolve(n)).min(n)
    }

    /// Validate the timeline against a concrete run: `n` nodes,
    /// `cycles`-cycle horizon.  Called by the configuration layer before a
    /// scenario reaches a simulator or the deployment.
    pub fn validate(&self, n: usize, cycles: u64) -> Result<(), ScenarioError> {
        let n0 = self.initial_nodes(n);
        if n0 < 2 {
            return Err(ScenarioError::BadMembership {
                what: "initial_nodes".into(),
                detail: format!("resolves to {n0} nodes; need at least 2"),
            });
        }
        // names must be unique per kind *after* INI sanitization:
        // `[phase.X]`/`[event.X]` sections collide otherwise and the
        // timeline could not serialize
        let mut names = std::collections::HashSet::new();
        for p in &self.phases {
            if !names.insert(sanitize(&p.name)) {
                return Err(ScenarioError::DuplicateName {
                    what: "phase".into(),
                    name: p.name.clone(),
                });
            }
        }
        names.clear();
        for e in &self.events {
            if !names.insert(sanitize(&e.name)) {
                return Err(ScenarioError::DuplicateName {
                    what: "event".into(),
                    name: e.name.clone(),
                });
            }
        }
        // phases: ordered, non-empty, inside the horizon, pairwise disjoint
        for p in &self.phases {
            if p.from >= p.to {
                return Err(ScenarioError::EmptyPhase { phase: p.name.clone() });
            }
            if p.to > cycles {
                return Err(ScenarioError::PastHorizon {
                    what: format!("phase {:?} end", p.name),
                    at: p.to,
                    cycles,
                });
            }
            if let Some(spec) = &p.partition {
                spec.validate(&format!("phase {:?} partition", p.name), n)?;
            }
        }
        // overlap check on a sorted view (parsing sorts phases, but
        // programmatically built scenarios need not be ordered)
        let mut order: Vec<&Phase> = self.phases.iter().collect();
        order.sort_by_key(|p| p.from);
        for w in order.windows(2) {
            if w[1].from < w[0].to {
                return Err(ScenarioError::OverlappingPhases {
                    a: w[0].name.clone(),
                    b: w[1].name.clone(),
                });
            }
        }
        // events: inside the horizon, feasible membership arithmetic
        let mut membership = n0;
        for e in &self.events {
            if e.at > cycles {
                return Err(ScenarioError::PastHorizon {
                    what: format!("event {:?}", e.name),
                    at: e.at,
                    cycles,
                });
            }
            match &e.action {
                PointAction::Join(m) => {
                    let k = m.resolve(n0);
                    if k == 0 || membership + k > n {
                        return Err(ScenarioError::BadMembership {
                            what: format!("event {:?} join", e.name),
                            detail: format!(
                                "{k} joiners on top of {membership} exceed the \
                                 {n}-node universe (one training row per node)"
                            ),
                        });
                    }
                    membership += k;
                }
                PointAction::Partition(spec) => {
                    spec.validate(&format!("event {:?} partition", e.name), n)?;
                }
                PointAction::BridgeCut(spec) => {
                    spec.validate(&format!("event {:?} bridge_cut", e.name), n)?;
                }
                PointAction::EdgeFail(EdgeSet::List(edges)) => {
                    validate_edges(&format!("event {:?} edge_fail", e.name), edges, n)?;
                }
                PointAction::EdgeRestore(Some(edges)) => {
                    validate_edges(&format!("event {:?} edge_restore", e.name), edges, n)?;
                }
                _ => {}
            }
        }
        if let Some(ChurnSpec::Trace(entries)) = &self.churn {
            validate_trace(entries, n)?;
        }
        Ok(())
    }

    /// Does the timeline mutate topology edges?  Such scenarios only make
    /// sense on a non-complete graph; the configuration layer pairs this
    /// with [`Scenario::validate_topology`] before a run starts.
    pub fn uses_edges(&self) -> bool {
        self.events.iter().any(|e| {
            matches!(
                e.action,
                PointAction::EdgeFail(_)
                    | PointAction::EdgeRestore(_)
                    | PointAction::BridgeCut(_)
            )
        })
    }

    /// Validate edge actions against the run's resolved graph: edge
    /// actions without a graph are rejected, and explicitly listed edges
    /// must exist in it.  Called by the configuration layer (typed
    /// `GolfError` before a run starts) and again by compilation.
    pub fn validate_topology(
        &self,
        topo: Option<&crate::p2p::Topology>,
    ) -> Result<(), ScenarioError> {
        let Some(t) = topo else {
            if let Some(e) = self.events.iter().find(|e| {
                matches!(
                    e.action,
                    PointAction::EdgeFail(_)
                        | PointAction::EdgeRestore(_)
                        | PointAction::BridgeCut(_)
                )
            }) {
                return Err(ScenarioError::NeedsTopology {
                    what: format!("event {:?}", e.name),
                });
            }
            return Ok(());
        };
        for e in &self.events {
            let edges = match &e.action {
                PointAction::EdgeFail(EdgeSet::List(edges)) => edges,
                PointAction::EdgeRestore(Some(edges)) => edges,
                _ => continue,
            };
            for &(a, b) in edges {
                if !t.has_edge(a as usize, b as usize) {
                    return Err(ScenarioError::UnknownEdge {
                        what: format!("event {:?}", e.name),
                        a,
                        b,
                    });
                }
            }
        }
        Ok(())
    }
}

/// Bounds/shape check for an explicit edge list (graph membership is
/// checked separately by [`Scenario::validate_topology`], which needs the
/// resolved graph).
fn validate_edges(what: &str, edges: &[(u32, u32)], n: usize) -> Result<(), ScenarioError> {
    for &(a, b) in edges {
        if a == b {
            return Err(ScenarioError::UnknownEdge { what: what.to_string(), a, b });
        }
        for v in [a, b] {
            if v as usize >= n {
                return Err(ScenarioError::UnknownNode {
                    what: what.to_string(),
                    node: v as usize,
                    n,
                });
            }
        }
    }
    Ok(())
}

fn validate_trace(entries: &[TraceEntry], n: usize) -> Result<(), ScenarioError> {
    let mut per_node: std::collections::HashMap<usize, Vec<(u64, u64)>> =
        std::collections::HashMap::new();
    for e in entries {
        if e.node >= n {
            return Err(ScenarioError::UnknownNode {
                what: "churn trace".into(),
                node: e.node,
                n,
            });
        }
        if e.from >= e.to {
            return Err(ScenarioError::BadTrace {
                detail: format!("node {}: interval [{}, {}) is empty", e.node, e.from, e.to),
            });
        }
        per_node.entry(e.node).or_default().push((e.from, e.to));
    }
    for (node, mut iv) in per_node {
        iv.sort_unstable();
        for w in iv.windows(2) {
            if w[1].0 < w[0].1 {
                return Err(ScenarioError::BadTrace {
                    detail: format!("node {node}: overlapping intervals {w:?}"),
                });
            }
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// value serializers (inverse of the parsers below; used by to_ini_sections)

/// Section/name and summary text must survive the INI lexer: `;`/`#` start
/// comments and `[`/`]` delimit sections, so they are replaced on emission.
fn sanitize(s: &str) -> String {
    s.replace([';', '#', '[', ']'], "-")
}

fn fmt_delay(d: &DelaySpec) -> String {
    match d {
        DelaySpec::Fixed(c) => format!("fixed:{c}"),
        DelaySpec::Uniform(lo, hi) => format!("uniform:{lo}:{hi}"),
    }
}

/// Fractions must keep a decimal point (`parse_membership` reads an
/// integer-looking value as an absolute count).
fn fmt_membership(m: &Membership) -> String {
    match m {
        Membership::Count(k) => k.to_string(),
        Membership::Fraction(f) if f.fract() == 0.0 => format!("{f:.1}"),
        Membership::Fraction(f) => f.to_string(),
    }
}

fn fmt_partition(p: &PartitionSpec) -> String {
    match p {
        PartitionSpec::Halves => "halves".to_string(),
        PartitionSpec::Mod(k) => format!("mod:{k}"),
        PartitionSpec::First(f) => format!("first:{f}"),
        PartitionSpec::Nodes(ids) => {
            let ids: Vec<String> = ids.iter().map(|i| i.to_string()).collect();
            format!("nodes:{}", ids.join(","))
        }
    }
}

fn fmt_churn(c: &ChurnSpec) -> String {
    match c {
        ChurnSpec::Off => "none".to_string(),
        ChurnSpec::Paper => "paper".to_string(),
        ChurnSpec::Trace(entries) => {
            let entries: Vec<String> = entries
                .iter()
                .map(|e| format!("{} {} {}", e.node, e.from, e.to))
                .collect();
            format!("inline:{}", entries.join(","))
        }
    }
}

/// `1-2,3-4`: the inverse of `parse_edge_pairs`.
fn fmt_edges(edges: &[(u32, u32)]) -> String {
    let parts: Vec<String> = edges.iter().map(|(a, b)| format!("{a}-{b}")).collect();
    parts.join(",")
}

fn fmt_action(a: &PointAction) -> String {
    match a {
        PointAction::Drift => "drift".to_string(),
        PointAction::Heal => "heal".to_string(),
        PointAction::Join(m) => format!("join:{}", fmt_membership(m)),
        PointAction::Leave(f) => format!("leave:{f}"),
        PointAction::Drop(p) => format!("drop:{p}"),
        PointAction::Delay(d) => format!("delay:{}", fmt_delay(d)),
        PointAction::Partition(p) => format!("partition:{}", fmt_partition(p)),
        // fractions never contain '-', so the parser can tell the forms apart
        PointAction::EdgeFail(EdgeSet::Fraction(f)) => format!("edge_fail:{f}"),
        PointAction::EdgeFail(EdgeSet::List(edges)) => {
            format!("edge_fail:{}", fmt_edges(edges))
        }
        PointAction::EdgeRestore(None) => "edge_restore".to_string(),
        PointAction::EdgeRestore(Some(edges)) => {
            format!("edge_restore:{}", fmt_edges(edges))
        }
        PointAction::BridgeCut(p) => format!("bridge_cut:{}", fmt_partition(p)),
    }
}

// ---------------------------------------------------------------------------
// value parsers

fn parse_prob(v: &str) -> Option<f64> {
    let p: f64 = v.parse().ok()?;
    (0.0..=1.0).contains(&p).then_some(p)
}

fn parse_delay(v: &str) -> Option<DelaySpec> {
    let mut it = v.split(':');
    match it.next()? {
        "fixed" => {
            let c: f64 = it.next()?.parse().ok()?;
            (it.next().is_none() && c >= 0.0).then_some(DelaySpec::Fixed(c))
        }
        "uniform" => {
            let lo: f64 = it.next()?.parse().ok()?;
            let hi: f64 = it.next()?.parse().ok()?;
            (it.next().is_none() && lo >= 0.0 && hi > lo).then_some(DelaySpec::Uniform(lo, hi))
        }
        _ => None,
    }
}

/// `"0.25"` → fraction, `"64"` → absolute count (integers without a dot
/// are counts, everything else a fraction — `join:3.0` means 3× initial).
fn parse_membership(v: &str) -> Option<Membership> {
    if !v.contains('.') {
        let k: usize = v.parse().ok()?;
        return (k > 0).then_some(Membership::Count(k));
    }
    let f: f64 = v.parse().ok()?;
    (f > 0.0).then_some(Membership::Fraction(f))
}

fn parse_partition(v: &str) -> Option<PartitionSpec> {
    match v.split_once(':') {
        None if v == "halves" => Some(PartitionSpec::Halves),
        Some(("mod", k)) => {
            let k: u32 = k.parse().ok()?;
            (k >= 2).then_some(PartitionSpec::Mod(k))
        }
        Some(("first", f)) => {
            let f: f64 = f.parse().ok()?;
            (f > 0.0 && f < 1.0).then_some(PartitionSpec::First(f))
        }
        Some(("nodes", ids)) => {
            let ids: Option<Vec<usize>> =
                ids.split(',').map(|s| s.trim().parse().ok()).collect();
            let ids = ids?;
            (!ids.is_empty()).then_some(PartitionSpec::Nodes(ids))
        }
        _ => None,
    }
}

fn parse_fraction(v: &str) -> Option<f64> {
    let f: f64 = v.parse().ok()?;
    (f > 0.0 && f <= 1.0).then_some(f)
}

/// `1-2,3-4` → canonical edge list: `(min, max)` per pair, sorted, deduped
/// (the same canonical form `Topology::edges` uses); self-loops rejected.
fn parse_edge_pairs(v: &str) -> Option<Vec<(u32, u32)>> {
    let mut out = Vec::new();
    for part in v.split(',') {
        let (a, b) = part.trim().split_once('-')?;
        let a: u32 = a.trim().parse().ok()?;
        let b: u32 = b.trim().parse().ok()?;
        if a == b {
            return None;
        }
        out.push((a.min(b), a.max(b)));
    }
    out.sort_unstable();
    out.dedup();
    (!out.is_empty()).then_some(out)
}

fn parse_churn(v: &str, key: &str) -> Result<ChurnSpec, ScenarioError> {
    match v {
        "none" | "off" => Ok(ChurnSpec::Off),
        "paper" => Ok(ChurnSpec::Paper),
        other => {
            if let Some(path) = other.strip_prefix("trace:") {
                let text = std::fs::read_to_string(path).map_err(|e| ScenarioError::Io {
                    path: path.to_string(),
                    detail: e.to_string(),
                })?;
                return Ok(ChurnSpec::Trace(parse_trace_text(&text)?));
            }
            if let Some(entries) = other.strip_prefix("inline:") {
                // file-free trace form (`to_ini_sections` emits it): comma-
                // separated `node from to` triples on one line
                let text: String = entries
                    .split(',')
                    .collect::<Vec<_>>()
                    .join("\n");
                return Ok(ChurnSpec::Trace(parse_trace_text(&text)?));
            }
            Err(ScenarioError::BadValue {
                section: "scenario".into(),
                key: key.to_string(),
                value: v.to_string(),
            })
        }
    }
}

/// Availability trace text: one `node from_cycle to_cycle` triple per line,
/// `#`/`;` comments and blank lines allowed.
pub fn parse_trace_text(text: &str) -> Result<Vec<TraceEntry>, ScenarioError> {
    let mut out = Vec::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw
            .split(|c| c == '#' || c == ';')
            .next()
            .unwrap_or("")
            .trim();
        if line.is_empty() {
            continue;
        }
        let parse3 = |line: &str| -> Option<TraceEntry> {
            let mut it = line.split_whitespace();
            let node = it.next()?.parse().ok()?;
            let from = it.next()?.parse().ok()?;
            let to = it.next()?.parse().ok()?;
            it.next().is_none().then_some(TraceEntry { node, from, to })
        };
        match parse3(line) {
            Some(e) => out.push(e),
            None => {
                return Err(ScenarioError::BadTrace {
                    detail: format!(
                        "line {}: expected `node from_cycle to_cycle`, got {line:?}",
                        lineno + 1
                    ),
                })
            }
        }
    }
    Ok(out)
}

fn parse_phase(name: &str, section: &str, kv: &Section) -> Result<Phase, ScenarioError> {
    let need = |key: &str| -> Result<u64, ScenarioError> {
        let v = kv.get(key).ok_or_else(|| ScenarioError::MissingKey {
            section: section.to_string(),
            key: key.to_string(),
        })?;
        v.parse().map_err(|_| ScenarioError::BadValue {
            section: section.to_string(),
            key: key.to_string(),
            value: v.clone(),
        })
    };
    let mut p = Phase {
        name: name.to_string(),
        from: need("from")?,
        to: need("to")?,
        drop: None,
        delay: None,
        partition: None,
        leave: None,
    };
    for (k, v) in kv {
        let bad = || ScenarioError::BadValue {
            section: section.to_string(),
            key: k.clone(),
            value: v.clone(),
        };
        match k.as_str() {
            "from" | "to" => {}
            "drop" => p.drop = Some(parse_prob(v).ok_or_else(bad)?),
            "delay" => p.delay = Some(parse_delay(v).ok_or_else(bad)?),
            "partition" => p.partition = Some(parse_partition(v).ok_or_else(bad)?),
            "leave" => p.leave = Some(parse_fraction(v).ok_or_else(bad)?),
            _ => {
                return Err(ScenarioError::UnknownKey {
                    section: section.to_string(),
                    key: k.clone(),
                })
            }
        }
    }
    Ok(p)
}

fn parse_event(name: &str, section: &str, kv: &Section) -> Result<PointEvent, ScenarioError> {
    let at_v = kv.get("at").ok_or_else(|| ScenarioError::MissingKey {
        section: section.to_string(),
        key: "at".into(),
    })?;
    let at: u64 = at_v.parse().map_err(|_| ScenarioError::BadValue {
        section: section.to_string(),
        key: "at".into(),
        value: at_v.clone(),
    })?;
    let action_v = kv.get("action").ok_or_else(|| ScenarioError::MissingKey {
        section: section.to_string(),
        key: "action".into(),
    })?;
    for k in kv.keys() {
        if k != "at" && k != "action" {
            return Err(ScenarioError::UnknownKey {
                section: section.to_string(),
                key: k.clone(),
            });
        }
    }
    let bad = || ScenarioError::BadValue {
        section: section.to_string(),
        key: "action".into(),
        value: action_v.clone(),
    };
    let action = match action_v.split_once(':') {
        None if action_v == "drift" => PointAction::Drift,
        None if action_v == "heal" => PointAction::Heal,
        None if action_v == "edge_restore" => PointAction::EdgeRestore(None),
        Some(("join", m)) => PointAction::Join(parse_membership(m).ok_or_else(bad)?),
        Some(("leave", f)) => PointAction::Leave(parse_fraction(f).ok_or_else(bad)?),
        Some(("drop", p)) => PointAction::Drop(parse_prob(p).ok_or_else(bad)?),
        Some(("delay", d)) => PointAction::Delay(parse_delay(d).ok_or_else(bad)?),
        Some(("partition", s)) => PointAction::Partition(parse_partition(s).ok_or_else(bad)?),
        // `edge_fail:0.3` (fraction of the graph's edges) vs
        // `edge_fail:1-2,3-4` (explicit list) — only lists contain '-'
        Some(("edge_fail", v)) if v.contains('-') => {
            PointAction::EdgeFail(EdgeSet::List(parse_edge_pairs(v).ok_or_else(bad)?))
        }
        Some(("edge_fail", v)) => {
            PointAction::EdgeFail(EdgeSet::Fraction(parse_fraction(v).ok_or_else(bad)?))
        }
        Some(("edge_restore", v)) => {
            PointAction::EdgeRestore(Some(parse_edge_pairs(v).ok_or_else(bad)?))
        }
        Some(("bridge_cut", s)) => PointAction::BridgeCut(parse_partition(s).ok_or_else(bad)?),
        _ => return Err(bad()),
    };
    Ok(PointEvent { name: name.to_string(), at, action })
}

// ---------------------------------------------------------------------------
// built-in library

/// Names of the built-in scenario library, in display order.
pub fn builtin_names() -> &'static [&'static str] {
    &[
        "paper-fig3",
        "partition-heal",
        "flash-crowd",
        "trace-replay",
        "drift",
        "delay-spike",
        "link-storm",
    ]
}

/// Look up a built-in scenario by name.
pub fn builtin(name: &str) -> Result<Scenario, ScenarioError> {
    let mut s = Scenario::empty(name);
    match name {
        // The paper's Section VI-A(i) "all failures" setup as a constant
        // timeline: reproduces `with_extreme_failures()` bit-for-bit.
        "paper-fig3" => {
            s.summary = "paper Fig. 3 extreme failures: 50% drop, [Δ,10Δ] delay, churn".into();
            s.cycles_hint = Some(200);
            s.churn = Some(ChurnSpec::Paper);
            s.drop = Some(0.5);
            s.delay = Some(DelaySpec::Uniform(1.0, 10.0));
        }
        "partition-heal" => {
            s.summary = "network splits into halves at cycle 40, heals at cycle 120".into();
            s.cycles_hint = Some(200);
            s.phases.push(Phase {
                name: "split".into(),
                from: 40,
                to: 120,
                drop: None,
                delay: None,
                partition: Some(PartitionSpec::Halves),
                leave: None,
            });
        }
        "flash-crowd" => {
            s.summary = "start at 25% membership, 4x flash-crowd join at cycle 100".into();
            s.cycles_hint = Some(300);
            s.initial = Some(Membership::Fraction(0.25));
            s.events.push(PointEvent {
                name: "crowd".into(),
                at: 100,
                action: PointAction::Join(Membership::Fraction(3.0)),
            });
        }
        // Deterministic staggered availability windows over the first 16
        // nodes (everyone else stays online): the replayed-trace churn
        // path without an external file.  Needs a >=16-node run.
        "trace-replay" => {
            s.summary = "replay a staggered 16-node availability trace as churn".into();
            s.cycles_hint = Some(200);
            let mut entries = Vec::new();
            for i in 0..16u64 {
                entries.push(TraceEntry {
                    node: i as usize,
                    from: 0,
                    to: 40 + 5 * i,
                });
                entries.push(TraceEntry {
                    node: i as usize,
                    from: 100 + 3 * i,
                    to: 200,
                });
            }
            s.churn = Some(ChurnSpec::Trace(entries));
        }
        "drift" => {
            s.summary = "concept inverts at cycle 100: labels flip, models re-learn".into();
            s.cycles_hint = Some(300);
            s.events.push(PointEvent {
                name: "invert".into(),
                at: 100,
                action: PointAction::Drift,
            });
        }
        "delay-spike" => {
            s.summary = "delay spikes to uniform [5Δ, 20Δ] during cycles 60..120".into();
            s.cycles_hint = Some(200);
            s.phases.push(Phase {
                name: "spike".into(),
                from: 60,
                to: 120,
                drop: None,
                delay: Some(DelaySpec::Uniform(5.0, 20.0)),
                partition: None,
                leave: None,
            });
        }
        // Needs a non-complete `topology =` graph: edge actions have no
        // edge set to mutate on the implicit complete topology.
        "link-storm" => {
            s.summary =
                "30% of topology links fail at cycle 40, all restored at cycle 120".into();
            s.cycles_hint = Some(200);
            s.events.push(PointEvent {
                name: "storm".into(),
                at: 40,
                action: PointAction::EdgeFail(EdgeSet::Fraction(0.3)),
            });
            s.events.push(PointEvent {
                name: "repair".into(),
                at: 120,
                action: PointAction::EdgeRestore(None),
            });
        }
        other => {
            return Err(ScenarioError::UnknownBuiltin { name: other.to_string() })
        }
    }
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_library_is_complete_and_valid() {
        assert!(builtin_names().len() >= 6);
        for &name in builtin_names() {
            let s = builtin(name).unwrap();
            assert_eq!(s.name, name);
            assert!(!s.summary.is_empty(), "{name} needs a summary");
            let cycles = s.cycles_hint.expect("built-ins carry a cycles hint");
            s.validate(100, cycles).unwrap_or_else(|e| panic!("{name}: {e}"));
        }
        assert!(matches!(
            builtin("bogus"),
            Err(ScenarioError::UnknownBuiltin { .. })
        ));
    }

    #[test]
    fn ini_roundtrip_full_surface() {
        let text = "
[scenario]
name = storm
summary = a bit of everything
cycles_hint = 200
churn = paper
drop = 0.1
delay = fixed:0.01
initial_nodes = 0.5

[phase.split]
from = 20
to = 60
partition = halves

[phase.storm]
from = 80
to = 120
drop = 0.8
delay = uniform:1.0:10.0
leave = 0.25

[event.crowd]
at = 150
action = join:1.0

[event.invert]
at = 160
action = drift
";
        let s = Scenario::from_ini(text).unwrap();
        assert_eq!(s.name, "storm");
        assert_eq!(s.cycles_hint, Some(200));
        assert_eq!(s.churn, Some(ChurnSpec::Paper));
        assert_eq!(s.drop, Some(0.1));
        assert_eq!(s.delay, Some(DelaySpec::Fixed(0.01)));
        assert_eq!(s.initial, Some(Membership::Fraction(0.5)));
        assert_eq!(s.phases.len(), 2);
        assert_eq!(s.phases[0].name, "split"); // sorted by from
        assert_eq!(s.phases[1].drop, Some(0.8));
        assert_eq!(s.phases[1].leave, Some(0.25));
        assert_eq!(s.events.len(), 2);
        assert_eq!(s.events[0].action, PointAction::Join(Membership::Fraction(1.0)));
        assert_eq!(s.events[1].action, PointAction::Drift);
        s.validate(100, 200).unwrap();
    }

    #[test]
    fn rejects_unknown_keys_and_bad_values() {
        let e = Scenario::from_ini("[scenario]\nbogus = 1").unwrap_err();
        assert!(matches!(e, ScenarioError::UnknownKey { .. }), "{e}");
        let e = Scenario::from_ini("[scenario]\ndrop = 1.5").unwrap_err();
        assert!(matches!(e, ScenarioError::BadValue { .. }), "{e}");
        let e = Scenario::from_ini("[scenario]\ndelay = uniform:5.0:2.0").unwrap_err();
        assert!(matches!(e, ScenarioError::BadValue { .. }), "{e}");
        let e = Scenario::from_ini("[phase.x]\nfrom = 1").unwrap_err();
        assert!(matches!(e, ScenarioError::MissingKey { .. }), "{e}");
        let e = Scenario::from_ini("[event.x]\nat = 1\naction = warp:9").unwrap_err();
        assert!(matches!(e, ScenarioError::BadValue { .. }), "{e}");
        let e = Scenario::from_ini("[event.x]\nat = 1\naction = drift\nextra = 1").unwrap_err();
        assert!(matches!(e, ScenarioError::UnknownKey { .. }), "{e}");
    }

    #[test]
    fn validation_rejects_empty_and_overlapping_phases() {
        let s = Scenario::from_ini("[phase.a]\nfrom = 10\nto = 10").unwrap();
        assert!(matches!(
            s.validate(50, 100),
            Err(ScenarioError::EmptyPhase { .. })
        ));
        let s = Scenario::from_ini(
            "[phase.a]\nfrom = 10\nto = 30\ndrop = 0.5\n[phase.b]\nfrom = 20\nto = 40\ndrop = 0.1",
        )
        .unwrap();
        assert!(matches!(
            s.validate(50, 100),
            Err(ScenarioError::OverlappingPhases { .. })
        ));
        // touching phases are fine
        let s = Scenario::from_ini(
            "[phase.a]\nfrom = 10\nto = 20\ndrop = 0.5\n[phase.b]\nfrom = 20\nto = 40\ndrop = 0.1",
        )
        .unwrap();
        s.validate(50, 100).unwrap();
    }

    #[test]
    fn validation_rejects_past_horizon() {
        let s = Scenario::from_ini("[phase.a]\nfrom = 10\nto = 120\ndrop = 0.5").unwrap();
        assert!(matches!(
            s.validate(50, 100),
            Err(ScenarioError::PastHorizon { .. })
        ));
        let s = Scenario::from_ini("[event.late]\nat = 101\naction = drift").unwrap();
        assert!(matches!(
            s.validate(50, 100),
            Err(ScenarioError::PastHorizon { .. })
        ));
        s.validate(50, 101).unwrap();
    }

    #[test]
    fn validation_rejects_unknown_partition_nodes() {
        let s =
            Scenario::from_ini("[phase.p]\nfrom = 1\nto = 5\npartition = nodes:1,2,99").unwrap();
        let e = s.validate(50, 100).unwrap_err();
        assert_eq!(
            e,
            ScenarioError::UnknownNode {
                what: "phase \"p\" partition".into(),
                node: 99,
                n: 50
            }
        );
        // the same list is fine on a big enough run
        s.validate(100, 100).unwrap();
    }

    #[test]
    fn validation_rejects_infeasible_membership() {
        // joins beyond the universe (one training row per node)
        let s = Scenario::from_ini(
            "[scenario]\ninitial_nodes = 0.5\n[event.j]\nat = 10\naction = join:2.0",
        )
        .unwrap();
        assert!(matches!(
            s.validate(100, 50),
            Err(ScenarioError::BadMembership { .. })
        ));
        // 0.5 + 0.5x joins fits
        let s = Scenario::from_ini(
            "[scenario]\ninitial_nodes = 0.5\n[event.j]\nat = 10\naction = join:1.0",
        )
        .unwrap();
        s.validate(100, 50).unwrap();
        // initial membership below 2
        let s = Scenario::from_ini("[scenario]\ninitial_nodes = 1").unwrap();
        assert!(matches!(
            s.validate(100, 50),
            Err(ScenarioError::BadMembership { .. })
        ));
    }

    #[test]
    fn trace_text_parses_and_validates() {
        let entries = parse_trace_text("# trace\n0 0 10\n1 5 20 ; tail\n\n0 15 30\n").unwrap();
        assert_eq!(entries.len(), 3);
        assert_eq!(entries[0], TraceEntry { node: 0, from: 0, to: 10 });
        validate_trace(&entries, 10).unwrap();
        // unknown node id
        let e = validate_trace(&entries, 1).unwrap_err();
        assert!(matches!(e, ScenarioError::UnknownNode { .. }), "{e}");
        // overlapping intervals for one node
        let bad = parse_trace_text("0 0 10\n0 5 15").unwrap();
        assert!(matches!(
            validate_trace(&bad, 10),
            Err(ScenarioError::BadTrace { .. })
        ));
        // malformed line
        assert!(matches!(
            parse_trace_text("0 1"),
            Err(ScenarioError::BadTrace { .. })
        ));
        // empty interval
        assert!(matches!(
            validate_trace(&parse_trace_text("0 5 5").unwrap(), 10),
            Err(ScenarioError::BadTrace { .. })
        ));
    }

    #[test]
    fn partition_components_cover_every_spec() {
        assert_eq!(PartitionSpec::Halves.components(4), vec![0, 0, 1, 1]);
        assert_eq!(PartitionSpec::Mod(3).components(5), vec![0, 1, 2, 0, 1]);
        assert_eq!(PartitionSpec::First(0.25).components(4), vec![0, 1, 1, 1]);
        assert_eq!(
            PartitionSpec::Nodes(vec![0, 3]).components(4),
            vec![1, 0, 0, 1]
        );
    }

    #[test]
    fn membership_and_delay_resolution() {
        assert_eq!(Membership::Fraction(0.25).resolve(100), 25);
        assert_eq!(Membership::Count(64).resolve(100), 64);
        use crate::sim::network::DelayModel;
        assert_eq!(DelaySpec::Fixed(0.01).to_model(1000), DelayModel::Fixed(10));
        assert_eq!(
            DelaySpec::Uniform(1.0, 10.0).to_model(1000),
            DelayModel::Uniform { lo: 1000, hi: 10_000 }
        );
    }

    /// `to_ini_sections` is the exact inverse of `from_ini` for every
    /// built-in — including trace-replay, whose churn trace serializes to
    /// the file-free `inline:` form.
    #[test]
    fn ini_serialization_roundtrips_every_builtin() {
        for &name in builtin_names() {
            let s = builtin(name).unwrap();
            let text = s.to_ini_sections();
            let back = Scenario::from_ini(&text)
                .unwrap_or_else(|e| panic!("{name}: reparse failed: {e}\n{text}"));
            assert_eq!(back, s, "{name} did not round-trip:\n{text}");
        }
    }

    #[test]
    fn ini_serialization_roundtrips_full_surface() {
        // every phase/event field populated at once (the "storm" scenario of
        // ini_roundtrip_full_surface, plus inline-trace churn and explicit
        // partitions/membership forms)
        let mut s = Scenario::empty("storm");
        s.summary = "a bit of everything".into();
        s.cycles_hint = Some(200);
        s.churn = Some(ChurnSpec::Trace(vec![
            TraceEntry { node: 0, from: 0, to: 10 },
            TraceEntry { node: 1, from: 5, to: 20 },
        ]));
        s.drop = Some(0.1);
        s.delay = Some(DelaySpec::Fixed(0.01));
        s.initial = Some(Membership::Fraction(0.5));
        s.phases.push(Phase {
            name: "split".into(),
            from: 20,
            to: 60,
            drop: None,
            delay: None,
            partition: Some(PartitionSpec::Nodes(vec![1, 2, 3])),
            leave: None,
        });
        s.phases.push(Phase {
            name: "storm".into(),
            from: 80,
            to: 120,
            drop: Some(0.8),
            delay: Some(DelaySpec::Uniform(1.0, 10.0)),
            partition: None,
            leave: Some(0.25),
        });
        s.events.push(PointEvent {
            name: "crowd".into(),
            at: 150,
            // a whole-number fraction must keep its decimal point
            action: PointAction::Join(Membership::Fraction(3.0)),
        });
        s.events.push(PointEvent {
            name: "invert".into(),
            at: 160,
            action: PointAction::Drift,
        });
        s.events.push(PointEvent {
            name: "zmod".into(),
            at: 170,
            action: PointAction::Partition(PartitionSpec::Mod(4)),
        });
        let back = Scenario::from_ini(&s.to_ini_sections()).unwrap();
        assert_eq!(back, s, "\n{}", s.to_ini_sections());
    }

    #[test]
    fn duplicate_phase_or_event_names_rejected() {
        // only reachable programmatically: the INI parser already rejects
        // colliding sections via its duplicate-key rule
        let mut s = Scenario::empty("dup");
        let phase = |name: &str, from: u64, to: u64| Phase {
            name: name.into(),
            from,
            to,
            drop: Some(0.5),
            delay: None,
            partition: None,
            leave: None,
        };
        s.phases.push(phase("outage", 1, 5));
        s.phases.push(phase("outage", 10, 15));
        assert!(matches!(
            s.validate(50, 100),
            Err(ScenarioError::DuplicateName { .. })
        ));
        s.phases[1].name = "outage2".into();
        s.validate(50, 100).unwrap();
        s.events.push(PointEvent { name: "e".into(), at: 20, action: PointAction::Drift });
        s.events.push(PointEvent { name: "e".into(), at: 30, action: PointAction::Drift });
        assert!(matches!(
            s.validate(50, 100),
            Err(ScenarioError::DuplicateName { .. })
        ));
    }

    #[test]
    fn edge_actions_parse_format_and_validate() {
        // fraction vs list forms, bare vs listed restore, bridge cuts
        let text = "
[event.storm]
at = 10
action = edge_fail:0.3

[event.snip]
at = 20
action = edge_fail:4-3,1-2,3-4

[event.bridge]
at = 30
action = bridge_cut:halves

[event.xfix]
at = 40
action = edge_restore:1-2

[event.yfix]
at = 50
action = edge_restore
";
        let s = Scenario::from_ini(text).unwrap();
        assert!(s.uses_edges());
        assert_eq!(s.events[0].action, PointAction::EdgeFail(EdgeSet::Fraction(0.3)));
        // lists canonicalize: (min,max), sorted, deduped
        assert_eq!(
            s.events[1].action,
            PointAction::EdgeFail(EdgeSet::List(vec![(1, 2), (3, 4)]))
        );
        assert_eq!(s.events[2].action, PointAction::BridgeCut(PartitionSpec::Halves));
        assert_eq!(s.events[3].action, PointAction::EdgeRestore(Some(vec![(1, 2)])));
        assert_eq!(s.events[4].action, PointAction::EdgeRestore(None));
        s.validate(50, 100).unwrap();
        // exact serialization round trip
        let back = Scenario::from_ini(&s.to_ini_sections()).unwrap();
        assert_eq!(back, s, "\n{}", s.to_ini_sections());
        // malformed forms are typed BadValue errors
        for bad in [
            "edge_fail:0.0",
            "edge_fail:1.5",
            "edge_fail:2-2",
            "edge_fail:1-",
            "edge_restore:",
            "bridge_cut:warp",
        ] {
            let e = Scenario::from_ini(&format!("[event.x]\nat = 1\naction = {bad}"))
                .unwrap_err();
            assert!(matches!(e, ScenarioError::BadValue { .. }), "{bad}: {e}");
        }
    }

    #[test]
    fn edge_actions_validate_bounds_and_topology() {
        // node bound: edge endpoint past the run size
        let s = Scenario::from_ini("[event.x]\nat = 1\naction = edge_fail:1-99").unwrap();
        assert!(matches!(
            s.validate(50, 100),
            Err(ScenarioError::UnknownNode { node: 99, .. })
        ));
        s.validate(100, 100).unwrap();
        // programmatic self-loop (the parser already rejects it)
        let mut s = Scenario::empty("loop");
        s.events.push(PointEvent {
            name: "x".into(),
            at: 1,
            action: PointAction::EdgeRestore(Some(vec![(3, 3)])),
        });
        assert!(matches!(
            s.validate(50, 100),
            Err(ScenarioError::UnknownEdge { a: 3, b: 3, .. })
        ));
        // topology cross-check: edge events need a graph...
        let s = Scenario::from_ini("[event.x]\nat = 1\naction = edge_fail:0.5").unwrap();
        assert!(matches!(
            s.validate_topology(None),
            Err(ScenarioError::NeedsTopology { .. })
        ));
        // ...and listed edges must exist in it
        use crate::p2p::{Topology, TopologySpec};
        let spec = TopologySpec::parse("ring:1").unwrap().unwrap();
        let topo = Topology::build(&spec, 10, 7).unwrap();
        s.validate_topology(Some(&topo)).unwrap(); // fractions fit any graph
        let s = Scenario::from_ini("[event.x]\nat = 1\naction = edge_fail:2-5").unwrap();
        assert_eq!(
            s.validate_topology(Some(&topo)),
            Err(ScenarioError::UnknownEdge { what: "event \"x\"".into(), a: 2, b: 5 })
        );
        let s = Scenario::from_ini("[event.x]\nat = 1\naction = edge_fail:2-3").unwrap();
        s.validate_topology(Some(&topo)).unwrap();
        // scenarios without edge actions never need a graph
        assert!(!builtin("paper-fig3").unwrap().uses_edges());
        builtin("paper-fig3").unwrap().validate_topology(None).unwrap();
        assert!(builtin("link-storm").unwrap().uses_edges());
    }

    #[test]
    fn inline_churn_trace_parses() {
        let s = Scenario::from_ini("[scenario]\nchurn = inline:0 0 10,1 5 20\n").unwrap();
        assert_eq!(
            s.churn,
            Some(ChurnSpec::Trace(vec![
                TraceEntry { node: 0, from: 0, to: 10 },
                TraceEntry { node: 1, from: 5, to: 20 },
            ]))
        );
        // malformed triples are rejected with the usual trace error
        assert!(matches!(
            Scenario::from_ini("[scenario]\nchurn = inline:0 0\n"),
            Err(ScenarioError::BadTrace { .. })
        ));
    }
}
