//! Hand-rolled CLI for the `golf` binary (the offline crate set has no
//! clap).  Subcommands:
//!
//! ```text
//! golf run [--config FILE] [--key value ...]   run one experiment
//! golf table1 [--scale S] [--seed N]           reproduce Table I
//! golf fig1|fig2|fig3 [--scale S] [--cycles N] reproduce a figure
//! golf sweep [--scale S] [--replicates K]      parallel grid sweep
//! golf scenario <name|file.scn> [--key value]  scripted failure timeline
//! golf scenario --list                         built-in scenario library
//! golf deploy [--config FILE] [--key value ..] real localhost-TCP run
//! golf info                                    artifact/runtime info
//! ```
//!
//! `--key value` flags mirror the INI keys of config::ExperimentSpec.  Figure
//! and sweep commands fan independent runs across threads (`--threads N`,
//! default: all cores).

use crate::config::{BackendChoice, ExperimentSpec};
use crate::engine::batched::run_batched;
use crate::engine::native::NativeBackend;
use crate::engine::pjrt::PjrtBackend;
use crate::experiments::{self, common, sweep};
use crate::gossip::protocol::{ExecMode, ExecPath, RunResult};
use std::collections::HashMap;

pub struct ParsedArgs {
    pub command: String,
    pub flags: HashMap<String, String>,
}

/// Parse `--key value` pairs after the subcommand. Bare `--flag` followed by
/// another flag (or end) gets value "true".
pub fn parse_args(args: &[String]) -> Result<ParsedArgs, String> {
    let command = args.first().cloned().unwrap_or_else(|| "help".to_string());
    let mut flags = HashMap::new();
    let mut i = 1;
    while i < args.len() {
        let a = &args[i];
        let key = a
            .strip_prefix("--")
            .ok_or(format!("expected --flag, got {a:?}"))?;
        let next_is_value = args.get(i + 1).map_or(false, |n| !n.starts_with("--"));
        if next_is_value {
            flags.insert(key.to_string(), args[i + 1].clone());
            i += 2;
        } else {
            flags.insert(key.to_string(), "true".to_string());
            i += 1;
        }
    }
    Ok(ParsedArgs { command, flags })
}

pub fn usage() -> &'static str {
    "golf — gossip learning with linear models (Ormándi et al., 2011)

USAGE:
  golf run    [--config FILE] [--dataset D] [--scale S] [--cycles N]
              [--variant rw|mu|um] [--learner pegasos|adaline]
              [--failures none|extreme]
              [--backend event|event-pjrt|batched-native|batched-pjrt]
              [--mode microbatch|scalar] [--coalesce TICKS]
              [--exec auto|dense|sparse]
              [--voting true] [--similarity true] [--seed N] [--out FILE.csv]
  golf table1 [--scale S] [--seed N] [--threads T]
  golf fig1   [--scale S] [--cycles N] [--seed N] [--threads T] [--out-dir DIR]
  golf fig2   [--scale S] [--cycles N] [--seed N] [--threads T] [--out-dir DIR]
  golf fig3   [--scale S] [--cycles N] [--seed N] [--threads T] [--out-dir DIR]
  golf sweep  [--scale S] [--cycles N] [--seed N] [--threads T]
              [--replicates K] [--mode microbatch|scalar] [--coalesce TICKS]
              [--exec auto|dense|sparse] [--scenarios a,b,c] [--out-dir DIR]
  golf scenario <name|file.scn> [--dataset D] [--scale S] [--cycles N]
              [--backend event|batched-native] [--deploy [--compare-sim]]
              [--seed N] [--eval_peers K] [--out FILE.csv]
  golf scenario --list
  golf deploy [--config FILE] [--dataset D] [--scale S] [--cycles N]
              [--variant rw|mu|um] [--learner pegasos|adaline|logreg]
              [--failures none|extreme] [--sampler newscast|oracle]
              [--nodes N] [--delta_ms MS] [--eval_peers K] [--seed N]
              [--compare-sim] [--out FILE.csv]
  golf info"
}

fn spec_from_flags(flags: &HashMap<String, String>) -> Result<ExperimentSpec, String> {
    let mut spec = if let Some(path) = flags.get("config") {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        ExperimentSpec::from_ini(&text)?
    } else {
        ExperimentSpec::default()
    };
    let mut kv = flags.clone();
    kv.remove("config");
    kv.remove("out");
    spec.apply(&kv)?;
    Ok(spec)
}

fn run_spec(spec: &ExperimentSpec) -> Result<RunResult, String> {
    let ds = spec.build_dataset()?;
    // scenarios must fit this run's node count and horizon before a
    // simulator may compile them
    spec.validate_scenario(ds.n_train())?;
    let cfg = spec.protocol_config()?;
    eprintln!(
        "running {} on {} ({} nodes, d={}) for {} cycles [{}]",
        cfg.variant.name(),
        ds.name,
        ds.n_train(),
        ds.d(),
        cfg.cycles,
        spec.backend.name()
    );
    match spec.backend {
        BackendChoice::Event => Ok(crate::gossip::run(cfg, &ds)),
        BackendChoice::EventPjrt => {
            let be = PjrtBackend::new(&PjrtBackend::default_dir())
                .map_err(|e| format!("{e:#}"))?;
            crate::gossip::run_with_backend(cfg, &ds, Box::new(be))
                .map_err(|e| format!("{e:#}"))
        }
        BackendChoice::BatchedNative => {
            let mut be = NativeBackend::new();
            run_batched(cfg, &ds, &mut be).map_err(|e| e.to_string())
        }
        BackendChoice::BatchedPjrt => {
            let mut be = PjrtBackend::new(&PjrtBackend::default_dir())
                .map_err(|e| format!("{e:#}"))?;
            run_batched(cfg, &ds, &mut be).map_err(|e| format!("{e:#}"))
        }
    }
}

/// Resolve a deployment spec against its dataset, run it, print the report,
/// and optionally run the matched simulator comparison / write CSV output.
/// Shared by `golf deploy` and `golf scenario --deploy`.
fn deploy_and_report(
    spec: &crate::config::DeploySpec,
    compare_sim: bool,
    out: Option<&str>,
) -> Result<(), String> {
    let ds = spec.experiment.build_dataset()?;
    let cfg = spec.deploy_config(&ds)?;
    eprintln!(
        "deploying {} {} nodes on {} (d={}) for {} cycles of {:?} [{} sampling{}{}]",
        cfg.n_nodes,
        cfg.variant.name(),
        ds.name,
        ds.d(),
        cfg.cycles,
        cfg.delta,
        cfg.sampler.name(),
        if cfg.churn.is_some() { ", churn+drop/delay" } else { "" },
        cfg.scenario
            .as_ref()
            .map_or(String::new(), |s| format!(", scenario {:?}", s.name)),
    );
    if compare_sim && cfg.n_nodes != ds.n_train() {
        eprintln!(
            "warning: --compare-sim with nodes = {} but {} training rows — \
             the simulator always runs one node per row",
            cfg.n_nodes,
            ds.n_train()
        );
    }
    let report = crate::coordinator::run_deployment(&cfg, &ds).map_err(|e| e.to_string())?;
    print_points(&report.curve);
    let s = &report.stats;
    eprintln!(
        "sent={} received={} bytes={} sim_dropped={} blocked={} backlog_lost={} \
         io_errors={} decode_errors={} conns={}",
        s.messages_sent,
        s.messages_received,
        s.bytes_sent,
        s.sim_dropped,
        s.partition_blocked,
        s.backlog_lost,
        s.io_errors,
        s.decode_errors,
        s.conns_accepted,
    );
    eprintln!(
        "final error {:.4} (mean model t {:.1})",
        report.final_error, report.mean_model_t
    );
    let mut curves = vec![report.curve.clone()];
    if compare_sim {
        let sim_cfg = crate::coordinator::matched_sim_config(&cfg);
        let sim = crate::gossip::run(sim_cfg, &ds);
        eprintln!(
            "matched simulator final {:.4} (deploy {:.4}, gap {:+.4})",
            sim.curve.final_error(),
            report.curve.final_error(),
            report.curve.final_error() - sim.curve.final_error(),
        );
        curves.push(sim.curve);
    }
    if let Some(out) = out {
        crate::eval::csv::write_curves(std::path::Path::new(out), &curves)
            .map_err(|e| e.to_string())?;
        eprintln!("wrote {out}");
    }
    Ok(())
}

fn print_points(curve: &crate::eval::tracker::Curve) {
    let mut t = crate::util::benchkit::Table::new(&[
        "cycle", "err", "±std", "vote", "similarity", "msgs",
    ]);
    for p in &curve.points {
        t.row(&[
            p.cycle.to_string(),
            format!("{:.4}", p.err_mean),
            format!("{:.4}", p.err_std),
            p.err_vote.map_or("-".into(), |v| format!("{v:.4}")),
            p.similarity.map_or("-".into(), |v| format!("{v:.4}")),
            p.messages_sent.to_string(),
        ]);
    }
    t.print();
}

fn print_curve(res: &RunResult) {
    print_points(&res.curve);
    eprintln!(
        "sent={} delivered={} dropped={} lost_offline={} updates={}",
        res.stats.messages_sent,
        res.stats.messages_delivered,
        res.stats.messages_dropped,
        res.stats.messages_lost_offline,
        res.stats.updates_applied
    );
}

/// Entry point used by main.rs; returns a process exit code.
pub fn dispatch(args: &[String]) -> i32 {
    // `golf scenario <name|file>` takes one positional argument; splice it
    // into the flag map so the strict `--flag value` parser stays strict
    // for every other command
    let mut args = args.to_vec();
    if args.first().map(String::as_str) == Some("scenario")
        && args.get(1).map_or(false, |a| !a.starts_with("--"))
    {
        let name = args.remove(1);
        args.insert(1, "--name".to_string());
        args.insert(2, name);
    }
    let parsed = match parse_args(&args) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("error: {e}\n\n{}", usage());
            return 2;
        }
    };
    match run_command(&parsed) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

struct FigArgs {
    scale: f64,
    cycles: Option<u64>,
    seed: u64,
    threads: usize,
    out: std::path::PathBuf,
}

fn fig_args(flags: &HashMap<String, String>) -> Result<FigArgs, String> {
    let scale: f64 = flags.get("scale").map_or(Ok(common::env_scale()), |s| {
        s.parse().map_err(|_| format!("bad scale {s:?}"))
    })?;
    let cycles: Option<u64> = match flags.get("cycles") {
        Some(s) => Some(s.parse().map_err(|_| format!("bad cycles {s:?}"))?),
        None => None,
    };
    let seed: u64 = flags.get("seed").map_or(Ok(42), |s| {
        s.parse().map_err(|_| format!("bad seed {s:?}"))
    })?;
    let threads: usize = flags.get("threads").map_or(Ok(sweep::thread_count()), |s| {
        s.parse().map_err(|_| format!("bad threads {s:?}"))
    })?;
    let out: std::path::PathBuf = flags
        .get("out-dir")
        .map(Into::into)
        .unwrap_or_else(common::results_dir);
    Ok(FigArgs { scale, cycles, seed, threads, out })
}

fn run_command(parsed: &ParsedArgs) -> Result<(), String> {
    match parsed.command.as_str() {
        "run" => {
            let spec = spec_from_flags(&parsed.flags)?;
            let res = run_spec(&spec)?;
            print_curve(&res);
            if let Some(out) = parsed.flags.get("out") {
                crate::eval::csv::write_curves(std::path::Path::new(out), &[res.curve.clone()])
                    .map_err(|e| e.to_string())?;
                eprintln!("wrote {out}");
            }
            Ok(())
        }
        "table1" => {
            let a = fig_args(&parsed.flags)?;
            let sets = experiments::datasets(a.seed, a.scale);
            let rows = experiments::table1::run_threads(&sets, a.seed, a.threads);
            experiments::table1::print(&rows);
            Ok(())
        }
        "fig1" => {
            let a = fig_args(&parsed.flags)?;
            let sets = experiments::datasets(a.seed, a.scale);
            let panels = experiments::fig1::run_figure_threads(&sets, a.cycles, a.seed, a.threads);
            experiments::fig1::to_csv(&panels, &a.out).map_err(|e| e.to_string())?;
            eprintln!("wrote {} panels to {}", panels.len(), a.out.display());
            Ok(())
        }
        "fig2" => {
            let a = fig_args(&parsed.flags)?;
            let sets = experiments::datasets(a.seed, a.scale);
            let panels = experiments::fig2::run_figure_threads(&sets, a.cycles, a.seed, a.threads);
            experiments::fig2::to_csv(&panels, &a.out).map_err(|e| e.to_string())?;
            eprintln!("wrote {} panels to {}", panels.len(), a.out.display());
            Ok(())
        }
        "fig3" => {
            let a = fig_args(&parsed.flags)?;
            let sets = experiments::datasets(a.seed, a.scale);
            let panels = experiments::fig3::run_figure_threads(&sets, a.cycles, a.seed, a.threads);
            experiments::fig3::to_csv(&panels, &a.out).map_err(|e| e.to_string())?;
            eprintln!("wrote {} panels to {}", panels.len(), a.out.display());
            Ok(())
        }
        "sweep" => {
            let a = fig_args(&parsed.flags)?;
            let replicates: u64 = parsed.flags.get("replicates").map_or(Ok(1), |s| {
                s.parse().map_err(|_| format!("bad replicates {s:?}"))
            })?;
            let coalesce: u64 = parsed.flags.get("coalesce").map_or(Ok(0), |s| {
                s.parse().map_err(|_| format!("bad coalesce {s:?}"))
            })?;
            let mut cfg =
                sweep::SweepConfig::paper_grid(a.scale, a.cycles.unwrap_or(200), a.seed);
            cfg.replicates = replicates.max(1);
            cfg.threads = a.threads;
            cfg.exec = match parsed.flags.get("mode").map(String::as_str) {
                None | Some("microbatch") => ExecMode::MicroBatch { coalesce },
                Some("scalar") => ExecMode::Scalar,
                Some(other) => return Err(format!("bad mode {other:?}")),
            };
            cfg.path = match parsed.flags.get("exec") {
                None => ExecPath::Auto,
                Some(s) => ExecPath::parse(s).ok_or(format!("bad exec {s:?}"))?,
            };
            if let Some(list) = parsed.flags.get("scenarios") {
                // names and timelines are validated against the grid's
                // actual datasets by run_grid before any job is dispatched
                cfg.scenarios =
                    list.split(',').map(|s| s.trim().to_string()).collect();
            }
            eprintln!(
                "sweep: 3 datasets x {} variants x {} failure modes x {} scenarios x {} \
                 replicates on {} threads",
                cfg.variants.len(),
                cfg.failures.len(),
                cfg.scenarios.len(),
                cfg.replicates,
                cfg.threads
            );
            let cells = sweep::run_grid(&cfg)?;
            let mut t = crate::util::benchkit::Table::new(&[
                "dataset", "variant", "failures", "scenario", "rep", "seed", "final err",
                "msgs",
            ]);
            for c in &cells {
                t.row(&[
                    c.dataset.clone(),
                    c.variant.name().to_string(),
                    if c.failures { "extreme" } else { "none" }.to_string(),
                    c.scenario.clone(),
                    c.replicate.to_string(),
                    format!("{:#x}", c.seed),
                    format!("{:.4}", c.curve.final_error()),
                    c.stats.messages_sent.to_string(),
                ]);
            }
            t.print();
            sweep::to_csv(&cells, &a.out).map_err(|e| e.to_string())?;
            eprintln!("wrote {} sweep cells to {}", cells.len(), a.out.display());
            Ok(())
        }
        "deploy" => {
            let mut flags = parsed.flags.clone();
            let compare_sim = flags.remove("compare-sim").is_some();
            let out = flags.remove("out");
            let mut spec = if let Some(path) = flags.remove("config") {
                let text =
                    std::fs::read_to_string(&path).map_err(|e| format!("{path}: {e}"))?;
                crate::config::DeploySpec::from_ini(&text)?
            } else {
                crate::config::DeploySpec::default()
            };
            spec.apply(&flags)?;
            deploy_and_report(&spec, compare_sim, out.as_deref())
        }
        "scenario" => {
            if parsed.flags.contains_key("list") {
                let mut t = crate::util::benchkit::Table::new(&["name", "cycles", "summary"]);
                for &name in crate::scenario::builtin_names() {
                    let s = crate::scenario::builtin(name).map_err(|e| e.to_string())?;
                    t.row(&[
                        name.to_string(),
                        s.cycles_hint.map_or("-".into(), |c| c.to_string()),
                        s.summary.clone(),
                    ]);
                }
                t.print();
                return Ok(());
            }
            let mut flags = parsed.flags.clone();
            let name = flags
                .remove("name")
                .ok_or("scenario: pass a built-in name or a .scn file (or --list)")?;
            let deploy = flags.remove("deploy").is_some();
            let compare_sim = flags.remove("compare-sim").is_some();
            let out = flags.remove("out");
            // a path (or anything ending in .scn) loads a scenario file —
            // which may bundle [experiment]/[deploy] sections; anything else
            // names a built-in
            let is_file = name.ends_with(".scn") || std::path::Path::new(&name).exists();
            let mut spec = if is_file {
                let text =
                    std::fs::read_to_string(&name).map_err(|e| format!("{name}: {e}"))?;
                let spec = crate::config::DeploySpec::from_ini(&text)?;
                if spec.experiment.scenario.is_none() {
                    return Err(format!("{name}: no [scenario] section"));
                }
                spec
            } else {
                let scn = crate::scenario::builtin(&name).map_err(|e| e.to_string())?;
                let mut spec = crate::config::DeploySpec::default();
                // built-ins carry a suggested run length; --cycles overrides
                if let Some(hint) = scn.cycles_hint {
                    spec.experiment.cycles = hint;
                }
                spec.experiment.scenario = Some(scn);
                spec
            };
            spec.apply(&flags)?;
            let scn_name = spec.experiment.scenario.as_ref().unwrap().name.clone();
            if deploy {
                eprintln!("scenario {scn_name:?} on the socket deployment runtime");
                return deploy_and_report(&spec, compare_sim, out.as_deref());
            }
            if compare_sim {
                // a simulator run has nothing to compare itself against;
                // never let the flag be silently ignored
                return Err(
                    "scenario: --compare-sim compares a deployment against the \
                     matched simulator; combine it with --deploy"
                        .into(),
                );
            }
            eprintln!("scenario {scn_name:?} [{}]", spec.experiment.backend.name());
            let res = run_spec(&spec.experiment)?;
            print_curve(&res);
            if res.stats.messages_blocked > 0 {
                eprintln!("partition-blocked={}", res.stats.messages_blocked);
            }
            if let Some(out) = out {
                crate::eval::csv::write_curves(std::path::Path::new(&out), &[res.curve.clone()])
                    .map_err(|e| e.to_string())?;
                eprintln!("wrote {out}");
            }
            Ok(())
        }
        "info" => {
            let dir = PjrtBackend::default_dir();
            match crate::runtime::Runtime::load(&dir) {
                Ok(rt) => {
                    println!("platform: {}", rt.platform());
                    println!("artifacts: {} ({} ops)", dir.display(), rt.manifest().ops().len());
                    for op in rt.manifest().ops() {
                        let n = rt.manifest().entries.iter().filter(|e| e.op == op).count();
                        println!("  {op}: {n} shape buckets");
                    }
                }
                Err(e) => println!("artifacts not available at {}: {e}", dir.display()),
            }
            Ok(())
        }
        "help" | "--help" | "-h" => {
            println!("{}", usage());
            Ok(())
        }
        other => Err(format!("unknown command {other:?}\n\n{}", usage())),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parse_flags_with_values_and_bools() {
        let p = parse_args(&s(&["run", "--dataset", "urls", "--voting", "--seed", "7"])).unwrap();
        assert_eq!(p.command, "run");
        assert_eq!(p.flags["dataset"], "urls");
        assert_eq!(p.flags["voting"], "true");
        assert_eq!(p.flags["seed"], "7");
    }

    #[test]
    fn rejects_positional_garbage() {
        assert!(parse_args(&s(&["run", "oops"])).is_err());
    }

    #[test]
    fn spec_from_flags_applies_overrides() {
        let p = parse_args(&s(&["run", "--dataset", "spambase", "--cycles", "5"])).unwrap();
        let spec = spec_from_flags(&p.flags).unwrap();
        assert_eq!(spec.dataset, "spambase");
        assert_eq!(spec.cycles, 5);
    }

    #[test]
    fn unknown_command_errors() {
        let p = parse_args(&s(&["frobnicate"])).unwrap();
        assert!(run_command(&p).is_err());
    }

    #[test]
    fn tiny_run_end_to_end() {
        let p = parse_args(&s(&[
            "run", "--dataset", "urls", "--scale", "0.005", "--cycles", "5",
            "--eval_peers", "5",
        ]))
        .unwrap();
        run_command(&p).unwrap();
    }

    #[test]
    fn tiny_forced_sparse_exec_run() {
        let p = parse_args(&s(&[
            "run", "--dataset", "urls", "--scale", "0.005", "--cycles", "3",
            "--eval_peers", "4", "--exec", "sparse",
        ]))
        .unwrap();
        run_command(&p).unwrap();
        // bad value is rejected
        let p = parse_args(&s(&[
            "run", "--dataset", "urls", "--scale", "0.005", "--cycles", "3",
            "--exec", "warp",
        ]))
        .unwrap();
        assert!(run_command(&p).is_err());
    }

    #[test]
    fn tiny_scalar_mode_run() {
        let p = parse_args(&s(&[
            "run", "--dataset", "urls", "--scale", "0.005", "--cycles", "3",
            "--eval_peers", "4", "--mode", "scalar",
        ]))
        .unwrap();
        run_command(&p).unwrap();
    }

    #[test]
    fn deployment_flag_errors_rejected() {
        // spec-level failures surface before any socket is opened (the
        // end-to-end `golf deploy` run lives in tests/deployment.rs, where
        // the socket-heavy tests are serialized)
        let p = parse_args(&s(&["deploy", "--delta_ms", "zero"])).unwrap();
        assert!(run_command(&p).is_err());
        let p = parse_args(&s(&["deploy", "--bogus_key", "1"])).unwrap();
        assert!(run_command(&p).is_err());
        // more nodes than training rows
        let p = parse_args(&s(&[
            "deploy", "--dataset", "urls", "--scale", "0.002", "--nodes", "21",
        ]))
        .unwrap();
        assert!(run_command(&p).is_err());
    }

    #[test]
    fn scenario_list_and_unknown_name() {
        assert_eq!(dispatch(&s(&["scenario", "--list"])), 0);
        assert_eq!(dispatch(&s(&["scenario", "no-such-scenario"])), 1);
        // no positional and no --list is an error with guidance
        assert_eq!(dispatch(&s(&["scenario"])), 1);
        // --compare-sim only makes sense against a deployment
        assert_eq!(dispatch(&s(&["scenario", "paper-fig3", "--compare-sim"])), 1);
    }

    #[test]
    fn tiny_scenario_builtin_run() {
        // positional splicing + built-in lookup + override of the hint
        assert_eq!(
            dispatch(&s(&[
                "scenario", "paper-fig3", "--dataset", "urls", "--scale", "0.005",
                "--cycles", "6", "--eval_peers", "5",
            ])),
            0
        );
        // a timeline that cannot fit the overridden horizon is rejected
        assert_eq!(
            dispatch(&s(&[
                "scenario", "partition-heal", "--scale", "0.005", "--cycles", "6",
            ])),
            1
        );
    }

    #[test]
    fn tiny_scenario_file_run() {
        let path = std::env::temp_dir().join("golf_cli_scenario_test.scn");
        std::fs::write(
            &path,
            "[experiment]\ndataset = urls\nscale = 0.005\ncycles = 8\neval_peers = 5\n\n\
             [scenario]\nname = file-blip\n\n[phase.blip]\nfrom = 2\nto = 5\ndrop = 0.9\n",
        )
        .unwrap();
        assert_eq!(dispatch(&s(&["scenario", path.to_str().unwrap()])), 0);
        // a file without a [scenario] section is rejected
        let bare = std::env::temp_dir().join("golf_cli_scenario_bare.scn");
        std::fs::write(&bare, "[experiment]\ndataset = urls\n").unwrap();
        assert_eq!(dispatch(&s(&["scenario", bare.to_str().unwrap()])), 1);
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&bare).ok();
    }

    #[test]
    fn sweep_scenarios_flag_rejects_unknown() {
        let p = parse_args(&s(&[
            "sweep", "--scale", "0.005", "--cycles", "3", "--scenarios", "warp",
        ]))
        .unwrap();
        assert!(run_command(&p).is_err());
    }

    #[test]
    fn tiny_sweep_end_to_end() {
        let dir = std::env::temp_dir().join("golf_cli_sweep_test");
        let p = parse_args(&s(&[
            "sweep", "--scale", "0.005", "--cycles", "3", "--threads", "2",
            "--out-dir", dir.to_str().unwrap(),
        ]))
        .unwrap();
        run_command(&p).unwrap();
        assert!(dir.join("sweep_urls_nofail.csv").exists());
        assert!(dir.join("sweep_urls_af.csv").exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}
