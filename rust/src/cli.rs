//! Hand-rolled CLI for the `golf` binary (the offline crate set has no
//! clap).  Subcommands:
//!
//! ```text
//! golf run [--config FILE] [--key value ...]   run one experiment
//! golf table1 [--scale S] [--seed N]           reproduce Table I
//! golf fig1|fig2|fig3 [--scale S] [--cycles N] reproduce a figure
//! golf fig-topology [--scale S] [--cycles N]   convergence vs. gossip graph
//! golf sweep [--scale S] [--replicates K]      parallel grid sweep
//! golf scenario <name|file.scn> [--key value]  scripted failure timeline
//! golf scenario --list                         built-in scenario library
//! golf deploy [--config FILE] [--key value ..] real localhost-TCP run
//! golf info                                    artifact/runtime info
//! ```
//!
//! `--key value` flags mirror the INI keys of `config::ExperimentSpec`; a
//! repeated flag is a configuration error, never silently last-wins.  Every
//! command constructs its runs through the [`crate::api`] facade
//! (`RunSpec → Session → Outcome`), streams progress live via
//! [`ProgressObserver`], and maps failures to distinct exit codes per
//! [`GolfError`] variant (config=2, data=3, io=4, scenario=5, backend=6,
//! wire=7).  Figure and sweep commands fan independent runs across threads
//! (`--threads N`, default: all cores).

use crate::api::{
    GolfError, NullObserver, ProgressObserver, RunSpec, Session, SweepAxes, Target,
};
use crate::config::ExperimentSpec;
use crate::engine::pjrt::PjrtBackend;
use crate::experiments::{self, common, sweep};
use crate::gossip::protocol::RunStats;
use std::collections::HashMap;

pub struct ParsedArgs {
    pub command: String,
    pub flags: HashMap<String, String>,
}

/// Parse `--key value` pairs after the subcommand.  Bare `--flag` followed
/// by another flag (or end) gets value "true".  A repeated flag is a typed
/// configuration error — `--cycles 10 --cycles 20` must never silently pick
/// one of the two.
pub fn parse_args(args: &[String]) -> Result<ParsedArgs, GolfError> {
    let command = args.first().cloned().unwrap_or_else(|| "help".to_string());
    let mut flags = HashMap::new();
    let mut i = 1;
    while i < args.len() {
        let a = &args[i];
        let key = a
            .strip_prefix("--")
            .ok_or_else(|| GolfError::config(format!("expected --flag, got {a:?}")))?;
        let next_is_value = args.get(i + 1).map_or(false, |n| !n.starts_with("--"));
        let value = if next_is_value {
            let v = args[i + 1].clone();
            i += 2;
            v
        } else {
            i += 1;
            "true".to_string()
        };
        if flags.insert(key.to_string(), value).is_some() {
            return Err(GolfError::config(format!(
                "duplicate flag --{key} (each flag may be given once)"
            )));
        }
    }
    Ok(ParsedArgs { command, flags })
}

pub fn usage() -> &'static str {
    "golf — gossip learning with linear models (Ormándi et al., 2011)

USAGE:
  golf run    [--config FILE] [--dataset D] [--scale S] [--cycles N]
              [--variant rw|mu|um|pairwise-auc]
              [--learner pegasos|adaline|logreg|pairwise-auc]
              [--merge average|quorum] [--reservoir K]
              [--failures none|extreme]
              [--backend event|event-pjrt|batched-native|batched-pjrt]
              [--mode microbatch|scalar] [--coalesce TICKS]
              [--exec auto|dense|sparse] [--shards N] [--threads T]
              [--topology complete|ring:K|grid|kreg:K|ba:M|graph:FILE]
              [--voting true] [--similarity true] [--seed N] [--out FILE.csv]
  golf table1 [--scale S] [--seed N] [--threads T]
  golf fig1   [--scale S] [--cycles N] [--seed N] [--threads T] [--out-dir DIR]
  golf fig2   [--scale S] [--cycles N] [--seed N] [--threads T] [--out-dir DIR]
  golf fig3   [--scale S] [--cycles N] [--seed N] [--threads T] [--out-dir DIR]
  golf fig-topology [--scale S] [--cycles N] [--seed N] [--threads T]
              [--out-dir DIR]
  golf sweep  [--config FILE] [--scale S] [--cycles N] [--seed N] [--threads T]
              [--replicates K] [--mode microbatch|scalar] [--coalesce TICKS]
              [--exec auto|dense|sparse] [--scenarios a,b,c]
              [--topologies complete,ring:2,...] [--out-dir DIR]
  golf scenario <name|file.scn> [--dataset D] [--scale S] [--cycles N]
              [--backend event|batched-native] [--deploy [--compare-sim]]
              [--topology SPEC] [--seed N] [--eval_peers K] [--out FILE.csv]
  golf scenario --list
  golf deploy [--config FILE] [--dataset D] [--scale S] [--cycles N]
              [--variant rw|mu|um|pairwise-auc]
              [--learner pegasos|adaline|logreg|pairwise-auc]
              [--merge average|quorum] [--reservoir K]
              [--failures none|extreme] [--sampler newscast|oracle]
              [--nodes N] [--node-groups G] [--delta_ms MS] [--eval_peers K]
              [--topology SPEC] [--seed N] [--compare-sim] [--out FILE.csv]
  golf info

EXIT CODES: 0 ok, 2 config, 3 data, 4 io, 5 scenario, 6 backend, 7 wire"
}

/// Reject a parsed config whose bundled sections belong to another
/// subcommand — nothing from a one-schema INI is ever silently ignored.
fn reject_bundled_sections(
    spec: &RunSpec,
    origin: &str,
    allow_deploy: bool,
    allow_sweep: bool,
) -> Result<(), GolfError> {
    if !allow_deploy && spec.target == Target::Deploy {
        return Err(GolfError::config(format!(
            "{origin}: bundles a [deploy] section; run it with `golf deploy --config`"
        )));
    }
    if !allow_sweep && spec.sweep.is_some() {
        return Err(GolfError::config(format!(
            "{origin}: bundles a [sweep] section; run it with `golf sweep --config`"
        )));
    }
    Ok(())
}

/// Build the spec for `golf run`: the strict full-schema parser, restricted
/// to what a single run can execute — a config bundling `[deploy]` or
/// `[sweep]` sections is redirected to the right command instead of having
/// parts of it silently ignored.
fn run_spec_from_flags(flags: &HashMap<String, String>) -> Result<RunSpec, GolfError> {
    let mut spec = if let Some(path) = flags.get("config") {
        let spec = RunSpec::from_ini_file(path)?;
        reject_bundled_sections(&spec, path, false, false)?;
        spec
    } else {
        RunSpec::default()
    };
    let mut kv = flags.clone();
    kv.remove("config");
    kv.remove("out");
    if let Some(s) = kv.remove("threads") {
        // one process-wide thread budget: the sharded simulator (and any
        // sweep running in the same process) lease workers from it
        let t: usize = s
            .parse()
            .map_err(|_| GolfError::config(format!("bad threads {s:?}")))?;
        if t == 0 {
            return Err(GolfError::config("threads must be at least 1".to_string()));
        }
        crate::util::threads::set_budget(t);
    }
    spec.experiment.apply(&kv)?;
    spec.target = Target::for_backend(spec.experiment.backend);
    Ok(spec)
}

/// Apply `--key value` flags onto a full [`RunSpec`]: deployment keys go
/// through the shared [`crate::config::DeploySpec::apply_deploy_key`],
/// everything else delegates to the experiment schema.  The target
/// re-follows the backend unless the spec is already a deployment.
fn apply_flags(spec: &mut RunSpec, flags: &HashMap<String, String>) -> Result<(), GolfError> {
    let mut d = spec.to_deploy_spec();
    let mut rest = HashMap::new();
    for (k, v) in flags {
        if !d.apply_deploy_key(k, v)? {
            rest.insert(k.clone(), v.clone());
        }
    }
    d.experiment.apply(&rest)?;
    spec.experiment = d.experiment;
    spec.delta_ms = d.delta_ms;
    spec.nodes = d.nodes;
    spec.node_groups = d.node_groups;
    if spec.target != Target::Deploy {
        spec.target = Target::for_backend(spec.experiment.backend);
    }
    Ok(())
}

fn announce(session: &Session<'_>) {
    let spec = session.spec();
    if let Some(ds) = session.data() {
        eprintln!(
            "running {} on {} ({} nodes, d={}) for {} cycles [{}]{}",
            spec.experiment.variant.name(),
            ds.name,
            ds.n_train(),
            ds.d(),
            spec.experiment.cycles,
            spec.experiment.backend.name(),
            spec.experiment
                .topology
                .as_ref()
                .map_or(String::new(), |t| format!(" graph {}", t.name())),
        );
    }
}

fn print_run_stats(s: &RunStats) {
    eprintln!(
        "sent={} delivered={} dropped={} lost_offline={} updates={}",
        s.messages_sent,
        s.messages_delivered,
        s.messages_dropped,
        s.messages_lost_offline,
        s.updates_applied
    );
    if s.messages_blocked > 0 {
        eprintln!("partition-blocked={}", s.messages_blocked);
    }
    if let Some(t) = &s.topology {
        eprintln!("topology: {}", t.summary());
    }
}

fn write_csv(path: &str, curves: &[crate::eval::tracker::Curve]) -> Result<(), GolfError> {
    crate::eval::csv::write_curves(std::path::Path::new(path), curves)
        .map_err(|e| GolfError::io(path.to_string(), e))?;
    eprintln!("wrote {path}");
    Ok(())
}

/// Build and run a deployment session, print the report, and optionally run
/// the matched simulator comparison / write CSV output.  Shared by
/// `golf deploy` and `golf scenario --deploy`.
fn deploy_and_report(
    mut spec: RunSpec,
    compare_sim: bool,
    out: Option<&str>,
) -> Result<(), GolfError> {
    spec.target = Target::Deploy;
    let session = spec.build()?;
    let ds = session.data().expect("a deployment session owns its dataset");
    // the one config the session resolved at build time backs the banner,
    // the run, and the matched-sim comparison
    let dcfg = session
        .deploy_config()
        .expect("deploy sessions resolve their config at build time");
    eprintln!(
        "deploying {} {} nodes in {} group(s) on {} (d={}) for {} cycles of {:?} \
         [{} sampling{}{}{}]",
        dcfg.n_nodes,
        dcfg.variant.name(),
        dcfg.resolved_groups(),
        ds.name,
        ds.d(),
        dcfg.cycles,
        dcfg.delta,
        dcfg.sampler.name(),
        if dcfg.churn.is_some() { ", churn+drop/delay" } else { "" },
        dcfg.scenario
            .as_ref()
            .map_or(String::new(), |s| format!(", scenario {:?}", s.name)),
        dcfg.topology
            .as_ref()
            .map_or(String::new(), |t| format!(", graph {}", t.name())),
    );
    if compare_sim && dcfg.n_nodes != ds.n_train() {
        eprintln!(
            "warning: --compare-sim with nodes = {} but {} training rows — \
             the simulator always runs one node per row",
            dcfg.n_nodes,
            ds.n_train()
        );
    }
    let mut obs = ProgressObserver::stderr();
    let outcome = session.run(&mut obs)?;
    let report = outcome.deploy_report().expect("deploy target yields a report");
    let s = &report.stats;
    eprintln!(
        "sent={} received={} bytes={} sim_dropped={} blocked={} backlog_lost={} \
         io_errors={} decode_errors={} conns={} reused={}",
        s.messages_sent,
        s.messages_received,
        s.bytes_sent,
        s.sim_dropped,
        s.partition_blocked,
        s.backlog_lost,
        s.io_errors,
        s.decode_errors,
        s.conns_accepted,
        s.conns_reused,
    );
    eprintln!(
        "groups={} frames/wake={:.2} timer_lag_max={:.2}ms",
        s.node_groups, s.frames_per_wake, s.timer_lag_ms_max,
    );
    eprintln!(
        "final error {:.4} (mean model t {:.1})",
        report.final_error, report.mean_model_t
    );
    let mut curves = vec![report.curve.clone()];
    if compare_sim {
        let sim = crate::api::run_matched_sim(dcfg, ds, &mut NullObserver)?;
        eprintln!(
            "matched simulator final {:.4} (deploy {:.4}, gap {:+.4})",
            sim.curve.final_error(),
            report.curve.final_error(),
            report.curve.final_error() - sim.curve.final_error(),
        );
        curves.push(sim.curve);
    }
    if let Some(out) = out {
        write_csv(out, &curves)?;
    }
    Ok(())
}

/// Entry point used by main.rs; returns a process exit code — 0 on success,
/// otherwise the failing [`GolfError`]'s distinct per-variant code.
pub fn dispatch(args: &[String]) -> i32 {
    // `golf scenario <name|file>` takes one positional argument; splice it
    // into the flag map so the strict `--flag value` parser stays strict
    // for every other command
    let mut args = args.to_vec();
    if args.first().map(String::as_str) == Some("scenario")
        && args.get(1).map_or(false, |a| !a.starts_with("--"))
    {
        let name = args.remove(1);
        args.insert(1, "--name".to_string());
        args.insert(2, name);
    }
    let parsed = match parse_args(&args) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("error: {e}\n\n{}", usage());
            return e.exit_code();
        }
    };
    match run_command(&parsed) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            e.exit_code()
        }
    }
}

struct FigArgs {
    scale: f64,
    cycles: Option<u64>,
    seed: u64,
    threads: usize,
    out: std::path::PathBuf,
}

/// The figure/table commands take exactly the [`FigArgs`] flags; anything
/// else (e.g. a per-run key like `--dataset`) is rejected instead of
/// vanishing silently.
fn check_fig_flags(flags: &HashMap<String, String>) -> Result<(), GolfError> {
    for k in flags.keys() {
        match k.as_str() {
            "scale" | "cycles" | "seed" | "threads" | "out-dir" => {}
            other => {
                return Err(GolfError::config(format!(
                    "unknown flag --{other} (figure commands take \
                     --scale/--cycles/--seed/--threads/--out-dir; per-run \
                     keys belong to `golf run`)"
                )))
            }
        }
    }
    Ok(())
}

fn fig_args(flags: &HashMap<String, String>) -> Result<FigArgs, GolfError> {
    let scale: f64 = flags.get("scale").map_or(Ok(common::env_scale()), |s| {
        s.parse()
            .map_err(|_| GolfError::config(format!("bad scale {s:?}")))
    })?;
    let cycles: Option<u64> = match flags.get("cycles") {
        Some(s) => Some(
            s.parse()
                .map_err(|_| GolfError::config(format!("bad cycles {s:?}")))?,
        ),
        None => None,
    };
    let seed: u64 = flags.get("seed").map_or(Ok(42), |s| {
        s.parse()
            .map_err(|_| GolfError::config(format!("bad seed {s:?}")))
    })?;
    let threads: usize = flags.get("threads").map_or(Ok(sweep::thread_count()), |s| {
        s.parse()
            .map_err(|_| GolfError::config(format!("bad threads {s:?}")))
    })?;
    let out: std::path::PathBuf = flags
        .get("out-dir")
        .map(Into::into)
        .unwrap_or_else(common::results_dir);
    Ok(FigArgs { scale, cycles, seed, threads, out })
}

fn run_command(parsed: &ParsedArgs) -> Result<(), GolfError> {
    match parsed.command.as_str() {
        "run" => {
            let spec = run_spec_from_flags(&parsed.flags)?;
            let session = spec.build()?;
            announce(&session);
            let outcome = session.run(&mut ProgressObserver::stderr())?;
            if let Some(stats) = outcome.run_stats() {
                print_run_stats(stats);
            }
            if let Some(out) = parsed.flags.get("out") {
                let curve = outcome.curve().expect("single run has a curve");
                write_csv(out, std::slice::from_ref(curve))?;
            }
            Ok(())
        }
        "table1" => {
            check_fig_flags(&parsed.flags)?;
            let a = fig_args(&parsed.flags)?;
            let sets = experiments::datasets(a.seed, a.scale);
            let rows = experiments::table1::run_threads(&sets, a.seed, a.threads);
            experiments::table1::print(&rows);
            Ok(())
        }
        "fig1" => {
            check_fig_flags(&parsed.flags)?;
            let a = fig_args(&parsed.flags)?;
            let sets = experiments::datasets(a.seed, a.scale);
            let panels = experiments::fig1::run_figure_threads(&sets, a.cycles, a.seed, a.threads);
            experiments::fig1::to_csv(&panels, &a.out)
                .map_err(|e| GolfError::io(a.out.display().to_string(), e))?;
            eprintln!("wrote {} panels to {}", panels.len(), a.out.display());
            Ok(())
        }
        "fig2" => {
            check_fig_flags(&parsed.flags)?;
            let a = fig_args(&parsed.flags)?;
            let sets = experiments::datasets(a.seed, a.scale);
            let panels = experiments::fig2::run_figure_threads(&sets, a.cycles, a.seed, a.threads);
            experiments::fig2::to_csv(&panels, &a.out)
                .map_err(|e| GolfError::io(a.out.display().to_string(), e))?;
            eprintln!("wrote {} panels to {}", panels.len(), a.out.display());
            Ok(())
        }
        "fig3" => {
            check_fig_flags(&parsed.flags)?;
            let a = fig_args(&parsed.flags)?;
            let sets = experiments::datasets(a.seed, a.scale);
            let panels = experiments::fig3::run_figure_threads(&sets, a.cycles, a.seed, a.threads);
            experiments::fig3::to_csv(&panels, &a.out)
                .map_err(|e| GolfError::io(a.out.display().to_string(), e))?;
            eprintln!("wrote {} panels to {}", panels.len(), a.out.display());
            Ok(())
        }
        "fig-topology" => {
            check_fig_flags(&parsed.flags)?;
            let a = fig_args(&parsed.flags)?;
            let sets = experiments::datasets(a.seed, a.scale);
            let panels =
                experiments::fig_topology::run_figure_threads(&sets, a.cycles, a.seed, a.threads);
            experiments::fig_topology::to_csv(&panels, &a.out)
                .map_err(|e| GolfError::io(a.out.display().to_string(), e))?;
            eprintln!("wrote {} panels to {}", panels.len(), a.out.display());
            Ok(())
        }
        "sweep" => {
            // strict flag set: anything else (e.g. --dataset, a per-run key)
            // would otherwise vanish silently
            for k in parsed.flags.keys() {
                match k.as_str() {
                    "config" | "scale" | "cycles" | "seed" | "threads" | "out-dir"
                    | "replicates" | "mode" | "coalesce" | "exec" | "scenarios"
                    | "topologies" => {}
                    other => {
                        return Err(GolfError::config(format!(
                            "sweep: unknown flag --{other} (the grid always runs \
                             the 3-dataset registry; per-run keys belong to \
                             `golf run` or the [experiment] section of --config)"
                        )))
                    }
                }
            }
            // --config FILE may carry the full one-schema INI, including a
            // [sweep] section; explicit flags override it
            let (mut exp, mut axes) = match parsed.flags.get("config") {
                Some(path) => {
                    let mut spec = RunSpec::from_ini_file(path)?;
                    reject_bundled_sections(&spec, path, false, true)?;
                    let axes = spec.sweep.take().unwrap_or_default();
                    (spec.experiment, axes)
                }
                None => (
                    ExperimentSpec { scale: common::env_scale(), ..Default::default() },
                    SweepAxes::default(),
                ),
            };
            // experiment-schema flags route through the one parser
            let mut kv = HashMap::new();
            for key in ["scale", "cycles", "seed", "mode", "coalesce", "exec"] {
                if let Some(v) = parsed.flags.get(key) {
                    kv.insert(key.to_string(), v.clone());
                }
            }
            exp.apply(&kv)?;
            if let Some(s) = parsed.flags.get("threads") {
                axes.threads = s
                    .parse()
                    .map_err(|_| GolfError::config(format!("bad threads {s:?}")))?;
            }
            let out_dir: std::path::PathBuf = parsed
                .flags
                .get("out-dir")
                .map(Into::into)
                .unwrap_or_else(common::results_dir);
            if let Some(s) = parsed.flags.get("replicates") {
                // 0 is rejected by RunSpec::validate, same as the INI key
                axes.replicates = s
                    .parse()
                    .map_err(|_| GolfError::config(format!("bad replicates {s:?}")))?;
            }
            if let Some(list) = parsed.flags.get("scenarios") {
                // names and timelines are validated against the grid's
                // actual datasets by run_grid before any job is dispatched
                axes.scenarios = list.split(',').map(|s| s.trim().to_string()).collect();
            }
            if let Some(list) = parsed.flags.get("topologies") {
                // spec strings are parsed and their graphs built against the
                // grid's datasets by run_grid before any job is dispatched
                axes.topologies = list.split(',').map(|s| s.trim().to_string()).collect();
            }
            eprintln!(
                "sweep: 3 datasets x {} variants x {} failure modes x {} scenarios x \
                 {} topologies x {} replicates on {} threads",
                axes.variants.len(),
                axes.failures.len(),
                axes.scenarios.len(),
                axes.topologies.len(),
                axes.replicates,
                axes.threads
            );
            let session = RunSpec::from_spec(exp).sweep(axes).build()?;
            let outcome = session.run(&mut NullObserver)?;
            let cells = outcome.sweep_cells().expect("sweep target yields cells");
            let mut t = crate::util::benchkit::Table::new(&[
                "dataset", "variant", "failures", "scenario", "topology", "rep", "seed",
                "final err", "msgs",
            ]);
            for c in cells {
                t.row(&[
                    c.dataset.clone(),
                    c.variant.name().to_string(),
                    if c.failures { "extreme" } else { "none" }.to_string(),
                    c.scenario.clone(),
                    c.topology.clone(),
                    c.replicate.to_string(),
                    format!("{:#x}", c.seed),
                    format!("{:.4}", c.curve.final_error()),
                    c.stats.messages_sent.to_string(),
                ]);
            }
            t.print();
            sweep::to_csv(cells, &out_dir)
                .map_err(|e| GolfError::io(out_dir.display().to_string(), e))?;
            eprintln!("wrote {} sweep cells to {}", cells.len(), out_dir.display());
            Ok(())
        }
        "deploy" => {
            let mut flags = parsed.flags.clone();
            let compare_sim = flags.remove("compare-sim").is_some();
            let out = flags.remove("out");
            let mut spec = if let Some(path) = flags.remove("config") {
                let spec = RunSpec::from_ini_file(&path)?;
                reject_bundled_sections(&spec, &path, true, false)?;
                spec
            } else {
                RunSpec::default()
            };
            spec.target = Target::Deploy;
            apply_flags(&mut spec, &flags)?;
            deploy_and_report(spec, compare_sim, out.as_deref())
        }
        "scenario" => {
            if parsed.flags.contains_key("list") {
                let mut t = crate::util::benchkit::Table::new(&["name", "cycles", "summary"]);
                for &name in crate::scenario::builtin_names() {
                    let s = crate::scenario::builtin(name)?;
                    t.row(&[
                        name.to_string(),
                        s.cycles_hint.map_or("-".into(), |c| c.to_string()),
                        s.summary.clone(),
                    ]);
                }
                t.print();
                return Ok(());
            }
            let mut flags = parsed.flags.clone();
            let name = flags.remove("name").ok_or_else(|| {
                GolfError::config(
                    "scenario: pass a built-in name or a .scn file (or --list)".to_string(),
                )
            })?;
            let deploy = flags.remove("deploy").is_some();
            let compare_sim = flags.remove("compare-sim").is_some();
            let out = flags.remove("out");
            // a path (or anything ending in .scn) loads a scenario file —
            // which may bundle [experiment]/[deploy] sections; anything else
            // names a built-in
            let is_file = name.ends_with(".scn") || std::path::Path::new(&name).exists();
            let mut spec = if is_file {
                let spec = RunSpec::from_ini_file(&name)?;
                if spec.experiment.scenario.is_none() {
                    return Err(GolfError::config(format!("{name}: no [scenario] section")));
                }
                // a bundled [deploy] section is fine here (--deploy uses it)
                reject_bundled_sections(&spec, &name, true, false)?;
                spec
            } else {
                let scn = crate::scenario::builtin(&name)?;
                let mut spec = RunSpec::default();
                // built-ins carry a suggested run length; --cycles overrides
                if let Some(hint) = scn.cycles_hint {
                    spec.experiment.cycles = hint;
                }
                spec.experiment.scenario = Some(scn);
                spec
            };
            if !deploy {
                // deployment flags without --deploy would be silently
                // swallowed by the simulator path; probe the authoritative
                // key set so this guard can never drift from it
                let mut probe = spec.to_deploy_spec();
                for (k, v) in &flags {
                    if !matches!(probe.apply_deploy_key(k, v), Ok(false)) {
                        return Err(GolfError::config(format!(
                            "scenario: --{k} configures the socket deployment; \
                             combine it with --deploy"
                        )));
                    }
                }
            }
            apply_flags(&mut spec, &flags)?;
            let scn_name = spec.experiment.scenario.as_ref().unwrap().name.clone();
            if deploy {
                eprintln!("scenario {scn_name:?} on the socket deployment runtime");
                return deploy_and_report(spec, compare_sim, out.as_deref());
            }
            if compare_sim {
                // a simulator run has nothing to compare itself against;
                // never let the flag be silently ignored
                return Err(GolfError::config(
                    "scenario: --compare-sim compares a deployment against the \
                     matched simulator; combine it with --deploy"
                        .to_string(),
                ));
            }
            // a bundled [deploy] section without --deploy still runs the
            // simulator, exactly as before the facade
            spec.target = Target::for_backend(spec.experiment.backend);
            eprintln!("scenario {scn_name:?} [{}]", spec.experiment.backend.name());
            let session = spec.build()?;
            announce(&session);
            let outcome = session.run(&mut ProgressObserver::stderr())?;
            if let Some(stats) = outcome.run_stats() {
                print_run_stats(stats);
            }
            if let Some(out) = out {
                let curve = outcome.curve().expect("single run has a curve");
                write_csv(&out, std::slice::from_ref(curve))?;
            }
            Ok(())
        }
        "info" => {
            let dir = PjrtBackend::default_dir();
            match crate::runtime::Runtime::load(&dir) {
                Ok(rt) => {
                    println!("platform: {}", rt.platform());
                    println!("artifacts: {} ({} ops)", dir.display(), rt.manifest().ops().len());
                    for op in rt.manifest().ops() {
                        let n = rt.manifest().entries.iter().filter(|e| e.op == op).count();
                        println!("  {op}: {n} shape buckets");
                    }
                }
                Err(e) => println!("artifacts not available at {}: {e}", dir.display()),
            }
            Ok(())
        }
        "help" | "--help" | "-h" => {
            println!("{}", usage());
            Ok(())
        }
        other => Err(GolfError::config(format!(
            "unknown command {other:?}\n\n{}",
            usage()
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parse_flags_with_values_and_bools() {
        let p = parse_args(&s(&["run", "--dataset", "urls", "--voting", "--seed", "7"])).unwrap();
        assert_eq!(p.command, "run");
        assert_eq!(p.flags["dataset"], "urls");
        assert_eq!(p.flags["voting"], "true");
        assert_eq!(p.flags["seed"], "7");
    }

    #[test]
    fn rejects_positional_garbage() {
        assert!(parse_args(&s(&["run", "oops"])).is_err());
    }

    /// Satellite pin: a repeated flag is a typed config error, never a
    /// silent last-wins.
    #[test]
    fn duplicate_flag_is_config_error() {
        let e = parse_args(&s(&["run", "--cycles", "10", "--cycles", "20"])).unwrap_err();
        assert!(matches!(e, GolfError::Config(_)), "{e}");
        assert!(e.to_string().contains("duplicate flag --cycles"), "{e}");
        assert_eq!(e.exit_code(), 2);
        // repeated bare flags are duplicates too
        let e = parse_args(&s(&["run", "--voting", "--voting"])).unwrap_err();
        assert!(matches!(e, GolfError::Config(_)), "{e}");
        // ... and the whole dispatch exits with the config code
        assert_eq!(dispatch(&s(&["run", "--seed", "1", "--seed", "2"])), 2);
    }

    #[test]
    fn spec_from_flags_applies_overrides() {
        let p = parse_args(&s(&["run", "--dataset", "spambase", "--cycles", "5"])).unwrap();
        let spec = run_spec_from_flags(&p.flags).unwrap();
        assert_eq!(spec.experiment.dataset, "spambase");
        assert_eq!(spec.experiment.cycles, 5);
    }

    /// `golf run --config` uses the strict full-schema parser: a config
    /// whose sections belong to another command is redirected, never
    /// silently half-applied.
    #[test]
    fn run_config_redirects_deploy_and_sweep_sections() {
        let dir = std::env::temp_dir();
        let dpath = dir.join("golf_cli_run_deploy.ini");
        std::fs::write(&dpath, "[experiment]\ndataset = urls\n\n[deploy]\nnodes = 8\n").unwrap();
        let e = run_spec_from_flags(
            &parse_args(&s(&["run", "--config", dpath.to_str().unwrap()]))
                .unwrap()
                .flags,
        )
        .unwrap_err();
        assert!(e.to_string().contains("golf deploy"), "{e}");
        let spath = dir.join("golf_cli_run_sweep.ini");
        std::fs::write(
            &spath,
            "[experiment]\ndataset = urls\n\n[sweep]\nvariants = mu\n",
        )
        .unwrap();
        let e = run_spec_from_flags(
            &parse_args(&s(&["run", "--config", spath.to_str().unwrap()]))
                .unwrap()
                .flags,
        )
        .unwrap_err();
        assert!(e.to_string().contains("golf sweep"), "{e}");
        // a typo'd section is a hard error, not a silently ignored block
        let tpath = dir.join("golf_cli_run_typo.ini");
        std::fs::write(&tpath, "[expermient]\ndataset = urls\n").unwrap();
        assert_eq!(dispatch(&s(&["run", "--config", tpath.to_str().unwrap()])), 2);
        std::fs::remove_file(&dpath).ok();
        std::fs::remove_file(&spath).ok();
        std::fs::remove_file(&tpath).ok();
    }

    /// `golf sweep --config` consumes a one-schema INI with a [sweep]
    /// section end to end.
    #[test]
    fn tiny_sweep_from_config_file() {
        let path = std::env::temp_dir().join("golf_cli_sweep_config.ini");
        std::fs::write(
            &path,
            "[experiment]\nscale = 0.005\ncycles = 3\neval_peers = 5\n\n\
             [sweep]\nvariants = mu\nfailures = none\nthreads = 2\n",
        )
        .unwrap();
        let dir = std::env::temp_dir().join("golf_cli_sweep_config_out");
        let p = parse_args(&s(&[
            "sweep", "--config", path.to_str().unwrap(),
            "--out-dir", dir.to_str().unwrap(),
        ]))
        .unwrap();
        run_command(&p).unwrap();
        assert!(dir.join("sweep_urls_nofail.csv").exists());
        std::fs::remove_file(&path).ok();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn unknown_command_errors() {
        let p = parse_args(&s(&["frobnicate"])).unwrap();
        assert!(run_command(&p).is_err());
        assert_eq!(dispatch(&s(&["frobnicate"])), 2);
    }

    /// Satellite pin: each GolfError variant surfaces as its own exit code.
    #[test]
    fn exit_codes_map_error_variants() {
        // config error (bad flag value)
        assert_eq!(dispatch(&s(&["run", "--variant", "xx"])), 2);
        // data error (unknown dataset)
        assert_eq!(dispatch(&s(&["run", "--dataset", "nope"])), 3);
        // io error (missing config file)
        assert_eq!(dispatch(&s(&["run", "--config", "/no/such/file.ini"])), 4);
        // scenario error (unknown built-in)
        assert_eq!(dispatch(&s(&["run", "--scenario", "warp"])), 5);
    }

    #[test]
    fn tiny_run_end_to_end() {
        let p = parse_args(&s(&[
            "run", "--dataset", "urls", "--scale", "0.005", "--cycles", "5",
            "--eval_peers", "5",
        ]))
        .unwrap();
        run_command(&p).unwrap();
    }

    #[test]
    fn tiny_forced_sparse_exec_run() {
        let p = parse_args(&s(&[
            "run", "--dataset", "urls", "--scale", "0.005", "--cycles", "3",
            "--eval_peers", "4", "--exec", "sparse",
        ]))
        .unwrap();
        run_command(&p).unwrap();
        // bad value is rejected
        let p = parse_args(&s(&[
            "run", "--dataset", "urls", "--scale", "0.005", "--cycles", "3",
            "--exec", "warp",
        ]))
        .unwrap();
        assert!(run_command(&p).is_err());
    }

    #[test]
    fn tiny_scalar_mode_run() {
        let p = parse_args(&s(&[
            "run", "--dataset", "urls", "--scale", "0.005", "--cycles", "3",
            "--eval_peers", "4", "--mode", "scalar",
        ]))
        .unwrap();
        run_command(&p).unwrap();
    }

    /// Satellite pin: `golf run --variant pairwise-auc` runs end to end
    /// (alias expands to Mu + the pairwise hinge learner, AUC eval on), and
    /// the merge/reservoir keys flow through the flag map like any other
    /// experiment key.
    #[test]
    fn tiny_pairwise_auc_run() {
        assert_eq!(
            dispatch(&s(&[
                "run", "--dataset", "urls", "--scale", "0.005", "--cycles", "4",
                "--eval_peers", "4", "--variant", "pairwise-auc", "--merge",
                "quorum", "--reservoir", "4",
            ])),
            0
        );
        // a bad merge mode is a config error (exit code 2)...
        assert_eq!(
            dispatch(&s(&[
                "run", "--dataset", "urls", "--scale", "0.005", "--merge", "median",
            ])),
            2
        );
        // ...and so is a reservoir the model cache cannot hold
        assert_eq!(
            dispatch(&s(&[
                "run", "--dataset", "urls", "--scale", "0.005", "--variant",
                "pairwise-auc", "--reservoir", "0",
            ])),
            2
        );
    }

    #[test]
    fn tiny_topology_constrained_run() {
        assert_eq!(
            dispatch(&s(&[
                "run", "--dataset", "urls", "--scale", "0.005", "--cycles", "3",
                "--eval_peers", "4", "--topology", "ring:2",
            ])),
            0
        );
        // an unparseable spec is a config error (exit code 2)...
        assert_eq!(
            dispatch(&s(&[
                "run", "--dataset", "urls", "--scale", "0.005", "--topology", "warp",
            ])),
            2
        );
        // ...and so is a graph that cannot build over the node count
        assert_eq!(
            dispatch(&s(&[
                "run", "--dataset", "urls", "--scale", "0.005", "--topology",
                "kreg:100000",
            ])),
            2
        );
    }

    #[test]
    fn deployment_flag_errors_rejected() {
        // spec-level failures surface before any socket is opened (the
        // end-to-end `golf deploy` run lives in tests/deployment.rs, where
        // the socket-heavy tests are serialized)
        let p = parse_args(&s(&["deploy", "--delta_ms", "zero"])).unwrap();
        assert!(run_command(&p).is_err());
        let p = parse_args(&s(&["deploy", "--bogus_key", "1"])).unwrap();
        assert!(run_command(&p).is_err());
        // more nodes than training rows is a data error (exit code 3)
        assert_eq!(
            dispatch(&s(&[
                "deploy", "--dataset", "urls", "--scale", "0.002", "--nodes", "21",
            ])),
            3
        );
    }

    #[test]
    fn scenario_list_and_unknown_name() {
        assert_eq!(dispatch(&s(&["scenario", "--list"])), 0);
        // unknown built-in -> scenario error code
        assert_eq!(dispatch(&s(&["scenario", "no-such-scenario"])), 5);
        // no positional and no --list is a config error with guidance
        assert_eq!(dispatch(&s(&["scenario"])), 2);
        // --compare-sim only makes sense against a deployment
        assert_eq!(dispatch(&s(&["scenario", "paper-fig3", "--compare-sim"])), 2);
        // ... and so do the deployment flags (never silently swallowed)
        assert_eq!(dispatch(&s(&["scenario", "paper-fig3", "--nodes", "64"])), 2);
        assert_eq!(dispatch(&s(&["scenario", "paper-fig3", "--delta_ms", "30"])), 2);
    }

    #[test]
    fn tiny_scenario_builtin_run() {
        // positional splicing + built-in lookup + override of the hint
        assert_eq!(
            dispatch(&s(&[
                "scenario", "paper-fig3", "--dataset", "urls", "--scale", "0.005",
                "--cycles", "6", "--eval_peers", "5",
            ])),
            0
        );
        // a timeline that cannot fit the overridden horizon is a scenario
        // error (exit code 5)
        assert_eq!(
            dispatch(&s(&[
                "scenario", "partition-heal", "--scale", "0.005", "--cycles", "6",
            ])),
            5
        );
    }

    #[test]
    fn tiny_scenario_file_run() {
        let path = std::env::temp_dir().join("golf_cli_scenario_test.scn");
        std::fs::write(
            &path,
            "[experiment]\ndataset = urls\nscale = 0.005\ncycles = 8\neval_peers = 5\n\n\
             [scenario]\nname = file-blip\n\n[phase.blip]\nfrom = 2\nto = 5\ndrop = 0.9\n",
        )
        .unwrap();
        assert_eq!(dispatch(&s(&["scenario", path.to_str().unwrap()])), 0);
        // a file without a [scenario] section is a config error
        let bare = std::env::temp_dir().join("golf_cli_scenario_bare.scn");
        std::fs::write(&bare, "[experiment]\ndataset = urls\n").unwrap();
        assert_eq!(dispatch(&s(&["scenario", bare.to_str().unwrap()])), 2);
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&bare).ok();
    }

    #[test]
    fn sweep_scenarios_flag_rejects_unknown() {
        let p = parse_args(&s(&[
            "sweep", "--scale", "0.005", "--cycles", "3", "--scenarios", "warp",
        ]))
        .unwrap();
        assert!(run_command(&p).is_err());
    }

    #[test]
    fn tiny_sweep_end_to_end() {
        let dir = std::env::temp_dir().join("golf_cli_sweep_test");
        let p = parse_args(&s(&[
            "sweep", "--scale", "0.005", "--cycles", "3", "--threads", "2",
            "--out-dir", dir.to_str().unwrap(),
        ]))
        .unwrap();
        run_command(&p).unwrap();
        assert!(dir.join("sweep_urls_nofail.csv").exists());
        assert!(dir.join("sweep_urls_af.csv").exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}
