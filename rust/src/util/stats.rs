//! Small statistics helpers shared by evaluation, tests and benches.

/// Streaming mean/variance (Welford).
#[derive(Clone, Debug, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        OnlineStats { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (xs.len() - 1) as f64)
        .sqrt()
}

/// p-th percentile (0..=100) by nearest-rank on a sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// Chi-square statistic of observed counts against a uniform expectation.
pub fn chi2_uniform(counts: &[u64]) -> f64 {
    let total: u64 = counts.iter().sum();
    if total == 0 || counts.is_empty() {
        return 0.0;
    }
    let exp = total as f64 / counts.len() as f64;
    counts.iter().map(|&c| (c as f64 - exp).powi(2) / exp).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_batch() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.0];
        let mut s = OnlineStats::new();
        for &x in &xs {
            s.push(x);
        }
        assert!((s.mean() - mean(&xs)).abs() < 1e-12);
        assert!((s.std_dev() - std_dev(&xs)).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 16.0);
    }

    #[test]
    fn percentile_bounds() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
    }

    #[test]
    fn chi2_zero_for_perfectly_uniform() {
        assert_eq!(chi2_uniform(&[10, 10, 10, 10]), 0.0);
        assert!(chi2_uniform(&[40, 0, 0, 0]) > 100.0);
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[1.0]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(chi2_uniform(&[]), 0.0);
    }
}
