//! Process-wide thread budget shared by every scoped-thread spawner.
//!
//! Two layers of the crate spawn worker threads: the sweep grid
//! (`experiments::sweep::run_indexed`) fans cells out across workers, and
//! the sharded event simulator (`gossip::sharded`, DESIGN.md §13) fans one
//! run's node ranges out across shard workers.  A sharded run *inside* a
//! parallel sweep would multiply the two counts and oversubscribe the
//! machine, so both spawners draw from this single ledger instead of
//! consulting `available_parallelism` independently.
//!
//! Model: a pool of `budget()` compute tokens (set by `--threads`, the
//! `GOLF_THREADS` environment variable, or the machine's available
//! parallelism).  Every thread that is about to run compute holds one
//! token.  A caller is always entitled to compute on its own thread without
//! holding a token — [`lease`] therefore hands out *additional* worker
//! tokens, clamped to what is left, and a grant of zero extra workers
//! degrades the caller to serial execution rather than failing.
//!
//! Composition rule (documented in README/DESIGN): a sweep over `T` workers
//! whose cells each run `S` shards never exceeds `budget()` live compute
//! threads — the sweep leases `T` tokens up front, and each cell's sharded
//! executor leases `S - 1` *extra* tokens (its own sweep-worker thread
//! drives the first shard), falling back toward single-threaded shard
//! multiplexing as the pool drains.  Results are identical either way:
//! shard execution is deterministic by construction, so the grant size
//! affects wall-clock only.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Explicit `--threads` override; 0 = unset (resolve from env/machine).
static OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Extra worker tokens currently leased out process-wide.
static LEASED: AtomicUsize = AtomicUsize::new(0);

/// Set the process-wide thread budget (the CLI's `--threads` flag).  A
/// value of 0 clears the override back to auto-detection.
pub fn set_budget(n: usize) {
    OVERRIDE.store(n, Ordering::SeqCst);
}

/// The total compute-thread budget: the `--threads` override if set, else
/// `GOLF_THREADS`, else the machine's available parallelism; at least 1.
pub fn budget() -> usize {
    let o = OVERRIDE.load(Ordering::SeqCst);
    if o > 0 {
        return o;
    }
    std::env::var("GOLF_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        })
        .max(1)
}

/// A granted allocation of extra worker threads.  Returns its tokens to the
/// pool on drop — hold it for exactly as long as the workers are alive.
#[derive(Debug)]
pub struct Lease {
    granted: usize,
}

impl Lease {
    /// How many *extra* worker threads the ledger granted (possibly 0 —
    /// run serial on the calling thread then).
    pub fn granted(&self) -> usize {
        self.granted
    }
}

impl Drop for Lease {
    fn drop(&mut self) {
        if self.granted > 0 {
            LEASED.fetch_sub(self.granted, Ordering::SeqCst);
        }
    }
}

/// Lease up to `want` extra worker tokens from the global pool.  The grant
/// is `min(want, budget() - 1 - already_leased)` (never negative): the
/// `- 1` reserves the caller's own thread, which is always entitled to
/// compute without a token.
pub fn lease(want: usize) -> Lease {
    lease_from(&LEASED, budget(), want)
}

/// Ledger math, factored for deterministic testing: grab up to `want`
/// tokens from `pool` given a total budget of `cap`.
fn lease_from(pool: &AtomicUsize, cap: usize, want: usize) -> Lease {
    if want == 0 {
        return Lease { granted: 0 };
    }
    let spawnable = cap.saturating_sub(1);
    loop {
        let used = pool.load(Ordering::SeqCst);
        let avail = spawnable.saturating_sub(used);
        let grant = want.min(avail);
        if grant == 0 {
            return Lease { granted: 0 };
        }
        if pool
            .compare_exchange(used, used + grant, Ordering::SeqCst, Ordering::SeqCst)
            .is_ok()
        {
            return Lease { granted: grant };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ledger_clamps_and_returns() {
        let pool = AtomicUsize::new(0);
        // budget 4 => 3 spawnable extras beyond the caller
        let a = lease_from(&pool, 4, 2);
        assert_eq!(a.granted(), 2);
        let b = lease_from(&pool, 4, 2);
        assert_eq!(b.granted(), 1, "only one token left");
        let c = lease_from(&pool, 4, 5);
        assert_eq!(c.granted(), 0, "pool exhausted degrades to serial");
        drop(a);
        assert_eq!(pool.load(Ordering::SeqCst), 1);
        let d = lease_from(&pool, 4, 8);
        assert_eq!(d.granted(), 2, "returned tokens are reusable");
        drop(b);
        drop(c);
        drop(d);
        assert_eq!(pool.load(Ordering::SeqCst), 0, "all tokens returned");
    }

    #[test]
    fn sweep_times_shards_respects_budget() {
        // the composition rule as pure ledger math: a sweep leasing T
        // workers, each cell then leasing S-1 shard extras, never exceeds
        // the cap in total live compute threads (caller + extras).
        let pool = AtomicUsize::new(0);
        let cap = 8;
        let sweep = lease_from(&pool, cap, 4); // 4 sweep workers
        assert_eq!(sweep.granted(), 4);
        let mut shard_leases = Vec::new();
        for _ in 0..4 {
            // each sweep worker asks for 4-way sharding (3 extras)
            shard_leases.push(lease_from(&pool, cap, 3));
        }
        let extras: usize =
            sweep.granted() + shard_leases.iter().map(|l| l.granted()).sum::<usize>();
        assert!(1 + extras <= cap, "live threads {} exceed cap {cap}", 1 + extras);
        // and the pool really drained: later cells got fewer extras
        assert!(shard_leases.iter().any(|l| l.granted() < 3));
    }

    #[test]
    fn zero_want_is_free() {
        let pool = AtomicUsize::new(0);
        let l = lease_from(&pool, 1, 0);
        assert_eq!(l.granted(), 0);
        // budget 1 => nothing spawnable, ever
        let l2 = lease_from(&pool, 1, 9);
        assert_eq!(l2.granted(), 0);
    }

    #[test]
    fn budget_is_at_least_one() {
        assert!(budget() >= 1);
    }
}
