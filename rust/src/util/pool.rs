//! Message-buffer pool (DESIGN.md §14): recycles the `Vec<f32>` weight
//! buffers that gossip messages carry, so the sharded simulator's hot path
//! (one model message per node per Δ — ~1M fresh allocations per cycle at
//! the ROADMAP's node target) reuses buffers instead of hammering the
//! allocator.
//!
//! Contract: [`BufPool::get`] always returns a buffer of **exactly** the
//! requested length — a recycled buffer is resized before it is handed out
//! — but its *contents* are stale garbage from the previous message.  Every
//! fill path must overwrite every element (`ModelStore::write_freshest_raw`
//! does this with a `copy_from_slice` of the whole row); parity of pooled
//! vs pooling-disabled runs is pinned bit-for-bit in tests/engine_parity.rs.
//!
//! The free list is unbounded: its high-water mark equals the peak number
//! of in-flight messages, which is exactly the memory a non-pooling run
//! allocates at that same moment anyway — pooling only removes the
//! free/realloc churn in between.

/// A free-list pool of `Vec<f32>` weight buffers with hit/miss accounting.
#[derive(Debug)]
pub struct BufPool {
    free: Vec<Vec<f32>>,
    enabled: bool,
    /// buffers served from the free list
    pub hits: u64,
    /// buffers served by a fresh allocation
    pub misses: u64,
}

impl BufPool {
    /// `enabled = false` makes `get` always allocate and `put` always drop
    /// — the reference behavior the pooled path is pinned against.
    pub fn new(enabled: bool) -> Self {
        BufPool { free: Vec::new(), enabled, hits: 0, misses: 0 }
    }

    /// A buffer of exactly `d` elements.  Recycled buffers are resized to
    /// `d` (longer ones truncated, shorter ones zero-extended) so callers
    /// can never index past a stale shorter length; contents beyond that
    /// guarantee are unspecified and must be fully overwritten.
    pub fn get(&mut self, d: usize) -> Vec<f32> {
        match self.free.pop() {
            Some(mut buf) => {
                buf.resize(d, 0.0);
                self.hits += 1;
                buf
            }
            None => {
                self.misses += 1;
                vec![0.0; d]
            }
        }
    }

    /// Return a consumed buffer to the free list (dropped when pooling is
    /// disabled, preserving the no-pool allocation profile).
    pub fn put(&mut self, buf: Vec<f32>) {
        if self.enabled && buf.capacity() > 0 {
            self.free.push(buf);
        }
    }

    /// Buffers currently parked on the free list.
    pub fn free_len(&self) -> usize {
        self.free.len()
    }

    /// Fraction of `get` calls served from the free list.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn get_put_recycles_and_counts() {
        let mut p = BufPool::new(true);
        let a = p.get(4);
        assert_eq!(a.len(), 4);
        assert_eq!((p.hits, p.misses), (0, 1));
        p.put(a);
        assert_eq!(p.free_len(), 1);
        let b = p.get(4);
        assert_eq!(b.len(), 4);
        assert_eq!((p.hits, p.misses), (1, 1));
        assert!(p.hit_rate() > 0.49 && p.hit_rate() < 0.51);
    }

    /// The stale-buffer hazard (regression in the spirit of PR 1's
    /// `StepBatch::resize` zeroing bug): a recycled buffer must come back
    /// at the requested length even when the previous message was a
    /// different dimensionality, and the zero-extension must not expose
    /// stale floats past the old length.
    #[test]
    fn get_guarantees_requested_length_across_dims() {
        let mut p = BufPool::new(true);
        let mut a = p.get(8);
        for v in a.iter_mut() {
            *v = 7.5; // poison: stale contents from a "previous message"
        }
        p.put(a);
        let shorter = p.get(3);
        assert_eq!(shorter.len(), 3, "recycled buffer must be resized down");
        p.put(shorter);
        let longer = p.get(6);
        assert_eq!(longer.len(), 6, "recycled buffer must be resized up");
        // elements past the old length are zero-extended, never stale
        assert!(longer[3..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn disabled_pool_never_recycles() {
        let mut p = BufPool::new(false);
        let a = p.get(5);
        p.put(a);
        assert_eq!(p.free_len(), 0);
        let _ = p.get(5);
        assert_eq!((p.hits, p.misses), (0, 2));
        assert_eq!(p.hit_rate(), 0.0);
    }

    #[test]
    fn zero_capacity_buffers_are_not_parked() {
        let mut p = BufPool::new(true);
        p.put(Vec::new());
        assert_eq!(p.free_len(), 0);
    }
}
