//! Packed liveness bitset (DESIGN.md §14): a `Vec<bool>` replacement for
//! the full-universe online/churn/forced-off replicas every shard keeps.
//!
//! At the 1M-node target each `Vec<bool>` replica costs 1 MB per shard;
//! packed into u64 words the same replica is 125 KB — 8× smaller — and
//! word-level scans (`iter_ones`, `count_ones`) replace per-element loops
//! in the peer-sampler filtering paths.
//!
//! Semantics are exactly those of a fixed-length `Vec<bool>`: `test`/`set`/
//! `clear`/`assign` address single bits, `fill` repaints the whole set,
//! `grow` appends `false` bits (membership growth), and `iter_ones` yields
//! set indices in increasing order — the property `MatchingState::refresh`
//! relies on to reproduce its historical live-node ordering bit-for-bit.

/// Fixed-length packed bitset over u64 words.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Bitset {
    words: Vec<u64>,
    len: usize,
}

impl Bitset {
    /// All-false bitset of `len` bits.
    pub fn new(len: usize) -> Self {
        Bitset { words: vec![0; len.div_ceil(64)], len }
    }

    /// Bitset of `len` bits, every bit set to `value`.
    pub fn filled(len: usize, value: bool) -> Self {
        let mut b = Bitset::new(len);
        if value {
            b.fill(true);
        }
        b
    }

    /// Build from a predicate over bit indices (the `Vec<bool>` collect
    /// idiom: `(0..n).map(f).collect()`).
    pub fn from_fn<F: FnMut(usize) -> bool>(len: usize, mut f: F) -> Self {
        let mut b = Bitset::new(len);
        for i in 0..len {
            if f(i) {
                b.set(i);
            }
        }
        b
    }

    /// Number of bits.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Read bit `i` (panics when out of range, like `Vec<bool>` indexing).
    #[inline]
    pub fn test(&self, i: usize) -> bool {
        assert!(i < self.len, "bit {i} out of range (len {})", self.len);
        self.words[i / 64] >> (i % 64) & 1 != 0
    }

    /// Set bit `i` to true.
    #[inline]
    pub fn set(&mut self, i: usize) {
        assert!(i < self.len, "bit {i} out of range (len {})", self.len);
        self.words[i / 64] |= 1u64 << (i % 64);
    }

    /// Set bit `i` to false.
    #[inline]
    pub fn clear(&mut self, i: usize) {
        assert!(i < self.len, "bit {i} out of range (len {})", self.len);
        self.words[i / 64] &= !(1u64 << (i % 64));
    }

    /// Set bit `i` to `value` (the `v[i] = value` idiom).
    #[inline]
    pub fn assign(&mut self, i: usize, value: bool) {
        if value {
            self.set(i);
        } else {
            self.clear(i);
        }
    }

    /// Repaint every bit to `value`.
    pub fn fill(&mut self, value: bool) {
        let w = if value { u64::MAX } else { 0 };
        for word in &mut self.words {
            *word = w;
        }
        self.mask_tail();
    }

    /// Append `extra` false bits (membership growth under a `Grow`
    /// mutation — new arrivals start offline until their join tick).
    pub fn grow(&mut self, extra: usize) {
        self.len += extra;
        self.words.resize(self.len.div_ceil(64), 0);
        // the old tail word's spare bits were already zero, so nothing to
        // clear: growth exposes zeros
    }

    /// Number of set bits (word-level popcount scan).
    pub fn count_ones(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Indices of set bits, in increasing order, via a word scan: each
    /// word's set bits pop in `trailing_zeros` order, so the whole
    /// iteration is exactly the order a `Vec<bool>` filter would produce.
    pub fn iter_ones(&self) -> IterOnes<'_> {
        IterOnes { words: &self.words, word_idx: 0, current: self.words.first().copied().unwrap_or(0) }
    }

    /// Zero the spare bits past `len` in the last word so popcounts and
    /// word iteration never see phantom bits.
    fn mask_tail(&mut self) {
        let spare = self.words.len() * 64 - self.len;
        if spare > 0 {
            if let Some(last) = self.words.last_mut() {
                *last &= u64::MAX >> spare;
            }
        }
    }
}

/// Iterator over set-bit indices in increasing order.
pub struct IterOnes<'a> {
    words: &'a [u64],
    word_idx: usize,
    current: u64,
}

impl Iterator for IterOnes<'_> {
    type Item = usize;

    fn next(&mut self) -> Option<usize> {
        while self.current == 0 {
            self.word_idx += 1;
            if self.word_idx >= self.words.len() {
                return None;
            }
            self.current = self.words[self.word_idx];
        }
        let bit = self.current.trailing_zeros() as usize;
        self.current &= self.current - 1; // clear lowest set bit
        Some(self.word_idx * 64 + bit)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_clear_test_roundtrip() {
        let mut b = Bitset::new(130);
        assert_eq!(b.len(), 130);
        for i in [0usize, 1, 63, 64, 65, 127, 128, 129] {
            assert!(!b.test(i));
            b.set(i);
            assert!(b.test(i));
        }
        assert_eq!(b.count_ones(), 8);
        b.clear(64);
        assert!(!b.test(64));
        b.assign(64, true);
        assert!(b.test(64));
        b.assign(64, false);
        assert!(!b.test(64));
    }

    #[test]
    fn filled_and_fill_mask_the_tail() {
        let mut b = Bitset::filled(70, true);
        assert_eq!(b.count_ones(), 70);
        assert!(b.test(69));
        b.fill(false);
        assert_eq!(b.count_ones(), 0);
        b.fill(true);
        assert_eq!(b.count_ones(), 70);
        assert_eq!(b.iter_ones().count(), 70);
        assert_eq!(b.iter_ones().last(), Some(69));
    }

    #[test]
    fn grow_appends_false_bits() {
        let mut b = Bitset::filled(64, true);
        b.grow(10);
        assert_eq!(b.len(), 74);
        assert_eq!(b.count_ones(), 64);
        for i in 64..74 {
            assert!(!b.test(i));
        }
        b.set(73);
        assert!(b.test(73));
    }

    #[test]
    fn iter_ones_is_increasing_and_complete() {
        let idx = [3usize, 5, 63, 64, 100, 191, 192];
        let mut b = Bitset::new(193);
        for &i in &idx {
            b.set(i);
        }
        let got: Vec<usize> = b.iter_ones().collect();
        assert_eq!(got, idx);
    }

    #[test]
    fn from_fn_matches_predicate() {
        let b = Bitset::from_fn(129, |i| i % 3 == 0);
        for i in 0..129 {
            assert_eq!(b.test(i), i % 3 == 0, "bit {i}");
        }
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn test_out_of_range_panics() {
        Bitset::new(64).test(64);
    }
}
