//! Cross-cutting substrates: RNG, statistics, property-test runner,
//! bench harness (all built in-repo; the offline crate set has no
//! rand/proptest/criterion).
pub mod benchkit;
pub mod bitset;
pub mod check;
pub mod pool;
pub mod rng;
pub mod stats;
pub mod threads;
