//! Minimal benchmarking harness (the offline crate set has no `criterion`).
//!
//! Used by the `cargo bench` targets (`harness = false`): warmup + timed
//! iterations with mean/stddev/min reporting, plus simple fixed-width table
//! printing for the paper-reproduction benches.

use crate::util::stats::OnlineStats;
use std::time::Instant;

pub struct BenchResult {
    pub label: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.mean_ns * 1e-9)
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Time `f` for `iters` iterations after `warmup` untimed ones.
pub fn bench<F: FnMut()>(label: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut s = OnlineStats::new();
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        s.push(t0.elapsed().as_nanos() as f64);
    }
    let r = BenchResult {
        label: label.to_string(),
        iters,
        mean_ns: s.mean(),
        std_ns: s.std_dev(),
        min_ns: s.min(),
    };
    println!(
        "bench  {:<44} {:>12}/iter  (±{}, min {}, n={})",
        r.label,
        fmt_ns(r.mean_ns),
        fmt_ns(r.std_ns),
        fmt_ns(r.min_ns),
        iters
    );
    r
}

/// Fixed-width table printer for paper-figure reproductions.
pub struct Table {
    headers: Vec<String>,
    widths: Vec<usize>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            widths: headers.iter().map(|h| h.len()).collect(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        for (w, c) in self.widths.iter_mut().zip(cells) {
            *w = (*w).max(c.len());
        }
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let line = |cells: &[String], widths: &[usize]| {
            let s: Vec<String> = cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect();
            println!("  {}", s.join("  "));
        };
        line(&self.headers, &self.widths);
        let total: usize = self.widths.iter().sum::<usize>() + 2 * self.widths.len();
        println!("  {}", "-".repeat(total));
        for r in &self.rows {
            line(r, &self.widths);
        }
    }
}

/// `fX.Y`-style float cell helper.
pub fn f(x: f64, prec: usize) -> String {
    format!("{x:.prec$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("noop-ish", 2, 10, || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(r.mean_ns >= 0.0);
        assert_eq!(r.iters, 10);
    }

    #[test]
    fn table_accepts_rows() {
        let mut t = Table::new(&["a", "bb"]);
        t.row(&["1".into(), "2".into()]);
        t.print(); // must not panic
        assert_eq!(t.rows.len(), 1);
    }

    #[test]
    fn fmt_scales() {
        assert!(fmt_ns(10.0).contains("ns"));
        assert!(fmt_ns(10_000.0).contains("µs"));
        assert!(fmt_ns(10_000_000.0).contains("ms"));
        assert!(fmt_ns(2e9).contains(" s"));
    }
}
