//! Deterministic PRNG for the simulator: xoshiro256++ seeded via splitmix64.
//!
//! The offline crate set has no `rand`; this is the project's single source
//! of randomness.  Every simulation object takes a `u64` seed so whole runs
//! (including failures and churn) replay bit-identically.

/// xoshiro256++ (Blackman & Vigna). Period 2^256 - 1.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal deviate from Box-Muller
    spare_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Deterministic per-run seed derivation for experiment sweeps: FNV-1a over
/// a textual job tag (e.g. `"urls/mu/failures/r3"`), mixed with the base seed
/// through splitmix64.  Stable across platforms, thread counts and job
/// execution order, so parallel and serial sweeps see identical streams.
pub fn derive_seed(base: u64, tag: &str) -> u64 {
    let mut h = 0xCBF2_9CE4_8422_2325u64; // FNV offset basis
    for b in tag.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3); // FNV prime
    }
    let mut s = base ^ h;
    let _ = splitmix64(&mut s);
    splitmix64(&mut s)
}

/// Order-independent per-entity stream derivation: an [`Rng`] that depends
/// only on `(base, domain, idx)` — not on how many other streams were
/// derived first, nor on which thread derives it.  This is the allocation-
/// free indexed analogue of [`derive_seed`] (no `format!("{domain}/{idx}")`
/// on the hot path): the domain hashes once through FNV-1a, the index mixes
/// in through two splitmix64 rounds.
///
/// The sharded simulator (DESIGN.md §13) gives every node its own stream
/// `derive_stream(seed, "node", id)`, so a node's draw sequence — gossip-
/// period jitter, SELECTPEER, drop/delay fate — is a pure function of the
/// run seed and the node id, identical under any shard count.
pub fn derive_stream(base: u64, domain: &str, idx: u64) -> Rng {
    let mut h = 0xCBF2_9CE4_8422_2325u64;
    for b in domain.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    let mut s = (base ^ h).wrapping_add(idx.wrapping_mul(0x9E37_79B9_7F4A_7C15));
    let _ = splitmix64(&mut s);
    Rng::new(splitmix64(&mut s))
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent child stream (for per-node RNGs).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0xA5A5_5A5A_DEAD_BEEF)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n). n must be > 0.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire's multiply-shift with rejection for unbiased results.
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    #[inline]
    pub fn below_usize(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in [lo, hi).
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo)
    }

    /// Bernoulli(p).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box-Muller (with spare caching).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.next_f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.next_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let th = 2.0 * std::f64::consts::PI * u2;
            self.spare_normal = Some(r * th.sin());
            return r * th.cos();
        }
    }

    pub fn normal_scaled(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.normal()
    }

    /// Lognormal with the given parameters of the underlying normal.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal_scaled(mu, sigma).exp()
    }

    /// Exponential with the given rate.
    pub fn exponential(&mut self, rate: f64) -> f64 {
        let u = 1.0 - self.next_f64(); // avoid ln(0)
        -u.ln() / rate
    }

    /// Label in {-1, +1}.
    pub fn sign(&mut self) -> f32 {
        if self.chance(0.5) {
            1.0
        } else {
            -1.0
        }
    }

    /// Fisher-Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Uniformly pick an element.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below_usize(xs.len())]
    }

    /// k distinct indices from [0, n) (partial Fisher-Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below_usize(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Random permutation of [0, n).
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_replay() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        // chi-square over 10 buckets, 100k draws; crit value for df=9 at
        // p=0.001 is 27.88 — use 35 for slack.
        let mut r = Rng::new(123);
        let mut counts = [0u32; 10];
        let n = 100_000;
        for _ in 0..n {
            counts[r.below(10) as usize] += 1;
        }
        let exp = n as f64 / 10.0;
        let chi2: f64 =
            counts.iter().map(|&c| (c as f64 - exp).powi(2) / exp).sum();
        assert!(chi2 < 35.0, "chi2 = {chi2}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(99);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn lognormal_positive_and_skewed() {
        let mut r = Rng::new(5);
        let xs: Vec<f64> = (0..10_000).map(|_| r.lognormal(0.0, 1.0)).collect();
        assert!(xs.iter().all(|&x| x > 0.0));
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        // E[lognormal(0,1)] = exp(0.5) ≈ 1.6487
        assert!((mean - 1.6487).abs() < 0.15, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(8);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut d = s.clone();
        d.sort();
        d.dedup();
        assert_eq!(d.len(), 20);
    }

    #[test]
    fn derive_seed_stable_and_tag_sensitive() {
        let a = derive_seed(42, "urls/mu/true/r0");
        let b = derive_seed(42, "urls/mu/true/r0");
        assert_eq!(a, b, "must be a pure function");
        assert_ne!(a, derive_seed(42, "urls/mu/true/r1"));
        assert_ne!(a, derive_seed(42, "urls/rw/true/r0"));
        assert_ne!(a, derive_seed(43, "urls/mu/true/r0"));
    }

    #[test]
    fn derive_stream_pure_and_index_sensitive() {
        // pure function of (base, domain, idx): derivation order and thread
        // placement cannot matter because there is no shared state at all
        let mut a = derive_stream(42, "node", 7);
        let mut b = derive_stream(42, "node", 7);
        for _ in 0..64 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = derive_stream(42, "node", 8);
        let mut d = derive_stream(42, "newscast", 7);
        let mut e = derive_stream(43, "node", 7);
        let mut a = derive_stream(42, "node", 7);
        let first = a.next_u64();
        assert_ne!(first, c.next_u64());
        assert_ne!(first, d.next_u64());
        assert_ne!(first, e.next_u64());
    }

    #[test]
    fn derive_stream_neighbor_indices_uncorrelated() {
        // adjacent node ids must not produce visibly related streams
        let mut a = derive_stream(1, "node", 0);
        let mut b = derive_stream(1, "node", 1);
        let same = (0..256).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng::new(77);
        let mut a = base.fork();
        let mut b = base.fork();
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
