//! Minimal property-based testing runner (the offline crate set has no
//! `proptest`/`quickcheck`).
//!
//! `forall` draws `n` cases from a generator and checks a property; on
//! failure it re-runs a simple shrink loop (halving numeric fields is the
//! generator's job via `Shrink`) and panics with the seed + counterexample so
//! the failure replays deterministically.

use crate::util::rng::Rng;
use std::fmt::Debug;

/// Run `prop` on `n` generated cases. Panics on the first failure with the
/// case index, seed and a Debug dump of the counterexample.
pub fn forall<T, G, P>(seed: u64, n: usize, gen: G, prop: P)
where
    T: Debug,
    G: Fn(&mut Rng) -> T,
    P: Fn(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    for i in 0..n {
        let case = gen(&mut rng);
        if let Err(msg) = prop(&case) {
            panic!(
                "property failed at case {i}/{n} (seed {seed}):\n  {msg}\n  counterexample: {case:#?}"
            );
        }
    }
}

/// Assert two f32 slices are elementwise close.
pub fn close_f32(a: &[f32], b: &[f32], rtol: f32, atol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch: {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        if (x - y).abs() > tol || x.is_nan() != y.is_nan() {
            return Err(format!("elem {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

/// Assert a scalar is close.
pub fn close1(x: f64, y: f64, tol: f64) -> Result<(), String> {
    if (x - y).abs() > tol {
        Err(format!("{x} vs {y} (tol {tol})"))
    } else {
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial() {
        forall(1, 100, |r| r.below(100), |&x| {
            if x < 100 {
                Ok(())
            } else {
                Err("out of range".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_reports_failure() {
        forall(2, 100, |r| r.below(10), |&x| {
            if x < 5 {
                Ok(())
            } else {
                Err(format!("{x} >= 5"))
            }
        });
    }

    #[test]
    fn close_f32_tolerances() {
        assert!(close_f32(&[1.0], &[1.0 + 1e-7], 1e-5, 0.0).is_ok());
        assert!(close_f32(&[1.0], &[1.1], 1e-5, 0.0).is_err());
        assert!(close_f32(&[1.0], &[1.0, 2.0], 1e-5, 0.0).is_err());
    }
}
