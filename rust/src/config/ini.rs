//! Tiny INI parser: `[section]` headers, `key = value` pairs, `;`/`#`
//! comments, blank lines.  Values keep internal whitespace.

use std::collections::HashMap;

pub type Section = HashMap<String, String>;
pub type Document = HashMap<String, Section>;

pub fn parse(text: &str) -> Result<Document, String> {
    let mut doc: Document = HashMap::new();
    let mut current = String::from("");
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[') {
            let name = name
                .strip_suffix(']')
                .ok_or(format!("line {}: unterminated section", lineno + 1))?
                .trim();
            if name.is_empty() {
                return Err(format!("line {}: empty section name", lineno + 1));
            }
            current = name.to_string();
            doc.entry(current.clone()).or_default();
        } else if let Some((k, v)) = line.split_once('=') {
            let (k, v) = (k.trim(), v.trim());
            if k.is_empty() {
                return Err(format!("line {}: empty key", lineno + 1));
            }
            doc.entry(current.clone())
                .or_default()
                .insert(k.to_string(), v.to_string());
        } else {
            return Err(format!("line {}: expected `key = value`", lineno + 1));
        }
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    let cut = line
        .find(';')
        .into_iter()
        .chain(line.find('#'))
        .min()
        .unwrap_or(line.len());
    &line[..cut]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_pairs() {
        let d = parse("[a]\nx = 1\ny = hello world\n[b]\nz=2 # trailing").unwrap();
        assert_eq!(d["a"]["x"], "1");
        assert_eq!(d["a"]["y"], "hello world");
        assert_eq!(d["b"]["z"], "2");
    }

    #[test]
    fn top_level_keys_in_anonymous_section() {
        let d = parse("k = v").unwrap();
        assert_eq!(d[""]["k"], "v");
    }

    #[test]
    fn comments_and_blank_lines() {
        let d = parse("; full comment\n\n# another\n[s]\nk = v ; tail").unwrap();
        assert_eq!(d["s"]["k"], "v");
    }

    #[test]
    fn errors_are_reported_with_lines() {
        assert!(parse("[unterminated").unwrap_err().contains("line 1"));
        assert!(parse("[s]\nnonsense").unwrap_err().contains("line 2"));
        assert!(parse("= v").unwrap_err().contains("empty key"));
        assert!(parse("[]").unwrap_err().contains("empty section"));
    }
}
