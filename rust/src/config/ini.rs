//! Tiny INI parser: `[section]` headers, `key = value` pairs, `;`/`#`
//! comments, blank lines.  Values keep internal whitespace.

use crate::api::GolfError;
use std::collections::HashMap;

pub type Section = HashMap<String, String>;
pub type Document = HashMap<String, Section>;

pub fn parse(text: &str) -> Result<Document, GolfError> {
    let bad = |lineno: usize, what: &str| {
        GolfError::config(format!("line {}: {what}", lineno + 1))
    };
    let mut doc: Document = HashMap::new();
    let mut current = String::from("");
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[') {
            let name = name
                .strip_suffix(']')
                .ok_or_else(|| bad(lineno, "unterminated section"))?
                .trim();
            if name.is_empty() {
                return Err(bad(lineno, "empty section name"));
            }
            current = name.to_string();
            doc.entry(current.clone()).or_default();
        } else if let Some((k, v)) = line.split_once('=') {
            let (k, v) = (k.trim(), v.trim());
            if k.is_empty() {
                return Err(bad(lineno, "empty key"));
            }
            let prev = doc
                .entry(current.clone())
                .or_default()
                .insert(k.to_string(), v.to_string());
            if prev.is_some() {
                // same contract as repeated CLI flags: never silently
                // last-wins
                return Err(bad(lineno, &format!("duplicate key {k:?}")));
            }
        } else {
            return Err(bad(lineno, "expected `key = value`"));
        }
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    let cut = line
        .find(';')
        .into_iter()
        .chain(line.find('#'))
        .min()
        .unwrap_or(line.len());
    &line[..cut]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_pairs() {
        let d = parse("[a]\nx = 1\ny = hello world\n[b]\nz=2 # trailing").unwrap();
        assert_eq!(d["a"]["x"], "1");
        assert_eq!(d["a"]["y"], "hello world");
        assert_eq!(d["b"]["z"], "2");
    }

    #[test]
    fn top_level_keys_in_anonymous_section() {
        let d = parse("k = v").unwrap();
        assert_eq!(d[""]["k"], "v");
    }

    #[test]
    fn comments_and_blank_lines() {
        let d = parse("; full comment\n\n# another\n[s]\nk = v ; tail").unwrap();
        assert_eq!(d["s"]["k"], "v");
    }

    #[test]
    fn errors_are_reported_with_lines() {
        let msg = |t: &str| parse(t).unwrap_err().to_string();
        assert!(msg("[unterminated").contains("line 1"));
        assert!(msg("[s]\nnonsense").contains("line 2"));
        assert!(msg("= v").contains("empty key"));
        assert!(msg("[]").contains("empty section"));
        // every parse failure is a typed Config error (exit code 2)
        assert_eq!(parse("[oops").unwrap_err().exit_code(), 2);
    }

    #[test]
    fn duplicate_keys_are_errors_not_last_wins() {
        let e = parse("[s]\ncycles = 10\ncycles = 20").unwrap_err().to_string();
        assert!(e.contains("duplicate key"), "{e}");
        assert!(e.contains("line 3"), "{e}");
        // the same key in different sections is fine
        parse("[a]\nk = 1\n[b]\nk = 2").unwrap();
        // ... but a re-opened section with a repeated key is caught
        assert!(parse("[a]\nk = 1\n[b]\nx = 1\n[a]\nk = 2").is_err());
    }
}
