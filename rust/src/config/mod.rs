//! Experiment configuration: a small INI-style format (the offline crate set
//! has no serde/toml) plus the mapping onto `ProtocolConfig` and datasets.
//!
//! ```ini
//! [experiment]
//! dataset = urls          ; reuters | spambase | urls
//! scale = 0.1             ; dataset size multiplier
//! cycles = 200
//! variant = mu            ; rw | mu | um | pairwise-auc (alias: mu + the
//!                         ; pairwise ranking learner, DESIGN.md §17)
//! learner = pegasos       ; pegasos | adaline | logreg | pairwise-auc
//! lambda = 0.01
//! merge = average         ; average | quorum (MU/UM model combination)
//! reservoir = 8           ; example-reservoir capacity K (pairwise only)
//! cache = 10
//! sampler = newscast      ; newscast | oracle | matching
//! view = 20
//! failures = none         ; none | extreme
//! seed = 42
//! eval_peers = 100
//! voting = false
//! similarity = false
//! backend = event         ; event | event-pjrt | batched-native | batched-pjrt
//! mode = microbatch       ; microbatch | scalar (event-driven stepping)
//! coalesce = 0            ; micro-batch coalescing window in ticks
//! exec = auto             ; auto | dense | sparse (kernel family dispatch)
//! shards = 1              ; node-range shards of the event-driven simulator
//! topology = complete     ; complete | ring:K | grid | kreg:K | ba:M |
//!                         ; graph:FILE | graph-inline:A-B,... (DESIGN.md §16)
//! scenario = paper-fig3   ; named built-in scenario (see `golf scenario --list`)
//!
//! [deploy]                ; `golf deploy` only (real localhost-TCP run)
//! delta_ms = 30           ; wall-clock gossip period in milliseconds
//! nodes = 0               ; node count; 0 = one node per training row
//! node_groups = 0         ; worker threads multiplexing the nodes; 0 = auto
//! ```
//!
//! A `[scenario]` section (plus `[phase.*]` / `[event.*]` sections — the
//! standalone `.scn` format, `crate::scenario`) may be embedded in the same
//! file; it overrides any `scenario =` built-in reference.

use crate::api::GolfError;
use crate::data::dataset::Dataset;
use crate::data::synthetic::{reuters_like, spambase_like, urls_like, Scale};
use crate::gossip::create_model::Variant;
use crate::gossip::protocol::{ExecMode, ExecPath, ProtocolConfig};
use crate::learning::{Learner, MergeMode};
use crate::p2p::overlay::SamplerConfig;
use crate::scenario::Scenario;
use std::collections::HashMap;

pub mod ini;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendChoice {
    Event,
    /// event-driven semantics, micro-batches executed through PJRT
    EventPjrt,
    BatchedNative,
    BatchedPjrt,
}

impl BackendChoice {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "event" => Some(BackendChoice::Event),
            "event-pjrt" => Some(BackendChoice::EventPjrt),
            "batched-native" => Some(BackendChoice::BatchedNative),
            "batched-pjrt" => Some(BackendChoice::BatchedPjrt),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackendChoice::Event => "event",
            BackendChoice::EventPjrt => "event-pjrt",
            BackendChoice::BatchedNative => "batched-native",
            BackendChoice::BatchedPjrt => "batched-pjrt",
        }
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct ExperimentSpec {
    pub dataset: String,
    pub scale: f64,
    pub cycles: u64,
    pub variant: Variant,
    pub learner_name: String,
    pub lambda: f32,
    pub eta: f32,
    /// MERGE rule for Mu/Um: coordinate averaging (Algorithm 3) or the
    /// sign-agreement quorum vote (DESIGN.md §17)
    pub merge: MergeMode,
    /// example-reservoir capacity K riding with each model when the learner
    /// is pairwise (ignored for pointwise learners)
    pub reservoir: usize,
    pub cache: usize,
    pub sampler: SamplerConfig,
    pub failures: bool,
    pub seed: u64,
    pub eval_peers: usize,
    pub voting: bool,
    pub similarity: bool,
    pub backend: BackendChoice,
    /// event-driven stepping mode: "microbatch" (default) or "scalar"
    pub mode: String,
    /// micro-batch coalescing window in ticks (0 = exact-timestamp batching)
    pub coalesce: u64,
    /// kernel-family dispatch: auto (density-based), dense, or sparse
    pub exec_path: ExecPath,
    /// node-range shards of the event-driven simulator (DESIGN.md §13);
    /// ≥ 2 leases worker threads and requires the native event backend
    pub shards: usize,
    /// failure/workload timeline: a named built-in (`scenario =` key) or an
    /// embedded/standalone `[scenario]` definition
    pub scenario: Option<Scenario>,
    /// gossip graph constraint (DESIGN.md §16); `None` is the implicit
    /// complete graph of the paper setup
    pub topology: Option<crate::p2p::TopologySpec>,
}

impl Default for ExperimentSpec {
    fn default() -> Self {
        ExperimentSpec {
            dataset: "urls".into(),
            scale: 1.0,
            cycles: 200,
            variant: Variant::Mu,
            learner_name: "pegasos".into(),
            lambda: 1e-2,
            eta: 1e-3,
            merge: MergeMode::Average,
            reservoir: crate::learning::pairwise::DEFAULT_CAPACITY,
            cache: 10,
            sampler: SamplerConfig::Newscast { view_size: 20 },
            failures: false,
            seed: 42,
            eval_peers: 100,
            voting: false,
            similarity: false,
            backend: BackendChoice::Event,
            mode: "microbatch".into(),
            coalesce: 0,
            exec_path: ExecPath::Auto,
            shards: 1,
            scenario: None,
            topology: None,
        }
    }
}

impl ExperimentSpec {
    /// Apply a parsed key=value map (e.g. from an INI section or CLI flags).
    ///
    /// `sampler` is applied before every other key: `view` edits the
    /// NEWSCAST sampler in place, and the map's iteration order is
    /// arbitrary, so without the pre-pass `sampler = newscast` could reset a
    /// `view` that happened to be applied first.
    pub fn apply(&mut self, kv: &HashMap<String, String>) -> Result<(), GolfError> {
        if let Some(v) = kv.get("sampler") {
            self.sampler = match v.as_str() {
                "newscast" => SamplerConfig::Newscast { view_size: 20 },
                "oracle" => SamplerConfig::Oracle,
                "matching" => SamplerConfig::Matching,
                _ => return Err(GolfError::config(format!("bad sampler {v:?}"))),
            };
        }
        if kv.get("variant").map(String::as_str) == Some("pairwise-auc") {
            // `variant = pairwise-auc` is the ranking-objective alias: the
            // paper-style Mu walk with the pairwise learner.  Applied before
            // the main loop so an explicit `learner =` key still wins
            // regardless of the map's iteration order.
            self.variant = Variant::Mu;
            self.learner_name = "pairwise-auc".into();
        }
        for (k, v) in kv {
            match k.as_str() {
                "sampler" => {} // applied above
                "dataset" => self.dataset = v.clone(),
                "scale" => self.scale = parse(v, k)?,
                "cycles" => self.cycles = parse(v, k)?,
                "variant" if v == "pairwise-auc" => {} // alias applied above
                "variant" => {
                    self.variant = Variant::parse(v)
                        .ok_or_else(|| GolfError::config(format!("bad variant {v:?}")))?
                }
                "learner" => self.learner_name = v.clone(),
                "lambda" => self.lambda = parse(v, k)?,
                "eta" => self.eta = parse(v, k)?,
                "merge" => {
                    self.merge = MergeMode::parse(v)
                        .ok_or_else(|| GolfError::config(format!("bad merge {v:?}")))?
                }
                "reservoir" => self.reservoir = parse(v, k)?,
                "cache" => self.cache = parse(v, k)?,
                "view" => match &mut self.sampler {
                    SamplerConfig::Newscast { view_size } => *view_size = parse(v, k)?,
                    other => {
                        return Err(GolfError::config(format!(
                            "view requires sampler = newscast (got {})",
                            other.name()
                        )))
                    }
                },
                "failures" => {
                    self.failures = match v.as_str() {
                        "none" => false,
                        "extreme" => true,
                        _ => return Err(GolfError::config(format!("bad failures {v:?}"))),
                    }
                }
                "seed" => self.seed = parse(v, k)?,
                "eval_peers" => self.eval_peers = parse(v, k)?,
                "voting" => self.voting = parse_bool(v, k)?,
                "similarity" => self.similarity = parse_bool(v, k)?,
                "backend" => {
                    self.backend = BackendChoice::parse(v)
                        .ok_or_else(|| GolfError::config(format!("bad backend {v:?}")))?
                }
                "mode" => match v.as_str() {
                    "scalar" | "microbatch" => self.mode = v.clone(),
                    _ => return Err(GolfError::config(format!("bad mode {v:?}"))),
                },
                "coalesce" => self.coalesce = parse(v, k)?,
                "exec" => {
                    self.exec_path = ExecPath::parse(v)
                        .ok_or_else(|| GolfError::config(format!("bad exec {v:?}")))?
                }
                "shards" => {
                    self.shards = parse(v, k)?;
                    if self.shards == 0 {
                        return Err(GolfError::config(
                            "shards must be at least 1".to_string(),
                        ));
                    }
                }
                "scenario" => {
                    self.scenario = match v.as_str() {
                        "none" => None,
                        name => Some(crate::scenario::builtin(name)?),
                    }
                }
                "topology" => {
                    self.topology = crate::p2p::TopologySpec::parse(v)
                        .map_err(GolfError::config)?
                }
                _ => return Err(GolfError::config(format!("unknown key {k:?}"))),
            }
        }
        Ok(())
    }

    pub fn learner(&self) -> Result<Learner, GolfError> {
        match self.learner_name.as_str() {
            "pegasos" => Ok(Learner::pegasos(self.lambda)),
            "adaline" => Ok(Learner::adaline(self.eta)),
            "logreg" => Ok(Learner::logreg(self.lambda)),
            "pairwise-auc" => Ok(Learner::pairwise_auc(self.lambda)),
            other => Err(GolfError::config(format!("unknown learner {other:?}"))),
        }
    }

    /// Cross-key learning-rule validation shared by every target (simulator,
    /// deployment, batched): the invalid combinations of the pairwise
    /// objective and the quorum merge fail as config errors (exit code 2)
    /// before any run state exists.
    pub fn validate_learning(&self) -> Result<(), GolfError> {
        let learner = self.learner()?;
        if self.merge == MergeMode::Quorum && self.sampler == SamplerConfig::Matching {
            // PERFECT MATCHING replaces models pairwise per cycle and never
            // merges, so a quorum setting would be silently dead
            return Err(GolfError::config(
                "merge = quorum is meaningless under the PERFECT MATCHING \
                 baseline (it never merges models); pick sampler = newscast \
                 or oracle"
                    .to_string(),
            ));
        }
        if learner.is_pairwise() {
            if self.reservoir == 0 {
                return Err(GolfError::config(
                    "learner = pairwise-auc needs reservoir >= 1: the \
                     pairwise step ranks the local example against the \
                     walking model's reservoir"
                        .to_string(),
                ));
            }
            if self.reservoir > self.cache {
                return Err(GolfError::config(format!(
                    "reservoir = {} exceeds cache = {}: a reservoir larger \
                     than the model cache skews the walk's example memory \
                     toward stale peers; shrink reservoir or raise cache",
                    self.reservoir, self.cache
                )));
            }
        }
        let batched = matches!(
            self.backend,
            BackendChoice::BatchedNative | BackendChoice::BatchedPjrt
        );
        if batched && (learner.is_pairwise() || self.merge == MergeMode::Quorum) {
            return Err(GolfError::config(
                "the batched target supports averaging pointwise learners \
                 only (its pending-message frames carry no reservoirs); use \
                 backend = event"
                    .to_string(),
            ));
        }
        Ok(())
    }

    pub fn build_dataset(&self) -> Result<Dataset, GolfError> {
        let s = Scale(self.scale);
        match self.dataset.as_str() {
            "reuters" => Ok(reuters_like(self.seed, s)),
            "spambase" => Ok(spambase_like(self.seed, s)),
            "urls" => Ok(urls_like(self.seed, s)),
            other => Err(GolfError::data(format!("unknown dataset {other:?}"))),
        }
    }

    /// The event-driven stepping mode the `mode`/`coalesce` keys select.
    pub fn exec_mode(&self) -> Result<ExecMode, GolfError> {
        match self.mode.as_str() {
            "scalar" => Ok(ExecMode::Scalar),
            "microbatch" => Ok(ExecMode::MicroBatch { coalesce: self.coalesce }),
            other => Err(GolfError::config(format!("bad mode {other:?}"))),
        }
    }

    pub fn protocol_config(&self) -> Result<ProtocolConfig, GolfError> {
        if self.topology.is_some() && self.sampler == SamplerConfig::Matching {
            // PERFECT MATCHING pairs the whole universe per cycle; a graph
            // constraint would silently be ignored, so refuse the combination
            return Err(GolfError::config(
                "sampler = matching ignores graph constraints; \
                 drop `topology =` or pick oracle/newscast"
                    .to_string(),
            ));
        }
        self.validate_learning()?;
        let mut cfg = ProtocolConfig::paper_default(self.cycles);
        cfg.variant = self.variant;
        cfg.learner = self.learner()?;
        cfg.merge = self.merge;
        cfg.reservoir = self.reservoir;
        cfg.cache_size = self.cache;
        cfg.sampler = self.sampler;
        cfg.seed = self.seed;
        cfg.eval.n_peers = self.eval_peers;
        cfg.eval.voting = self.voting;
        cfg.eval.similarity = self.similarity;
        // ranking objective => ranking metric: the AUC column turns on with
        // the pairwise learner (and stays available via eval output for
        // pointwise runs that opt in programmatically)
        cfg.eval.auc = cfg.learner.is_pairwise();
        cfg.exec = self.exec_mode()?;
        cfg.path = self.exec_path;
        cfg.shards = self.shards;
        if self.failures {
            cfg = cfg.with_extreme_failures();
        }
        cfg.scenario = self.scenario.clone();
        cfg.topology = self.topology.clone();
        Ok(cfg)
    }

    /// Validate the attached scenario (if any) against a concrete dataset:
    /// the simulators require a validated timeline.  When a topology is set
    /// it is built here too (same `(spec, n, seed)` inputs as the run), so
    /// bad graphs and edge events naming absent edges fail before any
    /// simulator state exists.
    pub fn validate_scenario(&self, n_nodes: usize) -> Result<(), GolfError> {
        let topo = self.build_topology(n_nodes)?;
        if let Some(s) = &self.scenario {
            s.validate(n_nodes, self.cycles).map_err(|e| {
                GolfError::scenario_in(format!("scenario {:?}", s.name), e)
            })?;
            s.validate_topology(topo.as_ref()).map_err(|e| {
                GolfError::scenario_in(format!("scenario {:?}", s.name), e)
            })?;
        }
        Ok(())
    }

    /// Build the configured topology over an `n_nodes` universe (`Ok(None)`
    /// for the implicit complete graph).  Generator errors — degree-0
    /// nodes, disconnected graphs without `allow-disconnected:`, unreadable
    /// edge lists — surface as config errors (exit code 2).
    pub fn build_topology(
        &self,
        n_nodes: usize,
    ) -> Result<Option<crate::p2p::Topology>, GolfError> {
        match &self.topology {
            None => Ok(None),
            Some(spec) => crate::p2p::Topology::build(spec, n_nodes, self.seed)
                .map(Some)
                .map_err(GolfError::config),
        }
    }

    /// Parse the `[experiment]` schema (plus any embedded scenario
    /// sections) from INI text.  Delegates to the strict full-schema parser
    /// (`api::RunSpec::from_ini`); nothing is silently ignored — unknown
    /// sections are rejected, and a config bundling `[deploy]` or `[sweep]`
    /// sections must go through `RunSpec::from_ini` instead.
    pub fn from_ini(text: &str) -> Result<Self, GolfError> {
        let spec = crate::api::RunSpec::from_ini(text)?;
        if spec.target == crate::api::Target::Deploy {
            return Err(GolfError::config(
                "config bundles a [deploy] section; parse it with \
                 DeploySpec::from_ini or api::RunSpec::from_ini"
                    .to_string(),
            ));
        }
        if spec.sweep.is_some() {
            return Err(GolfError::config(
                "config bundles a [sweep] section; parse it with \
                 api::RunSpec::from_ini"
                    .to_string(),
            ));
        }
        Ok(spec.experiment)
    }
}

/// Does an INI document define a scenario?  `[phase.*]` / `[event.*]`
/// sections count even without a `[scenario]` header (which the grammar
/// makes optional) — a timeline must never be silently dropped.
pub(crate) fn has_scenario_sections(doc: &ini::Document) -> bool {
    doc.keys()
        .any(|k| k == "scenario" || k.starts_with("phase.") || k.starts_with("event."))
}

/// Configuration of a `golf deploy` run: the shared experiment keys plus
/// the deployment-only wall-clock mapping.  Parsed from the same INI files
/// (`[experiment]` + `[deploy]` sections) and the same CLI flag map.
#[derive(Clone, Debug, PartialEq)]
pub struct DeploySpec {
    pub experiment: ExperimentSpec,
    /// wall-clock gossip period Δ in milliseconds
    pub delta_ms: u64,
    /// node count; 0 = one node per training row (required for parity with
    /// a matched simulator run)
    pub nodes: usize,
    /// worker threads multiplexing the nodes; 0 = auto (thread-ledger
    /// budget, clamped to the node count)
    pub node_groups: usize,
}

impl Default for DeploySpec {
    fn default() -> Self {
        DeploySpec {
            experiment: ExperimentSpec::default(),
            delta_ms: 30,
            nodes: 0,
            node_groups: 0,
        }
    }
}

impl DeploySpec {
    /// Apply one deployment-only key=value pair; `Ok(false)` means the key
    /// is not a deployment key (callers route it to the experiment schema
    /// or reject it).  The single source of `delta_ms`/`nodes`/
    /// `node_groups` parsing —
    /// [`DeploySpec::apply`], the CLI flag map, and `RunSpec::from_ini`'s
    /// `[deploy]` section all come through here.
    pub fn apply_deploy_key(&mut self, k: &str, v: &str) -> Result<bool, GolfError> {
        match k {
            "delta_ms" => {
                self.delta_ms = parse(v, k)?;
                Ok(true)
            }
            "nodes" => {
                self.nodes = parse(v, k)?;
                Ok(true)
            }
            // "node-groups" is the CLI flag spelling (--node-groups); the
            // INI canonical form is node_groups
            "node_groups" | "node-groups" => {
                self.node_groups = parse(v, k)?;
                Ok(true)
            }
            _ => Ok(false),
        }
    }

    /// Apply a key=value map: deployment keys are handled here, everything
    /// else is delegated to the embedded [`ExperimentSpec`].
    pub fn apply(&mut self, kv: &HashMap<String, String>) -> Result<(), GolfError> {
        let mut rest = HashMap::new();
        for (k, v) in kv {
            if !self.apply_deploy_key(k, v)? {
                rest.insert(k.clone(), v.clone());
            }
        }
        self.experiment.apply(&rest)
    }

    /// Parse the `[experiment]` and `[deploy]` sections (plus any embedded
    /// scenario definition) from INI text.  Delegates to the strict
    /// full-schema parser (`api::RunSpec::from_ini`): unknown sections,
    /// non-deployment keys inside `[deploy]`, and bundled `[sweep]`
    /// sections are rejected.
    pub fn from_ini(text: &str) -> Result<Self, GolfError> {
        let spec = crate::api::RunSpec::from_ini(text)?;
        if spec.sweep.is_some() {
            return Err(GolfError::config(
                "config bundles a [sweep] section; parse it with \
                 api::RunSpec::from_ini"
                    .to_string(),
            ));
        }
        Ok(spec.to_deploy_spec())
    }

    /// Resolve against a dataset into the runtime configuration.
    pub fn deploy_config(
        &self,
        data: &Dataset,
    ) -> Result<crate::net::deploy::DeployConfig, GolfError> {
        use crate::net::deploy::DeployConfig;
        let e = &self.experiment;
        let n = if self.nodes == 0 { data.n_train() } else { self.nodes };
        if n < 2 {
            return Err(GolfError::data(format!("need at least 2 nodes, got {n}")));
        }
        if n > data.n_train() {
            return Err(GolfError::data(format!(
                "nodes = {n} exceeds the {} training rows of {}",
                data.n_train(),
                data.name
            )));
        }
        if e.sampler == SamplerConfig::Matching {
            // PERFECT MATCHING needs a globally consistent partner table per
            // cycle; per-node sampler instances in a real deployment cannot
            // provide that (it is a simulator-only baseline)
            return Err(GolfError::config(
                "sampler = matching is not supported in deployment".to_string(),
            ));
        }
        // build the graph eagerly: bad topologies and edge events naming
        // absent edges must fail before any socket is bound
        let topo = e.build_topology(n)?;
        if let Some(s) = &e.scenario {
            // the deployment compiles the timeline over its node universe
            s.validate(n, e.cycles).map_err(|err| {
                GolfError::scenario_in(format!("scenario {:?}", s.name), err)
            })?;
            s.validate_topology(topo.as_ref()).map_err(|err| {
                GolfError::scenario_in(format!("scenario {:?}", s.name), err)
            })?;
        }
        e.validate_learning()?;
        let mut cfg = DeployConfig {
            n_nodes: n,
            node_groups: self.node_groups,
            delta: std::time::Duration::from_millis(self.delta_ms.max(1)),
            cycles: e.cycles,
            variant: e.variant,
            learner: e.learner()?,
            merge: e.merge,
            reservoir: e.reservoir,
            cache_size: e.cache,
            sampler: e.sampler,
            eval_peers: e.eval_peers,
            seed: e.seed,
            scenario: e.scenario.clone(),
            topology: e.topology.clone(),
            ..Default::default()
        };
        // group-aware node bound: each worker thread multiplexes at most
        // MAX_GROUP_NODES peers, so the ceiling scales with the group
        // count instead of the retired thread-per-node cap of 512
        let cap = crate::net::deploy::max_deploy_nodes(cfg.resolved_groups());
        if n > cap {
            return Err(GolfError::config(format!(
                "deployment of {n} nodes exceeds the {cap}-node bound of \
                 {} node group(s) ({} nodes per group); raise node_groups \
                 (--node-groups), or pass nodes = N or a smaller scale",
                cfg.resolved_groups(),
                crate::net::deploy::MAX_GROUP_NODES
            )));
        }
        if e.failures {
            cfg = cfg.with_extreme_failures();
        }
        Ok(cfg)
    }
}

fn parse<T: std::str::FromStr>(v: &str, k: &str) -> Result<T, GolfError> {
    v.parse()
        .map_err(|_| GolfError::config(format!("bad value for {k}: {v:?}")))
}

fn parse_bool(v: &str, k: &str) -> Result<bool, GolfError> {
    match v {
        "true" | "1" | "yes" => Ok(true),
        "false" | "0" | "no" => Ok(false),
        _ => Err(GolfError::config(format!("bad bool for {k}: {v:?}"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ini_roundtrip() {
        let text = "
; experiment config
[experiment]
dataset = spambase
scale = 0.5
cycles = 99
variant = um
failures = extreme
voting = true
backend = batched-native
";
        let spec = ExperimentSpec::from_ini(text).unwrap();
        assert_eq!(spec.dataset, "spambase");
        assert_eq!(spec.scale, 0.5);
        assert_eq!(spec.cycles, 99);
        assert_eq!(spec.variant, Variant::Um);
        assert!(spec.failures);
        assert!(spec.voting);
        assert_eq!(spec.backend, BackendChoice::BatchedNative);
        let cfg = spec.protocol_config().unwrap();
        assert!(cfg.churn.is_some());
        assert!(cfg.eval.voting);
    }

    #[test]
    fn rejects_unknown_keys_and_values() {
        let mut kv = HashMap::new();
        kv.insert("bogus".to_string(), "1".to_string());
        assert!(ExperimentSpec::default().apply(&kv).is_err());
        let mut kv = HashMap::new();
        kv.insert("variant".to_string(), "xx".to_string());
        assert!(ExperimentSpec::default().apply(&kv).is_err());
    }

    #[test]
    fn builds_all_datasets() {
        for name in ["reuters", "spambase", "urls"] {
            let mut spec = ExperimentSpec { scale: 0.01, ..Default::default() };
            spec.dataset = name.into();
            let ds = spec.build_dataset().unwrap();
            assert_eq!(ds.name, name);
        }
    }

    #[test]
    fn exec_mode_keys_map_to_protocol_config() {
        let mut kv = HashMap::new();
        kv.insert("mode".to_string(), "scalar".to_string());
        let mut spec = ExperimentSpec { scale: 0.01, ..Default::default() };
        spec.apply(&kv).unwrap();
        assert_eq!(spec.protocol_config().unwrap().exec, ExecMode::Scalar);

        let mut kv = HashMap::new();
        kv.insert("mode".to_string(), "microbatch".to_string());
        kv.insert("coalesce".to_string(), "250".to_string());
        let mut spec = ExperimentSpec { scale: 0.01, ..Default::default() };
        spec.apply(&kv).unwrap();
        assert_eq!(
            spec.protocol_config().unwrap().exec,
            ExecMode::MicroBatch { coalesce: 250 }
        );

        let mut kv = HashMap::new();
        kv.insert("mode".to_string(), "warp".to_string());
        assert!(ExperimentSpec::default().apply(&kv).is_err());
        assert_eq!(BackendChoice::parse("event-pjrt"), Some(BackendChoice::EventPjrt));
    }

    #[test]
    fn exec_key_maps_to_exec_path() {
        let mut spec = ExperimentSpec { scale: 0.01, ..Default::default() };
        assert_eq!(spec.protocol_config().unwrap().path, ExecPath::Auto);
        let mut kv = HashMap::new();
        kv.insert("exec".to_string(), "sparse".to_string());
        spec.apply(&kv).unwrap();
        assert_eq!(spec.protocol_config().unwrap().path, ExecPath::Sparse);
        let mut kv = HashMap::new();
        kv.insert("exec".to_string(), "dense".to_string());
        spec.apply(&kv).unwrap();
        assert_eq!(spec.protocol_config().unwrap().path, ExecPath::Dense);
        let mut kv = HashMap::new();
        kv.insert("exec".to_string(), "warp".to_string());
        assert!(spec.apply(&kv).is_err());
    }

    #[test]
    fn deployment_spec_ini_and_flags() {
        let text = "
[experiment]
dataset = urls
scale = 0.01
cycles = 12
variant = um
failures = extreme

[deploy]
delta_ms = 25
nodes = 40
";
        let spec = DeploySpec::from_ini(text).unwrap();
        assert_eq!(spec.delta_ms, 25);
        assert_eq!(spec.nodes, 40);
        assert_eq!(spec.experiment.cycles, 12);
        let ds = spec.experiment.build_dataset().unwrap();
        let cfg = spec.deploy_config(&ds).unwrap();
        assert_eq!(cfg.n_nodes, 40);
        assert_eq!(cfg.delta, std::time::Duration::from_millis(25));
        assert_eq!(cfg.cycles, 12);
        assert_eq!(cfg.variant, Variant::Um);
        assert!(cfg.churn.is_some(), "failures = extreme must enable churn");

        // flags: deploy keys + experiment keys in one map
        let mut spec = DeploySpec::default();
        let mut kv = HashMap::new();
        kv.insert("delta_ms".to_string(), "15".to_string());
        kv.insert("cycles".to_string(), "7".to_string());
        spec.apply(&kv).unwrap();
        assert_eq!(spec.delta_ms, 15);
        assert_eq!(spec.experiment.cycles, 7);
        let mut kv = HashMap::new();
        kv.insert("bogus".to_string(), "1".to_string());
        assert!(spec.apply(&kv).is_err());
    }

    #[test]
    fn deployment_spec_node_count_defaults_and_bounds() {
        let mut spec = DeploySpec::default();
        spec.experiment.scale = 0.005; // urls: 50 training rows
        let ds = spec.experiment.build_dataset().unwrap();
        let cfg = spec.deploy_config(&ds).unwrap();
        assert_eq!(cfg.n_nodes, ds.n_train(), "nodes = 0 means one per row");
        spec.nodes = ds.n_train() + 1;
        assert!(spec.deploy_config(&ds).is_err());
        spec.nodes = 1;
        assert!(spec.deploy_config(&ds).is_err());
        // the simulator-only PERFECT MATCHING baseline cannot deploy
        spec.nodes = 0;
        spec.experiment.sampler = SamplerConfig::Matching;
        assert!(spec.deploy_config(&ds).is_err());
        // the group-aware bound refuses node counts one group cannot
        // multiplex (urls at scale 0.25 -> 2500 training rows, beyond one
        // group's MAX_GROUP_NODES) and names the knob that raises it
        let mut spec = DeploySpec { node_groups: 1, ..Default::default() };
        spec.experiment.scale = 0.25;
        let big = spec.experiment.build_dataset().unwrap();
        assert!(big.n_train() > crate::net::deploy::max_deploy_nodes(1));
        let err = spec.deploy_config(&big).unwrap_err().to_string();
        assert!(err.contains("node_groups"), "error must name the knob: {err}");
        // more groups raise the bound: the same dataset deploys with 2
        spec.node_groups = 2;
        let cfg = spec.deploy_config(&big).unwrap();
        assert_eq!(cfg.n_nodes, big.n_train());
        assert_eq!(cfg.resolved_groups(), 2);
    }

    #[test]
    fn scenario_key_and_embedded_section() {
        // named built-in via the `scenario =` key
        let mut kv = HashMap::new();
        kv.insert("scenario".to_string(), "paper-fig3".to_string());
        let mut spec = ExperimentSpec { scale: 0.01, ..Default::default() };
        spec.apply(&kv).unwrap();
        assert_eq!(spec.scenario.as_ref().unwrap().name, "paper-fig3");
        assert!(spec.protocol_config().unwrap().scenario.is_some());
        // `scenario = none` clears it; unknown names are rejected
        let mut kv = HashMap::new();
        kv.insert("scenario".to_string(), "none".to_string());
        spec.apply(&kv).unwrap();
        assert!(spec.scenario.is_none());
        let mut kv = HashMap::new();
        kv.insert("scenario".to_string(), "warp".to_string());
        assert!(ExperimentSpec::default().apply(&kv).is_err());
        // an embedded [scenario] section wins over the key
        let text = "
[experiment]
dataset = urls
scenario = paper-fig3

[scenario]
name = inline
drop = 0.3
";
        let spec = ExperimentSpec::from_ini(text).unwrap();
        assert_eq!(spec.scenario.as_ref().unwrap().name, "inline");
        // regression: a timeline given only as [phase.*]/[event.*] sections
        // (the [scenario] header is optional) must not be silently dropped
        let text = "
[experiment]
dataset = urls

[phase.outage]
from = 10
to = 50
drop = 0.9
";
        let spec = ExperimentSpec::from_ini(text).unwrap();
        let scn = spec.scenario.as_ref().expect("headerless timeline must attach");
        assert_eq!(scn.phases.len(), 1);
        assert_eq!(scn.phases[0].drop, Some(0.9));
        // validation catches timelines that do not fit the run
        let mut spec = ExperimentSpec::default();
        spec.cycles = 10;
        spec.scenario = Some(crate::scenario::builtin("partition-heal").unwrap());
        assert!(
            spec.validate_scenario(100).is_err(),
            "a cycle-120 phase end cannot fit a 10-cycle run"
        );
        spec.cycles = 200;
        spec.validate_scenario(100).unwrap();
    }

    #[test]
    fn deploy_spec_carries_scenario() {
        let text = "
[experiment]
dataset = urls
scale = 0.01
cycles = 50

[deploy]
delta_ms = 20

[scenario]
name = blip
[phase.out]
from = 5
to = 10
drop = 0.9
";
        let spec = DeploySpec::from_ini(text).unwrap();
        assert_eq!(spec.experiment.scenario.as_ref().unwrap().name, "blip");
        let ds = spec.experiment.build_dataset().unwrap();
        let cfg = spec.deploy_config(&ds).unwrap();
        assert_eq!(cfg.scenario.as_ref().unwrap().name, "blip");
        // an infeasible timeline is rejected at deploy-config time
        let mut bad = spec.clone();
        bad.experiment.cycles = 7;
        assert!(bad.deploy_config(&ds).is_err());
    }

    #[test]
    fn shards_key_maps_to_protocol_config() {
        let mut spec = ExperimentSpec { scale: 0.01, ..Default::default() };
        assert_eq!(spec.protocol_config().unwrap().shards, 1);
        let mut kv = HashMap::new();
        kv.insert("shards".to_string(), "4".to_string());
        spec.apply(&kv).unwrap();
        assert_eq!(spec.shards, 4);
        assert_eq!(spec.protocol_config().unwrap().shards, 4);
        let mut kv = HashMap::new();
        kv.insert("shards".to_string(), "0".to_string());
        assert!(spec.apply(&kv).is_err(), "shards = 0 must be rejected");
    }

    #[test]
    fn topology_key_maps_to_protocol_config() {
        use crate::p2p::topology::TopologyKind;
        let mut spec = ExperimentSpec { scale: 0.01, ..Default::default() };
        assert!(spec.protocol_config().unwrap().topology.is_none());
        let mut kv = HashMap::new();
        kv.insert("topology".to_string(), "ring:2".to_string());
        spec.apply(&kv).unwrap();
        let t = spec.topology.clone().expect("ring:2 must attach");
        assert_eq!(t.kind, TopologyKind::Ring { k: 2 });
        assert_eq!(spec.protocol_config().unwrap().topology, Some(t));
        // `complete` (and `none`) mean the implicit complete graph
        let mut kv = HashMap::new();
        kv.insert("topology".to_string(), "complete".to_string());
        spec.apply(&kv).unwrap();
        assert!(spec.topology.is_none());
        // unknown generators are config errors
        let mut kv = HashMap::new();
        kv.insert("topology".to_string(), "warp".to_string());
        assert!(ExperimentSpec::default().apply(&kv).is_err());
        // the simulator-only PERFECT MATCHING baseline pairs the whole
        // universe; combining it with a graph constraint is rejected
        let mut spec = ExperimentSpec { scale: 0.01, ..Default::default() };
        spec.sampler = SamplerConfig::Matching;
        spec.topology = crate::p2p::TopologySpec::parse("ring:1").unwrap();
        assert!(spec.protocol_config().is_err());
        // validate_scenario builds the graph: edge events against the
        // implicit complete graph, and events naming absent edges, both fail
        let mut spec = ExperimentSpec { scale: 0.01, ..Default::default() };
        spec.cycles = 200;
        spec.scenario = Some(crate::scenario::builtin("link-storm").unwrap());
        assert!(
            spec.validate_scenario(50).is_err(),
            "link-storm needs a non-complete topology"
        );
        spec.topology = crate::p2p::TopologySpec::parse("ring:2").unwrap();
        spec.validate_scenario(50).unwrap();
    }

    #[test]
    fn deploy_spec_carries_topology() {
        let text = "
[experiment]
dataset = urls
scale = 0.01
cycles = 20
topology = ring:2

[deploy]
delta_ms = 20
nodes = 30
";
        let spec = DeploySpec::from_ini(text).unwrap();
        let ds = spec.experiment.build_dataset().unwrap();
        let cfg = spec.deploy_config(&ds).unwrap();
        assert_eq!(cfg.topology, spec.experiment.topology);
        assert!(cfg.topology.is_some());
        // a graph the generator rejects fails at deploy-config time:
        // ring:1 over 30 nodes is fine, but an inline graph leaving node 2
        // isolated is a config error
        let mut bad = spec.clone();
        bad.experiment.topology =
            crate::p2p::TopologySpec::parse("graph-inline:0-1").unwrap();
        assert!(bad.deploy_config(&ds).is_err());
    }

    #[test]
    fn pairwise_keys_map_and_validate() {
        // the variant alias expands to Mu + the pairwise learner, and the
        // AUC metric turns on automatically
        let mut spec = ExperimentSpec { scale: 0.01, ..Default::default() };
        let mut kv = HashMap::new();
        kv.insert("variant".to_string(), "pairwise-auc".to_string());
        kv.insert("merge".to_string(), "quorum".to_string());
        kv.insert("reservoir".to_string(), "4".to_string());
        spec.apply(&kv).unwrap();
        assert_eq!(spec.variant, Variant::Mu);
        assert_eq!(spec.learner_name, "pairwise-auc");
        assert_eq!(spec.merge, MergeMode::Quorum);
        assert_eq!(spec.reservoir, 4);
        let cfg = spec.protocol_config().unwrap();
        assert_eq!(cfg.merge, MergeMode::Quorum);
        assert_eq!(cfg.reservoir, 4);
        assert!(cfg.eval.auc, "pairwise learner must enable the AUC metric");
        assert!(cfg.learner.is_pairwise());
        // an explicit learner key beats the alias regardless of map order
        let mut spec = ExperimentSpec { scale: 0.01, ..Default::default() };
        let mut kv = HashMap::new();
        kv.insert("variant".to_string(), "pairwise-auc".to_string());
        kv.insert("learner".to_string(), "pegasos".to_string());
        spec.apply(&kv).unwrap();
        assert_eq!(spec.learner_name, "pegasos");
        assert_eq!(spec.variant, Variant::Mu);
        assert!(!spec.protocol_config().unwrap().eval.auc);
        // bad merge values are config errors
        let mut kv = HashMap::new();
        kv.insert("merge".to_string(), "majority".to_string());
        assert!(ExperimentSpec::default().apply(&kv).is_err());
        // quorum + matching is always rejected: matching never merges
        let mut spec = ExperimentSpec { scale: 0.01, ..Default::default() };
        spec.merge = MergeMode::Quorum;
        spec.sampler = SamplerConfig::Matching;
        assert!(spec.protocol_config().is_err());
        // reservoir bounds apply only to the pairwise learner
        let mut spec = ExperimentSpec { scale: 0.01, ..Default::default() };
        spec.reservoir = 0;
        spec.protocol_config().unwrap(); // pointwise: ignored
        spec.learner_name = "pairwise-auc".into();
        assert!(spec.protocol_config().is_err(), "reservoir = 0 rejected");
        spec.reservoir = 99; // > cache (10)
        assert!(spec.protocol_config().is_err(), "reservoir > cache rejected");
        spec.reservoir = 8;
        spec.protocol_config().unwrap();
        // the batched target carries no reservoirs and never quorum-merges
        spec.backend = BackendChoice::BatchedNative;
        assert!(spec.validate_learning().is_err());
        let mut spec = ExperimentSpec { scale: 0.01, ..Default::default() };
        spec.merge = MergeMode::Quorum;
        spec.backend = BackendChoice::BatchedPjrt;
        assert!(spec.validate_learning().is_err());
    }

    #[test]
    fn pairwise_deploy_config_carries_merge_and_reservoir() {
        let mut spec = DeploySpec::default();
        spec.experiment.scale = 0.01;
        spec.experiment.learner_name = "pairwise-auc".into();
        spec.experiment.merge = MergeMode::Quorum;
        spec.experiment.reservoir = 4;
        let ds = spec.experiment.build_dataset().unwrap();
        let cfg = spec.deploy_config(&ds).unwrap();
        assert_eq!(cfg.merge, MergeMode::Quorum);
        assert_eq!(cfg.reservoir, 4);
        assert!(cfg.learner.is_pairwise());
        // the deployment applies the same reservoir bounds
        spec.experiment.reservoir = 0;
        assert!(spec.deploy_config(&ds).is_err());
    }

    #[test]
    fn adaline_learner_selectable() {
        let mut spec = ExperimentSpec::default();
        spec.learner_name = "adaline".into();
        assert_eq!(spec.learner().unwrap().name(), "adaline");
    }
}
