//! `golf` binary: the L3 coordinator CLI.  See `golf help`.

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(golf::cli::dispatch(&args));
}
