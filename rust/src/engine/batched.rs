//! Cycle-synchronous batched driver: the same gossip-learning protocol as
//! gossip/protocol.rs, but with all per-node CREATEMODEL steps of a cycle
//! executed as batched engine ops — the maximally-vectorized hot path that a
//! future TPU deployment wants when exact event timing is not needed.
//!
//! Semantics relative to the event-driven simulator: sends are synchronized
//! at cycle boundaries (no Δ jitter within a cycle) and message delay is
//! quantized to whole cycles.  Per-node state lives in the same
//! structure-of-arrays [`ModelStore`] the event-driven simulator uses
//! (DESIGN.md §5).  When one node receives several messages in a cycle they
//! are chained in arrival order: message k's `lastModel` input is message
//! k-1's weights, which is known before stepping, so the whole cycle still
//! executes as flat batches.  DESIGN.md §2 discusses the tradeoff; the
//! engine-parity tests pin native and PJRT backends to each other on
//! identical schedules.

use crate::api::{NullObserver, Observer, RunEvent};
use crate::data::dataset::Dataset;
use crate::engine::{eval_peer_errors, Backend, StepBatch, StepOp, MAX_BATCH_ROWS};
use crate::eval::tracker::{point_from_errors, Curve};
use crate::eval::{self};
use crate::gossip::protocol::{ProtocolConfig, RunResult, RunStats};
use crate::gossip::state::ModelStore;
use crate::p2p::overlay::PeerSampler;
use crate::scenario::driver::{
    resolve_churn_schedule, CompiledScenario, Mutation, ScenarioDriver,
};
use crate::sim::network::{Fate, Network};
use crate::util::bitset::Bitset;
use crate::util::rng::Rng;
use anyhow::Result;
use std::collections::HashMap;

struct PendingMsg {
    dst: usize,
    w: Vec<f32>,
    t: f32,
    arrival_cycle: u64,
    seq: u64,
}

pub struct BatchedSim<'a, B: Backend> {
    cfg: ProtocolConfig,
    data: &'a Dataset,
    backend: &'a mut B,
    op: StepOp,
    /// unified SoA per-node model state, shared with the event-driven path
    store: ModelStore,
    dense_x: Vec<f32>, // local examples, densified once (full universe)
    /// drop/delay/partition models, scenario-mutable at cycle boundaries
    network: Network,
    /// compiled scenario timeline cursor, if any
    scn: Option<ScenarioDriver>,
    /// scenario mass-leave overlay (ANDed with the churn schedule), packed
    forced_off: Bitset,
    /// +1.0 normally; -1.0 after an odd number of concept-drift events
    drift_sign: f32,
    flipped_test_y: Option<Vec<f32>>,
    rng: Rng,
    stats: RunStats,
}

impl<'a, B: Backend> BatchedSim<'a, B> {
    pub fn new(cfg: ProtocolConfig, data: &'a Dataset, backend: &'a mut B) -> Self {
        let n_univ = data.n_train();
        let d = data.d();
        // the batched target rejects pairwise/quorum upstream
        // (RunSpec::validate): PendingMsg frames carry no reservoirs
        let op = StepOp::for_protocol(&cfg.learner, cfg.variant, cfg.merge);
        let mut dense_x = vec![0.0f32; n_univ * d];
        for i in 0..n_univ {
            data.train.row(i).write_dense(&mut dense_x[i * d..(i + 1) * d]);
        }
        // the batched target rejects `topology` upstream (RunSpec::validate),
        // so scenarios compile graph-free here; edge scenarios cannot reach
        // this driver
        let compiled = cfg.scenario.as_ref().map(|s| {
            CompiledScenario::compile(
                s, n_univ, cfg.delta, cfg.cycles, cfg.seed, cfg.network, None,
            )
            .expect("scenario must be validated before the batched driver runs")
        });
        let n0 = compiled.as_ref().map_or(n_univ, |c| c.initial);
        let rng = Rng::new(cfg.seed);
        BatchedSim {
            op,
            store: ModelStore::new(n0, d),
            dense_x,
            network: Network::new(cfg.network),
            scn: compiled.map(|c| ScenarioDriver::new(std::sync::Arc::new(c))),
            forced_off: Bitset::new(n_univ),
            drift_sign: 1.0,
            flipped_test_y: None,
            rng,
            stats: RunStats::default(),
            cfg,
            data,
            backend,
        }
    }

    /// Apply every scenario mutation due at or before `now` — the batched
    /// driver's tick boundaries are its cycle boundaries, so mutations land
    /// between the previous cycle's deliveries and this cycle's sends.
    fn apply_scenario(&mut self, now: u64, sampler: &mut PeerSampler, obs: &mut dyn Observer) {
        while let Some(m) = self.scn.as_mut().and_then(|d| d.pop_due(now)) {
            obs.on_event(&RunEvent::Scenario {
                cycle: now / self.cfg.delta,
                mutation: m.describe(),
            });
            match m {
                Mutation::SetDrop(p) => self.network.cfg.drop_prob = p,
                Mutation::SetDelay(model) => self.network.cfg.delay = model,
                Mutation::SetPartition(c) => self.network.set_partition(Some(c)),
                Mutation::Heal => {
                    self.network.set_partition(None);
                    self.network.restore_edges(None);
                }
                Mutation::EdgeFail(_) | Mutation::EdgeRestore(_) => {
                    unreachable!("edge mutations need a topology, which the batched target rejects")
                }
                Mutation::Drift => self.drift_sign = -self.drift_sign,
                Mutation::ForceOffline(ids) => {
                    for i in ids {
                        self.forced_off.set(i);
                    }
                }
                Mutation::Restore(ids) => {
                    for i in ids {
                        self.forced_off.clear(i);
                    }
                }
                Mutation::Grow(k) => {
                    let old = self.store.n();
                    let newn = (old + k).min(self.data.n_train());
                    self.store.grow(newn - old);
                    sampler.grow(newn, &mut self.rng);
                }
            }
        }
    }

    pub fn run(self) -> Result<RunResult> {
        self.run_observed(&mut NullObserver)
    }

    /// Run to completion, streaming typed progress events
    /// ([`crate::api::RunEvent`]) to `obs`: every cycle boundary, every
    /// measured curve point, and every scenario mutation as it is applied.
    /// Observation is passive — an observed run is bit-for-bit identical to
    /// an unobserved one.
    pub fn run_observed(mut self, obs: &mut dyn Observer) -> Result<RunResult> {
        let n_univ = self.data.n_train();
        let d = self.data.d();
        let delta = self.cfg.delta;
        let horizon = delta * (self.cfg.cycles + 1);

        // same churn resolution (and RNG fork discipline) as the
        // event-driven simulator; the schedule covers the full universe
        let churn = resolve_churn_schedule(
            self.cfg.churn.as_ref(),
            self.scn.as_ref().map(|d| d.compiled()),
            n_univ,
            delta,
            horizon,
            &mut self.rng,
        );
        let n0 = self.store.n();
        let mut sampler_rng = self.rng.fork();
        let mut sampler = PeerSampler::new(self.cfg.sampler, None, n0, delta, &mut sampler_rng);
        let mut eval_rng = self.rng.fork();
        let eval_peers = eval_rng.sample_indices(n0, self.cfg.eval.n_peers.min(n0));

        let eval_cycles: std::collections::BTreeSet<u64> = if self.cfg.eval.at_cycles.is_empty() {
            eval::log_spaced_cycles(self.cfg.cycles).into_iter().collect()
        } else {
            self.cfg.eval.at_cycles.iter().copied().collect()
        };

        let mut curve = Curve::new(format!(
            "{}-{}-batched-{}",
            self.cfg.learner.name(),
            self.cfg.variant.name(),
            self.backend.name()
        ));

        let mut pending: Vec<PendingMsg> = Vec::new();
        let mut batch = StepBatch::default();
        let mut seq = 0u64;

        for cycle in 1..=self.cfg.cycles {
            let now = cycle * delta;
            obs.on_event(&RunEvent::Cycle { cycle });
            // scenario mutations apply at the cycle boundary, before the
            // cycle's sends and deliveries
            self.apply_scenario(now, &mut sampler, obs);
            // effective liveness over the whole universe: a node must be a
            // member (flash crowds grow the store), up per the churn
            // schedule, and not forced offline by a scenario leave wave
            let n_active = self.store.n();
            let online = Bitset::from_fn(n_univ, |i| {
                i < n_active
                    && churn.as_ref().map_or(true, |c| c.is_online(i, now))
                    && !self.forced_off.test(i)
            });

            // -------- sends (synchronized at the cycle boundary)
            for node in 0..n_active {
                if !online.test(node) {
                    continue;
                }
                let Some(dst) = sampler.select(node, now, &online, &mut self.rng) else {
                    continue;
                };
                self.stats.messages_sent += 1;
                // full frame size; the cycle-synchronous driver piggybacks
                // no NEWSCAST views, so there are no descriptor bytes
                self.stats.bytes_sent +=
                    (crate::gossip::message::WIRE_FRAME_OVERHEAD + d * 4) as u64;
                let delay_ticks =
                    match self.network.transmit_between(node, dst, &mut self.rng) {
                        Fate::Dropped => {
                            self.stats.messages_dropped += 1;
                            continue;
                        }
                        Fate::Blocked => {
                            self.stats.messages_blocked += 1;
                            continue;
                        }
                        Fate::Deliver(t) => t,
                    };
                let delay_cycles = delay_ticks / delta; // quantized
                pending.push(PendingMsg {
                    dst,
                    w: self.store.freshest(node).to_vec(),
                    t: self.store.freshest_t(node),
                    arrival_cycle: cycle + delay_cycles,
                    seq,
                });
                seq += 1;
            }

            // -------- deliveries due this cycle, in arrival (seq) order
            let mut due: Vec<PendingMsg> = Vec::new();
            pending.retain_mut(|msg| {
                if msg.arrival_cycle <= cycle {
                    due.push(PendingMsg {
                        dst: msg.dst,
                        w: std::mem::take(&mut msg.w),
                        t: msg.t,
                        arrival_cycle: msg.arrival_cycle,
                        seq: msg.seq,
                    });
                    false
                } else {
                    true
                }
            });
            // `pending` is appended in send order and `retain_mut` preserves
            // relative order, so `due` is already in arrival (seq) order
            debug_assert!(due.windows(2).all(|w| w[0].seq <= w[1].seq));

            // offline receivers lose their messages
            due.retain(|m| {
                if online.test(m.dst) {
                    true
                } else {
                    self.stats.messages_lost_offline += 1;
                    false
                }
            });
            self.stats.messages_delivered += due.len() as u64;

            // single pass: per-node chaining is wired through the previous
            // message's weights, so rows stay independent within a batch
            let mut prev_in_cycle: HashMap<usize, usize> = HashMap::new();
            let mut start = 0;
            while start < due.len() {
                let end = (start + MAX_BATCH_ROWS).min(due.len());
                let b = end - start;
                batch.resize(b, d);
                for (i, m) in due[start..end].iter().enumerate() {
                    let dst = m.dst;
                    let r = i * d..(i + 1) * d;
                    batch.w1[r.clone()].copy_from_slice(&m.w);
                    batch.t1[i] = m.t;
                    match prev_in_cycle.insert(dst, start + i) {
                        Some(prev) => {
                            batch.w2[r.clone()].copy_from_slice(&due[prev].w);
                            batch.t2[i] = due[prev].t;
                        }
                        None => {
                            batch.w2[r.clone()].copy_from_slice(self.store.last(dst));
                            batch.t2[i] = self.store.last_t(dst);
                        }
                    }
                    batch.x[r].copy_from_slice(&self.dense_x[dst * d..(dst + 1) * d]);
                    // concept drift re-labels: the sign flips with the
                    // scenario
                    batch.y[i] = self.drift_sign * self.data.train_y[dst];
                }
                self.backend.step(&self.op, &mut batch)?;
                self.stats.engine_calls += 1;
                self.stats.updates_applied += b as u64;
                for (i, m) in due[start..end].iter().enumerate() {
                    let dst = m.dst;
                    self.store
                        .set_freshest(dst, &batch.out_w[i * d..(i + 1) * d], batch.out_t[i]);
                    // lastModel <- incoming (Algorithm 1 line 9)
                    self.store.set_last(dst, &m.w, m.t);
                }
                start = end;
            }

            // -------- measurement
            if eval_cycles.contains(&cycle) {
                let errs = self.measure_errors(&eval_peers)?;
                let pt = point_from_errors(
                    cycle,
                    &errs,
                    None,
                    None,
                    None,
                    self.stats.messages_sent,
                );
                obs.on_event(&RunEvent::Eval { point: pt.clone() });
                curve.push(pt);
            }
        }

        Ok(RunResult { curve, stats: self.stats })
    }

    /// 0-1 error of every eval peer's freshest model via the shared
    /// sparse-aware chunked evaluator (`engine::eval_peer_errors`): dense
    /// test sets score zero-copy off their storage on the native backend,
    /// sparse ones through O(nnz) sparse dots, and the PJRT backend
    /// densifies per chunk into its compiled buckets.
    fn measure_errors(&mut self, eval_peers: &[usize]) -> Result<Vec<f64>> {
        // drift evaluation matches the event-driven simulator: while the
        // drift sign is negative, score against sign-flipped test labels
        if self.drift_sign < 0.0 && self.flipped_test_y.is_none() {
            self.flipped_test_y = Some(eval::flipped_labels(&self.data.test_y));
        }
        let y: &[f32] = if self.drift_sign < 0.0 {
            self.flipped_test_y.as_ref().unwrap()
        } else {
            &self.data.test_y
        };
        eval_peer_errors(&self.store, eval_peers, &mut *self.backend, &self.data.test, y)
    }
}

/// Run the batched driver with the given backend.
#[deprecated(
    since = "0.2.0",
    note = "construct runs through api::RunSpec / api::Session (kept as a \
            thin shim so engine-parity pins stay bit-for-bit)"
)]
pub fn run_batched<B: Backend>(
    cfg: ProtocolConfig,
    data: &Dataset,
    backend: &mut B,
) -> Result<RunResult> {
    BatchedSim::new(cfg, data, backend).run()
}

#[cfg(test)]
#[allow(deprecated)] // the parity suite exercises the legacy shim directly
mod tests {
    use super::*;
    use crate::data::synthetic::{urls_like, Scale};
    use crate::engine::native::NativeBackend;

    #[test]
    fn batched_native_converges() {
        let ds = urls_like(1, Scale(0.02));
        let mut cfg = ProtocolConfig::paper_default(50);
        cfg.eval.n_peers = 20;
        let mut be = NativeBackend::new();
        let res = run_batched(cfg, &ds, &mut be).unwrap();
        let first = res.curve.points.first().unwrap().err_mean;
        let last = res.curve.final_error();
        assert!(last < first, "{first} -> {last}");
        assert!(last < 0.25, "final {last}");
    }

    #[test]
    fn batched_deterministic() {
        let ds = urls_like(2, Scale(0.01));
        let mk = || {
            let mut cfg = ProtocolConfig::paper_default(15);
            cfg.eval.n_peers = 10;
            cfg
        };
        let mut b1 = NativeBackend::new();
        let mut b2 = NativeBackend::new();
        let a = run_batched(mk(), &ds, &mut b1).unwrap();
        let b = run_batched(mk(), &ds, &mut b2).unwrap();
        let ea: Vec<f64> = a.curve.points.iter().map(|p| p.err_mean).collect();
        let eb: Vec<f64> = b.curve.points.iter().map(|p| p.err_mean).collect();
        assert_eq!(ea, eb);
    }

    #[test]
    fn batched_failures_converge() {
        let ds = urls_like(3, Scale(0.02));
        let mut cfg = ProtocolConfig::paper_default(80).with_extreme_failures();
        cfg.eval.n_peers = 15;
        let mut be = NativeBackend::new();
        let res = run_batched(cfg, &ds, &mut be).unwrap();
        assert!(res.stats.messages_dropped > 0);
        assert!(res.stats.messages_lost_offline > 0);
        let first = res.curve.points.first().unwrap().err_mean;
        assert!(res.curve.final_error() < first);
    }

    #[test]
    fn batched_close_to_event_driven() {
        // same protocol, different scheduling model: final errors must land
        // in the same regime (loose statistical check, not bitwise)
        let ds = urls_like(4, Scale(0.02));
        let mut cfg = ProtocolConfig::paper_default(40);
        cfg.eval.n_peers = 20;
        let ev = crate::gossip::run(cfg.clone(), &ds);
        let mut be = NativeBackend::new();
        let bt = run_batched(cfg, &ds, &mut be).unwrap();
        let (a, b) = (ev.curve.final_error(), bt.curve.final_error());
        assert!((a - b).abs() < 0.08, "event {a} vs batched {b}");
    }
}
