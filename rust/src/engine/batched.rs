//! Cycle-synchronous batched driver: the same gossip-learning protocol as
//! gossip/protocol.rs, but with all per-node CREATEMODEL steps of a cycle
//! executed as batched engine ops — the vectorized hot path that the PJRT
//! backend (and a future TPU deployment) needs.
//!
//! Semantics relative to the event-driven simulator: sends are synchronized
//! at cycle boundaries (no Δ jitter within a cycle) and message delay is
//! quantized to whole cycles.  Deliveries landing at the same node in the
//! same cycle are processed in arrival order through sequential sub-rounds,
//! so the per-node state machine (cache/lastModel chaining) is preserved
//! exactly.  DESIGN.md §2 discusses the tradeoff; the engine-parity tests
//! pin native and PJRT backends to each other on identical schedules.

use crate::data::dataset::Dataset;
use crate::engine::{Backend, LearnerKind, StepBatch, StepOp};
use crate::eval::tracker::{point_from_errors, Curve};
use crate::eval::{self};
use crate::gossip::protocol::{ProtocolConfig, RunResult, RunStats};
use crate::learning::Learner;
use crate::p2p::overlay::PeerSampler;
use crate::sim::churn::ChurnSchedule;
use crate::util::rng::Rng;
use anyhow::Result;

/// Maximum rows per engine call (matches the largest compiled bucket).
const MAX_BATCH: usize = 1024;
/// Test-set rows per eval chunk (matches the eval artifact bucket).
const EVAL_CHUNK: usize = 1024;
/// Models per eval call (matches the eval artifact bucket).
const EVAL_MODELS: usize = 128;

struct PendingMsg {
    dst: usize,
    w: Vec<f32>,
    t: f32,
    arrival_cycle: u64,
    seq: u64,
}

pub struct BatchedSim<'a, B: Backend> {
    cfg: ProtocolConfig,
    data: &'a Dataset,
    backend: &'a mut B,
    op: StepOp,
    // per-node state (flat [n, d])
    freshest_w: Vec<f32>,
    freshest_t: Vec<f32>,
    last_w: Vec<f32>,
    last_t: Vec<f32>,
    dense_x: Vec<f32>, // local examples, densified once
    rng: Rng,
    stats: RunStats,
}

fn learner_op(l: &Learner) -> StepOp {
    match l {
        Learner::Pegasos(p) => StepOp {
            learner: LearnerKind::Pegasos,
            variant: crate::gossip::Variant::Mu, // patched by caller
            hp: p.lambda,
        },
        Learner::Adaline(a) => StepOp {
            learner: LearnerKind::Adaline,
            variant: crate::gossip::Variant::Mu,
            hp: a.eta,
        },
        Learner::LogReg(l) => StepOp {
            learner: LearnerKind::LogReg,
            variant: crate::gossip::Variant::Mu,
            hp: l.lambda,
        },
    }
}

impl<'a, B: Backend> BatchedSim<'a, B> {
    pub fn new(cfg: ProtocolConfig, data: &'a Dataset, backend: &'a mut B) -> Self {
        let n = data.n_train();
        let d = data.d();
        let mut op = learner_op(&cfg.learner);
        op.variant = cfg.variant;
        let mut dense_x = vec![0.0f32; n * d];
        for i in 0..n {
            data.train.row(i).write_dense(&mut dense_x[i * d..(i + 1) * d]);
        }
        let rng = Rng::new(cfg.seed);
        BatchedSim {
            op,
            freshest_w: vec![0.0; n * d],
            freshest_t: vec![0.0; n],
            last_w: vec![0.0; n * d],
            last_t: vec![0.0; n],
            dense_x,
            rng,
            stats: RunStats::default(),
            cfg,
            data,
            backend,
        }
    }

    pub fn run(mut self) -> Result<RunResult> {
        let n = self.data.n_train();
        let d = self.data.d();
        let delta = self.cfg.delta;
        let horizon = delta * (self.cfg.cycles + 1);

        let churn = self.cfg.churn.as_ref().map(|c| {
            let mut crng = self.rng.fork();
            ChurnSchedule::generate(c, n, horizon, &mut crng)
        });
        let mut sampler_rng = self.rng.fork();
        let mut sampler = PeerSampler::new(self.cfg.sampler, n, delta, &mut sampler_rng);
        let mut eval_rng = self.rng.fork();
        let eval_peers = eval_rng.sample_indices(n, self.cfg.eval.n_peers.min(n));

        let eval_cycles: std::collections::BTreeSet<u64> = if self.cfg.eval.at_cycles.is_empty() {
            eval::log_spaced_cycles(self.cfg.cycles).into_iter().collect()
        } else {
            self.cfg.eval.at_cycles.iter().copied().collect()
        };

        let mut curve = Curve::new(format!(
            "{}-{}-batched-{}",
            self.cfg.learner.name(),
            self.cfg.variant.name(),
            self.backend.name()
        ));

        let mut pending: Vec<PendingMsg> = Vec::new();
        let mut batch = StepBatch::default();
        let mut seq = 0u64;

        for cycle in 1..=self.cfg.cycles {
            let now = cycle * delta;
            let online: Vec<bool> = (0..n)
                .map(|i| churn.as_ref().map_or(true, |c| c.is_online(i, now)))
                .collect();

            // -------- sends (synchronized at the cycle boundary)
            for node in 0..n {
                if !online[node] {
                    continue;
                }
                let Some(dst) = sampler.select(node, now, &online, &mut self.rng) else {
                    continue;
                };
                self.stats.messages_sent += 1;
                self.stats.bytes_sent += (d * 4 + 8) as u64;
                if self.cfg.network.drop_prob > 0.0
                    && self.rng.chance(self.cfg.network.drop_prob)
                {
                    self.stats.messages_dropped += 1;
                    continue;
                }
                let delay_ticks = self.cfg.network.delay.sample(&mut self.rng);
                let delay_cycles = delay_ticks / delta; // quantized
                pending.push(PendingMsg {
                    dst,
                    w: self.freshest_w[node * d..(node + 1) * d].to_vec(),
                    t: self.freshest_t[node],
                    arrival_cycle: cycle + delay_cycles,
                    seq,
                });
                seq += 1;
            }

            // -------- deliveries due this cycle, grouped by destination
            let mut due: Vec<PendingMsg> = Vec::new();
            pending.retain_mut(|msg| {
                if msg.arrival_cycle <= cycle {
                    due.push(PendingMsg {
                        dst: msg.dst,
                        w: std::mem::take(&mut msg.w),
                        t: msg.t,
                        arrival_cycle: msg.arrival_cycle,
                        seq: msg.seq,
                    });
                    false
                } else {
                    true
                }
            });
            due.sort_by_key(|m| (m.dst, m.seq));

            // offline receivers lose their messages
            due.retain(|m| {
                if online[m.dst] {
                    true
                } else {
                    self.stats.messages_lost_offline += 1;
                    false
                }
            });

            // sub-rounds: the k-th message of each node forms round k
            let mut rounds: Vec<Vec<PendingMsg>> = Vec::new();
            {
                let mut k_of_dst: std::collections::HashMap<usize, usize> =
                    std::collections::HashMap::new();
                for m in due {
                    let k = k_of_dst.entry(m.dst).or_insert(0);
                    if rounds.len() <= *k {
                        rounds.push(Vec::new());
                    }
                    rounds[*k].push(m);
                    *k += 1;
                }
            }

            for round in rounds {
                for chunk in round.chunks(MAX_BATCH) {
                    let b = chunk.len();
                    batch.resize(b, d);
                    for (i, m) in chunk.iter().enumerate() {
                        let dst = m.dst;
                        batch.w1[i * d..(i + 1) * d].copy_from_slice(&m.w);
                        batch.t1[i] = m.t;
                        batch.w2[i * d..(i + 1) * d]
                            .copy_from_slice(&self.last_w[dst * d..(dst + 1) * d]);
                        batch.t2[i] = self.last_t[dst];
                        batch.x[i * d..(i + 1) * d]
                            .copy_from_slice(&self.dense_x[dst * d..(dst + 1) * d]);
                        batch.y[i] = self.data.train_y[dst];
                    }
                    self.backend.step(&self.op, &mut batch)?;
                    self.stats.updates_applied += b as u64;
                    for (i, m) in chunk.iter().enumerate() {
                        let dst = m.dst;
                        self.freshest_w[dst * d..(dst + 1) * d]
                            .copy_from_slice(&batch.out_w[i * d..(i + 1) * d]);
                        self.freshest_t[dst] = batch.out_t[i];
                        // lastModel <- incoming (Algorithm 1 line 9)
                        self.last_w[dst * d..(dst + 1) * d].copy_from_slice(&m.w);
                        self.last_t[dst] = m.t;
                    }
                }
            }

            // -------- measurement
            if eval_cycles.contains(&cycle) {
                let errs = self.measure_errors(&eval_peers)?;
                curve.push(point_from_errors(
                    cycle,
                    &errs,
                    None,
                    None,
                    self.stats.messages_sent,
                ));
            }
        }

        Ok(RunResult { curve, stats: self.stats })
    }

    /// 0-1 error of every eval peer's freshest model via batched
    /// `error_counts` over test-set chunks.
    fn measure_errors(&mut self, eval_peers: &[usize]) -> Result<Vec<f64>> {
        let d = self.data.d();
        let n_test = self.data.n_test();
        let mut errs = vec![0.0f64; eval_peers.len()];

        let mut xchunk = vec![0.0f32; EVAL_CHUNK.min(n_test) * d];
        for mgroup in eval_peers.chunks(EVAL_MODELS) {
            let m = mgroup.len();
            let mut w = vec![0.0f32; m * d];
            for (j, &p) in mgroup.iter().enumerate() {
                w[j * d..(j + 1) * d]
                    .copy_from_slice(&self.freshest_w[p * d..(p + 1) * d]);
            }
            let mut counts = vec![0.0f64; m];
            let mut row = 0;
            while row < n_test {
                let rows = EVAL_CHUNK.min(n_test - row);
                xchunk.resize(rows * d, 0.0);
                let mut ychunk = vec![0.0f32; rows];
                for i in 0..rows {
                    self.data
                        .test
                        .row(row + i)
                        .write_dense(&mut xchunk[i * d..(i + 1) * d]);
                    ychunk[i] = self.data.test_y[row + i];
                }
                let c = self
                    .backend
                    .error_counts(&xchunk, &ychunk, rows, d, &w, m)?;
                for (acc, v) in counts.iter_mut().zip(&c) {
                    *acc += *v as f64;
                }
                row += rows;
            }
            let base = mgroup.as_ptr() as usize;
            let _ = base;
            for (j, &_p) in mgroup.iter().enumerate() {
                let idx = eval_peers.iter().position(|&q| q == mgroup[j]).unwrap();
                errs[idx] = counts[j] / n_test as f64;
            }
        }
        Ok(errs)
    }
}

/// Run the batched driver with the given backend.
pub fn run_batched<B: Backend>(
    cfg: ProtocolConfig,
    data: &Dataset,
    backend: &mut B,
) -> Result<RunResult> {
    BatchedSim::new(cfg, data, backend).run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{urls_like, Scale};
    use crate::engine::native::NativeBackend;

    #[test]
    fn batched_native_converges() {
        let ds = urls_like(1, Scale(0.02));
        let mut cfg = ProtocolConfig::paper_default(50);
        cfg.eval.n_peers = 20;
        let mut be = NativeBackend::new();
        let res = run_batched(cfg, &ds, &mut be).unwrap();
        let first = res.curve.points.first().unwrap().err_mean;
        let last = res.curve.final_error();
        assert!(last < first, "{first} -> {last}");
        assert!(last < 0.25, "final {last}");
    }

    #[test]
    fn batched_deterministic() {
        let ds = urls_like(2, Scale(0.01));
        let mk = || {
            let mut cfg = ProtocolConfig::paper_default(15);
            cfg.eval.n_peers = 10;
            cfg
        };
        let mut b1 = NativeBackend::new();
        let mut b2 = NativeBackend::new();
        let a = run_batched(mk(), &ds, &mut b1).unwrap();
        let b = run_batched(mk(), &ds, &mut b2).unwrap();
        let ea: Vec<f64> = a.curve.points.iter().map(|p| p.err_mean).collect();
        let eb: Vec<f64> = b.curve.points.iter().map(|p| p.err_mean).collect();
        assert_eq!(ea, eb);
    }

    #[test]
    fn batched_failures_converge() {
        let ds = urls_like(3, Scale(0.02));
        let mut cfg = ProtocolConfig::paper_default(80).with_extreme_failures();
        cfg.eval.n_peers = 15;
        let mut be = NativeBackend::new();
        let res = run_batched(cfg, &ds, &mut be).unwrap();
        assert!(res.stats.messages_dropped > 0);
        assert!(res.stats.messages_lost_offline > 0);
        let first = res.curve.points.first().unwrap().err_mean;
        assert!(res.curve.final_error() < first);
    }

    #[test]
    fn batched_close_to_event_driven() {
        // same protocol, different scheduling model: final errors must land
        // in the same regime (loose statistical check, not bitwise)
        let ds = urls_like(4, Scale(0.02));
        let mut cfg = ProtocolConfig::paper_default(40);
        cfg.eval.n_peers = 20;
        let ev = crate::gossip::run(cfg.clone(), &ds);
        let mut be = NativeBackend::new();
        let bt = run_batched(cfg, &ds, &mut be).unwrap();
        let (a, b) = (ev.curve.final_error(), bt.curve.final_error());
        assert!((a - b).abs() < 0.08, "event {a} vs batched {b}");
    }
}
