//! Native (pure Rust) batched backend — the written-down semantics of the
//! hot path, mirroring python/compile/kernels/ref.py line for line.
//!
//! Two kernel families (DESIGN.md §7):
//!
//! * **Dense** rows: one pass over all `d` coordinates per update, exactly
//!   ref.py's math (decay computed as `1 - ηλ`).
//! * **Sparse** rows (CSR-staged batches): O(nnz) kernels that mirror the
//!   scalar lazy-scale path of `learning/` op for op — the `1 - ηλ = 1 - 1/t`
//!   decay folds into the per-row scale (O(1), with the shared `SCALE_FLOOR`
//!   re-materialization) and the example touches only non-zero coordinates.

use crate::data::dataset::{sparse_dot, Examples};
use crate::engine::{Backend, LearnerKind, StepBatch, StepOp};
use crate::gossip::create_model::Variant;
use crate::learning::linear::{add_scaled_sparse_in_place, scale_in_place};
use anyhow::Result;

#[derive(Debug, Default)]
pub struct NativeBackend {
    // scratch rows for the UM variant (avoids per-row allocation; perf §L3)
    u1: Vec<f32>,
    u2: Vec<f32>,
}

impl NativeBackend {
    pub fn new() -> Self {
        NativeBackend::default()
    }

    /// ref.py pegasos_update_ref on one row.
    fn pegasos_row(w: &mut [f32], x: &[f32], y: f32, t: &mut f32, lam: f32) {
        *t += 1.0;
        let eta = 1.0 / (lam * *t);
        let margin = y * dot(w, x);
        let decay = 1.0 - eta * lam;
        if margin < 1.0 {
            let c = eta * y;
            for (wi, &xi) in w.iter_mut().zip(x) {
                *wi = decay * *wi + c * xi;
            }
        } else {
            for wi in w.iter_mut() {
                *wi *= decay;
            }
        }
    }

    /// ref.py adaline_update_ref on one row.
    fn adaline_row(w: &mut [f32], x: &[f32], y: f32, t: &mut f32, eta: f32) {
        let err = y - dot(w, x);
        let c = eta * err;
        for (wi, &xi) in w.iter_mut().zip(x) {
            *wi += c * xi;
        }
        *t += 1.0;
    }

    /// ref.py logreg_update_ref on one row (extension learner).
    fn logreg_row(w: &mut [f32], x: &[f32], y: f32, t: &mut f32, lam: f32) {
        *t += 1.0;
        let eta = 1.0 / (lam * *t);
        let p = 1.0 / (1.0 + (-dot(w, x)).exp());
        let y01 = (y + 1.0) * 0.5;
        let decay = 1.0 - eta * lam;
        let c = eta * (y01 - p);
        for (wi, &xi) in w.iter_mut().zip(x) {
            *wi = decay * *wi + c * xi;
        }
    }

    fn update_row(op: &StepOp, w: &mut [f32], x: &[f32], y: f32, t: &mut f32) {
        match op.learner {
            LearnerKind::Pegasos => Self::pegasos_row(w, x, y, t, op.hp),
            LearnerKind::Adaline => Self::adaline_row(w, x, y, t, op.hp),
            LearnerKind::LogReg => Self::logreg_row(w, x, y, t, op.hp),
        }
    }

    /// Pegasos on one lazy-scaled row, O(nnz): mirrors
    /// `learning::pegasos::Pegasos::update` op for op.
    fn pegasos_row_sparse(
        w: &mut [f32],
        s: &mut f32,
        idx: &[u32],
        val: &[f32],
        y: f32,
        t: &mut f32,
        lam: f32,
    ) {
        *t += 1.0;
        let eta = 1.0 / (lam * *t);
        let margin = y * (*s * sparse_dot(idx, val, w));
        scale_in_place(w, s, 1.0 - 1.0 / *t);
        if margin < 1.0 {
            add_scaled_sparse_in_place(w, s, eta * y, idx, val);
        }
    }

    /// Adaline on one lazy-scaled row, O(nnz): mirrors
    /// `learning::adaline::Adaline::update` (no decay; the scale only changes
    /// through the dead-model reset).
    fn adaline_row_sparse(
        w: &mut [f32],
        s: &mut f32,
        idx: &[u32],
        val: &[f32],
        y: f32,
        t: &mut f32,
        eta: f32,
    ) {
        let err = y - *s * sparse_dot(idx, val, w);
        add_scaled_sparse_in_place(w, s, eta * err, idx, val);
        *t += 1.0;
    }

    /// Logistic regression on one lazy-scaled row, O(nnz): mirrors
    /// `learning::logreg::LogReg::update` op for op.
    fn logreg_row_sparse(
        w: &mut [f32],
        s: &mut f32,
        idx: &[u32],
        val: &[f32],
        y: f32,
        t: &mut f32,
        lam: f32,
    ) {
        *t += 1.0;
        let eta = 1.0 / (lam * *t);
        let z = *s * sparse_dot(idx, val, w);
        let p = 1.0 / (1.0 + (-z).exp());
        let y01 = (y + 1.0) * 0.5;
        scale_in_place(w, s, 1.0 - 1.0 / *t);
        add_scaled_sparse_in_place(w, s, eta * (y01 - p), idx, val);
    }

    #[allow(clippy::too_many_arguments)]
    fn update_row_sparse(
        op: &StepOp,
        w: &mut [f32],
        s: &mut f32,
        idx: &[u32],
        val: &[f32],
        y: f32,
        t: &mut f32,
    ) {
        match op.learner {
            LearnerKind::Pegasos => Self::pegasos_row_sparse(w, s, idx, val, y, t, op.hp),
            LearnerKind::Adaline => Self::adaline_row_sparse(w, s, idx, val, y, t, op.hp),
            LearnerKind::LogReg => Self::logreg_row_sparse(w, s, idx, val, y, t, op.hp),
        }
    }

    /// The O(nnz) execution of a CSR-staged batch (see the sparse contract on
    /// [`Backend::step`]): per row, only the scale, the counter, and the
    /// example's non-zero coordinates are touched for RW; the merge variants
    /// additionally pay one O(d) averaging pass (models are dense, so
    /// averaging two of them is inherently O(d)).
    fn step_sparse(&mut self, op: &StepOp, batch: &mut StepBatch) -> Result<()> {
        let (b, d) = (batch.b, batch.d);
        for i in 0..b {
            let r = i * d..(i + 1) * d;
            let (lo, hi) = (batch.x_indptr[i], batch.x_indptr[i + 1]);
            let idx = &batch.x_indices[lo..hi];
            let val = &batch.x_values[lo..hi];
            let y = batch.y[i];
            match op.variant {
                Variant::Rw => {
                    let w = &mut batch.w1[r];
                    let mut s = batch.s1[i];
                    let mut t = batch.t1[i];
                    Self::update_row_sparse(op, w, &mut s, idx, val, y, &mut t);
                    batch.out_s[i] = s;
                    batch.out_t[i] = t;
                }
                Variant::Mu => {
                    // merge in place: w1 <- (s1*w1 + s2*w2)/2, then update
                    let w = &mut batch.w1[r.clone()];
                    let w2 = &batch.w2[r];
                    let (s1, s2) = (batch.s1[i], batch.s2[i]);
                    for (a, &bb) in w.iter_mut().zip(w2) {
                        *a = 0.5 * (s1 * *a + s2 * bb);
                    }
                    let mut s = 1.0f32;
                    let mut t = batch.t1[i].max(batch.t2[i]);
                    Self::update_row_sparse(op, w, &mut s, idx, val, y, &mut t);
                    batch.out_s[i] = s;
                    batch.out_t[i] = t;
                }
                Variant::Um => {
                    // update both rows in place with the same local example,
                    // then average into w1 (w2 is scratch per the contract)
                    let w1 = &mut batch.w1[r.clone()];
                    let mut s1 = batch.s1[i];
                    let mut t1 = batch.t1[i];
                    Self::update_row_sparse(op, w1, &mut s1, idx, val, y, &mut t1);
                    let w2 = &mut batch.w2[r];
                    let mut s2 = batch.s2[i];
                    let mut t2 = batch.t2[i];
                    Self::update_row_sparse(op, w2, &mut s2, idx, val, y, &mut t2);
                    for (a, &bb) in w1.iter_mut().zip(w2.iter()) {
                        *a = 0.5 * (s1 * *a + s2 * bb);
                    }
                    batch.out_s[i] = 1.0;
                    batch.out_t[i] = t1.max(t2);
                }
            }
        }
        Ok(())
    }
}

#[inline]
fn dot(a: &[f32], b: &[f32]) -> f32 {
    crate::data::dataset::dense_dot(a, b)
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn supports_sparse(&self) -> bool {
        true
    }

    fn step(&mut self, op: &StepOp, batch: &mut StepBatch) -> Result<()> {
        if batch.is_sparse_x() {
            return self.step_sparse(op, batch);
        }
        let (b, d) = (batch.b, batch.d);
        for i in 0..b {
            let r = i * d..(i + 1) * d;
            let w1 = &batch.w1[r.clone()];
            let w2 = &batch.w2[r.clone()];
            let x = &batch.x[r.clone()];
            let y = batch.y[i];
            let out_w = &mut batch.out_w[r];
            let out_t = &mut batch.out_t[i];
            match op.variant {
                Variant::Rw => {
                    out_w.copy_from_slice(w1);
                    *out_t = batch.t1[i];
                    Self::update_row(op, out_w, x, y, out_t);
                }
                Variant::Mu => {
                    for (o, (&a, &bb)) in out_w.iter_mut().zip(w1.iter().zip(w2)) {
                        *o = 0.5 * (a + bb);
                    }
                    *out_t = batch.t1[i].max(batch.t2[i]);
                    Self::update_row(op, out_w, x, y, out_t);
                }
                Variant::Um => {
                    // update both with the same local example, then average
                    self.u1.clear();
                    self.u1.extend_from_slice(w1);
                    self.u2.clear();
                    self.u2.extend_from_slice(w2);
                    let mut t1 = batch.t1[i];
                    let mut t2 = batch.t2[i];
                    Self::update_row(op, &mut self.u1, x, y, &mut t1);
                    Self::update_row(op, &mut self.u2, x, y, &mut t2);
                    for (o, (&a, &bb)) in
                        out_w.iter_mut().zip(self.u1.iter().zip(&self.u2))
                    {
                        *o = 0.5 * (a + bb);
                    }
                    *out_t = t1.max(t2);
                }
            }
        }
        Ok(())
    }

    fn error_counts(
        &mut self,
        x: &[f32],
        y: &[f32],
        n: usize,
        d: usize,
        w: &[f32],
        m: usize,
    ) -> Result<Vec<f32>> {
        let mut counts = vec![0.0f32; m];
        for i in 0..n {
            if y[i] == 0.0 {
                continue;
            }
            let xi = &x[i * d..(i + 1) * d];
            for (j, c) in counts.iter_mut().enumerate() {
                let z = dot(&w[j * d..(j + 1) * d], xi);
                if miss(y[i], z) {
                    *c += 1.0;
                }
            }
        }
        Ok(counts)
    }

    /// Sparse-aware batched evaluation: a dense test set is scored zero-copy
    /// straight off its `[n, d]` storage; a sparse one through O(nnz)
    /// sparse dots per (row, model) pair — no chunk densification either way.
    fn error_counts_examples(
        &mut self,
        test: &Examples,
        y: &[f32],
        w: &[f32],
        m: usize,
    ) -> Result<Vec<f32>> {
        let d = test.d();
        match test {
            Examples::Dense(mat) => self.error_counts(mat.as_slice(), y, mat.rows, d, w, m),
            Examples::Sparse(csr) => {
                let mut counts = vec![0.0f32; m];
                for i in 0..csr.rows {
                    if y[i] == 0.0 {
                        continue;
                    }
                    let (idx, val) = csr.row(i);
                    for (j, c) in counts.iter_mut().enumerate() {
                        let z = sparse_dot(idx, val, &w[j * d..(j + 1) * d]);
                        if miss(y[i], z) {
                            *c += 1.0;
                        }
                    }
                }
                Ok(counts)
            }
        }
    }
}

/// The repo-wide 0-1 convention (eval/metrics.rs): sign(0) = -1, so a zero
/// margin errs on positive examples only.
#[inline]
fn miss(y: f32, dot: f32) -> bool {
    if y > 0.0 {
        dot <= 0.0
    } else {
        dot > 0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Row;
    use crate::learning::{Learner, LinearModel};
    use crate::util::rng::Rng;

    fn random_batch(rng: &mut Rng, b: usize, d: usize) -> StepBatch {
        let mut sb = StepBatch::default();
        sb.resize(b, d);
        for v in sb.w1.iter_mut().chain(&mut sb.w2).chain(&mut sb.x) {
            *v = rng.normal() as f32;
        }
        for i in 0..b {
            sb.y[i] = rng.sign();
            sb.t1[i] = rng.below(50) as f32;
            sb.t2[i] = rng.below(50) as f32;
        }
        sb
    }

    /// The batched RW step must match the event-driven LinearModel path.
    #[test]
    fn rw_matches_linear_model_update() {
        let mut rng = Rng::new(3);
        let (b, d) = (16, 9);
        let mut sb = random_batch(&mut rng, b, d);
        let op = StepOp { learner: LearnerKind::Pegasos, variant: Variant::Rw, hp: 0.01 };
        let mut be = NativeBackend::new();
        let learner = Learner::pegasos(0.01);
        let expect: Vec<Vec<f32>> = (0..b)
            .map(|i| {
                let mut m = LinearModel::from_weights(
                    sb.w1[i * d..(i + 1) * d].to_vec(),
                    sb.t1[i] as u64,
                );
                learner.update(&mut m, &Row::Dense(&sb.x[i * d..(i + 1) * d]), sb.y[i]);
                m.weights()
            })
            .collect();
        be.step(&op, &mut sb).unwrap();
        for i in 0..b {
            for (a, e) in sb.out_w[i * d..(i + 1) * d].iter().zip(&expect[i]) {
                assert!((a - e).abs() < 1e-4, "{a} vs {e}");
            }
            assert_eq!(sb.out_t[i], sb.t1[i] + 1.0);
        }
    }

    #[test]
    fn mu_is_merge_then_update() {
        let mut rng = Rng::new(4);
        let (b, d) = (8, 5);
        let mut sb = random_batch(&mut rng, b, d);
        let op = StepOp { learner: LearnerKind::Pegasos, variant: Variant::Mu, hp: 0.1 };
        let snapshot = sb.clone();
        NativeBackend::new().step(&op, &mut sb).unwrap();
        for i in 0..b {
            let mut w: Vec<f32> = (0..d)
                .map(|k| 0.5 * (snapshot.w1[i * d + k] + snapshot.w2[i * d + k]))
                .collect();
            let mut t = snapshot.t1[i].max(snapshot.t2[i]);
            NativeBackend::pegasos_row(&mut w, &snapshot.x[i * d..(i + 1) * d], snapshot.y[i], &mut t, 0.1);
            for (a, e) in sb.out_w[i * d..(i + 1) * d].iter().zip(&w) {
                assert!((a - e).abs() < 1e-5);
            }
            assert_eq!(sb.out_t[i], t);
        }
    }

    #[test]
    fn adaline_um_equals_mu_eq8() {
        // Section V-A: for Adaline the two compositions coincide.
        let mut rng = Rng::new(5);
        let (b, d) = (12, 7);
        let base = random_batch(&mut rng, b, d);
        let mut be = NativeBackend::new();
        let mut mu = base.clone();
        let mut um = base.clone();
        be.step(&StepOp { learner: LearnerKind::Adaline, variant: Variant::Mu, hp: 0.05 }, &mut mu)
            .unwrap();
        be.step(&StepOp { learner: LearnerKind::Adaline, variant: Variant::Um, hp: 0.05 }, &mut um)
            .unwrap();
        for (a, e) in mu.out_w.iter().zip(&um.out_w) {
            assert!((a - e).abs() < 1e-5, "{a} vs {e}");
        }
    }

    #[test]
    fn error_counts_basic() {
        let mut be = NativeBackend::new();
        // 3 test rows (last padded), 2 models
        let x = vec![1.0, 0.0, -1.0, 0.0, 9.0, 9.0];
        let y = vec![1.0, -1.0, 0.0];
        let w = vec![1.0, 0.0, /* model 0: perfect */ -1.0, 0.0 /* model 1: inverted */];
        let c = be.error_counts(&x, &y, 3, 2, &w, 2).unwrap();
        assert_eq!(c, vec![0.0, 2.0]);
    }

    #[test]
    fn error_counts_zero_margin_follows_sign_convention() {
        // sign(0) = -1: the zero model is correct on negatives, wrong on
        // positives — pinned against eval::zero_one_error semantics.
        let mut be = NativeBackend::new();
        let x = vec![1.0, 0.0, -1.0, 0.0];
        let y = vec![1.0, -1.0];
        let w = vec![0.0, 0.0];
        let c = be.error_counts(&x, &y, 2, 2, &w, 1).unwrap();
        assert_eq!(c, vec![1.0]);
    }

    #[test]
    fn sparse_rw_step_exactly_matches_scalar_lazy_scale_path() {
        // The O(nnz) kernels mirror learning/'s lazy-scale ops one for one,
        // so a chained in-place RW run is bit-for-bit the scalar path.
        let mut rng = Rng::new(21);
        let d = 23;
        for (op, learner) in [
            (
                StepOp { learner: LearnerKind::Pegasos, variant: Variant::Rw, hp: 0.05 },
                Learner::pegasos(0.05),
            ),
            (
                StepOp { learner: LearnerKind::Adaline, variant: Variant::Rw, hp: 0.1 },
                Learner::adaline(0.1),
            ),
            (
                StepOp { learner: LearnerKind::LogReg, variant: Variant::Rw, hp: 0.05 },
                Learner::logreg(0.05),
            ),
        ] {
            let mut be = NativeBackend::new();
            let mut sb = StepBatch::default();
            let mut model = LinearModel::zeros(d);
            sb.resize_for(1, d, true);
            for _ in 0..60 {
                let mut idx: Vec<u32> = (0..4).map(|_| rng.below(d as u64) as u32).collect();
                idx.sort_unstable();
                idx.dedup();
                let val: Vec<f32> = idx.iter().map(|_| rng.normal() as f32).collect();
                let y = rng.sign();
                sb.resize_for(1, d, true); // keeps w1/s1/t1, resets the CSR payload
                sb.push_sparse_x_row(&idx, &val);
                sb.y[0] = y;
                be.step(&op, &mut sb).unwrap();
                // chain: carry the in-place result forward as the next input
                sb.s1[0] = sb.out_s[0];
                sb.t1[0] = sb.out_t[0];
                learner.update(&mut model, &Row::Sparse(&idx, &val), y);
            }
            let eff: Vec<f32> = sb.w1.iter().map(|&w| w * sb.s1[0]).collect();
            assert_eq!(eff, model.weights(), "{:?}", op.learner);
            assert_eq!(sb.t1[0], model.t as f32, "{:?}", op.learner);
        }
    }

    #[test]
    fn error_counts_examples_sparse_matches_dense_storage() {
        use crate::data::matrix::Matrix;
        use crate::data::sparse::Csr;
        let mut rng = Rng::new(22);
        let (n, d, m) = (40, 12, 5);
        let mut csr = Csr::new(d);
        let mut dense = vec![0.0f32; n * d];
        for i in 0..n {
            let mut entries: Vec<(u32, f32)> = Vec::new();
            for j in 0..d {
                if rng.below(3) == 0 {
                    let v = rng.normal() as f32;
                    entries.push((j as u32, v));
                    dense[i * d + j] = v;
                }
            }
            csr.push_row(&entries);
        }
        let y: Vec<f32> = (0..n).map(|_| rng.sign()).collect();
        let w: Vec<f32> = (0..m * d).map(|_| rng.normal() as f32).collect();
        let mut be = NativeBackend::new();
        let ds = Examples::Dense(Matrix::from_vec(n, d, dense));
        let sp = Examples::Sparse(csr);
        let a = be.error_counts_examples(&ds, &y, &w, m).unwrap();
        let b = be.error_counts_examples(&sp, &y, &w, m).unwrap();
        assert_eq!(a, b);
    }
}
