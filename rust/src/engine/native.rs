//! Native (pure Rust) batched backend — the written-down semantics of the
//! hot path, mirroring python/compile/kernels/ref.py line for line.
//!
//! Two kernel families (DESIGN.md §7):
//!
//! * **Dense** rows: one pass over all `d` coordinates per update, exactly
//!   ref.py's math (decay computed as `1 - ηλ`).
//! * **Sparse** rows (CSR-staged batches): O(nnz) kernels that mirror the
//!   scalar lazy-scale path of `learning/` op for op — the `1 - ηλ = 1 - 1/t`
//!   decay folds into the per-row scale (O(1), with the shared `SCALE_FLOOR`
//!   re-materialization) and the example touches only non-zero coordinates.

use crate::data::dataset::{sparse_dot, Examples};
use crate::engine::{Backend, LearnerKind, StepBatch, StepOp, PAR_MIN_WORK, PAR_ROWS_MIN};
use crate::gossip::create_model::Variant;
use crate::learning::linear::{add_scaled_sparse_in_place, scale_in_place};
use crate::learning::pairwise::{dense_pair_diff, quorum_coord, sparse_pair_diff};
use crate::learning::MergeMode;
use crate::util::threads;
use anyhow::Result;

/// Chunk view of a staged reservoir pair payload (DESIGN.md §17).
/// `indptr` is the chunk's `rows + 1` window of **absolute** per-row entry
/// offsets; the entry buffers are the full payload, shared read-only across
/// chunks — exactly the CSR-window convention of the sparse example payload.
#[derive(Clone, Copy)]
struct PairSlices<'a> {
    indptr: &'a [usize],
    /// dense layout: `[n_entries, d]` partner rows
    dense: &'a [f32],
    /// sparse layout: CSR over partner entries
    x_indptr: &'a [usize],
    indices: &'a [u32],
    values: &'a [f32],
}

/// MERGE of one coordinate pair of effective weights: the paper's average,
/// or the quorum vote (agreeing signs average, disagreements abstain).
#[inline]
fn combine(merge: MergeMode, a: f32, b: f32) -> f32 {
    match merge {
        MergeMode::Average => 0.5 * (a + b),
        MergeMode::Quorum => quorum_coord(a, b),
    }
}

#[derive(Debug, Default)]
pub struct NativeBackend {
    // scratch rows for the UM variant (avoids per-row allocation; perf §L3)
    u1: Vec<f32>,
    u2: Vec<f32>,
}

impl NativeBackend {
    pub fn new() -> Self {
        NativeBackend::default()
    }

    /// ref.py pegasos_update_ref on one row.
    fn pegasos_row(w: &mut [f32], x: &[f32], y: f32, t: &mut f32, lam: f32) {
        *t += 1.0;
        let eta = 1.0 / (lam * *t);
        let margin = y * dot(w, x);
        let decay = 1.0 - eta * lam;
        if margin < 1.0 {
            let c = eta * y;
            for (wi, &xi) in w.iter_mut().zip(x) {
                *wi = decay * *wi + c * xi;
            }
        } else {
            for wi in w.iter_mut() {
                *wi *= decay;
            }
        }
    }

    /// ref.py adaline_update_ref on one row.
    fn adaline_row(w: &mut [f32], x: &[f32], y: f32, t: &mut f32, eta: f32) {
        let err = y - dot(w, x);
        let c = eta * err;
        for (wi, &xi) in w.iter_mut().zip(x) {
            *wi += c * xi;
        }
        *t += 1.0;
    }

    /// ref.py logreg_update_ref on one row (extension learner).
    fn logreg_row(w: &mut [f32], x: &[f32], y: f32, t: &mut f32, lam: f32) {
        *t += 1.0;
        let eta = 1.0 / (lam * *t);
        let p = 1.0 / (1.0 + (-dot(w, x)).exp());
        let y01 = (y + 1.0) * 0.5;
        let decay = 1.0 - eta * lam;
        let c = eta * (y01 - p);
        for (wi, &xi) in w.iter_mut().zip(x) {
            *wi = decay * *wi + c * xi;
        }
    }

    fn update_row(op: &StepOp, w: &mut [f32], x: &[f32], y: f32, t: &mut f32) {
        match op.learner {
            LearnerKind::Pegasos => Self::pegasos_row(w, x, y, t, op.hp),
            LearnerKind::Adaline => Self::adaline_row(w, x, y, t, op.hp),
            LearnerKind::LogReg => Self::logreg_row(w, x, y, t, op.hp),
            LearnerKind::PairwiseAuc => {
                unreachable!("pairwise steps route through apply_update_dense")
            }
        }
    }

    /// One row's UPDATE, dense layout: pointwise learners take the usual
    /// `(x, y)` step; the pairwise learner takes one Pegasos hinge step on
    /// `z = y (x − x_j)` with implicit label +1 per staged partner (row `i`
    /// of the chunk's pair window).  An empty pair range is a complete
    /// no-op — no decay, no `t` bump — mirroring
    /// `pairwise::PairwiseAuc::update_with_reservoir`.
    #[allow(clippy::too_many_arguments)]
    fn apply_update_dense(
        op: &StepOp,
        w: &mut [f32],
        x: &[f32],
        y: f32,
        t: &mut f32,
        i: usize,
        pairs: &Option<PairSlices<'_>>,
        z: &mut Vec<f32>,
    ) {
        if op.learner != LearnerKind::PairwiseAuc {
            Self::update_row(op, w, x, y, t);
            return;
        }
        let p = pairs.as_ref().expect("pairwise op needs a staged pair payload");
        let d = x.len();
        for e in p.indptr[i]..p.indptr[i + 1] {
            let xj = &p.dense[e * d..(e + 1) * d];
            dense_pair_diff(y, x, xj, z);
            Self::pegasos_row(w, z, 1.0, t, op.hp);
        }
    }

    /// One row's UPDATE, sparse layout: the pairwise learner merges the two
    /// sorted index lists into a sparse difference row `z = y (x − x_j)` —
    /// O(nnz(x) + nnz(x_j)) — and takes one lazy-scale Pegasos step per
    /// staged partner.
    #[allow(clippy::too_many_arguments)]
    fn apply_update_sparse(
        op: &StepOp,
        w: &mut [f32],
        s: &mut f32,
        idx: &[u32],
        val: &[f32],
        y: f32,
        t: &mut f32,
        i: usize,
        pairs: &Option<PairSlices<'_>>,
        zidx: &mut Vec<u32>,
        zval: &mut Vec<f32>,
    ) {
        if op.learner != LearnerKind::PairwiseAuc {
            Self::update_row_sparse(op, w, s, idx, val, y, t);
            return;
        }
        let p = pairs.as_ref().expect("pairwise op needs a staged pair payload");
        for e in p.indptr[i]..p.indptr[i + 1] {
            let (lo, hi) = (p.x_indptr[e], p.x_indptr[e + 1]);
            sparse_pair_diff(y, idx, val, &p.indices[lo..hi], &p.values[lo..hi], zidx, zval);
            Self::pegasos_row_sparse(w, s, zidx, zval, 1.0, t, op.hp);
        }
    }

    /// Pegasos on one lazy-scaled row, O(nnz): mirrors
    /// `learning::pegasos::Pegasos::update` op for op.
    fn pegasos_row_sparse(
        w: &mut [f32],
        s: &mut f32,
        idx: &[u32],
        val: &[f32],
        y: f32,
        t: &mut f32,
        lam: f32,
    ) {
        *t += 1.0;
        let eta = 1.0 / (lam * *t);
        let margin = y * (*s * sparse_dot(idx, val, w));
        scale_in_place(w, s, 1.0 - 1.0 / *t);
        if margin < 1.0 {
            add_scaled_sparse_in_place(w, s, eta * y, idx, val);
        }
    }

    /// Adaline on one lazy-scaled row, O(nnz): mirrors
    /// `learning::adaline::Adaline::update` (no decay; the scale only changes
    /// through the dead-model reset).
    fn adaline_row_sparse(
        w: &mut [f32],
        s: &mut f32,
        idx: &[u32],
        val: &[f32],
        y: f32,
        t: &mut f32,
        eta: f32,
    ) {
        let err = y - *s * sparse_dot(idx, val, w);
        add_scaled_sparse_in_place(w, s, eta * err, idx, val);
        *t += 1.0;
    }

    /// Logistic regression on one lazy-scaled row, O(nnz): mirrors
    /// `learning::logreg::LogReg::update` op for op.
    fn logreg_row_sparse(
        w: &mut [f32],
        s: &mut f32,
        idx: &[u32],
        val: &[f32],
        y: f32,
        t: &mut f32,
        lam: f32,
    ) {
        *t += 1.0;
        let eta = 1.0 / (lam * *t);
        let z = *s * sparse_dot(idx, val, w);
        let p = 1.0 / (1.0 + (-z).exp());
        let y01 = (y + 1.0) * 0.5;
        scale_in_place(w, s, 1.0 - 1.0 / *t);
        add_scaled_sparse_in_place(w, s, eta * (y01 - p), idx, val);
    }

    #[allow(clippy::too_many_arguments)]
    fn update_row_sparse(
        op: &StepOp,
        w: &mut [f32],
        s: &mut f32,
        idx: &[u32],
        val: &[f32],
        y: f32,
        t: &mut f32,
    ) {
        match op.learner {
            LearnerKind::Pegasos => Self::pegasos_row_sparse(w, s, idx, val, y, t, op.hp),
            LearnerKind::Adaline => Self::adaline_row_sparse(w, s, idx, val, y, t, op.hp),
            LearnerKind::LogReg => Self::logreg_row_sparse(w, s, idx, val, y, t, op.hp),
            LearnerKind::PairwiseAuc => {
                unreachable!("pairwise steps route through apply_update_sparse")
            }
        }
    }

    /// The O(nnz) execution of a CSR-staged batch (see the sparse contract on
    /// [`Backend::step`]): per row, only the scale, the counter, and the
    /// example's non-zero coordinates are touched for RW; the merge variants
    /// additionally pay one O(d) averaging pass (models are dense, so
    /// averaging two of them is inherently O(d)).  Large batches split into
    /// contiguous row chunks on leased threads — rows are independent, so
    /// the result is bit-for-bit the serial loop's.
    fn step_sparse(&mut self, op: &StepOp, batch: &mut StepBatch) -> Result<()> {
        if op.learner == LearnerKind::PairwiseAuc && !batch.has_pairs() {
            anyhow::bail!("pairwise op on a batch without a staged pair payload");
        }
        let (b, d) = (batch.b, batch.d);
        let StepBatch {
            w1,
            w2,
            s1,
            s2,
            t1,
            t2,
            y,
            out_s,
            out_t,
            x_indptr,
            x_indices,
            x_values,
            pair_indptr,
            pair_x,
            pair_x_indptr,
            pair_x_indices,
            pair_x_values,
            ..
        } = batch;
        let (s1, s2, t1, t2, y) = (&s1[..], &s2[..], &t1[..], &t2[..], &y[..]);
        let (indptr, indices, values) = (&x_indptr[..], &x_indices[..], &x_values[..]);
        let pair_payload = (op.learner == LearnerKind::PairwiseAuc).then_some((
            &pair_indptr[..],
            &pair_x[..],
            &pair_x_indptr[..],
            &pair_x_indices[..],
            &pair_x_values[..],
        ));
        let window = |row0: usize, rows: usize| {
            pair_payload.map(|(pi, pd, pxi, pxn, pxv)| PairSlices {
                indptr: &pi[row0..row0 + rows + 1],
                dense: pd,
                x_indptr: pxi,
                indices: pxn,
                values: pxv,
            })
        };
        let want = par_extra_chunks(b, d);
        let lease = (want > 0).then(|| threads::lease(want));
        let workers = 1 + lease.as_ref().map_or(0, |l| l.granted());
        if workers <= 1 {
            // serial (the common path, and the drained-budget degradation)
            step_rows_sparse(
                op,
                d,
                w1,
                w2,
                s1,
                s2,
                t1,
                t2,
                y,
                indptr,
                indices,
                values,
                window(0, b),
                out_s,
                out_t,
            );
            return Ok(());
        }
        let rows_per = b.div_ceil(workers);
        let mut chunks: Vec<(usize, &mut [f32], &mut [f32], &mut [f32], &mut [f32])> = w1
            .chunks_mut(rows_per * d)
            .zip(w2.chunks_mut(rows_per * d))
            .zip(out_s.chunks_mut(rows_per).zip(out_t.chunks_mut(rows_per)))
            .enumerate()
            .map(|(k, ((w1c, w2c), (osc, otc)))| (k * rows_per, w1c, w2c, osc, otc))
            .collect();
        std::thread::scope(|scope| {
            let head = chunks.remove(0);
            for (row0, w1c, w2c, osc, otc) in chunks {
                let pw = window(row0, otc.len());
                scope.spawn(move || {
                    let rows = otc.len();
                    step_rows_sparse(
                        op,
                        d,
                        w1c,
                        w2c,
                        &s1[row0..row0 + rows],
                        &s2[row0..row0 + rows],
                        &t1[row0..row0 + rows],
                        &t2[row0..row0 + rows],
                        &y[row0..row0 + rows],
                        // indptr offsets are absolute into the full payload
                        &indptr[row0..row0 + rows + 1],
                        indices,
                        values,
                        pw,
                        osc,
                        otc,
                    );
                });
            }
            let (row0, w1c, w2c, osc, otc) = head;
            let rows = otc.len();
            step_rows_sparse(
                op,
                d,
                w1c,
                w2c,
                &s1[row0..row0 + rows],
                &s2[row0..row0 + rows],
                &t1[row0..row0 + rows],
                &t2[row0..row0 + rows],
                &y[row0..row0 + rows],
                &indptr[row0..row0 + rows + 1],
                indices,
                values,
                window(row0, rows),
                osc,
                otc,
            );
        });
        Ok(())
    }
}

/// Extra threads worth leasing for a `b x d` step (0 = stay serial): the
/// batch must split into at least two [`PAR_ROWS_MIN`]-row chunks and carry
/// [`PAR_MIN_WORK`] total coordinates before the spawn cost can pay off.
fn par_extra_chunks(b: usize, d: usize) -> usize {
    if b >= 2 * PAR_ROWS_MIN && b.saturating_mul(d) >= PAR_MIN_WORK {
        b / PAR_ROWS_MIN - 1
    } else {
        0
    }
}

/// One contiguous chunk of dense `StepBatch` rows (`y.len()` of them).
/// Free function over disjoint slices so chunks can run on leased threads;
/// `u1`/`u2` are caller-provided UM scratch, one pair per thread.  The
/// per-row math is exactly the serial loop's, so chunked == serial
/// bit-for-bit.
#[allow(clippy::too_many_arguments)]
fn step_rows_dense(
    op: &StepOp,
    d: usize,
    w1: &[f32],
    t1: &[f32],
    w2: &[f32],
    t2: &[f32],
    x: &[f32],
    y: &[f32],
    pairs: Option<PairSlices<'_>>,
    out_w: &mut [f32],
    out_t: &mut [f32],
    u1: &mut Vec<f32>,
    u2: &mut Vec<f32>,
) {
    // pairwise difference-row scratch, reused across the chunk's rows
    let mut z: Vec<f32> = Vec::new();
    for i in 0..y.len() {
        let r = i * d..(i + 1) * d;
        let w1r = &w1[r.clone()];
        let w2r = &w2[r.clone()];
        let xr = &x[r.clone()];
        let yi = y[i];
        let out_wr = &mut out_w[r];
        let out_ti = &mut out_t[i];
        match op.variant {
            Variant::Rw => {
                out_wr.copy_from_slice(w1r);
                *out_ti = t1[i];
                NativeBackend::apply_update_dense(op, out_wr, xr, yi, out_ti, i, &pairs, &mut z);
            }
            Variant::Mu => {
                for (o, (&a, &bb)) in out_wr.iter_mut().zip(w1r.iter().zip(w2r)) {
                    *o = combine(op.merge, a, bb);
                }
                *out_ti = t1[i].max(t2[i]);
                NativeBackend::apply_update_dense(op, out_wr, xr, yi, out_ti, i, &pairs, &mut z);
            }
            Variant::Um => {
                // update both with the same local example, then combine
                u1.clear();
                u1.extend_from_slice(w1r);
                u2.clear();
                u2.extend_from_slice(w2r);
                let mut t1i = t1[i];
                let mut t2i = t2[i];
                NativeBackend::apply_update_dense(op, u1, xr, yi, &mut t1i, i, &pairs, &mut z);
                NativeBackend::apply_update_dense(op, u2, xr, yi, &mut t2i, i, &pairs, &mut z);
                for (o, (&a, &bb)) in out_wr.iter_mut().zip(u1.iter().zip(u2.iter())) {
                    *o = combine(op.merge, a, bb);
                }
                *out_ti = t1i.max(t2i);
            }
        }
    }
}

/// One contiguous chunk of CSR-staged `StepBatch` rows.  `indptr` is the
/// chunk's `rows + 1` window of **absolute** offsets into the full
/// `indices`/`values` payload slices (shared read-only across chunks);
/// `w1`/`w2`/`out_s`/`out_t` are the chunk's disjoint mutable slices.
#[allow(clippy::too_many_arguments)]
fn step_rows_sparse(
    op: &StepOp,
    d: usize,
    w1: &mut [f32],
    w2: &mut [f32],
    s1: &[f32],
    s2: &[f32],
    t1: &[f32],
    t2: &[f32],
    y: &[f32],
    indptr: &[usize],
    indices: &[u32],
    values: &[f32],
    pairs: Option<PairSlices<'_>>,
    out_s: &mut [f32],
    out_t: &mut [f32],
) {
    // pairwise merged-difference scratch, reused across the chunk's rows
    let (mut zidx, mut zval): (Vec<u32>, Vec<f32>) = (Vec::new(), Vec::new());
    for i in 0..y.len() {
        let r = i * d..(i + 1) * d;
        let (lo, hi) = (indptr[i], indptr[i + 1]);
        let idx = &indices[lo..hi];
        let val = &values[lo..hi];
        let yi = y[i];
        match op.variant {
            Variant::Rw => {
                let w = &mut w1[r];
                let mut s = s1[i];
                let mut t = t1[i];
                NativeBackend::apply_update_sparse(
                    op, w, &mut s, idx, val, yi, &mut t, i, &pairs, &mut zidx, &mut zval,
                );
                out_s[i] = s;
                out_t[i] = t;
            }
            Variant::Mu => {
                // merge in place: w1 <- combine(s1*w1, s2*w2), then update
                let w = &mut w1[r.clone()];
                let w2r = &w2[r];
                let (s1i, s2i) = (s1[i], s2[i]);
                for (a, &bb) in w.iter_mut().zip(w2r) {
                    *a = combine(op.merge, s1i * *a, s2i * bb);
                }
                let mut s = 1.0f32;
                let mut t = t1[i].max(t2[i]);
                NativeBackend::apply_update_sparse(
                    op, w, &mut s, idx, val, yi, &mut t, i, &pairs, &mut zidx, &mut zval,
                );
                out_s[i] = s;
                out_t[i] = t;
            }
            Variant::Um => {
                // update both rows in place with the same local example,
                // then combine into w1 (w2 is scratch per the contract)
                let w1r = &mut w1[r.clone()];
                let mut s1i = s1[i];
                let mut t1i = t1[i];
                NativeBackend::apply_update_sparse(
                    op, w1r, &mut s1i, idx, val, yi, &mut t1i, i, &pairs, &mut zidx, &mut zval,
                );
                let w2r = &mut w2[r];
                let mut s2i = s2[i];
                let mut t2i = t2[i];
                NativeBackend::apply_update_sparse(
                    op, w2r, &mut s2i, idx, val, yi, &mut t2i, i, &pairs, &mut zidx, &mut zval,
                );
                for (a, &bb) in w1r.iter_mut().zip(w2r.iter()) {
                    *a = combine(op.merge, s1i * *a, s2i * bb);
                }
                out_s[i] = 1.0;
                out_t[i] = t1i.max(t2i);
            }
        }
    }
}

#[inline]
fn dot(a: &[f32], b: &[f32]) -> f32 {
    crate::data::dataset::dense_dot(a, b)
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn supports_sparse(&self) -> bool {
        true
    }

    fn step(&mut self, op: &StepOp, batch: &mut StepBatch) -> Result<()> {
        if batch.is_sparse_x() {
            return self.step_sparse(op, batch);
        }
        if op.learner == LearnerKind::PairwiseAuc && !batch.has_pairs() {
            anyhow::bail!("pairwise op on a batch without a staged pair payload");
        }
        let (b, d) = (batch.b, batch.d);
        let StepBatch { w1, w2, x, y, t1, t2, out_w, out_t, pair_indptr, pair_x, .. } = batch;
        let (w1, w2, x, y, t1, t2) = (&w1[..], &w2[..], &x[..], &y[..], &t1[..], &t2[..]);
        let pair_payload = (op.learner == LearnerKind::PairwiseAuc)
            .then_some((&pair_indptr[..], &pair_x[..]));
        let window = |row0: usize, rows: usize| {
            pair_payload.map(|(pi, pd)| PairSlices {
                indptr: &pi[row0..row0 + rows + 1],
                dense: pd,
                x_indptr: &[],
                indices: &[],
                values: &[],
            })
        };
        let want = par_extra_chunks(b, d);
        let lease = (want > 0).then(|| threads::lease(want));
        let workers = 1 + lease.as_ref().map_or(0, |l| l.granted());
        if workers <= 1 {
            // serial (the common path, and the drained-budget degradation)
            step_rows_dense(
                op,
                d,
                w1,
                t1,
                w2,
                t2,
                x,
                y,
                window(0, b),
                out_w,
                out_t,
                &mut self.u1,
                &mut self.u2,
            );
            return Ok(());
        }
        let rows_per = b.div_ceil(workers);
        let mut chunks: Vec<(usize, &mut [f32], &mut [f32])> = out_w
            .chunks_mut(rows_per * d)
            .zip(out_t.chunks_mut(rows_per))
            .enumerate()
            .map(|(k, (owc, otc))| (k * rows_per, owc, otc))
            .collect();
        std::thread::scope(|scope| {
            let head = chunks.remove(0);
            for (row0, owc, otc) in chunks {
                let pw = window(row0, otc.len());
                scope.spawn(move || {
                    let rows = otc.len();
                    // spawned chunks carry their own UM scratch pair
                    let (mut u1, mut u2) = (Vec::new(), Vec::new());
                    step_rows_dense(
                        op,
                        d,
                        &w1[row0 * d..(row0 + rows) * d],
                        &t1[row0..row0 + rows],
                        &w2[row0 * d..(row0 + rows) * d],
                        &t2[row0..row0 + rows],
                        &x[row0 * d..(row0 + rows) * d],
                        &y[row0..row0 + rows],
                        pw,
                        owc,
                        otc,
                        &mut u1,
                        &mut u2,
                    );
                });
            }
            let (row0, owc, otc) = head;
            let rows = otc.len();
            step_rows_dense(
                op,
                d,
                &w1[row0 * d..(row0 + rows) * d],
                &t1[row0..row0 + rows],
                &w2[row0 * d..(row0 + rows) * d],
                &t2[row0..row0 + rows],
                &x[row0 * d..(row0 + rows) * d],
                &y[row0..row0 + rows],
                window(row0, rows),
                owc,
                otc,
                &mut self.u1,
                &mut self.u2,
            );
        });
        Ok(())
    }

    fn error_counts(
        &mut self,
        x: &[f32],
        y: &[f32],
        n: usize,
        d: usize,
        w: &[f32],
        m: usize,
    ) -> Result<Vec<f32>> {
        let mut counts = vec![0.0f32; m];
        for i in 0..n {
            if y[i] == 0.0 {
                continue;
            }
            let xi = &x[i * d..(i + 1) * d];
            for (j, c) in counts.iter_mut().enumerate() {
                let z = dot(&w[j * d..(j + 1) * d], xi);
                if miss(y[i], z) {
                    *c += 1.0;
                }
            }
        }
        Ok(counts)
    }

    /// Sparse-aware batched evaluation: a dense test set is scored zero-copy
    /// straight off its `[n, d]` storage; a sparse one through O(nnz)
    /// sparse dots per (row, model) pair — no chunk densification either way.
    fn error_counts_examples(
        &mut self,
        test: &Examples,
        y: &[f32],
        w: &[f32],
        m: usize,
    ) -> Result<Vec<f32>> {
        let d = test.d();
        match test {
            Examples::Dense(mat) => self.error_counts(mat.as_slice(), y, mat.rows, d, w, m),
            Examples::Sparse(csr) => {
                let mut counts = vec![0.0f32; m];
                for i in 0..csr.rows {
                    if y[i] == 0.0 {
                        continue;
                    }
                    let (idx, val) = csr.row(i);
                    for (j, c) in counts.iter_mut().enumerate() {
                        let z = sparse_dot(idx, val, &w[j * d..(j + 1) * d]);
                        if miss(y[i], z) {
                            *c += 1.0;
                        }
                    }
                }
                Ok(counts)
            }
        }
    }
}

/// The repo-wide 0-1 convention (eval/metrics.rs): sign(0) = -1, so a zero
/// margin errs on positive examples only.
#[inline]
fn miss(y: f32, dot: f32) -> bool {
    if y > 0.0 {
        dot <= 0.0
    } else {
        dot > 0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Row;
    use crate::learning::{Learner, LinearModel};
    use crate::util::rng::Rng;

    fn random_batch(rng: &mut Rng, b: usize, d: usize) -> StepBatch {
        let mut sb = StepBatch::default();
        sb.resize(b, d);
        for v in sb.w1.iter_mut().chain(&mut sb.w2).chain(&mut sb.x) {
            *v = rng.normal() as f32;
        }
        for i in 0..b {
            sb.y[i] = rng.sign();
            sb.t1[i] = rng.below(50) as f32;
            sb.t2[i] = rng.below(50) as f32;
        }
        sb
    }

    /// The batched RW step must match the event-driven LinearModel path.
    #[test]
    fn rw_matches_linear_model_update() {
        let mut rng = Rng::new(3);
        let (b, d) = (16, 9);
        let mut sb = random_batch(&mut rng, b, d);
        let op = StepOp { learner: LearnerKind::Pegasos, variant: Variant::Rw, merge: MergeMode::Average, hp: 0.01 };
        let mut be = NativeBackend::new();
        let learner = Learner::pegasos(0.01);
        let expect: Vec<Vec<f32>> = (0..b)
            .map(|i| {
                let mut m = LinearModel::from_weights(
                    sb.w1[i * d..(i + 1) * d].to_vec(),
                    sb.t1[i] as u64,
                );
                learner.update(&mut m, &Row::Dense(&sb.x[i * d..(i + 1) * d]), sb.y[i]);
                m.weights()
            })
            .collect();
        be.step(&op, &mut sb).unwrap();
        for i in 0..b {
            for (a, e) in sb.out_w[i * d..(i + 1) * d].iter().zip(&expect[i]) {
                assert!((a - e).abs() < 1e-4, "{a} vs {e}");
            }
            assert_eq!(sb.out_t[i], sb.t1[i] + 1.0);
        }
    }

    #[test]
    fn mu_is_merge_then_update() {
        let mut rng = Rng::new(4);
        let (b, d) = (8, 5);
        let mut sb = random_batch(&mut rng, b, d);
        let op = StepOp { learner: LearnerKind::Pegasos, variant: Variant::Mu, merge: MergeMode::Average, hp: 0.1 };
        let snapshot = sb.clone();
        NativeBackend::new().step(&op, &mut sb).unwrap();
        for i in 0..b {
            let mut w: Vec<f32> = (0..d)
                .map(|k| 0.5 * (snapshot.w1[i * d + k] + snapshot.w2[i * d + k]))
                .collect();
            let mut t = snapshot.t1[i].max(snapshot.t2[i]);
            NativeBackend::pegasos_row(&mut w, &snapshot.x[i * d..(i + 1) * d], snapshot.y[i], &mut t, 0.1);
            for (a, e) in sb.out_w[i * d..(i + 1) * d].iter().zip(&w) {
                assert!((a - e).abs() < 1e-5);
            }
            assert_eq!(sb.out_t[i], t);
        }
    }

    #[test]
    fn adaline_um_equals_mu_eq8() {
        // Section V-A: for Adaline the two compositions coincide.
        let mut rng = Rng::new(5);
        let (b, d) = (12, 7);
        let base = random_batch(&mut rng, b, d);
        let mut be = NativeBackend::new();
        let mut mu = base.clone();
        let mut um = base.clone();
        be.step(&StepOp { learner: LearnerKind::Adaline, variant: Variant::Mu, merge: MergeMode::Average, hp: 0.05 }, &mut mu)
            .unwrap();
        be.step(&StepOp { learner: LearnerKind::Adaline, variant: Variant::Um, merge: MergeMode::Average, hp: 0.05 }, &mut um)
            .unwrap();
        for (a, e) in mu.out_w.iter().zip(&um.out_w) {
            assert!((a - e).abs() < 1e-5, "{a} vs {e}");
        }
    }

    #[test]
    fn error_counts_basic() {
        let mut be = NativeBackend::new();
        // 3 test rows (last padded), 2 models
        let x = vec![1.0, 0.0, -1.0, 0.0, 9.0, 9.0];
        let y = vec![1.0, -1.0, 0.0];
        let w = vec![1.0, 0.0, /* model 0: perfect */ -1.0, 0.0 /* model 1: inverted */];
        let c = be.error_counts(&x, &y, 3, 2, &w, 2).unwrap();
        assert_eq!(c, vec![0.0, 2.0]);
    }

    #[test]
    fn error_counts_zero_margin_follows_sign_convention() {
        // sign(0) = -1: the zero model is correct on negatives, wrong on
        // positives — pinned against eval::zero_one_error semantics.
        let mut be = NativeBackend::new();
        let x = vec![1.0, 0.0, -1.0, 0.0];
        let y = vec![1.0, -1.0];
        let w = vec![0.0, 0.0];
        let c = be.error_counts(&x, &y, 2, 2, &w, 1).unwrap();
        assert_eq!(c, vec![1.0]);
    }

    #[test]
    fn sparse_rw_step_exactly_matches_scalar_lazy_scale_path() {
        // The O(nnz) kernels mirror learning/'s lazy-scale ops one for one,
        // so a chained in-place RW run is bit-for-bit the scalar path.
        let mut rng = Rng::new(21);
        let d = 23;
        for (op, learner) in [
            (
                StepOp { learner: LearnerKind::Pegasos, variant: Variant::Rw, merge: MergeMode::Average, hp: 0.05 },
                Learner::pegasos(0.05),
            ),
            (
                StepOp { learner: LearnerKind::Adaline, variant: Variant::Rw, merge: MergeMode::Average, hp: 0.1 },
                Learner::adaline(0.1),
            ),
            (
                StepOp { learner: LearnerKind::LogReg, variant: Variant::Rw, merge: MergeMode::Average, hp: 0.05 },
                Learner::logreg(0.05),
            ),
        ] {
            let mut be = NativeBackend::new();
            let mut sb = StepBatch::default();
            let mut model = LinearModel::zeros(d);
            sb.resize_for(1, d, true);
            for _ in 0..60 {
                let mut idx: Vec<u32> = (0..4).map(|_| rng.below(d as u64) as u32).collect();
                idx.sort_unstable();
                idx.dedup();
                let val: Vec<f32> = idx.iter().map(|_| rng.normal() as f32).collect();
                let y = rng.sign();
                sb.resize_for(1, d, true); // keeps w1/s1/t1, resets the CSR payload
                sb.push_sparse_x_row(&idx, &val);
                sb.y[0] = y;
                be.step(&op, &mut sb).unwrap();
                // chain: carry the in-place result forward as the next input
                sb.s1[0] = sb.out_s[0];
                sb.t1[0] = sb.out_t[0];
                learner.update(&mut model, &Row::Sparse(&idx, &val), y);
            }
            let eff: Vec<f32> = sb.w1.iter().map(|&w| w * sb.s1[0]).collect();
            assert_eq!(eff, model.weights(), "{:?}", op.learner);
            assert_eq!(sb.t1[0], model.t as f32, "{:?}", op.learner);
        }
    }

    #[test]
    fn error_counts_examples_sparse_matches_dense_storage() {
        use crate::data::matrix::Matrix;
        use crate::data::sparse::Csr;
        let mut rng = Rng::new(22);
        let (n, d, m) = (40, 12, 5);
        let mut csr = Csr::new(d);
        let mut dense = vec![0.0f32; n * d];
        for i in 0..n {
            let mut entries: Vec<(u32, f32)> = Vec::new();
            for j in 0..d {
                if rng.below(3) == 0 {
                    let v = rng.normal() as f32;
                    entries.push((j as u32, v));
                    dense[i * d + j] = v;
                }
            }
            csr.push_row(&entries);
        }
        let y: Vec<f32> = (0..n).map(|_| rng.sign()).collect();
        let w: Vec<f32> = (0..m * d).map(|_| rng.normal() as f32).collect();
        let mut be = NativeBackend::new();
        let ds = Examples::Dense(Matrix::from_vec(n, d, dense));
        let sp = Examples::Sparse(csr);
        let a = be.error_counts_examples(&ds, &y, &w, m).unwrap();
        let b = be.error_counts_examples(&sp, &y, &w, m).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn pairwise_dense_rw_matches_scalar_reference() {
        use crate::data::matrix::Matrix;
        use crate::learning::pairwise::{self, PairScratch, PairwiseAuc};
        let mut rng = Rng::new(33);
        let (d, lam) = (6, 0.05f32);
        // a tiny "training set" the reservoir points into
        let n = 10;
        let rows: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let mut labels: Vec<f32> = (0..n).map(|_| rng.sign()).collect();
        labels[0] = -1.0; // guarantee an opposite-class partner for node 7
        labels[7] = 1.0;
        let train = Examples::Dense(Matrix::from_vec(n, d, rows));
        // fill phase only (4 offers into capacity 4): all entries retained
        let mut res = pairwise::reservoir_new(4);
        for node in 0..4u32 {
            pairwise::offer(&mut res, node, labels[node as usize], rng.next_u64());
        }
        let (x_local, y_local) = (train.row(7).to_dense(d), labels[7]);
        // scalar reference
        let mut model = LinearModel::from_weights(
            (0..d).map(|_| rng.normal() as f32).collect::<Vec<_>>(),
            3,
        );
        let w0 = model.weights();
        let mut scratch = PairScratch::default();
        PairwiseAuc::new(lam).update_with_reservoir(
            &mut model,
            &Row::Dense(&x_local),
            y_local,
            &res,
            &train,
            &mut scratch,
        );
        // engine path: one RW row with the same staged partners
        let mut sb = StepBatch::default();
        sb.resize(1, d);
        sb.w1.copy_from_slice(&w0);
        sb.t1[0] = 3.0;
        sb.x.copy_from_slice(&x_local);
        sb.y[0] = y_local;
        sb.begin_pair_rows();
        for (node, yj) in pairwise::entries(&res) {
            if yj * y_local < 0.0 {
                sb.push_pair_entry_dense(&train.row(node as usize));
            }
        }
        sb.seal_pair_row();
        let op = StepOp {
            learner: LearnerKind::PairwiseAuc,
            variant: Variant::Rw,
            merge: MergeMode::Average,
            hp: lam,
        };
        NativeBackend::new().step(&op, &mut sb).unwrap();
        assert!(
            sb.pair_indptr[1] > 0,
            "test vacuous: reservoir held no opposite-class partner"
        );
        for (a, e) in sb.out_w.iter().zip(model.weights()) {
            assert!((a - e).abs() < 1e-4, "{a} vs {e}");
        }
        assert_eq!(sb.out_t[0], model.t as f32);
    }

    #[test]
    fn pairwise_sparse_matches_dense_kernel() {
        use crate::learning::pairwise;
        let mut rng = Rng::new(34);
        let (d, lam) = (12, 0.1f32);
        let sparse_row = |rng: &mut Rng| {
            let mut idx: Vec<u32> = (0..5).map(|_| rng.below(d as u64) as u32).collect();
            idx.sort_unstable();
            idx.dedup();
            let val: Vec<f32> = idx.iter().map(|_| rng.normal() as f32).collect();
            (idx, val)
        };
        let (xi, xv) = sparse_row(&mut rng);
        let partners: Vec<(Vec<u32>, Vec<f32>)> = (0..3).map(|_| sparse_row(&mut rng)).collect();
        let w0: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let op = StepOp {
            learner: LearnerKind::PairwiseAuc,
            variant: Variant::Rw,
            merge: MergeMode::Average,
            hp: lam,
        };
        // sparse path
        let mut sp = StepBatch::default();
        sp.resize_for(1, d, true);
        sp.w1.copy_from_slice(&w0);
        sp.t1[0] = 5.0;
        sp.y[0] = 1.0;
        sp.push_sparse_x_row(&xi, &xv);
        sp.begin_pair_rows();
        for (pi, pv) in &partners {
            sp.push_pair_entry_sparse(pi, pv);
        }
        sp.seal_pair_row();
        NativeBackend::new().step(&op, &mut sp).unwrap();
        // dense path with the same data
        let mut dn = StepBatch::default();
        dn.resize(1, d);
        dn.w1.copy_from_slice(&w0);
        dn.t1[0] = 5.0;
        dn.y[0] = 1.0;
        Row::Sparse(&xi, &xv).write_dense(&mut dn.x);
        dn.begin_pair_rows();
        for (pi, pv) in &partners {
            dn.push_pair_entry_dense(&Row::Sparse(pi, pv));
        }
        dn.seal_pair_row();
        NativeBackend::new().step(&op, &mut dn).unwrap();
        let eff: Vec<f32> = sp.w1.iter().map(|&w| w * sp.out_s[0]).collect();
        for (a, e) in eff.iter().zip(&dn.out_w) {
            assert!((a - e).abs() < 1e-4, "{a} vs {e}");
        }
        assert_eq!(sp.out_t[0], dn.out_t[0]);
        assert_eq!(sp.out_t[0], 5.0 + partners.len() as f32);
        let _ = pairwise::reservoir_new(0); // keep the import exercised
    }

    #[test]
    fn pairwise_empty_range_is_complete_noop() {
        let d = 4;
        let op = StepOp {
            learner: LearnerKind::PairwiseAuc,
            variant: Variant::Rw,
            merge: MergeMode::Average,
            hp: 0.01,
        };
        let mut sb = StepBatch::default();
        sb.resize(1, d);
        sb.w1.copy_from_slice(&[1.0, -2.0, 3.0, 4.0]);
        sb.t1[0] = 9.0;
        sb.y[0] = 1.0;
        sb.begin_pair_rows();
        sb.seal_pair_row();
        NativeBackend::new().step(&op, &mut sb).unwrap();
        assert_eq!(sb.out_w, vec![1.0, -2.0, 3.0, 4.0], "no decay");
        assert_eq!(sb.out_t[0], 9.0, "no t bump");
    }

    #[test]
    fn pairwise_without_payload_is_an_error() {
        let op = StepOp {
            learner: LearnerKind::PairwiseAuc,
            variant: Variant::Rw,
            merge: MergeMode::Average,
            hp: 0.01,
        };
        let mut sb = StepBatch::default();
        sb.resize(1, 3);
        assert!(NativeBackend::new().step(&op, &mut sb).is_err());
    }

    #[test]
    fn quorum_merge_mu_matches_reference_dense_and_sparse() {
        use crate::learning::pairwise::quorum_merge;
        let mut rng = Rng::new(35);
        let (b, d) = (6, 5);
        let mut sb = random_batch(&mut rng, b, d);
        // isolate the merge: adaline with a zero example is err = -<w,0> = 0,
        // so the update adds nothing and out_w is exactly the merge result
        sb.x.fill(0.0);
        let snapshot = sb.clone();
        let op = StepOp {
            learner: LearnerKind::Adaline,
            variant: Variant::Mu,
            merge: MergeMode::Quorum,
            hp: 0.1,
        };
        NativeBackend::new().step(&op, &mut sb).unwrap();
        for i in 0..b {
            let m1 = LinearModel::from_weights(
                snapshot.w1[i * d..(i + 1) * d].to_vec(),
                snapshot.t1[i] as u64,
            );
            let m2 = LinearModel::from_weights(
                snapshot.w2[i * d..(i + 1) * d].to_vec(),
                snapshot.t2[i] as u64,
            );
            let expect = quorum_merge(&m1, &m2).weights();
            for (a, e) in sb.out_w[i * d..(i + 1) * d].iter().zip(&expect) {
                assert!((a - e).abs() < 1e-6, "{a} vs {e}");
            }
        }
        // sparse layout: same merge on lazy-scaled rows
        let mut sp = StepBatch::default();
        sp.resize_for(1, d, true);
        sp.w1.copy_from_slice(&snapshot.w1[..d]);
        sp.w2.copy_from_slice(&snapshot.w2[..d]);
        sp.s1[0] = 2.0;
        sp.t1[0] = snapshot.t1[0];
        sp.t2[0] = snapshot.t2[0];
        sp.y[0] = 1.0;
        sp.push_sparse_x_row(&[], &[]);
        NativeBackend::new().step(&op, &mut sp).unwrap();
        let mut m1 = LinearModel::from_weights(snapshot.w1[..d].to_vec(), 0);
        m1.scale_by(2.0);
        let m2 = LinearModel::from_weights(snapshot.w2[..d].to_vec(), 0);
        let expect = quorum_merge(&m1, &m2).weights();
        let eff: Vec<f32> = sp.w1[..d].iter().map(|&w| w * sp.out_s[0]).collect();
        for (a, e) in eff.iter().zip(&expect) {
            assert!((a - e).abs() < 1e-6, "{a} vs {e}");
        }
    }
}
