//! Native (pure Rust) batched backend — the written-down semantics of the
//! hot path, mirroring python/compile/kernels/ref.py line for line.

use crate::engine::{Backend, LearnerKind, StepBatch, StepOp};
use crate::gossip::create_model::Variant;
use anyhow::Result;

#[derive(Debug, Default)]
pub struct NativeBackend {
    // scratch rows for the UM variant (avoids per-row allocation; perf §L3)
    u1: Vec<f32>,
    u2: Vec<f32>,
}

impl NativeBackend {
    pub fn new() -> Self {
        NativeBackend::default()
    }

    /// ref.py pegasos_update_ref on one row.
    fn pegasos_row(w: &mut [f32], x: &[f32], y: f32, t: &mut f32, lam: f32) {
        *t += 1.0;
        let eta = 1.0 / (lam * *t);
        let margin = y * dot(w, x);
        let decay = 1.0 - eta * lam;
        if margin < 1.0 {
            let c = eta * y;
            for (wi, &xi) in w.iter_mut().zip(x) {
                *wi = decay * *wi + c * xi;
            }
        } else {
            for wi in w.iter_mut() {
                *wi *= decay;
            }
        }
    }

    /// ref.py adaline_update_ref on one row.
    fn adaline_row(w: &mut [f32], x: &[f32], y: f32, t: &mut f32, eta: f32) {
        let err = y - dot(w, x);
        let c = eta * err;
        for (wi, &xi) in w.iter_mut().zip(x) {
            *wi += c * xi;
        }
        *t += 1.0;
    }

    /// ref.py logreg_update_ref on one row (extension learner).
    fn logreg_row(w: &mut [f32], x: &[f32], y: f32, t: &mut f32, lam: f32) {
        *t += 1.0;
        let eta = 1.0 / (lam * *t);
        let p = 1.0 / (1.0 + (-dot(w, x)).exp());
        let y01 = (y + 1.0) * 0.5;
        let decay = 1.0 - eta * lam;
        let c = eta * (y01 - p);
        for (wi, &xi) in w.iter_mut().zip(x) {
            *wi = decay * *wi + c * xi;
        }
    }

    fn update_row(op: &StepOp, w: &mut [f32], x: &[f32], y: f32, t: &mut f32) {
        match op.learner {
            LearnerKind::Pegasos => Self::pegasos_row(w, x, y, t, op.hp),
            LearnerKind::Adaline => Self::adaline_row(w, x, y, t, op.hp),
            LearnerKind::LogReg => Self::logreg_row(w, x, y, t, op.hp),
        }
    }
}

#[inline]
fn dot(a: &[f32], b: &[f32]) -> f32 {
    crate::data::dataset::dense_dot(a, b)
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn step(&mut self, op: &StepOp, batch: &mut StepBatch) -> Result<()> {
        let (b, d) = (batch.b, batch.d);
        for i in 0..b {
            let r = i * d..(i + 1) * d;
            let w1 = &batch.w1[r.clone()];
            let w2 = &batch.w2[r.clone()];
            let x = &batch.x[r.clone()];
            let y = batch.y[i];
            let out_w = &mut batch.out_w[r];
            let out_t = &mut batch.out_t[i];
            match op.variant {
                Variant::Rw => {
                    out_w.copy_from_slice(w1);
                    *out_t = batch.t1[i];
                    Self::update_row(op, out_w, x, y, out_t);
                }
                Variant::Mu => {
                    for (o, (&a, &bb)) in out_w.iter_mut().zip(w1.iter().zip(w2)) {
                        *o = 0.5 * (a + bb);
                    }
                    *out_t = batch.t1[i].max(batch.t2[i]);
                    Self::update_row(op, out_w, x, y, out_t);
                }
                Variant::Um => {
                    // update both with the same local example, then average
                    self.u1.clear();
                    self.u1.extend_from_slice(w1);
                    self.u2.clear();
                    self.u2.extend_from_slice(w2);
                    let mut t1 = batch.t1[i];
                    let mut t2 = batch.t2[i];
                    Self::update_row(op, &mut self.u1, x, y, &mut t1);
                    Self::update_row(op, &mut self.u2, x, y, &mut t2);
                    for (o, (&a, &bb)) in
                        out_w.iter_mut().zip(self.u1.iter().zip(&self.u2))
                    {
                        *o = 0.5 * (a + bb);
                    }
                    *out_t = t1.max(t2);
                }
            }
        }
        Ok(())
    }

    fn error_counts(
        &mut self,
        x: &[f32],
        y: &[f32],
        n: usize,
        d: usize,
        w: &[f32],
        m: usize,
    ) -> Result<Vec<f32>> {
        let mut counts = vec![0.0f32; m];
        for i in 0..n {
            if y[i] == 0.0 {
                continue;
            }
            let xi = &x[i * d..(i + 1) * d];
            for (j, c) in counts.iter_mut().enumerate() {
                let margin = y[i] * dot(&w[j * d..(j + 1) * d], xi);
                if margin <= 0.0 {
                    *c += 1.0;
                }
            }
        }
        Ok(counts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Row;
    use crate::learning::{Learner, LinearModel};
    use crate::util::rng::Rng;

    fn random_batch(rng: &mut Rng, b: usize, d: usize) -> StepBatch {
        let mut sb = StepBatch::default();
        sb.resize(b, d);
        for v in sb.w1.iter_mut().chain(&mut sb.w2).chain(&mut sb.x) {
            *v = rng.normal() as f32;
        }
        for i in 0..b {
            sb.y[i] = rng.sign();
            sb.t1[i] = rng.below(50) as f32;
            sb.t2[i] = rng.below(50) as f32;
        }
        sb
    }

    /// The batched RW step must match the event-driven LinearModel path.
    #[test]
    fn rw_matches_linear_model_update() {
        let mut rng = Rng::new(3);
        let (b, d) = (16, 9);
        let mut sb = random_batch(&mut rng, b, d);
        let op = StepOp { learner: LearnerKind::Pegasos, variant: Variant::Rw, hp: 0.01 };
        let mut be = NativeBackend::new();
        let learner = Learner::pegasos(0.01);
        let expect: Vec<Vec<f32>> = (0..b)
            .map(|i| {
                let mut m = LinearModel::from_weights(
                    sb.w1[i * d..(i + 1) * d].to_vec(),
                    sb.t1[i] as u64,
                );
                learner.update(&mut m, &Row::Dense(&sb.x[i * d..(i + 1) * d]), sb.y[i]);
                m.weights()
            })
            .collect();
        be.step(&op, &mut sb).unwrap();
        for i in 0..b {
            for (a, e) in sb.out_w[i * d..(i + 1) * d].iter().zip(&expect[i]) {
                assert!((a - e).abs() < 1e-4, "{a} vs {e}");
            }
            assert_eq!(sb.out_t[i], sb.t1[i] + 1.0);
        }
    }

    #[test]
    fn mu_is_merge_then_update() {
        let mut rng = Rng::new(4);
        let (b, d) = (8, 5);
        let mut sb = random_batch(&mut rng, b, d);
        let op = StepOp { learner: LearnerKind::Pegasos, variant: Variant::Mu, hp: 0.1 };
        let snapshot = sb.clone();
        NativeBackend::new().step(&op, &mut sb).unwrap();
        for i in 0..b {
            let mut w: Vec<f32> = (0..d)
                .map(|k| 0.5 * (snapshot.w1[i * d + k] + snapshot.w2[i * d + k]))
                .collect();
            let mut t = snapshot.t1[i].max(snapshot.t2[i]);
            NativeBackend::pegasos_row(&mut w, &snapshot.x[i * d..(i + 1) * d], snapshot.y[i], &mut t, 0.1);
            for (a, e) in sb.out_w[i * d..(i + 1) * d].iter().zip(&w) {
                assert!((a - e).abs() < 1e-5);
            }
            assert_eq!(sb.out_t[i], t);
        }
    }

    #[test]
    fn adaline_um_equals_mu_eq8() {
        // Section V-A: for Adaline the two compositions coincide.
        let mut rng = Rng::new(5);
        let (b, d) = (12, 7);
        let base = random_batch(&mut rng, b, d);
        let mut be = NativeBackend::new();
        let mut mu = base.clone();
        let mut um = base.clone();
        be.step(&StepOp { learner: LearnerKind::Adaline, variant: Variant::Mu, hp: 0.05 }, &mut mu)
            .unwrap();
        be.step(&StepOp { learner: LearnerKind::Adaline, variant: Variant::Um, hp: 0.05 }, &mut um)
            .unwrap();
        for (a, e) in mu.out_w.iter().zip(&um.out_w) {
            assert!((a - e).abs() < 1e-5, "{a} vs {e}");
        }
    }

    #[test]
    fn error_counts_basic() {
        let mut be = NativeBackend::new();
        // 3 test rows (last padded), 2 models
        let x = vec![1.0, 0.0, -1.0, 0.0, 9.0, 9.0];
        let y = vec![1.0, -1.0, 0.0];
        let w = vec![1.0, 0.0, /* model 0: perfect */ -1.0, 0.0 /* model 1: inverted */];
        let c = be.error_counts(&x, &y, 3, 2, &w, 2).unwrap();
        assert_eq!(c, vec![0.0, 2.0]);
    }
}
