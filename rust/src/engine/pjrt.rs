//! PJRT batched backend: executes the AOT-compiled L2 graphs (Pallas kernels
//! inside) through the XLA CPU client.  Requests are padded up to the
//! compiled shape buckets with mask rows; padding rows pass through
//! unchanged and are never read back.
//!
//! The compiled buckets are dense, so CSR-staged sparse batches (DESIGN.md
//! §7) are transparently densified on entry — lazy scales fold into the
//! weight rows, the example payload scatters into `x` — and the dense
//! results are copied back into `w1`/`out_s` to honor the sparse in-place
//! contract of [`Backend::step`].

use crate::engine::{Backend, LearnerKind, StepBatch, StepOp};
use crate::gossip::create_model::Variant;
use crate::learning::MergeMode;
use crate::runtime::{literal_matrix, literal_to_vec, literal_vec, Runtime};
use anyhow::{Context, Result};
use std::path::Path;

pub struct PjrtBackend {
    rt: Runtime,
    // padded staging buffers, reused across calls
    mat: Vec<Vec<f32>>,
    vec: Vec<Vec<f32>>,
}

impl PjrtBackend {
    pub fn new(artifacts_dir: &Path) -> Result<Self> {
        Ok(PjrtBackend {
            rt: Runtime::load(artifacts_dir)?,
            mat: vec![Vec::new(); 3],
            vec: vec![Vec::new(); 4],
        })
    }

    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }

    /// Default artifact location: `$GOLF_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> std::path::PathBuf {
        std::env::var_os("GOLF_ARTIFACTS")
            .map(Into::into)
            .unwrap_or_else(|| "artifacts".into())
    }

    fn pad_matrix(dst: &mut Vec<f32>, src: &[f32], b: usize, d: usize, pb: usize, pd: usize) {
        dst.clear();
        dst.resize(pb * pd, 0.0);
        for i in 0..b {
            dst[i * pd..i * pd + d].copy_from_slice(&src[i * d..(i + 1) * d]);
        }
    }

    fn pad_vec(dst: &mut Vec<f32>, src: &[f32], b: usize, pb: usize) {
        dst.clear();
        dst.resize(pb, 0.0);
        dst[..b].copy_from_slice(&src[..b]);
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn step(&mut self, op: &StepOp, batch: &mut StepBatch) -> Result<()> {
        // pairwise/quorum ops have no compiled artifacts (and no pair-payload
        // marshalling); config validation routes them to the native backend
        if op.learner == LearnerKind::PairwiseAuc || op.merge == MergeMode::Quorum {
            anyhow::bail!(
                "no compiled artifact for op {} (pairwise/quorum ops run on the native backend)",
                op.op_name()
            );
        }
        // dense compiled buckets: densify sparse batches on entry, restore
        // the sparse in-place result contract on exit
        let was_sparse = batch.is_sparse_x();
        if was_sparse {
            batch.densify();
        }
        let (b, d) = (batch.b, batch.d);
        let (name, params) = self
            .rt
            .resolve(&op.op_name(), &[("b", b), ("d", d)])
            .context("resolving step artifact")?;
        let (pb, pd) = (params["b"], params["d"]);

        // mask: 1 for live rows, 0 for padding; hp broadcast per-row
        let mut mask = vec![0.0f32; pb];
        mask[..b].fill(1.0);
        let mut hp = vec![0.0f32; pb];
        hp[..b].fill(op.hp);

        let [m0, m1, m2] = &mut self.mat[..] else { unreachable!() };
        Self::pad_matrix(m0, &batch.w1, b, d, pb, pd);
        Self::pad_matrix(m2, &batch.x, b, d, pb, pd);
        let [v0, v1, v2, _v3] = &mut self.vec[..] else { unreachable!() };
        Self::pad_vec(v0, &batch.t1, b, pb);
        Self::pad_vec(v2, &batch.y, b, pb);

        let outs = match op.variant {
            Variant::Rw => {
                // (w, x, y, t, hp, mask)
                let inputs = [
                    literal_matrix(m0, pb, pd)?,
                    literal_matrix(m2, pb, pd)?,
                    literal_vec(v2),
                    literal_vec(v0),
                    literal_vec(&hp),
                    literal_vec(&mask),
                ];
                self.rt.execute(&name, &inputs)?
            }
            Variant::Mu | Variant::Um => {
                // (w1, t1, w2, t2, x, y, hp, mask)
                Self::pad_matrix(m1, &batch.w2, b, d, pb, pd);
                Self::pad_vec(v1, &batch.t2, b, pb);
                let inputs = [
                    literal_matrix(m0, pb, pd)?,
                    literal_vec(v0),
                    literal_matrix(m1, pb, pd)?,
                    literal_vec(v1),
                    literal_matrix(m2, pb, pd)?,
                    literal_vec(v2),
                    literal_vec(&hp),
                    literal_vec(&mask),
                ];
                self.rt.execute(&name, &inputs)?
            }
        };

        let w_out = literal_to_vec(&outs[0])?;
        let t_out = literal_to_vec(&outs[1])?;
        for i in 0..b {
            batch.out_w[i * d..(i + 1) * d]
                .copy_from_slice(&w_out[i * pd..i * pd + d]);
            batch.out_t[i] = t_out[i];
        }
        if was_sparse {
            // sparse callers read results from (w1, out_s, out_t)
            for i in 0..b {
                let r = i * d..(i + 1) * d;
                let (w1, out_w) = (&mut batch.w1, &batch.out_w);
                w1[r.clone()].copy_from_slice(&out_w[r]);
                batch.out_s[i] = 1.0;
            }
        }
        Ok(())
    }

    fn error_counts(
        &mut self,
        x: &[f32],
        y: &[f32],
        n: usize,
        d: usize,
        w: &[f32],
        m: usize,
    ) -> Result<Vec<f32>> {
        let (name, params) = self
            .rt
            .resolve("eval_error_counts", &[("n", n), ("m", m), ("d", d)])
            .context("resolving eval artifact")?;
        let (pn, pm, pd) = (params["n"], params["m"], params["d"]);

        let [m0, m1, _] = &mut self.mat[..] else { unreachable!() };
        Self::pad_matrix(m0, x, n, d, pn, pd);
        Self::pad_matrix(m1, w, m, d, pm, pd);
        let [v0, ..] = &mut self.vec[..] else { unreachable!() };
        Self::pad_vec(v0, y, n, pn); // label 0 marks padding rows

        let inputs = [
            literal_matrix(m0, pn, pd)?,
            literal_vec(v0),
            literal_matrix(m1, pm, pd)?,
        ];
        let outs = self.rt.execute(&name, &inputs)?;
        let counts = literal_to_vec(&outs[0])?;
        Ok(counts[..m].to_vec())
    }
}
