//! Compute engines: the batched hot path of the simulator.
//!
//! A delivery tick's worth of independent per-node CREATEMODEL steps is
//! expressed as one batched op over `[B, D]` matrices — the same graphs the
//! L2 JAX layer lowers to HLO (python/compile/model.py).  Two backends
//! implement the op set:
//!
//! * [`native::NativeBackend`] — pure Rust, mirrors ref.py exactly.
//! * [`pjrt::PjrtBackend`] — executes the AOT artifacts through the PJRT
//!   CPU client (runtime/), padding to the compiled shape buckets.
//!
//! The [`batched`] driver runs the gossip protocol cycle-synchronously on
//! top of either backend; the engine-parity integration test pins the two
//! backends to each other, and python/tests pins the artifacts to ref.py —
//! closing the loop Rust native == XLA == Pallas == paper math.

pub mod batched;
pub mod native;
pub mod pjrt;

use crate::gossip::create_model::Variant;
use crate::learning::Learner;
use anyhow::Result;

/// Maximum rows per engine call — matches the largest compiled PJRT shape
/// bucket.  Shared by the cycle-synchronous driver and the event-driven
/// micro-batch flush so both chunk identically.
pub const MAX_BATCH_ROWS: usize = 1024;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LearnerKind {
    Pegasos,
    Adaline,
    LogReg,
}

/// One batched CREATEMODEL op: which learner, which Algorithm-2 variant,
/// and the learner hyperparameter (λ for Pegasos, η for Adaline).
#[derive(Clone, Copy, Debug)]
pub struct StepOp {
    pub learner: LearnerKind,
    pub variant: Variant,
    pub hp: f32,
}

impl StepOp {
    /// Artifact op name, e.g. "pegasos_mu".
    pub fn op_name(&self) -> String {
        let l = match self.learner {
            LearnerKind::Pegasos => "pegasos",
            LearnerKind::Adaline => "adaline",
            LearnerKind::LogReg => "logreg",
        };
        format!("{}_{}", l, self.variant.name())
    }

    /// The op a protocol run executes: learner kind + hyperparameter from the
    /// [`Learner`] enum, combined with the CREATEMODEL variant.  Shared by the
    /// event-driven micro-batched simulator and the cycle-synchronous driver.
    pub fn for_protocol(learner: &Learner, variant: Variant) -> StepOp {
        match learner {
            Learner::Pegasos(p) => StepOp { learner: LearnerKind::Pegasos, variant, hp: p.lambda },
            Learner::Adaline(a) => StepOp { learner: LearnerKind::Adaline, variant, hp: a.eta },
            Learner::LogReg(l) => StepOp { learner: LearnerKind::LogReg, variant, hp: l.lambda },
        }
    }
}

/// Reusable batch buffers (flat row-major `[b, d]` matrices plus `[b]`
/// vectors). `w2`/`t2` are ignored for the RW variant.
#[derive(Clone, Debug, Default)]
pub struct StepBatch {
    pub b: usize,
    pub d: usize,
    pub w1: Vec<f32>,
    pub t1: Vec<f32>,
    pub w2: Vec<f32>,
    pub t2: Vec<f32>,
    pub x: Vec<f32>,
    pub y: Vec<f32>,
    pub out_w: Vec<f32>,
    pub out_t: Vec<f32>,
}

impl StepBatch {
    /// Resize the buffers for a `[b, d]` batch.
    ///
    /// Callers always refill `w1`/`t1`/`x`/`y` for every live row, but `w2`/
    /// `t2` are only filled for merge variants and `out_*` only written by
    /// the backend.  `Vec::resize` zero-fills grown elements but keeps the
    /// surviving prefix, so after shrinking `b` (or reflowing `d`) those
    /// buffers would still hold rows from an earlier, larger batch.  Any
    /// geometry change therefore clears them outright, so no engine call can
    /// observe stale data through an unfilled optional input or a read-back
    /// of an unwritten output row.
    pub fn resize(&mut self, b: usize, d: usize) {
        let changed = self.b != b || self.d != d;
        self.b = b;
        self.d = d;
        self.w1.resize(b * d, 0.0);
        self.w2.resize(b * d, 0.0);
        self.x.resize(b * d, 0.0);
        self.t1.resize(b, 0.0);
        self.t2.resize(b, 0.0);
        self.y.resize(b, 0.0);
        self.out_w.resize(b * d, 0.0);
        self.out_t.resize(b, 0.0);
        if changed {
            self.w2.fill(0.0);
            self.t2.fill(0.0);
            self.out_w.fill(0.0);
            self.out_t.fill(0.0);
        }
    }
}

/// A batched compute backend.
pub trait Backend {
    fn name(&self) -> &'static str;

    /// Apply `op` to every row of the batch, writing `out_w`/`out_t`.
    fn step(&mut self, op: &StepOp, batch: &mut StepBatch) -> Result<()>;

    /// Misclassification counts: `x` is a dense `[n, d]` test chunk with
    /// labels `y` (0 = padding row), `w` a `[m, d]` model batch; returns the
    /// per-model count of rows with `y * <w, x> <= 0`.
    fn error_counts(
        &mut self,
        x: &[f32],
        y: &[f32],
        n: usize,
        d: usize,
        w: &[f32],
        m: usize,
    ) -> Result<Vec<f32>>;
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fill(sb: &mut StepBatch, v: f32) {
        for buf in [&mut sb.w1, &mut sb.w2, &mut sb.x, &mut sb.out_w] {
            buf.fill(v);
        }
        for buf in [&mut sb.t1, &mut sb.t2, &mut sb.y, &mut sb.out_t] {
            buf.fill(v);
        }
    }

    /// Regression: shrinking `b` between engine calls and growing it again
    /// must not resurrect stale `w2`/`t2`/`out_*` rows from the larger batch.
    #[test]
    fn resize_clears_stale_optional_and_output_rows() {
        let mut sb = StepBatch::default();
        sb.resize(4, 3);
        fill(&mut sb, 7.0);
        sb.resize(2, 3); // shrink
        assert_eq!(sb.w2.len(), 6);
        assert!(sb.w2.iter().all(|&v| v == 0.0), "w2 stale after shrink");
        assert!(sb.t2.iter().all(|&v| v == 0.0), "t2 stale after shrink");
        assert!(sb.out_w.iter().all(|&v| v == 0.0), "out_w stale after shrink");
        assert!(sb.out_t.iter().all(|&v| v == 0.0), "out_t stale after shrink");
        fill(&mut sb, 9.0);
        sb.resize(4, 3); // grow back: rows 2..4 must not contain the old 7s
        assert!(sb.w2.iter().all(|&v| v == 0.0), "w2 stale after regrow");
        assert!(sb.out_w.iter().all(|&v| v == 0.0), "out_w stale after regrow");
    }

    #[test]
    fn resize_same_geometry_keeps_buffers() {
        let mut sb = StepBatch::default();
        sb.resize(2, 2);
        fill(&mut sb, 3.0);
        sb.resize(2, 2); // no-op geometry: caller-visible state preserved
        assert!(sb.w1.iter().all(|&v| v == 3.0));
        assert!(sb.w2.iter().all(|&v| v == 3.0));
    }

    #[test]
    fn for_protocol_maps_learner_and_hp() {
        let op = StepOp::for_protocol(&Learner::pegasos(0.25), Variant::Mu);
        assert_eq!(op.learner, LearnerKind::Pegasos);
        assert_eq!(op.variant, Variant::Mu);
        assert_eq!(op.hp, 0.25);
        assert_eq!(op.op_name(), "pegasos_mu");
        let op = StepOp::for_protocol(&Learner::adaline(0.1), Variant::Rw);
        assert_eq!(op.learner, LearnerKind::Adaline);
        assert_eq!(op.hp, 0.1);
        let op = StepOp::for_protocol(&Learner::logreg(0.01), Variant::Um);
        assert_eq!(op.learner, LearnerKind::LogReg);
        assert_eq!(op.op_name(), "logreg_um");
    }
}
