//! Compute engines: the batched hot path of the simulator.
//!
//! A delivery tick's worth of independent per-node CREATEMODEL steps is
//! expressed as one batched op over `[B, D]` matrices — the same graphs the
//! L2 JAX layer lowers to HLO (python/compile/model.py).  Two backends
//! implement the op set:
//!
//! * [`native::NativeBackend`] — pure Rust, mirrors ref.py exactly.
//! * [`pjrt::PjrtBackend`] — executes the AOT artifacts through the PJRT
//!   CPU client (runtime/), padding to the compiled shape buckets.
//!
//! The [`batched`] driver runs the gossip protocol cycle-synchronously on
//! top of either backend; the engine-parity integration test pins the two
//! backends to each other, and python/tests pins the artifacts to ref.py —
//! closing the loop Rust native == XLA == Pallas == paper math.

pub mod batched;
pub mod native;
pub mod pjrt;

use crate::data::dataset::{Examples, Row};
use crate::gossip::create_model::Variant;
use crate::gossip::state::ModelStore;
use crate::learning::{Learner, MergeMode};
use anyhow::Result;

/// Maximum rows per engine call — matches the largest compiled PJRT shape
/// bucket.  Shared by the cycle-synchronous driver and the event-driven
/// micro-batch flush so both chunk identically.
pub const MAX_BATCH_ROWS: usize = 1024;

/// Test-set rows per batched-evaluation chunk (matches the eval artifact
/// bucket).
pub const EVAL_CHUNK: usize = 1024;

/// Minimum rows per chunk when the native backend splits a `StepBatch`
/// across leased threads (DESIGN.md §14) — below this the spawn overhead
/// dominates the row math.  Rows are independent, so chunked execution is
/// bit-for-bit the serial loop; batches smaller than two chunks stay
/// serial.
pub const PAR_ROWS_MIN: usize = 128;

/// Minimum total work (`b * d`) before the native backend considers
/// parallel chunking at all: a full 1024-row batch of d=10 paper models is
/// ~10k mul-adds — far cheaper than a thread spawn — while a d=1000 batch
/// is worth splitting.
pub const PAR_MIN_WORK: usize = 1 << 17;
/// Models per batched-evaluation call (matches the eval artifact bucket).
pub const EVAL_MODELS: usize = 128;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LearnerKind {
    Pegasos,
    Adaline,
    LogReg,
    /// Pairwise hinge AUC (DESIGN.md §17): steps consume the batch's staged
    /// reservoir pair payload instead of the pointwise `x`/`y` example.
    PairwiseAuc,
}

/// One batched CREATEMODEL op: which learner, which Algorithm-2 variant,
/// how models combine, and the learner hyperparameter (λ for Pegasos and
/// PairwiseAuc, η for Adaline).
#[derive(Clone, Copy, Debug)]
pub struct StepOp {
    pub learner: LearnerKind,
    pub variant: Variant,
    /// how MERGE combines two models (Mu/Um only; Rw never merges)
    pub merge: MergeMode,
    pub hp: f32,
}

impl StepOp {
    /// Artifact op name, e.g. "pegasos_mu" (quorum merge suffixes
    /// "_quorum" — no compiled PJRT artifact exists for those ops).
    pub fn op_name(&self) -> String {
        let l = match self.learner {
            LearnerKind::Pegasos => "pegasos",
            LearnerKind::Adaline => "adaline",
            LearnerKind::LogReg => "logreg",
            LearnerKind::PairwiseAuc => "pairwise_auc",
        };
        match self.merge {
            MergeMode::Average => format!("{}_{}", l, self.variant.name()),
            MergeMode::Quorum => format!("{}_{}_quorum", l, self.variant.name()),
        }
    }

    /// The op a protocol run executes: learner kind + hyperparameter from the
    /// [`Learner`] enum, combined with the CREATEMODEL variant and merge
    /// mode.  Shared by the event-driven micro-batched simulator and the
    /// cycle-synchronous driver.
    pub fn for_protocol(learner: &Learner, variant: Variant, merge: MergeMode) -> StepOp {
        match learner {
            Learner::Pegasos(p) => {
                StepOp { learner: LearnerKind::Pegasos, variant, merge, hp: p.lambda }
            }
            Learner::Adaline(a) => {
                StepOp { learner: LearnerKind::Adaline, variant, merge, hp: a.eta }
            }
            Learner::LogReg(l) => {
                StepOp { learner: LearnerKind::LogReg, variant, merge, hp: l.lambda }
            }
            Learner::PairwiseAuc(p) => {
                StepOp { learner: LearnerKind::PairwiseAuc, variant, merge, hp: p.lambda }
            }
        }
    }
}

/// Reusable batch buffers (flat row-major `[b, d]` matrices plus `[b]`
/// vectors). `w2`/`t2` are ignored for the RW variant.
///
/// Two layouts share the struct (DESIGN.md §7):
///
/// * **Dense** (`resize`): examples live in the dense `x` buffer, scales are
///   all 1, and the backend writes results to `out_w`/`out_t`.
/// * **Sparse** (`resize_for(.., true)` + `push_sparse_x_row`): examples are
///   staged as a CSR payload (`x_indptr`/`x_indices`/`x_values`), model rows
///   carry per-row lazy scales `s1`/`s2` (effective weights are `s * w`),
///   and the O(nnz) kernels update `w1` **in place**, returning the final
///   scale in `out_s` and the counter in `out_t` (`w2` is clobbered as
///   scratch by the UM variant; `x`/`out_w` are unused and kept empty).
#[derive(Clone, Debug, Default)]
pub struct StepBatch {
    pub b: usize,
    pub d: usize,
    pub w1: Vec<f32>,
    pub t1: Vec<f32>,
    pub w2: Vec<f32>,
    pub t2: Vec<f32>,
    pub x: Vec<f32>,
    pub y: Vec<f32>,
    pub out_w: Vec<f32>,
    pub out_t: Vec<f32>,
    /// per-row lazy scale of `w1` rows (sparse layout; 1.0 otherwise)
    pub s1: Vec<f32>,
    /// per-row lazy scale of `w2` rows (sparse layout; 1.0 otherwise)
    pub s2: Vec<f32>,
    /// per-row result scale written by sparse kernels (result = out_s * w1)
    pub out_s: Vec<f32>,
    /// CSR row pointers of the sparse example payload (`b + 1` entries when
    /// staged; empty in the dense layout)
    pub x_indptr: Vec<usize>,
    pub x_indices: Vec<u32>,
    pub x_values: Vec<f32>,
    /// Reservoir pair payload (DESIGN.md §17): per-batch-row offsets into the
    /// staged opposite-class partner examples (`b + 1` entries when staged
    /// via [`StepBatch::begin_pair_rows`]; empty for pointwise ops).  Rows
    /// with an empty range take no step at all — no decay, no `t` bump.
    pub pair_indptr: Vec<usize>,
    /// dense layout: one `d`-row per staged pair entry
    pub pair_x: Vec<f32>,
    /// sparse layout: CSR over the staged pair entries
    pub pair_x_indptr: Vec<usize>,
    pub pair_x_indices: Vec<u32>,
    pub pair_x_values: Vec<f32>,
}

impl StepBatch {
    /// Resize the buffers for a dense `[b, d]` batch.
    ///
    /// Callers always refill `w1`/`t1`/`x`/`y` for every live row, but `w2`/
    /// `t2` are only filled for merge variants and `out_*` only written by
    /// the backend.  `Vec::resize` zero-fills grown elements but keeps the
    /// surviving prefix, so after shrinking `b` (or reflowing `d`) those
    /// buffers would still hold rows from an earlier, larger batch.  Any
    /// geometry change therefore clears them outright, so no engine call can
    /// observe stale data through an unfilled optional input or a read-back
    /// of an unwritten output row.
    pub fn resize(&mut self, b: usize, d: usize) {
        self.resize_for(b, d, false);
    }

    /// Resize for a `[b, d]` batch in the dense or sparse layout.
    ///
    /// The CSR payload is reset on every call: sparse callers follow up with
    /// one [`StepBatch::push_sparse_x_row`] per row, in row order.  In the
    /// sparse layout the dense `x`/`out_w` buffers are released to zero
    /// length — at Reuters scale (d = 9947) they would otherwise pin tens of
    /// megabytes that the O(nnz) path never touches.
    pub fn resize_for(&mut self, b: usize, d: usize, sparse: bool) {
        let changed = self.b != b || self.d != d;
        self.b = b;
        self.d = d;
        let dense_len = if sparse { 0 } else { b * d };
        self.w1.resize(b * d, 0.0);
        self.w2.resize(b * d, 0.0);
        self.x.resize(dense_len, 0.0);
        self.t1.resize(b, 0.0);
        self.t2.resize(b, 0.0);
        self.y.resize(b, 0.0);
        self.out_w.resize(dense_len, 0.0);
        self.out_t.resize(b, 0.0);
        self.s1.resize(b, 1.0);
        self.s2.resize(b, 1.0);
        self.out_s.resize(b, 1.0);
        self.x_indptr.clear();
        self.x_indices.clear();
        self.x_values.clear();
        self.pair_indptr.clear();
        self.pair_x.clear();
        self.pair_x_indptr.clear();
        self.pair_x_indices.clear();
        self.pair_x_values.clear();
        if sparse {
            self.x_indptr.push(0);
        }
        if changed {
            self.w2.fill(0.0);
            self.t2.fill(0.0);
            self.out_w.fill(0.0);
            self.out_t.fill(0.0);
            self.s1.fill(1.0);
            self.s2.fill(1.0);
            self.out_s.fill(1.0);
        }
    }

    /// Append the next row's sparse example (sorted column indices + values)
    /// to the CSR payload.
    pub fn push_sparse_x_row(&mut self, idx: &[u32], val: &[f32]) {
        debug_assert_eq!(idx.len(), val.len());
        debug_assert!(!self.x_indptr.is_empty(), "resize_for(.., true) first");
        debug_assert!(self.x_indptr.len() <= self.b, "more sparse rows than b");
        self.x_indices.extend_from_slice(idx);
        self.x_values.extend_from_slice(val);
        self.x_indptr.push(self.x_indices.len());
    }

    /// Row `i` of the CSR example payload.
    #[inline]
    pub fn sparse_x_row(&self, i: usize) -> (&[u32], &[f32]) {
        let (a, b) = (self.x_indptr[i], self.x_indptr[i + 1]);
        (&self.x_indices[a..b], &self.x_values[a..b])
    }

    /// Whether a complete sparse example payload is staged (one CSR row per
    /// batch row).
    #[inline]
    pub fn is_sparse_x(&self) -> bool {
        self.x_indptr.len() == self.b + 1
    }

    // ---- reservoir pair payload (pairwise ops) -------------------------

    /// Start staging the pair payload: one [`StepBatch::seal_pair_row`] per
    /// batch row, each preceded by zero or more `push_pair_entry_*` calls
    /// (the destination row's opposite-class reservoir partners, in
    /// reservoir order).  Must follow `resize`/`resize_for` (which clear any
    /// previous payload).
    pub fn begin_pair_rows(&mut self) {
        self.pair_indptr.clear();
        self.pair_indptr.push(0);
        self.pair_x.clear();
        self.pair_x_indptr.clear();
        self.pair_x_indptr.push(0);
        self.pair_x_indices.clear();
        self.pair_x_values.clear();
    }

    /// Append one partner example to the current row's pair range, staged
    /// densely (`d` floats; sparse source rows are scattered).
    pub fn push_pair_entry_dense(&mut self, row: &Row<'_>) {
        let d = self.d;
        let at = self.pair_x.len();
        self.pair_x.resize(at + d, 0.0);
        row.write_dense(&mut self.pair_x[at..at + d]);
    }

    /// Append one partner example to the current row's pair range as a
    /// sorted sparse (indices, values) pair.
    pub fn push_pair_entry_sparse(&mut self, idx: &[u32], val: &[f32]) {
        debug_assert_eq!(idx.len(), val.len());
        self.pair_x_indices.extend_from_slice(idx);
        self.pair_x_values.extend_from_slice(val);
        self.pair_x_indptr.push(self.pair_x_indices.len());
    }

    /// Close the current batch row's pair range.
    pub fn seal_pair_row(&mut self) {
        debug_assert!(!self.pair_indptr.is_empty(), "begin_pair_rows first");
        let n_dense = if self.d == 0 { 0 } else { self.pair_x.len() / self.d };
        let n_sparse = self.pair_x_indptr.len() - 1;
        self.pair_indptr.push(n_dense.max(n_sparse));
    }

    /// Whether a complete pair payload is staged (one sealed range per row).
    #[inline]
    pub fn has_pairs(&self) -> bool {
        self.pair_indptr.len() == self.b + 1
    }

    /// Dense pair entry `e` (absolute index).
    #[inline]
    pub fn pair_dense_entry(&self, e: usize) -> &[f32] {
        &self.pair_x[e * self.d..(e + 1) * self.d]
    }

    /// Sparse pair entry `e` (absolute index).
    #[inline]
    pub fn pair_sparse_entry(&self, e: usize) -> (&[u32], &[f32]) {
        let (a, b) = (self.pair_x_indptr[e], self.pair_x_indptr[e + 1]);
        (&self.pair_x_indices[a..b], &self.pair_x_values[a..b])
    }

    /// Convert a staged sparse batch to the dense layout: scatter the CSR
    /// payload into `x` and fold the lazy scales into the `w1`/`w2` rows.
    /// Used by backends whose compiled graphs are dense (the PJRT shape
    /// buckets); afterwards the batch is a plain dense batch with scales 1.
    pub fn densify(&mut self) {
        let (b, d) = (self.b, self.d);
        self.x.resize(b * d, 0.0);
        self.x.fill(0.0);
        self.out_w.resize(b * d, 0.0);
        self.out_w.fill(0.0);
        for i in 0..b {
            let (lo, hi) = (self.x_indptr[i], self.x_indptr[i + 1]);
            for (&j, &v) in self.x_indices[lo..hi].iter().zip(&self.x_values[lo..hi]) {
                self.x[i * d + j as usize] = v;
            }
            if self.s1[i] != 1.0 {
                for w in &mut self.w1[i * d..(i + 1) * d] {
                    *w *= self.s1[i];
                }
                self.s1[i] = 1.0;
            }
            if self.s2[i] != 1.0 {
                for w in &mut self.w2[i * d..(i + 1) * d] {
                    *w *= self.s2[i];
                }
                self.s2[i] = 1.0;
            }
        }
        self.x_indptr.clear();
        self.x_indices.clear();
        self.x_values.clear();
    }
}

/// A batched compute backend.
pub trait Backend {
    fn name(&self) -> &'static str;

    /// Apply `op` to every row of the batch.
    ///
    /// Dense layout: results are written to `out_w`/`out_t`.  Sparse layout
    /// (`batch.is_sparse_x()`): result weights land **in place** in `w1`
    /// with their lazy scale in `out_s` and the counter in `out_t`; backends
    /// without sparse kernels may [`StepBatch::densify`] first and copy
    /// `out_w` back into `w1` to honor the same contract.
    fn step(&mut self, op: &StepOp, batch: &mut StepBatch) -> Result<()>;

    /// Whether this backend executes CSR-staged batches with true O(nnz)
    /// kernels.  `false` means sparse batches are densified on entry
    /// ([`StepBatch::densify`]) — correct but strictly slower than plain
    /// dense staging, so automatic dispatch should only pick the sparse
    /// path when this returns `true`.
    fn supports_sparse(&self) -> bool {
        false
    }

    /// Misclassification counts: `x` is a dense `[n, d]` test chunk with
    /// labels `y` (0 = padding row), `w` a `[m, d]` model batch; returns the
    /// per-model count of misclassified rows under the repo-wide
    /// sign(0) = -1 convention (a zero margin errs on positives only).
    fn error_counts(
        &mut self,
        x: &[f32],
        y: &[f32],
        n: usize,
        d: usize,
        w: &[f32],
        m: usize,
    ) -> Result<Vec<f32>>;

    /// Misclassification counts of `m` models (`w` is `[m, d]` materialized
    /// weights) over a whole test set, chunked into [`EVAL_CHUNK`]-row
    /// engine passes.
    ///
    /// The default implementation densifies each chunk and calls
    /// [`Backend::error_counts`] — what the PJRT backend's dense shape
    /// buckets need.  The native backend overrides it with a zero-copy dense
    /// pass / O(nnz) sparse-dot pass per storage kind.
    fn error_counts_examples(
        &mut self,
        test: &Examples,
        y: &[f32],
        w: &[f32],
        m: usize,
    ) -> Result<Vec<f32>> {
        let (n, d) = (test.n(), test.d());
        let mut counts = vec![0.0f32; m];
        let mut xchunk = vec![0.0f32; EVAL_CHUNK.min(n) * d];
        let mut row = 0;
        while row < n {
            let rows = EVAL_CHUNK.min(n - row);
            xchunk.resize(rows * d, 0.0);
            for i in 0..rows {
                test.row(row + i).write_dense(&mut xchunk[i * d..(i + 1) * d]);
            }
            let c = self.error_counts(&xchunk, &y[row..row + rows], rows, d, w, m)?;
            for (acc, v) in counts.iter_mut().zip(&c) {
                *acc += v;
            }
            row += rows;
        }
        Ok(counts)
    }
}

/// 0-1 error of each listed peer's freshest model over the whole test set,
/// via the backend's sparse-aware chunked evaluator: peers are staged as
/// `[m, d]` batches of materialized weights ([`EVAL_MODELS`] per engine
/// call).  Shared by the event-driven (`gossip/protocol.rs`) and
/// cycle-synchronous (`engine/batched.rs`) drivers so their measurement
/// semantics cannot drift.
pub fn eval_peer_errors<B: Backend + ?Sized>(
    store: &ModelStore,
    peers: &[usize],
    backend: &mut B,
    test: &Examples,
    y: &[f32],
) -> Result<Vec<f64>> {
    let d = store.d();
    let n_test = test.n().max(1);
    let mut errs = Vec::with_capacity(peers.len());
    let mut w = Vec::new();
    for group in peers.chunks(EVAL_MODELS) {
        let m = group.len();
        w.clear();
        w.resize(m * d, 0.0);
        for (j, &p) in group.iter().enumerate() {
            store.write_freshest_into(p, &mut w[j * d..(j + 1) * d]);
        }
        let counts = backend.error_counts_examples(test, y, &w, m)?;
        errs.extend(counts.iter().map(|&c| c as f64 / n_test as f64));
    }
    Ok(errs)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fill(sb: &mut StepBatch, v: f32) {
        for buf in [&mut sb.w1, &mut sb.w2, &mut sb.x, &mut sb.out_w] {
            buf.fill(v);
        }
        for buf in [&mut sb.t1, &mut sb.t2, &mut sb.y, &mut sb.out_t] {
            buf.fill(v);
        }
    }

    /// Regression: shrinking `b` between engine calls and growing it again
    /// must not resurrect stale `w2`/`t2`/`out_*` rows from the larger batch.
    #[test]
    fn resize_clears_stale_optional_and_output_rows() {
        let mut sb = StepBatch::default();
        sb.resize(4, 3);
        fill(&mut sb, 7.0);
        sb.resize(2, 3); // shrink
        assert_eq!(sb.w2.len(), 6);
        assert!(sb.w2.iter().all(|&v| v == 0.0), "w2 stale after shrink");
        assert!(sb.t2.iter().all(|&v| v == 0.0), "t2 stale after shrink");
        assert!(sb.out_w.iter().all(|&v| v == 0.0), "out_w stale after shrink");
        assert!(sb.out_t.iter().all(|&v| v == 0.0), "out_t stale after shrink");
        fill(&mut sb, 9.0);
        sb.resize(4, 3); // grow back: rows 2..4 must not contain the old 7s
        assert!(sb.w2.iter().all(|&v| v == 0.0), "w2 stale after regrow");
        assert!(sb.out_w.iter().all(|&v| v == 0.0), "out_w stale after regrow");
    }

    #[test]
    fn resize_same_geometry_keeps_buffers() {
        let mut sb = StepBatch::default();
        sb.resize(2, 2);
        fill(&mut sb, 3.0);
        sb.resize(2, 2); // no-op geometry: caller-visible state preserved
        assert!(sb.w1.iter().all(|&v| v == 3.0));
        assert!(sb.w2.iter().all(|&v| v == 3.0));
    }

    #[test]
    fn sparse_layout_stages_csr_and_flips_back_to_dense() {
        let mut sb = StepBatch::default();
        sb.resize_for(2, 4, true);
        assert!(sb.x.is_empty() && sb.out_w.is_empty());
        assert!(!sb.is_sparse_x(), "payload not staged yet");
        sb.push_sparse_x_row(&[1, 3], &[2.0, -1.0]);
        sb.push_sparse_x_row(&[0], &[5.0]);
        assert!(sb.is_sparse_x());
        assert_eq!(sb.sparse_x_row(0), (&[1u32, 3][..], &[2.0f32, -1.0][..]));
        assert_eq!(sb.sparse_x_row(1), (&[0u32][..], &[5.0f32][..]));
        assert_eq!(sb.s1, vec![1.0, 1.0]);
        // same geometry, dense layout: buffers come back, payload resets
        sb.resize(2, 4);
        assert!(!sb.is_sparse_x());
        assert_eq!(sb.x.len(), 8);
        assert_eq!(sb.out_w.len(), 8);
    }

    #[test]
    fn densify_scatters_x_and_folds_scales() {
        let mut sb = StepBatch::default();
        sb.resize_for(2, 3, true);
        sb.w1.copy_from_slice(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        sb.w2.copy_from_slice(&[1.0; 6]);
        sb.s1[0] = 0.5;
        sb.s2[1] = 2.0;
        sb.push_sparse_x_row(&[2], &[7.0]);
        sb.push_sparse_x_row(&[0, 1], &[1.0, -1.0]);
        sb.densify();
        assert!(!sb.is_sparse_x());
        assert_eq!(sb.x, vec![0.0, 0.0, 7.0, 1.0, -1.0, 0.0]);
        assert_eq!(sb.w1, vec![0.5, 1.0, 1.5, 4.0, 5.0, 6.0]);
        assert_eq!(sb.w2, vec![1.0, 1.0, 1.0, 2.0, 2.0, 2.0]);
        assert_eq!(sb.s1, vec![1.0, 1.0]);
        assert_eq!(sb.s2, vec![1.0, 1.0]);
    }

    #[test]
    fn for_protocol_maps_learner_and_hp() {
        let op = StepOp::for_protocol(&Learner::pegasos(0.25), Variant::Mu, MergeMode::Average);
        assert_eq!(op.learner, LearnerKind::Pegasos);
        assert_eq!(op.variant, Variant::Mu);
        assert_eq!(op.merge, MergeMode::Average);
        assert_eq!(op.hp, 0.25);
        assert_eq!(op.op_name(), "pegasos_mu");
        let op = StepOp::for_protocol(&Learner::adaline(0.1), Variant::Rw, MergeMode::Average);
        assert_eq!(op.learner, LearnerKind::Adaline);
        assert_eq!(op.hp, 0.1);
        let op = StepOp::for_protocol(&Learner::logreg(0.01), Variant::Um, MergeMode::Average);
        assert_eq!(op.learner, LearnerKind::LogReg);
        assert_eq!(op.op_name(), "logreg_um");
        let op =
            StepOp::for_protocol(&Learner::pairwise_auc(0.01), Variant::Mu, MergeMode::Quorum);
        assert_eq!(op.learner, LearnerKind::PairwiseAuc);
        assert_eq!(op.merge, MergeMode::Quorum);
        assert_eq!(op.op_name(), "pairwise_auc_mu_quorum");
    }

    #[test]
    fn pair_payload_stages_ranges_per_row() {
        let mut sb = StepBatch::default();
        sb.resize(2, 3);
        sb.begin_pair_rows();
        sb.push_pair_entry_dense(&Row::Dense(&[1.0, 2.0, 3.0]));
        sb.push_pair_entry_dense(&Row::Sparse(&[2], &[9.0]));
        sb.seal_pair_row();
        sb.seal_pair_row(); // row 1: empty range
        assert!(sb.has_pairs());
        assert_eq!(sb.pair_indptr, vec![0, 2, 2]);
        assert_eq!(sb.pair_dense_entry(0), &[1.0, 2.0, 3.0]);
        assert_eq!(sb.pair_dense_entry(1), &[0.0, 0.0, 9.0]);
        // resize clears the payload
        sb.resize(2, 3);
        assert!(!sb.has_pairs());
        assert!(sb.pair_x.is_empty());
    }

    #[test]
    fn pair_payload_sparse_entries() {
        let mut sb = StepBatch::default();
        sb.resize_for(1, 4, true);
        sb.push_sparse_x_row(&[0], &[1.0]);
        sb.begin_pair_rows();
        sb.push_pair_entry_sparse(&[1, 3], &[2.0, -1.0]);
        sb.push_pair_entry_sparse(&[], &[]);
        sb.seal_pair_row();
        assert!(sb.has_pairs());
        assert_eq!(sb.pair_indptr, vec![0, 2]);
        assert_eq!(sb.pair_sparse_entry(0), (&[1u32, 3][..], &[2.0f32, -1.0][..]));
        assert_eq!(sb.pair_sparse_entry(1), (&[][..], &[][..]));
    }
}
