//! Compute engines: the batched hot path of the simulator.
//!
//! A delivery tick's worth of independent per-node CREATEMODEL steps is
//! expressed as one batched op over `[B, D]` matrices — the same graphs the
//! L2 JAX layer lowers to HLO (python/compile/model.py).  Two backends
//! implement the op set:
//!
//! * [`native::NativeBackend`] — pure Rust, mirrors ref.py exactly.
//! * [`pjrt::PjrtBackend`] — executes the AOT artifacts through the PJRT
//!   CPU client (runtime/), padding to the compiled shape buckets.
//!
//! The [`batched`] driver runs the gossip protocol cycle-synchronously on
//! top of either backend; the engine-parity integration test pins the two
//! backends to each other, and python/tests pins the artifacts to ref.py —
//! closing the loop Rust native == XLA == Pallas == paper math.

pub mod batched;
pub mod native;
pub mod pjrt;

use crate::gossip::create_model::Variant;
use anyhow::Result;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LearnerKind {
    Pegasos,
    Adaline,
    LogReg,
}

/// One batched CREATEMODEL op: which learner, which Algorithm-2 variant,
/// and the learner hyperparameter (λ for Pegasos, η for Adaline).
#[derive(Clone, Copy, Debug)]
pub struct StepOp {
    pub learner: LearnerKind,
    pub variant: Variant,
    pub hp: f32,
}

impl StepOp {
    /// Artifact op name, e.g. "pegasos_mu".
    pub fn op_name(&self) -> String {
        let l = match self.learner {
            LearnerKind::Pegasos => "pegasos",
            LearnerKind::Adaline => "adaline",
            LearnerKind::LogReg => "logreg",
        };
        format!("{}_{}", l, self.variant.name())
    }
}

/// Reusable batch buffers (flat row-major `[b, d]` matrices plus `[b]`
/// vectors). `w2`/`t2` are ignored for the RW variant.
#[derive(Clone, Debug, Default)]
pub struct StepBatch {
    pub b: usize,
    pub d: usize,
    pub w1: Vec<f32>,
    pub t1: Vec<f32>,
    pub w2: Vec<f32>,
    pub t2: Vec<f32>,
    pub x: Vec<f32>,
    pub y: Vec<f32>,
    pub out_w: Vec<f32>,
    pub out_t: Vec<f32>,
}

impl StepBatch {
    pub fn resize(&mut self, b: usize, d: usize) {
        self.b = b;
        self.d = d;
        self.w1.resize(b * d, 0.0);
        self.w2.resize(b * d, 0.0);
        self.x.resize(b * d, 0.0);
        self.t1.resize(b, 0.0);
        self.t2.resize(b, 0.0);
        self.y.resize(b, 0.0);
        self.out_w.resize(b * d, 0.0);
        self.out_t.resize(b, 0.0);
    }
}

/// A batched compute backend.
pub trait Backend {
    fn name(&self) -> &'static str;

    /// Apply `op` to every row of the batch, writing `out_w`/`out_t`.
    fn step(&mut self, op: &StepOp, batch: &mut StepBatch) -> Result<()>;

    /// Misclassification counts: `x` is a dense `[n, d]` test chunk with
    /// labels `y` (0 = padding row), `w` a `[m, d]` model batch; returns the
    /// per-model count of rows with `y * <w, x> <= 0`.
    fn error_counts(
        &mut self,
        x: &[f32],
        y: &[f32],
        n: usize,
        d: usize,
        w: &[f32],
        m: usize,
    ) -> Result<Vec<f32>>;
}
