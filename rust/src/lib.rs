//! # golf — Gossip Learning with Linear Models on Fully Distributed Data
//!
//! Rust + JAX/Pallas reproduction of Ormándi, Hegedűs & Jelasity (2011).
//!
//! The crate is organised in layers (see DESIGN.md):
//!
//! * [`util`] — RNG, stats, property-test and bench substrates.
//! * [`data`] — dataset containers, synthetic Table-I generators, libsvm.
//! * [`learning`] — linear models, Pegasos and Adaline online updates.
//! * [`sim`] — discrete-event P2P simulator with failure/churn models.
//! * [`p2p`] — NEWSCAST gossip-based peer sampling.
//! * [`gossip`] — the gossip-learning protocol (Algorithms 1, 2, 4).
//! * [`engine`] — compute backends: native Rust and batched PJRT.
//! * [`net`] / [`coordinator`] — deployment runtime: persistent-TCP peers
//!   over the framed wire format, orchestrated into simulator-comparable
//!   runs.
//! * [`runtime`] — XLA/PJRT artifact loading and execution.
//! * [`baselines`] — sequential Pegasos, weighted bagging, perfect matching.
//! * [`scenario`] — declarative phased failure/workload timelines driven
//!   uniformly through the simulators and the deployment.
//! * [`eval`] — 0-1 error tracking, model similarity, CSV output.
//! * [`experiments`] — drivers regenerating every paper table/figure.
//! * [`config`] / [`cli`] — experiment configuration and the `golf` binary.
//! * [`api`] — **the public front door**: `RunSpec → Session → Outcome` with
//!   typed [`api::GolfError`]s and live [`api::Observer`] progress streaming,
//!   unifying the simulators, the deployment, and the sweep grid behind one
//!   validated schema.

pub mod api;
pub mod baselines;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod eval;
pub mod experiments;
pub mod gossip;
pub mod learning;
pub mod net;
pub mod p2p;
pub mod runtime;
pub mod scenario;
pub mod sim;
pub mod util;
