//! Deployment run orchestration (L3, DESIGN.md §10, §15): lease worker
//! threads from the shared ledger, bind one listener per node *group*,
//! generate the shared wall-clock failure schedules, spawn one group
//! thread per range of nodes (`net/deploy.rs`), run the periodic
//! evaluation loop on the coordinating thread, then raise the stop flag
//! and collect per-node stats plus the convergence [`Curve`].
//!
//! The point of the coordinator is *parity*: [`run_deployment`] and a
//! `GossipSim` run built from [`matched_sim_config`] share the failure
//! models, the RNG fork order (churn schedule, evaluation-peer sample), the
//! measurement grid, and the curve format — so a deployment over real
//! sockets and a simulation of the same configuration produce curves on the
//! same axes, directly comparable point by point.

use crate::api::{NullObserver, Observer, RunEvent};
use crate::data::dataset::Dataset;
use crate::eval::tracker::{point_from_errors, Curve};
use crate::eval::zero_one_error;
use crate::gossip::protocol::ProtocolConfig;
use crate::net::deploy::{
    group_main, group_ranges, DeployConfig, GroupCtx, NodeStats, SharedRun, MAX_GROUP_NODES,
    SIM_DELTA,
};
use crate::scenario::driver::{resolve_churn_schedule, CompiledScenario, Mutation};
use crate::util::rng::Rng;
use crate::util::stats::mean;
use std::net::{SocketAddr, TcpListener};
use std::sync::atomic::Ordering;
use std::time::Instant;

/// Aggregate counters of one deployment run: sums of [`NodeStats`] plus the
/// group-runtime scheduling metrics (how hard the readiness loops worked).
#[derive(Clone, Debug, Default)]
pub struct DeployStats {
    pub messages_sent: u64,
    pub messages_received: u64,
    pub bytes_sent: u64,
    pub sim_dropped: u64,
    pub partition_blocked: u64,
    pub backlog_lost: u64,
    pub io_errors: u64,
    pub decode_errors: u64,
    pub conns_accepted: u64,
    /// sends that rode an already-open outbound connection (LRU hits)
    pub conns_reused: u64,
    /// worker threads the run actually used (post-lease)
    pub node_groups: usize,
    /// mean complete frames decoded per readiness-loop wake, across groups
    /// — high values mean the poll interval is batching well, near-zero
    /// means the loops are mostly idle spinning
    pub frames_per_wake: f64,
    /// worst observed lag between any timer's due time and its firing wake,
    /// in milliseconds — the group-runtime analogue of missed deadlines
    pub timer_lag_ms_max: f64,
}

/// Result of one deployment run: the same curve shape a `GossipSim` run
/// produces, plus deployment-side accounting.
#[derive(Clone, Debug)]
pub struct DeployReport {
    pub curve: Curve,
    /// mean 0-1 error of every node's freshest model at shutdown
    pub final_error: f64,
    /// mean freshest-model update counter (≈ learning absorbed per node)
    pub mean_model_t: f64,
    pub stats: DeployStats,
    pub per_node: Vec<NodeStats>,
}

/// The simulator configuration whose run is directly comparable to a
/// deployment of `cfg`: same protocol, failure models, seed, and
/// measurement grid, with the wall-clock Δ mapped back to [`SIM_DELTA`]
/// ticks.  Because the coordinator replicates `GossipSim`'s RNG fork order,
/// the two runs also share the churn schedule and the evaluation-peer
/// sample.
pub fn matched_sim_config(cfg: &DeployConfig) -> ProtocolConfig {
    let mut sim = ProtocolConfig::paper_default(cfg.cycles);
    sim.variant = cfg.variant;
    sim.learner = cfg.learner;
    sim.cache_size = cfg.cache_size;
    sim.delta = SIM_DELTA;
    sim.sampler = cfg.sampler;
    sim.network = cfg.network;
    sim.churn = cfg.churn;
    sim.seed = cfg.seed;
    sim.eval.n_peers = cfg.eval_peers;
    // the *resolved* grid, so a pathological eval_at_cycles (unsorted,
    // duplicated, out of range) still yields curves on identical axes
    sim.eval.at_cycles = cfg.eval_grid();
    // one shared scenario definition drives both runs: the simulator
    // compiles it at delta = SIM_DELTA, exactly the scale the deployment's
    // tick→wall-clock mapping uses
    sim.scenario = cfg.scenario.clone();
    // the graph is rebuilt from (spec, n, seed) on each side, so both runs
    // sample neighbors from the identical CSR
    sim.topology = cfg.topology.clone();
    sim
}

/// Run a real localhost deployment: spawn `cfg.n_nodes` peer threads, drive
/// churn and drop/delay injection from the simulator's models, sample the
/// evaluation peers at every measurement cycle, and shut down after the
/// last cycle.  `data.train` must have at least `n_nodes` rows; node i owns
/// row i.
#[deprecated(
    since = "0.2.0",
    note = "construct runs through api::RunSpec / api::Session (kept as a \
            thin shim so deployment-parity pins stay bit-for-bit)"
)]
pub fn run_deployment(cfg: &DeployConfig, data: &Dataset) -> std::io::Result<DeployReport> {
    run_deployment_observed(cfg, data, &mut NullObserver)
}

/// [`run_deployment`] with typed progress streaming: the coordinating
/// thread emits a [`RunEvent::Cycle`] + [`RunEvent::Eval`] pair per
/// measurement cycle (plus any compiled scenario mutations whose due time
/// has passed, observed from the coordinator's wall clock), and one
/// [`RunEvent::NodeStats`] per node at shutdown.  Observation is passive —
/// node threads are never touched by the observer.
pub fn run_deployment_observed(
    cfg: &DeployConfig,
    data: &Dataset,
    obs: &mut dyn Observer,
) -> std::io::Result<DeployReport> {
    assert!(cfg.n_nodes >= 2, "need at least two nodes");
    assert!(data.n_train() >= cfg.n_nodes, "need one training example per node");
    assert!(cfg.cycles >= 1, "need at least one cycle");
    let n = cfg.n_nodes;
    let d = data.d();

    // ---- resolved gossip graph (consumes no shared RNG: generators derive
    // private streams from (seed, "topo/…"), so the fork order below is
    // untouched and a matched simulator builds the identical CSR)
    let topology = cfg.topology.as_ref().map(|spec| {
        std::sync::Arc::new(
            crate::p2p::Topology::build(spec, n, cfg.seed)
                .expect("topology must be validated before the deployment runs"),
        )
    });

    // ---- compiled scenario timeline (one definition shared by the node
    // threads, the evaluation loop, and any matched simulator run)
    let compiled = cfg.scenario.as_ref().map(|s| {
        std::sync::Arc::new(
            CompiledScenario::compile(
                s,
                n,
                SIM_DELTA,
                cfg.cycles,
                cfg.seed,
                cfg.network,
                topology.as_deref(),
            )
            .expect("scenario must be validated before the deployment runs"),
        )
    });
    let initial = compiled.as_ref().map_or(n, |c| c.initial);

    // ---- shared failure schedule + evaluation peers, in GossipSim's exact
    // RNG fork order so a matched simulator run sees the same draws
    let mut rng = Rng::new(cfg.seed);
    let horizon = SIM_DELTA * (cfg.cycles + 1);
    let churn = resolve_churn_schedule(
        cfg.churn.as_ref(),
        compiled.as_deref(),
        n,
        SIM_DELTA,
        horizon,
        &mut rng,
    );
    let _sampler_rng = rng.fork(); // the simulator's sampler stream (deployment samplers are per-node)
    let mut eval_rng = rng.fork();
    // the simulator samples evaluation peers over its *initial* membership
    let eval_peers = eval_rng.sample_indices(initial, cfg.eval_peers.min(initial));

    // ---- worker-thread budget: lease the resolved group count from the
    // shared ledger so deployments compose with sweeps and shard runners.
    // A drained ledger degrades toward fewer groups, but never below the
    // floor that keeps every group within MAX_GROUP_NODES — the per-group
    // fd and scan budget is a harder constraint than oversubscription.
    let min_groups = n.div_ceil(MAX_GROUP_NODES).max(1);
    let lease = crate::util::threads::lease(cfg.resolved_groups());
    let groups = lease.granted().max(min_groups).min(n);
    let ranges = group_ranges(n, groups);

    // ---- bind one listener per group so every peer knows every address
    // (a node's address is its group's listener; routed frames carry the
    // destination id the socket no longer implies)
    let listeners: Vec<TcpListener> = ranges
        .iter()
        .map(|_| {
            let l = TcpListener::bind(("127.0.0.1", 0))?;
            l.set_nonblocking(true)?;
            Ok(l)
        })
        .collect::<std::io::Result<_>>()?;
    let mut addrs: Vec<SocketAddr> = Vec::with_capacity(n);
    for (range, l) in ranges.iter().zip(&listeners) {
        let a = l.local_addr()?;
        addrs.extend(std::iter::repeat(a).take(range.len()));
    }

    let shared = SharedRun::new(n, d);
    let start = Instant::now();

    let (curve, reports) = std::thread::scope(|scope| {
        let handles: Vec<_> = ranges
            .iter()
            .zip(listeners)
            .map(|(range, listener)| {
                let ctx = GroupCtx {
                    nodes: range.clone(),
                    listener,
                    addrs: &addrs,
                    cfg,
                    data,
                    churn: churn.as_ref(),
                    scn: compiled.as_ref(),
                    topo: topology.as_ref(),
                    start,
                    shared: &shared,
                };
                scope.spawn(move || group_main(ctx))
            })
            .collect();

        // ---- evaluation loop on the coordinating thread
        let curve =
            eval_loop(cfg, data, &eval_peers, compiled.as_deref(), &shared, start, &mut *obs);

        // the run length is cfg.cycles regardless of the measurement grid
        // (a sparse eval_at_cycles must not truncate the deployment)
        let end = start + cfg.cycle_offset(cfg.cycles);
        let now = Instant::now();
        if end > now {
            std::thread::sleep(end - now);
        }

        // ---- shutdown and collect
        shared.stop.store(true, Ordering::SeqCst);
        let reports: Vec<_> =
            handles.into_iter().map(|h| h.join().expect("group thread panicked")).collect();
        (curve, reports)
    });
    drop(lease);

    // group ranges are contiguous and ascending, so concatenating the
    // per-group reports restores node order 0..n
    let per_node: Vec<NodeStats> =
        reports.iter().flat_map(|r| r.per_node.iter().cloned()).collect();

    // ---- final sweep over every *member* node's published model (nodes a
    // scenario never grew into stay out of the average), against the test
    // labels of the concept in force at the horizon
    let members = compiled.as_ref().map_or(n, |c| c.final_membership().min(n));
    let flipped;
    let final_y: &[f32] = if drift_sign_at(compiled.as_deref(), horizon) < 0.0 {
        flipped = crate::eval::flipped_labels(&data.test_y);
        &flipped
    } else {
        &data.test_y
    };
    let mut errs = Vec::with_capacity(members);
    for slot in &shared.models[..members] {
        let m = slot.lock().unwrap().clone();
        errs.push(zero_one_error(&m, &data.test, final_y));
    }

    for (node, s) in per_node.iter().enumerate() {
        obs.on_event(&RunEvent::NodeStats {
            node,
            sent: s.sent,
            received: s.received,
            bytes_sent: s.bytes_sent,
        });
    }

    let mut stats = DeployStats::default();
    for s in &per_node {
        stats.messages_sent += s.sent;
        stats.messages_received += s.received;
        stats.bytes_sent += s.bytes_sent;
        stats.sim_dropped += s.sim_dropped;
        stats.partition_blocked += s.partition_blocked;
        stats.backlog_lost += s.backlog_lost;
        stats.io_errors += s.io_errors;
        stats.decode_errors += s.decode_errors;
        stats.conns_reused += s.conns_reused;
    }
    stats.node_groups = groups;
    let (mut wakes, mut frames) = (0u64, 0u64);
    for r in &reports {
        stats.conns_accepted += r.conns_accepted;
        // poisoned streams and misrouted frames are both framing-layer
        // failures the per-node counters cannot see
        stats.decode_errors += r.decode_errors + r.misrouted;
        stats.timer_lag_ms_max = stats.timer_lag_ms_max.max(r.timer_lag_max.as_secs_f64() * 1e3);
        wakes += r.wakes;
        frames += r.frames;
    }
    stats.frames_per_wake = if wakes > 0 { frames as f64 / wakes as f64 } else { 0.0 };
    let mean_model_t = mean(&per_node.iter().map(|s| s.model_t as f64).collect::<Vec<_>>());

    Ok(DeployReport {
        curve,
        final_error: mean(&errs),
        mean_model_t,
        stats,
        per_node,
    })
}

/// The concept's label sign after every scenario drift at or before `now`
/// (+1.0 with no scenario or an even number of drifts).
fn drift_sign_at(scn: Option<&CompiledScenario>, now: crate::sim::event::Ticks) -> f32 {
    let mut sign = 1.0f32;
    if let Some(c) = scn {
        for (t, m) in &c.muts {
            if *t <= now && matches!(m, Mutation::Drift) {
                sign = -sign;
            }
        }
    }
    sign
}

/// Sleep to each measurement-cycle boundary, sample the evaluation peers'
/// published models, and emit the same `EvalPoint`s a simulator run
/// produces (mean/std 0-1 error over the sampled peers, network-wide send
/// count).  Under a scenario with concept drift, each point scores against
/// the labels of the concept in force at that cycle — matching the
/// simulator's drift-aware measurement.
fn eval_loop(
    cfg: &DeployConfig,
    data: &Dataset,
    eval_peers: &[usize],
    scn: Option<&CompiledScenario>,
    shared: &SharedRun,
    start: Instant,
    obs: &mut dyn Observer,
) -> Curve {
    let cycles = cfg.eval_grid();
    let mut curve = Curve::new(format!(
        "{}-{}-{}-deploy",
        cfg.learner.name(),
        cfg.variant.name(),
        cfg.sampler.name()
    ));
    let mut flipped: Option<Vec<f32>> = None;
    // scenario mutations are applied inside the node threads; the
    // coordinator streams them as their due times pass its wall clock
    let mut next_mut = 0usize;
    for &c in &cycles {
        let due = start + cfg.cycle_offset(c);
        let now = Instant::now();
        if due > now {
            std::thread::sleep(due - now);
        }
        if let Some(scn) = scn {
            while next_mut < scn.muts.len() && scn.muts[next_mut].0 <= c * SIM_DELTA {
                let (t, m) = &scn.muts[next_mut];
                obs.on_event(&RunEvent::Scenario {
                    cycle: t / SIM_DELTA,
                    mutation: m.describe(),
                });
                next_mut += 1;
            }
        }
        obs.on_event(&RunEvent::Cycle { cycle: c });
        let y: &[f32] = if drift_sign_at(scn, c * SIM_DELTA) < 0.0 {
            flipped.get_or_insert_with(|| crate::eval::flipped_labels(&data.test_y))
        } else {
            &data.test_y
        };
        let errs: Vec<f64> = eval_peers
            .iter()
            .map(|&p| {
                let m = shared.models[p].lock().unwrap().clone();
                zero_one_error(&m, &data.test, y)
            })
            .collect();
        let pt = point_from_errors(
            c,
            &errs,
            None,
            None,
            None,
            shared.messages_sent.load(Ordering::Relaxed),
        );
        obs.on_event(&RunEvent::Eval { point: pt.clone() });
        curve.push(pt);
    }
    // mutations due after the last measurement cycle still apply inside the
    // node threads before shutdown — stream them too, so the Deploy target's
    // scenario-event stream covers the whole timeline like Sim/Batched
    if let Some(scn) = scn {
        while next_mut < scn.muts.len() && scn.muts[next_mut].0 <= cfg.cycles * SIM_DELTA {
            let (t, m) = &scn.muts[next_mut];
            obs.on_event(&RunEvent::Scenario {
                cycle: t / SIM_DELTA,
                mutation: m.describe(),
            });
            next_mut += 1;
        }
    }
    curve
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gossip::create_model::Variant;
    use crate::p2p::overlay::SamplerConfig;
    use crate::sim::network::NetworkConfig;

    #[test]
    fn matched_sim_config_mirrors_deployment() {
        let dcfg = DeployConfig {
            n_nodes: 10,
            cycles: 17,
            variant: Variant::Um,
            cache_size: 5,
            sampler: SamplerConfig::Oracle,
            eval_peers: 7,
            eval_at_cycles: vec![1, 5, 17],
            seed: 99,
            topology: crate::p2p::TopologySpec::parse("ring:2").unwrap(),
            ..Default::default()
        }
        .with_extreme_failures();
        let sim = matched_sim_config(&dcfg);
        assert_eq!(sim.cycles, 17);
        assert_eq!(sim.variant, Variant::Um);
        assert_eq!(sim.cache_size, 5);
        assert_eq!(sim.delta, SIM_DELTA);
        assert_eq!(sim.sampler, SamplerConfig::Oracle);
        assert_eq!(sim.seed, 99);
        assert_eq!(sim.eval.n_peers, 7);
        assert_eq!(sim.eval.at_cycles, vec![1, 5, 17]);
        assert!(sim.churn.is_some(), "churn model must carry over");
        assert_eq!(sim.network.drop_prob, NetworkConfig::extreme(SIM_DELTA).drop_prob);
        assert_eq!(sim.topology, dcfg.topology, "graph constraint must carry over");
    }

    /// The coordinator must derive the same evaluation peers a matched
    /// simulator run samples (same seed, same fork order).
    #[test]
    fn eval_peer_sample_matches_sim_fork_order() {
        let seed = 4242u64;
        let n = 50;
        let n_peers = 12;
        // coordinator's derivation, churn disabled (no churn fork)
        let mut rng = Rng::new(seed);
        let _sampler = rng.fork();
        let mut eval_rng = rng.fork();
        let ours = eval_rng.sample_indices(n, n_peers);
        // GossipSim::with_backend's derivation for churn = None
        let mut sim_rng = Rng::new(seed);
        let _sim_sampler = sim_rng.fork();
        let mut sim_eval = sim_rng.fork();
        let sims = sim_eval.sample_indices(n, n_peers);
        assert_eq!(ours, sims);
    }
}
