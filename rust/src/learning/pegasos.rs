//! Pegasos: primal estimated sub-gradient SVM solver (Shalev-Shwartz et al.),
//! exactly as instantiated in the paper's Algorithm 3 UPDATEPEGASOS.
//!
//! Semantics must match the L1 Pallas kernel (python/compile/kernels/
//! pegasos.py) — the engine-parity integration test compares full
//! trajectories between this implementation and the PJRT artifacts.

use crate::data::dataset::Row;
use crate::learning::linear::LinearModel;

#[derive(Clone, Copy, Debug)]
pub struct Pegasos {
    pub lambda: f32,
}

impl Pegasos {
    pub fn new(lambda: f32) -> Self {
        assert!(lambda > 0.0, "lambda must be positive");
        Pegasos { lambda }
    }

    /// One online update with the local example (x, y).
    ///
    /// ```text
    /// t <- t + 1;  eta <- 1/(lambda t)
    /// if y<w,x> < 1:  w <- (1-eta lambda) w + eta y x
    /// else:           w <- (1-eta lambda) w
    /// ```
    /// Note (1 - eta*lambda) = 1 - 1/t, so the decay is scale-only — O(1)
    /// with the lazy-scale model representation.
    #[inline]
    pub fn update(&self, m: &mut LinearModel, x: &Row<'_>, y: f32) {
        m.t += 1;
        let t = m.t as f32;
        let eta = 1.0 / (self.lambda * t);
        let margin = y * m.raw_margin(x);
        m.scale_by(1.0 - 1.0 / t);
        if margin < 1.0 {
            m.add_scaled(eta * y, x);
        }
    }

    /// Theoretical bound used by the property tests: the Pegasos iterate
    /// stays within the ball of radius 1/sqrt(lambda) (after the first step)
    /// when examples have norm <= 1... we use the weaker generic bound
    /// max(R/lambda) growth check instead; see tests/properties.rs.
    pub fn lambda(&self) -> f32 {
        self.lambda
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Row;

    #[test]
    fn first_update_from_zero_model() {
        // margin = 0 < 1 -> w1 = eta * y * x = y x / lambda (t=1)
        let p = Pegasos::new(0.01);
        let mut m = LinearModel::zeros(3);
        let x = [1.0, -2.0, 0.5];
        p.update(&mut m, &Row::Dense(&x), 1.0);
        let w = m.weights();
        for (wi, xi) in w.iter().zip(&x) {
            assert!((wi - xi / 0.01).abs() < 1e-3, "{wi} vs {}", xi / 0.01);
        }
        assert_eq!(m.t, 1);
    }

    #[test]
    fn confident_correct_only_decays() {
        let p = Pegasos::new(0.1);
        let mut m = LinearModel::from_weights(vec![1.0; 4], 9);
        // <w,x> = 4, y = 1 -> margin 4 >= 1: pure decay by (1 - 1/10)
        p.update(&mut m, &Row::Dense(&[1.0; 4]), 1.0);
        for w in m.weights() {
            assert!((w - 0.9).abs() < 1e-6);
        }
    }

    #[test]
    fn misclassified_moves_toward_label() {
        let p = Pegasos::new(0.01);
        let mut m = LinearModel::from_weights(vec![-1.0, 0.0], 4);
        let x = [1.0, 0.0];
        let before = m.raw_margin(&Row::Dense(&x));
        p.update(&mut m, &Row::Dense(&x), 1.0);
        let after = m.raw_margin(&Row::Dense(&x));
        assert!(after > before);
    }

    #[test]
    fn converges_to_low_error_on_separable_blob() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(42);
        let d = 10;
        let w_star: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let p = Pegasos::new(1e-3);
        let mut m = LinearModel::zeros(d);
        let mut xs = Vec::new();
        for _ in 0..500 {
            let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            let y = if crate::data::dataset::dense_dot(&x, &w_star) > 0.0 { 1.0 } else { -1.0 };
            xs.push((x, y));
        }
        for epoch in 0..20 {
            let _ = epoch;
            for (x, y) in &xs {
                p.update(&mut m, &Row::Dense(x), *y);
            }
        }
        let errs = xs
            .iter()
            .filter(|(x, y)| m.predict(&Row::Dense(x)) != *y)
            .count();
        assert!(
            (errs as f64) < 0.03 * xs.len() as f64,
            "train error too high: {errs}/{}",
            xs.len()
        );
    }

    #[test]
    #[should_panic]
    fn zero_lambda_rejected() {
        Pegasos::new(0.0);
    }
}
