//! L2-regularized online logistic regression (beyond-paper extension —
//! Section VII claims gossip learning generalizes across online learners).
//!
//! Uses the Pegasos step schedule eta_t = 1/(lambda t) with the log-loss
//! gradient; mirrors python/compile/kernels/logreg.py exactly.

use crate::data::dataset::Row;
use crate::learning::linear::LinearModel;

#[derive(Clone, Copy, Debug)]
pub struct LogReg {
    pub lambda: f32,
}

impl LogReg {
    pub fn new(lambda: f32) -> Self {
        assert!(lambda > 0.0, "lambda must be positive");
        LogReg { lambda }
    }

    /// w <- (1 - eta*lam) w + eta (y01 - sigmoid(<w,x>)) x
    #[inline]
    pub fn update(&self, m: &mut LinearModel, x: &Row<'_>, y: f32) {
        m.t += 1;
        let t = m.t as f32;
        let eta = 1.0 / (self.lambda * t);
        let z = m.raw_margin(x);
        let p = 1.0 / (1.0 + (-z).exp());
        let y01 = (y + 1.0) * 0.5;
        m.scale_by(1.0 - 1.0 / t); // (1 - eta*lam) = 1 - 1/t
        m.add_scaled(eta * (y01 - p), x);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn moves_probability_toward_label() {
        let lr = LogReg::new(0.1);
        let mut m = LinearModel::zeros(3);
        let x = [1.0, 0.5, -0.5];
        for _ in 0..200 {
            lr.update(&mut m, &Row::Dense(&x), 1.0);
        }
        let p = 1.0 / (1.0 + (-m.raw_margin(&Row::Dense(&x))).exp());
        assert!(p > 0.8, "p = {p}");
    }

    #[test]
    fn separates_a_simple_blob() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(9);
        let lr = LogReg::new(1e-2);
        let d = 8;
        let w_star: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let mut m = LinearModel::zeros(d);
        let mut errs = 0;
        let mut total = 0;
        for step in 0..4000 {
            let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            let y = if crate::data::dataset::dense_dot(&x, &w_star) > 0.0 { 1.0 } else { -1.0 };
            if step > 3000 {
                total += 1;
                if m.predict(&Row::Dense(&x)) != y {
                    errs += 1;
                }
            }
            lr.update(&mut m, &Row::Dense(&x), y);
        }
        assert!((errs as f64) < 0.08 * total as f64, "{errs}/{total}");
    }

    #[test]
    fn matches_reference_math() {
        // hand-computed single step from zeros: p = 0.5, y01 = 1
        // w1 = eta * 0.5 * x with eta = 1/lam (t=1)
        let lr = LogReg::new(0.5);
        let mut m = LinearModel::zeros(2);
        let x = [2.0, -1.0];
        lr.update(&mut m, &Row::Dense(&x), 1.0);
        let w = m.weights();
        assert!((w[0] - 2.0 * 0.5 * 2.0).abs() < 1e-5, "{w:?}");
        assert!((w[1] + 2.0 * 0.5 * 1.0).abs() < 1e-5, "{w:?}");
    }
}
