//! Online learners and the linear-model algebra (Algorithm 3): Pegasos,
//! Adaline, and merge-by-averaging.
pub mod adaline;
pub mod linear;
pub mod logreg;
pub mod pegasos;

pub use adaline::{Adaline, Learner};
pub use linear::LinearModel;
pub use logreg::LogReg;
pub use pegasos::Pegasos;
