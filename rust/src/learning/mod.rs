//! Online learners and the linear-model algebra (Algorithm 3): Pegasos,
//! Adaline, merge-by-averaging, and the pairwise AUC family (reservoirs +
//! quorum merge, DESIGN.md §17).
pub mod adaline;
pub mod linear;
pub mod logreg;
pub mod pairwise;
pub mod pegasos;

pub use adaline::{Adaline, Learner};
pub use linear::LinearModel;
pub use logreg::LogReg;
pub use pairwise::{MergeMode, PairwiseAuc};
pub use pegasos::Pegasos;
