//! Adaline perceptron (Widrow-Hoff LMS), paper Section V-A Eq. (5).
//!
//! The linear activation makes its update commute with averaging (Eq. 8),
//! which is the paper's motivating exact case for merge-as-voting.

use crate::data::dataset::Row;
use crate::learning::linear::LinearModel;

#[derive(Clone, Copy, Debug)]
pub struct Adaline {
    pub eta: f32,
}

impl Adaline {
    pub fn new(eta: f32) -> Self {
        assert!(eta > 0.0, "eta must be positive");
        Adaline { eta }
    }

    /// w <- w + eta (y - <w,x>) x
    #[inline]
    pub fn update(&self, m: &mut LinearModel, x: &Row<'_>, y: f32) {
        let err = y - m.raw_margin(x);
        m.add_scaled(self.eta * err, x);
        m.t += 1;
    }
}

/// Learner selection for the gossip protocol (enum dispatch keeps the
/// per-message hot path monomorphic and allocation-free).
#[derive(Clone, Copy, Debug)]
pub enum Learner {
    Pegasos(super::pegasos::Pegasos),
    Adaline(Adaline),
    LogReg(super::logreg::LogReg),
    PairwiseAuc(super::pairwise::PairwiseAuc),
}

impl Learner {
    pub fn pegasos(lambda: f32) -> Self {
        Learner::Pegasos(super::pegasos::Pegasos::new(lambda))
    }

    pub fn adaline(eta: f32) -> Self {
        Learner::Adaline(Adaline::new(eta))
    }

    pub fn logreg(lambda: f32) -> Self {
        Learner::LogReg(super::logreg::LogReg::new(lambda))
    }

    pub fn pairwise_auc(lambda: f32) -> Self {
        Learner::PairwiseAuc(super::pairwise::PairwiseAuc::new(lambda))
    }

    /// One pointwise step.  [`Learner::PairwiseAuc`] has no pointwise form —
    /// its step pairs the local example against the model's reservoir
    /// (`pairwise::PairwiseAuc::update_with_reservoir`), so here it is a
    /// deliberate no-op (no decay, no `t` bump).
    #[inline]
    pub fn update(&self, m: &mut LinearModel, x: &Row<'_>, y: f32) {
        match self {
            Learner::Pegasos(p) => p.update(m, x, y),
            Learner::Adaline(a) => a.update(m, x, y),
            Learner::LogReg(l) => l.update(m, x, y),
            Learner::PairwiseAuc(_) => {}
        }
    }

    /// Whether steps consume the walking model's example reservoir.
    pub fn is_pairwise(&self) -> bool {
        matches!(self, Learner::PairwiseAuc(_))
    }

    /// The pairwise learner itself, when this is [`Learner::PairwiseAuc`] —
    /// scalar paths (deployment runtime, reference tests) call its
    /// reservoir-consuming step directly.
    pub fn as_pairwise(&self) -> Option<&super::pairwise::PairwiseAuc> {
        match self {
            Learner::PairwiseAuc(p) => Some(p),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Learner::Pegasos(_) => "pegasos",
            Learner::Adaline(_) => "adaline",
            Learner::LogReg(_) => "logreg",
            Learner::PairwiseAuc(_) => "pairwise-auc",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Row;
    use crate::learning::linear::LinearModel;

    #[test]
    fn lms_step_reduces_error() {
        let a = Adaline::new(0.1);
        let mut m = LinearModel::zeros(2);
        let x = [1.0, 1.0];
        for _ in 0..100 {
            a.update(&mut m, &Row::Dense(&x), 1.0);
        }
        assert!((m.raw_margin(&Row::Dense(&x)) - 1.0).abs() < 1e-3);
        assert_eq!(m.t, 100);
    }

    #[test]
    fn update_merge_commute_eq8() {
        // Eq. (8): update(avg(w1,w2)) == avg(update(w1), update(w2))
        use crate::util::rng::Rng;
        let mut rng = Rng::new(11);
        let a = Adaline::new(0.05);
        let d = 6;
        for _ in 0..50 {
            let w1 = LinearModel::from_weights(
                (0..d).map(|_| rng.normal() as f32).collect(),
                0,
            );
            let w2 = LinearModel::from_weights(
                (0..d).map(|_| rng.normal() as f32).collect(),
                0,
            );
            let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            let y = rng.sign();

            let mut avg_then_update = LinearModel::merge(&w1, &w2);
            a.update(&mut avg_then_update, &Row::Dense(&x), y);

            let mut u1 = w1.clone();
            let mut u2 = w2.clone();
            a.update(&mut u1, &Row::Dense(&x), y);
            a.update(&mut u2, &Row::Dense(&x), y);
            let update_then_avg = LinearModel::merge(&u1, &u2);

            for (p, q) in avg_then_update
                .weights()
                .iter()
                .zip(update_then_avg.weights())
            {
                assert!((p - q).abs() < 1e-5, "{p} vs {q}");
            }
        }
    }

    #[test]
    fn learner_enum_dispatch() {
        let l = Learner::pegasos(0.01);
        assert_eq!(l.name(), "pegasos");
        let mut m = LinearModel::zeros(2);
        l.update(&mut m, &Row::Dense(&[1.0, 0.0]), 1.0);
        assert_eq!(m.t, 1);
        let l = Learner::adaline(0.1);
        assert_eq!(l.name(), "adaline");
    }
}
