//! Linear model representation shared by the whole system.
//!
//! A model is a dense weight vector `w` plus the Pegasos update counter `t`
//! (Algorithm 3).  The hot-path representation keeps an explicit scalar
//! `scale` so the Pegasos decay `(1 - eta*lambda) * w` is O(1) instead of
//! O(d), and sparse example updates touch only the non-zero coordinates —
//! the classic Pegasos trick; see the perf notes in DESIGN.md §7.

use crate::data::dataset::Row;

#[derive(Clone, Debug)]
pub struct LinearModel {
    /// unscaled weights; effective model is `scale * v`
    v: Vec<f32>,
    /// lazy global scale factor
    scale: f32,
    /// Pegasos update counter (Algorithm 3 `m.t`)
    pub t: u64,
}

/// Below this scale the weights are re-materialized to avoid f32 underflow.
///
/// Shared by [`LinearModel`] and the engine's sparse row kernels
/// (`engine/native.rs`) through [`scale_in_place`] so the two lazy-scale
/// implementations stay pinned to the same semantics.
pub const SCALE_FLOOR: f32 = 1e-20;

/// `(v, scale) *= c` with the [`SCALE_FLOOR`] re-materialization: when the
/// scale drifts below the floor (including the exact-zero Pegasos first
/// step), it is folded into the weights and reset to 1.
///
/// This free-function form of [`LinearModel::scale_by`] is the single
/// implementation of the lazy-scale decay; the engine's O(nnz) sparse
/// kernels call it on `StepBatch` rows.
#[inline]
pub fn scale_in_place(v: &mut [f32], scale: &mut f32, c: f32) {
    *scale *= c;
    if scale.abs() < SCALE_FLOOR {
        let s = *scale;
        for w in v.iter_mut() {
            *w *= s;
        }
        *scale = 1.0;
    }
}

/// `(v, scale) += c * x` for a sparse `x` given as (indices, values) —
/// touches only the non-zero coordinates.  Mirrors
/// [`LinearModel::add_scaled`] (including the dead-model reset at scale 0)
/// so chained engine-kernel updates are bit-for-bit identical to the scalar
/// lazy-scale path.
#[inline]
pub fn add_scaled_sparse_in_place(v: &mut [f32], scale: &mut f32, c: f32, idx: &[u32], val: &[f32]) {
    if *scale == 0.0 {
        // dead model: reset to exact zeros
        v.fill(0.0);
        *scale = 1.0;
    }
    let coef = c / *scale;
    for (&j, &x) in idx.iter().zip(val) {
        v[j as usize] += coef * x;
    }
}

impl LinearModel {
    pub fn zeros(d: usize) -> Self {
        LinearModel { v: vec![0.0; d], scale: 1.0, t: 0 }
    }

    pub fn from_weights(w: Vec<f32>, t: u64) -> Self {
        LinearModel { v: w, scale: 1.0, t }
    }

    pub fn dim(&self) -> usize {
        self.v.len()
    }

    /// Raw margin <w, x>.
    #[inline]
    pub fn raw_margin(&self, x: &Row<'_>) -> f32 {
        self.scale * x.dot(&self.v)
    }

    /// Predicted label in {-1, +1}; the all-zeros init model predicts -1
    /// (sign(0) <= 0 counts as a miss against y=+1, matching the evaluator).
    pub fn predict(&self, x: &Row<'_>) -> f32 {
        if self.raw_margin(x) > 0.0 {
            1.0
        } else {
            -1.0
        }
    }

    /// w *= c (lazy, O(1)).
    #[inline]
    pub fn scale_by(&mut self, c: f32) {
        scale_in_place(&mut self.v, &mut self.scale, c);
    }

    /// w += c * x.
    #[inline]
    pub fn add_scaled(&mut self, c: f32, x: &Row<'_>) {
        if self.scale == 0.0 {
            // dead model: reset to exact zeros
            self.v.fill(0.0);
            self.scale = 1.0;
        }
        x.add_scaled_into(c / self.scale, &mut self.v);
    }

    /// Fold the lazy scale into the weights.
    pub fn materialize(&mut self) {
        if self.scale != 1.0 {
            let s = self.scale;
            for w in &mut self.v {
                *w *= s;
            }
            self.scale = 1.0;
        }
    }

    /// Materialized weight slice (requires `materialize` for zero-copy; this
    /// clones only when a lazy scale is pending).
    pub fn weights(&self) -> Vec<f32> {
        if self.scale == 1.0 {
            self.v.clone()
        } else {
            self.v.iter().map(|&w| w * self.scale).collect()
        }
    }

    /// Write the effective weights into `out` (no allocation).
    pub fn write_weights(&self, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.v.len());
        if self.scale == 1.0 {
            out.copy_from_slice(&self.v);
        } else {
            for (o, &w) in out.iter_mut().zip(&self.v) {
                *o = w * self.scale;
            }
        }
    }

    pub fn norm_sq(&self) -> f32 {
        self.scale * self.scale * crate::data::dataset::dense_dot(&self.v, &self.v)
    }

    /// MERGE (Algorithm 3): fresh model = average of the two, t = max.
    pub fn merge(a: &LinearModel, b: &LinearModel) -> LinearModel {
        debug_assert_eq!(a.dim(), b.dim());
        let mut v = Vec::with_capacity(a.dim());
        for (&wa, &wb) in a.v.iter().zip(&b.v) {
            v.push(0.5 * (wa * a.scale + wb * b.scale));
        }
        LinearModel { v, scale: 1.0, t: a.t.max(b.t) }
    }

    /// In-place variant: self = (self + other)/2, t = max.
    pub fn merge_from(&mut self, other: &LinearModel) {
        debug_assert_eq!(self.dim(), other.dim());
        let (sa, sb) = (self.scale, other.scale);
        for (wa, &wb) in self.v.iter_mut().zip(&other.v) {
            *wa = 0.5 * (*wa * sa + wb * sb);
        }
        self.scale = 1.0;
        self.t = self.t.max(other.t);
    }

    /// Cosine similarity between two models (0 when either is zero).
    pub fn cosine(a: &LinearModel, b: &LinearModel) -> f32 {
        let num: f32 = a.v.iter().zip(&b.v).map(|(x, y)| x * y).sum::<f32>()
            * a.scale
            * b.scale;
        let den = (a.norm_sq() * b.norm_sq()).sqrt();
        if den > 0.0 {
            num / den
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Row;

    #[test]
    fn zero_model_predicts_negative() {
        let m = LinearModel::zeros(3);
        assert_eq!(m.predict(&Row::Dense(&[1.0, 1.0, 1.0])), -1.0);
    }

    #[test]
    fn lazy_scale_equals_eager() {
        let mut a = LinearModel::from_weights(vec![1.0, -2.0, 3.0], 5);
        let mut b = LinearModel::from_weights(vec![1.0, -2.0, 3.0], 5);
        let x = [0.5, 0.5, 0.5];
        a.scale_by(0.25);
        a.add_scaled(2.0, &Row::Dense(&x));
        // eager version
        let mut bw = b.weights();
        for w in &mut bw {
            *w *= 0.25;
        }
        for (w, &xi) in bw.iter_mut().zip(&x) {
            *w += 2.0 * xi;
        }
        for (wa, wb) in a.weights().iter().zip(&bw) {
            assert!((wa - wb).abs() < 1e-6);
        }
    }

    #[test]
    fn merge_is_average_and_max_t() {
        let a = LinearModel::from_weights(vec![2.0, 0.0], 3);
        let mut b = LinearModel::from_weights(vec![0.0, 4.0], 7);
        b.scale_by(0.5); // effective [0, 2]
        let m = LinearModel::merge(&a, &b);
        assert_eq!(m.weights(), vec![1.0, 1.0]);
        assert_eq!(m.t, 7);
    }

    #[test]
    fn merge_from_matches_merge() {
        let a = LinearModel::from_weights(vec![1.0, 2.0, 3.0], 2);
        let b = LinearModel::from_weights(vec![-1.0, 0.0, 5.0], 9);
        let m = LinearModel::merge(&a, &b);
        let mut c = a.clone();
        c.merge_from(&b);
        assert_eq!(c.weights(), m.weights());
        assert_eq!(c.t, m.t);
    }

    #[test]
    fn scale_floor_rematerializes() {
        let mut m = LinearModel::from_weights(vec![1.0e10], 0);
        for _ in 0..2000 {
            m.scale_by(0.1);
        }
        // would have underflowed the scale; value must still be finite & tiny
        let w = m.weights();
        assert!(w[0].abs() < 1e-6);
        assert!(w[0].is_finite());
    }

    #[test]
    fn cosine_limits() {
        let a = LinearModel::from_weights(vec![1.0, 0.0], 0);
        let b = LinearModel::from_weights(vec![2.0, 0.0], 0);
        let c = LinearModel::from_weights(vec![-1.0, 0.0], 0);
        let z = LinearModel::zeros(2);
        assert!((LinearModel::cosine(&a, &b) - 1.0).abs() < 1e-6);
        assert!((LinearModel::cosine(&a, &c) + 1.0).abs() < 1e-6);
        assert_eq!(LinearModel::cosine(&a, &z), 0.0);
    }

    #[test]
    fn in_place_helpers_match_model_methods_bitwise() {
        // The engine's sparse kernels run on (slice, scale) pairs through
        // scale_in_place / add_scaled_sparse_in_place; they must stay pinned
        // bit-for-bit to the LinearModel lazy-scale ops.
        let idx = [1u32, 3];
        let val = [0.5f32, -2.0];
        let mut m = LinearModel::from_weights(vec![1.0, -2.0, 3.0, 0.25], 0);
        let mut v = vec![1.0f32, -2.0, 3.0, 0.25];
        let mut s = 1.0f32;
        for step in 0..300 {
            let c = if step % 7 == 0 { 0.0 } else { 1.0 - 1.0 / (step as f32 + 2.0) };
            m.scale_by(c);
            scale_in_place(&mut v, &mut s, c);
            m.add_scaled(0.1, &Row::Sparse(&idx, &val));
            add_scaled_sparse_in_place(&mut v, &mut s, 0.1, &idx, &val);
        }
        let eff: Vec<f32> = v.iter().map(|&w| w * s).collect();
        assert_eq!(eff, m.weights());
    }

    #[test]
    fn write_weights_no_alloc_path() {
        let mut m = LinearModel::from_weights(vec![2.0, 4.0], 0);
        m.scale_by(0.5);
        let mut out = vec![0.0; 2];
        m.write_weights(&mut out);
        assert_eq!(out, vec![1.0, 2.0]);
    }
}
