//! Pairwise-objective gossip (DESIGN.md §17): AUC ranking via per-model
//! example reservoirs and a quorum-vote merge mode.
//!
//! The paper's learners are strictly pointwise — each random-walk step
//! consumes the one local example.  Pairwise objectives (AUC ranking, "On
//! Gossip Algorithms for Machine Learning with Pairwise Objectives",
//! PAPERS.md) need *two* examples from different nodes per step.  The fit
//! with the model-walk machinery is the U-statistic trick: every walking
//! model carries a small **reservoir** of previously visited examples, so a
//! step at node `i` can pair the local `(x, y)` against each reservoir entry
//! of the opposite class.
//!
//! Three pieces live here:
//!
//! 1. the reservoir encoding + Algorithm-R `offer` (seed-deterministic: one
//!    RNG draw per offer, keyed off the destination node's RNG stream);
//! 2. the [`PairwiseAuc`] learner — one Pegasos hinge step on the difference
//!    vector `z = y (x − x_j)` with implicit label `+1` per opposite-class
//!    reservoir entry (the hinge ranking loss `[1 − (s(x⁺) − s(x⁻))]₊`);
//! 3. the [`MergeMode::Quorum`] coordinate-wise merge (majority-vote rumor
//!    spreading style): where two models agree in sign, average; where they
//!    disagree, abstain (zero).
//!
//! The scalar paths here are the reference semantics; the engine kernels in
//! `engine/native.rs` reproduce them bit-for-bit on `StepBatch` rows (the
//! same contract the pointwise kernels honor).
//!
//! ## Reservoir encoding
//!
//! A reservoir is a plain `Vec<f32>` so it can ride the existing pooled
//! weight-buffer machinery (`util/pool.rs`) and the wire layer without any
//! new buffer type:
//!
//! ```text
//! [ seen_bits, node0_bits, y0, node1_bits, y1, ... ]   len = 1 + 2·K
//! ```
//!
//! `seen_bits` and `node*_bits` are `u32` values bit-cast into the `f32`
//! slots (`f32::from_bits`) — exact for the full `u32` range; `y*` are real
//! floats.  The live entry count is `min(seen, K)` — no explicit length
//! field.  A freshly pooled zero buffer is a valid empty reservoir
//! (`from_bits(0) == 0 seen`).

use crate::data::dataset::{Examples, Row};
use crate::learning::linear::LinearModel;
use crate::learning::pegasos::Pegasos;

/// Default reservoir capacity K (paper-default cache is 10; K must stay
/// within it — validated in `config/`).
pub const DEFAULT_CAPACITY: usize = 8;

/// How two models combine in CREATEMODEL (Algorithm 2 Mu/Um).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum MergeMode {
    /// The paper's weighted average: `w = (w1 + w2)/2`, `t = max`.
    #[default]
    Average,
    /// Quorum vote: coordinate-wise, average where the two models agree in
    /// sign, zero (abstain) where they disagree.  `t = max`.
    Quorum,
}

impl MergeMode {
    pub fn name(&self) -> &'static str {
        match self {
            MergeMode::Average => "average",
            MergeMode::Quorum => "quorum",
        }
    }

    pub fn parse(s: &str) -> Option<MergeMode> {
        match s {
            "average" | "avg" => Some(MergeMode::Average),
            "quorum" => Some(MergeMode::Quorum),
            _ => None,
        }
    }
}

// ---- reservoir ---------------------------------------------------------

/// An empty reservoir of capacity `k`.
pub fn reservoir_new(k: usize) -> Vec<f32> {
    vec![0.0; reservoir_len(k)]
}

/// Buffer length for capacity `k`: `1 + 2k` floats.
#[inline]
pub fn reservoir_len(k: usize) -> usize {
    1 + 2 * k
}

/// Capacity K of an encoded reservoir (0 for an empty/absent buffer).
#[inline]
pub fn capacity(res: &[f32]) -> usize {
    res.len().saturating_sub(1) / 2
}

/// Total examples ever offered.
#[inline]
pub fn seen(res: &[f32]) -> u32 {
    if res.is_empty() {
        0
    } else {
        res[0].to_bits()
    }
}

/// Live entry count, `min(seen, K)`.
#[inline]
pub fn occupancy(res: &[f32]) -> usize {
    (seen(res) as usize).min(capacity(res))
}

/// Entry `i` as `(origin node, label)`.
#[inline]
pub fn entry(res: &[f32], i: usize) -> (u32, f32) {
    (res[1 + 2 * i].to_bits(), res[2 + 2 * i])
}

fn set_entry(res: &mut [f32], i: usize, node: u32, y: f32) {
    res[1 + 2 * i] = f32::from_bits(node);
    res[2 + 2 * i] = y;
}

/// Iterator over the live `(node, y)` entries.
pub fn entries(res: &[f32]) -> impl Iterator<Item = (u32, f32)> + '_ {
    (0..occupancy(res)).map(move |i| entry(res, i))
}

/// Offer `(node, y)` into the reservoir — Algorithm R with the caller's RNG
/// draw.  Consumes **exactly one** draw per offer regardless of outcome, so
/// the per-node draw count (and with it shard-count determinism) depends
/// only on the node's delivery sequence, never on reservoir contents:
///
/// ```text
/// seen' = seen + 1
/// if seen < K:            slot seen        (fill phase)
/// else: j = draw % seen'; if j < K: slot j (replacement phase)
/// ```
pub fn offer(res: &mut [f32], node: u32, y: f32, draw: u64) {
    let k = capacity(res);
    if k == 0 {
        return;
    }
    let s = seen(res);
    let s1 = s.wrapping_add(1);
    res[0] = f32::from_bits(s1);
    if (s as usize) < k {
        set_entry(res, s as usize, node, y);
    } else {
        let j = (draw % s1 as u64) as usize;
        if j < k {
            set_entry(res, j, node, y);
        }
    }
}

/// Rebuild a reservoir buffer from its wire fields, at exactly
/// `entries.len()` capacity — the decode half of the reservoir tail
/// (net/wire.rs).  Receivers normalize to their configured capacity with
/// [`set_capacity`] before offering into it.
pub fn from_entries(seen: u32, entries: &[(u32, f32)]) -> Vec<f32> {
    let mut res = reservoir_new(entries.len());
    res[0] = f32::from_bits(seen);
    for (i, &(node, y)) in entries.iter().enumerate() {
        set_entry(&mut res, i, node, y);
    }
    res
}

/// Re-encode a reservoir to capacity `k`, preserving `seen` and the first
/// `min(occupancy, k)` entries.  The wire format carries only the live
/// entries, so a decoded reservoir arrives at its occupancy, not at the
/// configured capacity — receivers normalize with this before offering.
pub fn set_capacity(res: &mut Vec<f32>, k: usize) {
    if capacity(res) == k && res.len() == reservoir_len(k) {
        return;
    }
    let s = seen(res);
    let live: Vec<(u32, f32)> = entries(res).take(k).collect();
    res.clear();
    res.resize(reservoir_len(k), 0.0);
    res[0] = f32::from_bits(s);
    for (i, (node, y)) in live.into_iter().enumerate() {
        set_entry(res, i, node, y);
    }
}

// ---- pair-difference rows ----------------------------------------------

/// Dense difference row `z = y (x − x_j)` into `out` (resized to `d`).
///
/// This is the one construction of `z` shared by the scalar reference path
/// and the engine's dense pairwise kernel, so both see identical floats.
pub fn dense_pair_diff(y: f32, x: &[f32], xj: &[f32], out: &mut Vec<f32>) {
    out.clear();
    out.extend(x.iter().zip(xj).map(|(&a, &b)| y * (a - b)));
}

/// Sparse difference row `z = y (x − x_j)` by merging the two sorted index
/// lists into `(out_idx, out_val)` — O(nnz(x) + nnz(x_j)).  Shared by the
/// scalar reference path and the engine's sparse pairwise kernel.
pub fn sparse_pair_diff(
    y: f32,
    x_idx: &[u32],
    x_val: &[f32],
    xj_idx: &[u32],
    xj_val: &[f32],
    out_idx: &mut Vec<u32>,
    out_val: &mut Vec<f32>,
) {
    out_idx.clear();
    out_val.clear();
    let (mut a, mut b) = (0usize, 0usize);
    while a < x_idx.len() || b < xj_idx.len() {
        let ia = x_idx.get(a).copied().unwrap_or(u32::MAX);
        let ib = xj_idx.get(b).copied().unwrap_or(u32::MAX);
        if ia < ib {
            out_idx.push(ia);
            out_val.push(y * x_val[a]);
            a += 1;
        } else if ib < ia {
            out_idx.push(ib);
            out_val.push(y * -xj_val[b]);
            b += 1;
        } else {
            out_idx.push(ia);
            out_val.push(y * (x_val[a] - xj_val[b]));
            a += 1;
            b += 1;
        }
    }
}

// ---- the learner -------------------------------------------------------

/// Pairwise hinge AUC learner: one Pegasos step on `z = y (x − x_j)` with
/// implicit label `+1` for each reservoir entry `(x_j, y_j)` of the opposite
/// class.  The hinge on `z` is the ranking loss `[1 − (s(x⁺) − s(x⁻))]₊`
/// regardless of which of the two examples is local.
///
/// With zero opposite-class entries the step is a complete no-op — no decay,
/// no `t` bump — so a model walking through a single-class region is
/// untouched until it meets the other class (logistic ranking is deferred;
/// the hinge is the GADGET-SVM-compatible choice).
#[derive(Clone, Copy, Debug)]
pub struct PairwiseAuc {
    pub lambda: f32,
}

impl PairwiseAuc {
    pub fn new(lambda: f32) -> Self {
        assert!(lambda > 0.0, "lambda must be positive");
        PairwiseAuc { lambda }
    }

    /// Scalar reference step (the deployment runtime's per-node path; the
    /// engine kernels mirror it on `StepBatch` rows).  `train` resolves
    /// reservoir origin nodes to their feature rows.
    pub fn update_with_reservoir(
        &self,
        m: &mut LinearModel,
        x: &Row<'_>,
        y: f32,
        res: &[f32],
        train: &Examples,
        scratch: &mut PairScratch,
    ) {
        let peg = Pegasos::new(self.lambda);
        for (node, yj) in entries(res) {
            if yj * y >= 0.0 {
                continue;
            }
            let xj = train.row(node as usize);
            match (x, &xj) {
                (Row::Sparse(xi, xv), Row::Sparse(ji, jv)) => {
                    sparse_pair_diff(y, xi, xv, ji, jv, &mut scratch.idx, &mut scratch.val);
                    peg.update(m, &Row::Sparse(&scratch.idx, &scratch.val), 1.0);
                }
                _ => {
                    // mixed or dense storage: go through dense z
                    let d = m.dim();
                    scratch.xd.resize(d, 0.0);
                    x.write_dense(&mut scratch.xd);
                    scratch.jd.resize(d, 0.0);
                    xj.write_dense(&mut scratch.jd);
                    dense_pair_diff(y, &scratch.xd, &scratch.jd, &mut scratch.z);
                    peg.update(m, &Row::Dense(&scratch.z), 1.0);
                }
            }
        }
    }
}

/// Reusable buffers for [`PairwiseAuc::update_with_reservoir`].
#[derive(Default)]
pub struct PairScratch {
    pub idx: Vec<u32>,
    pub val: Vec<f32>,
    pub xd: Vec<f32>,
    pub jd: Vec<f32>,
    pub z: Vec<f32>,
}

// ---- quorum merge ------------------------------------------------------

/// One coordinate of the quorum vote over effective (scale-folded) weights.
#[inline]
pub fn quorum_coord(a: f32, b: f32) -> f32 {
    if a * b > 0.0 {
        0.5 * (a + b)
    } else {
        0.0
    }
}

/// Quorum-vote MERGE: sign-agreeing coordinates average, disagreeing ones
/// zero out; `t = max` exactly like the averaging merge.
pub fn quorum_merge(a: &LinearModel, b: &LinearModel) -> LinearModel {
    debug_assert_eq!(a.dim(), b.dim());
    let (wa, wb) = (a.weights(), b.weights());
    let v: Vec<f32> = wa.iter().zip(&wb).map(|(&p, &q)| quorum_coord(p, q)).collect();
    LinearModel::from_weights(v, a.t.max(b.t))
}

/// In-place variant mirroring [`LinearModel::merge_from`].
pub fn quorum_merge_from(m: &mut LinearModel, other: &LinearModel) {
    let merged = quorum_merge(m, other);
    *m = merged;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::matrix::Matrix;
    use crate::util::rng::Rng;

    #[test]
    fn merge_mode_parse_and_name_roundtrip() {
        for m in [MergeMode::Average, MergeMode::Quorum] {
            assert_eq!(MergeMode::parse(m.name()), Some(m));
        }
        assert_eq!(MergeMode::parse("avg"), Some(MergeMode::Average));
        assert_eq!(MergeMode::parse("majority"), None);
    }

    #[test]
    fn reservoir_fill_then_replace() {
        let mut res = reservoir_new(2);
        assert_eq!(capacity(&res), 2);
        assert_eq!(occupancy(&res), 0);
        offer(&mut res, 7, 1.0, 999);
        offer(&mut res, 8, -1.0, 999);
        assert_eq!(seen(&res), 2);
        assert_eq!(entry(&res, 0), (7, 1.0));
        assert_eq!(entry(&res, 1), (8, -1.0));
        // seen = 2 = K: replacement phase; draw % 3 == 0 -> slot 0
        offer(&mut res, 9, 1.0, 3);
        assert_eq!(seen(&res), 3);
        assert_eq!(entry(&res, 0), (9, 1.0));
        // draw % 4 == 2 >= K -> no replacement, but seen still advances
        offer(&mut res, 10, 1.0, 2);
        assert_eq!(seen(&res), 4);
        assert_eq!(occupancy(&res), 2);
        assert_eq!(entry(&res, 0), (9, 1.0));
        assert_eq!(entry(&res, 1), (8, -1.0));
    }

    #[test]
    fn reservoir_node_ids_are_exact_for_large_ids() {
        let mut res = reservoir_new(1);
        let big = u32::MAX - 17;
        offer(&mut res, big, -1.0, 0);
        assert_eq!(entry(&res, 0), (big, -1.0));
    }

    #[test]
    fn reservoir_is_approximately_uniform() {
        // Offer 0..N into a K-reservoir many times with independent RNG
        // streams; every element's inclusion frequency must be near K/N.
        let (n, k, trials) = (40u32, 4usize, 3000usize);
        let mut hits = vec![0usize; n as usize];
        for t in 0..trials {
            let mut rng = Rng::new(1000 + t as u64);
            let mut res = reservoir_new(k);
            for node in 0..n {
                offer(&mut res, node, 1.0, rng.next_u64());
            }
            for (node, _) in entries(&res) {
                hits[node as usize] += 1;
            }
        }
        let expect = trials as f64 * k as f64 / n as f64; // 300
        for (node, &h) in hits.iter().enumerate() {
            let dev = (h as f64 - expect).abs() / expect;
            assert!(dev < 0.25, "node {node}: {h} hits vs expected {expect}");
        }
    }

    #[test]
    fn set_capacity_preserves_entries_and_seen() {
        let mut res = reservoir_new(3);
        for i in 0..5 {
            offer(&mut res, i, if i % 2 == 0 { 1.0 } else { -1.0 }, i as u64 * 31);
        }
        let before: Vec<_> = entries(&res).collect();
        let s = seen(&res);
        // expand (wire decode arrives at occupancy, normalize up)
        set_capacity(&mut res, 8);
        assert_eq!(capacity(&res), 8);
        assert_eq!(seen(&res), s);
        assert_eq!(entries(&res).collect::<Vec<_>>(), before);
    }

    #[test]
    fn pairwise_update_is_noop_without_opposite_class() {
        let learner = PairwiseAuc::new(0.01);
        let train = Examples::Dense(Matrix::from_vec(2, 2, vec![1.0, 0.0, 0.0, 1.0]));
        let mut res = reservoir_new(2);
        offer(&mut res, 0, 1.0, 0);
        offer(&mut res, 1, 1.0, 1);
        let mut m = LinearModel::from_weights(vec![0.5, -0.5], 3);
        let before = m.weights();
        let mut scratch = PairScratch::default();
        learner.update_with_reservoir(&mut m, &Row::Dense(&[1.0, 1.0]), 1.0, &res, &train, &mut scratch);
        assert_eq!(m.weights(), before);
        assert_eq!(m.t, 3, "no decay, no t bump");
    }

    #[test]
    fn pairwise_update_orders_positive_above_negative() {
        // one positive local example repeatedly paired against a reservoir
        // negative must push s(x+) above s(x-)
        let learner = PairwiseAuc::new(0.1);
        let xp = vec![1.0f32, 0.2];
        let xn = vec![0.2f32, 1.0];
        let train =
            Examples::Dense(Matrix::from_vec(2, 2, vec![xp[0], xp[1], xn[0], xn[1]]));
        let mut res = reservoir_new(2);
        offer(&mut res, 1, -1.0, 0);
        let mut m = LinearModel::zeros(2);
        let mut scratch = PairScratch::default();
        for _ in 0..50 {
            learner.update_with_reservoir(&mut m, &Row::Dense(&xp), 1.0, &res, &train, &mut scratch);
        }
        let sp = m.raw_margin(&Row::Dense(&xp));
        let sn = m.raw_margin(&Row::Dense(&xn));
        assert!(sp > sn, "ranking not learned: s+={sp} s-={sn}");
        assert_eq!(m.t, 50);
    }

    #[test]
    fn sparse_and_dense_pair_diff_agree() {
        let x = vec![0.0f32, 2.0, 0.0, -1.0];
        let xj = vec![1.0f32, 0.0, 0.0, 3.0];
        let mut dense = Vec::new();
        dense_pair_diff(-1.0, &x, &xj, &mut dense);
        let (mut idx, mut val) = (Vec::new(), Vec::new());
        sparse_pair_diff(
            -1.0,
            &[1, 3],
            &[2.0, -1.0],
            &[0, 3],
            &[1.0, 3.0],
            &mut idx,
            &mut val,
        );
        let mut from_sparse = vec![0.0f32; 4];
        for (&j, &v) in idx.iter().zip(&val) {
            from_sparse[j as usize] = v;
        }
        assert_eq!(dense, from_sparse);
    }

    #[test]
    fn quorum_zeroes_disagreements_and_averages_agreements() {
        let a = LinearModel::from_weights(vec![2.0, -2.0, 1.0, 0.0], 3);
        let mut b = LinearModel::from_weights(vec![4.0, 2.0, -1.0, 2.0], 7);
        b.scale_by(0.5); // effective [2, 1, -0.5, 1]
        let m = quorum_merge(&a, &b);
        assert_eq!(m.weights(), vec![2.0, 0.0, 0.0, 0.0]);
        assert_eq!(m.t, 7);
        let mut c = a.clone();
        quorum_merge_from(&mut c, &b);
        assert_eq!(c.weights(), m.weights());
        assert_eq!(c.t, m.t);
    }
}
