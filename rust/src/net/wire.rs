//! Wire format for deployed gossip messages (the offline crate set has no
//! serde): a fixed little-endian framing with an explicit version byte.
//!
//! ```text
//! u32  frame length (bytes after this field)
//! u8   version (1)
//! u64  src node id
//! u64  model update counter t
//! u32  d  (weight count)
//! f32* d weights
//! u16  view entry count
//! (u64 node, u64 ts)* view entries
//! u16  reservoir entry count (live entries; 0 when the learner is pointwise)
//! [u32 seen]           — present only when the entry count is nonzero
//! (u32 node, f32 y)*   reservoir entries
//! ```
//!
//! Version 2 is the *routed* variant used by the node-group runtime
//! (DESIGN.md §15): a group's nodes share one listener, so the destination
//! can no longer be inferred from the socket a frame arrived on.  A v2
//! frame inserts a `u64 dst` node id immediately after the version byte;
//! everything else is identical to v1.

use crate::gossip::message::ModelMsg;
use crate::learning::pairwise;
use crate::p2p::newscast::Descriptor;
use std::io::{self, Read, Write};

pub const WIRE_VERSION: u8 = 1;
/// Frame version carrying an explicit destination node id (group routing).
pub const ROUTED_WIRE_VERSION: u8 = 2;
/// Hard cap against corrupt frames (largest paper model: d=9947 ≈ 40 KB).
pub const MAX_FRAME: u32 = 64 * 1024 * 1024;

fn encode_tail(buf: &mut Vec<u8>, msg: &ModelMsg) {
    buf.extend_from_slice(&(msg.src as u64).to_le_bytes());
    buf.extend_from_slice(&msg.t.to_le_bytes());
    buf.extend_from_slice(&(msg.w.len() as u32).to_le_bytes());
    // the wire carries the materialized model: any lazy scale folds here
    for &w in &msg.w {
        buf.extend_from_slice(&(w * msg.scale).to_le_bytes());
    }
    buf.extend_from_slice(&(msg.view.len() as u16).to_le_bytes());
    for d in &msg.view {
        buf.extend_from_slice(&(d.node as u64).to_le_bytes());
        buf.extend_from_slice(&d.ts.to_le_bytes());
    }
    // only the live reservoir entries travel: the receiver re-expands to its
    // configured capacity, so an empty reservoir costs two zero bytes
    let occ = pairwise::occupancy(&msg.res);
    buf.extend_from_slice(&(occ as u16).to_le_bytes());
    if occ > 0 {
        buf.extend_from_slice(&pairwise::seen(&msg.res).to_le_bytes());
        for (node, y) in pairwise::entries(&msg.res) {
            buf.extend_from_slice(&node.to_le_bytes());
            buf.extend_from_slice(&y.to_le_bytes());
        }
    }
}

/// Body bytes past the weights and view: the reservoir count plus, when
/// nonzero, the `seen` counter and the live entries.
fn res_tail_bytes(msg: &ModelMsg) -> usize {
    let occ = pairwise::occupancy(&msg.res);
    2 + if occ > 0 { 4 + 8 * occ } else { 0 }
}

pub fn encode(msg: &ModelMsg) -> Vec<u8> {
    let body_len =
        1 + 8 + 8 + 4 + msg.w.len() * 4 + 2 + msg.view.len() * 16 + res_tail_bytes(msg);
    let mut buf = Vec::with_capacity(4 + body_len);
    buf.extend_from_slice(&(body_len as u32).to_le_bytes());
    buf.push(WIRE_VERSION);
    encode_tail(&mut buf, msg);
    buf
}

/// Encode a v2 frame addressed to `dst` (8 bytes larger than the v1 frame
/// of the same message; `ModelMsg::wire_bytes` stays pinned to v1, which
/// both runtimes use for byte accounting so sim/deploy traffic metrics
/// remain directly comparable).
pub fn encode_routed(dst: usize, msg: &ModelMsg) -> Vec<u8> {
    let body_len =
        1 + 8 + 8 + 8 + 4 + msg.w.len() * 4 + 2 + msg.view.len() * 16 + res_tail_bytes(msg);
    let mut buf = Vec::with_capacity(4 + body_len);
    buf.extend_from_slice(&(body_len as u32).to_le_bytes());
    buf.push(ROUTED_WIRE_VERSION);
    buf.extend_from_slice(&(dst as u64).to_le_bytes());
    encode_tail(&mut buf, msg);
    buf
}

#[derive(Debug)]
pub enum WireError {
    Io(io::Error),
    BadVersion(u8),
    BadLength(u32),
    Truncated,
    /// The declared counts do not account for the whole body: a well-formed
    /// frame must be consumed exactly.
    TrailingBytes(usize),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Io(e) => write!(f, "io: {e}"),
            WireError::BadVersion(v) => write!(f, "bad wire version {v}"),
            WireError::BadLength(n) => write!(f, "bad frame length {n}"),
            WireError::Truncated => write!(f, "truncated frame"),
            WireError::TrailingBytes(n) => write!(f, "{n} trailing bytes after frame"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<io::Error> for WireError {
    fn from(e: io::Error) -> Self {
        WireError::Io(e)
    }
}

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.pos + n > self.buf.len() {
            return Err(WireError::Truncated);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, WireError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32, WireError> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
}

pub fn decode_body(body: &[u8]) -> Result<ModelMsg, WireError> {
    let mut c = Cursor { buf: body, pos: 0 };
    let version = c.u8()?;
    if version != WIRE_VERSION {
        return Err(WireError::BadVersion(version));
    }
    decode_fields(c)
}

/// Decode a v2 routed frame body into `(dst, msg)`.
pub fn decode_routed_body(body: &[u8]) -> Result<(usize, ModelMsg), WireError> {
    let mut c = Cursor { buf: body, pos: 0 };
    let version = c.u8()?;
    if version != ROUTED_WIRE_VERSION {
        return Err(WireError::BadVersion(version));
    }
    let dst = c.u64()? as usize;
    Ok((dst, decode_fields(c)?))
}

/// Shared v1/v2 field sequence after the version (and any routing) prefix.
fn decode_fields(mut c: Cursor<'_>) -> Result<ModelMsg, WireError> {
    let body = c.buf;
    let src = c.u64()? as usize;
    let t = c.u64()?;
    let d = c.u32()? as usize;
    // checked: `d * 4` overflows a 32-bit usize for d >= 2^30, which would
    // bypass the bound check and feed a huge d into Vec::with_capacity
    let need = d
        .checked_mul(4)
        .and_then(|b| b.checked_add(2)) // the u16 view count must follow
        .ok_or(WireError::Truncated)?;
    if need > body.len() - c.pos {
        return Err(WireError::Truncated);
    }
    let mut w = Vec::with_capacity(d);
    for _ in 0..d {
        w.push(c.f32()?);
    }
    let nv = c.u16()? as usize;
    let mut view = Vec::with_capacity(nv.min(1024));
    for _ in 0..nv {
        let node = c.u64()? as usize;
        let ts = c.u64()?;
        view.push(Descriptor { node, ts });
    }
    let nres = c.u16()? as usize;
    let res = if nres > 0 {
        let seen = c.u32()?;
        let mut entries = Vec::with_capacity(nres.min(1024));
        for _ in 0..nres {
            let node = c.u32()?;
            let y = c.f32()?;
            entries.push((node, y));
        }
        // reconstructed at occupancy; the receiver normalizes to its
        // configured capacity with pairwise::set_capacity before offering
        pairwise::from_entries(seen, &entries)
    } else {
        Vec::new()
    };
    // the declared counts must consume the body exactly
    if c.pos != body.len() {
        return Err(WireError::TrailingBytes(body.len() - c.pos));
    }
    Ok(ModelMsg { src, w, scale: 1.0, t, view, res })
}

/// Blocking framed read from a stream.
pub fn read_frame<R: Read>(r: &mut R) -> Result<ModelMsg, WireError> {
    let mut len4 = [0u8; 4];
    r.read_exact(&mut len4)?;
    let len = u32::from_le_bytes(len4);
    if len == 0 || len > MAX_FRAME {
        return Err(WireError::BadLength(len));
    }
    let mut body = vec![0u8; len as usize];
    r.read_exact(&mut body)?;
    decode_body(&body)
}

/// Blocking framed write to a stream.
pub fn write_frame<W: Write>(w: &mut W, msg: &ModelMsg) -> Result<(), WireError> {
    w.write_all(&encode(msg))?;
    Ok(())
}

/// Incremental frame extractor for a nonblocking byte stream: append raw
/// bytes as they arrive (`extend`), then pull every complete frame out per
/// wake (`next_frame`).  This is what lets the deployment runtime keep one
/// persistent connection per peer and drain an arbitrary number of frames
/// per poll instead of one frame per fresh connection.
#[derive(Debug, Default)]
pub struct FrameBuf {
    buf: Vec<u8>,
    /// consumed prefix of `buf` (compacted on the next `extend`)
    pos: usize,
}

impl FrameBuf {
    pub fn extend(&mut self, bytes: &[u8]) {
        if self.pos > 0 {
            self.buf.drain(..self.pos);
            self.pos = 0;
        }
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes buffered but not yet returned as complete frames.
    pub fn pending(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Consume the next complete frame's body range, if fully buffered.
    fn next_body_range(&mut self) -> Option<Result<std::ops::Range<usize>, WireError>> {
        let avail = &self.buf[self.pos..];
        if avail.len() < 4 {
            return None;
        }
        let len = u32::from_le_bytes(avail[..4].try_into().unwrap());
        if len == 0 || len > MAX_FRAME {
            return Some(Err(WireError::BadLength(len)));
        }
        let len = len as usize;
        if avail.len() < 4 + len {
            return None;
        }
        let start = self.pos + 4;
        self.pos = start + len;
        Some(Ok(start..start + len))
    }

    /// Extract the next complete frame, if one is fully buffered.
    /// `Some(Err(_))` means the stream is poisoned (bad length header or
    /// malformed body) — framing cannot resynchronize, so the caller should
    /// drop the connection.
    pub fn next_frame(&mut self) -> Option<Result<ModelMsg, WireError>> {
        match self.next_body_range()? {
            Ok(r) => Some(decode_body(&self.buf[r])),
            Err(e) => Some(Err(e)),
        }
    }

    /// [`FrameBuf::next_frame`] for v2 routed frames: the node-group
    /// runtime's readiness loop pulls `(dst, msg)` pairs off a stream whose
    /// destination node cannot be inferred from the shared group listener.
    pub fn next_routed(&mut self) -> Option<Result<(usize, ModelMsg), WireError>> {
        match self.next_body_range()? {
            Ok(r) => Some(decode_routed_body(&self.buf[r])),
            Err(e) => Some(Err(e)),
        }
    }
}

/// Pending-output buffer for a nonblocking socket: frames are queued whole,
/// and `flush` resumes wherever the previous attempt stopped.  The
/// node-group runtime keeps one per outbound connection, so a send that
/// hits `WouldBlock` mid-frame never tears the stream framing — the unsent
/// suffix simply waits for the next readiness pass (DESIGN.md §15).
#[derive(Debug, Default)]
pub struct WriteBuf {
    buf: Vec<u8>,
    /// flushed prefix of `buf` (compacted on the next `push`)
    pos: usize,
}

impl WriteBuf {
    /// Queue a complete frame behind whatever is still pending.
    pub fn push(&mut self, bytes: &[u8]) {
        if self.pos > 0 {
            self.buf.drain(..self.pos);
            self.pos = 0;
        }
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes queued but not yet accepted by the socket.
    pub fn pending(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Write as much as the sink accepts.  `Ok(true)` = fully drained,
    /// `Ok(false)` = the sink would block with bytes still pending, `Err` =
    /// the connection is dead (its pending bytes are lost with it).
    pub fn flush<W: Write>(&mut self, w: &mut W) -> io::Result<bool> {
        while self.pos < self.buf.len() {
            match w.write(&self.buf[self.pos..]) {
                Ok(0) => return Err(io::ErrorKind::WriteZero.into()),
                Ok(k) => self.pos += k,
                Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => return Ok(false),
                Err(ref e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
        self.buf.clear();
        self.pos = 0;
        Ok(true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(d: usize, nv: usize) -> ModelMsg {
        ModelMsg {
            src: 7,
            w: (0..d).map(|i| i as f32 * 0.5 - 1.0).collect(),
            scale: 1.0,
            t: 99,
            view: (0..nv).map(|i| Descriptor { node: i, ts: i as u64 * 3 }).collect(),
            res: Vec::new(),
        }
    }

    /// `sample` plus a capacity-`k` reservoir holding `occ` live entries.
    fn sample_with_res(d: usize, nv: usize, k: usize, occ: usize) -> ModelMsg {
        let mut m = sample(d, nv);
        m.res = pairwise::reservoir_new(k);
        for i in 0..occ {
            pairwise::offer(&mut m.res, 100 + i as u32, if i % 2 == 0 { 1.0 } else { -1.0 }, 0);
        }
        m
    }

    #[test]
    fn roundtrip() {
        for (d, nv) in [(0, 0), (1, 1), (57, 20), (9947, 20)] {
            let m = sample(d, nv);
            let enc = encode(&m);
            let got = decode_body(&enc[4..]).unwrap();
            assert_eq!(got.src, m.src);
            assert_eq!(got.t, m.t);
            assert_eq!(got.w, m.w);
            assert_eq!(got.view, m.view);
        }
    }

    #[test]
    fn stream_roundtrip_multiple_frames() {
        let mut buf = Vec::new();
        for d in [3, 5] {
            write_frame(&mut buf, &sample(d, 2)).unwrap();
        }
        let mut r = &buf[..];
        assert_eq!(read_frame(&mut r).unwrap().w.len(), 3);
        assert_eq!(read_frame(&mut r).unwrap().w.len(), 5);
    }

    #[test]
    fn rejects_bad_version_and_truncation() {
        let m = sample(4, 1);
        let mut enc = encode(&m);
        enc[4] = 9; // version byte
        assert!(matches!(decode_body(&enc[4..]), Err(WireError::BadVersion(9))));
        let enc = encode(&m);
        assert!(matches!(
            decode_body(&enc[4..enc.len() - 3]),
            Err(WireError::Truncated)
        ));
    }

    #[test]
    fn rejects_huge_frame_header() {
        let bytes = (MAX_FRAME + 1).to_le_bytes();
        let mut stream: Vec<u8> = bytes.to_vec();
        stream.extend_from_slice(&[0; 16]);
        assert!(matches!(
            read_frame(&mut &stream[..]),
            Err(WireError::BadLength(_))
        ));
    }

    #[test]
    fn frame_size_matches_wire_bytes_exactly() {
        // regression: wire_bytes used to omit the 19 bytes of framing
        // (length prefix, version, src, d/view counts)
        for (d, nv) in [(0, 0), (1, 1), (57, 20), (9947, 20)] {
            let m = sample(d, nv);
            assert_eq!(encode(&m).len(), m.wire_bytes(), "d={d} nv={nv}");
        }
        // the reservoir tail is counted too: 2 always, +4+8*occ when live
        for (k, occ) in [(8, 0), (8, 3), (4, 4), (16, 20)] {
            let m = sample_with_res(5, 2, k, occ);
            assert_eq!(encode(&m).len(), m.wire_bytes(), "k={k} occ={occ}");
        }
    }

    #[test]
    fn reservoir_roundtrips_at_occupancy() {
        // a half-full capacity-8 reservoir travels as 3 entries; the decoded
        // buffer sits at capacity 3 until the receiver set_capacity()s it
        let m = sample_with_res(4, 1, 8, 3);
        let got = decode_body(&encode(&m)[4..]).unwrap();
        assert_eq!(pairwise::capacity(&got.res), 3);
        assert_eq!(pairwise::seen(&got.res), pairwise::seen(&m.res));
        let want: Vec<(u32, f32)> = pairwise::entries(&m.res).collect();
        let have: Vec<(u32, f32)> = pairwise::entries(&got.res).collect();
        assert_eq!(have, want);
        // normalizing back to the configured capacity preserves everything
        let mut res = got.res;
        pairwise::set_capacity(&mut res, 8);
        assert_eq!(pairwise::seen(&res), pairwise::seen(&m.res));
        assert_eq!(pairwise::entries(&res).collect::<Vec<_>>(), want);
        // an over-seen reservoir keeps its seen counter (weights Algorithm R)
        let m = sample_with_res(4, 1, 4, 9);
        let got = decode_body(&encode(&m)[4..]).unwrap();
        assert_eq!(pairwise::seen(&got.res), 9);
        assert_eq!(pairwise::occupancy(&got.res), 4);
        // empty reservoirs decode to the empty buffer, not a zero-capacity one
        let m = sample_with_res(4, 1, 8, 0);
        assert!(decode_body(&encode(&m)[4..]).unwrap().res.is_empty());
    }

    #[test]
    fn routed_frames_carry_reservoirs_too() {
        let m = sample_with_res(6, 2, 8, 5);
        let enc = encode_routed(13, &m);
        assert_eq!(enc.len(), m.wire_bytes() + 8, "v2 = v1 + u64 dst");
        let (dst, got) = decode_routed_body(&enc[4..]).unwrap();
        assert_eq!(dst, 13);
        assert_eq!(
            pairwise::entries(&got.res).collect::<Vec<_>>(),
            pairwise::entries(&m.res).collect::<Vec<_>>()
        );
    }

    #[test]
    fn rejects_overflowing_weight_count() {
        // a frame declaring d near u32::MAX: `d * 4` wraps on 32-bit targets,
        // which used to bypass the bound check before Vec::with_capacity(d)
        let mut body = vec![WIRE_VERSION];
        body.extend_from_slice(&7u64.to_le_bytes()); // src
        body.extend_from_slice(&9u64.to_le_bytes()); // t
        body.extend_from_slice(&u32::MAX.to_le_bytes()); // absurd d
        body.extend_from_slice(&[0u8; 8]); // a few bytes of "weights"
        assert!(matches!(decode_body(&body), Err(WireError::Truncated)));
        // d = 2^30: d * 4 == 2^32 wraps to 0 on 32-bit usize
        body.truncate(17); // keep version + src + t, drop the d field
        body.extend_from_slice(&(1u32 << 30).to_le_bytes());
        body.extend_from_slice(&[0u8; 8]);
        assert!(matches!(decode_body(&body), Err(WireError::Truncated)));
    }

    #[test]
    fn rejects_inexact_body_fit() {
        // declared counts must consume the body exactly — trailing garbage
        // (e.g. a d that undercounts the weights present) is rejected
        let m = sample(4, 1);
        let mut enc = encode(&m);
        enc.extend_from_slice(&[0xAB; 3]);
        assert!(matches!(
            decode_body(&enc[4..]),
            Err(WireError::TrailingBytes(3))
        ));
    }

    #[test]
    fn frame_buf_drains_multiple_frames_per_extend() {
        let mut fb = FrameBuf::default();
        let mut bytes = Vec::new();
        for d in [3, 5, 7] {
            bytes.extend_from_slice(&encode(&sample(d, 2)));
        }
        fb.extend(&bytes);
        let dims: Vec<usize> = std::iter::from_fn(|| fb.next_frame())
            .map(|r| r.unwrap().w.len())
            .collect();
        assert_eq!(dims, vec![3, 5, 7]);
        assert_eq!(fb.pending(), 0);
    }

    #[test]
    fn frame_buf_handles_byte_by_byte_arrival() {
        let m = sample(6, 3);
        let enc = encode(&m);
        let mut fb = FrameBuf::default();
        for (i, &b) in enc.iter().enumerate() {
            fb.extend(&[b]);
            if i + 1 < enc.len() {
                assert!(fb.next_frame().is_none(), "partial frame at byte {i}");
            }
        }
        let got = fb.next_frame().unwrap().unwrap();
        assert_eq!(got.w, m.w);
        assert_eq!(got.view, m.view);
        assert!(fb.next_frame().is_none());
    }

    #[test]
    fn frame_buf_poisons_on_bad_header() {
        let mut fb = FrameBuf::default();
        fb.extend(&(MAX_FRAME + 1).to_le_bytes());
        fb.extend(&[0u8; 32]);
        assert!(matches!(fb.next_frame(), Some(Err(WireError::BadLength(_)))));
    }

    #[test]
    fn routed_roundtrip_carries_dst_and_costs_eight_bytes() {
        for (d, nv) in [(0, 0), (1, 1), (57, 20)] {
            let m = sample(d, nv);
            let enc = encode_routed(31, &m);
            assert_eq!(enc.len(), m.wire_bytes() + 8, "v2 = v1 + u64 dst");
            let (dst, got) = decode_routed_body(&enc[4..]).unwrap();
            assert_eq!(dst, 31);
            assert_eq!(got.src, m.src);
            assert_eq!(got.t, m.t);
            assert_eq!(got.w, m.w);
            assert_eq!(got.view, m.view);
        }
    }

    #[test]
    fn routed_and_plain_decoders_reject_each_other() {
        let m = sample(4, 1);
        assert!(matches!(
            decode_routed_body(&encode(&m)[4..]),
            Err(WireError::BadVersion(1))
        ));
        assert!(matches!(
            decode_body(&encode_routed(0, &m)[4..]),
            Err(WireError::BadVersion(2))
        ));
    }

    #[test]
    fn frame_buf_next_routed_handles_byte_by_byte_arrival() {
        let m = sample(6, 3);
        let enc = encode_routed(42, &m);
        let mut fb = FrameBuf::default();
        for (i, &b) in enc.iter().enumerate() {
            fb.extend(&[b]);
            if i + 1 < enc.len() {
                assert!(fb.next_routed().is_none(), "partial frame at byte {i}");
            }
        }
        let (dst, got) = fb.next_routed().unwrap().unwrap();
        assert_eq!(dst, 42);
        assert_eq!(got.w, m.w);
        assert!(fb.next_routed().is_none());
    }

    /// A sink that accepts a fixed quota of bytes per flush, then blocks —
    /// the shape of a nonblocking socket under backpressure.
    struct Throttled {
        accepted: Vec<u8>,
        quota: usize,
    }

    impl Write for Throttled {
        fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
            if self.quota == 0 {
                return Err(io::ErrorKind::WouldBlock.into());
            }
            let k = buf.len().min(self.quota);
            self.quota -= k;
            self.accepted.extend_from_slice(&buf[..k]);
            Ok(k)
        }
        fn flush(&mut self) -> io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn write_buf_resumes_partial_writes_without_tearing_frames() {
        let f1 = encode_routed(1, &sample(5, 2));
        let f2 = encode_routed(2, &sample(9, 0));
        let mut wb = WriteBuf::default();
        wb.push(&f1);
        wb.push(&f2);
        let total = f1.len() + f2.len();
        assert_eq!(wb.pending(), total);
        let mut sink = Throttled { accepted: Vec::new(), quota: 0 };
        // dribble the stream out 7 bytes per readiness pass
        let mut passes = 0;
        while wb.pending() > 0 {
            sink.quota = 7;
            let drained = wb.flush(&mut sink).unwrap();
            assert_eq!(drained, wb.pending() == 0);
            passes += 1;
            assert!(passes < 1000, "flush must make progress");
        }
        // the reassembled stream is byte-identical: framing survived
        let mut expect = f1.clone();
        expect.extend_from_slice(&f2);
        assert_eq!(sink.accepted, expect);
        let mut fb = FrameBuf::default();
        fb.extend(&sink.accepted);
        assert_eq!(fb.next_routed().unwrap().unwrap().0, 1);
        assert_eq!(fb.next_routed().unwrap().unwrap().0, 2);
        // pushing after a partial flush compacts, not corrupts
        sink.quota = 3;
        wb.push(&f1);
        wb.flush(&mut sink).unwrap();
        wb.push(&f2);
        assert_eq!(wb.pending(), total - 3);
    }

    #[test]
    fn write_buf_propagates_hard_errors() {
        struct Dead;
        impl Write for Dead {
            fn write(&mut self, _: &[u8]) -> io::Result<usize> {
                Err(io::ErrorKind::BrokenPipe.into())
            }
            fn flush(&mut self) -> io::Result<()> {
                Ok(())
            }
        }
        let mut wb = WriteBuf::default();
        wb.push(&encode(&sample(2, 0)));
        assert!(wb.flush(&mut Dead).is_err());
        // an empty buffer flushes trivially
        let mut wb = WriteBuf::default();
        assert!(wb.flush(&mut Dead).unwrap());
    }
}
