//! Real-deployment layer (DESIGN.md §10): the framed wire format with an
//! incremental multi-frame decoder (`wire`), and the per-node deployment
//! runtime (`deploy`) — persistent localhost-TCP peers executing the gossip
//! protocol with NEWSCAST views piggybacked over the wire and the
//! simulator's churn/drop/delay models injected on wall clock.  Run
//! orchestration (spawn/evaluate/shutdown/collect) lives in
//! `crate::coordinator`.
pub mod deploy;
pub mod wire;

#[allow(deprecated)] // the shim stays re-exported for downstream callers
pub use crate::coordinator::run_deployment;
pub use crate::coordinator::{run_deployment_observed, DeployReport, DeployStats};
pub use deploy::{DeployConfig, NodeStats, SIM_DELTA};
