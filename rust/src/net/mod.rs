//! Real-deployment layer: framed wire format + a threaded localhost-TCP
//! runner that executes the gossip protocol as actual concurrent peers
//! (validating the asynchronous message path outside the simulator).
pub mod deploy;
pub mod wire;

pub use deploy::{run_deployment, DeployConfig, DeployResult};
