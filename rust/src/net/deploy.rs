//! Deployed gossip learning node runtime: the same protocol logic as
//! gossip/protocol.rs, but running as real concurrent peers over localhost
//! TCP — **node groups** of peers multiplexed onto worker threads, framed
//! wire messages (net/wire.rs), wall-clock gossip periods (DESIGN.md §10,
//! §15).
//!
//! The thread-per-node runtime of PR 3 stopped at `512` nodes: one OS
//! thread plus one listener per peer does not reach the paper's "millions
//! of users" scale on one machine.  This module is its node-group
//! replacement:
//!
//! * **One worker thread per group, not per node.**  Each group owns a
//!   contiguous node range, one shared listener, and a readiness loop that
//!   per wake accepts new connections, drains every complete frame from
//!   every inbound stream through [`wire::FrameBuf`], retries pending
//!   nonblocking writes via [`wire::WriteBuf`], and fires due timers.
//! * **A per-group timer wheel** ([`TimerWheel`]) turns everything the old
//!   loop polled for into explicit wall-clock events: each node's jittered
//!   gossip period, its churn pause/resume boundaries, its delayed-send due
//!   times, and its scenario-mutation cursor.
//! * **Routed frames.**  Nodes share their group's listener, so a frame's
//!   destination cannot be inferred from the socket it arrived on; the
//!   runtime speaks wire v2 ([`wire::encode_routed`]), which carries an
//!   explicit `dst` node id.
//! * **Per-node semantics survive the inversion of control.**  Every node
//!   still owns its RNG (same seed derivation as thread-per-node, so RNG
//!   draw order per node is unchanged), [`PeerSampler`], [`ModelCache`],
//!   learner state, [`Network`] failure model, scenario cursor, LRU-capped
//!   [`OutConns`], and [`NodeStats`] — the group thread only multiplexes.
//! * **Failure injection on wall clock**, unchanged from PR 3: tick-based
//!   models are reused directly with [`SIM_DELTA`] ticks mapped onto Δ.

use crate::data::dataset::Dataset;
use crate::gossip::cache::ModelCache;
use crate::gossip::create_model::{create_model_pairwise_step, create_model_step, Variant};
use crate::gossip::message::ModelMsg;
use crate::learning::linear::LinearModel;
use crate::learning::pairwise::{self, PairScratch};
use crate::learning::{Learner, MergeMode};
use crate::net::wire::{self, FrameBuf, WriteBuf};
use crate::p2p::overlay::{PeerSampler, SamplerConfig};
use crate::scenario::driver::{CompiledScenario, Mutation, ScenarioDriver};
use crate::scenario::Scenario;
use crate::sim::churn::{ChurnConfig, ChurnSchedule};
use crate::sim::event::Ticks;
use crate::sim::network::{Fate, Network, NetworkConfig};
use crate::util::bitset::Bitset;
use crate::util::rng::Rng;
use std::collections::HashMap;
use std::io::{self, Read};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::ops::Range;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// One gossip period Δ expressed in simulator ticks — the scale on which the
/// reused tick-based failure models ([`NetworkConfig`], [`ChurnConfig`]) are
/// defined.  Matches `ProtocolConfig::paper_default`'s `delta`, so a
/// deployment and a matched simulator run interpret the same failure
/// configuration identically.
pub const SIM_DELTA: Ticks = 1000;

/// Cap on cached outbound connections per node (LRU-evicted beyond this);
/// bounds the deployment at O(n · cap) sockets instead of O(n²).
pub const OUT_CONN_CAP: usize = 16;

/// Ceiling on nodes multiplexed by one group thread, enforced at config
/// time through [`max_deploy_nodes`]: one readiness loop scanning far more
/// nodes than this would push per-wake work past the poll quantum, and the
/// per-node socket budget (`OUT_CONN_CAP` outbound each) past the default
/// fd limit.  10k nodes therefore need at least 5 groups.
pub const MAX_GROUP_NODES: usize = 2048;

/// The node-count bound for a deployment running `groups` worker threads —
/// the group-aware successor of the retired thread-per-node
/// `MAX_DEPLOY_NODES = 512` cap.
pub fn max_deploy_nodes(groups: usize) -> usize {
    groups.max(1).saturating_mul(MAX_GROUP_NODES)
}

/// Contiguous node ranges for `groups` worker threads, balanced to ±1 node
/// (the same partition shape as the sharded simulator's row ranges, so a
/// node id maps to its group by prefix sums, never by hashing).
pub fn group_ranges(n: usize, groups: usize) -> Vec<Range<usize>> {
    let g = groups.clamp(1, n.max(1));
    let base = n / g;
    let rem = n % g;
    let mut out = Vec::with_capacity(g);
    let mut start = 0;
    for i in 0..g {
        let len = base + usize::from(i < rem);
        out.push(start..start + len);
        start += len;
    }
    out
}

#[derive(Clone)]
pub struct DeployConfig {
    /// concurrent peers; node i owns training row i (needs
    /// `dataset.n_train() >= n_nodes`, and equality for simulator parity)
    pub n_nodes: usize,
    /// worker threads multiplexing the nodes; 0 = auto (the shared thread
    /// ledger's budget, clamped to the node count).  The coordinator leases
    /// the resolved count from `util::threads` so deployments compose with
    /// sweeps and shard runners without oversubscribing the machine.
    pub node_groups: usize,
    /// wall-clock gossip period Δ (one cycle)
    pub delta: Duration,
    /// run length in cycles (wall time = cycles * delta)
    pub cycles: u64,
    pub variant: Variant,
    pub learner: Learner,
    /// MERGE rule for Mu/Um: coordinate averaging or the quorum vote
    /// (DESIGN.md §17)
    pub merge: MergeMode,
    /// example-reservoir capacity K riding with each model when the learner
    /// is pairwise (ignored for pointwise learners)
    pub reservoir: usize,
    pub cache_size: usize,
    pub sampler: SamplerConfig,
    /// drop/delay model injected at send, in ticks ([`SIM_DELTA`] = Δ)
    pub network: NetworkConfig,
    /// tick-based pause/resume schedule driving wall-clock churn
    pub churn: Option<ChurnConfig>,
    /// peers sampled by the evaluation loop
    pub eval_peers: usize,
    /// cycles at which to measure; empty = log-spaced over the run
    pub eval_at_cycles: Vec<u64>,
    pub seed: u64,
    /// declarative failure/workload timeline (DESIGN.md §11), compiled at
    /// [`SIM_DELTA`] ticks per cycle so one scenario file drives the
    /// simulator and the deployment identically.  Flash-crowd joiners idle
    /// (state resident, protocol silent) until their join tick — the
    /// wall-clock analogue of the simulator's model-store growth.
    pub scenario: Option<Scenario>,
    /// gossip graph constraint (DESIGN.md §16); the coordinator builds the
    /// graph once from `(spec, n_nodes, seed)` and every node group samples
    /// neighbors from the shared CSR.  `None` = the implicit complete graph.
    pub topology: Option<crate::p2p::TopologySpec>,
}

impl Default for DeployConfig {
    fn default() -> Self {
        DeployConfig {
            n_nodes: 16,
            node_groups: 0,
            delta: Duration::from_millis(30),
            cycles: 30,
            variant: Variant::Mu,
            learner: Learner::pegasos(1e-2),
            merge: MergeMode::Average,
            reservoir: pairwise::DEFAULT_CAPACITY,
            cache_size: 10,
            sampler: SamplerConfig::Newscast { view_size: 20 },
            network: NetworkConfig::reliable(),
            churn: None,
            eval_peers: 16,
            eval_at_cycles: Vec::new(),
            seed: 42,
            scenario: None,
            topology: None,
        }
    }
}

impl DeployConfig {
    /// Section VI-A(i) "all failures" on wall clock: 50% drop, [Δ,10Δ]
    /// delay, churn at 90% online — the same models the simulator injects.
    pub fn with_extreme_failures(mut self) -> Self {
        self.network = NetworkConfig::extreme(SIM_DELTA);
        self.churn = Some(ChurnConfig::paper_default(SIM_DELTA));
        self
    }

    /// The worker-thread count this configuration asks for: an explicit
    /// `node_groups`, or the shared thread-ledger budget, clamped to
    /// `[1, n_nodes]`.  The ledger may still grant fewer at run time
    /// (degrading toward one group) when sweeps or shard runners hold the
    /// tokens.
    pub fn resolved_groups(&self) -> usize {
        let want = if self.node_groups == 0 {
            crate::util::threads::budget()
        } else {
            self.node_groups
        };
        want.clamp(1, self.n_nodes.max(1))
    }

    /// Map a tick count (simulator scale) to wall time: Δ = [`SIM_DELTA`].
    pub fn ticks_to_wall(&self, t: Ticks) -> Duration {
        Duration::from_secs_f64(self.delta.as_secs_f64() * t as f64 / SIM_DELTA as f64)
    }

    /// Map elapsed wall time since the run start to simulator ticks.
    pub fn wall_to_ticks(&self, since_start: Duration) -> Ticks {
        (since_start.as_secs_f64() / self.delta.as_secs_f64() * SIM_DELTA as f64) as Ticks
    }

    /// Wall-clock instant of a cycle boundary relative to the start.
    pub fn cycle_offset(&self, cycle: u64) -> Duration {
        Duration::from_secs_f64(self.delta.as_secs_f64() * cycle as f64)
    }

    /// The resolved measurement grid: `eval_at_cycles` sanitized (sorted,
    /// deduplicated, clamped to `[1, cycles]`), or the log-spaced default.
    /// Both the deployment's evaluation loop and `matched_sim_config` use
    /// this one grid, so the two curves always share their x axis.
    pub fn eval_grid(&self) -> Vec<u64> {
        let mut at: Vec<u64> = self
            .eval_at_cycles
            .iter()
            .copied()
            .filter(|&c| c >= 1 && c <= self.cycles)
            .collect();
        if at.is_empty() {
            // no explicit grid — or none of it within the run — falls back
            // to the log-spaced default (what the simulator does for an
            // empty at_cycles), keeping the curve non-empty and the axes
            // shared
            return crate::eval::log_spaced_cycles(self.cycles);
        }
        at.sort_unstable();
        at.dedup();
        at
    }
}

/// Per-node counters collected at shutdown.
#[derive(Clone, Debug, Default)]
pub struct NodeStats {
    /// Algorithm-1 sends initiated (before any injected loss)
    pub sent: u64,
    /// frame bytes handed to the wire layer (matches `ModelMsg::wire_bytes`)
    pub bytes_sent: u64,
    /// frames decoded and applied while online
    pub received: u64,
    /// sends lost to the injected drop model before reaching a socket
    pub sim_dropped: u64,
    /// sends blocked by an active scenario partition
    pub partition_blocked: u64,
    /// frames discarded because the node was offline (churn backlog)
    pub backlog_lost: u64,
    /// connect/write failures — real message loss the protocol tolerates
    pub io_errors: u64,
    /// malformed frames addressed to this node (wrong dimensionality)
    pub decode_errors: u64,
    /// sends that rode an already-open outbound connection (LRU hit)
    pub conns_reused: u64,
    /// freshest model's update counter at shutdown
    pub model_t: u64,
}

/// State shared between the coordinator, the group threads, and the
/// evaluation loop.
pub(crate) struct SharedRun {
    pub(crate) stop: AtomicBool,
    /// network-wide send counter (x-axis companion of curve points)
    pub(crate) messages_sent: AtomicU64,
    /// per-node freshest models — the deployment's monitoring tap.  Each
    /// node publishes after every update; the evaluation loop and the final
    /// error sweep read from here instead of poking protocol state.
    pub(crate) models: Vec<Mutex<LinearModel>>,
}

impl SharedRun {
    pub(crate) fn new(n: usize, d: usize) -> Self {
        SharedRun {
            stop: AtomicBool::new(false),
            messages_sent: AtomicU64::new(0),
            models: (0..n).map(|_| Mutex::new(LinearModel::zeros(d))).collect(),
        }
    }
}

/// Everything one group thread needs.
pub(crate) struct GroupCtx<'a> {
    /// contiguous node range this thread multiplexes
    pub(crate) nodes: Range<usize>,
    /// the group's shared listener — routed (v2) frames carry the
    /// destination node id this listener can no longer imply
    pub(crate) listener: TcpListener,
    /// every node's address = its group's listener address
    pub(crate) addrs: &'a [SocketAddr],
    pub(crate) cfg: &'a DeployConfig,
    pub(crate) data: &'a Dataset,
    pub(crate) churn: Option<&'a ChurnSchedule>,
    /// compiled scenario timeline; every node drives its own cursor off an
    /// Arc clone of the one shared compilation
    pub(crate) scn: Option<&'a std::sync::Arc<CompiledScenario>>,
    /// resolved gossip graph, shared read-only across groups; per-node
    /// samplers draw neighbors from its CSR rows
    pub(crate) topo: Option<&'a std::sync::Arc<crate::p2p::Topology>>,
    pub(crate) start: Instant,
    pub(crate) shared: &'a SharedRun,
}

/// What one group thread reports at shutdown: its nodes' counters plus the
/// group-level scheduling and I/O pressure metrics the coordinator
/// aggregates into `DeployStats`.
#[derive(Debug, Default)]
pub(crate) struct GroupReport {
    pub(crate) per_node: Vec<NodeStats>,
    /// readiness-loop iterations
    pub(crate) wakes: u64,
    /// complete frames pulled off inbound streams (before routing/gating)
    pub(crate) frames: u64,
    /// worst observed lag between a timer's due time and its firing wake
    pub(crate) timer_lag_max: Duration,
    /// inbound connections accepted on the group listener
    pub(crate) conns_accepted: u64,
    /// poisoned streams (bad header / malformed frame; connection dropped)
    pub(crate) decode_errors: u64,
    /// routed frames addressed outside this group's node range
    pub(crate) misrouted: u64,
}

// ---- timer wheel --------------------------------------------------------

/// What a due timer means for one node — the inversion of control at the
/// heart of the node-group runtime: everything the thread-per-node loop
/// checked by polling is an explicit wall-clock event here.
enum TimerKind {
    /// Algorithm-1 active gossip period; re-armed with fresh jitter on fire
    Gossip,
    /// a send held back by the injected delay model, carrying its frame
    Delayed { dst: usize, bytes: Vec<u8> },
    /// the node's next churn pause/resume boundary
    Churn,
    /// the node's scenario cursor has a mutation coming due
    Scenario,
}

struct TimerEntry {
    due: Instant,
    node: usize,
    kind: TimerKind,
}

/// Hashed timer wheel: fixed wall-clock slots, entries filed by due time
/// modulo one revolution.  Entries further out than a revolution land in
/// the farthest slot and are re-filed when it drains, so scheduling and
/// firing stay O(1) amortized regardless of horizon — the group loop
/// advances the wheel once per wake instead of scanning every node's
/// timers.
struct TimerWheel {
    slots: Vec<Vec<TimerEntry>>,
    /// wall time per slot
    res: Duration,
    /// slot index the cursor sits in
    cur: usize,
    /// slot-granular frontier: every whole slot before this has drained
    cursor: Instant,
}

impl TimerWheel {
    fn new(start: Instant, res: Duration, slots: usize) -> Self {
        TimerWheel {
            slots: (0..slots.max(2)).map(|_| Vec::new()).collect(),
            res: res.max(Duration::from_micros(50)),
            cur: 0,
            cursor: start,
        }
    }

    fn slot_of(&self, due: Instant) -> usize {
        let ahead = due.saturating_duration_since(self.cursor);
        let steps = (ahead.as_nanos() / self.res.as_nanos()) as usize;
        (self.cur + steps.min(self.slots.len() - 1)) % self.slots.len()
    }

    fn schedule(&mut self, due: Instant, node: usize, kind: TimerKind) {
        let due = due.max(self.cursor);
        let slot = self.slot_of(due);
        self.slots[slot].push(TimerEntry { due, node, kind });
    }

    /// Advance to `now`, moving every entry due at or before `now` into
    /// `fired` (ordering is slot-granular; sub-resolution reordering sits
    /// below the poll quantum).  Returns the worst firing lag observed.
    fn advance(&mut self, now: Instant, fired: &mut Vec<TimerEntry>) -> Duration {
        let mut lag = Duration::ZERO;
        loop {
            let slot_end = self.cursor + self.res;
            if slot_end <= now {
                // the whole slot is in the past: drain it, re-filing
                // entries that belong to a later revolution
                let entries = std::mem::take(&mut self.slots[self.cur]);
                self.cur = (self.cur + 1) % self.slots.len();
                self.cursor = slot_end;
                for e in entries {
                    if e.due <= now {
                        lag = lag.max(now.saturating_duration_since(e.due));
                        fired.push(e);
                    } else {
                        let slot = self.slot_of(e.due);
                        self.slots[slot].push(e);
                    }
                }
            } else {
                // partial slot: fire only what is already due, keep the rest
                let slot = &mut self.slots[self.cur];
                let mut i = 0;
                while i < slot.len() {
                    if slot[i].due <= now {
                        let e = slot.swap_remove(i);
                        lag = lag.max(now.saturating_duration_since(e.due));
                        fired.push(e);
                    } else {
                        i += 1;
                    }
                }
                return lag;
            }
        }
    }
}

/// Slots per group wheel.  At the default resolution (= poll interval ≈
/// Δ/30) one revolution spans ≈ 8.5Δ, so only the tail of the extreme
/// delay model ([Δ, 10Δ]) ever re-files.
const WHEEL_SLOTS: usize = 256;

// ---- per-connection I/O state -------------------------------------------

/// One accepted inbound connection with its incremental frame buffer.
struct InConn {
    stream: TcpStream,
    frames: FrameBuf,
}

impl InConn {
    fn new(stream: TcpStream) -> io::Result<Self> {
        stream.set_nonblocking(true)?;
        Ok(InConn { stream, frames: FrameBuf::default() })
    }

    /// Pull everything currently readable into the frame buffer and return
    /// all complete routed frames as `(dst, msg)`.  `closed` reports EOF /
    /// error / poisoned framing; buffered frames are still returned first.
    fn poll(&mut self) -> (Vec<(usize, ModelMsg)>, u64, bool) {
        let mut tmp = [0u8; 8192];
        let mut closed = false;
        loop {
            match self.stream.read(&mut tmp) {
                Ok(0) => {
                    closed = true;
                    break;
                }
                Ok(k) => self.frames.extend(&tmp[..k]),
                Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(_) => {
                    closed = true;
                    break;
                }
            }
        }
        let mut msgs = Vec::new();
        let mut bad = 0;
        while let Some(res) = self.frames.next_routed() {
            match res {
                Ok(m) => msgs.push(m),
                Err(_) => {
                    // framing cannot resynchronize past a malformed frame
                    bad += 1;
                    closed = true;
                    break;
                }
            }
        }
        (msgs, bad, closed)
    }
}

/// Persistent outbound connections, LRU-capped at `cap` so a large
/// deployment does not hold O(n²) sockets.  Streams are nonblocking: a
/// send queues the frame whole and flushes what the socket accepts;
/// `flush_pending` resumes partial writes on later readiness passes.
struct OutConn {
    stream: TcpStream,
    wbuf: WriteBuf,
}

struct OutConns {
    conns: HashMap<usize, OutConn>,
    order: Vec<usize>,
    cap: usize,
    /// evictions that discarded pending bytes (counted as message loss)
    dropped_pending: u64,
}

impl OutConns {
    fn new(cap: usize) -> Self {
        OutConns {
            conns: HashMap::new(),
            order: Vec::new(),
            cap: cap.max(1),
            dropped_pending: 0,
        }
    }

    #[allow(dead_code)] // used by the connection-reuse tests
    fn len(&self) -> usize {
        self.conns.len()
    }

    /// Queue a full frame to `dst`, connecting (or reconnecting) if needed,
    /// and flush as much as the socket accepts now.  `Ok(reused)` reports
    /// whether an already-open connection carried the frame (`WouldBlock`
    /// leaves the unsent suffix pending for `flush_pending`); an error
    /// means the frame is lost — the protocol tolerates message loss by
    /// design, so callers just count it.
    fn send(&mut self, dst: usize, addr: SocketAddr, bytes: &[u8]) -> io::Result<bool> {
        let reused = if self.conns.contains_key(&dst) {
            // LRU: a reused connection moves to the back of the order
            self.order.retain(|&p| p != dst);
            self.order.push(dst);
            true
        } else {
            if self.conns.len() >= self.cap {
                let evict = self.order.remove(0);
                if let Some(c) = self.conns.remove(&evict) {
                    // dropping closes the socket; pending bytes go with it
                    if c.wbuf.pending() > 0 {
                        self.dropped_pending += 1;
                    }
                }
            }
            let stream = TcpStream::connect_timeout(&addr, Duration::from_millis(200))?;
            stream.set_nodelay(true).ok();
            stream.set_nonblocking(true)?;
            self.conns.insert(dst, OutConn { stream, wbuf: WriteBuf::default() });
            self.order.push(dst);
            false
        };
        let conn = self.conns.get_mut(&dst).unwrap();
        conn.wbuf.push(bytes);
        match conn.wbuf.flush(&mut conn.stream) {
            Ok(_) => Ok(reused),
            Err(e) => {
                // drop the broken connection; the next send reconnects
                self.conns.remove(&dst);
                self.order.retain(|&p| p != dst);
                Err(e)
            }
        }
    }

    /// Retry partial writes left pending by `WouldBlock`; returns the
    /// number of connections dropped on hard errors (each counts as
    /// message loss).
    fn flush_pending(&mut self) -> u64 {
        let mut broken = Vec::new();
        for (&dst, c) in self.conns.iter_mut() {
            if c.wbuf.pending() > 0 && c.wbuf.flush(&mut c.stream).is_err() {
                broken.push(dst);
            }
        }
        let errs = broken.len() as u64;
        for dst in broken {
            self.conns.remove(&dst);
            self.order.retain(|&p| p != dst);
        }
        errs
    }
}

// ---- per-node protocol state --------------------------------------------

/// Everything one node owns — exactly the per-node state of the retired
/// thread-per-node runtime, minus the thread.  The group loop indexes into
/// its `Vec<NodeState>` by `node - range.start`.
struct NodeState {
    rng: Rng,
    sampler: PeerSampler,
    net: Network,
    cache: ModelCache,
    last_recv: LinearModel,
    stats: NodeStats,
    out: OutConns,
    scn: Option<ScenarioDriver>,
    /// the freshest model's example reservoir (empty for pointwise
    /// learners); rides out with every send and is replaced on every
    /// applied receive, mirroring the simulator's per-node reservoir row
    res: Vec<f32>,
    join_tick: Ticks,
    forced_off: bool,
    /// churn liveness cache, maintained by `TimerKind::Churn` events so the
    /// hot receive path skips the per-frame binary search over the schedule
    churn_online: bool,
    drift_sign: f32,
}

impl NodeState {
    fn online(&self, now_ticks: Ticks) -> bool {
        now_ticks >= self.join_tick && !self.forced_off && self.churn_online
    }
}

/// Poll interval of the group readiness loop: fine enough that delivery
/// latency stays well under Δ, coarse enough that a handful of group
/// threads do not saturate a small machine with wakeups.
fn poll_interval(delta: Duration) -> Duration {
    (delta / 30).clamp(Duration::from_micros(200), Duration::from_millis(2))
}

/// Jittered per-iteration gossip period: N(Δ, Δ/10), clipped positive
/// (Section IV — same jitter the simulator applies).
fn jitter(delta: Duration, rng: &mut Rng) -> Duration {
    let d = delta.as_secs_f64();
    Duration::from_secs_f64(rng.normal_scaled(d, d / 10.0).max(d / 10.0))
}

fn publish(slot: &Mutex<LinearModel>, m: &LinearModel) {
    *slot.lock().unwrap() = m.clone();
}

fn send_now(st: &mut NodeState, dst: usize, addr: SocketAddr, bytes: &[u8]) {
    match st.out.send(dst, addr, bytes) {
        Ok(true) => st.stats.conns_reused += 1,
        Ok(false) => {}
        Err(_) => st.stats.io_errors += 1,
    }
}

/// One group thread's event loop: multiplex `ctx.nodes` over a shared
/// listener, a timer wheel, and per-node nonblocking sockets until the
/// coordinator raises the stop flag.  Per wake it (1) fires due timers —
/// scenario/churn state transitions first, so gating matches the head of
/// the retired per-node loop — (2) accepts and drains inbound streams,
/// (3) fires the buffered gossip/delayed-send events, (4) retries pending
/// partial writes.
pub(crate) fn group_main(ctx: GroupCtx<'_>) -> GroupReport {
    let cfg = ctx.cfg;
    let d = ctx.data.d();
    let n0 = ctx.nodes.start;
    let horizon = SIM_DELTA * (cfg.cycles + 1);
    let poll = poll_interval(cfg.delta);
    // liveness is not globally observable in a deployment; samplers treat
    // every peer as a candidate and sends to offline peers are simply lost
    let assume_online = Bitset::filled(cfg.n_nodes, true);
    // pairwise learning: reservoirs ride with the models; one scratch per
    // group thread serves every node's reservoir-consuming step
    let pairwise_auc = cfg.learner.as_pairwise().copied();
    let res_cap = if pairwise_auc.is_some() { cfg.reservoir } else { 0 };
    let mut scratch = PairScratch::default();

    let mut wheel = TimerWheel::new(ctx.start, poll, WHEEL_SLOTS);
    let mut nodes: Vec<NodeState> = Vec::with_capacity(ctx.nodes.len());
    for me in ctx.nodes.clone() {
        // per-node RNG stream and draw order are identical to the
        // thread-per-node runtime: seed derivation, then sampler init, then
        // the first gossip jitter
        let mut rng = Rng::new(cfg.seed ^ (me as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let sampler =
            PeerSampler::new_local(cfg.sampler, ctx.topo, me, cfg.n_nodes, SIM_DELTA, &mut rng);
        let mut cache = ModelCache::new(cfg.cache_size);
        cache.add(LinearModel::zeros(d));
        let scn = ctx.scn.map(|c| ScenarioDriver::new(c.clone()));
        let join_tick = ctx.scn.map_or(0, |c| c.join_tick(me));
        let churn_online = ctx.churn.map_or(true, |ch| ch.is_online(me, 0));
        wheel.schedule(ctx.start + jitter(cfg.delta, &mut rng), me, TimerKind::Gossip);
        if let Some(ch) = ctx.churn {
            if let Some((t, _)) = ch.next_transition(me, 0) {
                wheel.schedule(ctx.start + cfg.ticks_to_wall(t), me, TimerKind::Churn);
            }
        }
        if let Some(t) = scn.as_ref().and_then(|drv| drv.next_due_tick()) {
            wheel.schedule(ctx.start + cfg.ticks_to_wall(t), me, TimerKind::Scenario);
        }
        nodes.push(NodeState {
            rng,
            sampler,
            net: Network::new(cfg.network),
            cache,
            last_recv: LinearModel::zeros(d),
            stats: NodeStats::default(),
            out: OutConns::new(OUT_CONN_CAP),
            scn,
            res: if res_cap > 0 { pairwise::reservoir_new(res_cap) } else { Vec::new() },
            join_tick,
            forced_off: false,
            churn_online,
            drift_sign: 1.0,
        });
    }

    let mut report = GroupReport::default();
    let mut in_conns: Vec<InConn> = Vec::new();
    let mut fired: Vec<TimerEntry> = Vec::new();
    let mut acts: Vec<TimerEntry> = Vec::new();

    while !ctx.shared.stop.load(Ordering::Relaxed) {
        let now = Instant::now();
        let now_ticks = cfg
            .wall_to_ticks(now.saturating_duration_since(ctx.start))
            .min(horizon - 1);
        report.wakes += 1;

        // ---- timers: everything due since the last wake.  State
        // transitions (scenario, churn) apply immediately; gossip and
        // delayed sends are buffered to fire after this wake's receives,
        // preserving the mutations → reads → sends order of the retired
        // per-node loop.
        fired.clear();
        let lag = wheel.advance(now, &mut fired);
        report.timer_lag_max = report.timer_lag_max.max(lag);
        for e in fired.drain(..) {
            let idx = e.node - n0;
            match e.kind {
                TimerKind::Scenario => {
                    let st = &mut nodes[idx];
                    while let Some(m) = st.scn.as_mut().and_then(|drv| drv.pop_due(now_ticks)) {
                        match m {
                            Mutation::SetDrop(p) => st.net.cfg.drop_prob = p,
                            Mutation::SetDelay(model) => st.net.cfg.delay = model,
                            Mutation::SetPartition(c) => st.net.set_partition(Some(c)),
                            Mutation::Heal => {
                                st.net.set_partition(None);
                                st.net.restore_edges(None);
                            }
                            Mutation::EdgeFail(edges) => st.net.fail_edges(&edges),
                            Mutation::EdgeRestore(edges) => {
                                st.net.restore_edges(edges.as_deref())
                            }
                            Mutation::Drift => st.drift_sign = -st.drift_sign,
                            Mutation::ForceOffline(ids) => st.forced_off |= ids.contains(&e.node),
                            Mutation::Restore(ids) => {
                                if ids.contains(&e.node) {
                                    st.forced_off = false;
                                }
                            }
                            // membership growth is precomputed per node via
                            // join_tick
                            Mutation::Grow(_) => {}
                        }
                    }
                    if let Some(t) = st.scn.as_ref().and_then(|drv| drv.next_due_tick()) {
                        wheel.schedule(ctx.start + cfg.ticks_to_wall(t), e.node, TimerKind::Scenario);
                    }
                }
                TimerKind::Churn => {
                    if let Some(ch) = ctx.churn {
                        nodes[idx].churn_online = ch.is_online(e.node, now_ticks);
                        if let Some((t, _)) = ch.next_transition(e.node, now_ticks) {
                            wheel.schedule(ctx.start + cfg.ticks_to_wall(t), e.node, TimerKind::Churn);
                        }
                    }
                }
                _ => acts.push(e),
            }
        }

        // ---- accept new inbound connections on the group listener
        loop {
            match ctx.listener.accept() {
                Ok((s, _)) => {
                    if let Ok(c) = InConn::new(s) {
                        report.conns_accepted += 1;
                        in_conns.push(c);
                    }
                }
                Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(_) => break,
            }
        }

        // ---- drain every complete frame from every connection, routing
        // each to its destination node's state machine
        let mut k = 0;
        while k < in_conns.len() {
            let (msgs, bad, closed) = in_conns[k].poll();
            report.frames += msgs.len() as u64;
            report.decode_errors += bad;
            for (dst, mut msg) in msgs {
                if dst < ctx.nodes.start || dst >= ctx.nodes.end {
                    // a frame for another group's node reached our
                    // listener: sender bug — never apply it to a wrong node
                    report.misrouted += 1;
                    continue;
                }
                let st = &mut nodes[dst - n0];
                if !st.online(now_ticks) {
                    // churn: the node is paused — the message is lost, as
                    // in the simulator's offline delivery
                    st.stats.backlog_lost += 1;
                    continue;
                }
                if msg.w.len() != d {
                    // wrong dimensionality: structurally valid frame from a
                    // confused peer — rejected like any malformed input
                    st.stats.decode_errors += 1;
                    continue;
                }
                st.stats.received += 1;
                // NEWSCAST view merge rides along with learning gossip.
                // Descriptor node ids come off the wire, so bound-check
                // them before they can enter the view (and later index
                // addrs).
                msg.view.retain(|desc| desc.node < cfg.n_nodes);
                st.sampler.on_receive(dst, &msg.view);
                // the wire carries materialized weights (scale folded)
                let incoming = LinearModel::from_weights(msg.w, msg.t);
                let x = ctx.data.train.row(dst);
                // concept drift re-labels the local example with the
                // scenario's current sign
                let y = st.drift_sign * ctx.data.train_y[dst];
                let created = if let Some(auc) = pairwise_auc.as_ref() {
                    // the reservoir decoded at occupancy; re-expand to the
                    // configured capacity before stepping/offering
                    pairwise::set_capacity(&mut msg.res, res_cap);
                    let created = create_model_pairwise_step(
                        cfg.variant,
                        cfg.merge,
                        auc,
                        incoming,
                        &mut st.last_recv,
                        &x,
                        y,
                        &msg.res,
                        &ctx.data.train,
                        &mut scratch,
                    );
                    // the created model inherits the walk's reservoir plus
                    // the local example — the simulator's flush semantics
                    let draw = st.rng.next_u64();
                    pairwise::offer(&mut msg.res, dst as u32, y, draw);
                    st.res = std::mem::take(&mut msg.res);
                    created
                } else {
                    create_model_step(
                        cfg.variant,
                        cfg.merge,
                        &cfg.learner,
                        incoming,
                        &mut st.last_recv,
                        &x,
                        y,
                    )
                };
                publish(&ctx.shared.models[dst], &created);
                st.cache.add(created);
            }
            if closed {
                in_conns.swap_remove(k);
            } else {
                k += 1;
            }
        }

        // ---- fire the buffered per-node events: Algorithm 1's periodic
        // send, and delayed sends whose injected latency elapsed
        for e in acts.drain(..) {
            let idx = e.node - n0;
            match e.kind {
                TimerKind::Gossip => {
                    // re-arm first: the jitter draw precedes the peer
                    // selection draw, as in the retired runtime
                    let due = now + jitter(cfg.delta, &mut nodes[idx].rng);
                    wheel.schedule(due, e.node, TimerKind::Gossip);
                    let st = &mut nodes[idx];
                    if !st.online(now_ticks) {
                        continue;
                    }
                    // belt-and-braces: a sampler can only know ids <
                    // n_nodes (views are bound-checked on receive), but
                    // never let a bad id reach the addrs index
                    let Some(dst) = st
                        .sampler
                        .select(e.node, now_ticks, &assume_online, &mut st.rng)
                        .filter(|&p| p < cfg.n_nodes)
                    else {
                        continue;
                    };
                    let freshest = st.cache.freshest();
                    let msg = ModelMsg {
                        src: e.node,
                        w: freshest.weights(),
                        scale: 1.0,
                        t: freshest.t,
                        view: st.sampler.payload(e.node, now_ticks),
                        res: st.res.clone(),
                    };
                    st.stats.sent += 1;
                    // byte accounting stays on the v1 frame size shared
                    // with the simulator; the +8 routing bytes are a
                    // runtime addressing artifact, not protocol traffic
                    st.stats.bytes_sent += msg.wire_bytes() as u64;
                    ctx.shared.messages_sent.fetch_add(1, Ordering::Relaxed);
                    match st.net.transmit_between(e.node, dst, &mut st.rng) {
                        Fate::Dropped => st.stats.sim_dropped += 1,
                        Fate::Blocked => st.stats.partition_blocked += 1,
                        Fate::Deliver(delay_ticks) => {
                            let bytes = wire::encode_routed(dst, &msg);
                            let due = now + cfg.ticks_to_wall(delay_ticks);
                            wheel.schedule(due, e.node, TimerKind::Delayed { dst, bytes });
                        }
                    }
                }
                TimerKind::Delayed { dst, bytes } => {
                    send_now(&mut nodes[idx], dst, ctx.addrs[dst], &bytes);
                }
                _ => unreachable!("state timers handled before receives"),
            }
        }

        // ---- resume partial writes left pending by WouldBlock
        for st in nodes.iter_mut() {
            st.stats.io_errors += st.out.flush_pending();
        }

        std::thread::sleep(poll);
    }

    // ---- shutdown: publish final models, collect counters
    for (i, mut st) in nodes.into_iter().enumerate() {
        let me = n0 + i;
        st.stats.model_t = st.cache.freshest().t;
        st.stats.io_errors += st.out.dropped_pending;
        publish(&ctx.shared.models[me], st.cache.freshest());
        report.per_node.push(st.stats);
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::p2p::newscast::Descriptor;

    fn msg(d: usize, t: u64) -> ModelMsg {
        ModelMsg {
            src: 1,
            w: (0..d).map(|i| i as f32).collect(),
            scale: 1.0,
            t,
            view: vec![Descriptor { node: 2, ts: t }],
            res: Vec::new(),
        }
    }

    /// The readiness-loop invariant: one persistent connection carries many
    /// routed frames, and a single poll drains every complete frame with
    /// its destination intact.
    #[test]
    fn persistent_connection_drains_all_routed_frames_per_poll() {
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap();
        let mut sender = TcpStream::connect(addr).unwrap();
        sender.set_nodelay(true).unwrap();
        for t in 0..5 {
            use std::io::Write;
            sender.write_all(&wire::encode_routed(t as usize + 10, &msg(7, t))).unwrap();
        }
        let (stream, _) = listener.accept().unwrap();
        let mut conn = InConn::new(stream).unwrap();
        // nonblocking localhost read: poll until all five frames landed
        let deadline = Instant::now() + Duration::from_secs(5);
        let mut got: Vec<(usize, ModelMsg)> = Vec::new();
        while got.len() < 5 && Instant::now() < deadline {
            let (msgs, bad, closed) = conn.poll();
            assert_eq!(bad, 0);
            assert!(!closed, "sender is still connected");
            got.extend(msgs);
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(got.len(), 5, "one wake must drain every buffered frame");
        for (t, (dst, m)) in got.iter().enumerate() {
            assert_eq!(*dst, t + 10, "routing survives multiplexed streams");
            assert_eq!(m.t, t as u64);
            assert_eq!(m.w.len(), 7);
            assert_eq!(m.view.len(), 1, "views travel over the wire");
        }
        // the connection stays open: more frames flow without reconnecting
        use std::io::Write;
        sender.write_all(&wire::encode_routed(3, &msg(7, 99))).unwrap();
        let deadline = Instant::now() + Duration::from_secs(5);
        let mut late = Vec::new();
        while late.is_empty() && Instant::now() < deadline {
            late.extend(conn.poll().0);
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(late[0].1.t, 99);
    }

    #[test]
    fn in_conn_reports_eof_after_draining() {
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap();
        let mut sender = TcpStream::connect(addr).unwrap();
        use std::io::Write;
        sender.write_all(&wire::encode_routed(0, &msg(3, 1))).unwrap();
        drop(sender);
        let (stream, _) = listener.accept().unwrap();
        let mut conn = InConn::new(stream).unwrap();
        let deadline = Instant::now() + Duration::from_secs(5);
        let mut all = Vec::new();
        let mut closed = false;
        while !closed && Instant::now() < deadline {
            let (msgs, _, c) = conn.poll();
            all.extend(msgs);
            closed = c;
            std::thread::sleep(Duration::from_millis(1));
        }
        assert!(closed);
        assert_eq!(all.len(), 1, "buffered frames are delivered before EOF");
    }

    #[test]
    fn out_conns_reuse_and_cap() {
        let listeners: Vec<TcpListener> =
            (0..3).map(|_| TcpListener::bind(("127.0.0.1", 0)).unwrap()).collect();
        let addrs: Vec<SocketAddr> =
            listeners.iter().map(|l| l.local_addr().unwrap()).collect();
        let mut out = OutConns::new(2);
        let frame = wire::encode_routed(0, &msg(2, 1));
        // two sends to the same peer share one connection
        assert!(!out.send(0, addrs[0], &frame).unwrap(), "first send connects");
        assert!(out.send(0, addrs[0], &frame).unwrap(), "second send reuses");
        assert_eq!(out.len(), 1);
        let (first, _) = listeners[0].accept().unwrap();
        listeners[0].set_nonblocking(true).unwrap();
        assert!(
            listeners[0].accept().is_err(),
            "repeat sends must not open a second connection"
        );
        let mut conn = InConn::new(first).unwrap();
        let deadline = Instant::now() + Duration::from_secs(5);
        let mut n = 0;
        while n < 2 && Instant::now() < deadline {
            n += conn.poll().0.len();
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(n, 2, "both frames arrive on the one persistent connection");
        // the cap bounds simultaneous sockets, evicting least-recently-used
        out.send(1, addrs[1], &frame).unwrap();
        out.send(0, addrs[0], &frame).unwrap(); // reuse: 0 becomes MRU
        out.send(2, addrs[2], &frame).unwrap(); // evicts 1, not 0
        assert_eq!(out.len(), 2, "LRU cap must evict");
        // peer 0's connection survived: another send opens no new connection
        assert!(out.send(0, addrs[0], &frame).unwrap());
        assert!(
            listeners[0].accept().is_err(),
            "the hot connection must not be the one evicted"
        );
    }

    #[test]
    fn timer_wheel_fires_due_entries_in_slot_order() {
        let t0 = Instant::now();
        let res = Duration::from_millis(1);
        let mut wheel = TimerWheel::new(t0, res, 16);
        wheel.schedule(t0 + Duration::from_millis(5), 2, TimerKind::Gossip);
        wheel.schedule(t0 + Duration::from_millis(1), 0, TimerKind::Gossip);
        wheel.schedule(t0 + Duration::from_millis(9), 3, TimerKind::Churn);
        let mut fired = Vec::new();
        // nothing before its time
        assert_eq!(wheel.advance(t0 + Duration::from_micros(500), &mut fired), Duration::ZERO);
        assert!(fired.is_empty());
        // the 1 ms and 5 ms entries fire by 6 ms, oldest slot first
        wheel.advance(t0 + Duration::from_millis(6), &mut fired);
        let nodes: Vec<usize> = fired.iter().map(|e| e.node).collect();
        assert_eq!(nodes, vec![0, 2]);
        // lag is measured against the due time, not the slot boundary
        fired.clear();
        let lag = wheel.advance(t0 + Duration::from_millis(30), &mut fired);
        assert_eq!(fired.len(), 1);
        assert_eq!(fired[0].node, 3);
        assert!(lag >= Duration::from_millis(20), "lag {lag:?}");
        // wheel is drained
        fired.clear();
        wheel.advance(t0 + Duration::from_millis(40), &mut fired);
        assert!(fired.is_empty());
    }

    #[test]
    fn timer_wheel_refiles_entries_beyond_one_revolution() {
        let t0 = Instant::now();
        let res = Duration::from_millis(1);
        // 4 slots => one revolution = 4 ms; schedule 10 ms out
        let mut wheel = TimerWheel::new(t0, res, 4);
        wheel.schedule(t0 + Duration::from_millis(10), 7, TimerKind::Gossip);
        let mut fired = Vec::new();
        for step in 1..=9 {
            wheel.advance(t0 + Duration::from_millis(step), &mut fired);
            assert!(fired.is_empty(), "fired early at {step} ms");
        }
        wheel.advance(t0 + Duration::from_millis(10), &mut fired);
        assert_eq!(fired.len(), 1, "far-future entry fires exactly once, on time");
        assert_eq!(fired[0].node, 7);
    }

    #[test]
    fn group_ranges_partition_the_node_universe() {
        for (n, g) in [(10, 3), (10_000, 8), (5, 5), (7, 1), (4, 9)] {
            let ranges = group_ranges(n, g);
            assert_eq!(ranges.len(), g.min(n), "n={n} g={g}");
            assert_eq!(ranges[0].start, 0);
            assert_eq!(ranges.last().unwrap().end, n);
            for w in ranges.windows(2) {
                assert_eq!(w[0].end, w[1].start, "contiguous");
                assert!(w[0].len().abs_diff(w[1].len()) <= 1, "balanced");
            }
        }
    }

    #[test]
    fn resolved_groups_and_cap_scale_together() {
        let mut cfg = DeployConfig { n_nodes: 10_000, node_groups: 8, ..Default::default() };
        assert_eq!(cfg.resolved_groups(), 8);
        assert!(max_deploy_nodes(cfg.resolved_groups()) >= 10_000);
        // auto mode never exceeds the node count
        cfg.n_nodes = 3;
        cfg.node_groups = 0;
        assert!(cfg.resolved_groups() <= 3);
        cfg.node_groups = 99;
        assert_eq!(cfg.resolved_groups(), 3, "clamped to n_nodes");
        // the retired fixed cap is strictly inside the 5-group bound
        assert_eq!(max_deploy_nodes(5), 5 * MAX_GROUP_NODES);
        assert!(max_deploy_nodes(1) > 512);
    }

    #[test]
    fn tick_wall_mapping_roundtrips() {
        let cfg = DeployConfig { delta: Duration::from_millis(40), ..Default::default() };
        assert_eq!(cfg.ticks_to_wall(SIM_DELTA), Duration::from_millis(40));
        let back = cfg.wall_to_ticks(Duration::from_millis(40));
        assert!((back as i64 - SIM_DELTA as i64).abs() <= 1, "{back}");
        assert_eq!(cfg.cycle_offset(3), Duration::from_millis(120));
    }

    #[test]
    fn eval_grid_sanitizes_custom_cycles() {
        let mut cfg = DeployConfig { cycles: 30, ..Default::default() };
        assert_eq!(cfg.eval_grid(), crate::eval::log_spaced_cycles(30));
        // unsorted, duplicated, out-of-range input resolves to a clean grid
        cfg.eval_at_cycles = vec![10, 0, 5, 10, 50, 1];
        assert_eq!(cfg.eval_grid(), vec![1, 5, 10]);
        // a grid with nothing inside the run falls back to log-spaced, so
        // the curve is never empty and parity axes stay shared
        cfg.eval_at_cycles = vec![40, 50];
        assert_eq!(cfg.eval_grid(), crate::eval::log_spaced_cycles(30));
    }
}
