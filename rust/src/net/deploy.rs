//! Deployed gossip learning: the same protocol logic as gossip/protocol.rs,
//! but running as real concurrent peers over localhost TCP — one thread per
//! node, framed wire messages (net/wire.rs), wall-clock gossip periods.
//!
//! This is the "it actually runs as a distributed system" proof for the
//! simulator results: no global clock, no shared state between peers beyond
//! the sockets.  Peer sampling uses the static bootstrap list (each node
//! knows every address, oracle-style), since NEWSCAST view piggybacking is
//! already exercised in the simulator and the deployment's purpose is to
//! validate the asynchronous message path.

use crate::data::dataset::Dataset;
use crate::eval::zero_one_error;
use crate::gossip::cache::ModelCache;
use crate::gossip::create_model::{create_model, Variant};
use crate::gossip::message::ModelMsg;
use crate::learning::adaline::Learner;
use crate::learning::linear::LinearModel;
use crate::net::wire;
use crate::util::rng::Rng;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

#[derive(Clone)]
pub struct DeployConfig {
    pub n_nodes: usize,
    /// gossip period (wall clock)
    pub delta: Duration,
    /// run length
    pub duration: Duration,
    pub variant: Variant,
    pub learner: Learner,
    pub cache_size: usize,
    pub seed: u64,
}

impl Default for DeployConfig {
    fn default() -> Self {
        DeployConfig {
            n_nodes: 16,
            delta: Duration::from_millis(30),
            duration: Duration::from_millis(900),
            variant: Variant::Mu,
            learner: Learner::pegasos(1e-2),
            cache_size: 10,
            seed: 42,
        }
    }
}

pub struct DeployResult {
    /// mean 0-1 error of every node's freshest model at shutdown
    pub final_error: f64,
    pub messages_sent: u64,
    pub messages_received: u64,
    /// mean freshest-model update count (≈ cycles of learning absorbed)
    pub mean_model_t: f64,
}

struct Shared {
    stop: AtomicBool,
    sent: AtomicU64,
    received: AtomicU64,
}

/// Run a real deployment on localhost. `dataset.train` must have at least
/// `n_nodes` rows; node i owns row i.
pub fn run_deployment(cfg: &DeployConfig, data: &Dataset) -> std::io::Result<DeployResult> {
    assert!(data.n_train() >= cfg.n_nodes, "need one example per node");
    let n = cfg.n_nodes;
    let d = data.d();

    // bind listeners first so every peer knows every address
    let listeners: Vec<TcpListener> = (0..n)
        .map(|_| TcpListener::bind(("127.0.0.1", 0)))
        .collect::<std::io::Result<_>>()?;
    let addrs: Vec<std::net::SocketAddr> =
        listeners.iter().map(|l| l.local_addr()).collect::<std::io::Result<_>>()?;

    let shared = Arc::new(Shared {
        stop: AtomicBool::new(false),
        sent: AtomicU64::new(0),
        received: AtomicU64::new(0),
    });

    let result_models: Vec<std::sync::Mutex<Option<LinearModel>>> =
        (0..n).map(|_| std::sync::Mutex::new(None)).collect();
    let result_models = Arc::new(result_models);

    std::thread::scope(|scope| -> std::io::Result<()> {
        for (i, listener) in listeners.into_iter().enumerate() {
            let addrs = addrs.clone();
            let shared = Arc::clone(&shared);
            let results = Arc::clone(&result_models);
            let cfg = cfg.clone();
            let x = data.train.row(i);
            let y = data.train_y[i];
            listener.set_nonblocking(true)?;
            scope.spawn(move || {
                node_main(i, listener, &addrs, &cfg, x, y, d, &shared, &results);
            });
        }
        // run for the configured duration, then signal shutdown
        std::thread::sleep(cfg.duration);
        shared.stop.store(true, Ordering::SeqCst);
        Ok(())
    })?;

    // evaluate the final models
    let mut errs = Vec::with_capacity(n);
    let mut ts = Vec::with_capacity(n);
    for slot in result_models.iter() {
        let m = slot.lock().unwrap().take().expect("node must leave a model");
        ts.push(m.t as f64);
        errs.push(zero_one_error(&m, &data.test, &data.test_y));
    }
    Ok(DeployResult {
        final_error: crate::util::stats::mean(&errs),
        messages_sent: shared.sent.load(Ordering::SeqCst),
        messages_received: shared.received.load(Ordering::SeqCst),
        mean_model_t: crate::util::stats::mean(&ts),
    })
}

#[allow(clippy::too_many_arguments)]
fn node_main(
    me: usize,
    listener: TcpListener,
    addrs: &[std::net::SocketAddr],
    cfg: &DeployConfig,
    x: crate::data::dataset::Row<'_>,
    y: f32,
    d: usize,
    shared: &Shared,
    results: &[std::sync::Mutex<Option<LinearModel>>],
) {
    let mut rng = Rng::new(cfg.seed ^ (me as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    let mut cache = ModelCache::new(cfg.cache_size);
    cache.add(LinearModel::zeros(d));
    let mut last_recv = LinearModel::zeros(d);

    let mut next_send = Instant::now() + jitter(cfg.delta, &mut rng);
    while !shared.stop.load(Ordering::Relaxed) {
        // ---- receive (non-blocking accept, then drain one frame)
        match listener.accept() {
            Ok((mut stream, _)) => {
                stream
                    .set_read_timeout(Some(Duration::from_millis(50)))
                    .ok();
                if let Ok(msg) = wire::read_frame(&mut stream) {
                    shared.received.fetch_add(1, Ordering::Relaxed);
                    let m1 = LinearModel::from_weights(msg.w, msg.t);
                    let created = create_model(
                        cfg.variant,
                        &cfg.learner,
                        m1.clone(),
                        &last_recv,
                        &x,
                        y,
                    );
                    cache.add(created);
                    last_recv = m1;
                }
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {}
            Err(_) => {}
        }

        // ---- periodic send (Algorithm 1 active loop)
        if Instant::now() >= next_send {
            next_send = Instant::now() + jitter(cfg.delta, &mut rng);
            let dst = loop {
                let p = rng.below_usize(addrs.len());
                if p != me {
                    break p;
                }
            };
            let freshest = cache.freshest();
            let msg = ModelMsg {
                src: me,
                w: freshest.weights(),
                scale: 1.0,
                t: freshest.t,
                view: Vec::new(),
            };
            // best-effort: connection failures are message loss (the
            // protocol tolerates it by design)
            if let Ok(mut stream) =
                TcpStream::connect_timeout(&addrs[dst], Duration::from_millis(100))
            {
                if wire::write_frame(&mut stream, &msg).is_ok() {
                    shared.sent.fetch_add(1, Ordering::Relaxed);
                }
            }
        }

        std::thread::sleep(Duration::from_micros(300));
    }

    *results[me].lock().unwrap() = Some(cache.freshest().clone());
}

fn jitter(delta: Duration, rng: &mut Rng) -> Duration {
    let d = delta.as_secs_f64();
    Duration::from_secs_f64(rng.normal_scaled(d, d / 10.0).max(d / 10.0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{urls_like, Scale};

    #[test]
    fn tcp_deployment_learns() {
        let ds = urls_like(5, Scale(0.01)); // 100 rows; use 24 nodes
        let cfg = DeployConfig {
            n_nodes: 24,
            delta: Duration::from_millis(20),
            duration: Duration::from_millis(1500),
            ..Default::default()
        };
        let res = run_deployment(&cfg, &ds).expect("deployment");
        assert!(res.messages_sent > 24, "sent {}", res.messages_sent);
        assert!(res.messages_received > 0, "received 0");
        assert!(res.mean_model_t > 1.0, "models never updated");
        // zero-model error on this set is ~0.33 (predict-all-negative);
        // a real learning signal must appear even in a short wall-clock run
        assert!(res.final_error < 0.30, "final error {}", res.final_error);
    }

    #[test]
    fn deployment_respects_stop_flag_quickly() {
        let ds = urls_like(6, Scale(0.01));
        let cfg = DeployConfig {
            n_nodes: 8,
            duration: Duration::from_millis(200),
            ..Default::default()
        };
        let t0 = Instant::now();
        run_deployment(&cfg, &ds).unwrap();
        assert!(t0.elapsed() < Duration::from_secs(10));
    }
}
