//! Deployed gossip learning node runtime: the same protocol logic as
//! gossip/protocol.rs, but running as real concurrent peers over localhost
//! TCP — one thread per node, framed wire messages (net/wire.rs), wall-clock
//! gossip periods (DESIGN.md §10).
//!
//! Production-shaped, unlike the earlier connect-per-message toy:
//!
//! * **Persistent connections, multi-frame streaming.**  Each node keeps one
//!   outbound TCP connection per recent peer (LRU-capped) and drains *every*
//!   complete frame from every inbound connection per wake through
//!   [`wire::FrameBuf`], instead of accepting a fresh connection and reading
//!   a single frame.
//! * **NEWSCAST over the wire.**  The piggybacked views carried by the frame
//!   format are routed through [`PeerSampler`], so a deployment does the
//!   paper's real gossip-based peer sampling instead of oracle selection
//!   from the bootstrap address list.
//! * **Failure injection on wall clock.**  The simulator's tick-based models
//!   are reused directly: a [`ChurnSchedule`] pauses/resumes nodes (state
//!   retained, incoming frames counted as backlog losses), and the
//!   [`Network`] drop/delay model is applied at send, with tick delays
//!   mapped to wall time via [`SIM_DELTA`].
//! * **Per-node receive stats** ([`NodeStats`]), aggregated by the
//!   coordinator (`coordinator/`), which also runs the periodic evaluation
//!   loop that emits a real [`crate::eval::tracker::Curve`] on the same
//!   cycle axis as a matched-config `GossipSim` run.

use crate::data::dataset::Dataset;
use crate::gossip::cache::ModelCache;
use crate::gossip::create_model::{create_model_step, Variant};
use crate::gossip::message::ModelMsg;
use crate::learning::linear::LinearModel;
use crate::learning::Learner;
use crate::net::wire::{self, FrameBuf};
use crate::p2p::overlay::{PeerSampler, SamplerConfig};
use crate::scenario::driver::{CompiledScenario, Mutation, ScenarioDriver};
use crate::scenario::Scenario;
use crate::sim::churn::{ChurnConfig, ChurnSchedule};
use crate::sim::event::Ticks;
use crate::sim::network::{Fate, Network, NetworkConfig};
use crate::util::bitset::Bitset;
use crate::util::rng::Rng;
use std::collections::HashMap;
use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// One gossip period Δ expressed in simulator ticks — the scale on which the
/// reused tick-based failure models ([`NetworkConfig`], [`ChurnConfig`]) are
/// defined.  Matches `ProtocolConfig::paper_default`'s `delta`, so a
/// deployment and a matched simulator run interpret the same failure
/// configuration identically.
pub const SIM_DELTA: Ticks = 1000;

/// Cap on cached outbound connections per node (LRU-evicted beyond this);
/// bounds the deployment at O(n · cap) sockets instead of O(n²).
pub const OUT_CONN_CAP: usize = 16;

/// Sanity ceiling for `config::DeploySpec`-driven runs: the runtime spawns
/// one OS thread and one listener per node, so an unscaled dataset (urls:
/// 10,000 rows) must not silently become 10,000 threads — beyond this the
/// configuration layer asks for an explicit `nodes` / smaller `scale`.
pub const MAX_DEPLOY_NODES: usize = 512;

#[derive(Clone)]
pub struct DeployConfig {
    /// concurrent peers; node i owns training row i (needs
    /// `dataset.n_train() >= n_nodes`, and equality for simulator parity)
    pub n_nodes: usize,
    /// wall-clock gossip period Δ (one cycle)
    pub delta: Duration,
    /// run length in cycles (wall time = cycles * delta)
    pub cycles: u64,
    pub variant: Variant,
    pub learner: Learner,
    pub cache_size: usize,
    pub sampler: SamplerConfig,
    /// drop/delay model injected at send, in ticks ([`SIM_DELTA`] = Δ)
    pub network: NetworkConfig,
    /// tick-based pause/resume schedule driving wall-clock churn
    pub churn: Option<ChurnConfig>,
    /// peers sampled by the evaluation loop
    pub eval_peers: usize,
    /// cycles at which to measure; empty = log-spaced over the run
    pub eval_at_cycles: Vec<u64>,
    pub seed: u64,
    /// declarative failure/workload timeline (DESIGN.md §11), compiled at
    /// [`SIM_DELTA`] ticks per cycle so one scenario file drives the
    /// simulator and the deployment identically.  Flash-crowd joiners idle
    /// (threads up, protocol silent) until their join tick — the wall-clock
    /// analogue of the simulator's model-store growth.
    pub scenario: Option<Scenario>,
}

impl Default for DeployConfig {
    fn default() -> Self {
        DeployConfig {
            n_nodes: 16,
            delta: Duration::from_millis(30),
            cycles: 30,
            variant: Variant::Mu,
            learner: Learner::pegasos(1e-2),
            cache_size: 10,
            sampler: SamplerConfig::Newscast { view_size: 20 },
            network: NetworkConfig::reliable(),
            churn: None,
            eval_peers: 16,
            eval_at_cycles: Vec::new(),
            seed: 42,
            scenario: None,
        }
    }
}

impl DeployConfig {
    /// Section VI-A(i) "all failures" on wall clock: 50% drop, [Δ,10Δ]
    /// delay, churn at 90% online — the same models the simulator injects.
    pub fn with_extreme_failures(mut self) -> Self {
        self.network = NetworkConfig::extreme(SIM_DELTA);
        self.churn = Some(ChurnConfig::paper_default(SIM_DELTA));
        self
    }

    /// Map a tick count (simulator scale) to wall time: Δ = [`SIM_DELTA`].
    pub fn ticks_to_wall(&self, t: Ticks) -> Duration {
        Duration::from_secs_f64(self.delta.as_secs_f64() * t as f64 / SIM_DELTA as f64)
    }

    /// Map elapsed wall time since the run start to simulator ticks.
    pub fn wall_to_ticks(&self, since_start: Duration) -> Ticks {
        (since_start.as_secs_f64() / self.delta.as_secs_f64() * SIM_DELTA as f64) as Ticks
    }

    /// Wall-clock instant of a cycle boundary relative to the start.
    pub fn cycle_offset(&self, cycle: u64) -> Duration {
        Duration::from_secs_f64(self.delta.as_secs_f64() * cycle as f64)
    }

    /// The resolved measurement grid: `eval_at_cycles` sanitized (sorted,
    /// deduplicated, clamped to `[1, cycles]`), or the log-spaced default.
    /// Both the deployment's evaluation loop and `matched_sim_config` use
    /// this one grid, so the two curves always share their x axis.
    pub fn eval_grid(&self) -> Vec<u64> {
        let mut at: Vec<u64> = self
            .eval_at_cycles
            .iter()
            .copied()
            .filter(|&c| c >= 1 && c <= self.cycles)
            .collect();
        if at.is_empty() {
            // no explicit grid — or none of it within the run — falls back
            // to the log-spaced default (what the simulator does for an
            // empty at_cycles), keeping the curve non-empty and the axes
            // shared
            return crate::eval::log_spaced_cycles(self.cycles);
        }
        at.sort_unstable();
        at.dedup();
        at
    }
}

/// Per-node counters collected at shutdown.
#[derive(Clone, Debug, Default)]
pub struct NodeStats {
    /// Algorithm-1 sends initiated (before any injected loss)
    pub sent: u64,
    /// frame bytes handed to the wire layer (matches `ModelMsg::wire_bytes`)
    pub bytes_sent: u64,
    /// frames decoded and applied while online
    pub received: u64,
    /// sends lost to the injected drop model before reaching a socket
    pub sim_dropped: u64,
    /// sends blocked by an active scenario partition
    pub partition_blocked: u64,
    /// frames discarded because the node was offline (churn backlog)
    pub backlog_lost: u64,
    /// connect/write failures — real message loss the protocol tolerates
    pub io_errors: u64,
    /// malformed frames (the connection is dropped after one)
    pub decode_errors: u64,
    /// inbound connections accepted over the run
    pub conns_accepted: u64,
    /// freshest model's update counter at shutdown
    pub model_t: u64,
}

/// State shared between the coordinator, the node threads, and the
/// evaluation loop.
pub(crate) struct SharedRun {
    pub(crate) stop: AtomicBool,
    /// network-wide send counter (x-axis companion of curve points)
    pub(crate) messages_sent: AtomicU64,
    /// per-node freshest models — the deployment's monitoring tap.  Each
    /// node publishes after every update; the evaluation loop and the final
    /// error sweep read from here instead of poking protocol state.
    pub(crate) models: Vec<Mutex<LinearModel>>,
}

impl SharedRun {
    pub(crate) fn new(n: usize, d: usize) -> Self {
        SharedRun {
            stop: AtomicBool::new(false),
            messages_sent: AtomicU64::new(0),
            models: (0..n).map(|_| Mutex::new(LinearModel::zeros(d))).collect(),
        }
    }
}

/// Everything one node thread needs.
pub(crate) struct NodeCtx<'a> {
    pub(crate) me: usize,
    pub(crate) listener: TcpListener,
    pub(crate) addrs: &'a [SocketAddr],
    pub(crate) cfg: &'a DeployConfig,
    pub(crate) data: &'a Dataset,
    pub(crate) churn: Option<&'a ChurnSchedule>,
    /// compiled scenario timeline; every node drives its own cursor off an
    /// Arc clone of the one shared compilation
    pub(crate) scn: Option<&'a std::sync::Arc<CompiledScenario>>,
    pub(crate) start: Instant,
    pub(crate) shared: &'a SharedRun,
}

/// One accepted inbound connection with its incremental frame buffer.
struct InConn {
    stream: TcpStream,
    frames: FrameBuf,
}

impl InConn {
    fn new(stream: TcpStream) -> io::Result<Self> {
        stream.set_nonblocking(true)?;
        Ok(InConn { stream, frames: FrameBuf::default() })
    }

    /// Pull everything currently readable into the frame buffer and return
    /// all complete frames.  `closed` reports EOF / error / poisoned
    /// framing; buffered frames are still returned first.
    fn poll(&mut self) -> (Vec<ModelMsg>, u64, bool) {
        let mut tmp = [0u8; 8192];
        let mut closed = false;
        loop {
            match self.stream.read(&mut tmp) {
                Ok(0) => {
                    closed = true;
                    break;
                }
                Ok(k) => self.frames.extend(&tmp[..k]),
                Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(_) => {
                    closed = true;
                    break;
                }
            }
        }
        let mut msgs = Vec::new();
        let mut bad = 0;
        while let Some(res) = self.frames.next_frame() {
            match res {
                Ok(m) => msgs.push(m),
                Err(_) => {
                    // framing cannot resynchronize past a malformed frame
                    bad += 1;
                    closed = true;
                    break;
                }
            }
        }
        (msgs, bad, closed)
    }
}

/// Persistent outbound connections, LRU-capped at `cap` so a large
/// deployment does not hold O(n²) sockets.
struct OutConns {
    conns: HashMap<usize, TcpStream>,
    order: Vec<usize>,
    cap: usize,
}

impl OutConns {
    fn new(cap: usize) -> Self {
        OutConns { conns: HashMap::new(), order: Vec::new(), cap: cap.max(1) }
    }

    #[allow(dead_code)] // used by the connection-reuse tests
    fn len(&self) -> usize {
        self.conns.len()
    }

    /// Write a full frame to `dst`, connecting (or reconnecting) if needed.
    /// An error means the frame is lost — the protocol tolerates message
    /// loss by design, so callers just count it.
    fn send(&mut self, dst: usize, addr: SocketAddr, bytes: &[u8]) -> io::Result<()> {
        if self.conns.contains_key(&dst) {
            // LRU: a reused connection moves to the back of the order
            self.order.retain(|&p| p != dst);
            self.order.push(dst);
        } else {
            if self.conns.len() >= self.cap {
                let evict = self.order.remove(0);
                self.conns.remove(&evict); // dropping closes the socket
            }
            let stream = TcpStream::connect_timeout(&addr, Duration::from_millis(200))?;
            stream.set_nodelay(true).ok();
            stream.set_write_timeout(Some(Duration::from_millis(100)))?;
            self.conns.insert(dst, stream);
            self.order.push(dst);
        }
        let res = self.conns.get_mut(&dst).unwrap().write_all(bytes);
        if res.is_err() {
            // drop the broken connection; the next send reconnects
            self.conns.remove(&dst);
            self.order.retain(|&p| p != dst);
        }
        res
    }
}

/// A send delayed by the injected network model, waiting for its due time.
struct DelayedSend {
    due: Instant,
    dst: usize,
    bytes: Vec<u8>,
}

/// Poll interval of the node event loop: fine enough that delivery latency
/// stays well under Δ, coarse enough that hundreds of node threads do not
/// saturate a small machine with wakeups.
fn poll_interval(delta: Duration) -> Duration {
    (delta / 30).clamp(Duration::from_micros(200), Duration::from_millis(2))
}

/// Jittered per-iteration gossip period: N(Δ, Δ/10), clipped positive
/// (Section IV — same jitter the simulator applies).
fn jitter(delta: Duration, rng: &mut Rng) -> Duration {
    let d = delta.as_secs_f64();
    Duration::from_secs_f64(rng.normal_scaled(d, d / 10.0).max(d / 10.0))
}

fn publish(slot: &Mutex<LinearModel>, m: &LinearModel) {
    *slot.lock().unwrap() = m.clone();
}

/// One node's event loop (Algorithm 1 over real sockets).  Runs until the
/// coordinator raises the stop flag; returns the node's counters.
pub(crate) fn node_main(ctx: NodeCtx<'_>) -> NodeStats {
    let cfg = ctx.cfg;
    let me = ctx.me;
    let d = ctx.data.d();
    let mut rng = Rng::new(cfg.seed ^ (me as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    // each node owns a sampler instance and uses only its own view slot —
    // the same NEWSCAST code path the simulator exercises, fed here by the
    // views that arrive piggybacked on real frames
    let mut sampler = PeerSampler::new_local(cfg.sampler, me, cfg.n_nodes, SIM_DELTA, &mut rng);
    // liveness is not globally observable in a deployment; samplers treat
    // every peer as a candidate and sends to offline peers are simply lost
    let assume_online = Bitset::filled(cfg.n_nodes, true);
    let mut net = Network::new(cfg.network);
    let mut cache = ModelCache::new(cfg.cache_size);
    cache.add(LinearModel::zeros(d));
    let mut last_recv = LinearModel::zeros(d);
    let mut stats = NodeStats::default();
    let x = ctx.data.train.row(me);
    let base_y = ctx.data.train_y[me];

    let mut in_conns: Vec<InConn> = Vec::new();
    let mut out = OutConns::new(OUT_CONN_CAP);
    let mut delayed: Vec<DelayedSend> = Vec::new();

    // scenario timeline: every node drives its own cursor over the shared
    // compiled mutation list (seed-deterministic, so all nodes agree)
    let mut scn_drv = ctx.scn.map(|c| ScenarioDriver::new(c.clone()));
    let join_tick = ctx.scn.map_or(0, |c| c.join_tick(me));
    let mut forced_off = false;
    let mut drift_sign = 1.0f32;

    let horizon = SIM_DELTA * (cfg.cycles + 1);
    let poll = poll_interval(cfg.delta);
    let mut next_send = ctx.start + jitter(cfg.delta, &mut rng);

    while !ctx.shared.stop.load(Ordering::Relaxed) {
        let now = Instant::now();
        let now_ticks = cfg
            .wall_to_ticks(now.saturating_duration_since(ctx.start))
            .min(horizon - 1);
        // apply scenario mutations whose tick boundary has passed: network
        // models mutate in place, drift flips the local label, leave waves
        // force this node offline until restored
        while let Some(m) = scn_drv.as_mut().and_then(|d| d.pop_due(now_ticks)) {
            match m {
                Mutation::SetDrop(p) => net.cfg.drop_prob = p,
                Mutation::SetDelay(model) => net.cfg.delay = model,
                Mutation::SetPartition(c) => net.set_partition(Some(c)),
                Mutation::Heal => net.set_partition(None),
                Mutation::Drift => drift_sign = -drift_sign,
                Mutation::ForceOffline(ids) => forced_off |= ids.contains(&me),
                Mutation::Restore(ids) => {
                    if ids.contains(&me) {
                        forced_off = false;
                    }
                }
                // membership growth is precomputed per node via join_tick
                Mutation::Grow(_) => {}
            }
        }
        let online = now_ticks >= join_tick
            && !forced_off
            && ctx.churn.map_or(true, |ch| ch.is_online(me, now_ticks));

        // ---- accept new inbound connections (kept until EOF)
        loop {
            match ctx.listener.accept() {
                Ok((s, _)) => {
                    if let Ok(c) = InConn::new(s) {
                        stats.conns_accepted += 1;
                        in_conns.push(c);
                    }
                }
                Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(_) => break,
            }
        }

        // ---- drain every complete frame from every connection
        let mut k = 0;
        while k < in_conns.len() {
            let (msgs, bad, closed) = in_conns[k].poll();
            stats.decode_errors += bad;
            for mut msg in msgs {
                if !online {
                    // churn: the node is paused — the message is lost, as
                    // in the simulator's offline delivery
                    stats.backlog_lost += 1;
                    continue;
                }
                if msg.w.len() != d {
                    // wrong dimensionality: structurally valid frame from a
                    // confused peer — rejected like any malformed input
                    stats.decode_errors += 1;
                    continue;
                }
                stats.received += 1;
                // NEWSCAST view merge rides along with learning gossip.
                // Descriptor node ids come off the wire, so bound-check them
                // before they can enter the view (and later index addrs).
                msg.view.retain(|desc| desc.node < cfg.n_nodes);
                sampler.on_receive(me, &msg.view);
                // the wire carries materialized weights (scale folded)
                let incoming = LinearModel::from_weights(msg.w, msg.t);
                // concept drift re-labels the local example with the
                // scenario's current sign
                let created = create_model_step(
                    cfg.variant,
                    &cfg.learner,
                    incoming,
                    &mut last_recv,
                    &x,
                    drift_sign * base_y,
                );
                publish(&ctx.shared.models[me], &created);
                cache.add(created);
            }
            if closed {
                in_conns.swap_remove(k);
            } else {
                k += 1;
            }
        }

        // ---- release sends whose injected delay has elapsed
        let mut i = 0;
        while i < delayed.len() {
            if delayed[i].due <= now {
                let s = delayed.swap_remove(i);
                if out.send(s.dst, ctx.addrs[s.dst], &s.bytes).is_err() {
                    stats.io_errors += 1;
                }
            } else {
                i += 1;
            }
        }

        // ---- Algorithm 1 active loop: periodic send of the freshest model
        if now >= next_send {
            next_send = now + jitter(cfg.delta, &mut rng);
            if online {
                // belt-and-braces: a sampler can only know ids < n_nodes
                // (views are bound-checked on receive), but never let a bad
                // id reach the addrs index
                if let Some(dst) = sampler
                    .select(me, now_ticks, &assume_online, &mut rng)
                    .filter(|&p| p < cfg.n_nodes)
                {
                    let freshest = cache.freshest();
                    let msg = ModelMsg {
                        src: me,
                        w: freshest.weights(),
                        scale: 1.0,
                        t: freshest.t,
                        view: sampler.payload(me, now_ticks),
                    };
                    stats.sent += 1;
                    stats.bytes_sent += msg.wire_bytes() as u64;
                    ctx.shared.messages_sent.fetch_add(1, Ordering::Relaxed);
                    match net.transmit_between(me, dst, &mut rng) {
                        Fate::Dropped => stats.sim_dropped += 1,
                        Fate::Blocked => stats.partition_blocked += 1,
                        Fate::Deliver(delay_ticks) => {
                            let bytes = wire::encode(&msg);
                            let due = now + cfg.ticks_to_wall(delay_ticks);
                            delayed.push(DelayedSend { due, dst, bytes });
                        }
                    }
                }
            }
        }

        std::thread::sleep(poll);
    }

    stats.model_t = cache.freshest().t;
    publish(&ctx.shared.models[me], cache.freshest());
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::p2p::newscast::Descriptor;

    fn msg(d: usize, t: u64) -> ModelMsg {
        ModelMsg {
            src: 1,
            w: (0..d).map(|i| i as f32).collect(),
            scale: 1.0,
            t,
            view: vec![Descriptor { node: 2, ts: t }],
        }
    }

    /// The tentpole behavior: one persistent connection carries many frames,
    /// and a single poll drains every complete frame.
    #[test]
    fn persistent_connection_drains_all_frames_per_poll() {
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap();
        let mut sender = TcpStream::connect(addr).unwrap();
        sender.set_nodelay(true).unwrap();
        for t in 0..5 {
            wire::write_frame(&mut sender, &msg(7, t)).unwrap();
        }
        let (stream, _) = listener.accept().unwrap();
        let mut conn = InConn::new(stream).unwrap();
        // nonblocking localhost read: poll until all five frames landed
        let deadline = Instant::now() + Duration::from_secs(5);
        let mut got: Vec<ModelMsg> = Vec::new();
        while got.len() < 5 && Instant::now() < deadline {
            let (msgs, bad, closed) = conn.poll();
            assert_eq!(bad, 0);
            assert!(!closed, "sender is still connected");
            got.extend(msgs);
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(got.len(), 5, "one wake must drain every buffered frame");
        for (t, m) in got.iter().enumerate() {
            assert_eq!(m.t, t as u64);
            assert_eq!(m.w.len(), 7);
            assert_eq!(m.view.len(), 1, "views travel over the wire");
        }
        // the connection stays open: more frames flow without reconnecting
        wire::write_frame(&mut sender, &msg(7, 99)).unwrap();
        let deadline = Instant::now() + Duration::from_secs(5);
        let mut late = Vec::new();
        while late.is_empty() && Instant::now() < deadline {
            late.extend(conn.poll().0);
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(late[0].t, 99);
    }

    #[test]
    fn in_conn_reports_eof_after_draining() {
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap();
        let mut sender = TcpStream::connect(addr).unwrap();
        wire::write_frame(&mut sender, &msg(3, 1)).unwrap();
        drop(sender);
        let (stream, _) = listener.accept().unwrap();
        let mut conn = InConn::new(stream).unwrap();
        let deadline = Instant::now() + Duration::from_secs(5);
        let mut all = Vec::new();
        let mut closed = false;
        while !closed && Instant::now() < deadline {
            let (msgs, _, c) = conn.poll();
            all.extend(msgs);
            closed = c;
            std::thread::sleep(Duration::from_millis(1));
        }
        assert!(closed);
        assert_eq!(all.len(), 1, "buffered frames are delivered before EOF");
    }

    #[test]
    fn out_conns_reuse_and_cap() {
        let listeners: Vec<TcpListener> =
            (0..3).map(|_| TcpListener::bind(("127.0.0.1", 0)).unwrap()).collect();
        let addrs: Vec<SocketAddr> =
            listeners.iter().map(|l| l.local_addr().unwrap()).collect();
        let mut out = OutConns::new(2);
        let frame = wire::encode(&msg(2, 1));
        // two sends to the same peer share one connection
        out.send(0, addrs[0], &frame).unwrap();
        out.send(0, addrs[0], &frame).unwrap();
        assert_eq!(out.len(), 1);
        let (first, _) = listeners[0].accept().unwrap();
        listeners[0].set_nonblocking(true).unwrap();
        assert!(
            listeners[0].accept().is_err(),
            "repeat sends must not open a second connection"
        );
        let mut conn = InConn::new(first).unwrap();
        let deadline = Instant::now() + Duration::from_secs(5);
        let mut n = 0;
        while n < 2 && Instant::now() < deadline {
            n += conn.poll().0.len();
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(n, 2, "both frames arrive on the one persistent connection");
        // the cap bounds simultaneous sockets, evicting least-recently-used
        out.send(1, addrs[1], &frame).unwrap();
        out.send(0, addrs[0], &frame).unwrap(); // reuse: 0 becomes MRU
        out.send(2, addrs[2], &frame).unwrap(); // evicts 1, not 0
        assert_eq!(out.len(), 2, "LRU cap must evict");
        // peer 0's connection survived: another send opens no new connection
        out.send(0, addrs[0], &frame).unwrap();
        assert!(
            listeners[0].accept().is_err(),
            "the hot connection must not be the one evicted"
        );
    }

    #[test]
    fn tick_wall_mapping_roundtrips() {
        let cfg = DeployConfig { delta: Duration::from_millis(40), ..Default::default() };
        assert_eq!(cfg.ticks_to_wall(SIM_DELTA), Duration::from_millis(40));
        let back = cfg.wall_to_ticks(Duration::from_millis(40));
        assert!((back as i64 - SIM_DELTA as i64).abs() <= 1, "{back}");
        assert_eq!(cfg.cycle_offset(3), Duration::from_millis(120));
    }

    #[test]
    fn eval_grid_sanitizes_custom_cycles() {
        let mut cfg = DeployConfig { cycles: 30, ..Default::default() };
        assert_eq!(cfg.eval_grid(), crate::eval::log_spaced_cycles(30));
        // unsorted, duplicated, out-of-range input resolves to a clean grid
        cfg.eval_at_cycles = vec![10, 0, 5, 10, 50, 1];
        assert_eq!(cfg.eval_grid(), vec![1, 5, 10]);
        // a grid with nothing inside the run falls back to log-spaced, so
        // the curve is never empty and parity axes stay shared
        cfg.eval_at_cycles = vec![40, 50];
        assert_eq!(cfg.eval_grid(), crate::eval::log_spaced_cycles(30));
    }
}
