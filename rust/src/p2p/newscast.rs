//! NEWSCAST (Jelasity et al.) — gossip-based peer sampling, Section III(c).
//!
//! Every node keeps a small partial view of (address, timestamp) descriptors.
//! Views travel piggybacked on gossip-learning messages (no extra traffic);
//! on receipt, the two views are merged and the freshest `c` distinct
//! descriptors are kept.  SELECTPEER draws uniformly from the local view,
//! which approximates a uniform random sample of the network.
//!
//! When a graph [`Topology`] constrains the run (DESIGN.md §16), the overlay
//! is confined to the graph: bootstrap views draw from each node's neighbor
//! list, and merges discard descriptors of non-neighbors (a node can learn
//! an address from gossip, but can only *link* to peers it has an edge to).

use crate::p2p::topology::Topology;
use crate::sim::event::{NodeId, Ticks};
use crate::util::rng::Rng;
use std::sync::Arc;

pub const DEFAULT_VIEW_SIZE: usize = 20;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Descriptor {
    pub node: NodeId,
    pub ts: Ticks,
}

/// The full network's newscast state (per-node views), owned by the
/// simulator.
///
/// A `Newscast` can also be a *range view* over nodes `[base, base + len)`
/// (sharded simulator, DESIGN.md §13): each shard runner owns the views of
/// its contiguous node range only, so S shards hold the same total view
/// memory as one full instance instead of S replicas.  All node-indexed
/// methods take global node ids and subtract `base`.
#[derive(Debug)]
pub struct Newscast {
    views: Vec<Vec<Descriptor>>,
    pub view_size: usize,
    base: NodeId,
    /// Graph constraint: views only ever hold topology neighbors.
    topo: Option<Arc<Topology>>,
}

/// One bootstrap view over a `members`-node universe: uniform rejection
/// draws, or — under a topology — draws from `me`'s neighbor list (all
/// neighbors when the list fits the view).  The uniform branch reproduces
/// the historical draw sequence exactly, so topology-free runs are
/// bit-for-bit unchanged.
fn fill_view(
    me: NodeId,
    members: usize,
    view_size: usize,
    topo: Option<&Topology>,
    rng: &mut Rng,
) -> Vec<Descriptor> {
    if let Some(t) = topo {
        let nbrs: Vec<NodeId> = t
            .neighbors(me)
            .iter()
            .map(|&w| w as usize)
            .filter(|&w| w < members)
            .collect();
        if nbrs.len() <= view_size {
            return nbrs.iter().map(|&node| Descriptor { node, ts: 0 }).collect();
        }
        let mut v = Vec::with_capacity(view_size);
        while v.len() < view_size {
            let peer = nbrs[rng.below_usize(nbrs.len())];
            if !v.iter().any(|d: &Descriptor| d.node == peer) {
                v.push(Descriptor { node: peer, ts: 0 });
            }
        }
        return v;
    }
    let mut v = Vec::with_capacity(view_size);
    while v.len() < view_size.min(members.saturating_sub(1)) {
        let peer = rng.below_usize(members);
        if peer != me && !v.iter().any(|d: &Descriptor| d.node == peer) {
            v.push(Descriptor { node: peer, ts: 0 });
        }
    }
    v
}

impl Newscast {
    /// Bootstrap: every node starts with `view_size` random descriptors
    /// (timestamp 0), as if a rendezvous service seeded the overlay.
    pub fn bootstrap(
        n: usize,
        view_size: usize,
        topo: Option<&Arc<Topology>>,
        rng: &mut Rng,
    ) -> Self {
        let views = (0..n)
            .map(|me| fill_view(me, n, view_size, topo.map(Arc::as_ref), rng))
            .collect();
        Newscast { views, view_size, base: 0, topo: topo.cloned() }
    }

    /// Bootstrap a *single* node's view in an otherwise empty state: used by
    /// the deployment runtime, where each node owns a sampler instance and
    /// only ever touches its own slot.  Keeps per-node cost O(view_size)
    /// instead of O(n · view_size) (which would be O(n²) across a
    /// deployment).
    pub fn bootstrap_node(
        me: NodeId,
        n: usize,
        view_size: usize,
        topo: Option<&Arc<Topology>>,
        rng: &mut Rng,
    ) -> Self {
        let mut views = vec![Vec::new(); n];
        views[me] = fill_view(me, n, view_size, topo.map(Arc::as_ref), rng);
        Newscast { views, view_size, base: 0, topo: topo.cloned() }
    }

    /// Range view for the sharded simulator: views for nodes
    /// `[lo, hi)` only, each bootstrapped from its own order-independent
    /// stream `derive_stream(seed, "newscast", node)` over the current
    /// membership `members`.  Nodes at or beyond `members` (scenario
    /// latecomers) start with empty views and are seeded by
    /// [`Newscast::grow_range`] when they join.  Because every node's view
    /// comes from its own stream, the result is identical however nodes are
    /// grouped into shards.
    pub fn bootstrap_range(
        lo: NodeId,
        hi: NodeId,
        members: usize,
        view_size: usize,
        seed: u64,
        topo: Option<&Arc<Topology>>,
    ) -> Self {
        let views = (lo..hi)
            .map(|me| {
                if me < members {
                    Self::boot_view(me, members, view_size, seed, topo.map(Arc::as_ref))
                } else {
                    Vec::new()
                }
            })
            .collect();
        Newscast { views, view_size, base: lo, topo: topo.cloned() }
    }

    /// One node's bootstrap view over a `members`-node universe, drawn from
    /// the node's own derived stream.
    fn boot_view(
        me: NodeId,
        members: usize,
        view_size: usize,
        seed: u64,
        topo: Option<&Topology>,
    ) -> Vec<Descriptor> {
        let mut rng = crate::util::rng::derive_stream(seed, "newscast", me as u64);
        fill_view(me, members, view_size, topo, &mut rng)
    }

    /// Range-view counterpart of [`Newscast::grow`]: activate nodes in
    /// `[old_members, new_members)` that fall inside this view's range,
    /// bootstrapping each over the *enlarged* universe from its own stream.
    pub fn grow_range(&mut self, old_members: usize, new_members: usize, seed: u64) {
        let lo = self.base.max(old_members);
        let hi = (self.base + self.views.len()).min(new_members);
        let topo = self.topo.clone();
        for me in lo..hi {
            self.views[me - self.base] = Self::boot_view(
                me,
                new_members,
                self.view_size,
                seed,
                topo.as_deref(),
            );
        }
    }

    /// Grow the overlay to `n_new` nodes (scenario flash crowds): each new
    /// node bootstraps a fresh view over the *enlarged* universe, as if the
    /// rendezvous service seeded it on arrival.  Existing views are left
    /// alone — descriptors of the newcomers spread organically once they
    /// start gossiping (their payloads lead with their own descriptor).
    pub fn grow(&mut self, n_new: usize, rng: &mut Rng) {
        let old = self.views.len();
        let topo = self.topo.clone();
        for me in old..n_new {
            let v = fill_view(me, n_new, self.view_size, topo.as_deref(), rng);
            self.views.push(v);
        }
    }

    /// SELECTPEER: uniform draw from the local view.
    pub fn select(&self, node: NodeId, rng: &mut Rng) -> Option<NodeId> {
        let v = &self.views[node - self.base];
        if v.is_empty() {
            None
        } else {
            Some(v[rng.below_usize(v.len())].node)
        }
    }

    /// Payload to piggyback on an outgoing message: own view + own fresh
    /// descriptor.
    pub fn payload(&self, node: NodeId, now: Ticks) -> Vec<Descriptor> {
        let v = &self.views[node - self.base];
        let mut p = Vec::with_capacity(v.len() + 1);
        p.push(Descriptor { node, ts: now });
        p.extend_from_slice(v);
        p
    }

    /// [`Newscast::payload`] into a recycled buffer (pooled message path,
    /// DESIGN.md §14): clears `out` and writes the identical descriptors.
    pub fn payload_into(&self, node: NodeId, now: Ticks, out: &mut Vec<Descriptor>) {
        let v = &self.views[node - self.base];
        out.clear();
        out.reserve(v.len() + 1);
        out.push(Descriptor { node, ts: now });
        out.extend_from_slice(v);
    }

    /// Merge an incoming payload into `node`'s view: union, dedup by node id
    /// keeping the freshest timestamp, drop self, keep the `view_size`
    /// freshest.  Under a topology, descriptors of non-neighbors are
    /// discarded — the overlay never links across a missing edge.
    pub fn merge(&mut self, node: NodeId, payload: &[Descriptor]) {
        let view = &mut self.views[node - self.base];
        for &d in payload {
            if d.node == node {
                continue;
            }
            if let Some(t) = &self.topo {
                if !t.has_edge(node, d.node) {
                    continue;
                }
            }
            match view.iter_mut().find(|e| e.node == d.node) {
                Some(e) => e.ts = e.ts.max(d.ts),
                None => view.push(d),
            }
        }
        // keep freshest view_size (stable by node id on timestamp ties)
        view.sort_by(|a, b| b.ts.cmp(&a.ts).then(a.node.cmp(&b.node)));
        view.truncate(self.view_size);
    }

    pub fn view(&self, node: NodeId) -> &[Descriptor] {
        &self.views[node - self.base]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::p2p::topology::TopologySpec;
    use crate::util::stats::chi2_uniform;

    #[test]
    fn bootstrap_views_valid() {
        let mut rng = Rng::new(1);
        let nc = Newscast::bootstrap(50, 20, None, &mut rng);
        for me in 0..50 {
            let v = nc.view(me);
            assert_eq!(v.len(), 20);
            assert!(v.iter().all(|d| d.node != me));
            let mut ids: Vec<_> = v.iter().map(|d| d.node).collect();
            ids.sort();
            ids.dedup();
            assert_eq!(ids.len(), 20, "duplicate descriptors");
        }
    }

    #[test]
    fn bootstrap_node_fills_only_own_slot() {
        let mut rng = Rng::new(6);
        let nc = Newscast::bootstrap_node(7, 50, 20, None, &mut rng);
        let v = nc.view(7);
        assert_eq!(v.len(), 20);
        assert!(v.iter().all(|d| d.node != 7 && d.node < 50));
        let mut ids: Vec<_> = v.iter().map(|d| d.node).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), 20, "duplicate descriptors");
        // every other slot stays empty (and unallocated beyond the Vec)
        for me in 0..50 {
            if me != 7 {
                assert!(nc.view(me).is_empty());
            }
        }
        // the node's own slot behaves like a normal newscast view
        assert!(nc.select(7, &mut rng).is_some());
    }

    /// Range views are sharding-invariant: splitting [0, n) into any set of
    /// ranges yields exactly the views of the full-range bootstrap, because
    /// each node draws from its own derived stream.
    #[test]
    fn bootstrap_range_is_grouping_independent() {
        let (n, seed) = (24, 99);
        let full = Newscast::bootstrap_range(0, n, n, 6, seed, None);
        for (lo, hi) in [(0usize, 7usize), (7, 16), (16, 24)] {
            let shard = Newscast::bootstrap_range(lo, hi, n, 6, seed, None);
            for me in lo..hi {
                assert_eq!(shard.view(me), full.view(me), "node {me}");
                assert!(shard.view(me).iter().all(|d| d.node != me && d.node < n));
            }
        }
        // grow: latecomers start empty, then bootstrap over the new universe
        let mut shard = Newscast::bootstrap_range(8, 16, 12, 6, seed, None);
        assert!(shard.view(13).is_empty());
        shard.grow_range(12, 20, seed);
        assert!(!shard.view(13).is_empty());
        let mut full2 = Newscast::bootstrap_range(0, 20, 12, 6, seed, None);
        full2.grow_range(12, 20, seed);
        for me in 8..16 {
            assert_eq!(shard.view(me), full2.view(me), "grown node {me}");
        }
        // payload/merge/select work through the base offset
        let mut rng = Rng::new(1);
        let p = shard.payload(9, 50);
        assert_eq!(p[0].node, 9);
        shard.merge(9, &[Descriptor { node: 17, ts: 80 }]);
        assert!(shard.view(9).iter().any(|d| d.node == 17));
        assert!(shard.select(9, &mut rng).is_some());
    }

    #[test]
    fn merge_keeps_freshest_and_bounds_size() {
        let mut rng = Rng::new(2);
        let mut nc = Newscast::bootstrap(10, 4, None, &mut rng);
        let payload = vec![
            Descriptor { node: 1, ts: 100 },
            Descriptor { node: 2, ts: 99 },
            Descriptor { node: 3, ts: 98 },
            Descriptor { node: 4, ts: 97 },
            Descriptor { node: 0, ts: 1000 }, // self — must be dropped
        ];
        nc.merge(0, &payload);
        let v = nc.view(0);
        assert_eq!(v.len(), 4);
        assert!(v.iter().all(|d| d.node != 0));
        assert_eq!(v[0].node, 1);
        assert_eq!(v[0].ts, 100);
    }

    #[test]
    fn merge_dedups_updating_timestamp() {
        let mut rng = Rng::new(3);
        let mut nc = Newscast::bootstrap(5, 3, None, &mut rng);
        nc.merge(0, &[Descriptor { node: 1, ts: 5 }]);
        nc.merge(0, &[Descriptor { node: 1, ts: 9 }]);
        let hits: Vec<_> = nc.view(0).iter().filter(|d| d.node == 1).collect();
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].ts, 9);
    }

    /// Under a topology, bootstrap views hold only graph neighbors and
    /// merges refuse descriptors across missing edges.
    #[test]
    fn topology_confines_views_to_neighbors() {
        let spec = TopologySpec::parse("ring:2").unwrap().unwrap();
        let topo = Arc::new(crate::p2p::topology::Topology::build(&spec, 20, 7).unwrap());
        let mut rng = Rng::new(4);
        let mut nc = Newscast::bootstrap(20, 6, Some(&topo), &mut rng);
        for me in 0..20 {
            let v = nc.view(me);
            // ring:2 degree is 4 < view_size, so the view is the full list
            assert_eq!(v.len(), 4, "node {me}");
            assert!(v.iter().all(|d| topo.has_edge(me, d.node)));
        }
        // a non-neighbor descriptor is discarded, a neighbor's accepted
        nc.merge(0, &[Descriptor { node: 10, ts: 50 }, Descriptor { node: 2, ts: 60 }]);
        assert!(nc.view(0).iter().all(|d| d.node != 10));
        assert!(nc.view(0).iter().any(|d| d.node == 2 && d.ts == 60));
        // range bootstrap is grouping-independent under the constraint too
        let full = Newscast::bootstrap_range(0, 20, 20, 3, 99, Some(&topo));
        let shard = Newscast::bootstrap_range(5, 12, 20, 3, 99, Some(&topo));
        for me in 5..12 {
            assert_eq!(shard.view(me), full.view(me), "node {me}");
            assert!(full.view(me).iter().all(|d| topo.has_edge(me, d.node)));
        }
    }

    #[test]
    fn selection_approximately_uniform_over_time() {
        // NEWSCAST's uniformity guarantee is for the *time-averaged*
        // sampling distribution (any snapshot is biased toward recent
        // senders).  Count the targets each node selects while gossiping —
        // exactly how the protocol consumes the service — and check the
        // time-averaged histogram against uniform.
        let n = 60;
        let mut rng = Rng::new(4);
        let mut nc = Newscast::bootstrap(n, 15, None, &mut rng);
        let mut counts = vec![0u64; n];
        let mut order: Vec<usize> = (0..n).collect();
        for round in 0..700u64 {
            rng.shuffle(&mut order); // nodes fire in random order per round
            for (k, &node) in order.iter().enumerate() {
                if let Some(dst) = nc.select(node, &mut rng) {
                    if round >= 100 {
                        counts[dst] += 1;
                    }
                    let now = round * n as u64 + k as u64;
                    let p = nc.payload(node, now);
                    nc.merge(dst, &p);
                }
            }
        }
        // df = 59; p=0.001 critical value ~98. Allow slack for the residual
        // freshness bias — what we must rule out is gross concentration.
        let chi2 = chi2_uniform(&counts);
        assert!(chi2 < 59.0 * 4.0, "chi2 = {chi2}");
        assert!(counts.iter().all(|&c| c > 0), "some node never selected");
    }
}
