//! Peer-sampling layer: NEWSCAST plus oracle and perfect-matching
//! baselines, and the graph-topology constraint (DESIGN.md §16).
pub mod newscast;
pub mod overlay;
pub mod topology;

pub use newscast::{Descriptor, Newscast};
pub use overlay::{PeerSampler, SamplerConfig};
pub use topology::{Topology, TopologyKind, TopologyMetrics, TopologySpec};
