//! Peer-sampling layer: NEWSCAST plus oracle and perfect-matching baselines.
pub mod newscast;
pub mod overlay;

pub use newscast::{Descriptor, Newscast};
pub use overlay::{PeerSampler, SamplerConfig};
