//! Graph topologies constraining SELECTPEER (DESIGN.md §16).
//!
//! The paper's protocol assumes a (near-)uniform overlay, but real P2P
//! deployments gossip over structured graphs whose diameter and expansion
//! govern mixing time.  This module provides:
//!
//! * [`TopologySpec`] — the parsed, serializable form of a `topology =` key
//!   (`ring:K`, `grid`, `kreg:K`, `ba:M`, `graph:<file>`,
//!   `graph-inline:a-b,c-d,…`), round-tripping losslessly through
//!   [`TopologySpec::name`].  `"complete"` / `"none"` parse to `None`: the
//!   implicit all-pairs overlay every run used before this subsystem.
//! * [`Topology`] — the resolved graph: a symmetric adjacency in CSR form
//!   (sorted, deduped neighbor lists; no self loops) plus a canonical
//!   undirected edge list for scenario-level edge mutations, built
//!   seed-deterministically (generators derive their own RNG streams via
//!   [`derive_seed`], never consuming from a shared RNG — the
//!   `resolve_churn_schedule → sampler → eval` fork order in
//!   `build_shared` is load-bearing and must not shift).
//! * [`TopologyMetrics`] — cheap structural metrics (degree min/mean/max,
//!   BFS double-sweep diameter estimate, component count) surfaced in run
//!   banners and `RunStats`.
//!
//! Validation is typed at the boundary: a graph with a degree-0 node can
//! never gossip and is always rejected; a disconnected graph is rejected
//! unless the spec opts in with the `allow-disconnected:` prefix (each
//! component then converges to its own model, which is sometimes exactly
//! the experiment).

use crate::util::rng::{derive_seed, Rng};
use std::fmt;

/// How a [`TopologySpec`] generates its edge set.
#[derive(Clone, Debug, PartialEq)]
pub enum TopologyKind {
    /// `ring:K` — circulant graph: node `i` links to its `K` nearest
    /// neighbors on each side (degree `2K` once `n > 2K`).
    Ring { k: usize },
    /// `grid` — 2D torus on `rows × cols = n` with `rows` the largest
    /// divisor of `n` at most `√n` (degenerates to a cycle for prime `n`).
    Grid,
    /// `kreg:K` — uniform random `K`-regular graph (stub matching with
    /// edge-swap repair), seed-deterministic.
    KRegular { k: usize },
    /// `ba:M` — Barabási–Albert preferential attachment: an `M+1`-clique
    /// seed, then each new node attaches `M` edges biased by degree.
    BarabasiAlbert { m: usize },
    /// `graph:<file>` — whitespace/`-` separated 0-based edge pairs, one
    /// per line, `#` comments; read at build time.
    GraphFile { path: String },
    /// `graph-inline:a-b,c-d,…` — the same edge list embedded in the spec
    /// string (kept sorted/deduped so serialization is canonical).
    GraphInline { edges: Vec<(usize, usize)> },
}

/// A parsed `topology =` value.  `parse` ↔ `name` round-trip exactly.
#[derive(Clone, Debug, PartialEq)]
pub struct TopologySpec {
    pub kind: TopologyKind,
    /// Accept a multi-component graph (`allow-disconnected:` prefix).
    /// Degree-0 nodes are rejected regardless — they can never gossip.
    pub allow_disconnected: bool,
}

const ALLOW_PREFIX: &str = "allow-disconnected:";

impl TopologySpec {
    /// Parse a spec string.  `"complete"` and `"none"` — the implicit
    /// uniform overlay — parse to `None`; everything else is a constrained
    /// graph or a typed error message.
    pub fn parse(s: &str) -> Result<Option<TopologySpec>, String> {
        let s = s.trim();
        if s.eq_ignore_ascii_case("complete") || s.eq_ignore_ascii_case("none") {
            return Ok(None);
        }
        let (allow_disconnected, body) = match s.strip_prefix(ALLOW_PREFIX) {
            Some(rest) => (true, rest),
            None => (false, s),
        };
        let kind = if let Some(k) = body.strip_prefix("ring:") {
            let k: usize = k
                .parse()
                .map_err(|_| format!("ring:K needs an integer K, got {k:?}"))?;
            if k == 0 {
                return Err("ring:K needs K >= 1".into());
            }
            TopologyKind::Ring { k }
        } else if body == "grid" {
            TopologyKind::Grid
        } else if let Some(k) = body.strip_prefix("kreg:") {
            let k: usize = k
                .parse()
                .map_err(|_| format!("kreg:K needs an integer K, got {k:?}"))?;
            if k == 0 {
                return Err("kreg:K needs K >= 1".into());
            }
            TopologyKind::KRegular { k }
        } else if let Some(m) = body.strip_prefix("ba:") {
            let m: usize = m
                .parse()
                .map_err(|_| format!("ba:M needs an integer M, got {m:?}"))?;
            if m == 0 {
                return Err("ba:M needs M >= 1".into());
            }
            TopologyKind::BarabasiAlbert { m }
        } else if let Some(path) = body.strip_prefix("graph:") {
            if path.is_empty() {
                return Err("graph:<file> needs a path".into());
            }
            TopologyKind::GraphFile { path: path.to_string() }
        } else if let Some(list) = body.strip_prefix("graph-inline:") {
            TopologyKind::GraphInline { edges: parse_edge_list(list)? }
        } else {
            return Err(format!(
                "unknown topology {s:?} (expected complete, ring:K, grid, kreg:K, \
                 ba:M, graph:<file>, or graph-inline:a-b,c-d)"
            ));
        };
        Ok(Some(TopologySpec { kind, allow_disconnected }))
    }

    /// The canonical spec string; `parse(name())` reproduces `self`.
    pub fn name(&self) -> String {
        let body = match &self.kind {
            TopologyKind::Ring { k } => format!("ring:{k}"),
            TopologyKind::Grid => "grid".into(),
            TopologyKind::KRegular { k } => format!("kreg:{k}"),
            TopologyKind::BarabasiAlbert { m } => format!("ba:{m}"),
            TopologyKind::GraphFile { path } => format!("graph:{path}"),
            TopologyKind::GraphInline { edges } => {
                let pairs: Vec<String> =
                    edges.iter().map(|&(a, b)| format!("{a}-{b}")).collect();
                format!("graph-inline:{}", pairs.join(","))
            }
        };
        if self.allow_disconnected {
            format!("{ALLOW_PREFIX}{body}")
        } else {
            body
        }
    }
}

impl fmt::Display for TopologySpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.name())
    }
}

/// Parse `a-b,c-d,…` into canonical (min, max) pairs, sorted and deduped
/// (the same canonicalization `Csr::push_row` applies to column indices).
fn parse_edge_list(list: &str) -> Result<Vec<(usize, usize)>, String> {
    let mut edges = Vec::new();
    for pair in list.split(',') {
        let pair = pair.trim();
        if pair.is_empty() {
            continue;
        }
        let (a, b) = pair
            .split_once('-')
            .ok_or_else(|| format!("edge {pair:?} is not of the form a-b"))?;
        let a: usize = a
            .trim()
            .parse()
            .map_err(|_| format!("edge {pair:?}: bad node id {a:?}"))?;
        let b: usize = b
            .trim()
            .parse()
            .map_err(|_| format!("edge {pair:?}: bad node id {b:?}"))?;
        if a == b {
            return Err(format!("edge {pair:?} is a self loop"));
        }
        edges.push((a.min(b), a.max(b)));
    }
    if edges.is_empty() {
        return Err("edge list is empty".into());
    }
    edges.sort_unstable();
    edges.dedup();
    Ok(edges)
}

/// Cheap structural metrics, computed once at build time.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TopologyMetrics {
    pub nodes: usize,
    /// undirected edge count
    pub edges: usize,
    pub degree_min: usize,
    pub degree_max: usize,
    pub degree_mean: f64,
    /// connected components
    pub components: usize,
    /// BFS double-sweep eccentricity on the largest component — a lower
    /// bound on the true diameter, exact on trees and usually tight.
    pub diameter_est: usize,
}

impl TopologyMetrics {
    /// One-line banner form.
    pub fn summary(&self) -> String {
        format!(
            "{} nodes, {} edges, degree {}/{:.1}/{}, diameter>={}, {} component{}",
            self.nodes,
            self.edges,
            self.degree_min,
            self.degree_mean,
            self.degree_max,
            self.diameter_est,
            self.components,
            if self.components == 1 { "" } else { "s" }
        )
    }
}

/// A resolved symmetric graph over the node universe: CSR adjacency plus a
/// canonical undirected edge list.  Immutable after build; shared across
/// shards / node groups behind an `Arc`.
#[derive(Debug)]
pub struct Topology {
    spec: TopologySpec,
    n: usize,
    /// CSR row pointers, length `n + 1`.
    indptr: Vec<u32>,
    /// Sorted, deduped neighbor lists (both directions of every edge).
    indices: Vec<u32>,
    /// Canonical `(min, max)` undirected edges, sorted — the index space
    /// scenario edge mutations sample from.
    edges: Vec<(u32, u32)>,
    metrics: TopologyMetrics,
}

impl Topology {
    /// Build the graph `spec` describes over `n` nodes.  Deterministic in
    /// `(spec, n, seed)`; randomized generators derive private streams
    /// (`derive_seed(seed, "topo/…")`) and never touch a shared RNG.
    pub fn build(spec: &TopologySpec, n: usize, seed: u64) -> Result<Topology, String> {
        if n < 2 {
            return Err(format!("a topology needs at least 2 nodes, got {n}"));
        }
        let raw = match &spec.kind {
            TopologyKind::Ring { k } => gen_ring(n, *k),
            TopologyKind::Grid => gen_grid(n),
            TopologyKind::KRegular { k } => gen_kregular(n, *k, seed)?,
            TopologyKind::BarabasiAlbert { m } => gen_ba(n, *m, seed)?,
            TopologyKind::GraphFile { path } => {
                let text = std::fs::read_to_string(path)
                    .map_err(|e| format!("topology graph:{path}: {e}"))?;
                edges_from_text(&text)?
            }
            TopologyKind::GraphInline { edges } => edges.clone(),
        };
        Self::from_edges(spec.clone(), n, raw)
    }

    /// Assemble CSR + metrics from a raw (possibly unsorted, duplicated)
    /// undirected edge list, then run the typed validation pass.
    fn from_edges(
        spec: TopologySpec,
        n: usize,
        mut raw: Vec<(usize, usize)>,
    ) -> Result<Topology, String> {
        for &(a, b) in &raw {
            if a >= n || b >= n {
                return Err(format!(
                    "topology {}: edge {}-{} names a node >= n = {n}",
                    spec.name(),
                    a.min(b),
                    a.max(b)
                ));
            }
            debug_assert!(a != b, "generators never emit self loops");
        }
        raw.iter_mut().for_each(|e| {
            if e.0 > e.1 {
                *e = (e.1, e.0);
            }
        });
        raw.sort_unstable();
        raw.dedup();
        let edges: Vec<(u32, u32)> =
            raw.iter().map(|&(a, b)| (a as u32, b as u32)).collect();

        // CSR: count degrees, prefix-sum, scatter, then sort each row.
        let mut deg = vec![0u32; n];
        for &(a, b) in &edges {
            deg[a as usize] += 1;
            deg[b as usize] += 1;
        }
        let mut indptr = vec![0u32; n + 1];
        for i in 0..n {
            indptr[i + 1] = indptr[i] + deg[i];
        }
        let mut indices = vec![0u32; indptr[n] as usize];
        let mut cursor: Vec<u32> = indptr[..n].to_vec();
        for &(a, b) in &edges {
            indices[cursor[a as usize] as usize] = b;
            cursor[a as usize] += 1;
            indices[cursor[b as usize] as usize] = a;
            cursor[b as usize] += 1;
        }
        for i in 0..n {
            indices[indptr[i] as usize..indptr[i + 1] as usize].sort_unstable();
        }

        // Typed validation: degree-0 nodes can never gossip.
        if let Some(isolated) = (0..n).find(|&i| deg[i] == 0) {
            return Err(format!(
                "topology {}: node {isolated} has degree 0 and can never gossip",
                spec.name()
            ));
        }
        let metrics = compute_metrics(n, &indptr, &indices, edges.len(), &deg);
        if metrics.components > 1 && !spec.allow_disconnected {
            return Err(format!(
                "topology {}: graph has {} components; prefix the spec with \
                 {ALLOW_PREFIX:?} to run on a disconnected graph",
                spec.name(),
                metrics.components
            ));
        }
        Ok(Topology { spec, n, indptr, indices, edges, metrics })
    }

    pub fn spec(&self) -> &TopologySpec {
        &self.spec
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn degree(&self, v: usize) -> usize {
        (self.indptr[v + 1] - self.indptr[v]) as usize
    }

    /// Sorted neighbor list of `v`.
    pub fn neighbors(&self, v: usize) -> &[u32] {
        &self.indices[self.indptr[v] as usize..self.indptr[v + 1] as usize]
    }

    /// O(log degree) membership test.
    pub fn has_edge(&self, a: usize, b: usize) -> bool {
        a < self.n && b < self.n && self.neighbors(a).binary_search(&(b as u32)).is_ok()
    }

    /// Canonical `(min, max)` undirected edge list, sorted — stable index
    /// space for seed-deterministic scenario sampling.
    pub fn edges(&self) -> &[(u32, u32)] {
        &self.edges
    }

    /// All edges crossing between components of a partition map
    /// (`components[v]` = component id) — the `bridge_cut` edge set.
    pub fn crossing_edges(&self, components: &[u32]) -> Vec<(u32, u32)> {
        let comp = |v: u32| components.get(v as usize).copied().unwrap_or(0);
        self.edges
            .iter()
            .copied()
            .filter(|&(a, b)| comp(a) != comp(b))
            .collect()
    }

    pub fn metrics(&self) -> &TopologyMetrics {
        &self.metrics
    }

    /// Banner line: `ring:2: 500 nodes, 1000 edges, …`.
    pub fn summary(&self) -> String {
        format!("{}: {}", self.spec.name(), self.metrics.summary())
    }
}

/// BFS from `start`, writing distances into `dist` (u32::MAX = unreached).
/// Returns the farthest reached node and its distance.
fn bfs(
    indptr: &[u32],
    indices: &[u32],
    start: usize,
    dist: &mut [u32],
    queue: &mut Vec<u32>,
) -> (usize, u32) {
    dist.fill(u32::MAX);
    queue.clear();
    dist[start] = 0;
    queue.push(start as u32);
    let mut head = 0;
    let (mut far, mut far_d) = (start, 0u32);
    while head < queue.len() {
        let v = queue[head] as usize;
        head += 1;
        let d = dist[v];
        if d > far_d {
            (far, far_d) = (v, d);
        }
        for &w in &indices[indptr[v] as usize..indptr[v + 1] as usize] {
            if dist[w as usize] == u32::MAX {
                dist[w as usize] = d + 1;
                queue.push(w);
            }
        }
    }
    (far, far_d)
}

fn compute_metrics(
    n: usize,
    indptr: &[u32],
    indices: &[u32],
    edges: usize,
    deg: &[u32],
) -> TopologyMetrics {
    let degree_min = deg.iter().copied().min().unwrap_or(0) as usize;
    let degree_max = deg.iter().copied().max().unwrap_or(0) as usize;
    let degree_mean = if n == 0 { 0.0 } else { 2.0 * edges as f64 / n as f64 };

    // Components + largest-component representative in one sweep.
    let mut dist = vec![u32::MAX; n];
    let mut queue = Vec::with_capacity(n);
    let mut seen = vec![false; n];
    let mut components = 0usize;
    let (mut big_rep, mut big_size) = (0usize, 0usize);
    for v in 0..n {
        if seen[v] {
            continue;
        }
        components += 1;
        bfs(indptr, indices, v, &mut dist, &mut queue);
        let size = queue.len();
        for &w in &queue {
            seen[w as usize] = true;
        }
        if size > big_size {
            (big_rep, big_size) = (v, size);
        }
    }
    // Double-sweep diameter estimate on the largest component.
    let (far, _) = bfs(indptr, indices, big_rep, &mut dist, &mut queue);
    let (_, ecc) = bfs(indptr, indices, far, &mut dist, &mut queue);
    TopologyMetrics {
        nodes: n,
        edges,
        degree_min,
        degree_max,
        degree_mean,
        components,
        diameter_est: ecc as usize,
    }
}

/// Circulant ring: `i ↔ i±1..k (mod n)`.
fn gen_ring(n: usize, k: usize) -> Vec<(usize, usize)> {
    let mut edges = Vec::with_capacity(n * k);
    for i in 0..n {
        for d in 1..=k.min(n - 1) {
            edges.push((i, (i + d) % n));
        }
    }
    edges
}

/// 2D torus on `rows × cols = n`; `rows` = largest divisor of `n` ≤ `√n`.
fn gen_grid(n: usize) -> Vec<(usize, usize)> {
    let mut rows = 1;
    let mut d = 1;
    while d * d <= n {
        if n % d == 0 {
            rows = d;
        }
        d += 1;
    }
    let cols = n / rows;
    let idx = |r: usize, c: usize| r * cols + c;
    let mut edges = Vec::with_capacity(2 * n);
    for r in 0..rows {
        for c in 0..cols {
            let right = idx(r, (c + 1) % cols);
            if right != idx(r, c) {
                edges.push((idx(r, c), right));
            }
            let down = idx((r + 1) % rows, c);
            if down != idx(r, c) {
                edges.push((idx(r, c), down));
            }
        }
    }
    edges
}

/// Uniform random k-regular graph: stub matching with edge-swap repair,
/// full restarts on a stuck repair, typed error when infeasible.
fn gen_kregular(n: usize, k: usize, seed: u64) -> Result<Vec<(usize, usize)>, String> {
    if k >= n {
        return Err(format!("kreg:{k} needs n > k, got n = {n}"));
    }
    if n * k % 2 != 0 {
        return Err(format!("kreg:{k} over {n} nodes: n*k must be even"));
    }
    let mut rng = Rng::new(derive_seed(seed, "topo/kreg"));
    let canon = |a: u32, b: u32| (a.min(b), a.max(b));
    'attempt: for _ in 0..100 {
        let mut stubs: Vec<u32> = (0..n as u32).flat_map(|v| std::iter::repeat(v).take(k)).collect();
        rng.shuffle(&mut stubs);
        let mut set = std::collections::HashSet::with_capacity(n * k / 2);
        let mut accepted: Vec<(u32, u32)> = Vec::with_capacity(n * k / 2);
        let mut bad: Vec<(u32, u32)> = Vec::new();
        for pair in stubs.chunks_exact(2) {
            let (a, b) = (pair[0], pair[1]);
            if a != b && set.insert(canon(a, b)) {
                accepted.push(canon(a, b));
            } else {
                bad.push((a, b));
            }
        }
        // Repair: swap one endpoint of a bad pair with a random accepted
        // edge; both resulting edges must be fresh non-loops.
        let mut budget = 200 * (bad.len() + 1);
        while let Some((a, b)) = bad.pop() {
            let mut fixed = false;
            while budget > 0 {
                budget -= 1;
                let j = rng.below_usize(accepted.len());
                let (c, d) = accepted[j];
                let (e1, e2) = (canon(a, d), canon(c, b));
                if a != d && c != b && e1 != e2 && !set.contains(&e1) && !set.contains(&e2) {
                    set.remove(&canon(c, d));
                    accepted[j] = e1;
                    set.insert(e1);
                    set.insert(e2);
                    accepted.push(e2);
                    fixed = true;
                    break;
                }
            }
            if !fixed {
                continue 'attempt; // stuck: reshuffle and start over
            }
        }
        return Ok(accepted.into_iter().map(|(a, b)| (a as usize, b as usize)).collect());
    }
    Err(format!("kreg:{k} over {n} nodes: could not realize a simple k-regular graph"))
}

/// Barabási–Albert preferential attachment: an (m+1)-clique seed, then
/// each new node draws `m` distinct targets from the repeated-endpoint
/// list (degree-proportional), with a uniform fallback against stalls.
fn gen_ba(n: usize, m: usize, seed: u64) -> Result<Vec<(usize, usize)>, String> {
    if n < m + 2 {
        return Err(format!("ba:{m} needs n >= m + 2, got n = {n}"));
    }
    let mut rng = Rng::new(derive_seed(seed, "topo/ba"));
    let m0 = m + 1;
    let mut edges: Vec<(usize, usize)> = Vec::with_capacity(m0 * m / 2 + (n - m0) * m);
    // Endpoint multiset: each node appears once per incident edge, so a
    // uniform draw over it is degree-proportional.
    let mut endpoints: Vec<u32> = Vec::with_capacity(2 * (n - m0) * m);
    for a in 0..m0 {
        for b in (a + 1)..m0 {
            edges.push((a, b));
            endpoints.push(a as u32);
            endpoints.push(b as u32);
        }
    }
    let mut picked: Vec<usize> = Vec::with_capacity(m);
    for v in m0..n {
        picked.clear();
        let mut attempts = 0;
        while picked.len() < m {
            attempts += 1;
            let t = if attempts <= 64 * m {
                endpoints[rng.below_usize(endpoints.len())] as usize
            } else {
                rng.below_usize(v) // uniform fallback; cannot stall
            };
            if t != v && !picked.contains(&t) {
                picked.push(t);
            }
        }
        for &t in &picked {
            edges.push((t, v));
            endpoints.push(t as u32);
            endpoints.push(v as u32);
        }
    }
    Ok(edges)
}

/// Parse a `graph:<file>` body: one edge per line, `a b` or `a-b` or
/// `a,b`; blank lines and `#` comments ignored.
fn edges_from_text(text: &str) -> Result<Vec<(usize, usize)>, String> {
    let mut edges = Vec::new();
    for (ln, line) in text.lines().enumerate() {
        let line = line.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut it = line.split(|c: char| c == '-' || c == ',' || c.is_whitespace()).filter(|t| !t.is_empty());
        let (a, b) = match (it.next(), it.next(), it.next()) {
            (Some(a), Some(b), None) => (a, b),
            _ => return Err(format!("line {}: expected one edge `a b`", ln + 1)),
        };
        let a: usize = a.parse().map_err(|_| format!("line {}: bad node id {a:?}", ln + 1))?;
        let b: usize = b.parse().map_err(|_| format!("line {}: bad node id {b:?}", ln + 1))?;
        if a == b {
            return Err(format!("line {}: edge {a}-{b} is a self loop", ln + 1));
        }
        edges.push((a, b));
    }
    if edges.is_empty() {
        return Err("edge list file has no edges".into());
    }
    Ok(edges)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn build(spec: &str, n: usize) -> Topology {
        let s = TopologySpec::parse(spec).unwrap().unwrap();
        Topology::build(&s, n, 42).unwrap()
    }

    #[test]
    fn spec_parse_name_roundtrip() {
        for s in [
            "ring:2",
            "grid",
            "kreg:4",
            "ba:3",
            "graph:edges.txt",
            "graph-inline:0-1,1-2,0-2",
            "allow-disconnected:graph-inline:0-1,2-3",
        ] {
            let spec = TopologySpec::parse(s).unwrap().unwrap();
            assert_eq!(spec.name(), s, "round trip of {s:?}");
            assert_eq!(TopologySpec::parse(&spec.name()).unwrap().unwrap(), spec);
        }
        assert_eq!(TopologySpec::parse("complete").unwrap(), None);
        assert_eq!(TopologySpec::parse("none").unwrap(), None);
        assert!(TopologySpec::parse("ring:0").is_err());
        assert!(TopologySpec::parse("hypercube").is_err());
        assert!(TopologySpec::parse("graph-inline:3-3").is_err());
    }

    #[test]
    fn inline_edge_list_canonicalizes() {
        // unsorted, duplicated, reversed pairs all collapse to one form
        let spec = TopologySpec::parse("graph-inline:2-1,0-1,1-2,1-0").unwrap().unwrap();
        assert_eq!(spec.name(), "graph-inline:0-1,1-2");
    }

    #[test]
    fn ring_structure() {
        let t = build("ring:2", 10);
        for v in 0..10 {
            assert_eq!(t.degree(v), 4);
        }
        assert!(t.has_edge(0, 1) && t.has_edge(0, 2) && t.has_edge(0, 8));
        assert!(!t.has_edge(0, 5));
        assert_eq!(t.metrics().components, 1);
        assert_eq!(t.metrics().edges, 20);
    }

    #[test]
    fn grid_is_a_torus() {
        let t = build("grid", 12); // 3 x 4
        for v in 0..12 {
            assert_eq!(t.degree(v), 4);
        }
        assert_eq!(t.metrics().components, 1);
    }

    #[test]
    fn kregular_has_exact_degree() {
        let t = build("kreg:4", 50);
        for v in 0..50 {
            assert_eq!(t.degree(v), 4, "node {v}");
        }
        // seed-deterministic: rebuilding gives the identical edge set
        let s = TopologySpec::parse("kreg:4").unwrap().unwrap();
        let t2 = Topology::build(&s, 50, 42).unwrap();
        assert_eq!(t.edges(), t2.edges());
    }

    #[test]
    fn ba_is_connected_and_deterministic() {
        let t = build("ba:2", 100);
        assert_eq!(t.metrics().components, 1);
        assert!(t.metrics().degree_min >= 2);
        let s = TopologySpec::parse("ba:2").unwrap().unwrap();
        let t2 = Topology::build(&s, 100, 42).unwrap();
        assert_eq!(t.edges(), t2.edges());
        let t3 = Topology::build(&s, 100, 43).unwrap();
        assert_ne!(t.edges(), t3.edges(), "different seeds give different graphs");
    }

    #[test]
    fn degree_zero_and_disconnected_are_typed_errors() {
        // node 3 exists (n = 4) but has no edges
        let s = TopologySpec::parse("graph-inline:0-1,1-2").unwrap().unwrap();
        let err = Topology::build(&s, 4, 1).unwrap_err();
        assert!(err.contains("degree 0"), "{err}");
        // two components without the opt-in prefix
        let s = TopologySpec::parse("graph-inline:0-1,2-3").unwrap().unwrap();
        let err = Topology::build(&s, 4, 1).unwrap_err();
        assert!(err.contains("components"), "{err}");
        // …and with it
        let s = TopologySpec::parse("allow-disconnected:graph-inline:0-1,2-3")
            .unwrap()
            .unwrap();
        let t = Topology::build(&s, 4, 1).unwrap();
        assert_eq!(t.metrics().components, 2);
    }

    #[test]
    fn crossing_edges_match_partition() {
        let t = build("ring:1", 6);
        // halves split 0..3 vs 3..6: ring edges 2-3 and 0-5 cross
        let comps: Vec<u32> = (0..6).map(|v| if v < 3 { 0 } else { 1 }).collect();
        let mut crossing = t.crossing_edges(&comps);
        crossing.sort_unstable();
        assert_eq!(crossing, vec![(0, 5), (2, 3)]);
    }

    #[test]
    fn diameter_estimate_on_a_path_is_exact() {
        let s = TopologySpec::parse("graph-inline:0-1,1-2,2-3,3-4").unwrap().unwrap();
        let t = Topology::build(&s, 5, 1).unwrap();
        assert_eq!(t.metrics().diameter_est, 4);
        assert_eq!(t.metrics().degree_min, 1);
        assert_eq!(t.metrics().degree_max, 2);
    }
}
