//! Peer-sampling service abstraction: the SELECTPEER placeholder of
//! Algorithm 1.  Four implementations (Section VI-A + DESIGN.md §16):
//!
//! * `Newscast` — the paper's choice: gossip-based peer sampling with
//!   piggybacked views (p2p/newscast.rs).  Under a graph topology the
//!   overlay is confined to topology neighbors.
//! * `Oracle` — idealized uniform sampling over *online* nodes (baseline for
//!   testing the NEWSCAST uniformity assumption).
//! * `Topo` — graph-constrained oracle: uniform rejection sampling over the
//!   node's topology neighbor list (DESIGN.md §16).  Selected when a
//!   [`Topology`] is configured and the sampler is `oracle`.
//! * `Matching` — PERFECT MATCHING: a fresh random perfect matching of the
//!   online nodes each cycle, so every peer receives exactly one message
//!   (Section VI-A(e); not practical, used as a diversity-maximizing
//!   baseline).  Incompatible with a topology (the matching is global).

use crate::p2p::newscast::{Descriptor, Newscast};
use crate::p2p::topology::Topology;
use crate::sim::event::{NodeId, Ticks};
use crate::util::bitset::Bitset;
use crate::util::rng::Rng;
use std::sync::Arc;

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SamplerConfig {
    Oracle,
    Newscast { view_size: usize },
    Matching,
}

impl SamplerConfig {
    pub fn name(&self) -> &'static str {
        match self {
            SamplerConfig::Oracle => "oracle",
            SamplerConfig::Newscast { .. } => "newscast",
            SamplerConfig::Matching => "matching",
        }
    }
}

#[derive(Debug)]
pub enum PeerSampler {
    Oracle { n: usize },
    Newscast(Newscast),
    Topo(TopoState),
    Matching(MatchingState),
}

/// Graph-constrained sampler state: the shared immutable graph plus the
/// current membership bound (scenario latecomers sit beyond `members` until
/// a grow mutation activates them; selection rejects them like offline
/// peers).
#[derive(Debug)]
pub struct TopoState {
    topo: Arc<Topology>,
    members: usize,
}

#[derive(Debug)]
pub struct MatchingState {
    n: usize,
    delta: Ticks,
    cycle: u64,
    partner: Vec<Option<NodeId>>,
}

impl PeerSampler {
    /// `topo` constrains sampling to a graph: `oracle` becomes the
    /// graph-constrained `Topo` sampler, NEWSCAST views are confined to
    /// topology neighbors, and `matching` is rejected upstream by spec
    /// validation (a global matching has no graph-local analogue).
    pub fn new(
        cfg: SamplerConfig,
        topo: Option<&Arc<Topology>>,
        n: usize,
        delta: Ticks,
        rng: &mut Rng,
    ) -> Self {
        match cfg {
            SamplerConfig::Oracle => match topo {
                Some(t) => PeerSampler::Topo(TopoState { topo: t.clone(), members: n }),
                None => PeerSampler::Oracle { n },
            },
            SamplerConfig::Newscast { view_size } => {
                PeerSampler::Newscast(Newscast::bootstrap(n, view_size, topo, rng))
            }
            SamplerConfig::Matching => {
                debug_assert!(topo.is_none(), "matching/topology conflict is validated upstream");
                PeerSampler::Matching(MatchingState {
                    n,
                    delta,
                    cycle: u64::MAX,
                    partner: vec![None; n],
                })
            }
        }
    }

    /// A sampler for one shard runner of the sharded simulator (DESIGN.md
    /// §13), covering nodes `[lo, hi)` of a `members`-node universe.
    /// Construction consumes no shared RNG: NEWSCAST views come from
    /// per-node derived streams (`Newscast::bootstrap_range`), and the
    /// topo sampler is pure replicated state over the shared graph, so the
    /// result is identical however nodes are grouped into shards.  The
    /// oracle is replicated state (`n = members`); MATCHING needs a
    /// globally consistent partner table and is only valid when a single
    /// runner covers the whole range (`shards = 1` — enforced upstream by
    /// spec validation).
    pub fn new_range(
        cfg: SamplerConfig,
        topo: Option<&Arc<Topology>>,
        lo: NodeId,
        hi: NodeId,
        members: usize,
        delta: Ticks,
        seed: u64,
    ) -> Self {
        match cfg {
            SamplerConfig::Oracle => match topo {
                Some(t) => PeerSampler::Topo(TopoState { topo: t.clone(), members }),
                None => PeerSampler::Oracle { n: members },
            },
            SamplerConfig::Newscast { view_size } => PeerSampler::Newscast(
                Newscast::bootstrap_range(lo, hi, members, view_size, seed, topo),
            ),
            SamplerConfig::Matching => {
                debug_assert!(lo == 0, "matching requires a full-range runner");
                debug_assert!(
                    hi == members,
                    "matching is full-range: hi is a NodeId bound and must equal members"
                );
                debug_assert!(topo.is_none(), "matching/topology conflict is validated upstream");
                PeerSampler::Matching(MatchingState {
                    n: members,
                    delta,
                    cycle: u64::MAX,
                    partner: vec![None; members],
                })
            }
        }
    }

    /// Range-aware counterpart of [`PeerSampler::grow`]: activate the
    /// membership step `[old_members, new_members)` using per-node derived
    /// streams instead of a shared RNG (shard-grouping independent).
    pub fn grow_range(&mut self, old_members: usize, new_members: usize, seed: u64) {
        match self {
            PeerSampler::Oracle { n } => *n = (*n).max(new_members),
            PeerSampler::Newscast(nc) => nc.grow_range(old_members, new_members, seed),
            PeerSampler::Topo(st) => st.members = st.members.max(new_members),
            PeerSampler::Matching(st) => {
                if new_members > st.n {
                    st.n = new_members;
                    if st.partner.len() < new_members {
                        st.partner.resize(new_members, None);
                    }
                    st.cycle = u64::MAX; // force a refresh with the new nodes
                }
            }
        }
    }

    /// A sampler for one node of a real deployment: only `me`'s view slot is
    /// populated (NEWSCAST) since each deployed node owns its own sampler
    /// instance and never reads another node's state.  Matching is not
    /// meaningful per-node (it needs a globally consistent partner table)
    /// and must be rejected by the deployment configuration.
    pub fn new_local(
        cfg: SamplerConfig,
        topo: Option<&Arc<Topology>>,
        me: NodeId,
        n: usize,
        delta: Ticks,
        rng: &mut Rng,
    ) -> Self {
        match cfg {
            SamplerConfig::Newscast { view_size } => {
                PeerSampler::Newscast(Newscast::bootstrap_node(me, n, view_size, topo, rng))
            }
            other => PeerSampler::new(other, topo, n, delta, rng),
        }
    }

    /// SELECTPEER for `node` at `now`. `online` gives current liveness as a
    /// packed [`Bitset`] (the oracle, topo, and matching samplers restrict
    /// to online peers; newscast may return an offline peer — the message
    /// is then simply lost, as in a real deployment).  The oracle keeps its
    /// rejection-sampling draw sequence: `Bitset::test` is an O(1) drop-in
    /// for the old `Vec<bool>` index, so selections are bit-for-bit
    /// unchanged.  The topo sampler mirrors the oracle's bounded rejection
    /// loop over the node's neighbor list, drawing from the caller's
    /// per-node stream — selections are therefore shard-grouping
    /// independent.
    pub fn select(
        &mut self,
        node: NodeId,
        now: Ticks,
        online: &Bitset,
        rng: &mut Rng,
    ) -> Option<NodeId> {
        match self {
            PeerSampler::Oracle { n } => {
                for _ in 0..64 {
                    let p = rng.below_usize(*n);
                    if p != node && online.test(p) {
                        return Some(p);
                    }
                }
                None
            }
            PeerSampler::Topo(st) => {
                let nbrs = st.topo.neighbors(node);
                if nbrs.is_empty() {
                    return None;
                }
                for _ in 0..64 {
                    let p = nbrs[rng.below_usize(nbrs.len())] as usize;
                    if p != node && p < st.members && online.test(p) {
                        return Some(p);
                    }
                }
                None
            }
            PeerSampler::Newscast(nc) => {
                nc.select(node, rng).filter(|&p| p != node)
            }
            PeerSampler::Matching(st) => {
                st.refresh(now, online, rng);
                st.partner[node]
            }
        }
    }

    /// Grow the sampling universe to `n_new` nodes (scenario flash crowds):
    /// the oracle widens its range, NEWSCAST bootstraps views for the new
    /// arrivals, the topo sampler raises its membership bound (the graph
    /// covers the full universe up front), and the matching baseline
    /// enlarges (and invalidates) its partner table.
    pub fn grow(&mut self, n_new: usize, rng: &mut Rng) {
        match self {
            PeerSampler::Oracle { n } => *n = (*n).max(n_new),
            PeerSampler::Newscast(nc) => nc.grow(n_new, rng),
            PeerSampler::Topo(st) => st.members = st.members.max(n_new),
            PeerSampler::Matching(st) => {
                if n_new > st.n {
                    st.n = n_new;
                    st.partner.resize(n_new, None);
                    st.cycle = u64::MAX; // force a refresh with the new nodes
                }
            }
        }
    }

    /// Piggyback payload for an outgoing message (newscast only).
    pub fn payload(&self, node: NodeId, now: Ticks) -> Vec<Descriptor> {
        match self {
            PeerSampler::Newscast(nc) => nc.payload(node, now),
            _ => Vec::new(),
        }
    }

    /// [`PeerSampler::payload`] into a recycled buffer: clears `out` and
    /// fills it with exactly the same descriptors, so the pooled message
    /// path (DESIGN.md §14) stages views without a per-message `Vec`.
    pub fn payload_into(&self, node: NodeId, now: Ticks, out: &mut Vec<Descriptor>) {
        out.clear();
        if let PeerSampler::Newscast(nc) = self {
            nc.payload_into(node, now, out);
        }
    }

    /// Handle the piggybacked view of a received message.
    pub fn on_receive(&mut self, dst: NodeId, view: &[Descriptor]) {
        if let PeerSampler::Newscast(nc) = self {
            if !view.is_empty() {
                nc.merge(dst, view);
            }
        }
    }
}

impl MatchingState {
    fn refresh(&mut self, now: Ticks, online: &Bitset, rng: &mut Rng) {
        let cycle = now / self.delta.max(1);
        if cycle == self.cycle {
            return;
        }
        self.cycle = cycle;
        self.partner.iter_mut().for_each(|p| *p = None);
        // iter_ones yields increasing indices — exactly the order the old
        // (0..n).filter(|i| online[i]) scan produced, so the shuffle sees
        // the same input and the matching is bit-for-bit unchanged
        let mut live: Vec<NodeId> =
            online.iter_ones().take_while(|&i| i < self.n).collect();
        rng.shuffle(&mut live);
        for pair in live.chunks(2) {
            if let [a, b] = *pair {
                self.partner[a] = Some(b);
                self.partner[b] = Some(a);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::p2p::topology::TopologySpec;

    fn ring(n: usize, k: usize) -> Arc<Topology> {
        let spec = TopologySpec::parse(&format!("ring:{k}")).unwrap().unwrap();
        Arc::new(Topology::build(&spec, n, 7).unwrap())
    }

    #[test]
    fn oracle_skips_offline_and_self() {
        let mut s = PeerSampler::new(SamplerConfig::Oracle, None, 4, 1000, &mut Rng::new(1));
        let online = Bitset::from_fn(4, |i| i != 1);
        let mut rng = Rng::new(2);
        for _ in 0..200 {
            let p = s.select(0, 0, &online, &mut rng).unwrap();
            assert!(p != 0 && p != 1);
        }
    }

    #[test]
    fn oracle_gives_up_when_alone() {
        let mut s = PeerSampler::new(SamplerConfig::Oracle, None, 3, 1000, &mut Rng::new(1));
        let online = Bitset::from_fn(3, |i| i == 0);
        assert_eq!(s.select(0, 0, &online, &mut Rng::new(2)), None);
    }

    #[test]
    fn topo_samples_only_neighbors_and_skips_offline() {
        let topo = ring(10, 1); // node 3's neighbors: 2 and 4
        let mut s = PeerSampler::new(SamplerConfig::Oracle, Some(&topo), 10, 1000, &mut Rng::new(1));
        assert!(matches!(s, PeerSampler::Topo(_)));
        let online = Bitset::filled(10, true);
        let mut rng = Rng::new(2);
        for _ in 0..200 {
            let p = s.select(3, 0, &online, &mut rng).unwrap();
            assert!(p == 2 || p == 4, "ring:1 must sample adjacent nodes, got {p}");
        }
        // offline neighbor is rejected; with both offline, selection fails
        let online = Bitset::from_fn(10, |i| i != 2);
        for _ in 0..200 {
            assert_eq!(s.select(3, 0, &online, &mut rng), Some(4));
        }
        let online = Bitset::from_fn(10, |i| i != 2 && i != 4);
        assert_eq!(s.select(3, 0, &online, &mut rng), None);
    }

    #[test]
    fn topo_range_sampler_is_grouping_independent() {
        // the topo sampler keeps no per-node state and draws from the
        // caller's per-node stream, so a shard-range instance selects
        // exactly what the full-range instance selects
        let topo = ring(20, 3);
        let online = Bitset::filled(20, true);
        let mut full =
            PeerSampler::new_range(SamplerConfig::Oracle, Some(&topo), 0, 20, 20, 1000, 42);
        let mut shard =
            PeerSampler::new_range(SamplerConfig::Oracle, Some(&topo), 5, 10, 20, 1000, 42);
        for me in 5..10 {
            let mut r1 = crate::util::rng::derive_stream(42, "node", me as u64);
            let mut r2 = crate::util::rng::derive_stream(42, "node", me as u64);
            for _ in 0..50 {
                assert_eq!(
                    full.select(me, 0, &online, &mut r1),
                    shard.select(me, 0, &online, &mut r2),
                    "node {me}"
                );
            }
        }
    }

    #[test]
    fn topo_rejects_latecomers_until_grown() {
        // universe of 12 nodes, 6 members: neighbors >= members are
        // rejected like offline peers until grow_range activates them
        let topo = ring(12, 1);
        let online = Bitset::filled(12, true);
        let mut s = PeerSampler::new_range(SamplerConfig::Oracle, Some(&topo), 0, 12, 6, 1000, 1);
        let mut rng = Rng::new(3);
        for _ in 0..100 {
            // node 5's ring neighbors are 4 and 6; 6 is beyond membership
            assert_eq!(s.select(5, 0, &online, &mut rng), Some(4));
        }
        s.grow_range(6, 12, 1);
        let mut seen6 = false;
        for _ in 0..200 {
            if s.select(5, 0, &online, &mut rng) == Some(6) {
                seen6 = true;
            }
        }
        assert!(seen6, "grown topo sampler must reach activated neighbors");
    }

    #[test]
    fn matching_is_a_perfect_matching_per_cycle() {
        let n = 10;
        let mut s = PeerSampler::new(SamplerConfig::Matching, None, n, 100, &mut Rng::new(3));
        let online = Bitset::filled(n, true);
        let mut rng = Rng::new(4);
        let partners: Vec<Option<NodeId>> =
            (0..n).map(|i| s.select(i, 50, &online, &mut rng)).collect();
        for (i, p) in partners.iter().enumerate() {
            let p = p.unwrap();
            assert_eq!(partners[p], Some(i), "matching must be symmetric");
            assert_ne!(p, i);
        }
        // same cycle -> stable; next cycle -> refreshed
        assert_eq!(
            (0..n).map(|i| s.select(i, 60, &online, &mut rng)).collect::<Vec<_>>(),
            partners
        );
    }

    #[test]
    fn matching_range_partner_table_is_member_sized() {
        let s = PeerSampler::new_range(SamplerConfig::Matching, None, 0, 8, 8, 100, 1);
        if let PeerSampler::Matching(st) = s {
            assert_eq!(st.partner.len(), 8);
        } else {
            panic!("expected a matching sampler");
        }
    }

    #[test]
    fn matching_leaves_odd_node_out() {
        let n = 5;
        let mut s = PeerSampler::new(SamplerConfig::Matching, None, n, 100, &mut Rng::new(5));
        let online = Bitset::filled(n, true);
        let mut rng = Rng::new(6);
        let unmatched = (0..n)
            .filter(|&i| s.select(i, 0, &online, &mut rng).is_none())
            .count();
        assert_eq!(unmatched, 1);
    }

    #[test]
    fn local_sampler_uses_single_view_slot() {
        let mut rng = Rng::new(9);
        let mut s = PeerSampler::new_local(
            SamplerConfig::Newscast { view_size: 5 },
            None,
            3,
            20,
            1000,
            &mut rng,
        );
        let online = Bitset::filled(20, true);
        let p = s.select(3, 0, &online, &mut rng).unwrap();
        assert!(p != 3 && p < 20);
        let payload = s.payload(3, 10);
        assert_eq!(payload[0].node, 3);
        assert_eq!(payload.len(), 6); // own descriptor + 5 view entries
        // payload_into fills a recycled buffer with the identical view
        let mut buf = vec![Descriptor { node: 99, ts: 0 }; 3];
        s.payload_into(3, 10, &mut buf);
        assert_eq!(buf, payload);
        s.on_receive(3, &[Descriptor { node: 11, ts: 99 }]);
    }

    #[test]
    fn grow_extends_every_sampler_kind() {
        let mut rng = Rng::new(12);
        // oracle: range widens
        let mut s = PeerSampler::new(SamplerConfig::Oracle, None, 4, 1000, &mut rng);
        s.grow(8, &mut rng);
        let online = Bitset::filled(8, true);
        let mut seen_new = false;
        for _ in 0..200 {
            if s.select(0, 0, &online, &mut rng).unwrap() >= 4 {
                seen_new = true;
            }
        }
        assert!(seen_new, "oracle must sample grown nodes");
        // newscast: new nodes get bootstrapped views, old views untouched
        let mut s = PeerSampler::new(
            SamplerConfig::Newscast { view_size: 5 },
            None,
            10,
            1000,
            &mut rng,
        );
        s.grow(14, &mut rng);
        for node in 10..14 {
            let p = s.select(node, 0, &online, &mut rng);
            assert!(p.is_some(), "grown node {node} has an empty view");
            assert!(p.unwrap() < 14);
            let payload = s.payload(node, 5);
            assert_eq!(payload[0].node, node);
        }
        // matching: partner table covers the grown universe
        let mut s = PeerSampler::new(SamplerConfig::Matching, None, 4, 100, &mut rng);
        s.grow(6, &mut rng);
        let online = Bitset::filled(6, true);
        let partners: Vec<_> = (0..6).map(|i| s.select(i, 0, &online, &mut rng)).collect();
        for (i, p) in partners.iter().enumerate() {
            if let Some(p) = p {
                assert_eq!(partners[*p], Some(i));
            }
        }
    }

    #[test]
    fn range_sampler_matches_full_range_views() {
        // the NEWSCAST range sampler is grouping-independent: a [5,10)
        // shard sees exactly the views the full-range sampler holds
        let seed = 42;
        // universe of 20 rows, 15 initially in the overlay
        let full = PeerSampler::new_range(
            SamplerConfig::Newscast { view_size: 4 },
            None,
            0,
            20,
            15,
            1000,
            seed,
        );
        let mut shard = PeerSampler::new_range(
            SamplerConfig::Newscast { view_size: 4 },
            None,
            5,
            10,
            15,
            1000,
            seed,
        );
        for me in 5..10 {
            assert_eq!(shard.payload(me, 0), full.payload(me, 0), "node {me}");
        }
        // grow through the range API keeps them aligned too
        let mut full = full;
        full.grow_range(15, 20, seed);
        shard.grow_range(15, 20, seed);
        for me in 5..10 {
            assert_eq!(shard.payload(me, 0), full.payload(me, 0), "grown {me}");
        }
        // oracle range sampler widens like the legacy one
        let mut o = PeerSampler::new_range(SamplerConfig::Oracle, None, 5, 10, 15, 1000, seed);
        o.grow_range(15, 20, seed);
        let online = Bitset::filled(20, true);
        let mut rng = Rng::new(3);
        let mut seen_new = false;
        for _ in 0..200 {
            if o.select(6, 0, &online, &mut rng).unwrap() >= 15 {
                seen_new = true;
            }
        }
        assert!(seen_new, "grown oracle must sample new nodes");
    }

    #[test]
    fn newscast_sampler_integration() {
        let mut rng = Rng::new(7);
        let mut s = PeerSampler::new(
            SamplerConfig::Newscast { view_size: 5 },
            None,
            20,
            1000,
            &mut rng,
        );
        let online = Bitset::filled(20, true);
        let p = s.select(3, 0, &online, &mut rng);
        assert!(p.is_some());
        let payload = s.payload(3, 10);
        assert_eq!(payload[0].node, 3);
        s.on_receive(7, &payload);
    }
}
