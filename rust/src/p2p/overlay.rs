//! Peer-sampling service abstraction: the SELECTPEER placeholder of
//! Algorithm 1.  Three implementations (Section VI-A):
//!
//! * `Newscast` — the paper's choice: gossip-based peer sampling with
//!   piggybacked views (p2p/newscast.rs).
//! * `Oracle` — idealized uniform sampling over *online* nodes (baseline for
//!   testing the NEWSCAST uniformity assumption).
//! * `Matching` — PERFECT MATCHING: a fresh random perfect matching of the
//!   online nodes each cycle, so every peer receives exactly one message
//!   (Section VI-A(e); not practical, used as a diversity-maximizing
//!   baseline).

use crate::p2p::newscast::{Descriptor, Newscast};
use crate::sim::event::{NodeId, Ticks};
use crate::util::bitset::Bitset;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SamplerConfig {
    Oracle,
    Newscast { view_size: usize },
    Matching,
}

impl SamplerConfig {
    pub fn name(&self) -> &'static str {
        match self {
            SamplerConfig::Oracle => "oracle",
            SamplerConfig::Newscast { .. } => "newscast",
            SamplerConfig::Matching => "matching",
        }
    }
}

#[derive(Debug)]
pub enum PeerSampler {
    Oracle { n: usize },
    Newscast(Newscast),
    Matching(MatchingState),
}

#[derive(Debug)]
pub struct MatchingState {
    n: usize,
    delta: Ticks,
    cycle: u64,
    partner: Vec<Option<NodeId>>,
}

impl PeerSampler {
    pub fn new(cfg: SamplerConfig, n: usize, delta: Ticks, rng: &mut Rng) -> Self {
        match cfg {
            SamplerConfig::Oracle => PeerSampler::Oracle { n },
            SamplerConfig::Newscast { view_size } => {
                PeerSampler::Newscast(Newscast::bootstrap(n, view_size, rng))
            }
            SamplerConfig::Matching => PeerSampler::Matching(MatchingState {
                n,
                delta,
                cycle: u64::MAX,
                partner: vec![None; n],
            }),
        }
    }

    /// A sampler for one shard runner of the sharded simulator (DESIGN.md
    /// §13), covering nodes `[lo, hi)` of a `members`-node universe.
    /// Construction consumes no shared RNG: NEWSCAST views come from
    /// per-node derived streams (`Newscast::bootstrap_range`), so the
    /// result is identical however nodes are grouped into shards.  The
    /// oracle is replicated state (`n = members`); MATCHING needs a
    /// globally consistent partner table and is only valid when a single
    /// runner covers the whole range (`shards = 1` — enforced upstream by
    /// spec validation).
    pub fn new_range(
        cfg: SamplerConfig,
        lo: NodeId,
        hi: NodeId,
        members: usize,
        delta: Ticks,
        seed: u64,
    ) -> Self {
        match cfg {
            SamplerConfig::Oracle => PeerSampler::Oracle { n: members },
            SamplerConfig::Newscast { view_size } => PeerSampler::Newscast(
                Newscast::bootstrap_range(lo, hi, members, view_size, seed),
            ),
            SamplerConfig::Matching => {
                debug_assert!(lo == 0, "matching requires a full-range runner");
                PeerSampler::Matching(MatchingState {
                    n: members,
                    delta,
                    cycle: u64::MAX,
                    partner: vec![None; hi.max(members)],
                })
            }
        }
    }

    /// Range-aware counterpart of [`PeerSampler::grow`]: activate the
    /// membership step `[old_members, new_members)` using per-node derived
    /// streams instead of a shared RNG (shard-grouping independent).
    pub fn grow_range(&mut self, old_members: usize, new_members: usize, seed: u64) {
        match self {
            PeerSampler::Oracle { n } => *n = (*n).max(new_members),
            PeerSampler::Newscast(nc) => nc.grow_range(old_members, new_members, seed),
            PeerSampler::Matching(st) => {
                if new_members > st.n {
                    st.n = new_members;
                    if st.partner.len() < new_members {
                        st.partner.resize(new_members, None);
                    }
                    st.cycle = u64::MAX; // force a refresh with the new nodes
                }
            }
        }
    }

    /// A sampler for one node of a real deployment: only `me`'s view slot is
    /// populated (NEWSCAST) since each deployed node owns its own sampler
    /// instance and never reads another node's state.  Matching is not
    /// meaningful per-node (it needs a globally consistent partner table)
    /// and must be rejected by the deployment configuration.
    pub fn new_local(cfg: SamplerConfig, me: NodeId, n: usize, delta: Ticks, rng: &mut Rng) -> Self {
        match cfg {
            SamplerConfig::Newscast { view_size } => {
                PeerSampler::Newscast(Newscast::bootstrap_node(me, n, view_size, rng))
            }
            other => PeerSampler::new(other, n, delta, rng),
        }
    }

    /// SELECTPEER for `node` at `now`. `online` gives current liveness as a
    /// packed [`Bitset`] (the oracle and matching samplers restrict to
    /// online peers; newscast may return an offline peer — the message is
    /// then simply lost, as in a real deployment).  The oracle keeps its
    /// rejection-sampling draw sequence: `Bitset::test` is an O(1) drop-in
    /// for the old `Vec<bool>` index, so selections are bit-for-bit
    /// unchanged.
    pub fn select(
        &mut self,
        node: NodeId,
        now: Ticks,
        online: &Bitset,
        rng: &mut Rng,
    ) -> Option<NodeId> {
        match self {
            PeerSampler::Oracle { n } => {
                for _ in 0..64 {
                    let p = rng.below_usize(*n);
                    if p != node && online.test(p) {
                        return Some(p);
                    }
                }
                None
            }
            PeerSampler::Newscast(nc) => {
                nc.select(node, rng).filter(|&p| p != node)
            }
            PeerSampler::Matching(st) => {
                st.refresh(now, online, rng);
                st.partner[node]
            }
        }
    }

    /// Grow the sampling universe to `n_new` nodes (scenario flash crowds):
    /// the oracle widens its range, NEWSCAST bootstraps views for the new
    /// arrivals, and the matching baseline enlarges (and invalidates) its
    /// partner table.
    pub fn grow(&mut self, n_new: usize, rng: &mut Rng) {
        match self {
            PeerSampler::Oracle { n } => *n = (*n).max(n_new),
            PeerSampler::Newscast(nc) => nc.grow(n_new, rng),
            PeerSampler::Matching(st) => {
                if n_new > st.n {
                    st.n = n_new;
                    st.partner.resize(n_new, None);
                    st.cycle = u64::MAX; // force a refresh with the new nodes
                }
            }
        }
    }

    /// Piggyback payload for an outgoing message (newscast only).
    pub fn payload(&self, node: NodeId, now: Ticks) -> Vec<Descriptor> {
        match self {
            PeerSampler::Newscast(nc) => nc.payload(node, now),
            _ => Vec::new(),
        }
    }

    /// [`PeerSampler::payload`] into a recycled buffer: clears `out` and
    /// fills it with exactly the same descriptors, so the pooled message
    /// path (DESIGN.md §14) stages views without a per-message `Vec`.
    pub fn payload_into(&self, node: NodeId, now: Ticks, out: &mut Vec<Descriptor>) {
        out.clear();
        if let PeerSampler::Newscast(nc) = self {
            nc.payload_into(node, now, out);
        }
    }

    /// Handle the piggybacked view of a received message.
    pub fn on_receive(&mut self, dst: NodeId, view: &[Descriptor]) {
        if let PeerSampler::Newscast(nc) = self {
            if !view.is_empty() {
                nc.merge(dst, view);
            }
        }
    }
}

impl MatchingState {
    fn refresh(&mut self, now: Ticks, online: &Bitset, rng: &mut Rng) {
        let cycle = now / self.delta.max(1);
        if cycle == self.cycle {
            return;
        }
        self.cycle = cycle;
        self.partner.iter_mut().for_each(|p| *p = None);
        // iter_ones yields increasing indices — exactly the order the old
        // (0..n).filter(|i| online[i]) scan produced, so the shuffle sees
        // the same input and the matching is bit-for-bit unchanged
        let mut live: Vec<NodeId> =
            online.iter_ones().take_while(|&i| i < self.n).collect();
        rng.shuffle(&mut live);
        for pair in live.chunks(2) {
            if let [a, b] = *pair {
                self.partner[a] = Some(b);
                self.partner[b] = Some(a);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oracle_skips_offline_and_self() {
        let mut s = PeerSampler::new(SamplerConfig::Oracle, 4, 1000, &mut Rng::new(1));
        let online = Bitset::from_fn(4, |i| i != 1);
        let mut rng = Rng::new(2);
        for _ in 0..200 {
            let p = s.select(0, 0, &online, &mut rng).unwrap();
            assert!(p != 0 && p != 1);
        }
    }

    #[test]
    fn oracle_gives_up_when_alone() {
        let mut s = PeerSampler::new(SamplerConfig::Oracle, 3, 1000, &mut Rng::new(1));
        let online = Bitset::from_fn(3, |i| i == 0);
        assert_eq!(s.select(0, 0, &online, &mut Rng::new(2)), None);
    }

    #[test]
    fn matching_is_a_perfect_matching_per_cycle() {
        let n = 10;
        let mut s = PeerSampler::new(SamplerConfig::Matching, n, 100, &mut Rng::new(3));
        let online = Bitset::filled(n, true);
        let mut rng = Rng::new(4);
        let partners: Vec<Option<NodeId>> =
            (0..n).map(|i| s.select(i, 50, &online, &mut rng)).collect();
        for (i, p) in partners.iter().enumerate() {
            let p = p.unwrap();
            assert_eq!(partners[p], Some(i), "matching must be symmetric");
            assert_ne!(p, i);
        }
        // same cycle -> stable; next cycle -> refreshed
        assert_eq!(
            (0..n).map(|i| s.select(i, 60, &online, &mut rng)).collect::<Vec<_>>(),
            partners
        );
    }

    #[test]
    fn matching_leaves_odd_node_out() {
        let n = 5;
        let mut s = PeerSampler::new(SamplerConfig::Matching, n, 100, &mut Rng::new(5));
        let online = Bitset::filled(n, true);
        let mut rng = Rng::new(6);
        let unmatched = (0..n)
            .filter(|&i| s.select(i, 0, &online, &mut rng).is_none())
            .count();
        assert_eq!(unmatched, 1);
    }

    #[test]
    fn local_sampler_uses_single_view_slot() {
        let mut rng = Rng::new(9);
        let mut s = PeerSampler::new_local(
            SamplerConfig::Newscast { view_size: 5 },
            3,
            20,
            1000,
            &mut rng,
        );
        let online = Bitset::filled(20, true);
        let p = s.select(3, 0, &online, &mut rng).unwrap();
        assert!(p != 3 && p < 20);
        let payload = s.payload(3, 10);
        assert_eq!(payload[0].node, 3);
        assert_eq!(payload.len(), 6); // own descriptor + 5 view entries
        // payload_into fills a recycled buffer with the identical view
        let mut buf = vec![Descriptor { node: 99, ts: 0 }; 3];
        s.payload_into(3, 10, &mut buf);
        assert_eq!(buf, payload);
        s.on_receive(3, &[Descriptor { node: 11, ts: 99 }]);
    }

    #[test]
    fn grow_extends_every_sampler_kind() {
        let mut rng = Rng::new(12);
        // oracle: range widens
        let mut s = PeerSampler::new(SamplerConfig::Oracle, 4, 1000, &mut rng);
        s.grow(8, &mut rng);
        let online = Bitset::filled(8, true);
        let mut seen_new = false;
        for _ in 0..200 {
            if s.select(0, 0, &online, &mut rng).unwrap() >= 4 {
                seen_new = true;
            }
        }
        assert!(seen_new, "oracle must sample grown nodes");
        // newscast: new nodes get bootstrapped views, old views untouched
        let mut s = PeerSampler::new(
            SamplerConfig::Newscast { view_size: 5 },
            10,
            1000,
            &mut rng,
        );
        s.grow(14, &mut rng);
        for node in 10..14 {
            let p = s.select(node, 0, &online, &mut rng);
            assert!(p.is_some(), "grown node {node} has an empty view");
            assert!(p.unwrap() < 14);
            let payload = s.payload(node, 5);
            assert_eq!(payload[0].node, node);
        }
        // matching: partner table covers the grown universe
        let mut s = PeerSampler::new(SamplerConfig::Matching, 4, 100, &mut rng);
        s.grow(6, &mut rng);
        let online = Bitset::filled(6, true);
        let partners: Vec<_> = (0..6).map(|i| s.select(i, 0, &online, &mut rng)).collect();
        for (i, p) in partners.iter().enumerate() {
            if let Some(p) = p {
                assert_eq!(partners[*p], Some(i));
            }
        }
    }

    #[test]
    fn range_sampler_matches_full_range_views() {
        // the NEWSCAST range sampler is grouping-independent: a [5,10)
        // shard sees exactly the views the full-range sampler holds
        let seed = 42;
        // universe of 20 rows, 15 initially in the overlay
        let full = PeerSampler::new_range(
            SamplerConfig::Newscast { view_size: 4 },
            0,
            20,
            15,
            1000,
            seed,
        );
        let mut shard = PeerSampler::new_range(
            SamplerConfig::Newscast { view_size: 4 },
            5,
            10,
            15,
            1000,
            seed,
        );
        for me in 5..10 {
            assert_eq!(shard.payload(me, 0), full.payload(me, 0), "node {me}");
        }
        // grow through the range API keeps them aligned too
        let mut full = full;
        full.grow_range(15, 20, seed);
        shard.grow_range(15, 20, seed);
        for me in 5..10 {
            assert_eq!(shard.payload(me, 0), full.payload(me, 0), "grown {me}");
        }
        // oracle range sampler widens like the legacy one
        let mut o = PeerSampler::new_range(SamplerConfig::Oracle, 5, 10, 15, 1000, seed);
        o.grow_range(15, 20, seed);
        let online = Bitset::filled(20, true);
        let mut rng = Rng::new(3);
        let mut seen_new = false;
        for _ in 0..200 {
            if o.select(6, 0, &online, &mut rng).unwrap() >= 15 {
                seen_new = true;
            }
        }
        assert!(seen_new, "grown oracle must sample new nodes");
    }

    #[test]
    fn newscast_sampler_integration() {
        let mut rng = Rng::new(7);
        let mut s = PeerSampler::new(
            SamplerConfig::Newscast { view_size: 5 },
            20,
            1000,
            &mut rng,
        );
        let online = Bitset::filled(20, true);
        let p = s.select(3, 0, &online, &mut rng);
        assert!(p.is_some());
        let payload = s.payload(3, 10);
        assert_eq!(payload[0].node, 3);
        s.on_receive(7, &payload);
    }
}
