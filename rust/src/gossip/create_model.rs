//! CREATEMODEL (Algorithm 2): the three studied ways to combine the incoming
//! model `m1`, the previously received model `m2`, and the node's single
//! local example.

use crate::data::dataset::Row;
use crate::learning::adaline::Learner;
use crate::learning::linear::LinearModel;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    /// CREATEMODELRW: update(m1) — independent random walks (baseline).
    Rw,
    /// CREATEMODELMU: update(merge(m1, m2)).
    Mu,
    /// CREATEMODELUM: merge(update(m1), update(m2)) — both updates use the
    /// same local example.
    Um,
}

impl Variant {
    pub fn name(&self) -> &'static str {
        match self {
            Variant::Rw => "rw",
            Variant::Mu => "mu",
            Variant::Um => "um",
        }
    }

    pub fn parse(s: &str) -> Option<Variant> {
        match s.to_ascii_lowercase().as_str() {
            "rw" => Some(Variant::Rw),
            "mu" => Some(Variant::Mu),
            "um" => Some(Variant::Um),
            _ => None,
        }
    }
}

/// Create the new model from the incoming model (consumed) and the last
/// received model, using the node's local example (x, y).
pub fn create_model(
    variant: Variant,
    learner: &Learner,
    m1: LinearModel,
    m2: &LinearModel,
    x: &Row<'_>,
    y: f32,
) -> LinearModel {
    match variant {
        Variant::Rw => {
            let mut m = m1;
            learner.update(&mut m, x, y);
            m
        }
        Variant::Mu => {
            // merge into m1's buffer in place: the incoming model is owned,
            // so no allocation is needed on this hot path (perf pass §L3)
            let mut m = m1;
            m.merge_from(m2);
            learner.update(&mut m, x, y);
            m
        }
        Variant::Um => {
            let mut u1 = m1;
            let mut u2 = m2.clone();
            learner.update(&mut u1, x, y);
            learner.update(&mut u2, x, y);
            LinearModel::merge(&u1, &u2)
        }
    }
}

/// Allocation-minimal CREATEMODEL used by the simulator hot path: consumes
/// the incoming model, performs the Algorithm-1 `lastModel <- m` assignment
/// in place, and returns the created model.  For MU this needs **zero**
/// extra allocations (the merge reuses the previous lastModel's buffer);
/// RW/UM need exactly one clone of the incoming model.
/// Equivalent to `create_model` + assignment — pinned by a property test.
pub fn create_model_step(
    variant: Variant,
    learner: &Learner,
    incoming: LinearModel,
    last_recv: &mut LinearModel,
    x: &Row<'_>,
    y: f32,
) -> LinearModel {
    match variant {
        Variant::Rw => {
            let mut created = incoming.clone();
            learner.update(&mut created, x, y);
            *last_recv = incoming;
            created
        }
        Variant::Mu => {
            // prev <- merge(prev, incoming) in prev's buffer, then update
            let mut prev = std::mem::replace(last_recv, incoming);
            prev.merge_from(last_recv);
            learner.update(&mut prev, x, y);
            prev
        }
        Variant::Um => {
            let mut u1 = incoming.clone();
            learner.update(&mut u1, x, y);
            let mut u2 = std::mem::replace(last_recv, incoming);
            learner.update(&mut u2, x, y);
            u2.merge_from(&u1);
            u2
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::learning::adaline::Learner;

    fn setup() -> (Learner, LinearModel, LinearModel, Vec<f32>) {
        (
            Learner::pegasos(0.1),
            LinearModel::from_weights(vec![1.0, 0.0], 4),
            LinearModel::from_weights(vec![0.0, 1.0], 2),
            vec![1.0, 1.0],
        )
    }

    #[test]
    fn rw_ignores_m2() {
        let (l, m1, m2, x) = setup();
        let a = create_model(Variant::Rw, &l, m1.clone(), &m2, &Row::Dense(&x), 1.0);
        let zero = LinearModel::zeros(2);
        let b = create_model(Variant::Rw, &l, m1, &zero, &Row::Dense(&x), 1.0);
        assert_eq!(a.weights(), b.weights());
        assert_eq!(a.t, 5);
    }

    #[test]
    fn mu_merges_then_updates() {
        let (l, m1, m2, x) = setup();
        let got = create_model(Variant::Mu, &l, m1.clone(), &m2, &Row::Dense(&x), 1.0);
        let mut expect = LinearModel::merge(&m1, &m2);
        l.update(&mut expect, &Row::Dense(&x), 1.0);
        assert_eq!(got.weights(), expect.weights());
        assert_eq!(got.t, 5); // max(4,2)+1
    }

    #[test]
    fn um_updates_both_with_same_example() {
        let (l, m1, m2, x) = setup();
        let got = create_model(Variant::Um, &l, m1.clone(), &m2, &Row::Dense(&x), 1.0);
        let mut u1 = m1;
        let mut u2 = m2;
        l.update(&mut u1, &Row::Dense(&x), 1.0);
        l.update(&mut u2, &Row::Dense(&x), 1.0);
        let expect = LinearModel::merge(&u1, &u2);
        assert_eq!(got.weights(), expect.weights());
        assert_eq!(got.t, 5); // max(4+1, 2+1)
    }

    #[test]
    fn step_variant_equivalent_to_reference_for_all_variants() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(31);
        for _ in 0..60 {
            let d = 1 + rng.below_usize(12);
            let variant = *rng.pick(&[Variant::Rw, Variant::Mu, Variant::Um]);
            let l = Learner::pegasos(0.05);
            let w1: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            let w2: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            let y = rng.sign();
            let m1 = LinearModel::from_weights(w1, 3);
            let m2 = LinearModel::from_weights(w2, 8);

            let expect = create_model(variant, &l, m1.clone(), &m2, &Row::Dense(&x), y);
            let mut last = m2.clone();
            let got = create_model_step(variant, &l, m1.clone(), &mut last, &Row::Dense(&x), y);
            for (a, b) in got.weights().iter().zip(expect.weights()) {
                assert!((a - b).abs() < 1e-5, "{variant:?}: {a} vs {b}");
            }
            assert_eq!(got.t, expect.t);
            // Algorithm 1 line 9: lastModel <- incoming
            assert_eq!(last.weights(), m1.weights());
            assert_eq!(last.t, m1.t);
        }
    }

    #[test]
    fn variant_parse_roundtrip() {
        for v in [Variant::Rw, Variant::Mu, Variant::Um] {
            assert_eq!(Variant::parse(v.name()), Some(v));
        }
        assert_eq!(Variant::parse("bogus"), None);
    }
}
