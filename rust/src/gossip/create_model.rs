//! CREATEMODEL (Algorithm 2): the three studied ways to combine the incoming
//! model `m1`, the previously received model `m2`, and the node's single
//! local example.

use crate::data::dataset::{Examples, Row};
use crate::learning::adaline::Learner;
use crate::learning::linear::LinearModel;
use crate::learning::pairwise::{
    quorum_merge, quorum_merge_from, MergeMode, PairScratch, PairwiseAuc,
};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    /// CREATEMODELRW: update(m1) — independent random walks (baseline).
    Rw,
    /// CREATEMODELMU: update(merge(m1, m2)).
    Mu,
    /// CREATEMODELUM: merge(update(m1), update(m2)) — both updates use the
    /// same local example.
    Um,
}

impl Variant {
    pub fn name(&self) -> &'static str {
        match self {
            Variant::Rw => "rw",
            Variant::Mu => "mu",
            Variant::Um => "um",
        }
    }

    pub fn parse(s: &str) -> Option<Variant> {
        match s.to_ascii_lowercase().as_str() {
            "rw" => Some(Variant::Rw),
            "mu" => Some(Variant::Mu),
            "um" => Some(Variant::Um),
            _ => None,
        }
    }
}

/// MERGE dispatch (in place): averaging (Algorithm 3) or the quorum vote
/// (DESIGN.md §17).  Mirrors the engine's `combine` so the scalar and
/// batched paths agree bitwise.
#[inline]
fn merge_from(mode: MergeMode, m: &mut LinearModel, other: &LinearModel) {
    match mode {
        MergeMode::Average => m.merge_from(other),
        MergeMode::Quorum => quorum_merge_from(m, other),
    }
}

/// MERGE dispatch (allocating).
#[inline]
fn merge_new(mode: MergeMode, a: &LinearModel, b: &LinearModel) -> LinearModel {
    match mode {
        MergeMode::Average => LinearModel::merge(a, b),
        MergeMode::Quorum => quorum_merge(a, b),
    }
}

/// Create the new model from the incoming model (consumed) and the last
/// received model, using the node's local example (x, y).
pub fn create_model(
    variant: Variant,
    merge: MergeMode,
    learner: &Learner,
    m1: LinearModel,
    m2: &LinearModel,
    x: &Row<'_>,
    y: f32,
) -> LinearModel {
    match variant {
        Variant::Rw => {
            let mut m = m1;
            learner.update(&mut m, x, y);
            m
        }
        Variant::Mu => {
            // merge into m1's buffer in place: the incoming model is owned,
            // so no allocation is needed on this hot path (perf pass §L3)
            let mut m = m1;
            merge_from(merge, &mut m, m2);
            learner.update(&mut m, x, y);
            m
        }
        Variant::Um => {
            let mut u1 = m1;
            let mut u2 = m2.clone();
            learner.update(&mut u1, x, y);
            learner.update(&mut u2, x, y);
            merge_new(merge, &u1, &u2)
        }
    }
}

/// Allocation-minimal CREATEMODEL used by the simulator hot path: consumes
/// the incoming model, performs the Algorithm-1 `lastModel <- m` assignment
/// in place, and returns the created model.  For MU this needs **zero**
/// extra allocations (the merge reuses the previous lastModel's buffer);
/// RW/UM need exactly one clone of the incoming model.
/// Equivalent to `create_model` + assignment — pinned by a property test.
pub fn create_model_step(
    variant: Variant,
    merge: MergeMode,
    learner: &Learner,
    incoming: LinearModel,
    last_recv: &mut LinearModel,
    x: &Row<'_>,
    y: f32,
) -> LinearModel {
    match variant {
        Variant::Rw => {
            let mut created = incoming.clone();
            learner.update(&mut created, x, y);
            *last_recv = incoming;
            created
        }
        Variant::Mu => {
            // prev <- merge(prev, incoming) in prev's buffer, then update
            let mut prev = std::mem::replace(last_recv, incoming);
            merge_from(merge, &mut prev, last_recv);
            learner.update(&mut prev, x, y);
            prev
        }
        Variant::Um => {
            let mut u1 = incoming.clone();
            learner.update(&mut u1, x, y);
            let mut u2 = std::mem::replace(last_recv, incoming);
            learner.update(&mut u2, x, y);
            merge_from(merge, &mut u2, &u1);
            u2
        }
    }
}

/// CREATEMODEL for the pairwise AUC objective (DESIGN.md §17): the learner
/// step pairs the local example against the walking model's example
/// reservoir instead of taking a pointwise gradient.  `res` is the incoming
/// message's reservoir and `train` resolves its origin nodes to feature
/// rows; the caller offers its local example into the reservoir *after* this
/// step (the engine kernels follow the same order).  Same `lastModel`
/// assignment discipline as [`create_model_step`].
#[allow(clippy::too_many_arguments)]
pub fn create_model_pairwise_step(
    variant: Variant,
    merge: MergeMode,
    auc: &PairwiseAuc,
    incoming: LinearModel,
    last_recv: &mut LinearModel,
    x: &Row<'_>,
    y: f32,
    res: &[f32],
    train: &Examples,
    scratch: &mut PairScratch,
) -> LinearModel {
    match variant {
        Variant::Rw => {
            let mut created = incoming.clone();
            auc.update_with_reservoir(&mut created, x, y, res, train, scratch);
            *last_recv = incoming;
            created
        }
        Variant::Mu => {
            let mut prev = std::mem::replace(last_recv, incoming);
            merge_from(merge, &mut prev, last_recv);
            auc.update_with_reservoir(&mut prev, x, y, res, train, scratch);
            prev
        }
        Variant::Um => {
            let mut u1 = incoming.clone();
            auc.update_with_reservoir(&mut u1, x, y, res, train, scratch);
            let mut u2 = std::mem::replace(last_recv, incoming);
            auc.update_with_reservoir(&mut u2, x, y, res, train, scratch);
            merge_from(merge, &mut u2, &u1);
            u2
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::learning::adaline::Learner;

    fn setup() -> (Learner, LinearModel, LinearModel, Vec<f32>) {
        (
            Learner::pegasos(0.1),
            LinearModel::from_weights(vec![1.0, 0.0], 4),
            LinearModel::from_weights(vec![0.0, 1.0], 2),
            vec![1.0, 1.0],
        )
    }

    const AVG: MergeMode = MergeMode::Average;

    #[test]
    fn rw_ignores_m2() {
        let (l, m1, m2, x) = setup();
        let a = create_model(Variant::Rw, AVG, &l, m1.clone(), &m2, &Row::Dense(&x), 1.0);
        let zero = LinearModel::zeros(2);
        let b = create_model(Variant::Rw, AVG, &l, m1, &zero, &Row::Dense(&x), 1.0);
        assert_eq!(a.weights(), b.weights());
        assert_eq!(a.t, 5);
    }

    #[test]
    fn mu_merges_then_updates() {
        let (l, m1, m2, x) = setup();
        let got = create_model(Variant::Mu, AVG, &l, m1.clone(), &m2, &Row::Dense(&x), 1.0);
        let mut expect = LinearModel::merge(&m1, &m2);
        l.update(&mut expect, &Row::Dense(&x), 1.0);
        assert_eq!(got.weights(), expect.weights());
        assert_eq!(got.t, 5); // max(4,2)+1
    }

    #[test]
    fn um_updates_both_with_same_example() {
        let (l, m1, m2, x) = setup();
        let got = create_model(Variant::Um, AVG, &l, m1.clone(), &m2, &Row::Dense(&x), 1.0);
        let mut u1 = m1;
        let mut u2 = m2;
        l.update(&mut u1, &Row::Dense(&x), 1.0);
        l.update(&mut u2, &Row::Dense(&x), 1.0);
        let expect = LinearModel::merge(&u1, &u2);
        assert_eq!(got.weights(), expect.weights());
        assert_eq!(got.t, 5); // max(4+1, 2+1)
    }

    #[test]
    fn quorum_mu_votes_before_updating() {
        let (l, _, _, x) = setup();
        let m1 = LinearModel::from_weights(vec![1.0, -2.0], 4);
        let m2 = LinearModel::from_weights(vec![3.0, 2.0], 2);
        let got = create_model(
            Variant::Mu, MergeMode::Quorum, &l, m1.clone(), &m2, &Row::Dense(&x), 1.0,
        );
        // quorum vote: agree on coord 0 (avg 2.0), disagree on coord 1 (0.0)
        let mut expect = quorum_merge(&m1, &m2);
        assert_eq!(expect.weights(), vec![2.0, 0.0]);
        l.update(&mut expect, &Row::Dense(&x), 1.0);
        assert_eq!(got.weights(), expect.weights());
        assert_eq!(got.t, 5);
    }

    #[test]
    fn step_variant_equivalent_to_reference_for_all_variants() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(31);
        for _ in 0..60 {
            let d = 1 + rng.below_usize(12);
            let variant = *rng.pick(&[Variant::Rw, Variant::Mu, Variant::Um]);
            let merge = *rng.pick(&[MergeMode::Average, MergeMode::Quorum]);
            let l = Learner::pegasos(0.05);
            let w1: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            let w2: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            let y = rng.sign();
            let m1 = LinearModel::from_weights(w1, 3);
            let m2 = LinearModel::from_weights(w2, 8);

            let expect = create_model(variant, merge, &l, m1.clone(), &m2, &Row::Dense(&x), y);
            let mut last = m2.clone();
            let got =
                create_model_step(variant, merge, &l, m1.clone(), &mut last, &Row::Dense(&x), y);
            for (a, b) in got.weights().iter().zip(expect.weights()) {
                assert!((a - b).abs() < 1e-5, "{variant:?}/{merge:?}: {a} vs {b}");
            }
            assert_eq!(got.t, expect.t);
            // Algorithm 1 line 9: lastModel <- incoming
            assert_eq!(last.weights(), m1.weights());
            assert_eq!(last.t, m1.t);
        }
    }

    #[test]
    fn pairwise_step_matches_manual_reservoir_update() {
        use crate::data::matrix::Matrix;
        use crate::learning::pairwise::{offer, reservoir_new};
        let auc = PairwiseAuc::new(0.1);
        let train = Examples::Dense(Matrix::from_vec(
            2,
            2,
            vec![1.0, 0.0, 0.0, 1.0],
        ));
        let mut res = reservoir_new(2);
        offer(&mut res, 0, -1.0, 0); // opposite class: pairs with y = +1
        offer(&mut res, 1, 1.0, 0); // same class: filtered out
        let m1 = LinearModel::from_weights(vec![0.5, -0.5], 4);
        let m2 = LinearModel::from_weights(vec![0.0, 0.25], 2);
        let x = [0.0f32, 2.0];

        for variant in [Variant::Rw, Variant::Mu, Variant::Um] {
            let mut scratch = PairScratch::default();
            let mut last = m2.clone();
            let got = create_model_pairwise_step(
                variant,
                MergeMode::Average,
                &auc,
                m1.clone(),
                &mut last,
                &Row::Dense(&x),
                1.0,
                &res,
                &train,
                &mut scratch,
            );
            // reference: apply update_with_reservoir by hand per variant
            let mut scratch2 = PairScratch::default();
            let expect = match variant {
                Variant::Rw => {
                    let mut m = m1.clone();
                    auc.update_with_reservoir(
                        &mut m, &Row::Dense(&x), 1.0, &res, &train, &mut scratch2,
                    );
                    m
                }
                Variant::Mu => {
                    let mut m = LinearModel::merge(&m1, &m2);
                    auc.update_with_reservoir(
                        &mut m, &Row::Dense(&x), 1.0, &res, &train, &mut scratch2,
                    );
                    m
                }
                Variant::Um => {
                    let mut u1 = m1.clone();
                    let mut u2 = m2.clone();
                    auc.update_with_reservoir(
                        &mut u1, &Row::Dense(&x), 1.0, &res, &train, &mut scratch2,
                    );
                    auc.update_with_reservoir(
                        &mut u2, &Row::Dense(&x), 1.0, &res, &train, &mut scratch2,
                    );
                    LinearModel::merge(&u1, &u2)
                }
            };
            for (a, b) in got.weights().iter().zip(expect.weights()) {
                assert!((a - b).abs() < 1e-5, "{variant:?}: {a} vs {b}");
            }
            assert_eq!(got.t, expect.t);
            assert!(got.t > m1.t.max(m2.t), "{variant:?}: opposite pair must step t");
            assert_eq!(last.weights(), m1.weights(), "lastModel <- incoming");
        }
    }

    #[test]
    fn variant_parse_roundtrip() {
        for v in [Variant::Rw, Variant::Mu, Variant::Um] {
            assert_eq!(Variant::parse(v.name()), Some(v));
        }
        assert_eq!(Variant::parse("bogus"), None);
    }
}
