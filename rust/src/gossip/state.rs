//! Unified structure-of-arrays model store (DESIGN.md §5).
//!
//! Every simulator driver — the event-driven [`crate::gossip::protocol`]
//! simulator and the cycle-synchronous [`crate::engine::batched`] driver —
//! keeps the same per-node protocol state: the freshest model created at the
//! node and the last model received (Algorithm 1's `lastModel`).  Before this
//! module existed each driver kept its own copy (per-node `LinearModel`
//! allocations in the event path, private flat arrays in the batched path).
//!
//! `ModelStore` is the single representation: flat row-major `[n, d]` weight
//! matrices plus `[n]` update-counter vectors, with node ids as row handles.
//! Each weight row additionally carries a lazy scale factor in a `[n]`
//! column (effective weights are `scale * w`): the dense execution path
//! keeps every scale at 1.0, so rows memcpy straight into
//! [`crate::engine::StepBatch`] buffers and back, while the O(nnz) sparse
//! path (DESIGN.md §7) carries the Pegasos decay in the scale and
//! materializes only at the evaluation/cache boundary
//! ([`ModelStore::freshest_model`], [`ModelStore::write_freshest_into`]).
//! The scale semantics — including the `SCALE_FLOOR` re-materialization —
//! live in `learning/linear.rs` and are shared with the engine kernels.
//!
//! The update counter `t` is f32 to match the engine's `StepBatch`/kernel
//! representation: exact up to 2^24 (~16.7M) updates per node, far beyond
//! any paper-scale run (one update per received message, hundreds to
//! thousands of cycles).  `LinearModel`'s u64 `t` is recovered at the
//! evaluation/cache boundary via [`ModelStore::freshest_model`].

use crate::learning::linear::LinearModel;
use crate::learning::pairwise::reservoir_len;
use std::ops::Range;

#[derive(Clone, Debug)]
pub struct ModelStore {
    n: usize,
    d: usize,
    freshest_w: Vec<f32>,
    freshest_s: Vec<f32>,
    freshest_t: Vec<f32>,
    last_w: Vec<f32>,
    last_s: Vec<f32>,
    last_t: Vec<f32>,
    /// reservoir capacity K (0 = pointwise learner, no reservoir rows)
    res_cap: usize,
    /// packed `[n, 1 + 2K]` example reservoirs riding with the freshest
    /// models (pairwise objectives, DESIGN.md §17); empty when `res_cap == 0`.
    /// `lastModel` needs no reservoir — only the walking model trains.
    freshest_res: Vec<f32>,
}

impl ModelStore {
    /// INITMODEL (Algorithm 3) for every node: zero weights, scale 1, t = 0.
    pub fn new(n: usize, d: usize) -> Self {
        Self::with_reservoirs(n, d, 0)
    }

    /// INITMODEL plus an empty capacity-`res_cap` example reservoir per node
    /// (the all-zero buffer *is* the empty reservoir — `seen = 0`).
    pub fn with_reservoirs(n: usize, d: usize, res_cap: usize) -> Self {
        ModelStore {
            n,
            d,
            freshest_w: vec![0.0; n * d],
            freshest_s: vec![1.0; n],
            freshest_t: vec![0.0; n],
            last_w: vec![0.0; n * d],
            last_s: vec![1.0; n],
            last_t: vec![0.0; n],
            res_cap,
            freshest_res: vec![0.0; if res_cap > 0 { n * reservoir_len(res_cap) } else { 0 }],
        }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn d(&self) -> usize {
        self.d
    }

    #[inline]
    fn row(&self, i: usize) -> Range<usize> {
        debug_assert!(i < self.n);
        i * self.d..(i + 1) * self.d
    }

    /// Unscaled weight row of the freshest model created at node `i`
    /// (effective weights are `freshest_scale(i) * freshest(i)`; the scale
    /// is 1.0 on the dense execution path).
    #[inline]
    pub fn freshest(&self, i: usize) -> &[f32] {
        &self.freshest_w[self.row(i)]
    }

    #[inline]
    pub fn freshest_scale(&self, i: usize) -> f32 {
        self.freshest_s[i]
    }

    #[inline]
    pub fn freshest_t(&self, i: usize) -> f32 {
        self.freshest_t[i]
    }

    /// Reservoir capacity K (0 when the store carries no reservoirs).
    #[inline]
    pub fn res_cap(&self) -> usize {
        self.res_cap
    }

    #[inline]
    fn res_row(&self, i: usize) -> Range<usize> {
        debug_assert!(self.res_cap > 0 && i < self.n);
        let len = reservoir_len(self.res_cap);
        i * len..(i + 1) * len
    }

    /// Packed example reservoir riding with node `i`'s freshest model.
    /// Panics (debug) when the store was built without reservoirs.
    #[inline]
    pub fn res(&self, i: usize) -> &[f32] {
        &self.freshest_res[self.res_row(i)]
    }

    /// Overwrite node `i`'s reservoir row; `res` must already be encoded at
    /// this store's capacity (`set_capacity` normalizes wire-decoded ones).
    #[inline]
    pub fn set_res(&mut self, i: usize, res: &[f32]) {
        let r = self.res_row(i);
        self.freshest_res[r].copy_from_slice(res);
    }

    /// Copy node `i`'s reservoir row into the possibly-recycled buffer `out`
    /// (resized first, every element overwritten) — the pooled
    /// message-staging path, mirroring [`ModelStore::write_freshest_raw`].
    pub fn write_res_raw(&self, i: usize, out: &mut Vec<f32>) {
        out.resize(reservoir_len(self.res_cap), 0.0);
        out.copy_from_slice(&self.freshest_res[self.res_row(i)]);
    }

    /// Unscaled weight row of the last model received at node `i`
    /// (`lastModel`).
    #[inline]
    pub fn last(&self, i: usize) -> &[f32] {
        &self.last_w[self.row(i)]
    }

    #[inline]
    pub fn last_scale(&self, i: usize) -> f32 {
        self.last_s[i]
    }

    #[inline]
    pub fn last_t(&self, i: usize) -> f32 {
        self.last_t[i]
    }

    /// Store materialized weights (scale resets to 1.0) — the dense path.
    #[inline]
    pub fn set_freshest(&mut self, i: usize, w: &[f32], t: f32) {
        self.set_freshest_scaled(i, w, 1.0, t);
    }

    /// Store a lazily-scaled row — the sparse path.
    #[inline]
    pub fn set_freshest_scaled(&mut self, i: usize, w: &[f32], s: f32, t: f32) {
        let r = self.row(i);
        self.freshest_w[r].copy_from_slice(w);
        self.freshest_s[i] = s;
        self.freshest_t[i] = t;
    }

    /// Store materialized weights (scale resets to 1.0) — the dense path.
    #[inline]
    pub fn set_last(&mut self, i: usize, w: &[f32], t: f32) {
        self.set_last_scaled(i, w, 1.0, t);
    }

    /// Store a lazily-scaled row — the sparse path.
    #[inline]
    pub fn set_last_scaled(&mut self, i: usize, w: &[f32], s: f32, t: f32) {
        let r = self.row(i);
        self.last_w[r].copy_from_slice(w);
        self.last_s[i] = s;
        self.last_t[i] = t;
    }

    /// Append `k` new nodes in INITMODEL state (zero weights, scale 1,
    /// t = 0) — dynamic membership growth for scenario flash crowds
    /// (DESIGN.md §11).  Existing rows keep their ids and contents; the new
    /// nodes take ids `n..n+k`.
    pub fn grow(&mut self, k: usize) {
        self.n += k;
        self.freshest_w.resize(self.n * self.d, 0.0);
        self.freshest_s.resize(self.n, 1.0);
        self.freshest_t.resize(self.n, 0.0);
        self.last_w.resize(self.n * self.d, 0.0);
        self.last_s.resize(self.n, 1.0);
        self.last_t.resize(self.n, 0.0);
        if self.res_cap > 0 {
            // zeroed rows are valid empty reservoirs
            self.freshest_res.resize(self.n * reservoir_len(self.res_cap), 0.0);
        }
    }

    /// Reset node `i` back to INITMODEL state (restart schedules, churn with
    /// state loss, drifting-concept experiments).
    pub fn reset(&mut self, i: usize) {
        let r = self.row(i);
        self.freshest_w[r.clone()].fill(0.0);
        self.last_w[r].fill(0.0);
        self.freshest_s[i] = 1.0;
        self.last_s[i] = 1.0;
        self.freshest_t[i] = 0.0;
        self.last_t[i] = 0.0;
        if self.res_cap > 0 {
            let rr = self.res_row(i);
            self.freshest_res[rr].fill(0.0);
        }
    }

    /// Write node `i`'s **materialized** freshest weights into `out` (the
    /// lazy scale folds during the copy; no allocation).  Evaluation staging
    /// uses this to build `[m, d]` model batches.
    pub fn write_freshest_into(&self, i: usize, out: &mut [f32]) {
        let r = self.row(i);
        let w = &self.freshest_w[r];
        let s = self.freshest_s[i];
        if s == 1.0 {
            out.copy_from_slice(w);
        } else {
            for (o, &v) in out.iter_mut().zip(w) {
                *o = v * s;
            }
        }
    }

    /// Write node `i`'s **raw** (unscaled) freshest weight row into the
    /// possibly-recycled buffer `out`, resizing it to exactly `d` first and
    /// overwriting every element.  This is the pooled message-staging path
    /// (DESIGN.md §14): the resize + full `copy_from_slice` together are
    /// what make a recycled buffer safe — no stale float from a previous
    /// message can survive, whatever length the buffer came back at.
    pub fn write_freshest_raw(&self, i: usize, out: &mut Vec<f32>) {
        out.resize(self.d, 0.0);
        out.copy_from_slice(&self.freshest_w[self.row(i)]);
    }

    /// Materialize node `i`'s freshest model as a [`LinearModel`] (evaluation
    /// and cache paths; allocates one weight vector).
    pub fn freshest_model(&self, i: usize) -> LinearModel {
        let mut w = vec![0.0f32; self.d];
        self.write_freshest_into(i, &mut w);
        LinearModel::from_weights(w, self.freshest_t(i) as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn starts_at_init_model() {
        let s = ModelStore::new(3, 4);
        assert_eq!(s.n(), 3);
        assert_eq!(s.d(), 4);
        for i in 0..3 {
            assert!(s.freshest(i).iter().all(|&v| v == 0.0));
            assert!(s.last(i).iter().all(|&v| v == 0.0));
            assert_eq!(s.freshest_t(i), 0.0);
            assert_eq!(s.last_t(i), 0.0);
        }
    }

    #[test]
    fn set_get_roundtrip_per_row() {
        let mut s = ModelStore::new(3, 2);
        s.set_freshest(1, &[1.0, 2.0], 5.0);
        s.set_last(1, &[-3.0, 4.0], 2.0);
        assert_eq!(s.freshest(1), &[1.0, 2.0]);
        assert_eq!(s.freshest_t(1), 5.0);
        assert_eq!(s.last(1), &[-3.0, 4.0]);
        assert_eq!(s.last_t(1), 2.0);
        // neighbours untouched
        assert!(s.freshest(0).iter().all(|&v| v == 0.0));
        assert!(s.freshest(2).iter().all(|&v| v == 0.0));
    }

    #[test]
    fn reset_zeroes_one_node_only() {
        let mut s = ModelStore::new(2, 2);
        s.set_freshest(0, &[1.0, 1.0], 3.0);
        s.set_freshest(1, &[2.0, 2.0], 4.0);
        s.set_last(1, &[5.0, 5.0], 1.0);
        s.reset(1);
        assert_eq!(s.freshest(1), &[0.0, 0.0]);
        assert_eq!(s.last(1), &[0.0, 0.0]);
        assert_eq!(s.freshest_t(1), 0.0);
        assert_eq!(s.freshest(0), &[1.0, 1.0]);
        assert_eq!(s.freshest_t(0), 3.0);
    }

    #[test]
    fn grow_appends_init_rows_and_keeps_existing() {
        let mut s = ModelStore::new(2, 3);
        s.set_freshest(1, &[1.0, 2.0, 3.0], 4.0);
        s.set_last_scaled(0, &[5.0, 6.0, 7.0], 0.5, 2.0);
        s.grow(3);
        assert_eq!(s.n(), 5);
        assert_eq!(s.freshest(1), &[1.0, 2.0, 3.0]);
        assert_eq!(s.last(0), &[5.0, 6.0, 7.0]);
        assert_eq!(s.last_scale(0), 0.5);
        for i in 2..5 {
            assert!(s.freshest(i).iter().all(|&v| v == 0.0));
            assert_eq!(s.freshest_scale(i), 1.0);
            assert_eq!(s.freshest_t(i), 0.0);
            assert_eq!(s.last_scale(i), 1.0);
        }
        // grown rows are fully functional
        s.set_freshest(4, &[9.0, 9.0, 9.0], 1.0);
        assert_eq!(s.freshest_model(4).weights(), vec![9.0, 9.0, 9.0]);
    }

    #[test]
    fn write_freshest_raw_overwrites_recycled_buffers() {
        let mut s = ModelStore::new(1, 3);
        s.set_freshest_scaled(0, &[4.0, -8.0, 2.0], 0.25, 9.0);
        // a "recycled" buffer: wrong length, poisoned contents
        let mut buf = vec![99.0f32; 7];
        s.write_freshest_raw(0, &mut buf);
        assert_eq!(buf, vec![4.0, -8.0, 2.0], "raw row, scale NOT folded");
        // and from the short side
        let mut short = vec![5.0f32; 1];
        s.write_freshest_raw(0, &mut short);
        assert_eq!(short, vec![4.0, -8.0, 2.0]);
    }

    #[test]
    fn reservoir_rows_follow_grow_and_reset() {
        use crate::learning::pairwise::{occupancy, offer, reservoir_new};
        let mut s = ModelStore::with_reservoirs(2, 2, 3);
        assert_eq!(s.res_cap(), 3);
        assert_eq!(occupancy(s.res(0)), 0, "zeroed row is an empty reservoir");
        let mut r = reservoir_new(3);
        offer(&mut r, 42, -1.0, 0);
        s.set_res(1, &r);
        assert_eq!(occupancy(s.res(1)), 1);
        assert_eq!(occupancy(s.res(0)), 0, "neighbour untouched");
        s.grow(2);
        assert_eq!(occupancy(s.res(1)), 1, "grow keeps existing reservoirs");
        assert_eq!(occupancy(s.res(3)), 0, "grown rows start empty");
        s.reset(1);
        assert_eq!(occupancy(s.res(1)), 0, "reset clears the reservoir");
        // pointwise stores allocate nothing
        assert_eq!(ModelStore::new(4, 2).res_cap(), 0);
    }

    #[test]
    fn freshest_model_materializes() {
        let mut s = ModelStore::new(1, 3);
        s.set_freshest(0, &[0.5, -0.5, 1.0], 7.0);
        let m = s.freshest_model(0);
        assert_eq!(m.weights(), vec![0.5, -0.5, 1.0]);
        assert_eq!(m.t, 7);
    }

    #[test]
    fn scaled_rows_materialize_at_the_eval_boundary() {
        let mut s = ModelStore::new(2, 2);
        s.set_freshest_scaled(0, &[4.0, -8.0], 0.25, 9.0);
        s.set_last_scaled(0, &[2.0, 2.0], 0.5, 3.0);
        assert_eq!(s.freshest(0), &[4.0, -8.0]); // raw row stays unscaled
        assert_eq!(s.freshest_scale(0), 0.25);
        assert_eq!(s.last_scale(0), 0.5);
        let mut out = vec![0.0; 2];
        s.write_freshest_into(0, &mut out);
        assert_eq!(out, vec![1.0, -2.0]);
        let m = s.freshest_model(0);
        assert_eq!(m.weights(), vec![1.0, -2.0]);
        assert_eq!(m.t, 9);
        // a dense-path store resets the scale
        s.set_freshest(0, &[1.0, 1.0], 1.0);
        assert_eq!(s.freshest_scale(0), 1.0);
        // reset restores scale 1
        s.set_freshest_scaled(1, &[1.0, 1.0], 0.5, 2.0);
        s.reset(1);
        assert_eq!(s.freshest_scale(1), 1.0);
        assert_eq!(s.last_scale(1), 1.0);
    }
}
