//! The gossip learning protocol (Algorithm 1) running over the
//! discrete-event simulator: the paper's core system.
//!
//! Every node runs the same loop: wait(Δ) (with Δ jittered per-iteration as
//! N(Δ, Δ/10), Section IV), SELECTPEER, send the freshest cached model; on
//! receive, CREATEMODEL combines the incoming model with the previously
//! received one and the node's single local example, refreshing the cache.
//! No synchrony and no reliability is assumed: messages can be dropped,
//! delayed far beyond Δ, and nodes churn with state retention.
//!
//! Execution (DESIGN.md §2): per-node state lives in the structure-of-arrays
//! [`ModelStore`]; `Deliver` events are drained into [`StepBatch`]
//! micro-batches and executed through a [`Backend`], so the faithful
//! event-driven semantics (jitter, arbitrary delay, churn, deterministic FIFO
//! tie-breaking) run on the same vectorized kernels as the cycle-synchronous
//! driver.  [`ExecMode::Scalar`] keeps one-delivery-at-a-time stepping as a
//! debug/parity mode; the two modes are pinned bit-for-bit against each other
//! in tests/engine_parity.rs.
//!
//! Orthogonally, [`ExecPath`] picks the kernel family per run (DESIGN.md §7):
//! dense `[b, d]` rows, or — for sparse high-dimensional datasets like
//! Reuters — CSR-staged batches through the O(nnz) lazy-scale kernels, with
//! evaluation batched through the same sparse-aware backend.

use crate::api::{NullObserver, Observer, RunEvent};
use crate::data::dataset::{Dataset, Examples};
use crate::data::sparse::Csr;
use crate::engine::native::NativeBackend;
use crate::engine::{eval_peer_errors, Backend, StepBatch, StepOp, MAX_BATCH_ROWS};
use crate::eval::{
    self,
    tracker::{point_from_errors, Curve},
};
use crate::gossip::cache::ModelCache;
use crate::gossip::create_model::Variant;
use crate::gossip::message::ModelMsg;
use crate::gossip::predict::Predictor;
use crate::gossip::state::ModelStore;
use crate::learning::adaline::Learner;
use crate::learning::linear::LinearModel;
use crate::p2p::overlay::{PeerSampler, SamplerConfig};
use crate::scenario::driver::{resolve_churn_schedule, CompiledScenario, Mutation, ScenarioDriver};
use crate::scenario::Scenario;
use crate::sim::churn::{ChurnConfig, ChurnSchedule};
use crate::sim::event::{Event, EventQueue, NodeId, Ticks};
use crate::sim::network::{Fate, Network, NetworkConfig};
use crate::util::rng::Rng;
use anyhow::Result;
use std::collections::HashMap;

/// Evaluation settings (Section VI-A(h): misclassification ratio over the
/// test set, measured at 100 randomly selected peers).
#[derive(Clone, Debug)]
pub struct EvalConfig {
    pub n_peers: usize,
    /// measure the Algorithm-4 voting predictor too (needs caches at the
    /// sampled peers)
    pub voting: bool,
    /// measure mean pairwise cosine similarity of sampled models
    pub similarity: bool,
    /// cycles at which to measure; empty = log-spaced over the run
    pub at_cycles: Vec<u64>,
}

impl Default for EvalConfig {
    fn default() -> Self {
        EvalConfig { n_peers: 100, voting: false, similarity: false, at_cycles: Vec::new() }
    }
}

/// How the event-driven simulator executes CREATEMODEL steps.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    /// One backend call per delivery — the reference semantics, kept as a
    /// debug/parity mode.
    Scalar,
    /// Drain all `Deliver` events sharing a timestamp into one batched engine
    /// call.  With `coalesce > 0`, delivery times are additionally quantized
    /// up to the next multiple of `coalesce` ticks, so deliveries landing
    /// within the same window share a timestamp and batch together (a bounded
    /// timing approximation, off by default; DESIGN.md §2).
    MicroBatch { coalesce: Ticks },
}

impl Default for ExecMode {
    fn default() -> Self {
        ExecMode::MicroBatch { coalesce: 0 }
    }
}

impl ExecMode {
    pub fn name(&self) -> &'static str {
        match self {
            ExecMode::Scalar => "scalar",
            ExecMode::MicroBatch { .. } => "microbatch",
        }
    }
}

/// Which kernel family executes CREATEMODEL steps and evaluation
/// (DESIGN.md §7) — orthogonal to [`ExecMode`], which decides how deliveries
/// batch.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum ExecPath {
    /// Density-based dispatch: sparsely stored training sets whose density
    /// nnz/(n·d) is at most [`ExecPath::AUTO_DENSITY_MAX`] take the sparse
    /// path; everything else runs dense.
    #[default]
    Auto,
    /// Dense `[b, d]` row kernels (O(d) per update).
    Dense,
    /// CSR-staged batches through the O(nnz) lazy-scale kernels.  Forcing
    /// this on a densely stored dataset copies it to CSR at construction.
    Sparse,
}

impl ExecPath {
    /// Above this training-set density the sparse path stops paying for
    /// itself (per-update work ~ merge O(d) + O(nnz) vs. the dense kernels'
    /// ~3 O(d) passes) and `Auto` dispatches dense.
    pub const AUTO_DENSITY_MAX: f64 = 0.25;

    pub fn parse(s: &str) -> Option<ExecPath> {
        match s {
            "auto" => Some(ExecPath::Auto),
            "dense" => Some(ExecPath::Dense),
            "sparse" => Some(ExecPath::Sparse),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ExecPath::Auto => "auto",
            ExecPath::Dense => "dense",
            ExecPath::Sparse => "sparse",
        }
    }

    /// Resolve the dispatch against a training set.
    pub fn use_sparse(&self, train: &Examples) -> bool {
        match self {
            ExecPath::Dense => false,
            ExecPath::Sparse => true,
            ExecPath::Auto => {
                matches!(train, Examples::Sparse(_))
                    && train.density() <= Self::AUTO_DENSITY_MAX
            }
        }
    }
}

#[derive(Clone, Debug)]
pub struct ProtocolConfig {
    pub variant: Variant,
    pub learner: Learner,
    /// model cache capacity (paper: 10)
    pub cache_size: usize,
    /// gossip period Δ in ticks
    pub delta: Ticks,
    /// run length in cycles (wall time = cycles * Δ)
    pub cycles: u64,
    pub sampler: SamplerConfig,
    pub network: NetworkConfig,
    pub churn: Option<ChurnConfig>,
    pub eval: EvalConfig,
    pub seed: u64,
    /// Restart schedule (Section IV mentions randomly restarted loops as the
    /// mechanism for following drifting concepts — beyond-paper extension):
    /// every `k` cycles a node resets its models to the initial state.
    pub restart_every: Option<u64>,
    /// CREATEMODEL execution strategy (micro-batched by default).
    pub exec: ExecMode,
    /// dense vs. O(nnz) sparse kernels (density-dispatched by default).
    pub path: ExecPath,
    /// declarative failure/workload timeline (DESIGN.md §11).  Must be
    /// validated against (n, cycles) by the configuration layer; the
    /// simulators compile it into tick-indexed mutations at construction.
    pub scenario: Option<Scenario>,
}

impl ProtocolConfig {
    /// Paper defaults: MU variant, Pegasos(λ=1e-2, calibrated on the
    /// synthetic Table-I sets — the paper does not report its λ), cache 10,
    /// NEWSCAST(20), reliable network, no churn.
    pub fn paper_default(cycles: u64) -> Self {
        ProtocolConfig {
            variant: Variant::Mu,
            learner: Learner::pegasos(1e-2),
            cache_size: 10,
            delta: 1000,
            cycles,
            sampler: SamplerConfig::Newscast { view_size: 20 },
            network: NetworkConfig::reliable(),
            churn: None,
            eval: EvalConfig::default(),
            seed: 42,
            restart_every: None,
            exec: ExecMode::default(),
            path: ExecPath::default(),
            scenario: None,
        }
    }

    /// Section VI-A(i) "all failures": 50% drop, [Δ,10Δ] delay, churn @ 90%.
    pub fn with_extreme_failures(mut self) -> Self {
        self.network = NetworkConfig::extreme(self.delta);
        self.churn = Some(ChurnConfig::paper_default(self.delta));
        self
    }
}

/// Counters for the paper's cost model (one message per node per Δ).
#[derive(Clone, Debug, Default)]
pub struct RunStats {
    pub messages_sent: u64,
    pub messages_dropped: u64,
    /// sends blocked by an active scenario partition (cross-component)
    pub messages_blocked: u64,
    pub messages_lost_offline: u64,
    /// messages actually applied at a receiver; `sent - dropped -
    /// lost_offline - delivered` is the in-flight count at the horizon
    pub messages_delivered: u64,
    pub bytes_sent: u64,
    pub updates_applied: u64,
    /// engine calls made by the micro-batched path (batching effectiveness =
    /// updates_applied / engine_calls)
    pub engine_calls: u64,
    /// batch rows staged through the sparse (CSR) layout — the O(nnz)
    /// kernels on a sparse-capable backend, the densify fallback otherwise;
    /// 0 on the dense path.  Exposes which way the dispatch resolved.
    pub sparse_rows: u64,
}

/// Result of one simulated run.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub curve: Curve,
    pub stats: RunStats,
}

pub struct GossipSim<'a> {
    cfg: ProtocolConfig,
    data: &'a Dataset,
    /// unified SoA per-node model state (freshest + lastModel rows)
    store: ModelStore,
    /// full model caches, materialized only at evaluation peers when voting
    /// is measured (memory: Reuters models are 40 KB each — 10-deep caches at
    /// all 2000 nodes would be ~800 MB)
    caches: Vec<Option<ModelCache>>,
    /// last cycle at which each node executed a scheduled restart
    last_restart: Vec<u64>,
    /// effective liveness per node: churn state AND NOT forced offline
    /// (sized for the full universe; nodes beyond the current membership
    /// never send or receive)
    online: Vec<bool>,
    /// churn-model liveness (before the scenario's forced-offline overlay)
    churn_online: Vec<bool>,
    /// scenario mass-leave overlay
    forced_off: Vec<bool>,
    /// compiled scenario timeline cursor, if any
    scn: Option<ScenarioDriver>,
    /// +1.0 normally; -1.0 after an odd number of concept-drift events
    /// (training and test labels flip sign)
    drift_sign: f32,
    /// lazily built sign-flipped test labels (drift evaluation)
    flipped_test_y: Option<Vec<f32>>,
    queue: EventQueue,
    network: Network,
    sampler: PeerSampler,
    churn: Option<ChurnSchedule>,
    rng: Rng,
    eval_peers: Vec<NodeId>,
    stats: RunStats,
    now: Ticks,
    backend: Box<dyn Backend>,
    op: StepOp,
    batch: StepBatch,
    /// deliveries awaiting the next flush, in FIFO (seq) order
    pending: Vec<(NodeId, ModelMsg)>,
    batch_start: Ticks,
    /// local examples staged once for batch filling, in the layout the
    /// resolved [`ExecPath`] wants
    staged: Staged<'a>,
}

/// Per-node local examples in batch-staging form.
enum Staged<'a> {
    /// densified `[n, d]` (dense execution path)
    Dense(Vec<f32>),
    /// sparse training storage borrowed as-is (sparse path; no copy)
    Csr(&'a Csr),
    /// CSR copy built when the sparse path is forced on dense storage
    CsrOwned(Csr),
}

impl Staged<'_> {
    fn csr(&self) -> &Csr {
        match self {
            Staged::Csr(c) => c,
            Staged::CsrOwned(c) => c,
            Staged::Dense(_) => unreachable!("dense staging has no CSR"),
        }
    }
}

impl<'a> GossipSim<'a> {
    pub fn new(cfg: ProtocolConfig, data: &'a Dataset) -> Self {
        Self::with_backend(cfg, data, Box::new(NativeBackend::new()))
    }

    /// Build the simulator on an explicit compute backend (native or PJRT).
    pub fn with_backend(cfg: ProtocolConfig, data: &'a Dataset, backend: Box<dyn Backend>) -> Self {
        // the node *universe* is one per training row; a scenario may start
        // with a smaller initial membership and grow into the universe
        let n_univ = data.n_train();
        assert!(n_univ >= 2, "need at least two nodes");
        let compiled = cfg.scenario.as_ref().map(|s| {
            CompiledScenario::compile(s, n_univ, cfg.delta, cfg.cycles, cfg.seed, cfg.network)
                .expect("scenario must be validated before the simulator runs")
        });
        let n = compiled.as_ref().map_or(n_univ, |c| c.initial);
        let mut rng = Rng::new(cfg.seed);
        let horizon = cfg.delta * (cfg.cycles + 1);

        // the schedule covers the whole universe so flash-crowd joiners
        // have churn state waiting for them; fork order is unchanged when
        // no scenario overrides churn (resolve_churn_schedule docs)
        let churn = resolve_churn_schedule(
            cfg.churn.as_ref(),
            compiled.as_ref(),
            n_univ,
            cfg.delta,
            horizon,
            &mut rng,
        );

        let mut sampler_rng = rng.fork();
        let sampler = PeerSampler::new(cfg.sampler, n, cfg.delta, &mut sampler_rng);

        let mut eval_rng = rng.fork();
        let eval_peers = eval_rng.sample_indices(n, cfg.eval.n_peers.min(n));

        let d = data.d();
        let churn_online: Vec<bool> = (0..n_univ)
            .map(|i| churn.as_ref().map_or(true, |ch| ch.is_online(i, 0)))
            .collect();
        let online = churn_online.clone();

        let mut caches: Vec<Option<ModelCache>> = vec![None; n_univ];
        if cfg.eval.voting {
            for &p in &eval_peers {
                // INITMODEL (Algorithm 3): seeded cache at evaluation peers.
                let mut c = ModelCache::new(cfg.cache_size);
                c.add(LinearModel::zeros(d));
                caches[p] = Some(c);
            }
        }

        // Auto dispatch only picks the sparse layout when the backend has
        // true O(nnz) kernels — a densifying backend (PJRT) would pay CSR
        // staging plus a densify pass for nothing.  Forcing `--exec sparse`
        // still stages sparse on any backend (the densify fallback keeps it
        // correct).
        let sparse = match cfg.path {
            ExecPath::Sparse => true,
            _ => backend.supports_sparse() && cfg.path.use_sparse(&data.train),
        };
        let staged = if sparse {
            match &data.train {
                Examples::Sparse(csr) => Staged::Csr(csr),
                Examples::Dense(_) => Staged::CsrOwned(data.train.to_csr()),
            }
        } else {
            // stage the whole universe: flash-crowd joiners beyond the
            // initial membership already have their rows waiting
            let mut dense_x = vec![0.0f32; n_univ * d];
            for i in 0..n_univ {
                data.train.row(i).write_dense(&mut dense_x[i * d..(i + 1) * d]);
            }
            Staged::Dense(dense_x)
        };

        let op = StepOp::for_protocol(&cfg.learner, cfg.variant);

        GossipSim {
            network: Network::new(cfg.network),
            store: ModelStore::new(n, d),
            caches,
            last_restart: vec![0; n_univ],
            online,
            churn_online,
            forced_off: vec![false; n_univ],
            scn: compiled.map(ScenarioDriver::new),
            drift_sign: 1.0,
            flipped_test_y: None,
            queue: EventQueue::new(),
            sampler,
            churn,
            rng,
            eval_peers,
            stats: RunStats::default(),
            now: 0,
            backend,
            op,
            batch: StepBatch::default(),
            pending: Vec::new(),
            batch_start: 0,
            cfg,
            data,
            staged,
        }
    }

    /// Jittered per-iteration gossip period: N(Δ, Δ/10), clipped positive.
    fn next_period(&mut self) -> Ticks {
        let d = self.cfg.delta as f64;
        let p = self.rng.normal_scaled(d, d / 10.0);
        p.max(1.0) as Ticks
    }

    /// Run to completion, panicking on backend errors (the native backend is
    /// infallible; use [`GossipSim::try_run`] with fallible backends).
    pub fn run(self) -> RunResult {
        self.try_run().expect("backend error in event-driven run")
    }

    /// Run to completion, returning the convergence curve and stats.
    pub fn try_run(self) -> Result<RunResult> {
        self.try_run_observed(&mut NullObserver)
    }

    /// Run to completion, streaming typed progress events
    /// ([`crate::api::RunEvent`]) to `obs`: every gossip-cycle boundary the
    /// event stream crosses, every measured curve point, and every scenario
    /// mutation as it is applied.  Observation is passive — an observed run
    /// is bit-for-bit identical to an unobserved one.
    pub fn try_run_observed(mut self, obs: &mut dyn Observer) -> Result<RunResult> {
        let n = self.store.n();
        let horizon = self.cfg.delta * self.cfg.cycles;

        // synchronized start (Section IV): first tick after one period
        for node in 0..n {
            let p = self.next_period();
            self.queue.push(p, Event::GossipTick { node });
        }
        // churn transitions
        if let Some(ch) = &self.churn {
            for (t, node, up) in ch.events() {
                if t <= horizon {
                    self.queue.push(
                        t,
                        if up { Event::Join { node } } else { Event::Leave { node } },
                    );
                }
            }
        }
        // measurement probes at cycle boundaries
        let eval_cycles = if self.cfg.eval.at_cycles.is_empty() {
            eval::log_spaced_cycles(self.cfg.cycles)
        } else {
            self.cfg.eval.at_cycles.clone()
        };
        for &c in &eval_cycles {
            self.queue.push(c * self.cfg.delta, Event::Eval);
        }

        let mut curve = Curve::new(format!(
            "{}-{}-{}",
            self.cfg.learner.name(),
            self.cfg.variant.name(),
            self.cfg.sampler.name()
        ));

        let mut observed_cycle = 0u64;
        while let Some((t, ev)) = self.queue.pop() {
            if t > horizon {
                // deliveries due at or before the horizon still apply
                self.flush()?;
                break;
            }
            // cycle-boundary progress events: every integer boundary the
            // event stream crosses, emitted once, in order
            let cycle_now = t / self.cfg.delta;
            while observed_cycle < cycle_now {
                observed_cycle += 1;
                obs.on_event(&RunEvent::Cycle { cycle: observed_cycle });
            }
            // scenario mutations apply at tick boundaries, before any event
            // of that tick — with pending micro-batches flushed first, so
            // scalar and micro-batched execution observe mutations at
            // identical points (pinned in tests/engine_parity.rs)
            if self.scn.as_ref().map_or(false, |d| d.has_due(t)) {
                self.flush()?;
                self.apply_scenario(t, obs);
            }
            self.now = t;
            match ev {
                Event::Deliver { dst, msg } => {
                    if self.pending.is_empty() {
                        self.batch_start = t;
                    }
                    self.pending.push((dst, msg));
                    if self.should_flush() {
                        self.flush()?;
                    }
                }
                Event::GossipTick { node } => {
                    self.flush()?;
                    self.on_tick(node);
                }
                Event::Join { node } => {
                    self.flush()?;
                    self.churn_online[node] = true;
                    self.online[node] = !self.forced_off[node];
                }
                Event::Leave { node } => {
                    self.flush()?;
                    self.churn_online[node] = false;
                    self.online[node] = false;
                }
                Event::Eval => {
                    self.flush()?;
                    let cycle = (t / self.cfg.delta).max(1);
                    let pt = self.measure(cycle)?;
                    obs.on_event(&RunEvent::Eval { point: pt.clone() });
                    curve.push(pt);
                }
            }
        }
        self.flush()?;

        // single source of truth: the Network tracks actual deliveries
        self.stats.messages_delivered = self.network.delivered();
        Ok(RunResult { curve, stats: self.stats })
    }

    /// Apply every scenario mutation due at or before `now` (pending
    /// deliveries are already flushed).  Mutations touch the network models
    /// in place, toggle the drift sign, maintain the forced-offline overlay,
    /// and grow membership for flash crowds.
    fn apply_scenario(&mut self, now: Ticks, obs: &mut dyn Observer) {
        while let Some(m) = self.scn.as_mut().and_then(|d| d.pop_due(now)) {
            obs.on_event(&RunEvent::Scenario {
                cycle: now / self.cfg.delta,
                mutation: m.describe(),
            });
            match m {
                Mutation::SetDrop(p) => self.network.cfg.drop_prob = p,
                Mutation::SetDelay(model) => self.network.cfg.delay = model,
                Mutation::SetPartition(components) => {
                    self.network.set_partition(Some(components))
                }
                Mutation::Heal => self.network.set_partition(None),
                Mutation::Drift => self.drift_sign = -self.drift_sign,
                Mutation::ForceOffline(ids) => {
                    for i in ids {
                        self.forced_off[i] = true;
                        self.online[i] = false;
                    }
                }
                Mutation::Restore(ids) => {
                    for i in ids {
                        self.forced_off[i] = false;
                        self.online[i] = self.churn_online[i];
                    }
                }
                Mutation::Grow(k) => {
                    let old = self.store.n();
                    let newn = (old + k).min(self.data.n_train());
                    self.store.grow(newn - old);
                    self.sampler.grow(newn, &mut self.rng);
                    for node in old..newn {
                        // arrivals adopt the universe-wide churn state and
                        // enter the active loop on a fresh jittered period
                        self.online[node] = self.churn_online[node] && !self.forced_off[node];
                        let p = self.next_period();
                        self.queue.push(now + p, Event::GossipTick { node });
                    }
                }
            }
        }
    }

    /// Keep accumulating while the next event is another delivery at the same
    /// (possibly window-quantized) timestamp — any other event must observe
    /// fully applied state, so it forces a flush first.
    fn should_flush(&self) -> bool {
        match self.cfg.exec {
            ExecMode::Scalar => true,
            ExecMode::MicroBatch { .. } => match self.queue.peek() {
                Some((t, Event::Deliver { .. })) => t != self.batch_start,
                _ => true,
            },
        }
    }

    /// Quantize a delivery time up to the coalescing-window boundary.
    fn arrival_time(&self, at: Ticks) -> Ticks {
        match self.cfg.exec {
            ExecMode::MicroBatch { coalesce } if coalesce > 0 => {
                ((at + coalesce - 1) / coalesce) * coalesce
            }
            _ => at,
        }
    }

    /// Apply the pending deliveries: FIFO ordering, offline losses, NEWSCAST
    /// view merges, then all CREATEMODEL steps as engine micro-batches.
    ///
    /// Rows are independent even when one node receives several messages in a
    /// flush: message k's `m2` input is message k-1's *weights* (Algorithm 1
    /// line 9 assigns `lastModel <- m`, not the created model), which is known
    /// before any stepping.  Per-node chaining is wired through `prev_in_flush`.
    fn flush(&mut self) -> Result<()> {
        if self.pending.is_empty() {
            return Ok(());
        }
        let d = self.store.d();
        let pending = std::mem::take(&mut self.pending);
        let mut live: Vec<(NodeId, ModelMsg)> = Vec::with_capacity(pending.len());
        for (dst, msg) in pending {
            if !self.online[dst] {
                self.network.note_lost_offline();
                self.stats.messages_lost_offline += 1;
                continue;
            }
            self.sampler.on_receive(dst, &msg.view);
            self.network.note_delivered();
            live.push((dst, msg));
        }
        let per_msg_updates: u64 = match self.cfg.variant {
            Variant::Um => 2,
            _ => 1,
        };
        let sparse = !matches!(self.staged, Staged::Dense(_));
        let mut prev_in_flush: HashMap<NodeId, usize> = HashMap::new();
        let mut start = 0;
        while start < live.len() {
            let end = (start + MAX_BATCH_ROWS).min(live.len());
            let b = end - start;
            self.batch.resize_for(b, d, sparse);
            for (row, (dst, msg)) in live[start..end].iter().enumerate() {
                let dst = *dst;
                let r = row * d..(row + 1) * d;
                self.batch.w1[r.clone()].copy_from_slice(&msg.w);
                self.batch.s1[row] = msg.scale;
                self.batch.t1[row] = msg.t as f32;
                match prev_in_flush.insert(dst, start + row) {
                    Some(prev) => {
                        let pm = &live[prev].1;
                        self.batch.w2[r.clone()].copy_from_slice(&pm.w);
                        self.batch.s2[row] = pm.scale;
                        self.batch.t2[row] = pm.t as f32;
                    }
                    None => {
                        self.batch.w2[r.clone()].copy_from_slice(self.store.last(dst));
                        self.batch.s2[row] = self.store.last_scale(dst);
                        self.batch.t2[row] = self.store.last_t(dst);
                    }
                }
                match &self.staged {
                    Staged::Dense(dx) => {
                        self.batch.x[r].copy_from_slice(&dx[dst * d..(dst + 1) * d]);
                    }
                    s => {
                        let (idx, val) = s.csr().row(dst);
                        self.batch.push_sparse_x_row(idx, val);
                    }
                }
                // concept drift re-labels: the sign flips with the scenario
                self.batch.y[row] = self.drift_sign * self.data.train_y[dst];
            }
            self.backend.step(&self.op, &mut self.batch)?;
            self.stats.engine_calls += 1;
            self.stats.updates_applied += per_msg_updates * b as u64;
            if sparse {
                self.stats.sparse_rows += b as u64;
            }
            for (row, (dst, msg)) in live[start..end].iter().enumerate() {
                let dst = *dst;
                let r = row * d..(row + 1) * d;
                // sparse results land in place in w1 (scale in out_s); dense
                // results in out_w — see the Backend::step contract
                let (out, out_s) = if sparse {
                    (&self.batch.w1[r], self.batch.out_s[row])
                } else {
                    (&self.batch.out_w[r], 1.0)
                };
                let out_t = self.batch.out_t[row];
                if let Some(cache) = &mut self.caches[dst] {
                    let mut w = out.to_vec();
                    if out_s != 1.0 {
                        for v in &mut w {
                            *v *= out_s;
                        }
                    }
                    cache.add(LinearModel::from_weights(w, out_t as u64));
                }
                self.store.set_freshest_scaled(dst, out, out_s, out_t);
                // lastModel <- incoming (Algorithm 1 line 9)
                self.store.set_last_scaled(dst, &msg.w, msg.scale, msg.t as f32);
            }
            start = end;
        }
        Ok(())
    }

    /// Active loop body (Algorithm 1 lines 3-5).
    fn on_tick(&mut self, node: NodeId) {
        // always schedule the next iteration (the loop runs forever; an
        // offline node simply skips the send)
        let p = self.next_period();
        self.queue.push(self.now + p, Event::GossipTick { node });

        if !self.online[node] {
            return;
        }
        // scheduled model restart (drifting-concept support, DESIGN.md §8)
        if let Some(k) = self.cfg.restart_every {
            let cycle = self.now / self.cfg.delta;
            if k > 0 && cycle > 0 && cycle % k == 0 && self.last_restart[node] != cycle {
                self.last_restart[node] = cycle;
                self.store.reset(node);
                if let Some(c) = &mut self.caches[node] {
                    *c = ModelCache::new(self.cfg.cache_size);
                    c.add(LinearModel::zeros(self.data.d()));
                }
            }
        }
        let Some(dst) = self.sampler.select(node, self.now, &self.online, &mut self.rng) else {
            return;
        };

        let msg = ModelMsg {
            src: node,
            w: self.store.freshest(node).to_vec(),
            scale: self.store.freshest_scale(node),
            t: self.store.freshest_t(node) as u64,
            view: self.sampler.payload(node, self.now),
        };
        self.stats.messages_sent += 1;
        self.stats.bytes_sent += msg.wire_bytes() as u64;
        match self.network.transmit_between(node, dst, &mut self.rng) {
            Fate::Deliver(delay) => {
                let at = self.arrival_time(self.now + delay);
                self.queue.push(at, Event::Deliver { dst, msg });
            }
            Fate::Dropped => self.stats.messages_dropped += 1,
            Fate::Blocked => self.stats.messages_blocked += 1,
        }
    }

    /// Measure the error curve point at `cycle` over the evaluation peers.
    ///
    /// The freshest-model sweep runs as chunked engine passes through the
    /// backend's sparse-aware [`Backend::error_counts_examples`] (shared
    /// with the cycle-synchronous driver via `engine::eval_peer_errors`):
    /// `[m, d]` batches of materialized peer models against the whole test
    /// set, O(nnz) per (row, model) pair on sparse test sets.  Counts are
    /// exact small integers, so on the native backend this is
    /// value-identical to the scalar per-peer `zero_one_error` loop it
    /// replaces; PJRT artifacts compiled before the sign(0) = -1 fix in
    /// python/compile/model.py differ on zero-margin negative rows until
    /// regenerated.
    fn measure(&mut self, cycle: u64) -> Result<eval::EvalPoint> {
        // under concept drift the *current* concept is what peers must
        // predict: evaluate against sign-flipped test labels (built lazily,
        // once) while the drift sign is negative
        if self.drift_sign < 0.0 && self.flipped_test_y.is_none() {
            self.flipped_test_y = Some(eval::flipped_labels(&self.data.test_y));
        }
        let test = &self.data.test;
        let y: &[f32] = if self.drift_sign < 0.0 {
            self.flipped_test_y.as_ref().unwrap()
        } else {
            &self.data.test_y
        };
        let errs =
            eval_peer_errors(&self.store, &self.eval_peers, &mut *self.backend, test, y)?;
        let vote_errs: Option<Vec<f64>> = self.cfg.eval.voting.then(|| {
            self.eval_peers
                .iter()
                .filter_map(|&p| self.caches[p].as_ref())
                .map(|c| eval::cache_error(c, Predictor::MajorityVote, test, y))
                .collect()
        });
        let similarity = self.cfg.eval.similarity.then(|| {
            let models: Vec<LinearModel> =
                self.eval_peers.iter().map(|&p| self.store.freshest_model(p)).collect();
            let refs: Vec<&LinearModel> = models.iter().collect();
            eval::mean_pairwise_cosine(&refs)
        });
        Ok(point_from_errors(
            cycle,
            &errs,
            vote_errs.as_deref(),
            similarity,
            self.stats.messages_sent,
        ))
    }
}

/// Convenience: run one configuration against a dataset on the native
/// backend.
#[deprecated(
    since = "0.2.0",
    note = "construct runs through api::RunSpec / api::Session (kept as a \
            thin shim so engine-parity pins stay bit-for-bit)"
)]
pub fn run(cfg: ProtocolConfig, data: &Dataset) -> RunResult {
    GossipSim::new(cfg, data).run()
}

/// Run the event-driven simulator on an explicit backend (e.g. PJRT), with
/// backend errors surfaced.
#[deprecated(
    since = "0.2.0",
    note = "construct runs through api::RunSpec / api::Session (kept as a \
            thin shim so engine-parity pins stay bit-for-bit)"
)]
pub fn run_with_backend(
    cfg: ProtocolConfig,
    data: &Dataset,
    backend: Box<dyn Backend>,
) -> Result<RunResult> {
    GossipSim::with_backend(cfg, data, backend).try_run()
}

#[cfg(test)]
#[allow(deprecated)] // the parity suite exercises the legacy shims directly
mod tests {
    use super::*;
    use crate::data::synthetic::{spambase_like, urls_like, Scale};

    fn quick_cfg(cycles: u64) -> ProtocolConfig {
        let mut cfg = ProtocolConfig::paper_default(cycles);
        cfg.eval.n_peers = 20;
        cfg
    }

    #[test]
    fn error_decreases_over_time() {
        let ds = urls_like(1, Scale(0.02)); // 200 nodes, d=10
        let cfg = quick_cfg(60);
        let res = run(cfg, &ds);
        let first = res.curve.points.first().unwrap().err_mean;
        let last = res.curve.final_error();
        assert!(last < first, "error should fall: {first} -> {last}");
        assert!(last < 0.25, "final error too high: {last}");
    }

    #[test]
    fn message_complexity_one_per_node_per_cycle() {
        let ds = spambase_like(2, Scale(0.03)); // ~124 nodes
        let cfg = quick_cfg(20);
        let n = ds.n_train() as f64;
        let res = run(cfg, &ds);
        let per_node_cycle = res.stats.messages_sent as f64 / (n * 20.0);
        assert!(
            (per_node_cycle - 1.0).abs() < 0.1,
            "messages per node-cycle = {per_node_cycle}"
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let ds = spambase_like(3, Scale(0.02));
        let a = run(quick_cfg(10), &ds);
        let b = run(quick_cfg(10), &ds);
        let ea: Vec<f64> = a.curve.points.iter().map(|p| p.err_mean).collect();
        let eb: Vec<f64> = b.curve.points.iter().map(|p| p.err_mean).collect();
        assert_eq!(ea, eb);
        assert_eq!(a.stats.messages_sent, b.stats.messages_sent);
    }

    #[test]
    fn extreme_failures_still_converge() {
        let ds = urls_like(4, Scale(0.02));
        let cfg = quick_cfg(100).with_extreme_failures();
        let res = run(cfg, &ds);
        assert!(res.stats.messages_dropped > 0);
        let first = res.curve.points.first().unwrap().err_mean;
        let last = res.curve.final_error();
        assert!(last < first, "error should still fall: {first} -> {last}");
    }

    #[test]
    fn voting_and_similarity_fields_populated() {
        let ds = spambase_like(5, Scale(0.02));
        let mut cfg = quick_cfg(8);
        cfg.eval.voting = true;
        cfg.eval.similarity = true;
        let res = run(cfg, &ds);
        let p = res.curve.points.last().unwrap();
        assert!(p.err_vote.is_some());
        let s = p.similarity.unwrap();
        assert!((-1.0..=1.0).contains(&s));
    }

    #[test]
    fn restart_schedule_resets_models() {
        let ds = urls_like(7, Scale(0.02));
        let mut cfg = quick_cfg(30);
        cfg.restart_every = Some(10);
        cfg.eval.at_cycles = (1..=30).collect();
        let with_restart = run(cfg.clone(), &ds);
        cfg.restart_every = None;
        let without = run(cfg, &ds);
        // shortly after a restart the error must jump back toward the
        // zero-model level while the non-restarting run stays converged
        let err_at = |r: &RunResult, c: u64| {
            r.curve.points.iter().find(|p| p.cycle == c).unwrap().err_mean
        };
        assert!(
            err_at(&with_restart, 11) > err_at(&without, 11) + 0.05,
            "restart {} vs none {}",
            err_at(&with_restart, 11),
            err_at(&without, 11)
        );
    }

    #[test]
    fn logreg_gossip_converges() {
        let ds = urls_like(8, Scale(0.02));
        let mut cfg = quick_cfg(50);
        cfg.learner = Learner::logreg(1e-2);
        let res = run(cfg, &ds);
        let first = res.curve.points.first().unwrap().err_mean;
        let last = res.curve.final_error();
        assert!(last < first && last < 0.25, "{first} -> {last}");
    }

    #[test]
    fn all_variants_run() {
        let ds = spambase_like(6, Scale(0.02));
        for v in [Variant::Rw, Variant::Mu, Variant::Um] {
            let mut cfg = quick_cfg(10);
            cfg.variant = v;
            let res = run(cfg, &ds);
            assert!(!res.curve.points.is_empty());
        }
    }

    #[test]
    fn scalar_mode_runs_and_converges() {
        let ds = urls_like(9, Scale(0.02));
        let mut cfg = quick_cfg(40);
        cfg.exec = ExecMode::Scalar;
        let res = run(cfg, &ds);
        let first = res.curve.points.first().unwrap().err_mean;
        assert!(res.curve.final_error() < first);
        // scalar mode = one engine call per applied delivery
        assert_eq!(
            res.stats.engine_calls, res.stats.updates_applied,
            "scalar mode must step one row at a time"
        );
    }

    #[test]
    fn auto_path_dispatches_by_density() {
        use crate::data::synthetic::reuters_like;
        // Reuters: sparse storage, density ~0.6% -> sparse kernels
        let ds = reuters_like(11, Scale(0.02));
        let mut cfg = quick_cfg(4);
        cfg.eval.n_peers = 8;
        let res = run(cfg, &ds);
        assert!(res.stats.sparse_rows > 0, "reuters should take the sparse path");
        assert_eq!(res.stats.sparse_rows, res.stats.updates_applied);
        // URLs: dense d=10 storage -> dense kernels
        let ds = urls_like(11, Scale(0.02));
        let res = run(quick_cfg(4), &ds);
        assert_eq!(res.stats.sparse_rows, 0, "urls should take the dense path");
    }

    #[test]
    fn auto_dispatch_respects_backend_capability() {
        use crate::data::synthetic::reuters_like;
        /// Wraps the native backend but hides its sparse kernels — the shape
        /// of a compiled-dense backend (PJRT), testable without artifacts.
        struct DenseOnly(NativeBackend);
        impl Backend for DenseOnly {
            fn name(&self) -> &'static str {
                "dense-only"
            }
            fn step(&mut self, op: &StepOp, batch: &mut StepBatch) -> Result<()> {
                if batch.is_sparse_x() {
                    // the documented densify fallback + in-place copy-back
                    batch.densify();
                    self.0.step(op, batch)?;
                    let (b, d) = (batch.b, batch.d);
                    for i in 0..b {
                        let r = i * d..(i + 1) * d;
                        let (w1, out_w) = (&mut batch.w1, &batch.out_w);
                        w1[r.clone()].copy_from_slice(&out_w[r]);
                        batch.out_s[i] = 1.0;
                    }
                    return Ok(());
                }
                self.0.step(op, batch)
            }
            fn error_counts(
                &mut self,
                x: &[f32],
                y: &[f32],
                n: usize,
                d: usize,
                w: &[f32],
                m: usize,
            ) -> Result<Vec<f32>> {
                self.0.error_counts(x, y, n, d, w, m)
            }
        }
        let ds = reuters_like(15, Scale(0.02));
        let mut cfg = quick_cfg(3);
        cfg.eval.n_peers = 5;
        let res = run_with_backend(cfg.clone(), &ds, Box::new(DenseOnly(NativeBackend::new())))
            .unwrap();
        assert_eq!(
            res.stats.sparse_rows, 0,
            "auto must resolve dense on a backend without sparse kernels"
        );
        // forcing sparse still runs correctly through the densify fallback
        // (and the default chunk-densifying error_counts_examples)
        cfg.path = ExecPath::Sparse;
        let forced = run_with_backend(cfg, &ds, Box::new(DenseOnly(NativeBackend::new())))
            .unwrap();
        assert!(forced.stats.sparse_rows > 0);
        assert!(!forced.curve.points.is_empty());
    }

    #[test]
    fn forced_sparse_path_on_dense_data_still_converges() {
        let ds = urls_like(13, Scale(0.02));
        let mut cfg = quick_cfg(60);
        cfg.path = ExecPath::Sparse;
        let res = run(cfg, &ds);
        assert!(res.stats.sparse_rows > 0);
        let first = res.curve.points.first().unwrap().err_mean;
        let last = res.curve.final_error();
        assert!(last < first && last < 0.25, "{first} -> {last}");
    }

    #[test]
    fn sparse_path_supports_voting_similarity_and_restarts() {
        use crate::data::synthetic::reuters_like;
        let ds = reuters_like(14, Scale(0.02));
        let mut cfg = quick_cfg(10);
        cfg.eval.n_peers = 8;
        cfg.eval.voting = true;
        cfg.eval.similarity = true;
        cfg.restart_every = Some(5);
        let res = run(cfg, &ds);
        assert!(res.stats.sparse_rows > 0);
        let p = res.curve.points.last().unwrap();
        assert!(p.err_vote.is_some());
        assert!((-1.0..=1.0).contains(&p.similarity.unwrap()));
    }

    #[test]
    fn exec_path_resolution_rules() {
        use crate::data::matrix::Matrix;
        use crate::data::sparse::Csr;
        let mut csr = Csr::new(100);
        csr.push_row(&[(3, 1.0)]); // density 0.01
        let sparse_ex = Examples::Sparse(csr);
        let dense_ex = Examples::Dense(Matrix::from_vec(1, 4, vec![1.0; 4]));
        assert!(ExecPath::Auto.use_sparse(&sparse_ex));
        assert!(!ExecPath::Auto.use_sparse(&dense_ex));
        assert!(!ExecPath::Dense.use_sparse(&sparse_ex));
        assert!(ExecPath::Sparse.use_sparse(&dense_ex));
        // a dense-ish sparse matrix stays on the dense kernels under Auto
        let mut thick = Csr::new(4);
        thick.push_row(&[(0, 1.0), (1, 1.0), (2, 1.0)]); // density 0.75
        assert!(!ExecPath::Auto.use_sparse(&Examples::Sparse(thick)));
        assert_eq!(ExecPath::parse("sparse"), Some(ExecPath::Sparse));
        assert_eq!(ExecPath::parse("bogus"), None);
        assert_eq!(ExecPath::default(), ExecPath::Auto);
    }

    #[test]
    fn microbatching_reduces_engine_calls() {
        let ds = urls_like(10, Scale(0.03));
        let mut cfg = quick_cfg(30);
        cfg.exec = ExecMode::MicroBatch { coalesce: cfg.delta / 4 };
        let res = run(cfg, &ds);
        assert!(
            res.stats.engine_calls < res.stats.updates_applied,
            "coalesced micro-batching should batch multiple rows per call: {} calls for {} updates",
            res.stats.engine_calls,
            res.stats.updates_applied
        );
        let first = res.curve.points.first().unwrap().err_mean;
        assert!(res.curve.final_error() < first);
    }
}
