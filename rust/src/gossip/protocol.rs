//! The gossip learning protocol (Algorithm 1) running over the
//! discrete-event simulator: the paper's core system.
//!
//! Every node runs the same loop: wait(Δ) (with Δ jittered per-iteration as
//! N(Δ, Δ/10), Section IV), SELECTPEER, send the freshest cached model; on
//! receive, CREATEMODEL combines the incoming model with the previously
//! received one and the node's single local example, refreshing the cache.
//! No synchrony and no reliability is assumed: messages can be dropped,
//! delayed far beyond Δ, and nodes churn with state retention.
//!
//! Execution (DESIGN.md §2, §13): per-node state lives in structure-of-array
//! stores; `Deliver` events are drained into engine micro-batches, so the
//! faithful event-driven semantics (jitter, arbitrary delay, churn,
//! deterministic event ordering) run on the same vectorized kernels as the
//! cycle-synchronous driver.  [`ExecMode::Scalar`] keeps
//! one-delivery-at-a-time stepping as a debug/parity mode; the two modes are
//! pinned bit-for-bit against each other in tests/engine_parity.rs.
//!
//! Orthogonally, [`ExecPath`] picks the kernel family per run (DESIGN.md §7):
//! dense `[b, d]` rows, or — for sparse high-dimensional datasets like
//! Reuters — CSR-staged batches through the O(nnz) lazy-scale kernels, with
//! evaluation batched through the same sparse-aware backend.
//!
//! This module owns the run *configuration* and result types; the engine
//! itself lives in [`crate::gossip::sharded`], which partitions the node
//! universe into `shards` row ranges and runs them on leased worker
//! threads.  `shards = 1` is the default single-runner path; any shard
//! count yields bit-for-bit identical results (tests/engine_parity.rs).

use crate::api::{NullObserver, Observer};
use crate::data::dataset::{Dataset, Examples};
use crate::engine::native::NativeBackend;
use crate::engine::Backend;
use crate::eval::tracker::Curve;
use crate::gossip::create_model::Variant;
use crate::gossip::sharded;
use crate::learning::adaline::Learner;
use crate::learning::pairwise::MergeMode;
use crate::p2p::overlay::SamplerConfig;
use crate::p2p::topology::{TopologyMetrics, TopologySpec};
use crate::scenario::Scenario;
use crate::sim::churn::ChurnConfig;
use crate::sim::event::Ticks;
use crate::sim::network::NetworkConfig;
use anyhow::Result;

/// Evaluation settings (Section VI-A(h): misclassification ratio over the
/// test set, measured at 100 randomly selected peers).
#[derive(Clone, Debug)]
pub struct EvalConfig {
    pub n_peers: usize,
    /// measure the Algorithm-4 voting predictor too (needs caches at the
    /// sampled peers)
    pub voting: bool,
    /// measure mean pairwise cosine similarity of sampled models
    pub similarity: bool,
    /// measure test-set AUC (Mann-Whitney) of the sampled peers' models —
    /// auto-enabled by the configuration layer for the pairwise ranking
    /// objective (DESIGN.md §17)
    pub auc: bool,
    /// cycles at which to measure; empty = log-spaced over the run
    pub at_cycles: Vec<u64>,
}

impl Default for EvalConfig {
    fn default() -> Self {
        EvalConfig {
            n_peers: 100,
            voting: false,
            similarity: false,
            auc: false,
            at_cycles: Vec::new(),
        }
    }
}

/// How the event-driven simulator executes CREATEMODEL steps.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    /// One backend call per delivery — the reference semantics, kept as a
    /// debug/parity mode.
    Scalar,
    /// Drain all `Deliver` events sharing a timestamp into one batched engine
    /// call.  With `coalesce > 0`, delivery times are additionally quantized
    /// up to the next multiple of `coalesce` ticks, so deliveries landing
    /// within the same window share a timestamp and batch together (a bounded
    /// timing approximation, off by default; DESIGN.md §2).
    MicroBatch { coalesce: Ticks },
}

impl Default for ExecMode {
    fn default() -> Self {
        ExecMode::MicroBatch { coalesce: 0 }
    }
}

impl ExecMode {
    pub fn name(&self) -> &'static str {
        match self {
            ExecMode::Scalar => "scalar",
            ExecMode::MicroBatch { .. } => "microbatch",
        }
    }
}

/// Which kernel family executes CREATEMODEL steps and evaluation
/// (DESIGN.md §7) — orthogonal to [`ExecMode`], which decides how deliveries
/// batch.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum ExecPath {
    /// Density-based dispatch: sparsely stored training sets whose density
    /// nnz/(n·d) is at most [`ExecPath::AUTO_DENSITY_MAX`] take the sparse
    /// path; everything else runs dense.
    #[default]
    Auto,
    /// Dense `[b, d]` row kernels (O(d) per update).
    Dense,
    /// CSR-staged batches through the O(nnz) lazy-scale kernels.  Forcing
    /// this on a densely stored dataset copies it to CSR at construction.
    Sparse,
}

impl ExecPath {
    /// Above this training-set density the sparse path stops paying for
    /// itself (per-update work ~ merge O(d) + O(nnz) vs. the dense kernels'
    /// ~3 O(d) passes) and `Auto` dispatches dense.
    pub const AUTO_DENSITY_MAX: f64 = 0.25;

    pub fn parse(s: &str) -> Option<ExecPath> {
        match s {
            "auto" => Some(ExecPath::Auto),
            "dense" => Some(ExecPath::Dense),
            "sparse" => Some(ExecPath::Sparse),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ExecPath::Auto => "auto",
            ExecPath::Dense => "dense",
            ExecPath::Sparse => "sparse",
        }
    }

    /// Resolve the dispatch against a training set.
    pub fn use_sparse(&self, train: &Examples) -> bool {
        match self {
            ExecPath::Dense => false,
            ExecPath::Sparse => true,
            ExecPath::Auto => {
                matches!(train, Examples::Sparse(_))
                    && train.density() <= Self::AUTO_DENSITY_MAX
            }
        }
    }
}

#[derive(Clone, Debug)]
pub struct ProtocolConfig {
    pub variant: Variant,
    pub learner: Learner,
    /// MERGE semantics for MU/UM: coordinate-wise averaging (Algorithm 3,
    /// the paper's choice) or the quorum vote that zeroes sign-disagreeing
    /// coordinates (DESIGN.md §17).  RW never merges, so it is unaffected.
    pub merge: MergeMode,
    /// example-reservoir capacity K riding with each walking model — only
    /// consulted when `learner` is the pairwise AUC objective; pointwise
    /// learners allocate no reservoirs
    pub reservoir: usize,
    /// model cache capacity (paper: 10)
    pub cache_size: usize,
    /// gossip period Δ in ticks
    pub delta: Ticks,
    /// run length in cycles (wall time = cycles * Δ)
    pub cycles: u64,
    pub sampler: SamplerConfig,
    /// graph constraint on who can gossip with whom (DESIGN.md §16).
    /// `None` is the implicit complete graph — every pair may interact,
    /// the paper's setting.  With a spec set, SELECTPEER draws only
    /// topology neighbors (NEWSCAST views are constrained to them) and
    /// scenarios may fail individual edges.  The graph is built
    /// deterministically from `(spec, n, seed)` by every execution path.
    pub topology: Option<TopologySpec>,
    pub network: NetworkConfig,
    pub churn: Option<ChurnConfig>,
    pub eval: EvalConfig,
    pub seed: u64,
    /// Restart schedule (Section IV mentions randomly restarted loops as the
    /// mechanism for following drifting concepts — beyond-paper extension):
    /// every `k` cycles a node resets its models to the initial state.
    pub restart_every: Option<u64>,
    /// CREATEMODEL execution strategy (micro-batched by default).
    pub exec: ExecMode,
    /// dense vs. O(nnz) sparse kernels (density-dispatched by default).
    pub path: ExecPath,
    /// declarative failure/workload timeline (DESIGN.md §11).  Must be
    /// validated against (n, cycles) by the configuration layer; the
    /// simulators compile it into tick-indexed mutations at construction.
    pub scenario: Option<Scenario>,
    /// how many contiguous node-range shards execute the run (DESIGN.md
    /// §13).  1 = single runner on the caller's backend; ≥ 2 leases worker
    /// threads from [`crate::util::threads`] and requires the native
    /// backend.  Results are bit-for-bit independent of the shard count.
    pub shards: usize,
    /// recycle message weight buffers through per-shard pools (DESIGN.md
    /// §14).  Purely an allocator-level change: pooled and unpooled runs are
    /// bit-for-bit identical (tests/engine_parity.rs); off = every send
    /// allocates fresh, the pre-pool behavior kept for leak triage.
    pub pool: bool,
}

impl ProtocolConfig {
    /// Paper defaults: MU variant, Pegasos(λ=1e-2, calibrated on the
    /// synthetic Table-I sets — the paper does not report its λ), cache 10,
    /// NEWSCAST(20), reliable network, no churn.
    pub fn paper_default(cycles: u64) -> Self {
        ProtocolConfig {
            variant: Variant::Mu,
            learner: Learner::pegasos(1e-2),
            merge: MergeMode::Average,
            reservoir: crate::learning::pairwise::DEFAULT_CAPACITY,
            cache_size: 10,
            delta: 1000,
            cycles,
            sampler: SamplerConfig::Newscast { view_size: 20 },
            topology: None,
            network: NetworkConfig::reliable(),
            churn: None,
            eval: EvalConfig::default(),
            seed: 42,
            restart_every: None,
            exec: ExecMode::default(),
            path: ExecPath::default(),
            scenario: None,
            shards: 1,
            pool: true,
        }
    }

    /// Section VI-A(i) "all failures": 50% drop, [Δ,10Δ] delay, churn @ 90%.
    pub fn with_extreme_failures(mut self) -> Self {
        self.network = NetworkConfig::extreme(self.delta);
        self.churn = Some(ChurnConfig::paper_default(self.delta));
        self
    }
}

/// Counters for the paper's cost model (one message per node per Δ).
#[derive(Clone, Debug, Default)]
pub struct RunStats {
    pub messages_sent: u64,
    pub messages_dropped: u64,
    /// sends blocked by an active scenario partition (cross-component)
    pub messages_blocked: u64,
    pub messages_lost_offline: u64,
    /// messages actually applied at a receiver; `sent - dropped -
    /// lost_offline - delivered` is the in-flight count at the horizon
    pub messages_delivered: u64,
    pub bytes_sent: u64,
    pub updates_applied: u64,
    /// engine calls made by the micro-batched path (batching effectiveness =
    /// updates_applied / engine_calls)
    pub engine_calls: u64,
    /// batch rows staged through the sparse (CSR) layout — the O(nnz)
    /// kernels on a sparse-capable backend, the densify fallback otherwise;
    /// 0 on the dense path.  Exposes which way the dispatch resolved.
    pub sparse_rows: u64,
    /// message weight buffers served from a shard's recycle pool.  Unlike
    /// the counters above, the hit/miss split is NOT independent of the
    /// shard count (recycling happens per shard) — only `hits + misses`
    /// (= buffers requested) is; parity tests must not compare these.
    pub pool_hits: u64,
    /// message weight buffers that had to be freshly allocated (pool empty,
    /// or pooling disabled).
    pub pool_misses: u64,
    /// structural metrics of the run's graph topology (DESIGN.md §16);
    /// `None` on the implicit complete graph.
    pub topology: Option<TopologyMetrics>,
}

/// Result of one simulated run.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub curve: Curve,
    pub stats: RunStats,
}

/// The event-driven simulator, configured and ready to run.
///
/// This is a thin handle: all execution lives in the sharded engine
/// ([`sharded::run_sharded`]), which `try_run_observed` delegates to.  With
/// the default `shards = 1` a single full-range runner executes inline on
/// the configured backend; higher shard counts partition the node universe
/// and lease worker threads, with bit-for-bit identical results.
pub struct GossipSim<'a> {
    cfg: ProtocolConfig,
    data: &'a Dataset,
    backend: Box<dyn Backend>,
}

impl<'a> GossipSim<'a> {
    pub fn new(cfg: ProtocolConfig, data: &'a Dataset) -> Self {
        Self::with_backend(cfg, data, Box::new(NativeBackend::new()))
    }

    /// Build the simulator on an explicit compute backend (native or PJRT).
    pub fn with_backend(cfg: ProtocolConfig, data: &'a Dataset, backend: Box<dyn Backend>) -> Self {
        // the node universe is one per training row; fail early here so
        // misconfigured callers hear about it at construction
        assert!(data.n_train() >= 2, "need at least two nodes");
        GossipSim { cfg, data, backend }
    }

    /// Run to completion, panicking on backend errors (the native backend is
    /// infallible; use [`GossipSim::try_run`] with fallible backends).
    pub fn run(self) -> RunResult {
        self.try_run().expect("backend error in event-driven run")
    }

    /// Run to completion, returning the convergence curve and stats.
    pub fn try_run(self) -> Result<RunResult> {
        self.try_run_observed(&mut NullObserver)
    }

    /// Run to completion, streaming typed progress events
    /// ([`crate::api::RunEvent`]) to `obs`: every gossip-cycle boundary, every
    /// measured curve point, and every scenario mutation as it is applied.
    /// Observation is passive — an observed run is bit-for-bit identical to
    /// an unobserved one.
    pub fn try_run_observed(self, obs: &mut dyn Observer) -> Result<RunResult> {
        sharded::run_sharded(self.cfg, self.data, self.backend, obs)
    }
}

/// Convenience: run one configuration against a dataset on the native
/// backend.
#[deprecated(
    since = "0.2.0",
    note = "construct runs through api::RunSpec / api::Session (kept as a \
            thin shim so engine-parity pins stay bit-for-bit)"
)]
pub fn run(cfg: ProtocolConfig, data: &Dataset) -> RunResult {
    GossipSim::new(cfg, data).run()
}

/// Run the event-driven simulator on an explicit backend (e.g. PJRT), with
/// backend errors surfaced.
#[deprecated(
    since = "0.2.0",
    note = "construct runs through api::RunSpec / api::Session (kept as a \
            thin shim so engine-parity pins stay bit-for-bit)"
)]
pub fn run_with_backend(
    cfg: ProtocolConfig,
    data: &Dataset,
    backend: Box<dyn Backend>,
) -> Result<RunResult> {
    GossipSim::with_backend(cfg, data, backend).try_run()
}

#[cfg(test)]
#[allow(deprecated)] // the parity suite exercises the legacy shims directly
mod tests {
    use super::*;
    use crate::engine::{StepBatch, StepOp};
    use crate::data::synthetic::{spambase_like, urls_like, Scale};

    fn quick_cfg(cycles: u64) -> ProtocolConfig {
        let mut cfg = ProtocolConfig::paper_default(cycles);
        cfg.eval.n_peers = 20;
        cfg
    }

    #[test]
    fn error_decreases_over_time() {
        let ds = urls_like(1, Scale(0.02)); // 200 nodes, d=10
        let cfg = quick_cfg(60);
        let res = run(cfg, &ds);
        let first = res.curve.points.first().unwrap().err_mean;
        let last = res.curve.final_error();
        assert!(last < first, "error should fall: {first} -> {last}");
        assert!(last < 0.25, "final error too high: {last}");
    }

    #[test]
    fn message_complexity_one_per_node_per_cycle() {
        let ds = spambase_like(2, Scale(0.03)); // ~124 nodes
        let cfg = quick_cfg(20);
        let n = ds.n_train() as f64;
        let res = run(cfg, &ds);
        let per_node_cycle = res.stats.messages_sent as f64 / (n * 20.0);
        assert!(
            (per_node_cycle - 1.0).abs() < 0.1,
            "messages per node-cycle = {per_node_cycle}"
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let ds = spambase_like(3, Scale(0.02));
        let a = run(quick_cfg(10), &ds);
        let b = run(quick_cfg(10), &ds);
        let ea: Vec<f64> = a.curve.points.iter().map(|p| p.err_mean).collect();
        let eb: Vec<f64> = b.curve.points.iter().map(|p| p.err_mean).collect();
        assert_eq!(ea, eb);
        assert_eq!(a.stats.messages_sent, b.stats.messages_sent);
    }

    #[test]
    fn extreme_failures_still_converge() {
        let ds = urls_like(4, Scale(0.02));
        let cfg = quick_cfg(100).with_extreme_failures();
        let res = run(cfg, &ds);
        assert!(res.stats.messages_dropped > 0);
        let first = res.curve.points.first().unwrap().err_mean;
        let last = res.curve.final_error();
        assert!(last < first, "error should still fall: {first} -> {last}");
    }

    #[test]
    fn voting_and_similarity_fields_populated() {
        let ds = spambase_like(5, Scale(0.02));
        let mut cfg = quick_cfg(8);
        cfg.eval.voting = true;
        cfg.eval.similarity = true;
        let res = run(cfg, &ds);
        let p = res.curve.points.last().unwrap();
        assert!(p.err_vote.is_some());
        let s = p.similarity.unwrap();
        assert!((-1.0..=1.0).contains(&s));
    }

    #[test]
    fn restart_schedule_resets_models() {
        let ds = urls_like(7, Scale(0.02));
        let mut cfg = quick_cfg(30);
        cfg.restart_every = Some(10);
        cfg.eval.at_cycles = (1..=30).collect();
        let with_restart = run(cfg.clone(), &ds);
        cfg.restart_every = None;
        let without = run(cfg, &ds);
        // shortly after a restart the error must jump back toward the
        // zero-model level while the non-restarting run stays converged
        let err_at = |r: &RunResult, c: u64| {
            r.curve.points.iter().find(|p| p.cycle == c).unwrap().err_mean
        };
        assert!(
            err_at(&with_restart, 11) > err_at(&without, 11) + 0.05,
            "restart {} vs none {}",
            err_at(&with_restart, 11),
            err_at(&without, 11)
        );
    }

    #[test]
    fn logreg_gossip_converges() {
        let ds = urls_like(8, Scale(0.02));
        let mut cfg = quick_cfg(50);
        cfg.learner = Learner::logreg(1e-2);
        let res = run(cfg, &ds);
        let first = res.curve.points.first().unwrap().err_mean;
        let last = res.curve.final_error();
        assert!(last < first && last < 0.25, "{first} -> {last}");
    }

    #[test]
    fn pairwise_auc_gossip_learns_to_rank() {
        let ds = urls_like(21, Scale(0.02));
        let mut cfg = quick_cfg(60);
        cfg.learner = Learner::pairwise_auc(1e-2);
        cfg.reservoir = 8;
        cfg.eval.auc = true;
        let res = run(cfg, &ds);
        let first = res.curve.points.first().unwrap();
        let last = res.curve.points.last().unwrap();
        let (a0, a1) = (first.auc.unwrap(), last.auc.unwrap());
        assert!(a1 > a0, "AUC should rise: {a0} -> {a1}");
        assert!(a1 > 0.7, "final AUC too low: {a1}");
        // reservoirs ride on the wire: frames are larger than pointwise ones
        assert!(res.stats.bytes_sent > 0);
    }

    #[test]
    fn quorum_merge_converges() {
        let ds = urls_like(22, Scale(0.02));
        let mut cfg = quick_cfg(60);
        cfg.merge = MergeMode::Quorum;
        let res = run(cfg, &ds);
        let first = res.curve.points.first().unwrap().err_mean;
        let last = res.curve.final_error();
        assert!(last < first, "{first} -> {last}");
        assert!(last < 0.3, "final {last}");
    }

    #[test]
    fn all_variants_run() {
        let ds = spambase_like(6, Scale(0.02));
        for v in [Variant::Rw, Variant::Mu, Variant::Um] {
            let mut cfg = quick_cfg(10);
            cfg.variant = v;
            let res = run(cfg, &ds);
            assert!(!res.curve.points.is_empty());
        }
    }

    #[test]
    fn scalar_mode_runs_and_converges() {
        let ds = urls_like(9, Scale(0.02));
        let mut cfg = quick_cfg(40);
        cfg.exec = ExecMode::Scalar;
        let res = run(cfg, &ds);
        let first = res.curve.points.first().unwrap().err_mean;
        assert!(res.curve.final_error() < first);
        // scalar mode = one engine call per applied delivery
        assert_eq!(
            res.stats.engine_calls, res.stats.updates_applied,
            "scalar mode must step one row at a time"
        );
    }

    #[test]
    fn auto_path_dispatches_by_density() {
        use crate::data::synthetic::reuters_like;
        // Reuters: sparse storage, density ~0.6% -> sparse kernels
        let ds = reuters_like(11, Scale(0.02));
        let mut cfg = quick_cfg(4);
        cfg.eval.n_peers = 8;
        let res = run(cfg, &ds);
        assert!(res.stats.sparse_rows > 0, "reuters should take the sparse path");
        assert_eq!(res.stats.sparse_rows, res.stats.updates_applied);
        // URLs: dense d=10 storage -> dense kernels
        let ds = urls_like(11, Scale(0.02));
        let res = run(quick_cfg(4), &ds);
        assert_eq!(res.stats.sparse_rows, 0, "urls should take the dense path");
    }

    #[test]
    fn auto_dispatch_respects_backend_capability() {
        use crate::data::synthetic::reuters_like;
        /// Wraps the native backend but hides its sparse kernels — the shape
        /// of a compiled-dense backend (PJRT), testable without artifacts.
        struct DenseOnly(NativeBackend);
        impl Backend for DenseOnly {
            fn name(&self) -> &'static str {
                "dense-only"
            }
            fn step(&mut self, op: &StepOp, batch: &mut StepBatch) -> Result<()> {
                if batch.is_sparse_x() {
                    // the documented densify fallback + in-place copy-back
                    batch.densify();
                    self.0.step(op, batch)?;
                    let (b, d) = (batch.b, batch.d);
                    for i in 0..b {
                        let r = i * d..(i + 1) * d;
                        let (w1, out_w) = (&mut batch.w1, &batch.out_w);
                        w1[r.clone()].copy_from_slice(&out_w[r]);
                        batch.out_s[i] = 1.0;
                    }
                    return Ok(());
                }
                self.0.step(op, batch)
            }
            fn error_counts(
                &mut self,
                x: &[f32],
                y: &[f32],
                n: usize,
                d: usize,
                w: &[f32],
                m: usize,
            ) -> Result<Vec<f32>> {
                self.0.error_counts(x, y, n, d, w, m)
            }
        }
        let ds = reuters_like(15, Scale(0.02));
        let mut cfg = quick_cfg(3);
        cfg.eval.n_peers = 5;
        let res = run_with_backend(cfg.clone(), &ds, Box::new(DenseOnly(NativeBackend::new())))
            .unwrap();
        assert_eq!(
            res.stats.sparse_rows, 0,
            "auto must resolve dense on a backend without sparse kernels"
        );
        // forcing sparse still runs correctly through the densify fallback
        // (and the default chunk-densifying error_counts_examples)
        cfg.path = ExecPath::Sparse;
        let forced = run_with_backend(cfg, &ds, Box::new(DenseOnly(NativeBackend::new())))
            .unwrap();
        assert!(forced.stats.sparse_rows > 0);
        assert!(!forced.curve.points.is_empty());
    }

    #[test]
    fn forced_sparse_path_on_dense_data_still_converges() {
        let ds = urls_like(13, Scale(0.02));
        let mut cfg = quick_cfg(60);
        cfg.path = ExecPath::Sparse;
        let res = run(cfg, &ds);
        assert!(res.stats.sparse_rows > 0);
        let first = res.curve.points.first().unwrap().err_mean;
        let last = res.curve.final_error();
        assert!(last < first && last < 0.25, "{first} -> {last}");
    }

    #[test]
    fn sparse_path_supports_voting_similarity_and_restarts() {
        use crate::data::synthetic::reuters_like;
        let ds = reuters_like(14, Scale(0.02));
        let mut cfg = quick_cfg(10);
        cfg.eval.n_peers = 8;
        cfg.eval.voting = true;
        cfg.eval.similarity = true;
        cfg.restart_every = Some(5);
        let res = run(cfg, &ds);
        assert!(res.stats.sparse_rows > 0);
        let p = res.curve.points.last().unwrap();
        assert!(p.err_vote.is_some());
        assert!((-1.0..=1.0).contains(&p.similarity.unwrap()));
    }

    #[test]
    fn exec_path_resolution_rules() {
        use crate::data::matrix::Matrix;
        use crate::data::sparse::Csr;
        let mut csr = Csr::new(100);
        csr.push_row(&[(3, 1.0)]); // density 0.01
        let sparse_ex = Examples::Sparse(csr);
        let dense_ex = Examples::Dense(Matrix::from_vec(1, 4, vec![1.0; 4]));
        assert!(ExecPath::Auto.use_sparse(&sparse_ex));
        assert!(!ExecPath::Auto.use_sparse(&dense_ex));
        assert!(!ExecPath::Dense.use_sparse(&sparse_ex));
        assert!(ExecPath::Sparse.use_sparse(&dense_ex));
        // a dense-ish sparse matrix stays on the dense kernels under Auto
        let mut thick = Csr::new(4);
        thick.push_row(&[(0, 1.0), (1, 1.0), (2, 1.0)]); // density 0.75
        assert!(!ExecPath::Auto.use_sparse(&Examples::Sparse(thick)));
        assert_eq!(ExecPath::parse("sparse"), Some(ExecPath::Sparse));
        assert_eq!(ExecPath::parse("bogus"), None);
        assert_eq!(ExecPath::default(), ExecPath::Auto);
    }

    #[test]
    fn microbatching_reduces_engine_calls() {
        let ds = urls_like(10, Scale(0.03));
        let mut cfg = quick_cfg(30);
        cfg.exec = ExecMode::MicroBatch { coalesce: cfg.delta / 4 };
        let res = run(cfg, &ds);
        assert!(
            res.stats.engine_calls < res.stats.updates_applied,
            "coalesced micro-batching should batch multiple rows per call: {} calls for {} updates",
            res.stats.engine_calls,
            res.stats.updates_applied
        );
        let first = res.curve.points.first().unwrap().err_mean;
        assert!(res.curve.final_error() < first);
    }
}
