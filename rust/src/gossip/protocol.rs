//! The gossip learning protocol (Algorithm 1) running over the
//! discrete-event simulator: the paper's core system.
//!
//! Every node runs the same loop: wait(Δ) (with Δ jittered per-iteration as
//! N(Δ, Δ/10), Section IV), SELECTPEER, send the freshest cached model; on
//! receive, CREATEMODEL combines the incoming model with the previously
//! received one and the node's single local example, refreshing the cache.
//! No synchrony and no reliability is assumed: messages can be dropped,
//! delayed far beyond Δ, and nodes churn with state retention.

use crate::data::dataset::Dataset;
use crate::eval::{self, tracker::{point_from_errors, Curve}};
use crate::gossip::cache::ModelCache;
use crate::gossip::create_model::{create_model_step, Variant};
use crate::gossip::message::ModelMsg;
use crate::gossip::predict::Predictor;
use crate::learning::adaline::Learner;
use crate::learning::linear::LinearModel;
use crate::p2p::overlay::{PeerSampler, SamplerConfig};
use crate::sim::churn::{ChurnConfig, ChurnSchedule};
use crate::sim::event::{Event, EventQueue, NodeId, Ticks};
use crate::sim::network::{Network, NetworkConfig};
use crate::util::rng::Rng;

/// Evaluation settings (Section VI-A(h): misclassification ratio over the
/// test set, measured at 100 randomly selected peers).
#[derive(Clone, Debug)]
pub struct EvalConfig {
    pub n_peers: usize,
    /// measure the Algorithm-4 voting predictor too (needs caches at the
    /// sampled peers)
    pub voting: bool,
    /// measure mean pairwise cosine similarity of sampled models
    pub similarity: bool,
    /// cycles at which to measure; empty = log-spaced over the run
    pub at_cycles: Vec<u64>,
}

impl Default for EvalConfig {
    fn default() -> Self {
        EvalConfig { n_peers: 100, voting: false, similarity: false, at_cycles: Vec::new() }
    }
}

#[derive(Clone, Debug)]
pub struct ProtocolConfig {
    pub variant: Variant,
    pub learner: Learner,
    /// model cache capacity (paper: 10)
    pub cache_size: usize,
    /// gossip period Δ in ticks
    pub delta: Ticks,
    /// run length in cycles (wall time = cycles * Δ)
    pub cycles: u64,
    pub sampler: SamplerConfig,
    pub network: NetworkConfig,
    pub churn: Option<ChurnConfig>,
    pub eval: EvalConfig,
    pub seed: u64,
    /// Restart schedule (Section IV mentions randomly restarted loops as the
    /// mechanism for following drifting concepts — beyond-paper extension):
    /// every `k` cycles a node resets its models to the initial state.
    pub restart_every: Option<u64>,
}

impl ProtocolConfig {
    /// Paper defaults: MU variant, Pegasos(λ=1e-2, calibrated on the
    /// synthetic Table-I sets — the paper does not report its λ), cache 10,
    /// NEWSCAST(20), reliable network, no churn.
    pub fn paper_default(cycles: u64) -> Self {
        ProtocolConfig {
            variant: Variant::Mu,
            learner: Learner::pegasos(1e-2),
            cache_size: 10,
            delta: 1000,
            cycles,
            sampler: SamplerConfig::Newscast { view_size: 20 },
            network: NetworkConfig::reliable(),
            churn: None,
            eval: EvalConfig::default(),
            seed: 42,
            restart_every: None,
        }
    }

    /// Section VI-A(i) "all failures": 50% drop, [Δ,10Δ] delay, churn @ 90%.
    pub fn with_extreme_failures(mut self) -> Self {
        self.network = NetworkConfig::extreme(self.delta);
        self.churn = Some(ChurnConfig::paper_default(self.delta));
        self
    }
}

/// Per-node protocol state. `freshest` mirrors cache.freshest() and is kept
/// for every node; the full cache is materialized only at evaluation peers
/// unless voting for all is requested (memory: Reuters models are 40 KB
/// each — 10-deep caches at all 2000 nodes would be ~800 MB).
struct Node {
    online: bool,
    last_recv: LinearModel,
    freshest: LinearModel,
    cache: Option<ModelCache>,
    /// last cycle at which this node executed a scheduled restart
    last_restart: u64,
}

/// Counters for the paper's cost model (one message per node per Δ).
#[derive(Clone, Debug, Default)]
pub struct RunStats {
    pub messages_sent: u64,
    pub messages_dropped: u64,
    pub messages_lost_offline: u64,
    pub bytes_sent: u64,
    pub updates_applied: u64,
}

/// Result of one simulated run.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub curve: Curve,
    pub stats: RunStats,
}

pub struct GossipSim<'a> {
    cfg: ProtocolConfig,
    data: &'a Dataset,
    nodes: Vec<Node>,
    queue: EventQueue,
    network: Network,
    sampler: PeerSampler,
    churn: Option<ChurnSchedule>,
    rng: Rng,
    eval_peers: Vec<NodeId>,
    online_flags: Vec<bool>,
    stats: RunStats,
    now: Ticks,
}

impl<'a> GossipSim<'a> {
    pub fn new(cfg: ProtocolConfig, data: &'a Dataset) -> Self {
        let n = data.n_train();
        assert!(n >= 2, "need at least two nodes");
        let mut rng = Rng::new(cfg.seed);
        let horizon = cfg.delta * (cfg.cycles + 1);

        let churn = cfg.churn.as_ref().map(|c| {
            let mut crng = rng.fork();
            ChurnSchedule::generate(c, n, horizon, &mut crng)
        });

        let mut sampler_rng = rng.fork();
        let sampler = PeerSampler::new(cfg.sampler, n, cfg.delta, &mut sampler_rng);

        let mut eval_rng = rng.fork();
        let eval_peers = eval_rng.sample_indices(n, cfg.eval.n_peers.min(n));

        let d = data.d();
        let need_cache: std::collections::HashSet<NodeId> = if cfg.eval.voting {
            eval_peers.iter().copied().collect()
        } else {
            Default::default()
        };
        let nodes: Vec<Node> = (0..n)
            .map(|i| {
                // INITMODEL (Algorithm 3): zero model, t = 0, seeded cache.
                let init = LinearModel::zeros(d);
                let cache = need_cache.contains(&i).then(|| {
                    let mut c = ModelCache::new(cfg.cache_size);
                    c.add(init.clone());
                    c
                });
                Node {
                    online: churn.as_ref().map_or(true, |ch| ch.is_online(i, 0)),
                    last_recv: init.clone(),
                    freshest: init,
                    cache,
                    last_restart: 0,
                }
            })
            .collect();
        let online_flags = nodes.iter().map(|nd| nd.online).collect();

        GossipSim {
            network: Network::new(cfg.network),
            nodes,
            queue: EventQueue::new(),
            sampler,
            churn,
            eval_peers,
            online_flags,
            stats: RunStats::default(),
            now: 0,
            rng,
            cfg,
            data,
        }
    }

    /// Jittered per-iteration gossip period: N(Δ, Δ/10), clipped positive.
    fn next_period(&mut self) -> Ticks {
        let d = self.cfg.delta as f64;
        let p = self.rng.normal_scaled(d, d / 10.0);
        p.max(1.0) as Ticks
    }

    /// Run to completion, returning the convergence curve and stats.
    pub fn run(mut self) -> RunResult {
        let n = self.nodes.len();
        let horizon = self.cfg.delta * self.cfg.cycles;

        // synchronized start (Section IV): first tick after one period
        for node in 0..n {
            let p = self.next_period();
            self.queue.push(p, Event::GossipTick { node });
        }
        // churn transitions
        if let Some(ch) = &self.churn {
            for (t, node, up) in ch.events() {
                if t <= horizon {
                    self.queue.push(
                        t,
                        if up { Event::Join { node } } else { Event::Leave { node } },
                    );
                }
            }
        }
        // measurement probes at cycle boundaries
        let eval_cycles = if self.cfg.eval.at_cycles.is_empty() {
            eval::log_spaced_cycles(self.cfg.cycles)
        } else {
            self.cfg.eval.at_cycles.clone()
        };
        for &c in &eval_cycles {
            self.queue.push(c * self.cfg.delta, Event::Eval);
        }

        let mut curve = Curve::new(format!(
            "{}-{}-{}",
            self.cfg.learner.name(),
            self.cfg.variant.name(),
            self.cfg.sampler.name()
        ));

        while let Some((t, ev)) = self.queue.pop() {
            if t > horizon {
                break;
            }
            self.now = t;
            match ev {
                Event::GossipTick { node } => self.on_tick(node),
                Event::Deliver { dst, msg } => self.on_deliver(dst, msg),
                Event::Join { node } => {
                    self.nodes[node].online = true;
                    self.online_flags[node] = true;
                }
                Event::Leave { node } => {
                    self.nodes[node].online = false;
                    self.online_flags[node] = false;
                }
                Event::Eval => {
                    let cycle = (t / self.cfg.delta).max(1);
                    curve.push(self.measure(cycle));
                }
            }
        }

        RunResult { curve, stats: self.stats }
    }

    /// Active loop body (Algorithm 1 lines 3-5).
    fn on_tick(&mut self, node: NodeId) {
        // always schedule the next iteration (the loop runs forever; an
        // offline node simply skips the send)
        let p = self.next_period();
        self.queue.push(self.now + p, Event::GossipTick { node });

        if !self.nodes[node].online {
            return;
        }
        // scheduled model restart (drifting-concept support, DESIGN.md §8)
        if let Some(k) = self.cfg.restart_every {
            let cycle = self.now / self.cfg.delta;
            if k > 0 && cycle > 0 && cycle % k == 0 && self.nodes[node].last_restart != cycle {
                let d = self.data.d();
                let nd = &mut self.nodes[node];
                nd.last_restart = cycle;
                nd.freshest = LinearModel::zeros(d);
                nd.last_recv = LinearModel::zeros(d);
                if let Some(c) = &mut nd.cache {
                    *c = ModelCache::new(self.cfg.cache_size);
                    c.add(LinearModel::zeros(d));
                }
            }
        }
        let Some(dst) =
            self.sampler.select(node, self.now, &self.online_flags, &mut self.rng)
        else {
            return;
        };

        let m = &self.nodes[node].freshest;
        let msg = ModelMsg {
            src: node,
            w: m.weights(),
            t: m.t,
            view: self.sampler.payload(node, self.now),
        };
        self.stats.messages_sent += 1;
        self.stats.bytes_sent += msg.wire_bytes() as u64;
        match self.network.transmit(&mut self.rng) {
            Some(delay) => {
                self.queue.push(self.now + delay, Event::Deliver { dst, msg });
            }
            None => self.stats.messages_dropped += 1,
        }
    }

    /// ONRECEIVEMODEL (Algorithm 1 lines 7-10).
    fn on_deliver(&mut self, dst: NodeId, msg: ModelMsg) {
        if !self.nodes[dst].online {
            self.network.note_lost_offline();
            self.stats.messages_lost_offline += 1;
            return;
        }
        self.sampler.on_receive(dst, &msg.view);

        let m1 = LinearModel::from_weights(msg.w, msg.t);
        let node = &mut self.nodes[dst];
        let x = self.data.train.row(dst);
        let y = self.data.train_y[dst];
        // allocation-minimal CREATEMODEL + `lastModel <- m` in one step
        let created = create_model_step(
            self.cfg.variant,
            &self.cfg.learner,
            m1,
            &mut node.last_recv,
            &x,
            y,
        );
        self.stats.updates_applied += match self.cfg.variant {
            Variant::Um => 2,
            _ => 1,
        };
        if let Some(cache) = &mut node.cache {
            cache.add(created.clone());
        }
        node.freshest = created;
    }

    /// Measure the error curve point at `cycle` over the evaluation peers.
    fn measure(&mut self, cycle: u64) -> eval::EvalPoint {
        let test = &self.data.test;
        let y = &self.data.test_y;
        let errs: Vec<f64> = self
            .eval_peers
            .iter()
            .map(|&p| eval::zero_one_error(&self.nodes[p].freshest, test, y))
            .collect();
        let vote_errs: Option<Vec<f64>> = self.cfg.eval.voting.then(|| {
            self.eval_peers
                .iter()
                .filter_map(|&p| self.nodes[p].cache.as_ref())
                .map(|c| eval::cache_error(c, Predictor::MajorityVote, test, y))
                .collect()
        });
        let similarity = self.cfg.eval.similarity.then(|| {
            let models: Vec<&LinearModel> =
                self.eval_peers.iter().map(|&p| &self.nodes[p].freshest).collect();
            eval::mean_pairwise_cosine(&models)
        });
        point_from_errors(
            cycle,
            &errs,
            vote_errs.as_deref(),
            similarity,
            self.stats.messages_sent,
        )
    }
}

/// Convenience: run one configuration against a dataset.
pub fn run(cfg: ProtocolConfig, data: &Dataset) -> RunResult {
    GossipSim::new(cfg, data).run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{spambase_like, urls_like, Scale};

    fn quick_cfg(cycles: u64) -> ProtocolConfig {
        let mut cfg = ProtocolConfig::paper_default(cycles);
        cfg.eval.n_peers = 20;
        cfg
    }

    #[test]
    fn error_decreases_over_time() {
        let ds = urls_like(1, Scale(0.02)); // 200 nodes, d=10
        let cfg = quick_cfg(60);
        let res = run(cfg, &ds);
        let first = res.curve.points.first().unwrap().err_mean;
        let last = res.curve.final_error();
        assert!(last < first, "error should fall: {first} -> {last}");
        assert!(last < 0.25, "final error too high: {last}");
    }

    #[test]
    fn message_complexity_one_per_node_per_cycle() {
        let ds = spambase_like(2, Scale(0.03)); // ~124 nodes
        let cfg = quick_cfg(20);
        let n = ds.n_train() as f64;
        let res = run(cfg, &ds);
        let per_node_cycle = res.stats.messages_sent as f64 / (n * 20.0);
        assert!(
            (per_node_cycle - 1.0).abs() < 0.1,
            "messages per node-cycle = {per_node_cycle}"
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let ds = spambase_like(3, Scale(0.02));
        let a = run(quick_cfg(10), &ds);
        let b = run(quick_cfg(10), &ds);
        let ea: Vec<f64> = a.curve.points.iter().map(|p| p.err_mean).collect();
        let eb: Vec<f64> = b.curve.points.iter().map(|p| p.err_mean).collect();
        assert_eq!(ea, eb);
        assert_eq!(a.stats.messages_sent, b.stats.messages_sent);
    }

    #[test]
    fn extreme_failures_still_converge() {
        let ds = urls_like(4, Scale(0.02));
        let cfg = quick_cfg(100).with_extreme_failures();
        let res = run(cfg, &ds);
        assert!(res.stats.messages_dropped > 0);
        let first = res.curve.points.first().unwrap().err_mean;
        let last = res.curve.final_error();
        assert!(last < first, "error should still fall: {first} -> {last}");
    }

    #[test]
    fn voting_and_similarity_fields_populated() {
        let ds = spambase_like(5, Scale(0.02));
        let mut cfg = quick_cfg(8);
        cfg.eval.voting = true;
        cfg.eval.similarity = true;
        let res = run(cfg, &ds);
        let p = res.curve.points.last().unwrap();
        assert!(p.err_vote.is_some());
        let s = p.similarity.unwrap();
        assert!((-1.0..=1.0).contains(&s));
    }

    #[test]
    fn restart_schedule_resets_models() {
        let ds = urls_like(7, Scale(0.02));
        let mut cfg = quick_cfg(30);
        cfg.restart_every = Some(10);
        cfg.eval.at_cycles = (1..=30).collect();
        let with_restart = run(cfg.clone(), &ds);
        cfg.restart_every = None;
        let without = run(cfg, &ds);
        // shortly after a restart the error must jump back toward the
        // zero-model level while the non-restarting run stays converged
        let err_at = |r: &RunResult, c: u64| {
            r.curve.points.iter().find(|p| p.cycle == c).unwrap().err_mean
        };
        assert!(
            err_at(&with_restart, 11) > err_at(&without, 11) + 0.05,
            "restart {} vs none {}",
            err_at(&with_restart, 11),
            err_at(&without, 11)
        );
    }

    #[test]
    fn logreg_gossip_converges() {
        let ds = urls_like(8, Scale(0.02));
        let mut cfg = quick_cfg(50);
        cfg.learner = Learner::logreg(1e-2);
        let res = run(cfg, &ds);
        let first = res.curve.points.first().unwrap().err_mean;
        let last = res.curve.final_error();
        assert!(last < first && last < 0.25, "{first} -> {last}");
    }

    #[test]
    fn all_variants_run() {
        let ds = spambase_like(6, Scale(0.02));
        for v in [Variant::Rw, Variant::Mu, Variant::Um] {
            let mut cfg = quick_cfg(10);
            cfg.variant = v;
            let res = run(cfg, &ds);
            assert!(!res.curve.points.is_empty());
        }
    }
}
