//! Sharded execution engine for the event-driven simulator (DESIGN.md §13).
//!
//! The node universe is partitioned into `shards` contiguous ranges; each
//! range is owned by a [`Runner`] with its own keyed event queue, SoA
//! [`ModelStore`] rows, step batches, network instance, and backend.
//! Cross-shard deliveries travel as [`Envelope`]s over mpsc lanes.  A
//! coordinator drives everything in *windows* bounded by conservative
//! lookahead: with `L` the minimum delivery delay any installed delay model
//! can produce (floored at one tick), every message sent inside a window
//! `[S, E)` with `E − S ≤ L` arrives at or after `E` — so runners never
//! need to see each other's sends mid-window, and the whole window can run
//! in parallel.
//!
//! Determinism does not come from synchronization but from a *keyed total
//! order* ([`EventKey`]): every event's heap position is a pure function of
//! its content — `(time, class, src, per-source send counter)` for
//! deliveries, `(time, class, node)` for gossip ticks — so the pop order is
//! independent of envelope arrival order, thread interleaving, and shard
//! count.  Per-node RNG streams (`derive_stream(seed, "node", i)`) are
//! consumed only by node `i`'s own events, which the keyed order sequences
//! identically for any sharding; NEWSCAST bootstrap draws from per-node
//! `"newscast"` streams the same way.  The result: `shards = k` is
//! bit-for-bit identical to `shards = 1` (pinned in
//! tests/engine_parity.rs), and the thread count only changes wall-clock.
//!
//! Shared state that mutations touch (churn liveness, forced-offline
//! overlays, membership, network drop/delay/partition models) is
//! *replicated* per runner and advanced from the same compiled schedules at
//! the same ticks: churn via a cursor over one sorted transition list
//! (transitions at time ≤ t are visible to every event processed at t),
//! scenario mutations at window starts (every mutation tick is a barrier).
//! Evaluation happens at barriers: each runner measures its slice of the
//! evaluation peers through the same chunked backend kernels (per-model
//! counts are grouping-independent), and the coordinator reassembles the
//! curve point in global peer order.
//!
//! Memory hot path (DESIGN.md §14): the three full-universe liveness
//! replicas are packed [`Bitset`]s (1 bit/node instead of 1 byte), the
//! compiled scenario is shared behind one `Arc` instead of deep-cloned per
//! runner, and message weight buffers recycle through per-runner
//! [`BufPool`]s — consumed `Envelope` payloads travel back to the sending
//! shard's free-list over dedicated recycle lanes.  All of it is
//! allocator-level only: pooled and unpooled runs are bit-for-bit identical.

use crate::api::{Observer, RunEvent};
use crate::data::dataset::{Dataset, Examples};
use crate::data::sparse::Csr;
use crate::engine::native::NativeBackend;
use crate::engine::{eval_peer_errors, Backend, StepBatch, StepOp, MAX_BATCH_ROWS};
use crate::eval::{
    self,
    tracker::{point_from_errors, Curve},
};
use crate::gossip::cache::ModelCache;
use crate::gossip::create_model::Variant;
use crate::gossip::message::ModelMsg;
use crate::gossip::predict::Predictor;
use crate::gossip::protocol::{ExecMode, ProtocolConfig, RunResult, RunStats};
use crate::gossip::state::ModelStore;
use crate::learning::linear::LinearModel;
use crate::learning::pairwise::{self, reservoir_len};
use crate::p2p::overlay::{PeerSampler, SamplerConfig};
use crate::p2p::topology::Topology;
use crate::scenario::driver::{resolve_churn_schedule, CompiledScenario, Mutation, ScenarioDriver};
use crate::sim::event::{EventKey, KeyedQueue, NodeId, Ticks};
use crate::sim::network::{Fate, Network};
use crate::util::bitset::Bitset;
use crate::util::pool::BufPool;
use crate::util::rng::{derive_stream, Rng};
use crate::util::threads;
use anyhow::{anyhow, bail, Result};
use std::collections::{BTreeSet, HashMap};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

/// A cross-shard delivery in flight: the message plus the key material that
/// fixes its position in the receiver's total order.
struct Envelope {
    at: Ticks,
    dst: NodeId,
    src: NodeId,
    seq: u64,
    msg: ModelMsg,
}

/// Events a runner's keyed queue holds.  Churn is not queued (cursor over
/// the shared transition list); evaluation is coordinator-driven.
enum REvent {
    /// Gossip tick; the node is the key's `a` component.
    Tick,
    Deliver { dst: NodeId, msg: ModelMsg },
}

/// Read-only state shared by every runner and the coordinator.
struct Shared<'a> {
    cfg: &'a ProtocolConfig,
    data: &'a Dataset,
    /// shared, not replicated: runners and the coordinator mirror hold Arc
    /// clones (a ForceOffline wave at 1M nodes carries tens of thousands of
    /// ids — deep-cloning it per shard used to dominate setup memory)
    compiled: Option<Arc<CompiledScenario>>,
    /// the run's graph topology (DESIGN.md §16), built once from
    /// `(spec, n_univ, seed)` and shared read-only: samplers hold Arc
    /// clones, edge-failure mutations reference its canonical edge list
    topology: Option<Arc<Topology>>,
    /// sorted (time, node, joined) churn transitions within the horizon
    churn_events: Vec<(Ticks, NodeId, bool)>,
    /// churn liveness at tick 0, over the full universe
    churn_online0: Bitset,
    /// global evaluation peers, in measurement order
    eval_peers: Vec<NodeId>,
    /// sign-flipped test labels, precomputed iff the scenario can drift
    flipped_y: Option<Vec<f32>>,
    /// CSR copy when the sparse path is forced on densely stored data
    owned_csr: Option<Csr>,
    sparse: bool,
    op: StepOp,
    /// example-reservoir capacity K riding with each walking model — 0 for
    /// pointwise learners, `cfg.reservoir` for the pairwise AUC objective
    /// (DESIGN.md §17)
    res_cap: usize,
    members0: usize,
    n_univ: usize,
    /// shard range bounds: shard `i` owns nodes `[bounds[i], bounds[i+1])`
    bounds: Vec<usize>,
}

impl<'a> Shared<'a> {
    fn csr(&self) -> &Csr {
        if let Some(c) = &self.owned_csr {
            return c;
        }
        match &self.data.train {
            Examples::Sparse(c) => c,
            Examples::Dense(_) => unreachable!("dense staging has no CSR"),
        }
    }

    fn shard_of(&self, node: NodeId) -> usize {
        self.bounds.partition_point(|&b| b <= node) - 1
    }
}

/// Per-runner evaluation slice, tagged with each peer's position in the
/// global `eval_peers` order so the coordinator can reassemble.
struct EvalOut {
    errs: Vec<(usize, f64)>,
    votes: Vec<(usize, f64)>,
    models: Vec<(usize, LinearModel)>,
    aucs: Vec<(usize, f64)>,
    sent: u64,
}

/// One shard's worth of simulation state: nodes `[lo, hi)`.
struct Runner<'a, B: Backend> {
    lo: NodeId,
    hi: NodeId,
    sh: &'a Shared<'a>,
    /// SoA rows for the own range only (local index = node − lo)
    store: ModelStore,
    caches: Vec<Option<ModelCache>>,
    last_restart: Vec<u64>,
    /// full-universe liveness replicas (the oracle sampler and send/receive
    /// checks index arbitrary nodes) — packed 1 bit/node; at 1M nodes the
    /// three replicas cost ~375 KiB per runner instead of ~3 MiB
    online: Bitset,
    churn_online: Bitset,
    forced_off: Bitset,
    /// replicated membership counter (grows with scenario flash crowds)
    members: usize,
    scn: Option<ScenarioDriver>,
    drift_sign: f32,
    queue: KeyedQueue<REvent>,
    network: Network,
    sampler: PeerSampler,
    /// per-node streams for own-range nodes: period jitter, peer selection,
    /// transmission fate — consumed in node-local event order
    node_rngs: Vec<Rng>,
    /// per-source send counters (the delivery key's tie-breaker)
    send_seq: Vec<u64>,
    churn_cursor: usize,
    /// (global position, node) for own-range evaluation peers
    my_eval: Vec<(usize, NodeId)>,
    stats: RunStats,
    backend: B,
    batch: StepBatch,
    pending: Vec<(NodeId, ModelMsg)>,
    batch_start: Ticks,
    /// own-range dense staging (None on the sparse path)
    dense_x: Option<Vec<f32>>,
    inbox: Receiver<Envelope>,
    lanes: Vec<Sender<Envelope>>,
    /// free-list of message weight buffers for this runner's sends
    pool: BufPool,
    /// consumed buffers coming home from other shards
    recycle_rx: Receiver<Vec<f32>>,
    recycle_lanes: Vec<Sender<Vec<f32>>>,
    /// flush staging, persistent so a 1M-node run does not realloc the
    /// delivery vectors every window
    live: Vec<(NodeId, ModelMsg)>,
    prev_in_flush: HashMap<NodeId, usize>,
}

impl<'a, B: Backend> Runner<'a, B> {
    fn new(
        sh: &'a Shared<'a>,
        shard: usize,
        backend: B,
        inbox: Receiver<Envelope>,
        lanes: Vec<Sender<Envelope>>,
        recycle_rx: Receiver<Vec<f32>>,
        recycle_lanes: Vec<Sender<Vec<f32>>>,
    ) -> Self {
        let (lo, hi) = (sh.bounds[shard], sh.bounds[shard + 1]);
        let d = sh.data.d();
        let rows = hi - lo;
        let my_eval: Vec<(usize, NodeId)> = sh
            .eval_peers
            .iter()
            .enumerate()
            .filter(|&(_, &p)| lo <= p && p < hi)
            .map(|(pos, &p)| (pos, p))
            .collect();
        let mut caches: Vec<Option<ModelCache>> = vec![None; rows];
        if sh.cfg.eval.voting {
            for &(_, p) in &my_eval {
                // INITMODEL (Algorithm 3): seeded cache at evaluation peers
                let mut c = ModelCache::new(sh.cfg.cache_size);
                c.add(LinearModel::zeros(d));
                caches[p - lo] = Some(c);
            }
        }
        let dense_x = (!sh.sparse).then(|| {
            let mut dx = vec![0.0f32; rows * d];
            for i in lo..hi {
                sh.data.train.row(i).write_dense(&mut dx[(i - lo) * d..(i - lo + 1) * d]);
            }
            dx
        });
        let mut r = Runner {
            lo,
            hi,
            sh,
            store: ModelStore::with_reservoirs(rows, d, sh.res_cap),
            caches,
            last_restart: vec![0; rows],
            online: sh.churn_online0.clone(),
            churn_online: sh.churn_online0.clone(),
            forced_off: Bitset::new(sh.n_univ),
            members: sh.members0,
            // Arc clone: every runner reads the one compiled timeline
            scn: sh.compiled.clone().map(ScenarioDriver::new),
            drift_sign: 1.0,
            queue: KeyedQueue::new(),
            network: Network::new(sh.cfg.network),
            sampler: PeerSampler::new_range(
                sh.cfg.sampler,
                sh.topology.as_ref(),
                lo,
                hi,
                sh.members0,
                sh.cfg.delta,
                sh.cfg.seed,
            ),
            node_rngs: (lo..hi).map(|n| derive_stream(sh.cfg.seed, "node", n as u64)).collect(),
            send_seq: vec![0; rows],
            churn_cursor: 0,
            my_eval,
            stats: RunStats::default(),
            backend,
            batch: StepBatch::default(),
            pending: Vec::new(),
            batch_start: 0,
            dense_x,
            inbox,
            lanes,
            pool: BufPool::new(sh.cfg.pool),
            recycle_rx,
            recycle_lanes,
            live: Vec::new(),
            prev_in_flush: HashMap::new(),
        };
        // synchronized start (Section IV): first tick after one jittered
        // period, drawn from each member node's own stream
        for node in r.lo..r.hi.min(sh.members0.max(r.lo)) {
            let p = r.next_period(node);
            r.queue.push(EventKey::tick(p, node), REvent::Tick);
        }
        r
    }

    /// Jittered per-iteration gossip period N(Δ, Δ/10), clipped positive —
    /// from the node's own stream.
    fn next_period(&mut self, node: NodeId) -> Ticks {
        let d = self.sh.cfg.delta as f64;
        let p = self.node_rngs[node - self.lo].normal_scaled(d, d / 10.0);
        p.max(1.0) as Ticks
    }

    /// Make every churn transition with time ≤ `t` visible (flushing any
    /// pending micro-batch first so deliveries precede later toggles).
    fn advance_churn(&mut self, t: Ticks) -> Result<()> {
        let ev = &self.sh.churn_events;
        if self.churn_cursor < ev.len() && ev[self.churn_cursor].0 <= t {
            self.flush()?;
            while self.churn_cursor < ev.len() && ev[self.churn_cursor].0 <= t {
                let (_, node, up) = ev[self.churn_cursor];
                self.churn_cursor += 1;
                self.churn_online.assign(node, up);
                self.online.assign(node, up && !self.forced_off.test(node));
            }
        }
        Ok(())
    }

    /// Apply every scenario mutation due at or before `now` to the
    /// replicated state.  Called at window starts only — every mutation tick
    /// is a barrier, so `now` is exactly the mutation's tick.
    fn apply_scenario(&mut self, now: Ticks) {
        while let Some(m) = self.scn.as_mut().and_then(|d| d.pop_due(now)) {
            match m {
                Mutation::SetDrop(p) => self.network.cfg.drop_prob = p,
                Mutation::SetDelay(model) => self.network.cfg.delay = model,
                Mutation::SetPartition(components) => {
                    self.network.set_partition(Some(components))
                }
                Mutation::Heal => {
                    self.network.set_partition(None);
                    self.network.restore_edges(None);
                }
                Mutation::EdgeFail(edges) => self.network.fail_edges(&edges),
                Mutation::EdgeRestore(edges) => self.network.restore_edges(edges.as_deref()),
                Mutation::Drift => self.drift_sign = -self.drift_sign,
                Mutation::ForceOffline(ids) => {
                    for i in ids {
                        self.forced_off.set(i);
                        self.online.clear(i);
                    }
                }
                Mutation::Restore(ids) => {
                    for i in ids {
                        self.forced_off.clear(i);
                        self.online.assign(i, self.churn_online.test(i));
                    }
                }
                Mutation::Grow(k) => {
                    let old = self.members;
                    let newn = (old + k).min(self.sh.n_univ);
                    self.members = newn;
                    self.sampler.grow_range(old, newn, self.sh.cfg.seed);
                    // liveness flags are full-universe replicas
                    for node in old..newn {
                        self.online
                            .assign(node, self.churn_online.test(node) && !self.forced_off.test(node));
                    }
                    // arrivals in the own range enter the active loop on a
                    // fresh jittered period from their own streams
                    for node in old.max(self.lo)..newn.min(self.hi) {
                        let p = self.next_period(node);
                        self.queue.push(EventKey::tick(now + p, node), REvent::Tick);
                    }
                }
            }
        }
    }

    /// Run one window `[start, end)`: drain the envelope inbox, catch up
    /// churn and scenario state to `start`, then process every queued event
    /// with time < `end` in keyed order.
    fn step_window(&mut self, start: Ticks, end: Ticks) -> Result<()> {
        // buffers consumed by other shards come home before new sends
        while let Ok(buf) = self.recycle_rx.try_recv() {
            self.pool.put(buf);
        }
        while let Ok(env) = self.inbox.try_recv() {
            debug_assert!(env.at >= start, "envelope violates the lookahead bound");
            self.queue.push(
                EventKey::deliver(env.at, env.src, env.seq),
                REvent::Deliver { dst: env.dst, msg: env.msg },
            );
        }
        self.advance_churn(start)?;
        self.apply_scenario(start);
        while let Some(key) = self.queue.peek_key() {
            if key.time >= end {
                break;
            }
            self.advance_churn(key.time)?;
            let (key, ev) = self.queue.pop().expect("peeked");
            match ev {
                REvent::Deliver { dst, msg } => {
                    if self.pending.is_empty() {
                        self.batch_start = key.time;
                    }
                    self.pending.push((dst, msg));
                    if self.should_flush() {
                        self.flush()?;
                    }
                }
                REvent::Tick => {
                    self.flush()?;
                    self.on_tick(key.a as NodeId, key.time);
                }
            }
        }
        self.flush()
    }

    /// Keep accumulating while the next event is another delivery at the
    /// same (possibly window-quantized) timestamp.
    fn should_flush(&self) -> bool {
        match self.sh.cfg.exec {
            ExecMode::Scalar => true,
            ExecMode::MicroBatch { .. } => match self.queue.peek() {
                Some((k, REvent::Deliver { .. })) => k.time != self.batch_start,
                _ => true,
            },
        }
    }

    /// Quantize a delivery time up to the coalescing-window boundary
    /// (quantizing up only *increases* arrival times, so the lookahead
    /// bound survives coalescing).
    fn arrival_time(&self, at: Ticks) -> Ticks {
        match self.sh.cfg.exec {
            ExecMode::MicroBatch { coalesce } if coalesce > 0 => {
                ((at + coalesce - 1) / coalesce) * coalesce
            }
            _ => at,
        }
    }

    /// Active loop body (Algorithm 1 lines 3-5) at `now`.
    fn on_tick(&mut self, node: NodeId, now: Ticks) {
        // always reschedule (the loop runs forever; an offline node simply
        // skips the send) — key uniqueness holds because each node has at
        // most one pending tick
        let p = self.next_period(node);
        self.queue.push(EventKey::tick(now + p, node), REvent::Tick);

        if !self.online.test(node) {
            return;
        }
        let li = node - self.lo;
        // scheduled model restart (drifting-concept support, DESIGN.md §8)
        if let Some(k) = self.sh.cfg.restart_every {
            let cycle = now / self.sh.cfg.delta;
            if k > 0 && cycle > 0 && cycle % k == 0 && self.last_restart[li] != cycle {
                self.last_restart[li] = cycle;
                self.store.reset(li);
                if let Some(c) = &mut self.caches[li] {
                    *c = ModelCache::new(self.sh.cfg.cache_size);
                    c.add(LinearModel::zeros(self.sh.data.d()));
                }
            }
        }
        let rng = &mut self.node_rngs[li];
        let Some(dst) = self.sampler.select(node, now, &self.online, rng) else {
            return;
        };

        // the weight buffer comes from the pool; write_freshest_raw resizes
        // it to d and overwrites every element, so recycled contents can
        // never leak into a send
        let mut w = self.pool.get(self.store.d());
        self.store.write_freshest_raw(li, &mut w);
        // the reservoir rides with the walking model; its buffer comes from
        // the same pool and is overwritten in full, like the weights
        let res = if self.sh.res_cap > 0 {
            let mut r = self.pool.get(reservoir_len(self.sh.res_cap));
            self.store.write_res_raw(li, &mut r);
            r
        } else {
            Vec::new()
        };
        let msg = ModelMsg {
            src: node,
            w,
            scale: self.store.freshest_scale(li),
            t: self.store.freshest_t(li) as u64,
            view: self.sampler.payload(node, now),
            res,
        };
        self.stats.messages_sent += 1;
        self.stats.bytes_sent += msg.wire_bytes() as u64;
        let seq = self.send_seq[li];
        self.send_seq[li] += 1;
        let rng = &mut self.node_rngs[li];
        match self.network.transmit_between(node, dst, rng) {
            Fate::Deliver(delay) => {
                // the one-tick floor keeps arrivals strictly after the send
                // and is what makes the conservative lookahead ≥ 1
                let at = self.arrival_time(now + delay.max(1));
                if self.lo <= dst && dst < self.hi {
                    self.queue
                        .push(EventKey::deliver(at, node, seq), REvent::Deliver { dst, msg });
                } else {
                    // a failed send here means teardown is in progress
                    let _ = self.lanes[self.sh.shard_of(dst)].send(Envelope {
                        at,
                        dst,
                        src: node,
                        seq,
                        msg,
                    });
                }
            }
            Fate::Dropped => {
                self.stats.messages_dropped += 1;
                self.pool.put(msg.w);
                if !msg.res.is_empty() {
                    self.pool.put(msg.res);
                }
            }
            Fate::Blocked => {
                self.stats.messages_blocked += 1;
                self.pool.put(msg.w);
                if !msg.res.is_empty() {
                    self.pool.put(msg.res);
                }
            }
        }
    }

    /// Return a consumed weight buffer to the free-list of the shard that
    /// allocated it (local sends go straight back; foreign buffers travel
    /// the recycle lane).  No-op with pooling off — `BufPool::put` drops,
    /// and cross-shard sends are skipped outright.
    fn recycle(&mut self, w: Vec<f32>, src: NodeId) {
        if self.lo <= src && src < self.hi {
            self.pool.put(w);
        } else if self.sh.cfg.pool {
            // a failed send here means teardown is in progress
            let _ = self.recycle_lanes[self.sh.shard_of(src)].send(w);
        }
    }

    /// Apply the pending deliveries: keyed ordering, offline losses,
    /// NEWSCAST view merges, then all CREATEMODEL steps as engine
    /// micro-batches.  Identical to the unsharded flush except that store,
    /// cache, and staging indices are range-local.
    fn flush(&mut self) -> Result<()> {
        if self.pending.is_empty() {
            return Ok(());
        }
        let d = self.store.d();
        let lo = self.lo;
        // staging vectors and the duplicate-receiver map persist across
        // flushes (drained, never dropped), so steady-state windows run
        // without reallocating them
        let mut pending = std::mem::take(&mut self.pending);
        let mut live = std::mem::take(&mut self.live);
        self.prev_in_flush.clear();
        for (dst, msg) in pending.drain(..) {
            if !self.online.test(dst) {
                self.network.note_lost_offline();
                self.stats.messages_lost_offline += 1;
                let src = msg.src;
                self.recycle(msg.w, src);
                if !msg.res.is_empty() {
                    self.recycle(msg.res, src);
                }
                continue;
            }
            self.sampler.on_receive(dst, &msg.view);
            self.network.note_delivered();
            live.push((dst, msg));
        }
        self.pending = pending;
        let per_msg_updates: u64 = match self.sh.cfg.variant {
            Variant::Um => 2,
            _ => 1,
        };
        let sparse = self.sh.sparse;
        let pairwise = self.sh.res_cap > 0;
        let mut start = 0;
        while start < live.len() {
            let end = (start + MAX_BATCH_ROWS).min(live.len());
            let b = end - start;
            self.batch.resize_for(b, d, sparse);
            if pairwise {
                self.batch.begin_pair_rows();
            }
            for (row, (dst, msg)) in live[start..end].iter().enumerate() {
                let dst = *dst;
                let r = row * d..(row + 1) * d;
                self.batch.w1[r.clone()].copy_from_slice(&msg.w);
                self.batch.s1[row] = msg.scale;
                self.batch.t1[row] = msg.t as f32;
                match self.prev_in_flush.insert(dst, start + row) {
                    Some(prev) => {
                        let pm = &live[prev].1;
                        self.batch.w2[r.clone()].copy_from_slice(&pm.w);
                        self.batch.s2[row] = pm.scale;
                        self.batch.t2[row] = pm.t as f32;
                    }
                    None => {
                        self.batch.w2[r.clone()].copy_from_slice(self.store.last(dst - lo));
                        self.batch.s2[row] = self.store.last_scale(dst - lo);
                        self.batch.t2[row] = self.store.last_t(dst - lo);
                    }
                }
                match &self.dense_x {
                    Some(dx) => {
                        let li = dst - lo;
                        self.batch.x[r].copy_from_slice(&dx[li * d..(li + 1) * d]);
                    }
                    None => {
                        let (idx, val) = self.sh.csr().row(dst);
                        self.batch.push_sparse_x_row(idx, val);
                    }
                }
                // concept drift re-labels: the sign flips with the scenario
                self.batch.y[row] = self.drift_sign * self.sh.data.train_y[dst];
                if pairwise {
                    // stage the walking reservoir's opposite-class partners
                    // in reservoir order (the kernel applies every staged
                    // pair, so the class filter lives here — bitwise the
                    // same filter as the scalar reference)
                    let yloc = self.batch.y[row];
                    for (node, yj) in pairwise::entries(&msg.res) {
                        if yj * yloc >= 0.0 {
                            continue;
                        }
                        if sparse {
                            let (idx, val) = self.sh.csr().row(node as usize);
                            self.batch.push_pair_entry_sparse(idx, val);
                        } else {
                            self.batch
                                .push_pair_entry_dense(&self.sh.data.train.row(node as usize));
                        }
                    }
                    self.batch.seal_pair_row();
                }
            }
            self.backend.step(&self.sh.op, &mut self.batch)?;
            self.stats.engine_calls += 1;
            self.stats.updates_applied += per_msg_updates * b as u64;
            if sparse {
                self.stats.sparse_rows += b as u64;
            }
            for (row, (dst, msg)) in live[start..end].iter_mut().enumerate() {
                let li = *dst - lo;
                let r = row * d..(row + 1) * d;
                // sparse results land in place in w1 (scale in out_s); dense
                // results in out_w — see the Backend::step contract
                let (out, out_s) = if sparse {
                    (&self.batch.w1[r], self.batch.out_s[row])
                } else {
                    (&self.batch.out_w[r], 1.0)
                };
                let out_t = self.batch.out_t[row];
                if let Some(cache) = &mut self.caches[li] {
                    let mut w = out.to_vec();
                    if out_s != 1.0 {
                        for v in &mut w {
                            *v *= out_s;
                        }
                    }
                    cache.add(LinearModel::from_weights(w, out_t as u64));
                }
                self.store.set_freshest_scaled(li, out, out_s, out_t);
                // lastModel <- incoming (Algorithm 1 line 9)
                self.store.set_last_scaled(li, &msg.w, msg.scale, msg.t as f32);
                if pairwise {
                    // the created model inherits the walking reservoir plus
                    // the receiver's local example (drift-adjusted label).
                    // Exactly one draw per delivery from the receiver's own
                    // stream, consumed in keyed delivery order — reservoir
                    // contents stay bit-for-bit shard-count independent
                    let draw = self.node_rngs[li].next_u64();
                    pairwise::offer(&mut msg.res, *dst as u32, self.batch.y[row], draw);
                    self.store.set_res(li, &msg.res);
                }
            }
            start = end;
        }
        // every message is fully consumed (copied into the batch and the
        // store) — send the buffers back to their allocating shards
        for (_, msg) in live.drain(..) {
            let src = msg.src;
            self.recycle(msg.w, src);
            if !msg.res.is_empty() {
                self.recycle(msg.res, src);
            }
        }
        self.live = live;
        Ok(())
    }

    /// Measure this runner's slice of the evaluation peers.  Counts are
    /// per-model and grouping-independent, so the reassembled values are
    /// bit-for-bit what a full-range runner would compute.
    fn eval(&mut self, flipped: bool) -> Result<EvalOut> {
        let test = &self.sh.data.test;
        let y: &[f32] = if flipped {
            self.sh.flipped_y.as_deref().expect("flipped labels precomputed at setup")
        } else {
            &self.sh.data.test_y
        };
        let lo = self.lo;
        let local: Vec<usize> = self.my_eval.iter().map(|&(_, p)| p - lo).collect();
        let errs = eval_peer_errors(&self.store, &local, &mut self.backend, test, y)?;
        let errs = self.my_eval.iter().map(|&(pos, _)| pos).zip(errs).collect();
        let votes = if self.sh.cfg.eval.voting {
            self.my_eval
                .iter()
                .filter_map(|&(pos, p)| {
                    self.caches[p - lo]
                        .as_ref()
                        .map(|c| (pos, eval::cache_error(c, Predictor::MajorityVote, test, y)))
                })
                .collect()
        } else {
            Vec::new()
        };
        let models = if self.sh.cfg.eval.similarity {
            self.my_eval
                .iter()
                .map(|&(pos, p)| (pos, self.store.freshest_model(p - lo)))
                .collect()
        } else {
            Vec::new()
        };
        let aucs = if self.sh.cfg.eval.auc {
            // rank the (possibly drift-flipped) test labels by each peer's
            // raw margin — Mann-Whitney AUC, per-model and therefore
            // grouping-independent like the error counts
            let mut w = vec![0.0f32; self.store.d()];
            let mut scores = vec![0.0f32; test.n()];
            self.my_eval
                .iter()
                .map(|&(pos, p)| {
                    self.store.write_freshest_into(p - lo, &mut w);
                    for (i, s) in scores.iter_mut().enumerate() {
                        *s = test.row(i).dot(&w);
                    }
                    (pos, eval::auc(&scores, y))
                })
                .collect()
        } else {
            Vec::new()
        };
        Ok(EvalOut { errs, votes, models, aucs, sent: self.stats.messages_sent })
    }

    /// Final flush; hand back this runner's counters.
    fn finish(&mut self) -> Result<RunStats> {
        self.flush()?;
        let mut stats = std::mem::take(&mut self.stats);
        stats.messages_delivered = self.network.delivered();
        stats.pool_hits = self.pool.hits;
        stats.pool_misses = self.pool.misses;
        Ok(stats)
    }
}

/// The coordinator's view of a set of runners — direct calls (serial /
/// multiplexed) or channel commands (worker threads).
trait Pool {
    fn window(&mut self, start: Ticks, end: Ticks) -> Result<()>;
    fn eval(&mut self, flipped: bool) -> Result<Vec<EvalOut>>;
    fn finish(&mut self) -> Result<RunStats>;
}

/// All runners on the calling thread, stepped in shard order.  Results are
/// identical to the threaded pool by the keyed-order argument, so the
/// thread budget only affects wall-clock.
struct SerialPool<'a, B: Backend> {
    runners: Vec<Runner<'a, B>>,
}

impl<B: Backend> Pool for SerialPool<'_, B> {
    fn window(&mut self, start: Ticks, end: Ticks) -> Result<()> {
        for r in &mut self.runners {
            r.step_window(start, end)?;
        }
        Ok(())
    }

    fn eval(&mut self, flipped: bool) -> Result<Vec<EvalOut>> {
        self.runners.iter_mut().map(|r| r.eval(flipped)).collect()
    }

    fn finish(&mut self) -> Result<RunStats> {
        let mut total = RunStats::default();
        for r in &mut self.runners {
            merge_stats(&mut total, r.finish()?);
        }
        Ok(total)
    }
}

#[derive(Clone)]
enum Cmd {
    Window { start: Ticks, end: Ticks },
    Eval { flipped: bool },
    Finish,
}

enum Reply {
    Window(Result<()>),
    Eval(Result<EvalOut>),
    Finish(Result<RunStats>),
}

/// Coordinator handle to worker threads, each multiplexing a chunk of
/// runners.  Every worker answers one reply per runner per command, so the
/// coordinator always collects exactly `n_runners` replies per phase.
struct ThreadPool {
    cmds: Vec<Sender<Cmd>>,
    replies: Receiver<Reply>,
    n_runners: usize,
}

impl ThreadPool {
    fn broadcast(&self, cmd: Cmd) {
        for tx in &self.cmds {
            // a closed worker channel means a worker panicked; the missing
            // replies surface below as a recv error
            let _ = tx.send(cmd.clone());
        }
    }

    fn collect<T>(&mut self, pick: impl Fn(Reply) -> Result<T>) -> Result<Vec<T>> {
        let mut out = Vec::with_capacity(self.n_runners);
        let mut first_err = None;
        for _ in 0..self.n_runners {
            let reply = self
                .replies
                .recv()
                .map_err(|_| anyhow!("shard worker exited unexpectedly"))?;
            match pick(reply) {
                Ok(v) => out.push(v),
                Err(e) if first_err.is_none() => first_err = Some(e),
                Err(_) => {}
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(out),
        }
    }
}

impl Pool for ThreadPool {
    fn window(&mut self, start: Ticks, end: Ticks) -> Result<()> {
        self.broadcast(Cmd::Window { start, end });
        self.collect(|r| match r {
            Reply::Window(res) => res,
            _ => Err(anyhow!("out-of-phase shard reply")),
        })?;
        Ok(())
    }

    fn eval(&mut self, flipped: bool) -> Result<Vec<EvalOut>> {
        self.broadcast(Cmd::Eval { flipped });
        self.collect(|r| match r {
            Reply::Eval(res) => res,
            _ => Err(anyhow!("out-of-phase shard reply")),
        })
    }

    fn finish(&mut self) -> Result<RunStats> {
        self.broadcast(Cmd::Finish);
        let all = self.collect(|r| match r {
            Reply::Finish(res) => res,
            _ => Err(anyhow!("out-of-phase shard reply")),
        })?;
        let mut total = RunStats::default();
        for s in all {
            merge_stats(&mut total, s);
        }
        Ok(total)
    }
}

fn worker_loop<B: Backend>(
    runners: &mut [Runner<'_, B>],
    cmds: Receiver<Cmd>,
    replies: Sender<Reply>,
) {
    while let Ok(cmd) = cmds.recv() {
        let done = matches!(cmd, Cmd::Finish);
        for r in runners.iter_mut() {
            let reply = match cmd {
                Cmd::Window { start, end } => Reply::Window(r.step_window(start, end)),
                Cmd::Eval { flipped } => Reply::Eval(r.eval(flipped)),
                Cmd::Finish => Reply::Finish(r.finish()),
            };
            if replies.send(reply).is_err() {
                return; // coordinator gone (error teardown)
            }
        }
        if done {
            return;
        }
    }
}

fn merge_stats(total: &mut RunStats, s: RunStats) {
    total.messages_sent += s.messages_sent;
    total.messages_dropped += s.messages_dropped;
    total.messages_blocked += s.messages_blocked;
    total.messages_lost_offline += s.messages_lost_offline;
    total.messages_delivered += s.messages_delivered;
    total.bytes_sent += s.bytes_sent;
    total.updates_applied += s.updates_applied;
    total.engine_calls += s.engine_calls;
    total.sparse_rows += s.sparse_rows;
    total.pool_hits += s.pool_hits;
    total.pool_misses += s.pool_misses;
}

/// The barrier/window plan for one run.
struct Plan {
    /// sorted unique ticks where the coordinator takes control: tick 0,
    /// every cycle boundary, every scenario mutation tick, every eval tick,
    /// and the horizon
    barriers: Vec<Ticks>,
    /// sorted eval ticks (duplicates preserved: one curve point each)
    eval_ticks: Vec<Ticks>,
    /// conservative lookahead: no window is longer than this
    lookahead: Ticks,
    horizon: Ticks,
    label: String,
}

fn build_plan(cfg: &ProtocolConfig, compiled: Option<&CompiledScenario>) -> Plan {
    let horizon = cfg.delta * cfg.cycles;
    // minimum delay any installed model can sample; the send-time
    // `delay.max(1)` floor makes 1 the effective lower bound
    let mut min_d = cfg.network.delay.min_delay();
    if let Some(c) = compiled {
        for (_, m) in &c.muts {
            if let Mutation::SetDelay(dm) = m {
                min_d = min_d.min(dm.min_delay());
            }
        }
    }
    let lookahead = min_d.max(1);

    let eval_cycles = if cfg.eval.at_cycles.is_empty() {
        eval::log_spaced_cycles(cfg.cycles)
    } else {
        cfg.eval.at_cycles.clone()
    };
    let mut eval_ticks: Vec<Ticks> = eval_cycles
        .iter()
        .map(|&c| c * cfg.delta)
        .filter(|&t| t <= horizon)
        .collect();
    eval_ticks.sort_unstable();

    let mut set: BTreeSet<Ticks> = BTreeSet::new();
    set.insert(0);
    set.insert(horizon);
    for c in 1..=cfg.cycles {
        set.insert(c * cfg.delta);
    }
    for &t in &eval_ticks {
        set.insert(t);
    }
    if let Some(c) = compiled {
        for &(t, _) in &c.muts {
            if t <= horizon {
                set.insert(t);
            }
        }
    }
    Plan {
        barriers: set.into_iter().collect(),
        eval_ticks,
        lookahead,
        horizon,
        label: format!("{}-{}-{}", cfg.learner.name(), cfg.variant.name(), cfg.sampler.name()),
    }
}

/// Drive the barrier schedule over a pool of runners: windows up to each
/// barrier, then cycle/scenario observer events and due measurements.
fn drive(
    pool: &mut dyn Pool,
    sh: &Shared<'_>,
    plan: &Plan,
    obs: &mut dyn Observer,
) -> Result<RunResult> {
    let delta = sh.cfg.delta;
    let mut curve = Curve::new(plan.label.clone());
    // the coordinator's mirror of the scenario timeline: drift bookkeeping
    // for eval labels plus the observer's mutation events
    let mut mirror = sh.compiled.clone().map(ScenarioDriver::new);
    let mut drift = 1.0f32;
    let mut observed_cycle = 0u64;
    let mut ei = 0usize;
    let mut cur: Ticks = 0;
    for &b in &plan.barriers {
        while cur < b {
            let end = (cur + plan.lookahead).min(b);
            pool.window(cur, end)?;
            cur = end;
        }
        while observed_cycle < b / delta {
            observed_cycle += 1;
            obs.on_event(&RunEvent::Cycle { cycle: observed_cycle });
        }
        while let Some(m) = mirror.as_mut().and_then(|d| d.pop_due(b)) {
            if matches!(m, Mutation::Drift) {
                drift = -drift;
            }
            obs.on_event(&RunEvent::Scenario { cycle: b / delta, mutation: m.describe() });
        }
        while ei < plan.eval_ticks.len() && plan.eval_ticks[ei] == b {
            ei += 1;
            let outs = pool.eval(drift < 0.0)?;
            let pt = assemble_point(sh, (b / delta).max(1), outs);
            obs.on_event(&RunEvent::Eval { point: pt.clone() });
            curve.push(pt);
        }
    }
    // events at exactly the horizon still apply (legacy `t <= horizon`
    // semantics); their effects land in the final stats, after the last
    // measurement
    pool.window(plan.horizon, plan.horizon + 1)?;
    let mut stats = pool.finish()?;
    stats.topology = sh.topology.as_ref().map(|t| *t.metrics());
    Ok(RunResult { curve, stats })
}

/// Reassemble per-runner eval slices into one curve point in global
/// evaluation-peer order.
fn assemble_point(sh: &Shared<'_>, cycle: u64, outs: Vec<EvalOut>) -> eval::EvalPoint {
    let mut errs = vec![0.0f64; sh.eval_peers.len()];
    let mut votes: Vec<(usize, f64)> = Vec::new();
    let mut models: Vec<(usize, LinearModel)> = Vec::new();
    let mut aucs: Vec<(usize, f64)> = Vec::new();
    let mut sent = 0u64;
    for out in outs {
        for (pos, e) in out.errs {
            errs[pos] = e;
        }
        votes.extend(out.votes);
        models.extend(out.models);
        aucs.extend(out.aucs);
        sent += out.sent;
    }
    let vote_errs: Option<Vec<f64>> = sh.cfg.eval.voting.then(|| {
        votes.sort_by_key(|&(pos, _)| pos);
        votes.iter().map(|&(_, v)| v).collect()
    });
    let similarity = sh.cfg.eval.similarity.then(|| {
        models.sort_by_key(|&(pos, _)| pos);
        let refs: Vec<&LinearModel> = models.iter().map(|(_, m)| m).collect();
        eval::mean_pairwise_cosine(&refs)
    });
    let auc_vals: Option<Vec<f64>> = sh.cfg.eval.auc.then(|| {
        aucs.sort_by_key(|&(pos, _)| pos);
        aucs.iter().map(|&(_, v)| v).collect()
    });
    point_from_errors(cycle, &errs, vote_errs.as_deref(), similarity, auc_vals.as_deref(), sent)
}

/// Build the shared setup for a run: compiled scenario, churn schedule,
/// evaluation peers, kernel-path resolution, shard ranges.
fn build_shared<'a>(
    cfg: &'a ProtocolConfig,
    data: &'a Dataset,
    backend_supports_sparse: bool,
    shards: usize,
) -> Shared<'a> {
    let n_univ = data.n_train();
    assert!(n_univ >= 2, "need at least two nodes");
    // the graph is a pure function of (spec, n_univ, seed) — generators
    // derive their own streams, so building it here consumes nothing from
    // the run RNG and the fork order below stays load-bearing and intact
    let topology = cfg.topology.as_ref().map(|spec| {
        Arc::new(
            Topology::build(spec, n_univ, cfg.seed)
                .expect("topology must be validated before the simulator runs"),
        )
    });
    let compiled = cfg.scenario.as_ref().map(|s| {
        Arc::new(
            CompiledScenario::compile(
                s,
                n_univ,
                cfg.delta,
                cfg.cycles,
                cfg.seed,
                cfg.network,
                topology.as_deref(),
            )
            .expect("scenario must be validated before the simulator runs"),
        )
    });
    let members0 = compiled.as_ref().map_or(n_univ, |c| c.initial);
    let mut rng = Rng::new(cfg.seed);
    // the schedule horizon covers one period past the run so in-flight
    // sessions do not truncate at the boundary (legacy constant)
    let sched_horizon = cfg.delta * (cfg.cycles + 1);
    let churn = resolve_churn_schedule(
        cfg.churn.as_ref(),
        compiled.as_deref(),
        n_univ,
        cfg.delta,
        sched_horizon,
        &mut rng,
    );
    // fork-order preservation: the sampler fork predates per-node streams
    // and keeps the eval-peer draw on its historical stream
    let _sampler_rng = rng.fork();
    let mut eval_rng = rng.fork();
    let eval_peers = eval_rng.sample_indices(members0, cfg.eval.n_peers.min(members0));

    let run_horizon = cfg.delta * cfg.cycles;
    let churn_online0 =
        churn.as_ref().map_or_else(|| Bitset::filled(n_univ, true), |ch| ch.online_at(0));
    let churn_events: Vec<(Ticks, NodeId, bool)> = churn
        .as_ref()
        .map(|ch| ch.events().into_iter().filter(|&(t, _, _)| t <= run_horizon).collect())
        .unwrap_or_default();

    let flipped_y = compiled
        .as_ref()
        .map_or(false, |c| c.muts.iter().any(|(_, m)| matches!(m, Mutation::Drift)))
        .then(|| eval::flipped_labels(&data.test_y));

    let sparse = match cfg.path {
        crate::gossip::protocol::ExecPath::Sparse => true,
        _ => backend_supports_sparse && cfg.path.use_sparse(&data.train),
    };
    let owned_csr = (sparse && matches!(data.train, Examples::Dense(_)))
        .then(|| data.train.to_csr());

    let bounds: Vec<usize> = (0..=shards).map(|i| i * n_univ / shards).collect();

    Shared {
        cfg,
        data,
        compiled,
        topology,
        churn_events,
        churn_online0,
        eval_peers,
        flipped_y,
        owned_csr,
        sparse,
        op: StepOp::for_protocol(&cfg.learner, cfg.variant, cfg.merge),
        res_cap: if cfg.learner.is_pairwise() { cfg.reservoir } else { 0 },
        members0,
        n_univ,
        bounds,
    }
}

/// Thin adapter so a caller-supplied `Box<dyn Backend>` can drive the
/// generic runner (single-shard path only; worker threads build their own
/// [`NativeBackend`]s).
struct BoxedBackend(Box<dyn Backend>);

impl Backend for BoxedBackend {
    fn name(&self) -> &'static str {
        self.0.name()
    }
    fn step(&mut self, op: &StepOp, batch: &mut StepBatch) -> Result<()> {
        self.0.step(op, batch)
    }
    fn supports_sparse(&self) -> bool {
        self.0.supports_sparse()
    }
    fn error_counts(
        &mut self,
        x: &[f32],
        y: &[f32],
        n: usize,
        d: usize,
        w: &[f32],
        m: usize,
    ) -> Result<Vec<f32>> {
        self.0.error_counts(x, y, n, d, w, m)
    }
    fn error_counts_examples(
        &mut self,
        test: &Examples,
        y: &[f32],
        w: &[f32],
        m: usize,
    ) -> Result<Vec<f32>> {
        // forward so a sparse-capable inner backend keeps its O(nnz) eval
        self.0.error_counts_examples(test, y, w, m)
    }
}

/// Run the event-driven protocol, sharded `cfg.shards` ways.
///
/// `shards = 1` runs a single full-range runner inline on the caller's
/// backend (any backend, PJRT included).  `shards ≥ 2` requires the native
/// backend and a non-MATCHING sampler; it leases worker threads from the
/// process-wide [`threads`] budget — a drained budget degrades to
/// single-thread multiplexing with identical results.
pub fn run_sharded(
    cfg: ProtocolConfig,
    data: &Dataset,
    backend: Box<dyn Backend>,
    obs: &mut dyn Observer,
) -> Result<RunResult> {
    let shards = cfg.shards.max(1).min(data.n_train());
    if shards >= 2 {
        if backend.name() != "native" {
            bail!(
                "sharded execution (shards = {shards}) requires the native backend; \
                 backend '{}' can only run with shards = 1",
                backend.name()
            );
        }
        if matches!(cfg.sampler, SamplerConfig::Matching) {
            bail!("the MATCHING sampler needs a global partner table; use shards = 1");
        }
    }
    let sh = build_shared(&cfg, data, backend.supports_sparse(), shards);
    let plan = build_plan(&cfg, sh.compiled.as_deref());

    // one mpsc lane per runner; every runner can send to every other.
    // Delivery lanes carry Envelopes out, recycle lanes carry consumed
    // weight buffers home to the shard whose pool allocated them.
    let (txs, rxs): (Vec<Sender<Envelope>>, Vec<Receiver<Envelope>>) =
        (0..shards).map(|_| channel()).unzip();
    let (rtxs, rrxs): (Vec<Sender<Vec<f32>>>, Vec<Receiver<Vec<f32>>>) =
        (0..shards).map(|_| channel()).unzip();

    if shards == 1 {
        let inbox = rxs.into_iter().next().expect("one lane");
        let recycle = rrxs.into_iter().next().expect("one lane");
        let runner = Runner::new(&sh, 0, BoxedBackend(backend), inbox, txs, recycle, rtxs);
        let mut pool = SerialPool { runners: vec![runner] };
        return drive(&mut pool, &sh, &plan, obs);
    }

    // shards ≥ 2: lease extra worker threads; the caller's thread drives
    // the coordinator (and all runners, if the budget is drained)
    let lease = threads::lease(shards - 1);
    let workers = (1 + lease.granted()).min(shards);
    let mut runners: Vec<Runner<'_, NativeBackend>> = Vec::with_capacity(shards);
    let mut rx_iter = rxs.into_iter();
    let mut rrx_iter = rrxs.into_iter();
    for i in 0..shards {
        let inbox = rx_iter.next().expect("one lane per runner");
        let recycle = rrx_iter.next().expect("one lane per runner");
        runners.push(Runner::new(
            &sh,
            i,
            NativeBackend::new(),
            inbox,
            txs.clone(),
            recycle,
            rtxs.clone(),
        ));
    }
    drop(txs);
    drop(rtxs);

    if workers == 1 {
        let mut pool = SerialPool { runners };
        return drive(&mut pool, &sh, &plan, obs);
    }

    std::thread::scope(|scope| {
        let (reply_tx, reply_rx) = channel::<Reply>();
        let mut cmd_txs = Vec::with_capacity(workers);
        // contiguous chunks of runners per worker; chunk sizes only affect
        // scheduling, never results
        let mut chunks: Vec<Vec<Runner<'_, NativeBackend>>> = Vec::with_capacity(workers);
        let total = runners.len();
        for w in (0..workers).rev() {
            let at = w * total / workers;
            chunks.push(runners.split_off(at));
        }
        for mut chunk in chunks {
            let (cmd_tx, cmd_rx) = channel::<Cmd>();
            cmd_txs.push(cmd_tx);
            let reply_tx = reply_tx.clone();
            scope.spawn(move || worker_loop(&mut chunk, cmd_rx, reply_tx));
        }
        drop(reply_tx);
        let mut pool = ThreadPool { cmds: cmd_txs, replies: reply_rx, n_runners: shards };
        let out = drive(&mut pool, &sh, &plan, obs);
        // dropping the pool closes the command channels; workers exit and
        // the scope joins them
        drop(pool);
        out
    })
}
