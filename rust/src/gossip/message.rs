//! The single message type of gossip learning (Algorithm 1 line 5): a model
//! plus the piggybacked NEWSCAST descriptors (Section IV — peer-sampling
//! gossip rides along with learning gossip, so the message complexity stays
//! one message per node per Δ).

use crate::p2p::newscast::Descriptor;
use crate::sim::event::NodeId;

#[derive(Clone, Debug)]
pub struct ModelMsg {
    pub src: NodeId,
    /// model weights; the semantically transmitted model is `scale * w`.
    /// In the sharded engine this buffer is recycled through the sending
    /// shard's [`crate::util::pool::BufPool`] once the receiver has consumed
    /// the message (DESIGN.md §14) — every fill path overwrites all `d`
    /// elements, so recycled contents never leak
    pub w: Vec<f32>,
    /// lazy scale of `w` (1.0 on the dense execution path).  This is a
    /// simulator-internal compute representation — a real deployment sends
    /// the materialized product, so the wire format is unchanged.
    pub scale: f32,
    /// Pegasos update counter
    pub t: u64,
    /// piggybacked peer-sampling descriptors (empty for oracle samplers)
    pub view: Vec<Descriptor>,
    /// example reservoir riding with the model (pairwise objectives,
    /// DESIGN.md §17): the packed `[seen, node0, y0, node1, y1, ...]` layout
    /// of `learning/pairwise`, empty for pointwise learners.  Like `w`, this
    /// buffer is recycled through the sending shard's
    /// [`crate::util::pool::BufPool`] once consumed.
    pub res: Vec<f32>,
}

/// Fixed per-frame overhead of the deployment wire format (net/wire.rs):
/// u32 length prefix + u8 version + u64 src + u64 t + u32 weight count +
/// u16 view count + u16 reservoir entry count = 29 bytes.  Shared with the
/// simulator's byte accounting so `RunStats::bytes_sent` matches what
/// `net/wire::encode` actually puts on a socket.
pub const WIRE_FRAME_OVERHEAD: usize = 4 + 1 + 8 + 8 + 4 + 2 + 2;

impl ModelMsg {
    /// Wire size in bytes of the full encoded frame:
    /// `WIRE_FRAME_OVERHEAD + d * 4 + |view| * 16 + reservoir bytes`, where
    /// a non-empty reservoir adds a u32 `seen` counter plus 8 bytes
    /// (u32 node + f32 label) per occupied slot.  Used by the
    /// message-complexity metrics (the paper's cost analysis in Section IV)
    /// and pinned to `net/wire::encode(&m).len()` exactly by test.  The lazy
    /// `scale` does not count: it is folded into the weights on a real wire.
    pub fn wire_bytes(&self) -> usize {
        let occ = crate::learning::pairwise::occupancy(&self.res);
        let res_bytes = if occ > 0 { 4 + 8 * occ } else { 0 };
        WIRE_FRAME_OVERHEAD + self.w.len() * 4 + self.view.len() * 16 + res_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_size_counts_all_fields_and_framing() {
        let msg = ModelMsg {
            src: 0,
            w: vec![0.0; 10],
            scale: 1.0,
            t: 3,
            view: vec![Descriptor { node: 1, ts: 2 }; 20],
            res: Vec::new(),
        };
        // regression: the old estimate (4d + 8) omitted the length prefix,
        // version byte, src, and the d/view count fields — 19 bytes/message
        assert_eq!(WIRE_FRAME_OVERHEAD, 29);
        assert_eq!(msg.wire_bytes(), 29 + 40 + 320);
    }

    #[test]
    fn wire_size_counts_reservoir_entries() {
        use crate::learning::pairwise::{offer, reservoir_new};
        let mut res = reservoir_new(4);
        offer(&mut res, 7, 1.0, 0);
        offer(&mut res, 9, -1.0, 0);
        let msg = ModelMsg {
            src: 0,
            w: vec![0.0; 10],
            scale: 1.0,
            t: 3,
            view: Vec::new(),
            res,
        };
        // u32 seen + 2 × (u32 node + f32 label) = 20 reservoir bytes
        assert_eq!(msg.wire_bytes(), 29 + 40 + 4 + 16);
        // an allocated-but-empty reservoir costs nothing beyond the count
        let empty = ModelMsg {
            src: 0,
            w: vec![0.0; 10],
            scale: 1.0,
            t: 0,
            view: Vec::new(),
            res: reservoir_new(4),
        };
        assert_eq!(empty.wire_bytes(), 29 + 40);
    }
}
