//! The gossip learning protocol: Algorithm 1 skeleton, the CREATEMODEL
//! variants (Algorithm 2), model caches and local prediction (Algorithm 4),
//! and the event-driven simulation driver.
pub mod cache;
pub mod create_model;
pub mod message;
pub mod predict;
pub mod protocol;
pub mod sharded;
pub mod state;

pub use cache::ModelCache;
pub use create_model::{create_model, Variant};
pub use predict::Predictor;
#[allow(deprecated)] // the shims stay re-exported for downstream callers
pub use protocol::{run, run_with_backend};
pub use protocol::{
    EvalConfig, ExecMode, ExecPath, GossipSim, ProtocolConfig, RunResult, RunStats,
};
pub use state::ModelStore;
