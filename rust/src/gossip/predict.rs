//! Local prediction (Algorithm 4): every node predicts from its own cache at
//! zero communication cost — either with the freshest model, or by majority
//! voting over the cached models, or by the margin-weighted vote of Eq. (7)
//! (which for linear models equals prediction by the averaged model).
//!
//! Margins are sparse-aware: `raw_margin` goes through [`Row::dot`], which
//! routes sparse test rows through the shared `data::dataset::sparse_dot`
//! (O(nnz) per vote instead of O(d); DESIGN.md §7).  Bulk freshest-model
//! evaluation over a whole test set uses the batched
//! `engine::Backend::error_counts_examples` path instead of per-row calls.

use crate::data::dataset::Row;
use crate::gossip::cache::ModelCache;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Predictor {
    /// sign(<freshest.w, x>)
    Freshest,
    /// Algorithm 4 VOTEDPREDICT: majority of sign votes over the cache.
    MajorityVote,
    /// Eq. (7): sign of the mean raw margin (margin-weighted voting).
    WeightedVote,
}

impl Predictor {
    pub fn name(&self) -> &'static str {
        match self {
            Predictor::Freshest => "freshest",
            Predictor::MajorityVote => "vote",
            Predictor::WeightedVote => "wvote",
        }
    }

    pub fn predict(&self, cache: &ModelCache, x: &Row<'_>) -> f32 {
        match self {
            Predictor::Freshest => cache.freshest().predict(x),
            Predictor::MajorityVote => {
                // Algorithm 4: pRatio counts sign(<w,x>) >= 0 votes
                let mut p = 0usize;
                for m in cache.iter() {
                    if m.raw_margin(x) >= 0.0 {
                        p += 1;
                    }
                }
                if 2 * p > cache.len() {
                    1.0
                } else {
                    -1.0
                }
            }
            Predictor::WeightedVote => {
                let s: f32 = cache.iter().map(|m| m.raw_margin(x)).sum();
                if s > 0.0 {
                    1.0
                } else {
                    -1.0
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::learning::linear::LinearModel;

    fn cache_with(ws: &[f32]) -> ModelCache {
        let mut c = ModelCache::new(10);
        for &w in ws {
            c.add(LinearModel::from_weights(vec![w], 0));
        }
        c
    }

    #[test]
    fn freshest_uses_last_model() {
        let c = cache_with(&[1.0, -1.0]);
        let x = [1.0];
        assert_eq!(Predictor::Freshest.predict(&c, &Row::Dense(&x)), -1.0);
    }

    #[test]
    fn majority_outvotes_freshest() {
        let c = cache_with(&[1.0, 1.0, 1.0, -1.0]);
        let x = [1.0];
        assert_eq!(Predictor::MajorityVote.predict(&c, &Row::Dense(&x)), 1.0);
    }

    #[test]
    fn weighted_vote_uses_margins() {
        // two weak +, one strong -: majority says +, weighted says -
        let c = cache_with(&[0.1, 0.1, -5.0]);
        let x = [1.0];
        assert_eq!(Predictor::MajorityVote.predict(&c, &Row::Dense(&x)), 1.0);
        assert_eq!(Predictor::WeightedVote.predict(&c, &Row::Dense(&x)), -1.0);
    }

    #[test]
    fn weighted_vote_equals_average_model_prediction() {
        // Eq. (6)/(7): weighted voting == prediction by the mean model
        use crate::util::rng::Rng;
        let mut rng = Rng::new(5);
        for _ in 0..50 {
            let mut c = ModelCache::new(8);
            let d = 6;
            let mut sum = vec![0.0f32; d];
            let k = 1 + rng.below_usize(8);
            for _ in 0..k {
                let w: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
                for (s, &wi) in sum.iter_mut().zip(&w) {
                    *s += wi;
                }
                c.add(LinearModel::from_weights(w, 0));
            }
            let avg: Vec<f32> = sum.iter().map(|s| s / k as f32).collect();
            let avg_model = LinearModel::from_weights(avg, 0);
            let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            let xr = Row::Dense(&x[..]);
            assert_eq!(
                Predictor::WeightedVote.predict(&c, &xr),
                avg_model.predict(&xr)
            );
        }
    }

    #[test]
    fn tie_breaks_negative() {
        let c = cache_with(&[1.0, -1.0]);
        let x = [1.0];
        assert_eq!(Predictor::MajorityVote.predict(&c, &Row::Dense(&x)), -1.0);
    }
}
