//! Bounded FIFO model cache (Algorithm 1): stores the most recent models
//! created at a node; `freshest` is what the active loop sends, and the full
//! cache backs the local voting predictor (Algorithm 4, cache size 10).

use crate::learning::linear::LinearModel;
use std::collections::VecDeque;

#[derive(Clone, Debug)]
pub struct ModelCache {
    models: VecDeque<LinearModel>,
    cap: usize,
}

impl ModelCache {
    pub fn new(cap: usize) -> Self {
        assert!(cap >= 1);
        ModelCache { models: VecDeque::with_capacity(cap), cap }
    }

    /// Add a model; evicts the oldest when full (Algorithm 1 line 8).
    pub fn add(&mut self, m: LinearModel) {
        if self.models.len() == self.cap {
            self.models.pop_front();
        }
        self.models.push_back(m);
    }

    /// The most recently added model (Algorithm 1 line 5).
    pub fn freshest(&self) -> &LinearModel {
        self.models.back().expect("cache never empty after init")
    }

    pub fn len(&self) -> usize {
        self.models.len()
    }

    pub fn is_empty(&self) -> bool {
        self.models.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn iter(&self) -> impl Iterator<Item = &LinearModel> {
        self.models.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(t: u64) -> LinearModel {
        LinearModel::from_weights(vec![t as f32], t)
    }

    #[test]
    fn fifo_eviction() {
        let mut c = ModelCache::new(3);
        for t in 0..5 {
            c.add(m(t));
        }
        assert_eq!(c.len(), 3);
        let ts: Vec<u64> = c.iter().map(|x| x.t).collect();
        assert_eq!(ts, vec![2, 3, 4]);
        assert_eq!(c.freshest().t, 4);
    }

    #[test]
    fn freshest_is_last_added() {
        let mut c = ModelCache::new(10);
        c.add(m(1));
        assert_eq!(c.freshest().t, 1);
        c.add(m(9));
        assert_eq!(c.freshest().t, 9);
    }

    #[test]
    #[should_panic]
    fn zero_capacity_rejected() {
        ModelCache::new(0);
    }
}
