//! [`Session`] and [`Outcome`] — the execution half of the facade
//! (DESIGN.md §12).
//!
//! `RunSpec::build()? -> Session`, `session.run(&mut impl Observer)? ->
//! Outcome`: one pipeline over every execution target.  The session owns (or
//! borrows) the resolved dataset, dispatches to the right driver, streams
//! [`crate::api::RunEvent`]s to the observer while the run executes, and
//! wraps the result in one [`Outcome`] type with uniform accessors for the
//! convergence curve, run statistics, and wire-cost totals.

use crate::api::error::GolfError;
use crate::api::observer::Observer;
use crate::api::spec::{RunSpec, Target};
use crate::config::BackendChoice;
use crate::coordinator::DeployReport;
use crate::data::dataset::Dataset;
use crate::engine::batched::BatchedSim;
use crate::engine::native::NativeBackend;
use crate::engine::pjrt::PjrtBackend;
use crate::eval::tracker::Curve;
use crate::experiments::sweep::{self, SweepCell, SweepConfig};
use crate::gossip::protocol::{GossipSim, ProtocolConfig, RunResult, RunStats};
use crate::net::deploy::DeployConfig;

/// The dataset a session runs against: built by [`RunSpec::build`] or
/// borrowed via [`RunSpec::build_with`].
enum Data<'d> {
    Owned(Dataset),
    Borrowed(&'d Dataset),
    /// sweep sessions build the three-dataset registry inside the grid
    /// runner instead
    Registry,
}

/// A validated, runnable configuration bound to its dataset.
pub struct Session<'d> {
    spec: RunSpec,
    data: Data<'d>,
    /// the resolved deployment configuration, validated once at build time
    /// ([`Target::Deploy`] only)
    deploy: Option<DeployConfig>,
}

impl Session<'static> {
    pub(crate) fn create_owned(spec: RunSpec) -> Result<Self, GolfError> {
        if spec.sweep.is_some() {
            return Ok(Session { spec, data: Data::Registry, deploy: None });
        }
        let data = spec.experiment.build_dataset()?;
        let deploy = check_against(&spec, &data)?;
        Ok(Session { spec, data: Data::Owned(data), deploy })
    }
}

impl<'d> Session<'d> {
    pub(crate) fn create_borrowed(spec: RunSpec, data: &'d Dataset) -> Result<Self, GolfError> {
        if spec.sweep.is_some() {
            return Err(GolfError::config(
                "a sweep builds its own dataset registry; use RunSpec::build"
                    .to_string(),
            ));
        }
        let deploy = check_against(&spec, data)?;
        Ok(Session { spec, data: Data::Borrowed(data), deploy })
    }

    /// The validated spec this session will execute.
    pub fn spec(&self) -> &RunSpec {
        &self.spec
    }

    /// The resolved dataset (`None` for sweep sessions, which run the
    /// three-dataset registry).
    pub fn data(&self) -> Option<&Dataset> {
        match &self.data {
            Data::Owned(d) => Some(d),
            Data::Borrowed(d) => Some(d),
            Data::Registry => None,
        }
    }

    /// The deployment configuration this session resolved at build time
    /// ([`Target::Deploy`] sessions only).
    pub fn deploy_config(&self) -> Option<&DeployConfig> {
        self.deploy.as_ref()
    }

    /// Execute the run, streaming progress events to `obs`.  Observation is
    /// passive: an observed run is bit-for-bit identical to an unobserved
    /// one.  Sweep sessions fan their cells across worker threads, so their
    /// per-cell events are not streamed — inspect the returned cells.
    pub fn run(&self, obs: &mut dyn Observer) -> Result<Outcome, GolfError> {
        if self.spec.sweep.is_some() {
            return self.run_sweep();
        }
        let data = self.data().expect("non-sweep sessions hold a dataset");
        match self.spec.target {
            Target::Sim => {
                let cfg = self.spec.experiment.protocol_config()?;
                let res = match self.spec.experiment.backend {
                    BackendChoice::Event => GossipSim::new(cfg, data)
                        .try_run_observed(obs)
                        .map_err(|e| GolfError::backend(format!("{e:#}")))?,
                    _ => {
                        let be = pjrt_backend()?;
                        GossipSim::with_backend(cfg, data, Box::new(be))
                            .try_run_observed(obs)
                            .map_err(|e| GolfError::backend(format!("{e:#}")))?
                    }
                };
                Ok(Outcome::Run(res))
            }
            Target::Batched => {
                let cfg = self.spec.experiment.protocol_config()?;
                let res = match self.spec.experiment.backend {
                    BackendChoice::BatchedPjrt => {
                        let mut be = pjrt_backend()?;
                        BatchedSim::new(cfg, data, &mut be)
                            .run_observed(obs)
                            .map_err(|e| GolfError::backend(format!("{e:#}")))?
                    }
                    _ => {
                        let mut be = NativeBackend::new();
                        BatchedSim::new(cfg, data, &mut be)
                            .run_observed(obs)
                            .map_err(|e| GolfError::backend(format!("{e:#}")))?
                    }
                };
                Ok(Outcome::Run(res))
            }
            Target::Deploy => {
                let dcfg = self
                    .deploy
                    .as_ref()
                    .expect("deploy sessions resolve their config at build time");
                let report = crate::coordinator::run_deployment_observed(dcfg, data, obs)
                    .map_err(|e| GolfError::io("deployment", e))?;
                Ok(Outcome::Deploy(report))
            }
        }
    }

    fn run_sweep(&self) -> Result<Outcome, GolfError> {
        let axes = self.spec.sweep.as_ref().expect("run_sweep needs axes");
        let e = &self.spec.experiment;
        let cfg = SweepConfig {
            scale: e.scale,
            cycles: e.cycles,
            variants: axes.variants.clone(),
            failures: axes.failures.clone(),
            scenarios: axes.scenarios.clone(),
            topologies: axes.topologies.clone(),
            replicates: axes.replicates,
            base_seed: e.seed,
            eval_peers: e.eval_peers,
            exec: e.exec_mode()?,
            path: e.exec_path,
            threads: axes.threads,
        };
        Ok(Outcome::Sweep(sweep::run_grid(&cfg)?))
    }
}

/// Dataset-dependent half of the single validation pass.  For deployments
/// the resolved [`DeployConfig`] is returned so the session (and its
/// callers) never re-derive it.
fn check_against(spec: &RunSpec, data: &Dataset) -> Result<Option<DeployConfig>, GolfError> {
    if data.name != spec.experiment.dataset {
        return Err(GolfError::data(format!(
            "spec names dataset {:?} but the provided dataset is {:?}",
            spec.experiment.dataset, data.name
        )));
    }
    if data.n_train() < 2 {
        return Err(GolfError::data(format!(
            "{} has {} training rows at scale {}; a gossip network needs at least 2 nodes",
            data.name,
            data.n_train(),
            spec.experiment.scale
        )));
    }
    match spec.target {
        Target::Deploy => {
            // deploy_config performs the full deployment validation: node
            // bounds, sampler feasibility, scenario fit
            Ok(Some(spec.to_deploy_spec().deploy_config(data)?))
        }
        _ => {
            spec.experiment.validate_scenario(data.n_train())?;
            Ok(None)
        }
    }
}

fn pjrt_backend() -> Result<PjrtBackend, GolfError> {
    PjrtBackend::new(&PjrtBackend::default_dir())
        .map_err(|e| GolfError::backend(format!("{e:#}")))
}

/// Run the simulator configuration matched to a deployment (same failure
/// models, RNG fork order, and measurement grid — see
/// [`crate::coordinator::matched_sim_config`]), observed.  Backs the CLI's
/// `--compare-sim`.
pub fn run_matched_sim(
    cfg: &DeployConfig,
    data: &Dataset,
    obs: &mut dyn Observer,
) -> Result<RunResult, GolfError> {
    let sim_cfg: ProtocolConfig = crate::coordinator::matched_sim_config(cfg);
    GossipSim::new(sim_cfg, data)
        .try_run_observed(obs)
        .map_err(|e| GolfError::backend(format!("{e:#}")))
}

/// The one result type of the facade: whichever driver ran, the outcome
/// exposes the convergence curve(s), statistics, and wire-cost totals
/// through the same accessors.
#[derive(Clone, Debug)]
pub enum Outcome {
    /// a single simulated run (event-driven or batched)
    Run(RunResult),
    /// a socket deployment run
    Deploy(DeployReport),
    /// a grid sweep, one cell per (dataset × variant × failures × scenario
    /// × replicate)
    Sweep(Vec<SweepCell>),
}

impl Outcome {
    /// The primary convergence curve: the run's curve, the deployment's
    /// curve, or the first sweep cell's curve (`None` only for an empty
    /// sweep).
    pub fn curve(&self) -> Option<&Curve> {
        match self {
            Outcome::Run(r) => Some(&r.curve),
            Outcome::Deploy(d) => Some(&d.curve),
            Outcome::Sweep(cells) => cells.first().map(|c| &c.curve),
        }
    }

    /// Every curve this outcome holds.
    pub fn curves(&self) -> Vec<&Curve> {
        match self {
            Outcome::Run(r) => vec![&r.curve],
            Outcome::Deploy(d) => vec![&d.curve],
            Outcome::Sweep(cells) => cells.iter().map(|c| &c.curve).collect(),
        }
    }

    /// Final mean 0-1 error of the primary curve.
    pub fn final_error(&self) -> Option<f64> {
        self.curve().map(|c| c.final_error())
    }

    /// Total protocol messages sent (summed over sweep cells).
    pub fn messages_sent(&self) -> u64 {
        match self {
            Outcome::Run(r) => r.stats.messages_sent,
            Outcome::Deploy(d) => d.stats.messages_sent,
            Outcome::Sweep(cells) => cells.iter().map(|c| c.stats.messages_sent).sum(),
        }
    }

    /// Total wire cost in bytes (summed over sweep cells).
    pub fn bytes_sent(&self) -> u64 {
        match self {
            Outcome::Run(r) => r.stats.bytes_sent,
            Outcome::Deploy(d) => d.stats.bytes_sent,
            Outcome::Sweep(cells) => cells.iter().map(|c| c.stats.bytes_sent).sum(),
        }
    }

    /// Simulation statistics, when this outcome is a single simulated run.
    pub fn run_stats(&self) -> Option<&RunStats> {
        match self {
            Outcome::Run(r) => Some(&r.stats),
            _ => None,
        }
    }

    pub fn run_result(&self) -> Option<&RunResult> {
        match self {
            Outcome::Run(r) => Some(r),
            _ => None,
        }
    }

    pub fn deploy_report(&self) -> Option<&DeployReport> {
        match self {
            Outcome::Deploy(d) => Some(d),
            _ => None,
        }
    }

    pub fn sweep_cells(&self) -> Option<&[SweepCell]> {
        match self {
            Outcome::Sweep(cells) => Some(cells),
            _ => None,
        }
    }

    pub fn into_run(self) -> Option<RunResult> {
        match self {
            Outcome::Run(r) => Some(r),
            _ => None,
        }
    }

    pub fn into_deploy(self) -> Option<DeployReport> {
        match self {
            Outcome::Deploy(d) => Some(d),
            _ => None,
        }
    }

    pub fn into_sweep(self) -> Option<Vec<SweepCell>> {
        match self {
            Outcome::Sweep(cells) => Some(cells),
            _ => None,
        }
    }
}
