//! The crate's single typed entry point (DESIGN.md §12): one `RunSpec →
//! Session → Outcome` pipeline over every execution regime the paper's
//! protocol runs under.
//!
//! The paper's contribution is *one* protocol observed under many regimes —
//! random walk vs. merge variants, failure scenarios, network sizes, real
//! sockets vs. simulation.  This module is the front door that keeps it one
//! protocol in code, too:
//!
//! * [`RunSpec`] — a validating builder unifying dataset selection, protocol
//!   parameters, execution mode/path, backend choice, scenario timelines,
//!   deployment ([`Target::Deploy`]), and sweep axes ([`SweepAxes`]);
//!   bidirectional with the INI layer ([`RunSpec::from_ini`] /
//!   [`RunSpec::to_ini`]).
//! * [`GolfError`] — the typed error enum every facade operation returns,
//!   with a stable per-variant CLI exit code ([`GolfError::exit_code`]).
//! * [`Session`] / [`Observer`] — `spec.build()? -> Session`,
//!   `session.run(&mut obs)? -> Outcome`; the observer receives typed
//!   [`RunEvent`]s (cycle boundaries, eval points, scenario mutations, node
//!   stats) streamed live from all three drivers.
//! * [`Outcome`] — one result type over single runs, deployments, and
//!   sweeps, with uniform curve/stats/wire-cost accessors.
//!
//! ```
//! use golf::api::{CurveRecorder, RunSpec};
//!
//! # fn main() -> Result<(), golf::api::GolfError> {
//! let mut rec = CurveRecorder::new();
//! let outcome = RunSpec::new("urls")
//!     .scale(0.005)
//!     .cycles(3)
//!     .eval_peers(5)
//!     .build()?
//!     .run(&mut rec)?;
//! // the streamed eval points are exactly the returned curve
//! assert_eq!(rec.eval_points().len(), outcome.curve().unwrap().points.len());
//! # Ok(())
//! # }
//! ```
//!
//! The legacy free functions (`gossip::run`, `gossip::run_with_backend`,
//! `engine::batched::run_batched`, `coordinator::run_deployment`) remain as
//! thin deprecated shims so existing parity pins stay bit-for-bit; new code
//! should construct runs here.

pub mod error;
pub mod observer;
pub mod session;
pub mod spec;

pub use error::GolfError;
pub use observer::{CurveRecorder, NullObserver, Observer, ProgressObserver, RunEvent};
pub use session::{run_matched_sim, Outcome, Session};
pub use spec::{RunSpec, SweepAxes, Target};
